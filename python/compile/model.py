"""L2: the LogicNets model in JAX — sparse-masked, activation-quantized MLP.

This is the training-time twin of the hardware view: every layer applies an
*input quantizer* (the paper's implicit quantizer, §4), a masked linear layer
with per-neuron fan-in (kernels/masked_linear.py), and batch normalization.
After training, each neuron collapses to a truth table over its fan-in codes;
the Rust side (rust/src/luts/) performs that export.

Everything here is lowered ONCE by aot.py to HLO text and driven from Rust —
python never runs on the request path.

Parameter/IO flattening contract (mirrored by rust/src/runtime/manifest.rs):

  train_step inputs :  w[0..L) , b[0..L) , gamma[0..L) , beta[0..L) ,
                       vw[0..L), vb[0..L), vgamma[0..L), vbeta[0..L),
                       mask[0..L), x[B,in], y[B] (i32), lr (f32 scalar)
  train_step outputs:  w', b', gamma', beta', vw', vb', vgamma', vbeta',
                       loss (f32 scalar),
                       gw[0..L)  (raw weight grads, for momentum pruning),
                       mu[0..L), var[0..L)  (batch stats, for EMA in Rust)

  forward inputs    :  w, b, gamma, beta, mask, rmean[0..L), rvar[0..L),
                       x[Be,in]
  forward outputs   :  logits [Be, classes]  (post output-quantizer)
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from .kernels.masked_linear import masked_linear
from .kernels.quantize import quantize

BN_EPS = 1e-5
MOMENTUM = 0.9


@dataclasses.dataclass
class ModelCfg:
    """Topology + quantization config.  Single source: configs/models.json."""

    name: str
    kind: str  # "mlp" | "cnn"
    in_features: int
    classes: int
    hidden: List[int]
    bw: int            # hidden activation bit-width
    bw_in: int         # input quantizer bit-width
    bw_out: int        # final-layer output quantizer bit-width (BW_fc)
    fanin: int         # synapses per hidden neuron (X)
    fanin_fc: Optional[int]  # final layer fan-in; None = dense
    skips: int = 0     # number of extra earlier activations concatenated
    batch: int = 128
    eval_batch: int = 256
    maxv_in: float = 1.0
    maxv_hidden: float = 2.0
    maxv_out: float = 4.0
    train_softmax: bool = True
    dataset: str = "jets"
    steps: int = 300
    lr: float = 0.02
    # CNN-only knobs (ignored for MLPs)
    channels: Optional[List[int]] = None
    kernel_size: int = 3
    fanin_dw: Optional[int] = None
    fanin_pw: Optional[int] = None
    conv_mode: str = "quant_x_dw"  # fp | fp_dw | fp_x_dw | quant_x_dw
    image_hw: int = 28

    @staticmethod
    def from_dict(name: str, d: dict) -> "ModelCfg":
        fields = {f.name for f in dataclasses.fields(ModelCfg)}
        kw = {k: v for k, v in d.items() if k in fields}
        kw["name"] = name
        return ModelCfg(**kw)

    # ---- derived topology ----------------------------------------------

    def layer_sizes(self) -> List[int]:
        """Output width of each layer (hidden layers + final classifier)."""
        return list(self.hidden) + [self.classes]

    def layer_inputs(self) -> List[int]:
        """Input width of each layer, accounting for skip concatenation.

        With ``skips=s`` the input of layer i (i>=1) is the concatenation of
        the last min(s,i)+1 activations (paper §7, Skip Connections).  The
        per-neuron fan-in is unchanged, so the LUT cost is unchanged.
        """
        widths = [self.in_features] + list(self.hidden)  # activation widths
        ins = []
        for i in range(len(widths)):
            lo = max(0, i - self.skips) if i > 0 else i
            ins.append(sum(widths[lo : i + 1]))
        return ins

    def layer_fanin(self, i: int) -> Optional[int]:
        last = len(self.hidden)
        if i == last:
            return self.fanin_fc
        return self.fanin

    def layer_bw_in(self, i: int) -> int:
        return self.bw_in if i == 0 else self.bw

    def layer_maxv_in(self, i: int) -> float:
        return self.maxv_in if i == 0 else self.maxv_hidden

    def num_layers(self) -> int:
        return len(self.hidden) + 1


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _skip_input(cfg: ModelCfg, acts: List[jnp.ndarray], i: int) -> jnp.ndarray:
    if i == 0 or cfg.skips == 0:
        return acts[-1]
    lo = max(0, i - cfg.skips)
    # acts holds [a_0 .. a_i]; concatenate a_i, a_{i-1}, ..., a_lo in order
    # newest-first (matches rust/src/nn/mlp.rs::skip_input).
    parts = [acts[j] for j in range(len(acts) - 1, lo - 1, -1)]
    return jnp.concatenate(parts, axis=1)


def forward_train(cfg: ModelCfg, params, masks, x):
    """Training-mode forward: batch-stat BN.  Returns (logits, mus, vars)."""
    a = quantize(x, cfg.bw_in, cfg.maxv_in)
    acts = [a]
    mus, vars_ = [], []
    n = cfg.num_layers()
    for i in range(n):
        w, b, gamma, beta = params[i]
        inp = _skip_input(cfg, acts, i)
        z = masked_linear(inp, w, masks[i], b)
        mu = jnp.mean(z, axis=0)
        var = jnp.mean((z - mu) ** 2, axis=0)
        zh = (z - mu) / jnp.sqrt(var + BN_EPS)
        y = gamma * zh + beta
        mus.append(mu)
        vars_.append(var)
        if i == n - 1:
            a = quantize(y, cfg.bw_out, cfg.maxv_out)
        else:
            a = quantize(y, cfg.bw, cfg.maxv_hidden)
            acts.append(a)
    return a, mus, vars_


def forward_eval(cfg: ModelCfg, params, masks, rmeans, rvars, x):
    """Inference-mode forward: running-stat BN (the exportable function)."""
    a = quantize(x, cfg.bw_in, cfg.maxv_in)
    acts = [a]
    n = cfg.num_layers()
    for i in range(n):
        w, b, gamma, beta = params[i]
        inp = _skip_input(cfg, acts, i)
        z = masked_linear(inp, w, masks[i], b)
        zh = (z - rmeans[i]) / jnp.sqrt(rvars[i] + BN_EPS)
        y = gamma * zh + beta
        if i == n - 1:
            a = quantize(y, cfg.bw_out, cfg.maxv_out)
        else:
            a = quantize(y, cfg.bw, cfg.maxv_hidden)
            acts.append(a)
    return a


def loss_fn(cfg: ModelCfg, params, masks, x, y):
    logits, mus, vars_ = forward_train(cfg, params, masks, x)
    onehot = jax.nn.one_hot(y, cfg.classes, dtype=logits.dtype)
    if cfg.train_softmax:
        # Softmax CE.  The quantized logit range is narrow (paper §6); the
        # 1/maxv_out temperature keeps gradients healthy without changing
        # the argmax (it is a fixed positive scale).
        logp = jax.nn.log_softmax(logits * (8.0 / cfg.maxv_out), axis=1)
        loss = -jnp.mean(jnp.sum(onehot * logp, axis=1))
    else:
        target = onehot * cfg.maxv_out
        loss = jnp.mean(jnp.sum((logits - target) ** 2, axis=1))
    return loss, (mus, vars_)


# ---------------------------------------------------------------------------
# Train step (SGD + momentum), flat-signature builders for AOT
# ---------------------------------------------------------------------------


def train_step(cfg: ModelCfg, params, vel, masks, x, y, lr):
    (loss, (mus, vars_)), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, masks, x, y), has_aux=True
    )(params)
    new_params, new_vel = [], []
    for p, v, g in zip(params, vel, grads):
        nv = tuple(MOMENTUM * vi + gi for vi, gi in zip(v, g))
        np_ = tuple(pi - lr * nvi for pi, nvi in zip(p, nv))
        new_params.append(np_)
        new_vel.append(nv)
    gws = [g[0] for g in grads]
    return new_params, new_vel, loss, gws, mus, vars_


def _group(flat, counts):
    out, i = [], 0
    for c in counts:
        out.append(list(flat[i : i + c]))
        i += c
    assert i == len(flat)
    return out


def build_train_step_flat(cfg: ModelCfg):
    """Flat-arg train step + example ShapeDtypeStructs for jax.jit().lower."""
    n = cfg.num_layers()
    ins = cfg.layer_inputs()
    outs = cfg.layer_sizes()

    def step(*flat):
        grouped = _group(flat[: 9 * n], [n] * 9)
        ws, bs, gs, bes, vws, vbs, vgs, vbes, masks = grouped
        x, y, lr = flat[9 * n], flat[9 * n + 1], flat[9 * n + 2]
        params = [(ws[i], bs[i], gs[i], bes[i]) for i in range(n)]
        vel = [(vws[i], vbs[i], vgs[i], vbes[i]) for i in range(n)]
        new_params, new_vel, loss, gws, mus, vars_ = train_step(
            cfg, params, vel, masks, x, y, lr
        )
        out = []
        for k in range(4):
            out.extend(p[k] for p in new_params)
        for k in range(4):
            out.extend(v[k] for v in new_vel)
        out.append(loss)
        out.extend(gws)
        out.extend(mus)
        out.extend(vars_)
        return tuple(out)

    f32 = jnp.float32
    ex = []
    ex += [jax.ShapeDtypeStruct((outs[i], ins[i]), f32) for i in range(n)]  # w
    ex += [jax.ShapeDtypeStruct((outs[i],), f32) for i in range(n)]          # b
    ex += [jax.ShapeDtypeStruct((outs[i],), f32) for i in range(n)]          # gamma
    ex += [jax.ShapeDtypeStruct((outs[i],), f32) for i in range(n)]          # beta
    ex = ex + list(ex)  # velocities mirror parameters
    ex += [jax.ShapeDtypeStruct((outs[i], ins[i]), f32) for i in range(n)]  # mask
    ex.append(jax.ShapeDtypeStruct((cfg.batch, cfg.in_features), f32))      # x
    ex.append(jax.ShapeDtypeStruct((cfg.batch,), jnp.int32))                # y
    ex.append(jax.ShapeDtypeStruct((), f32))                                # lr
    return step, ex


def build_forward_flat(cfg: ModelCfg):
    n = cfg.num_layers()
    ins = cfg.layer_inputs()
    outs = cfg.layer_sizes()

    def fwd(*flat):
        grouped = _group(flat[: 7 * n], [n] * 7)
        ws, bs, gs, bes, masks, rms, rvs = grouped
        x = flat[7 * n]
        params = [(ws[i], bs[i], gs[i], bes[i]) for i in range(n)]
        return (forward_eval(cfg, params, masks, rms, rvs, x),)

    f32 = jnp.float32
    ex = []
    ex += [jax.ShapeDtypeStruct((outs[i], ins[i]), f32) for i in range(n)]
    for _ in range(3):
        ex += [jax.ShapeDtypeStruct((outs[i],), f32) for i in range(n)]
    ex += [jax.ShapeDtypeStruct((outs[i], ins[i]), f32) for i in range(n)]
    for _ in range(2):
        ex += [jax.ShapeDtypeStruct((outs[i],), f32) for i in range(n)]
    ex.append(jax.ShapeDtypeStruct((cfg.eval_batch, cfg.in_features), f32))
    return fwd, ex
