"""AOT compiler: lower every named model config to HLO **text** artifacts.

For each model in configs/models.json this emits

    artifacts/<name>/train_step.hlo.txt
    artifacts/<name>/forward.hlo.txt
    artifacts/<name>/manifest.json

The Rust coordinator (rust/src/runtime/) loads the text with
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.
HLO *text* is the interchange format — jax >= 0.5 serializes protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Run via ``make artifacts`` (a no-op when inputs are unchanged).  Python never
runs on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import BN_EPS, MOMENTUM, ModelCfg, build_forward_flat, build_train_step_flat

# CNN builders are imported lazily (convmodel.py) to keep MLP-only runs fast.


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def manifest_for(cfg: ModelCfg) -> dict:
    if cfg.kind == "cnn":
        from .convmodel import conv_manifest_extra

        extra = conv_manifest_extra(cfg)
    else:
        extra = {
            "layers": [
                {
                    "in": cfg.layer_inputs()[i],
                    "out": cfg.layer_sizes()[i],
                    "fanin": cfg.layer_fanin(i),
                    "bw_in": cfg.layer_bw_in(i),
                    "maxv_in": cfg.layer_maxv_in(i),
                }
                for i in range(cfg.num_layers())
            ]
        }
    m = {
        "name": cfg.name,
        "kind": cfg.kind,
        "in_features": cfg.in_features,
        "classes": cfg.classes,
        "hidden": cfg.hidden,
        "bw": cfg.bw,
        "bw_in": cfg.bw_in,
        "bw_out": cfg.bw_out,
        "fanin": cfg.fanin,
        "fanin_fc": cfg.fanin_fc,
        "skips": cfg.skips,
        "batch": cfg.batch,
        "eval_batch": cfg.eval_batch,
        "maxv_in": cfg.maxv_in,
        "maxv_hidden": cfg.maxv_hidden,
        "maxv_out": cfg.maxv_out,
        "momentum": MOMENTUM,
        "bn_eps": BN_EPS,
        "dataset": cfg.dataset,
        "train_softmax": cfg.train_softmax,
        "steps": cfg.steps,
        "lr": cfg.lr,
        "conv_mode": cfg.conv_mode,
        "image_hw": cfg.image_hw,
        "channels": cfg.channels,
        "kernel_size": cfg.kernel_size,
        "fanin_dw": cfg.fanin_dw,
        "fanin_pw": cfg.fanin_pw,
    }
    m.update(extra)
    return m


def emit_model(cfg: ModelCfg, outdir: str, verbose: bool = True) -> None:
    mdir = os.path.join(outdir, cfg.name)
    os.makedirs(mdir, exist_ok=True)
    if cfg.kind == "cnn":
        from .convmodel import build_conv_forward_flat, build_conv_train_step_flat

        builders = [
            ("train_step", build_conv_train_step_flat),
            ("forward", build_conv_forward_flat),
        ]
    else:
        builders = [
            ("train_step", build_train_step_flat),
            ("forward", build_forward_flat),
        ]
    for tag, build in builders:
        fn, ex = build(cfg)
        lowered = jax.jit(fn).lower(*ex)
        text = to_hlo_text(lowered)
        path = os.path.join(mdir, f"{tag}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        if verbose:
            print(f"  {cfg.name}/{tag}: {len(text)} chars", flush=True)
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest_for(cfg), f, indent=1)


def load_configs(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="../configs/models.json")
    ap.add_argument(
        "--models",
        default="",
        help="comma-separated model names; default = all in the config file",
    )
    args = ap.parse_args()

    configs = load_configs(args.configs)
    names = [n for n in args.models.split(",") if n] or list(configs.keys())
    os.makedirs(args.out, exist_ok=True)
    for name in names:
        if name not in configs:
            print(f"unknown model {name!r}", file=sys.stderr)
            sys.exit(1)
        cfg = ModelCfg.from_dict(name, configs[name])
        print(f"lowering {name} ({cfg.kind}) ...", flush=True)
        emit_model(cfg, args.out)
    print(f"artifacts written to {args.out}")


if __name__ == "__main__":
    main()
