"""L2: LogicNets convolutional models — Sparse Depthwise-Separable
Convolutions with input/intermediate quantizers (paper §4.4, ch. 7).

Four variants (Table 7.4):
  fp          vanilla convolutions, full precision (baseline)
  fp_dw       depthwise-separable, full precision
  fp_x_dw     + per-kernel / per-neuron sparsity masks
  quant_x_dw  + activation quantization (the LogicNets-mappable variant)

All variants share the flat train_step/forward signature of model.py, so the
Rust driver is architecture-agnostic: every stage is a "layer" with a 2-D
weight `[out, in]`:

  quant_x_dw / fp_dw / fp_x_dw (5 layers):
    L0 dw1  [C1, k*k]     depthwise on the 1-channel input (first_layer
                          trick: one kernel per *output* channel, §4.4)
    L1 pw1  [F1, C1]      pointwise
    L2 dw2  [F1, k*k]     depthwise per channel
    L3 pw2  [F2, F1]      pointwise
    L4 head [classes, P2*F2]   dense classifier
  fp (3 layers):
    L0 conv1 [F1, k*k], L1 conv2 [F2, F1*k*k], L2 head

Spatial plan: 28 -> (stride 2, SAME) 14 -> (stride 2, SAME) 7; P1 = 196,
P2 = 49.

Skip connections (Table 7.6): with `skips >= 1`, pw2's input concatenates a
stride-2 subsample of pw1's output (wiring is free in hardware, so the
per-neuron fan-in — and hence LUT cost — is unchanged); with `skips >= 2`
the head additionally sees that subsampled map.  Masks are sized for the
concatenated widths.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.masked_linear import masked_linear
from .kernels.quantize import quantize
from .model import BN_EPS, ModelCfg, train_step as _shared_sgd  # noqa: F401

HW = 28
K = 3


def spatial_sizes(cfg: ModelCfg) -> Tuple[int, int]:
    h1 = (cfg.image_hw + 1) // 2
    h2 = (h1 + 1) // 2
    return h1, h2


def conv_layer_dims(cfg: ModelCfg) -> List[Tuple[int, int]]:
    """(out, in) dims of each 2-D weight, mirroring the docstring tables."""
    c1, f1, f2 = cfg.channels
    k2 = cfg.kernel_size * cfg.kernel_size
    _, h2 = spatial_sizes(cfg)
    p2 = h2 * h2
    if cfg.conv_mode == "fp":
        return [(f1, k2), (f2, f1 * k2), (cfg.classes, p2 * f2)]
    dims = [(c1, k2), (f1, c1), (f1, k2)]
    pw2_in = f1 * 2 if cfg.skips >= 1 else f1
    dims.append((f2, pw2_in))
    head_in = p2 * f2 + (p2 * f1 if cfg.skips >= 2 else 0)
    dims.append((cfg.classes, head_in))
    return dims


def conv_layer_fanins(cfg: ModelCfg) -> List[int | None]:
    sparse = cfg.conv_mode in ("fp_x_dw", "quant_x_dw")
    if cfg.conv_mode == "fp":
        return [None, None, None]
    if not sparse:
        return [None] * 5
    return [cfg.fanin_dw, cfg.fanin_pw, cfg.fanin_dw, cfg.fanin_pw, None]


def conv_layer_bws(cfg: ModelCfg) -> List[Tuple[int, float]]:
    """(bw_in, maxv_in) of the quantizer at each layer input."""
    q = cfg.conv_mode == "quant_x_dw"
    if cfg.conv_mode == "fp":
        return [(cfg.bw_in if q else 32, 1.0)] * 3
    bws = [(cfg.bw_in, cfg.maxv_in)]
    bws += [(cfg.bw, cfg.maxv_hidden)] * 4
    if not q:
        bws = [(32, m) for (_, m) in bws]
    return bws


def _q(x, bw: int, maxv: float):
    """Quantize unless bw is the FP sentinel (32)."""
    if bw >= 32:
        return x
    return quantize(x, bw, maxv)


def _patches(x, k: int, stride: int):
    """x [B, H, W, C] -> [B, Ho*Wo, C, k*k] with SAME padding."""
    b, h, w, c = x.shape
    ho = (h + stride - 1) // stride
    wo = (w + stride - 1) // stride
    pad_h = max((ho - 1) * stride + k - h, 0)
    pad_w = max((wo - 1) * stride + k - w, 0)
    xp = jnp.pad(
        x,
        ((0, 0), (pad_h // 2, pad_h - pad_h // 2), (pad_w // 2, pad_w - pad_w // 2), (0, 0)),
    )
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(xp[:, dy : dy + ho * stride : stride, dx : dx + wo * stride : stride, :])
    # [k*k] x [B, Ho, Wo, C] -> [B, Ho*Wo, C, k*k]
    st = jnp.stack(cols, axis=-1)
    return st.reshape(b, ho * wo, c, k * k)


def _bn(z, gamma, beta):
    """Batch norm over all axes but the last; returns (y, mu, var)."""
    axes = tuple(range(z.ndim - 1))
    mu = jnp.mean(z, axis=axes)
    var = jnp.mean((z - mu) ** 2, axis=axes)
    y = gamma * (z - mu) / jnp.sqrt(var + BN_EPS) + beta
    return y, mu, var


def _bn_eval(z, gamma, beta, rm, rv):
    return gamma * (z - rm) / jnp.sqrt(rv + BN_EPS) + beta


def conv_forward(cfg: ModelCfg, params, masks, x, rstats=None):
    """Shared train/eval forward.  `rstats=(rmeans, rvars)` switches to
    running statistics; otherwise batch stats are used and returned."""
    b = x.shape[0]
    bws = conv_layer_bws(cfg)
    h1, h2 = spatial_sizes(cfg)
    img = x.reshape(b, cfg.image_hw, cfg.image_hw, 1)
    mus, vars_ = [], []

    def bn_at(i, z):
        w_, b_, g_, be_ = params[i]
        if rstats is None:
            y, mu, var = _bn(z, g_, be_)
            mus.append(mu)
            vars_.append(var)
            return y
        return _bn_eval(z, g_, be_, rstats[0][i], rstats[1][i])

    a0 = _q(img, bws[0][0], bws[0][1])

    if cfg.conv_mode == "fp":
        k2 = cfg.kernel_size**2
        p1 = _patches(a0, cfg.kernel_size, 2).reshape(b * h1 * h1, k2)
        z1 = masked_linear(p1, params[0][0], masks[0], params[0][1])
        a1 = _q(bn_at(0, z1.reshape(b, h1 * h1, -1)), *bws[1])
        f1 = a1.shape[-1]
        p2 = _patches(a1.reshape(b, h1, h1, f1), cfg.kernel_size, 2)
        p2 = p2.reshape(b * h2 * h2, f1 * k2)
        z2 = masked_linear(p2, params[1][0], masks[1], params[1][1])
        a2 = _q(bn_at(1, z2.reshape(b, h2 * h2, -1)), *bws[2])
        flat = a2.reshape(b, -1)
        z3 = masked_linear(flat, params[2][0], masks[2], params[2][1])
        logits = _q(bn_at(2, z3), cfg.bw_out if cfg.conv_mode == "quant_x_dw" else 32, cfg.maxv_out)
        return logits, mus, vars_

    c1, f1n, f2n = cfg.channels
    k2 = cfg.kernel_size**2
    # dw1 (first_layer trick): matmul of 1-channel patches against C1 kernels
    p1 = _patches(a0, cfg.kernel_size, 2)[:, :, 0, :]  # [B, P1, k2]
    z = masked_linear(p1.reshape(b * h1 * h1, k2), params[0][0], masks[0], params[0][1])
    a = _q(bn_at(0, z.reshape(b, h1 * h1, c1)), *bws[1])
    # pw1
    z = masked_linear(a.reshape(b * h1 * h1, c1), params[1][0], masks[1], params[1][1])
    pw1 = _q(bn_at(1, z.reshape(b, h1 * h1, f1n)), *bws[2])
    # dw2: per-channel over patches of pw1
    p2 = _patches(pw1.reshape(b, h1, h1, f1n), cfg.kernel_size, 2)  # [B,P2,F1,k2]
    wm2 = params[2][0] * masks[2]
    z = jnp.einsum("bpct,ct->bpc", p2, wm2) + params[2][1]
    dw2 = _q(bn_at(2, z), *bws[3])  # [B, P2, F1]
    # optional skip: stride-2 subsample of pw1 concatenated channel-wise
    if cfg.skips >= 1:
        sub = pw1.reshape(b, h1, h1, f1n)[:, ::2, ::2, :][:, :h2, :h2, :]
        sub = sub.reshape(b, h2 * h2, f1n)
        pw2_in = jnp.concatenate([dw2, sub], axis=-1)
    else:
        pw2_in = dw2
    z = masked_linear(
        pw2_in.reshape(b * h2 * h2, pw2_in.shape[-1]), params[3][0], masks[3], params[3][1]
    )
    pw2 = _q(bn_at(3, z.reshape(b, h2 * h2, f2n)), *bws[4])
    flat = pw2.reshape(b, -1)
    if cfg.skips >= 2:
        sub = pw1.reshape(b, h1, h1, f1n)[:, ::2, ::2, :][:, :h2, :h2, :]
        flat = jnp.concatenate([flat, sub.reshape(b, -1)], axis=1)
    z = masked_linear(flat, params[4][0], masks[4], params[4][1])
    out_bw = cfg.bw_out if cfg.conv_mode == "quant_x_dw" else 32
    logits = _q(bn_at(4, z), out_bw, cfg.maxv_out)
    return logits, mus, vars_


def conv_loss(cfg: ModelCfg, params, masks, x, y):
    logits, mus, vars_ = conv_forward(cfg, params, masks, x)
    onehot = jax.nn.one_hot(y, cfg.classes, dtype=logits.dtype)
    if cfg.conv_mode == "quant_x_dw":
        logits = logits * (8.0 / cfg.maxv_out)
    logp = jax.nn.log_softmax(logits, axis=1)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=1))
    return loss, (mus, vars_)


def conv_manifest_extra(cfg: ModelCfg) -> dict:
    dims = conv_layer_dims(cfg)
    fanins = conv_layer_fanins(cfg)
    bws = conv_layer_bws(cfg)
    return {
        "layers": [
            {
                "in": din,
                "out": dout,
                "fanin": fanins[i],
                "bw_in": bws[i][0],
                "maxv_in": bws[i][1],
            }
            for i, (dout, din) in enumerate(dims)
        ]
    }


def _group(flat, counts):
    out, i = [], 0
    for c in counts:
        out.append(list(flat[i : i + c]))
        i += c
    assert i == len(flat)
    return out


def build_conv_train_step_flat(cfg: ModelCfg):
    from .model import MOMENTUM

    dims = conv_layer_dims(cfg)
    n = len(dims)

    def step(*flat):
        grouped = _group(flat[: 9 * n], [n] * 9)
        ws, bs, gs, bes, vws, vbs, vgs, vbes, masks = grouped
        x, y, lr = flat[9 * n], flat[9 * n + 1], flat[9 * n + 2]
        params = [(ws[i], bs[i], gs[i], bes[i]) for i in range(n)]
        vel = [(vws[i], vbs[i], vgs[i], vbes[i]) for i in range(n)]
        (loss, (mus, vars_)), grads = jax.value_and_grad(
            lambda p: conv_loss(cfg, p, masks, x, y), has_aux=True
        )(params)
        new_params, new_vel = [], []
        for p, v, g in zip(params, vel, grads):
            nv = tuple(MOMENTUM * vi + gi for vi, gi in zip(v, g))
            np_ = tuple(pi - lr * nvi for pi, nvi in zip(p, nv))
            new_params.append(np_)
            new_vel.append(nv)
        out = []
        for k in range(4):
            out.extend(p[k] for p in new_params)
        for k in range(4):
            out.extend(v[k] for v in new_vel)
        out.append(loss)
        out.extend(g[0] for g in grads)
        out.extend(mus)
        out.extend(vars_)
        return tuple(out)

    f32 = jnp.float32
    ex = []
    ex += [jax.ShapeDtypeStruct(d, f32) for d in dims]  # w
    for _ in range(3):
        ex += [jax.ShapeDtypeStruct((d[0],), f32) for d in dims]
    ex += [jax.ShapeDtypeStruct(d, f32) for d in dims]  # vw
    for _ in range(3):
        ex += [jax.ShapeDtypeStruct((d[0],), f32) for d in dims]
    ex += [jax.ShapeDtypeStruct(d, f32) for d in dims]  # masks
    ex.append(jax.ShapeDtypeStruct((cfg.batch, cfg.image_hw * cfg.image_hw), f32))
    ex.append(jax.ShapeDtypeStruct((cfg.batch,), jnp.int32))
    ex.append(jax.ShapeDtypeStruct((), f32))
    return step, ex


def build_conv_forward_flat(cfg: ModelCfg):
    dims = conv_layer_dims(cfg)
    n = len(dims)

    def fwd(*flat):
        grouped = _group(flat[: 7 * n], [n] * 7)
        ws, bs, gs, bes, masks, rms, rvs = grouped
        x = flat[7 * n]
        params = [(ws[i], bs[i], gs[i], bes[i]) for i in range(n)]
        logits, _, _ = conv_forward(cfg, params, masks, x, rstats=(rms, rvs))
        return (logits,)

    f32 = jnp.float32
    ex = []
    ex += [jax.ShapeDtypeStruct(d, f32) for d in dims]
    for _ in range(3):
        ex += [jax.ShapeDtypeStruct((d[0],), f32) for d in dims]
    ex += [jax.ShapeDtypeStruct(d, f32) for d in dims]
    for _ in range(2):
        ex += [jax.ShapeDtypeStruct((d[0],), f32) for d in dims]
    ex.append(jax.ShapeDtypeStruct((cfg.eval_batch, cfg.image_hw * cfg.image_hw), f32))
    return fwd, ex
