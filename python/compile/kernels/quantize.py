"""L1 Pallas kernels: activation quantizers (QuantReLU / QuantHardTanh).

LogicNets quantizes the *activations* entering every layer; the weights stay
full-precision.  The quantizer is the contract between the JAX training graph
and the Rust export path (truth-table generation), so the math here must match
``rust/src/nn/quant.rs`` bit-for-bit:

* bit-width 1 (QuantHardTanh):  value = sign(x) * max_val, code c in {0,1},
  value = (2c - 1) * max_val.
* bit-width b > 1 (QuantReLU):  step s = max_val / (2^b - 1),
  code c = clamp(round_ties_even(x / s), 0, 2^b - 1), value = c * s.

``jnp.round`` rounds half-to-even, as does Rust's ``f32::round_ties_even`` —
this is why the two sides agree exactly.

Kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the BlockSpec tiling below is still the schedule a real TPU
lowering would use (rows of the activation matrix stream HBM->VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantize", "quant_codes", "dequant_codes"]


def _levels(bw: int) -> float:
    return float(2**bw - 1)


def _quant_relu_kernel(x_ref, o_ref, *, step: float, levels: float):
    x = x_ref[...]
    c = jnp.clip(jnp.round(x / step), 0.0, levels)
    o_ref[...] = c * step


def _quant_ht_kernel(x_ref, o_ref, *, maxv: float):
    x = x_ref[...]
    o_ref[...] = jnp.where(x >= 0.0, maxv, -maxv)


def _row_grid(x):
    """Tile the leading (batch) dimension when it divides evenly.

    On TPU this is the HBM->VMEM schedule: one block of rows at a time; the
    quantizer is purely elementwise so no halo is needed.
    """
    if x.ndim >= 2 and x.shape[0] % 8 == 0 and x.shape[0] > 8:
        bm = 8
        grid = (x.shape[0] // bm,)
        block = (bm,) + x.shape[1:]
        nidx = len(x.shape) - 1
        index_map = lambda i: (i,) + (0,) * nidx
        spec = pl.BlockSpec(block, index_map)
        return grid, spec
    spec = pl.BlockSpec(x.shape, lambda: (0,) * x.ndim)
    return (), spec


def _quant_impl(x, bw: int, maxv: float):
    grid, spec = _row_grid(x)
    if bw == 1:
        kern = functools.partial(_quant_ht_kernel, maxv=maxv)
    else:
        kern = functools.partial(
            _quant_relu_kernel, step=maxv / _levels(bw), levels=_levels(bw)
        )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def quantize(x, bw: int, maxv: float):
    """Quantize activations to ``bw`` bits; straight-through gradient."""
    return _quant_impl(x, bw, maxv)


def _quantize_fwd(x, bw, maxv):
    return _quant_impl(x, bw, maxv), x


def _quantize_bwd(bw, maxv, x, g):
    # Clipped straight-through estimator.  QuantHardTanh passes gradient in
    # [-maxv, maxv]; QuantReLU passes it where the input lands inside the
    # representable range (ReLU-like dead zone below 0).
    if bw == 1:
        mask = jnp.abs(x) <= maxv
    else:
        mask = (x >= 0.0) & (x <= maxv)
    return (g * mask.astype(g.dtype),)


quantize.defvjp(_quantize_fwd, _quantize_bwd)


def quant_codes(x, bw: int, maxv: float):
    """Integer codes of the quantizer (the truth-table input/output bits)."""
    if bw == 1:
        return (x >= 0.0).astype(jnp.int32)
    step = maxv / _levels(bw)
    return jnp.clip(jnp.round(x / step), 0.0, _levels(bw)).astype(jnp.int32)


def dequant_codes(c, bw: int, maxv: float):
    """Inverse of :func:`quant_codes` (codes -> representable float values)."""
    if bw == 1:
        return (2.0 * c.astype(jnp.float32) - 1.0) * maxv
    step = maxv / _levels(bw)
    return c.astype(jnp.float32) * step
