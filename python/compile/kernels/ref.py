"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

pytest (python/tests/) sweeps shapes/dtypes with hypothesis and asserts
``assert_allclose(kernel(...), ref(...))``.  Nothing in here may import
pallas.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "quantize_ref",
    "quant_codes_ref",
    "masked_linear_ref",
    "lut_lookup_ref",
    "batchnorm_ref",
]


def quantize_ref(x, bw: int, maxv: float):
    if bw == 1:
        return jnp.where(x >= 0.0, maxv, -maxv)
    levels = float(2**bw - 1)
    step = maxv / levels
    return jnp.clip(jnp.round(x / step), 0.0, levels) * step


def quant_codes_ref(x, bw: int, maxv: float):
    if bw == 1:
        return (x >= 0.0).astype(jnp.int32)
    levels = float(2**bw - 1)
    step = maxv / levels
    return jnp.clip(jnp.round(x / step), 0.0, levels).astype(jnp.int32)


def masked_linear_ref(x, w, mask, b):
    return x @ (w * mask).T + b[None, :]


def lut_lookup_ref(codes, table, bw: int):
    fanin = codes.shape[1]
    idx = jnp.zeros(codes.shape[0], dtype=jnp.int32)
    for j in range(fanin):
        idx = idx | (codes[:, j] << (bw * j))
    return table[idx]


def batchnorm_ref(z, gamma, beta, eps: float = 1e-5):
    mu = jnp.mean(z, axis=0)
    var = jnp.mean((z - mu) ** 2, axis=0)
    return gamma * (z - mu) / jnp.sqrt(var + eps) + beta, mu, var
