"""L1 Pallas kernel: the LogicNets hot-spot — sparse-masked linear layer.

``z = x @ (w * mask)^T + b`` where ``mask`` encodes the per-neuron fan-in
(exactly ``F`` ones per output row).  The mask is applied *inside* the kernel
so the masked weight product never round-trips through HBM, and the matmul
feeds the MXU-shaped ``dot`` directly — this is the TPU re-think of what the
paper's PyTorch stack does with a dense cuDNN GEMM plus an elementwise mask.

Backward is implemented as two more Pallas kernels (dx and dw) wired up with
``jax.custom_vjp`` because ``pallas_call`` has no automatic transpose rule.

All kernels use ``interpret=True`` (see kernels/quantize.py for why).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["masked_linear"]

# Row-block size for the batch dimension.  8 is the TPU sublane count; on CPU
# interpret mode it just bounds the working set of a block.
_BM = 8


def _fwd_kernel(x_ref, w_ref, m_ref, b_ref, o_ref):
    x = x_ref[...]                       # [bm, I]
    wm = w_ref[...] * m_ref[...]         # [O, I] masked in-register
    o_ref[...] = x @ wm.T + b_ref[...][None, :]


def _dx_kernel(g_ref, w_ref, m_ref, o_ref):
    g = g_ref[...]                       # [bm, O]
    wm = w_ref[...] * m_ref[...]         # [O, I]
    o_ref[...] = g @ wm


def _dw_kernel(g_ref, x_ref, m_ref, o_ref):
    g = g_ref[...]                       # [B, O]
    x = x_ref[...]                       # [B, I]
    o_ref[...] = (g.T @ x) * m_ref[...]  # [O, I]


def _batch_grid(b: int):
    if b % _BM == 0 and b > _BM:
        return (b // _BM,), _BM
    return (), b


def _fwd_impl(x, w, mask, b):
    bsz, i = x.shape
    o = w.shape[0]
    grid, bm = _batch_grid(bsz)
    if grid:
        in_specs = [
            pl.BlockSpec((bm, i), lambda n: (n, 0)),
            pl.BlockSpec((o, i), lambda n: (0, 0)),
            pl.BlockSpec((o, i), lambda n: (0, 0)),
            pl.BlockSpec((o,), lambda n: (0,)),
        ]
        out_specs = pl.BlockSpec((bm, o), lambda n: (n, 0))
    else:
        in_specs = [
            pl.BlockSpec((bm, i), lambda: (0, 0)),
            pl.BlockSpec((o, i), lambda: (0, 0)),
            pl.BlockSpec((o, i), lambda: (0, 0)),
            pl.BlockSpec((o,), lambda: (0,)),
        ]
        out_specs = pl.BlockSpec((bm, o), lambda: (0, 0))
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct((bsz, o), x.dtype),
        interpret=True,
    )(x, w, mask, b)


def _dx_impl(g, w, mask):
    bsz, o = g.shape
    i = w.shape[1]
    grid, bm = _batch_grid(bsz)
    if grid:
        in_specs = [
            pl.BlockSpec((bm, o), lambda n: (n, 0)),
            pl.BlockSpec((o, i), lambda n: (0, 0)),
            pl.BlockSpec((o, i), lambda n: (0, 0)),
        ]
        out_specs = pl.BlockSpec((bm, i), lambda n: (n, 0))
    else:
        in_specs = [
            pl.BlockSpec((bm, o), lambda: (0, 0)),
            pl.BlockSpec((o, i), lambda: (0, 0)),
            pl.BlockSpec((o, i), lambda: (0, 0)),
        ]
        out_specs = pl.BlockSpec((bm, i), lambda: (0, 0))
    return pl.pallas_call(
        _dx_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct((bsz, i), g.dtype),
        interpret=True,
    )(g, w, mask)


def _dw_impl(g, x, mask):
    bsz, o = g.shape
    i = x.shape[1]
    full = lambda *shape: pl.BlockSpec(shape, lambda: (0,) * len(shape))
    return pl.pallas_call(
        _dw_kernel,
        grid=(),
        in_specs=[full(bsz, o), full(bsz, i), full(o, i)],
        out_specs=full(o, i),
        out_shape=jax.ShapeDtypeStruct((o, i), g.dtype),
        interpret=True,
    )(g, x, mask)


@jax.custom_vjp
def masked_linear(x, w, mask, b):
    """``x @ (w*mask)^T + b`` with per-neuron fan-in mask fused in-kernel."""
    return _fwd_impl(x, w, mask, b)


def _ml_fwd(x, w, mask, b):
    return _fwd_impl(x, w, mask, b), (x, w, mask)


def _ml_bwd(res, g):
    x, w, mask = res
    dx = _dx_impl(g, w, mask)
    dw = _dw_impl(g, x, mask)
    db = jnp.sum(g, axis=0)
    # The mask is a structural constant; its cotangent is never used.
    return dx, dw, jnp.zeros_like(mask), db


masked_linear.defvjp(_ml_fwd, _ml_bwd)
