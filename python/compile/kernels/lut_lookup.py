"""L1 Pallas kernel: truth-table (LUT) neuron evaluation.

After training, every LogicNets neuron *is* a truth table: its fan-in
activation codes are packed into an integer index and the output value is a
single gather.  This kernel is the software model of the FPGA inference path
(one LUT read per neuron per cycle, initiation interval 1) and is used to
cross-check the Rust serving engine (`rust/src/serve/`) against the JAX graph.

``codes``  [B, F] int32 — quantizer codes of the fan-in activations
``table``  [2^(F*bw)] f32 — dequantized neuron output per input pattern
returns    [B] f32
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lut_lookup"]


def _lut_kernel(codes_ref, table_ref, o_ref, *, bw: int, fanin: int):
    codes = codes_ref[...]               # [B, F]
    table = table_ref[...]               # [2^(F*bw)]
    idx = jnp.zeros(codes.shape[:1], dtype=jnp.int32)
    # Bit-pack: input j occupies bits [j*bw, (j+1)*bw).  Matches
    # rust/src/luts/table.rs::pack_index exactly.
    for j in range(fanin):
        idx = idx | (codes[:, j] << (bw * j))
    o_ref[...] = jnp.take(table, idx, axis=0)


def lut_lookup(codes, table, bw: int):
    bsz, fanin = codes.shape
    assert table.shape[0] == 1 << (fanin * bw), (table.shape, fanin, bw)
    full = lambda *shape: pl.BlockSpec(shape, lambda: (0,) * len(shape))
    return pl.pallas_call(
        functools.partial(_lut_kernel, bw=bw, fanin=fanin),
        grid=(),
        in_specs=[full(bsz, fanin), full(table.shape[0])],
        out_specs=full(bsz),
        out_shape=jax.ShapeDtypeStruct((bsz,), table.dtype),
        interpret=True,
    )(codes, table)
