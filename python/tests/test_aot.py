# pytest: the AOT path — lowering to HLO text and manifest consistency.

import json
import os

import pytest

from compile.aot import load_configs, manifest_for, to_hlo_text
from compile.model import ModelCfg, build_forward_flat, build_train_step_flat

import jax


def small_cfg():
    return ModelCfg(
        name="aot_t",
        kind="mlp",
        in_features=8,
        classes=3,
        hidden=[12],
        bw=2,
        bw_in=2,
        bw_out=2,
        fanin=3,
        fanin_fc=None,
        batch=16,
        eval_batch=16,
    )


def test_hlo_text_emission():
    cfg = small_cfg()
    for build in (build_train_step_flat, build_forward_flat):
        fn, ex = build(cfg)
        lowered = jax.jit(fn).lower(*ex)
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule"), text[:60]
        # return_tuple=True: the root computation returns a tuple
        assert "ROOT" in text


def test_manifest_matches_cfg():
    cfg = small_cfg()
    man = manifest_for(cfg)
    assert man["name"] == "aot_t"
    assert [l["in"] for l in man["layers"]] == [8, 12]
    assert [l["out"] for l in man["layers"]] == [12, 3]
    assert man["layers"][0]["fanin"] == 3
    assert man["layers"][1]["fanin"] is None
    assert man["layers"][0]["bw_in"] == 2
    # json-serializable
    json.dumps(man)


def test_config_registry_is_consistent():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "configs", "models.json")
    configs = load_configs(path)
    assert len(configs) > 50, "full registry expected"
    for name, d in configs.items():
        cfg = ModelCfg.from_dict(name, d)
        assert cfg.kind in ("mlp", "cnn"), name
        if cfg.kind == "mlp":
            ins = cfg.layer_inputs()
            outs = cfg.layer_sizes()
            assert len(ins) == len(outs) == cfg.num_layers()
            # every sparse layer's truth table must be generable (<=24 bits)
            for i in range(cfg.num_layers()):
                f = cfg.layer_fanin(i)
                if f is not None:
                    assert f * cfg.layer_bw_in(i) <= 24, (name, i)
        else:
            from compile.convmodel import conv_layer_dims

            dims = conv_layer_dims(cfg)
            assert dims[-1][0] == cfg.classes


def test_manifest_for_cnn_has_stage_layers():
    from compile.convmodel import conv_layer_dims

    cfg = ModelCfg(
        name="c",
        kind="cnn",
        in_features=784,
        classes=10,
        hidden=[],
        bw=2,
        bw_in=2,
        bw_out=4,
        fanin=0,
        fanin_fc=None,
        batch=8,
        eval_batch=8,
        channels=[6, 8, 10],
        fanin_dw=5,
        fanin_pw=4,
        conv_mode="quant_x_dw",
    )
    man = manifest_for(cfg)
    dims = conv_layer_dims(cfg)
    assert len(man["layers"]) == len(dims)
    assert man["layers"][0]["out"] == 6
    assert man["layers"][-1]["out"] == 10
