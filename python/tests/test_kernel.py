# pytest: Pallas kernels vs the pure-jnp oracle (ref.py) — the CORE
# correctness signal of L1.  hypothesis sweeps shapes, bit-widths and value
# ranges; assert_allclose against ref for values, exact equality for codes.

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.lut_lookup import lut_lookup
from compile.kernels.masked_linear import masked_linear
from compile.kernels.quantize import dequant_codes, quant_codes, quantize

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape, scale=2.0):
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    bw=st.integers(1, 6),
    rows=st.integers(1, 33),
    cols=st.integers(1, 17),
    maxv=st.sampled_from([1.0, 2.0, 4.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(bw, rows, cols, maxv, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, rows, cols)
    got = quantize(x, bw, maxv)
    want = ref.quantize_ref(x, bw, maxv)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@settings(**SETTINGS)
@given(bw=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_quant_codes_roundtrip(bw, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, 16, 8)
    codes = quant_codes(x, bw, 2.0)
    assert np.asarray(codes).min() >= 0
    assert np.asarray(codes).max() < 2**bw
    # dequant(code) must be a fixed point of the quantizer
    vals = dequant_codes(codes, bw, 2.0)
    again = quant_codes(vals, bw, 2.0)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(again))


def test_quantize_ste_gradient():
    # Gradient must pass inside the representable range, be zero outside.
    x = jnp.array([-1.0, 0.5, 1.5, 3.0])
    g = jax.grad(lambda v: jnp.sum(quantize(v, 2, 2.0)))(x)
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 0.0])
    g1 = jax.grad(lambda v: jnp.sum(quantize(v, 1, 1.0)))(x)
    np.testing.assert_array_equal(np.asarray(g1), [1.0, 1.0, 0.0, 0.0])


# ---------------------------------------------------------------------------
# masked_linear
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 3, 8, 24, 64]),
    i=st.integers(1, 40),
    o=st.integers(1, 24),
    fanin=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_linear_matches_ref(b, i, o, fanin, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b, i)
    w = rand(rng, o, i, scale=1.0)
    bias = rand(rng, o, scale=0.2)
    mask = np.zeros((o, i), np.float32)
    for r in range(o):
        mask[r, rng.choice(i, min(fanin, i), replace=False)] = 1.0
    mask = jnp.asarray(mask)
    got = masked_linear(x, w, mask, bias)
    want = ref.masked_linear_ref(x, w, mask, bias)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_masked_linear_grads_match_ref(seed):
    rng = np.random.default_rng(seed)
    b, i, o = 16, 12, 7
    x = rand(rng, b, i)
    w = rand(rng, o, i, scale=1.0)
    bias = rand(rng, o, scale=0.2)
    mask = jnp.asarray((rng.random((o, i)) < 0.3).astype(np.float32))

    def loss_kernel(x, w, bias):
        return jnp.sum(masked_linear(x, w, mask, bias) ** 2)

    def loss_ref(x, w, bias):
        return jnp.sum(ref.masked_linear_ref(x, w, mask, bias) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, bias)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, bias)
    for a, b_ in zip(gk, gr):
        assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-4)
    # weight gradient must respect the mask (no gradient off-mask)
    assert np.all(np.asarray(gk[1])[np.asarray(mask) == 0] == 0)


def test_masked_linear_under_jit():
    rng = np.random.default_rng(0)
    x = rand(rng, 8, 10)
    w = rand(rng, 4, 10)
    bias = rand(rng, 4)
    mask = jnp.ones((4, 10), jnp.float32)
    f = jax.jit(lambda a: masked_linear(a, w, mask, bias))
    assert_allclose(
        np.asarray(f(x)),
        np.asarray(ref.masked_linear_ref(x, w, mask, bias)),
        rtol=1e-5,
        atol=1e-5,
    )


# ---------------------------------------------------------------------------
# lut_lookup
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    bw=st.integers(1, 3),
    fanin=st.integers(1, 4),
    b=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_lookup_matches_ref(bw, fanin, b, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 2**bw, (b, fanin)).astype(np.int32))
    table = jnp.asarray(rng.normal(0, 1, (2 ** (bw * fanin),)).astype(np.float32))
    got = lut_lookup(codes, table, bw)
    want = ref.lut_lookup_ref(codes, table, bw)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_lut_lookup_rejects_bad_table():
    codes = jnp.zeros((4, 2), jnp.int32)
    table = jnp.zeros((7,), jnp.float32)  # wrong size
    with pytest.raises(AssertionError):
        lut_lookup(codes, table, 2)


# ---------------------------------------------------------------------------
# batchnorm oracle self-check (used by model tests)
# ---------------------------------------------------------------------------


def test_batchnorm_ref_normalizes():
    rng = np.random.default_rng(1)
    z = rand(rng, 256, 8, scale=3.0) + 2.0
    y, mu, var = ref.batchnorm_ref(z, jnp.ones(8), jnp.zeros(8))
    assert_allclose(np.asarray(jnp.mean(y, 0)), np.zeros(8), atol=1e-4)
    assert_allclose(np.asarray(jnp.std(y, 0)), np.ones(8), atol=1e-2)
    assert_allclose(np.asarray(mu), np.asarray(jnp.mean(z, 0)), rtol=1e-5)
