# pytest: L2 model semantics — forward shapes, quantizer-grid outputs,
# trainability of the flat train step, skip wiring, and the conv variants.

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.convmodel import (
    build_conv_forward_flat,
    build_conv_train_step_flat,
    conv_layer_dims,
    conv_layer_fanins,
)
from compile.model import (
    ModelCfg,
    build_forward_flat,
    build_train_step_flat,
)


def mlp_cfg(**kw):
    base = dict(
        name="t",
        kind="mlp",
        in_features=16,
        classes=5,
        hidden=[24, 16],
        bw=2,
        bw_in=2,
        bw_out=2,
        fanin=3,
        fanin_fc=None,
        batch=32,
        eval_batch=32,
    )
    base.update(kw)
    return ModelCfg(**base)


def init_flat(cfg, rng):
    n = cfg.num_layers()
    ins, outs = cfg.layer_inputs(), cfg.layer_sizes()
    ws, masks = [], []
    for i in range(n):
        f = cfg.layer_fanin(i)
        m = np.zeros((outs[i], ins[i]), np.float32)
        if f is None:
            m[:] = 1.0
        else:
            for o in range(outs[i]):
                m[o, rng.choice(ins[i], min(f, ins[i]), replace=False)] = 1.0
        masks.append(jnp.asarray(m))
        std = np.sqrt(2.0 / max(1, f or ins[i]))
        ws.append(jnp.asarray((rng.normal(0, std, (outs[i], ins[i])) * m).astype(np.float32)))
    bs = [jnp.zeros(o) for o in outs]
    gs = [jnp.ones(o) for o in outs]
    bes = [jnp.zeros(o) for o in outs]
    zeros = lambda: [jnp.zeros_like(w) for w in ws]
    z1 = lambda: [jnp.zeros(o) for o in outs]
    return ws, bs, gs, bes, zeros(), z1(), z1(), z1(), masks


def test_layer_inputs_with_skips():
    cfg = mlp_cfg(hidden=[10, 20, 30], skips=0)
    assert cfg.layer_inputs() == [16, 10, 20, 30]
    cfg1 = mlp_cfg(hidden=[10, 20, 30], skips=1)
    assert cfg1.layer_inputs() == [16, 10 + 16, 20 + 10, 30 + 20]
    cfg2 = mlp_cfg(hidden=[10, 20, 30], skips=2)
    assert cfg2.layer_inputs() == [16, 26, 46, 60]


@pytest.mark.parametrize("skips", [0, 1, 2])
def test_train_step_shapes_and_loss(skips):
    cfg = mlp_cfg(skips=skips)
    rng = np.random.default_rng(0)
    step, ex = build_train_step_flat(cfg)
    n = cfg.num_layers()
    assert len(ex) == 9 * n + 3
    ws, bs, gs, bes, vws, vbs, vgs, vbes, masks = init_flat(cfg, rng)
    x = jnp.asarray(rng.random((cfg.batch, 16), np.float32))
    y = jnp.asarray(rng.integers(0, 5, cfg.batch).astype(np.int32))
    out = jax.jit(step)(*ws, *bs, *gs, *bes, *vws, *vbs, *vgs, *vbes, *masks, x, y, jnp.float32(0.05))
    assert len(out) == 11 * n + 1
    loss = float(out[8 * n])
    assert np.isfinite(loss) and loss > 0
    # shapes preserved
    for i in range(n):
        assert out[i].shape == ws[i].shape
        assert out[9 * n + 1 + i].shape == bs[i].shape  # mu


def test_training_reduces_loss_quickly():
    cfg = mlp_cfg(hidden=[32, 32], steps=0)
    rng = np.random.default_rng(1)
    step = jax.jit(build_train_step_flat(cfg)[0])
    ws, bs, gs, bes, vws, vbs, vgs, vbes, masks = init_flat(cfg, rng)
    protos = rng.normal(0, 1.5, (5, 16)).astype(np.float32)
    losses = []
    state = [ws, bs, gs, bes, vws, vbs, vgs, vbes]
    n = cfg.num_layers()
    for t in range(60):
        y = rng.integers(0, 5, cfg.batch)
        x = (protos[y] + rng.normal(0, 0.6, (cfg.batch, 16))).astype(np.float32)
        x = (x - x.min()) / (x.max() - x.min())
        flat = [a for g in state for a in g]
        out = step(*flat, *masks, jnp.asarray(x), jnp.asarray(y.astype(np.int32)), jnp.float32(0.05))
        state = [list(out[k * n:(k + 1) * n]) for k in range(8)]
        losses.append(float(out[8 * n]))
    assert np.mean(losses[-10:]) < np.mean(losses[:5]) * 0.85, losses[:5] + losses[-5:]


def test_forward_logits_on_quantizer_grid():
    cfg = mlp_cfg()
    rng = np.random.default_rng(2)
    fwd = jax.jit(build_forward_flat(cfg)[0])
    ws, bs, gs, bes, _, _, _, _, masks = init_flat(cfg, rng)
    rms = [jnp.zeros(o) for o in cfg.layer_sizes()]
    rvs = [jnp.ones(o) for o in cfg.layer_sizes()]
    x = jnp.asarray(rng.random((cfg.eval_batch, 16), np.float32))
    (logits,) = fwd(*ws, *bs, *gs, *bes, *masks, *rms, *rvs, x)
    step = cfg.maxv_out / (2**cfg.bw_out - 1)
    arr = np.asarray(logits)
    assert arr.shape == (cfg.eval_batch, 5)
    frac = arr / step
    np.testing.assert_allclose(frac, np.round(frac), atol=1e-4)
    assert arr.min() >= 0 and arr.max() <= cfg.maxv_out + 1e-6


# ---------------------------------------------------------------------------
# conv variants
# ---------------------------------------------------------------------------


def cnn_cfg(mode, skips=0):
    return ModelCfg(
        name="c",
        kind="cnn",
        in_features=784,
        classes=10,
        hidden=[],
        bw=2,
        bw_in=2,
        bw_out=4,
        fanin=0,
        fanin_fc=None,
        skips=skips,
        batch=8,
        eval_batch=8,
        channels=[6, 8, 10],
        kernel_size=3,
        fanin_dw=5,
        fanin_pw=4,
        conv_mode=mode,
        image_hw=28,
    )


@pytest.mark.parametrize("mode", ["fp", "fp_dw", "fp_x_dw", "quant_x_dw"])
def test_conv_dims_and_forward(mode):
    cfg = cnn_cfg(mode)
    dims = conv_layer_dims(cfg)
    fanins = conv_layer_fanins(cfg)
    assert len(dims) == len(fanins)
    n = len(dims)
    rng = np.random.default_rng(3)
    step, ex = build_conv_train_step_flat(cfg)
    assert len(ex) == 9 * n + 3
    # init from example shapes
    flat = []
    for k, e in enumerate(ex[:-3]):
        if k < n:  # weights
            flat.append(jnp.asarray(rng.normal(0, 0.3, e.shape).astype(np.float32)))
        elif 2 * n <= k < 3 * n:  # gammas
            flat.append(jnp.ones(e.shape, jnp.float32))
        elif 8 * n <= k < 9 * n:  # masks
            m = np.zeros(e.shape, np.float32)
            f = fanins[k - 8 * n]
            if f is None:
                m[:] = 1.0
            else:
                for o in range(e.shape[0]):
                    m[o, rng.choice(e.shape[1], min(f, e.shape[1]), replace=False)] = 1.0
            flat.append(jnp.asarray(m))
        else:
            flat.append(jnp.zeros(e.shape, jnp.float32))
    x = jnp.asarray(rng.random((cfg.batch, 784), np.float32))
    y = jnp.asarray(rng.integers(0, 10, cfg.batch).astype(np.int32))
    out = jax.jit(step)(*flat, x, y, jnp.float32(0.02))
    assert len(out) == 11 * n + 1
    assert np.isfinite(float(out[8 * n]))


@pytest.mark.parametrize("skips", [0, 1, 2])
def test_conv_skip_dims(skips):
    cfg = cnn_cfg("quant_x_dw", skips=skips)
    dims = conv_layer_dims(cfg)
    c1, f1, f2 = cfg.channels
    assert dims[3] == (f2, f1 * 2 if skips >= 1 else f1)
    head_in = 49 * f2 + (49 * f1 if skips >= 2 else 0)
    assert dims[4] == (10, head_in)


def test_conv_eval_forward_shapes():
    cfg = cnn_cfg("quant_x_dw")
    rng = np.random.default_rng(4)
    fwd, ex = build_conv_forward_flat(cfg)
    n = len(conv_layer_dims(cfg))
    flat = []
    for k, e in enumerate(ex[:-1]):
        if 2 * n <= k < 3 * n or 6 * n <= k < 7 * n:  # gammas / rvars
            flat.append(jnp.ones(e.shape, jnp.float32))
        elif 4 * n <= k < 5 * n:  # masks
            flat.append(jnp.ones(e.shape, jnp.float32))
        elif k < n:
            flat.append(jnp.asarray(rng.normal(0, 0.3, e.shape).astype(np.float32)))
        else:
            flat.append(jnp.zeros(e.shape, jnp.float32))
    x = jnp.asarray(rng.random((cfg.eval_batch, 784), np.float32))
    (logits,) = jax.jit(fwd)(*flat, x)
    assert logits.shape == (cfg.eval_batch, 10)
