//! Minimal offline subset of the `anyhow` crate: a string-chained error
//! type plus the `anyhow!` / `bail!` / `ensure!` macros and the `Context`
//! extension trait.  The API mirrors upstream closely enough that swapping
//! in the real crate requires no source changes in this repository.

// Vendored offline shim: exempt from the workspace clippy gate.
#![allow(clippy::all)]

use std::fmt;

/// A context-chained error.  Each `.context(...)` layer wraps the previous
/// error; `Display` shows the outermost message, `Debug` the whole chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` under a new outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(s) = &cur.source {
            cur = s;
        }
        &cur.msg
    }
}

pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;
    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut rest = self.source.as_deref();
        if rest.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = rest {
            write!(f, "\n    {}", e.msg)?;
            rest = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as context layers.
        let mut msgs: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(msgs.pop().unwrap());
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, exactly as upstream anyhow does.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_displays() {
        let r: Result<()> = Err(io_err().into());
        let e = r.context("loading file").unwrap_err();
        assert_eq!(format!("{e}"), "loading file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
        assert_eq!(e.root_cause(), "gone");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing flag").unwrap_err();
        assert_eq!(format!("{e}"), "missing flag");
        let some: Option<u32> = Some(7);
        assert_eq!(some.context("x").unwrap(), 7);
    }

    #[test]
    fn macros_compile_and_fire() {
        fn inner(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with code {}", 3);
            Ok(1)
        }
        fn inner2() -> Result<u32> {
            bail!("always {}", "bails");
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(format!("{}", inner(true).unwrap_err()), "failed with code 3");
        assert_eq!(format!("{}", inner2().unwrap_err()), "always bails");
        let e = anyhow!("x = {}", 5);
        assert_eq!(format!("{e}"), "x = 5");
    }

    #[test]
    fn bare_ensure() {
        fn inner(v: usize) -> Result<()> {
            ensure!(v < 10);
            Ok(())
        }
        assert!(inner(5).is_ok());
        assert!(format!("{}", inner(20).unwrap_err()).contains("v < 10"));
    }
}
