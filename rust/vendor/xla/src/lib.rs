//! Offline stub of the PJRT/XLA bindings surface the coordinator uses.
//!
//! No PJRT runtime is linked: `PjRtClient::cpu()` (and everything that
//! would need a live client) returns [`XlaError`] with a clear message.
//! Artifact-dependent call sites (training, HLO evaluation) surface that
//! error at runtime; all pure-Rust paths — synthesis, bitsliced
//! simulation, serving, checkpoint-based experiments — are unaffected.
//! Swap this path dependency for the real `xla` bindings to restore PJRT
//! execution; the API subset below matches it.

// Vendored offline shim: exempt from the workspace clippy gate.
#![allow(clippy::all)]

use std::borrow::Borrow;

const STUB_MSG: &str =
    "PJRT runtime unavailable: built against the offline xla stub (see rust/vendor/README.md)";

#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub_err() -> XlaError {
    XlaError(STUB_MSG.to_string())
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side literal.  Constructible offline (the training driver builds
/// its inputs before ever touching a client); all device-backed reads fail.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(stub_err())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(stub_err())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_err())
    }
}

#[derive(Debug)]
pub struct HloModuleProto {
    _p: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub_err())
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_err())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err())
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err())
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_stub() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.0.contains("stub"), "{e}");
    }

    #[test]
    fn literals_construct_offline() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        let _ = Literal::scalar(0.5f32);
    }

    #[test]
    fn hlo_parse_fails_gracefully() {
        assert!(HloModuleProto::from_text_file("missing.hlo.txt").is_err());
    }
}
