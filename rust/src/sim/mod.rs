//! Bitsliced wide-plane netlist simulation (DESIGN.md §Bitsliced-Simulation
//! and §11 Levelized-Wide-Plane-Plan).
//!
//! The scalar `Netlist::eval` walks one sample at a time through `Vec<bool>`
//! — fine for spot checks, hopeless for equivalence sweeps and for serving
//! from the synthesized circuit.  This module stores a batch of samples as
//! *bit-planes* and evaluates every `LutNode` over whole machine words: a
//! 6-input LUT becomes a short Shannon expansion of AND/OR/NOT word ops.
//! Two widths exist:
//!
//! - the 64-way path ([`lut_word`], [`eval_netlist_64`]) — one `u64` per
//!   net, recursive expansion; kept as the bit-exact oracle and the
//!   `bench_sim` baseline;
//! - the 256-way path ([`lut_chunk`], [`plan::EvalPlan`] /
//!   [`plan::eval_plan`]) — one `[u64; LANES]` chunk per net, the Shannon
//!   recursion unrolled into an iterative mask-select fold over the chunk
//!   lanes so the autovectorizer lifts it to SIMD.  [`eval_netlist`]
//!   compiles a plan on the fly and runs this path; hot callers (serving,
//!   verification sweeps) compile once and reuse a [`plan::SimScratch`].
//!
//! Layout: [`BitMatrix`] is plane-major — plane `p` (one named bit: a
//! primary input, or one output bit) owns `words_per_plane` consecutive
//! `u64`s, and sample `s` lives at bit `s % 64` of word `s / 64`.  Bits at
//! or beyond `samples` in the last word of every plane are kept zero
//! (enforced by every constructor and by [`eval_netlist`]), so whole-word
//! comparisons between matrices are exact.
//!
//! The evaluation schedule is levelized *explicitly*: [`plan::EvalPlan`]
//! recomputes each node's topological level from the wiring and stores the
//! records level-ordered in a flat arena (the old "levelized implicitly —
//! node order is topological" note only survives in [`eval_netlist_64`],
//! which still sweeps nodes in list order under a debug assertion).

pub mod plan;

pub use plan::{eval_plan, EvalPlan, SimScratch};

use crate::synth::netlist::{Net, Netlist};
use crate::util::bits::var_word;
use crate::util::pool;

/// Runtime-dispatched SIMD tier for the chunk kernels.  Each
/// [`EvalPlan`] compile picks one via [`SimdTier::detect`] and routes
/// every LUT record through [`lut_chunk_at`]; the portable tier stays the
/// byte-exact oracle the intrinsic tiers are property-tested against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdTier {
    /// Portable `[u64; LANES]` lane loops (autovectorized; the oracle).
    Portable,
    /// 256-bit AVX2 intrinsics: `vpand`/`vpandn`/`vpor` mask-select muxes.
    Avx2,
    /// AVX-512VL ternary-logic muxes on 256-bit registers (`vpternlogq`
    /// imm 0xCA — one instruction per mux instead of three).
    Avx512,
}

impl SimdTier {
    fn rank(self) -> u8 {
        match self {
            SimdTier::Portable => 0,
            SimdTier::Avx2 => 1,
            SimdTier::Avx512 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Portable => "portable",
            SimdTier::Avx2 => "avx2",
            SimdTier::Avx512 => "avx512",
        }
    }

    /// Widest tier the CPU reports at runtime (compile-time features play
    /// no part: a `-C target-cpu=x86-64` baseline build still dispatches
    /// to AVX2 when the host has it).
    fn hardware() -> SimdTier {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx512vl")
            {
                return SimdTier::Avx512;
            }
            if std::arch::is_x86_feature_detected!("avx2") {
                return SimdTier::Avx2;
            }
        }
        SimdTier::Portable
    }

    /// The tier dispatch uses: the widest the hardware supports, unless
    /// `LOGICNETS_SIMD=portable|avx2|avx512` requests a different one.
    /// The request is clamped to what the hardware reports, so a forced
    /// tier can lower the dispatch but never make it unsound.
    pub fn detect() -> SimdTier {
        let hw = SimdTier::hardware();
        let req = match std::env::var("LOGICNETS_SIMD").ok().as_deref() {
            Some("portable") => Some(SimdTier::Portable),
            Some("avx2") => Some(SimdTier::Avx2),
            Some("avx512") => Some(SimdTier::Avx512),
            _ => None,
        };
        match req {
            Some(r) if r.rank() <= hw.rank() => r,
            _ => hw,
        }
    }

    /// Every tier eligible for dispatch on this host under the current
    /// config, lowest first (always contains `Portable`).  Test suites
    /// sweep this to pin each dispatched kernel against the portable
    /// oracle; `bench_sim` uses it for the tier-comparison scenarios.
    pub fn supported() -> Vec<SimdTier> {
        let top = SimdTier::detect();
        [SimdTier::Portable, SimdTier::Avx2, SimdTier::Avx512]
            .into_iter()
            .filter(|t| t.rank() <= top.rank())
            .collect()
    }
}

/// `u64` lanes per chunk in the wide path: 4 lanes = 256 samples evaluated
/// per LUT record.  Chosen to match one 256-bit vector register (AVX2 /
/// NEON pairs) while keeping the per-worker value array small enough to
/// stay cache-resident for real netlists (see DESIGN.md §11).
pub const LANES: usize = 4;

/// One wide-plane value: the same named bit of `64 * LANES` samples.
pub type Chunk = [u64; LANES];

/// A batch of bit-vectors stored as bit-planes, 64 samples per word.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitMatrix {
    planes: usize,
    samples: usize,
    /// Words per plane: `samples.div_ceil(64)`.
    wpp: usize,
    /// Plane-major storage: plane `p` is `words[p*wpp .. (p+1)*wpp]`.
    words: Vec<u64>,
}

impl BitMatrix {
    pub fn new(planes: usize, samples: usize) -> BitMatrix {
        let wpp = samples.div_ceil(64);
        BitMatrix { planes, samples, wpp, words: vec![0u64; planes * wpp] }
    }

    pub fn planes(&self) -> usize {
        self.planes
    }

    pub fn samples(&self) -> usize {
        self.samples
    }

    pub fn words_per_plane(&self) -> usize {
        self.wpp
    }

    pub fn plane(&self, p: usize) -> &[u64] {
        &self.words[p * self.wpp..(p + 1) * self.wpp]
    }

    pub fn plane_mut(&mut self, p: usize) -> &mut [u64] {
        &mut self.words[p * self.wpp..(p + 1) * self.wpp]
    }

    /// Clear and reshape in place, keeping the allocation (the scratch
    /// pattern: serving engines reuse one input matrix across batches).
    pub fn reset(&mut self, planes: usize, samples: usize) {
        let wpp = samples.div_ceil(64);
        self.planes = planes;
        self.samples = samples;
        self.wpp = wpp;
        self.words.clear();
        self.words.resize(planes * wpp, 0);
    }

    /// Valid-bit mask of the last word of every plane.
    pub(crate) fn tail_mask(&self) -> u64 {
        let rem = self.samples % 64;
        if rem == 0 {
            u64::MAX
        } else {
            (1u64 << rem) - 1
        }
    }

    #[inline]
    pub fn get(&self, plane: usize, sample: usize) -> bool {
        debug_assert!(plane < self.planes && sample < self.samples);
        (self.words[plane * self.wpp + sample / 64] >> (sample % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, plane: usize, sample: usize, v: bool) {
        debug_assert!(plane < self.planes && sample < self.samples);
        let idx = plane * self.wpp + sample / 64;
        let bit = 1u64 << (sample % 64);
        if v {
            self.words[idx] |= bit;
        } else {
            self.words[idx] &= !bit;
        }
    }

    /// Write the `bw` bits of `code` into planes `base..base+bw` of one
    /// sample (bit `b` of the code lands in plane `base + b`) — the layout
    /// the synthesizer uses for a quantized activation bus.
    #[inline]
    pub fn set_code(&mut self, base: usize, bw: usize, sample: usize, code: u32) {
        debug_assert!(bw == 32 || (code as u64) < (1u64 << bw), "code {code} too wide");
        for b in 0..bw {
            self.set(base + b, sample, (code >> b) & 1 == 1);
        }
    }

    /// Read back a `bw`-bit code from planes `base..base+bw` of one sample.
    #[inline]
    pub fn get_code(&self, base: usize, bw: usize, sample: usize) -> u32 {
        let mut c = 0u32;
        for b in 0..bw {
            c |= (self.get(base + b, sample) as u32) << b;
        }
        c
    }

    /// Write one sample's full bit-vector (one column across all planes).
    pub fn set_column(&mut self, sample: usize, bits: &[bool]) {
        assert_eq!(bits.len(), self.planes);
        for (p, &b) in bits.iter().enumerate() {
            self.set(p, sample, b);
        }
    }

    /// Read one sample's full bit-vector.
    pub fn column(&self, sample: usize) -> Vec<bool> {
        (0..self.planes).map(|p| self.get(p, sample)).collect()
    }

    /// Enumerate all `2^k` input patterns as bit-planes: sample `s` of
    /// plane `v` is `(s >> v) & 1`.  This is how exhaustive table-vs-netlist
    /// equivalence enumerates a truth-table's index space in word-parallel
    /// form (64 patterns per word) instead of one scalar eval per pattern.
    pub fn all_patterns(k: usize) -> BitMatrix {
        assert!(k < usize::BITS as usize - 7, "pattern space 2^{k} too large");
        let samples = 1usize << k;
        let mut m = BitMatrix::new(k, samples);
        let (wpp, tail) = (m.wpp, m.tail_mask());
        for v in 0..k {
            for w in 0..wpp {
                let mut word = var_word(v, w);
                if w + 1 == wpp {
                    word &= tail;
                }
                m.words[v * wpp + w] = word;
            }
        }
        m
    }
}

impl Default for BitMatrix {
    fn default() -> BitMatrix {
        BitMatrix::new(0, 0)
    }
}

/// Word-level evaluation of one K<=6-input LUT by Shannon expansion of its
/// packed truth table: `xs[j]` holds input `j` of 64 samples, the result
/// holds the LUT output of the same 64 samples.  k∈{0,1,2} take direct
/// mask-select fast paths (no recursion); wider LUTs fall back to
/// [`lut_word_rec`].
#[inline]
pub fn lut_word(tt: u64, xs: &[u64]) -> u64 {
    match xs.len() {
        0 => {
            if tt & 1 == 1 {
                u64::MAX
            } else {
                0
            }
        }
        1 => pair_mux(tt, xs[0]),
        2 => {
            let f0 = pair_mux(tt, xs[0]);
            let f1 = pair_mux(tt >> 2, xs[0]);
            (xs[1] & f1) | (!xs[1] & f0)
        }
        k => {
            debug_assert!(k <= 6, "LUT arity {k} > 6");
            let mask = if k >= 6 { u64::MAX } else { (1u64 << (1usize << k)) - 1 };
            lut_word_rec(tt & mask, xs, mask)
        }
    }
}

/// Evaluate a 1-input LUT (the two low truth-table bits) over a word:
/// 00 → 0, 11 → 1, 10 → x, 01 → !x.
#[inline]
fn pair_mux(tt: u64, x: u64) -> u64 {
    match tt & 0b11 {
        0b00 => 0,
        0b11 => u64::MAX,
        0b10 => x,
        _ => !x,
    }
}

/// Recursive Shannon-expansion reference form of [`lut_word`].  `mask`
/// must be the valid-bit mask of `tt` for the current arity
/// (`(1 << (1 << k)) - 1`, saturating to all-ones at k=6) and `tt` must be
/// pre-masked.  Public so tests can pin the fast paths against it.
pub fn lut_word_rec(tt: u64, xs: &[u64], mask: u64) -> u64 {
    // Constant cofactors terminate most branches early: sparse and
    // saturated truth tables (the common LogicNets case) cost far fewer
    // than the worst-case 2^k word ops.
    if tt == 0 {
        return 0;
    }
    if tt == mask {
        return u64::MAX;
    }
    let k = xs.len();
    debug_assert!(k >= 1, "non-constant 0-input LUT");
    let half = 1usize << (k - 1);
    let lo_mask = (1u64 << half) - 1;
    let x = xs[k - 1];
    let f0 = lut_word_rec(tt & lo_mask, &xs[..k - 1], lo_mask);
    let f1 = lut_word_rec((tt >> half) & lo_mask, &xs[..k - 1], lo_mask);
    (x & f1) | (!x & f0)
}

/// Per-lane mux: `x ? a1 : a0` on every lane.  The straight-line loop over
/// a fixed-size array is what the autovectorizer turns into vector
/// `and/andnot/or` — keep it branch-free.
#[inline(always)]
fn chunk_mux(x: &Chunk, a1: &Chunk, a0: &Chunk) -> Chunk {
    let mut r = [0u64; LANES];
    for l in 0..LANES {
        r[l] = (x[l] & a1[l]) | (!x[l] & a0[l]);
    }
    r
}

/// Evaluate a 1-input LUT (two low tt bits) over a chunk; the lane loop in
/// each arm vectorizes, and the constant arms splat without touching `x`.
#[inline(always)]
fn chunk_pair_mux(tt: u64, x: &Chunk) -> Chunk {
    match tt & 0b11 {
        0b00 => [0u64; LANES],
        0b11 => [u64::MAX; LANES],
        0b10 => *x,
        _ => {
            let mut r = [0u64; LANES];
            for l in 0..LANES {
                r[l] = !x[l];
            }
            r
        }
    }
}

/// Iterative wide-plane LUT evaluation: seed `HALF` 1-input cofactors from
/// the truth-table bit pairs, then fold the remaining variables with
/// [`chunk_mux`] — the Shannon recursion unrolled into `HALF - 1` muxes of
/// straight-line lane loops, no call tree.
#[inline(always)]
fn lut_chunk_wide<const HALF: usize>(tt: u64, xs: &[Chunk]) -> Chunk {
    debug_assert_eq!(HALF, 1usize << (xs.len() - 1));
    let mut cof = [[0u64; LANES]; HALF];
    for (i, c) in cof.iter_mut().enumerate() {
        *c = chunk_pair_mux(tt >> (2 * i), &xs[0]);
    }
    let mut width = HALF;
    let mut v = 1;
    while width > 1 {
        width /= 2;
        for i in 0..width {
            cof[i] = chunk_mux(&xs[v], &cof[2 * i + 1], &cof[2 * i]);
        }
        v += 1;
    }
    cof[0]
}

/// Chunk-level evaluation of one K<=6-input LUT: `xs[j]` holds input `j`
/// of `64 * LANES` samples, the result holds the LUT output of the same
/// samples.  Semantics match [`lut_word`] lane-by-lane; constant truth
/// tables short-circuit as in the recursive form.
#[inline]
pub fn lut_chunk(tt: u64, xs: &[Chunk]) -> Chunk {
    let k = xs.len();
    debug_assert!(k <= 6, "LUT arity {k} > 6");
    let mask = if k >= 6 { u64::MAX } else { (1u64 << (1usize << k)) - 1 };
    let tt = tt & mask;
    if tt == 0 {
        return [0u64; LANES];
    }
    if tt == mask {
        return [u64::MAX; LANES];
    }
    match k {
        1 => chunk_pair_mux(tt, &xs[0]),
        2 => {
            let f0 = chunk_pair_mux(tt, &xs[0]);
            let f1 = chunk_pair_mux(tt >> 2, &xs[0]);
            chunk_mux(&xs[1], &f1, &f0)
        }
        3 => lut_chunk_wide::<4>(tt, xs),
        4 => lut_chunk_wide::<8>(tt, xs),
        5 => lut_chunk_wide::<16>(tt, xs),
        _ => lut_chunk_wide::<32>(tt, xs),
    }
}

/// [`lut_chunk`] at an explicit dispatch tier — semantics are identical on
/// every tier, bit for bit.  The intrinsic arms run `unsafe`
/// `#[target_feature]` kernels, which is sound because `tier` values other
/// than `Portable` only come from [`SimdTier::detect`] (hardware-clamped);
/// constructing one by hand and calling this on a CPU without the feature
/// is the caller's UB to avoid.
#[inline]
pub fn lut_chunk_at(tier: SimdTier, tt: u64, xs: &[Chunk]) -> Chunk {
    match tier {
        SimdTier::Portable => lut_chunk(tt, xs),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the tier was hardware-clamped by `SimdTier::detect`.
        SimdTier::Avx2 => unsafe { x86::lut_chunk_avx2(tt, xs) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        SimdTier::Avx512 => unsafe { x86::lut_chunk_avx512(tt, xs) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdTier::Avx2 | SimdTier::Avx512 => lut_chunk(tt, xs),
    }
}

/// Explicit-intrinsic variants of the chunk kernels.  A [`Chunk`]
/// (`[u64; 4]`) is exactly one 256-bit register, moved with unaligned
/// loads/stores (the arena gives no alignment guarantee).  Every fn here
/// is `unsafe` + `#[target_feature]`: callers must have verified the
/// feature at runtime (`SimdTier::detect` does).
#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{Chunk, LANES};
    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load(c: &Chunk) -> __m256i {
        _mm256_loadu_si256(c.as_ptr().cast())
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn store(r: __m256i) -> Chunk {
        let mut out = [0u64; LANES];
        _mm256_storeu_si256(out.as_mut_ptr().cast(), r);
        out
    }

    /// 1-input LUT over a register (the two low tt bits), constant arms
    /// splatted: 00 → 0, 11 → 1, 10 → x, 01 → !x.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn pair_mux(tt: u64, x: __m256i) -> __m256i {
        match tt & 0b11 {
            0b00 => _mm256_setzero_si256(),
            0b11 => _mm256_set1_epi64x(-1),
            0b10 => x,
            _ => _mm256_xor_si256(x, _mm256_set1_epi64x(-1)),
        }
    }

    /// `x ? a1 : a0` per bit: and + andnot + or (three AVX2 ops).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn mux_avx2(x: __m256i, a1: __m256i, a0: __m256i) -> __m256i {
        _mm256_or_si256(_mm256_and_si256(x, a1), _mm256_andnot_si256(x, a0))
    }

    /// `x ? a1 : a0` per bit in ONE `vpternlogq`: imm 0xCA reads the
    /// operand bits as (x, a1, a0) and selects a1 where x=1, a0 where x=0.
    #[inline]
    #[target_feature(enable = "avx512f,avx512vl")]
    unsafe fn mux_avx512(x: __m256i, a1: __m256i, a0: __m256i) -> __m256i {
        _mm256_ternarylogic_epi64::<0xCA>(x, a1, a0)
    }

    // One macro stamps both kernels: identical Shannon fold (seed the
    // 1-input cofactors from tt bit pairs over xs[0], then halve with the
    // tier's mux), differing only in the mux instruction.
    macro_rules! lut_chunk_kernel {
        ($name:ident, $feature:literal, $mux:ident) => {
            /// # Safety
            /// The CPU must support the `#[target_feature]` set of this
            /// fn, verified at runtime (`SimdTier::detect`).
            #[target_feature(enable = $feature)]
            pub unsafe fn $name(tt: u64, xs: &[Chunk]) -> Chunk {
                let k = xs.len();
                debug_assert!(k <= 6, "LUT arity {k} > 6");
                let mask = if k >= 6 { u64::MAX } else { (1u64 << (1usize << k)) - 1 };
                let tt = tt & mask;
                if tt == 0 {
                    return [0u64; LANES];
                }
                if tt == mask {
                    return [u64::MAX; LANES];
                }
                // Non-constant => k >= 1 here; half = 1 folds nothing and
                // returns the seeded pair_mux, matching `lut_chunk`'s k=1
                // arm.
                let x0 = load(&xs[0]);
                let half = 1usize << (k - 1);
                let mut cof = [_mm256_setzero_si256(); 32];
                for i in 0..half {
                    cof[i] = pair_mux(tt >> (2 * i), x0);
                }
                let mut width = half;
                let mut v = 1;
                while width > 1 {
                    width /= 2;
                    let x = load(&xs[v]);
                    for i in 0..width {
                        cof[i] = $mux(x, cof[2 * i + 1], cof[2 * i]);
                    }
                    v += 1;
                }
                store(cof[0])
            }
        };
    }

    lut_chunk_kernel!(lut_chunk_avx2, "avx2", mux_avx2);
    lut_chunk_kernel!(lut_chunk_avx512, "avx512f,avx512vl", mux_avx512);
}

#[inline]
fn read_net(inputs: &BitMatrix, vals: &[u64], net: Net, w: usize) -> u64 {
    match net {
        Net::Const0 => 0,
        Net::Const1 => u64::MAX,
        Net::Input(i) => inputs.plane(i as usize)[w],
        Net::Node(i) => vals[i as usize],
    }
}

/// Evaluate a whole word-block (a contiguous range of sample words): one
/// topological sweep over the nodes per word, all node values live in one
/// reused `vals` buffer.  Returns the output planes of the block, laid out
/// `[output][word_in_block]`.
fn eval_block(netlist: &Netlist, inputs: &BitMatrix, range: std::ops::Range<usize>) -> Vec<u64> {
    let len = range.len();
    let mut vals = vec![0u64; netlist.nodes.len()];
    let mut block = vec![0u64; netlist.outputs.len() * len];
    let mut xs = [0u64; 6];
    for (k, w) in range.enumerate() {
        for (i, node) in netlist.nodes.iter().enumerate() {
            let arity = node.inputs.len();
            debug_assert!(arity <= 6);
            for (j, &inp) in node.inputs.iter().enumerate() {
                xs[j] = read_net(inputs, &vals, inp, w);
            }
            vals[i] = lut_word(node.tt, &xs[..arity]);
        }
        for (oi, &o) in netlist.outputs.iter().enumerate() {
            block[oi * len + k] = read_net(inputs, &vals, o, w);
        }
    }
    block
}

/// [`eval_block`] for netlists carrying content-bearing BRAM records: the
/// input planes of each word are staged into a mutable overlay so a fired
/// BRAM can overwrite its pseudo-input words, and BRAMs fire at their
/// [`Netlist::bram_triggers`] index exactly as in the scalar evaluator.
/// The memory lookup itself is inherently per-sample (64 address packs +
/// table reads per word); the LUT sweep around it stays word-parallel.
fn eval_block_bram(
    netlist: &Netlist,
    inputs: &BitMatrix,
    range: std::ops::Range<usize>,
) -> Vec<u64> {
    let len = range.len();
    let triggers = netlist.bram_triggers();
    let mut vals = vec![0u64; netlist.nodes.len()];
    let mut inw = vec![0u64; netlist.num_inputs];
    let mut block = vec![0u64; netlist.outputs.len() * len];
    let mut xs = [0u64; 6];
    let read = |inw: &[u64], vals: &[u64], net: Net| -> u64 {
        match net {
            Net::Const0 => 0,
            Net::Const1 => u64::MAX,
            Net::Input(i) => inw[i as usize],
            Net::Node(i) => vals[i as usize],
        }
    };
    let mut fired = vec![false; netlist.brams.len()];
    for (k, w) in range.enumerate() {
        for i in 0..netlist.num_inputs {
            inw[i] = inputs.plane(i)[w];
        }
        fired.iter_mut().for_each(|f| *f = false);
        for i in 0..=netlist.nodes.len() {
            for (bi, b) in netlist.brams.iter().enumerate() {
                if fired[bi] || triggers[bi] > i {
                    continue;
                }
                debug_assert!(b.is_evaluable());
                // Pack each sample's address from the gathered word bits,
                // look it up, and scatter the code into the pseudo words.
                let addr: Vec<u64> = b.inputs.iter().map(|&n| read(&inw, &vals, n)).collect();
                let mut outw = vec![0u64; b.out_bits];
                for s in 0..64usize {
                    let mut idx = 0usize;
                    for (j, aw) in addr.iter().enumerate() {
                        idx |= (((aw >> s) & 1) as usize) << j;
                    }
                    let code = b.content[idx] as u64;
                    for (ob, o) in outw.iter_mut().enumerate() {
                        *o |= ((code >> ob) & 1) << s;
                    }
                }
                for (ob, &o) in outw.iter().enumerate() {
                    inw[b.out_base as usize + ob] = o;
                }
                fired[bi] = true;
            }
            if i == netlist.nodes.len() {
                break;
            }
            let node = &netlist.nodes[i];
            let arity = node.inputs.len();
            debug_assert!(arity <= 6);
            for (j, &inp) in node.inputs.iter().enumerate() {
                xs[j] = read(&inw, &vals, inp);
            }
            vals[i] = lut_word(node.tt, &xs[..arity]);
        }
        for (oi, &o) in netlist.outputs.iter().enumerate() {
            block[oi * len + k] = read(&inw, &vals, o);
        }
    }
    block
}

/// Bitsliced batch evaluation of a netlist: `inputs` holds one plane per
/// primary input, the result one plane per output net.  Runs the wide
/// 256-way path by compiling an [`EvalPlan`] on the fly — the convenience
/// entry point for one-shot callers (synthesis verification, equivalence
/// sweeps).  Hot paths should compile the plan once and call
/// [`eval_plan`] with a reused [`SimScratch`].
pub fn eval_netlist(netlist: &Netlist, inputs: &BitMatrix) -> BitMatrix {
    assert!(
        netlist.brams_evaluable(),
        "netlist with opaque (content-less) BRAM ports is not evaluable"
    );
    let plan = EvalPlan::compile(netlist);
    eval_plan(&plan, inputs, &mut SimScratch::default())
}

/// The original 64-way bitsliced evaluator: one `u64` word per net,
/// recursive Shannon expansion, nodes swept in list order (topological by
/// construction, checked by a debug assertion).  Kept as the bit-exact
/// oracle for the wide path and as the `bench_sim` speedup baseline.
pub fn eval_netlist_64(netlist: &Netlist, inputs: &BitMatrix) -> BitMatrix {
    assert!(
        netlist.brams_evaluable(),
        "netlist with opaque (content-less) BRAM ports is not evaluable"
    );
    assert_eq!(inputs.planes(), netlist.num_inputs, "input plane count");
    #[cfg(debug_assertions)]
    for (i, node) in netlist.nodes.iter().enumerate() {
        for &inp in &node.inputs {
            if let Net::Node(j) = inp {
                debug_assert!((j as usize) < i, "node {i} not in topological order");
            }
        }
    }
    let samples = inputs.samples();
    let mut out = BitMatrix::new(netlist.outputs.len(), samples);
    let wpp = inputs.words_per_plane();
    if wpp == 0 || netlist.outputs.is_empty() {
        return out;
    }
    let per = wpp.div_ceil(pool::num_threads()).max(1);
    let ranges: Vec<std::ops::Range<usize>> =
        (0..wpp).step_by(per).map(|lo| lo..(lo + per).min(wpp)).collect();
    let blocks: Vec<Vec<u64>> = if netlist.brams.is_empty() {
        pool::par_map(&ranges, |_, r| eval_block(netlist, inputs, r.clone()))
    } else {
        pool::par_map(&ranges, |_, r| eval_block_bram(netlist, inputs, r.clone()))
    };
    let tail = out.tail_mask();
    for (range, block) in ranges.iter().zip(blocks) {
        let len = range.len();
        for p in 0..out.planes {
            for (k, w) in range.clone().enumerate() {
                let mut word = block[p * len + k];
                if w + 1 == wpp {
                    word &= tail;
                }
                out.words[p * out.wpp + w] = word;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::netlist::LutNode;
    use crate::util::rng::Rng;

    fn and_or_netlist() -> Netlist {
        // n0 = AND(in0, in1); n1 = OR(n0, in2); outputs exercise consts and
        // input passthrough alongside node outputs.
        Netlist {
            num_inputs: 3,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b1000, level: 1 },
                LutNode { inputs: vec![Net::Node(0), Net::Input(2)], tt: 0b1110, level: 2 },
            ],
            outputs: vec![Net::Node(1), Net::Const1, Net::Const0, Net::Input(2)],
            brams: vec![],
            layer_depths: vec![2],
        }
    }

    #[test]
    fn bitmatrix_set_get_roundtrip() {
        let mut m = BitMatrix::new(5, 130);
        let mut rng = Rng::new(1);
        let mut mirror = vec![vec![false; 130]; 5];
        for _ in 0..400 {
            let (p, s) = (rng.below(5), rng.below(130));
            let v = rng.f64() < 0.5;
            m.set(p, s, v);
            mirror[p][s] = v;
        }
        for p in 0..5 {
            for s in 0..130 {
                assert_eq!(m.get(p, s), mirror[p][s], "p={p} s={s}");
            }
        }
        // Tail invariant: bits beyond `samples` stay zero.
        let tail = m.tail_mask();
        for p in 0..5 {
            assert_eq!(m.plane(p)[2] & !tail, 0);
        }
    }

    #[test]
    fn codes_and_columns_roundtrip() {
        let mut m = BitMatrix::new(6, 70);
        m.set_code(2, 3, 65, 0b101);
        assert_eq!(m.get_code(2, 3, 65), 0b101);
        assert!(m.get(2, 65) && !m.get(3, 65) && m.get(4, 65));
        let bits = vec![true, false, true, true, false, false];
        m.set_column(7, &bits);
        assert_eq!(m.column(7), bits);
    }

    #[test]
    fn all_patterns_enumerates_indices() {
        for k in [1usize, 3, 6, 8] {
            let m = BitMatrix::all_patterns(k);
            assert_eq!(m.samples(), 1 << k);
            for s in 0..(1usize << k) {
                for v in 0..k {
                    assert_eq!(m.get(v, s), (s >> v) & 1 == 1, "k={k} s={s} v={v}");
                }
            }
        }
    }

    #[test]
    fn lut_word_matches_scalar_lookup() {
        let mut rng = Rng::new(7);
        for k in 0..=6usize {
            for _ in 0..20 {
                let tt = rng.next_u64();
                let xs: Vec<u64> = (0..k).map(|_| rng.next_u64()).collect();
                let word = lut_word(tt, &xs);
                for b in 0..64usize {
                    let mut idx = 0usize;
                    for (j, x) in xs.iter().enumerate() {
                        if (x >> b) & 1 == 1 {
                            idx |= 1 << j;
                        }
                    }
                    let expect = (tt >> idx) & 1 == 1;
                    assert_eq!((word >> b) & 1 == 1, expect, "k={k} bit={b}");
                }
            }
        }
    }

    /// Satellite: the k∈{0,1,2} fast paths in `lut_word` must agree with
    /// the recursive reference form for EVERY truth table at those widths.
    #[test]
    fn lut_word_fast_paths_pin_against_recursive_form() {
        let mut rng = Rng::new(11);
        let xs_pool: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
        for k in 0..=2usize {
            let mask = (1u64 << (1usize << k)) - 1;
            for tt in 0..=mask {
                for trial in 0..4 {
                    let xs: Vec<u64> =
                        (0..k).map(|j| xs_pool[(trial * 2 + j) % xs_pool.len()]).collect();
                    assert_eq!(
                        lut_word(tt, &xs),
                        lut_word_rec(tt, &xs, mask),
                        "k={k} tt={tt:#b} trial={trial}"
                    );
                    // High junk bits in tt must be ignored by the fast path
                    // exactly as lut_word always masked them.
                    let junk = tt | (rng.next_u64() & !mask);
                    assert_eq!(lut_word(junk, &xs), lut_word_rec(tt, &xs, mask));
                }
            }
        }
    }

    /// Every lane of `lut_chunk` must equal `lut_word` on the same words,
    /// for all arities and for all truth tables at k<=2 / random ones above.
    #[test]
    fn lut_chunk_lanes_match_lut_word() {
        let mut rng = Rng::new(13);
        for k in 0..=6usize {
            let exhaustive = k <= 2;
            let mask = if k >= 6 { u64::MAX } else { (1u64 << (1usize << k)) - 1 };
            let tts: Vec<u64> = if exhaustive {
                (0..=mask).collect()
            } else {
                (0..40).map(|_| rng.next_u64()).collect()
            };
            for tt in tts {
                let xs: Vec<Chunk> = (0..k)
                    .map(|_| {
                        let mut c = [0u64; LANES];
                        for l in &mut c {
                            *l = rng.next_u64();
                        }
                        c
                    })
                    .collect();
                let wide = lut_chunk(tt, &xs);
                for l in 0..LANES {
                    let lane_xs: Vec<u64> = xs.iter().map(|c| c[l]).collect();
                    assert_eq!(wide[l], lut_word(tt, &lane_xs), "k={k} tt={tt:#x} lane={l}");
                }
            }
        }
    }

    #[test]
    fn bitmatrix_reset_keeps_invariants() {
        let mut m = BitMatrix::new(3, 130);
        m.set(2, 129, true);
        m.reset(5, 70);
        assert_eq!((m.planes(), m.samples(), m.words_per_plane()), (5, 70, 2));
        for p in 0..5 {
            assert!(m.plane(p).iter().all(|&w| w == 0), "plane {p} not cleared");
        }
        m.set(4, 69, true);
        m.reset(1, 0);
        assert_eq!(m.words_per_plane(), 0);
    }

    #[test]
    fn eval_matches_scalar_on_mixed_outputs() {
        let nl = and_or_netlist();
        let samples = 130; // crosses word boundaries, non-multiple of 64
        let mut inputs = BitMatrix::new(3, samples);
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<bool>> = (0..samples)
            .map(|s| {
                let bits: Vec<bool> = (0..3).map(|_| rng.f64() < 0.5).collect();
                inputs.set_column(s, &bits);
                bits
            })
            .collect();
        let out = eval_netlist(&nl, &inputs);
        assert_eq!(out.planes(), 4);
        for (s, bits) in rows.iter().enumerate() {
            assert_eq!(out.column(s), nl.eval(bits), "sample {s}");
        }
        // Tail bits of every output plane (including Const1) must be zero.
        let tail = out.tail_mask();
        for p in 0..out.planes() {
            assert_eq!(out.plane(p)[out.words_per_plane() - 1] & !tail, 0, "plane {p}");
        }
    }

    #[test]
    fn eval_exhaustive_via_all_patterns() {
        let nl = and_or_netlist();
        let inputs = BitMatrix::all_patterns(3);
        let out = eval_netlist(&nl, &inputs);
        for idx in 0..8usize {
            let bits: Vec<bool> = (0..3).map(|v| (idx >> v) & 1 == 1).collect();
            assert_eq!(out.column(idx), nl.eval(&bits), "idx {idx}");
        }
    }

    #[test]
    fn empty_batch_and_empty_outputs() {
        let nl = and_or_netlist();
        let out = eval_netlist(&nl, &BitMatrix::new(3, 0));
        assert_eq!(out.samples(), 0);
        let mut no_out = nl.clone();
        no_out.outputs.clear();
        let out = eval_netlist(&no_out, &BitMatrix::new(3, 100));
        assert_eq!(out.planes(), 0);
        let out = eval_netlist_64(&no_out, &BitMatrix::new(3, 100));
        assert_eq!(out.planes(), 0);
    }

    /// Every dispatched tier must match the portable kernel bit for bit
    /// on random truth tables at every arity (the cross-stack property
    /// sweep lives in `tests/simd_dispatch.rs`; this is the in-crate
    /// smoke version).
    #[test]
    fn dispatched_tiers_match_portable_kernel() {
        let mut rng = Rng::new(17);
        for tier in SimdTier::supported() {
            for k in 0..=6usize {
                for _ in 0..25 {
                    let tt = rng.next_u64();
                    let xs: Vec<Chunk> = (0..k)
                        .map(|_| {
                            let mut c = [0u64; LANES];
                            for l in &mut c {
                                *l = rng.next_u64();
                            }
                            c
                        })
                        .collect();
                    assert_eq!(
                        lut_chunk_at(tier, tt, &xs),
                        lut_chunk(tt, &xs),
                        "tier={} k={k} tt={tt:#x}",
                        tier.name()
                    );
                }
            }
        }
    }

    #[test]
    fn simd_tier_detection_is_clamped_and_ordered() {
        let tiers = SimdTier::supported();
        assert_eq!(tiers[0], SimdTier::Portable, "portable is always eligible");
        assert!(tiers.contains(&SimdTier::detect()), "detected tier must be eligible");
        assert_eq!(SimdTier::Portable.name(), "portable");
    }

    /// Wide path vs 64-way oracle: whole-`BitMatrix` equality (the tail
    /// invariant makes `==` exact) across chunk-straddling batch sizes.
    #[test]
    fn wide_path_equals_64_way_oracle() {
        let nl = and_or_netlist();
        for samples in [1usize, 64, 129, 255, 256, 257, 300] {
            let mut rng = Rng::new(samples as u64 ^ 0xabc);
            let mut inputs = BitMatrix::new(3, samples);
            for s in 0..samples {
                let bits: Vec<bool> = (0..3).map(|_| rng.f64() < 0.5).collect();
                inputs.set_column(s, &bits);
            }
            assert_eq!(eval_netlist(&nl, &inputs), eval_netlist_64(&nl, &inputs), "{samples}");
        }
    }
}
