//! Bitsliced 64-way netlist simulation (DESIGN.md §Bitsliced-Simulation).
//!
//! The scalar `Netlist::eval` walks one sample at a time through `Vec<bool>`
//! — fine for spot checks, hopeless for equivalence sweeps and for serving
//! from the synthesized circuit.  This module stores a batch of samples as
//! *bit-planes* (one `u64` word holds the same bit of 64 samples) and
//! evaluates every `LutNode` over whole words: a 6-input LUT becomes a
//! short Shannon expansion of AND/OR/NOT word ops, so one pass computes 64
//! samples per core, parallelized over word-blocks via `util::pool`.
//!
//! Layout: [`BitMatrix`] is plane-major — plane `p` (one named bit: a
//! primary input, or one output bit) owns `words_per_plane` consecutive
//! `u64`s, and sample `s` lives at bit `s % 64` of word `s / 64`.  Bits at
//! or beyond `samples` in the last word of every plane are kept zero
//! (enforced by every constructor and by [`eval_netlist`]), so whole-word
//! comparisons between matrices are exact.
//!
//! The evaluation schedule is levelized implicitly: `Mapper` only ever
//! appends nodes whose inputs already exist, so node order is a topological
//! order and a single forward sweep per word suffices (checked by a
//! debug assertion).

use crate::synth::netlist::{Net, Netlist};
use crate::util::bits::var_word;
use crate::util::pool;

/// A batch of bit-vectors stored as bit-planes, 64 samples per word.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitMatrix {
    planes: usize,
    samples: usize,
    /// Words per plane: `samples.div_ceil(64)`.
    wpp: usize,
    /// Plane-major storage: plane `p` is `words[p*wpp .. (p+1)*wpp]`.
    words: Vec<u64>,
}

impl BitMatrix {
    pub fn new(planes: usize, samples: usize) -> BitMatrix {
        let wpp = samples.div_ceil(64);
        BitMatrix { planes, samples, wpp, words: vec![0u64; planes * wpp] }
    }

    pub fn planes(&self) -> usize {
        self.planes
    }

    pub fn samples(&self) -> usize {
        self.samples
    }

    pub fn words_per_plane(&self) -> usize {
        self.wpp
    }

    pub fn plane(&self, p: usize) -> &[u64] {
        &self.words[p * self.wpp..(p + 1) * self.wpp]
    }

    pub fn plane_mut(&mut self, p: usize) -> &mut [u64] {
        &mut self.words[p * self.wpp..(p + 1) * self.wpp]
    }

    /// Valid-bit mask of the last word of every plane.
    fn tail_mask(&self) -> u64 {
        let rem = self.samples % 64;
        if rem == 0 {
            u64::MAX
        } else {
            (1u64 << rem) - 1
        }
    }

    #[inline]
    pub fn get(&self, plane: usize, sample: usize) -> bool {
        debug_assert!(plane < self.planes && sample < self.samples);
        (self.words[plane * self.wpp + sample / 64] >> (sample % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, plane: usize, sample: usize, v: bool) {
        debug_assert!(plane < self.planes && sample < self.samples);
        let idx = plane * self.wpp + sample / 64;
        let bit = 1u64 << (sample % 64);
        if v {
            self.words[idx] |= bit;
        } else {
            self.words[idx] &= !bit;
        }
    }

    /// Write the `bw` bits of `code` into planes `base..base+bw` of one
    /// sample (bit `b` of the code lands in plane `base + b`) — the layout
    /// the synthesizer uses for a quantized activation bus.
    #[inline]
    pub fn set_code(&mut self, base: usize, bw: usize, sample: usize, code: u32) {
        debug_assert!(bw == 32 || (code as u64) < (1u64 << bw), "code {code} too wide");
        for b in 0..bw {
            self.set(base + b, sample, (code >> b) & 1 == 1);
        }
    }

    /// Read back a `bw`-bit code from planes `base..base+bw` of one sample.
    #[inline]
    pub fn get_code(&self, base: usize, bw: usize, sample: usize) -> u32 {
        let mut c = 0u32;
        for b in 0..bw {
            c |= (self.get(base + b, sample) as u32) << b;
        }
        c
    }

    /// Write one sample's full bit-vector (one column across all planes).
    pub fn set_column(&mut self, sample: usize, bits: &[bool]) {
        assert_eq!(bits.len(), self.planes);
        for (p, &b) in bits.iter().enumerate() {
            self.set(p, sample, b);
        }
    }

    /// Read one sample's full bit-vector.
    pub fn column(&self, sample: usize) -> Vec<bool> {
        (0..self.planes).map(|p| self.get(p, sample)).collect()
    }

    /// Enumerate all `2^k` input patterns as bit-planes: sample `s` of
    /// plane `v` is `(s >> v) & 1`.  This is how exhaustive table-vs-netlist
    /// equivalence enumerates a truth-table's index space in word-parallel
    /// form (64 patterns per word) instead of one scalar eval per pattern.
    pub fn all_patterns(k: usize) -> BitMatrix {
        assert!(k < usize::BITS as usize - 7, "pattern space 2^{k} too large");
        let samples = 1usize << k;
        let mut m = BitMatrix::new(k, samples);
        let (wpp, tail) = (m.wpp, m.tail_mask());
        for v in 0..k {
            for w in 0..wpp {
                let mut word = var_word(v, w);
                if w + 1 == wpp {
                    word &= tail;
                }
                m.words[v * wpp + w] = word;
            }
        }
        m
    }
}

/// Word-level evaluation of one K<=6-input LUT by Shannon expansion of its
/// packed truth table: `xs[j]` holds input `j` of 64 samples, the result
/// holds the LUT output of the same 64 samples.
#[inline]
pub fn lut_word(tt: u64, xs: &[u64]) -> u64 {
    let k = xs.len();
    debug_assert!(k <= 6, "LUT arity {k} > 6");
    let mask = if k >= 6 { u64::MAX } else { (1u64 << (1usize << k)) - 1 };
    lut_word_rec(tt & mask, xs, mask)
}

fn lut_word_rec(tt: u64, xs: &[u64], mask: u64) -> u64 {
    // Constant cofactors terminate most branches early: sparse and
    // saturated truth tables (the common LogicNets case) cost far fewer
    // than the worst-case 2^k word ops.
    if tt == 0 {
        return 0;
    }
    if tt == mask {
        return u64::MAX;
    }
    let k = xs.len();
    debug_assert!(k >= 1, "non-constant 0-input LUT");
    let half = 1usize << (k - 1);
    let lo_mask = (1u64 << half) - 1;
    let x = xs[k - 1];
    let f0 = lut_word_rec(tt & lo_mask, &xs[..k - 1], lo_mask);
    let f1 = lut_word_rec((tt >> half) & lo_mask, &xs[..k - 1], lo_mask);
    (x & f1) | (!x & f0)
}

#[inline]
fn read_net(inputs: &BitMatrix, vals: &[u64], net: Net, w: usize) -> u64 {
    match net {
        Net::Const0 => 0,
        Net::Const1 => u64::MAX,
        Net::Input(i) => inputs.plane(i as usize)[w],
        Net::Node(i) => vals[i as usize],
    }
}

/// Evaluate a whole word-block (a contiguous range of sample words): one
/// topological sweep over the nodes per word, all node values live in one
/// reused `vals` buffer.  Returns the output planes of the block, laid out
/// `[output][word_in_block]`.
fn eval_block(netlist: &Netlist, inputs: &BitMatrix, range: std::ops::Range<usize>) -> Vec<u64> {
    let len = range.len();
    let mut vals = vec![0u64; netlist.nodes.len()];
    let mut block = vec![0u64; netlist.outputs.len() * len];
    let mut xs = [0u64; 6];
    for (k, w) in range.enumerate() {
        for (i, node) in netlist.nodes.iter().enumerate() {
            let arity = node.inputs.len();
            debug_assert!(arity <= 6);
            for (j, &inp) in node.inputs.iter().enumerate() {
                xs[j] = read_net(inputs, &vals, inp, w);
            }
            vals[i] = lut_word(node.tt, &xs[..arity]);
        }
        for (oi, &o) in netlist.outputs.iter().enumerate() {
            block[oi * len + k] = read_net(inputs, &vals, o, w);
        }
    }
    block
}

/// Bitsliced batch evaluation of a netlist: `inputs` holds one plane per
/// primary input, the result one plane per output net.  Word-blocks are
/// distributed over the worker pool; each worker owns its value buffer and
/// writes a disjoint slice of the result, so the sweep is lock-free.
pub fn eval_netlist(netlist: &Netlist, inputs: &BitMatrix) -> BitMatrix {
    assert!(netlist.brams.is_empty(), "netlist with BRAM ports is not evaluable");
    assert_eq!(inputs.planes(), netlist.num_inputs, "input plane count");
    #[cfg(debug_assertions)]
    for (i, node) in netlist.nodes.iter().enumerate() {
        for &inp in &node.inputs {
            if let Net::Node(j) = inp {
                debug_assert!((j as usize) < i, "node {i} not in topological order");
            }
        }
    }
    let samples = inputs.samples();
    let mut out = BitMatrix::new(netlist.outputs.len(), samples);
    let wpp = inputs.words_per_plane();
    if wpp == 0 || netlist.outputs.is_empty() {
        return out;
    }
    let per = wpp.div_ceil(pool::num_threads()).max(1);
    let ranges: Vec<std::ops::Range<usize>> =
        (0..wpp).step_by(per).map(|lo| lo..(lo + per).min(wpp)).collect();
    let blocks: Vec<Vec<u64>> =
        pool::par_map(&ranges, |_, r| eval_block(netlist, inputs, r.clone()));
    let tail = out.tail_mask();
    for (range, block) in ranges.iter().zip(blocks) {
        let len = range.len();
        for p in 0..out.planes {
            for (k, w) in range.clone().enumerate() {
                let mut word = block[p * len + k];
                if w + 1 == wpp {
                    word &= tail;
                }
                out.words[p * out.wpp + w] = word;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::netlist::LutNode;
    use crate::util::rng::Rng;

    fn and_or_netlist() -> Netlist {
        // n0 = AND(in0, in1); n1 = OR(n0, in2); outputs exercise consts and
        // input passthrough alongside node outputs.
        Netlist {
            num_inputs: 3,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b1000, level: 1 },
                LutNode { inputs: vec![Net::Node(0), Net::Input(2)], tt: 0b1110, level: 2 },
            ],
            outputs: vec![Net::Node(1), Net::Const1, Net::Const0, Net::Input(2)],
            brams: vec![],
            layer_depths: vec![2],
        }
    }

    #[test]
    fn bitmatrix_set_get_roundtrip() {
        let mut m = BitMatrix::new(5, 130);
        let mut rng = Rng::new(1);
        let mut mirror = vec![vec![false; 130]; 5];
        for _ in 0..400 {
            let (p, s) = (rng.below(5), rng.below(130));
            let v = rng.f64() < 0.5;
            m.set(p, s, v);
            mirror[p][s] = v;
        }
        for p in 0..5 {
            for s in 0..130 {
                assert_eq!(m.get(p, s), mirror[p][s], "p={p} s={s}");
            }
        }
        // Tail invariant: bits beyond `samples` stay zero.
        let tail = m.tail_mask();
        for p in 0..5 {
            assert_eq!(m.plane(p)[2] & !tail, 0);
        }
    }

    #[test]
    fn codes_and_columns_roundtrip() {
        let mut m = BitMatrix::new(6, 70);
        m.set_code(2, 3, 65, 0b101);
        assert_eq!(m.get_code(2, 3, 65), 0b101);
        assert!(m.get(2, 65) && !m.get(3, 65) && m.get(4, 65));
        let bits = vec![true, false, true, true, false, false];
        m.set_column(7, &bits);
        assert_eq!(m.column(7), bits);
    }

    #[test]
    fn all_patterns_enumerates_indices() {
        for k in [1usize, 3, 6, 8] {
            let m = BitMatrix::all_patterns(k);
            assert_eq!(m.samples(), 1 << k);
            for s in 0..(1usize << k) {
                for v in 0..k {
                    assert_eq!(m.get(v, s), (s >> v) & 1 == 1, "k={k} s={s} v={v}");
                }
            }
        }
    }

    #[test]
    fn lut_word_matches_scalar_lookup() {
        let mut rng = Rng::new(7);
        for k in 0..=6usize {
            for _ in 0..20 {
                let tt = rng.next_u64();
                let xs: Vec<u64> = (0..k).map(|_| rng.next_u64()).collect();
                let word = lut_word(tt, &xs);
                for b in 0..64usize {
                    let mut idx = 0usize;
                    for (j, x) in xs.iter().enumerate() {
                        if (x >> b) & 1 == 1 {
                            idx |= 1 << j;
                        }
                    }
                    let expect = (tt >> idx) & 1 == 1;
                    assert_eq!((word >> b) & 1 == 1, expect, "k={k} bit={b}");
                }
            }
        }
    }

    #[test]
    fn eval_matches_scalar_on_mixed_outputs() {
        let nl = and_or_netlist();
        let samples = 130; // crosses word boundaries, non-multiple of 64
        let mut inputs = BitMatrix::new(3, samples);
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<bool>> = (0..samples)
            .map(|s| {
                let bits: Vec<bool> = (0..3).map(|_| rng.f64() < 0.5).collect();
                inputs.set_column(s, &bits);
                bits
            })
            .collect();
        let out = eval_netlist(&nl, &inputs);
        assert_eq!(out.planes(), 4);
        for (s, bits) in rows.iter().enumerate() {
            assert_eq!(out.column(s), nl.eval(bits), "sample {s}");
        }
        // Tail bits of every output plane (including Const1) must be zero.
        let tail = out.tail_mask();
        for p in 0..out.planes() {
            assert_eq!(out.plane(p)[out.words_per_plane() - 1] & !tail, 0, "plane {p}");
        }
    }

    #[test]
    fn eval_exhaustive_via_all_patterns() {
        let nl = and_or_netlist();
        let inputs = BitMatrix::all_patterns(3);
        let out = eval_netlist(&nl, &inputs);
        for idx in 0..8usize {
            let bits: Vec<bool> = (0..3).map(|v| (idx >> v) & 1 == 1).collect();
            assert_eq!(out.column(idx), nl.eval(&bits), "idx {idx}");
        }
    }

    #[test]
    fn empty_batch_and_empty_outputs() {
        let nl = and_or_netlist();
        let out = eval_netlist(&nl, &BitMatrix::new(3, 0));
        assert_eq!(out.samples(), 0);
        let mut no_out = nl.clone();
        no_out.outputs.clear();
        let out = eval_netlist(&no_out, &BitMatrix::new(3, 100));
        assert_eq!(out.planes(), 0);
    }
}
