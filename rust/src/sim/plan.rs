//! Levelized arena evaluation plan for the wide-plane simulator
//! (DESIGN.md §11).
//!
//! `eval_netlist` used to walk `Netlist::nodes` directly: per LUT it
//! matched every fan-in `Net` enum (branchy), chased node indices through
//! one buffer and input planes through another, and re-did all of that for
//! every word.  [`EvalPlan`] compiles a `Netlist` once into a flat arena —
//! per record one truth table plus pre-resolved *value-array slots* for its
//! fan-ins — so the inner loop is a branch-free sweep over contiguous
//! `(tt, slots)` records.  The value array is unified: slot 0/1 are the
//! constants, slots `2..2+num_inputs` are the primary-input chunks (loaded
//! once per chunk, hoisting the plane reads out of the per-LUT loop), and
//! the node records follow.  Records are stored in level order (levels are
//! recomputed from the wiring, so plans stay correct even if an
//! optimization pass left stale `LutNode::level` fields), which groups
//! same-depth LUTs contiguously for cache locality.
//!
//! Three capabilities added on top of the arena (DESIGN.md §11):
//!
//! - **SIMD dispatch**: each compile picks a [`SimdTier`] once
//!   ([`SimdTier::detect`], overridable via `LOGICNETS_SIMD`) and the
//!   record sweep routes through [`super::lut_chunk_at`], so AVX2 /
//!   AVX-512VL hosts run the intrinsic kernels while the portable fold
//!   stays the oracle.
//! - **BRAM records**: content-bearing `BramNeuron`s compile to
//!   [`BramRecord`]s (gather address slots → per-sample table lookup →
//!   scatter output bits into the pseudo-input slots), scheduled at level
//!   `1 + max(address levels)` before that level's LUT records — so
//!   BRAM-threshold designs run the wide path end to end instead of
//!   falling back to scalar.
//! - **Level-parallel splitting**: when one chunk carries enough
//!   independent records per level (width heuristic, default 4096,
//!   `LOGICNETS_LEVEL_PAR` overrides; 0 disables), a single-chunk batch —
//!   the serve single-sample latency case — partitions each level across
//!   a spawn-once worker scope with a barrier per level instead of
//!   running inline on one core.
//!
//! Evaluation is chunk-at-a-time: one [`super::Chunk`] (`LANES` × `u64` =
//! 256 samples) per net, with all scratch owned by a caller-passed
//! [`SimScratch`] so repeated evaluations (serving, verification sweeps)
//! allocate nothing after warmup.

use super::{lut_chunk_at, BitMatrix, Chunk, SimdTier, LANES};
use crate::obs;
use crate::synth::netlist::{Net, Netlist};
use crate::util::pool;
use std::sync::{Arc, Barrier, OnceLock};

/// Chunks-evaluated counter handle, cached so the per-chunk hot path is
/// one relaxed atomic add (no registry lookup).  One chunk = 256 samples
/// of work, so the overhead is far below the sim bench's 5% budget.
fn chunks_counter() -> &'static Arc<obs::Counter> {
    static CHUNKS: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    CHUNKS.get_or_init(|| obs::counter("sim.chunks_evaluated.count"))
}

/// Scratch-pool reuse counters ([`eval_plan`]'s worker scratch): a hit
/// means the passed [`SimScratch`] already held enough warmed-up workers,
/// a miss that it had to grow.  Counted once per call *before* the
/// inline/scoped split so the accounting is identical on both paths.
fn scratch_hits_counter() -> &'static Arc<obs::Counter> {
    static HITS: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    HITS.get_or_init(|| obs::counter("sim.scratch_pool.hits.count"))
}

fn scratch_misses_counter() -> &'static Arc<obs::Counter> {
    static MISSES: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    MISSES.get_or_init(|| obs::counter("sim.scratch_pool.misses.count"))
}

/// Records-per-level width at which a single chunk is worth splitting
/// across the pool.  `LOGICNETS_LEVEL_PAR=<n>` overrides (0 disables);
/// the default is calibrated by `bench_sim`'s `sim256-levelpar` scenarios
/// — below a few thousand records the per-level barrier costs more than
/// the split saves.
fn level_par_threshold() -> usize {
    std::env::var("LOGICNETS_LEVEL_PAR")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(4096)
}

/// One BRAM neuron in the arena schedule: gather the address chunks,
/// look up each sample's code, scatter the code bits into the pseudo-input
/// slots.  Scheduled before the LUT records of its level.
#[derive(Debug, Clone)]
struct BramRecord {
    /// Value-array slots of the address bits, LSB-first.
    addr_slots: Vec<u32>,
    /// First value-array slot of the pseudo-input output bits
    /// (`2 + out_base`).
    out_slot: u32,
    out_bits: u32,
    /// Output codes indexed by packed address (`1 << addr_slots.len()`).
    content: Vec<u32>,
}

/// A `Netlist` compiled to a level-ordered arena schedule.
#[derive(Debug, Clone)]
pub struct EvalPlan {
    num_inputs: usize,
    /// Truth table per record, in level order.
    tts: Vec<u64>,
    /// Flat fan-in arena: record `r` reads `slots[off[r]..off[r+1]]`.
    slots: Vec<u32>,
    off: Vec<u32>,
    /// Value-array slots of the netlist's output nets.
    out_slots: Vec<u32>,
    /// Exclusive record end index of each topological level (level `l`'s
    /// records are `level_ends[l-1]..level_ends[l]`, `level_ends[-1]` = 0).
    level_ends: Vec<u32>,
    /// BRAM records grouped by execution level; level `l` fires
    /// `brams[bram_ends[l-1]..bram_ends[l]]` before its LUT records.
    brams: Vec<BramRecord>,
    bram_ends: Vec<u32>,
    /// SIMD dispatch tier chosen at compile time.
    tier: SimdTier,
    /// Width heuristic verdict: worth splitting single chunks per level.
    level_par: bool,
}

impl EvalPlan {
    /// Compile a netlist into the arena schedule, dispatching to the
    /// widest SIMD tier the host supports ([`SimdTier::detect`]).  The
    /// structural preconditions (topological node order, in-range
    /// references, K<=6 fan-in, BRAM trigger ordering) are checked via
    /// `synth::lint::evaluability_errors` — the same rule set every
    /// `synthesize`/`opt` gate enforces — so a violation panics here with
    /// the full finding list instead of an ad-hoc assert.  Content-bearing
    /// BRAM records compile into the schedule; only opaque (content-less)
    /// BRAM ports are rejected.
    pub fn compile(netlist: &Netlist) -> EvalPlan {
        EvalPlan::compile_with_tier(netlist, SimdTier::detect())
    }

    /// [`Self::compile`] at an explicit dispatch tier — tests pin every
    /// supported tier against the portable oracle with this, and
    /// `bench_sim` uses it for the tier-comparison scenarios.  `tier`
    /// must be [`SimdTier::Portable`] or come from [`SimdTier::detect`] /
    /// [`SimdTier::supported`] on this host.
    pub fn compile_with_tier(netlist: &Netlist, tier: SimdTier) -> EvalPlan {
        obs::inc("sim.plan_compiles.count");
        assert!(
            netlist.brams_evaluable(),
            "netlist with opaque (content-less) BRAM ports is not evaluable"
        );
        let errs = crate::synth::lint::evaluability_errors(netlist);
        assert!(
            errs.is_empty(),
            "netlist is not evaluable; design-rule findings:\n{}",
            crate::synth::lint::LintReport { findings: errs }.render()
        );
        let nn = netlist.nodes.len();
        let base = (2 + netlist.num_inputs) as u32;
        // Levels recomputed from the wiring (stored `LutNode::level` fields
        // may be stale); topo order was validated above.  BRAMs are walked
        // at their trigger index: a BRAM's level is 1 + max over its
        // address levels, its pseudo inputs inherit that level, and any
        // consumer therefore lands at least one level later — which is
        // what lets eval fire each BRAM right before its level's records.
        let triggers = netlist.bram_triggers();
        let mut bram_level = vec![0u32; netlist.brams.len()];
        let mut input_level = vec![0u32; netlist.num_inputs];
        let mut placed = vec![false; netlist.brams.len()];
        let mut level = vec![0u32; nn];
        let mut max_level = 0u32;
        for i in 0..=nn {
            for (bi, b) in netlist.brams.iter().enumerate() {
                if placed[bi] || triggers[bi] > i {
                    continue;
                }
                let mut lv = 1u32;
                for &net in &b.inputs {
                    match net {
                        Net::Node(j) => lv = lv.max(level[j as usize] + 1),
                        Net::Input(p) => lv = lv.max(input_level[p as usize] + 1),
                        Net::Const0 | Net::Const1 => {}
                    }
                }
                bram_level[bi] = lv;
                for ob in 0..b.out_bits {
                    input_level[b.out_base as usize + ob] = lv;
                }
                max_level = max_level.max(lv);
                placed[bi] = true;
            }
            if i == nn {
                break;
            }
            let mut lv = 1u32;
            for &inp in &netlist.nodes[i].inputs {
                match inp {
                    Net::Node(j) => {
                        debug_assert!((j as usize) < i);
                        lv = lv.max(level[j as usize] + 1);
                    }
                    Net::Input(p) => lv = lv.max(input_level[p as usize] + 1),
                    Net::Const0 | Net::Const1 => {}
                }
            }
            level[i] = lv;
            max_level = max_level.max(lv);
        }
        // Counting sort into level order (stable: within a level, records
        // keep netlist order).  `pos[i]` = record index of original node i.
        let mut counts = vec![0u32; max_level as usize + 1];
        for &lv in &level {
            counts[lv as usize] += 1;
        }
        let mut starts = vec![0u32; max_level as usize + 1];
        let mut acc = 0u32;
        let mut level_ends = Vec::with_capacity(max_level as usize);
        for lv in 1..=max_level as usize {
            starts[lv] = acc;
            acc += counts[lv];
            level_ends.push(acc);
        }
        let mut pos = vec![0u32; nn];
        for (i, &lv) in level.iter().enumerate() {
            pos[i] = starts[lv as usize];
            starts[lv as usize] += 1;
        }
        let slot_of = |net: Net| -> u32 {
            match net {
                Net::Const0 => 0,
                Net::Const1 => 1,
                Net::Input(i) => 2 + i,
                Net::Node(i) => base + pos[i as usize],
            }
        };
        let mut tts = vec![0u64; nn];
        let mut arity = vec![0u32; nn];
        for (i, node) in netlist.nodes.iter().enumerate() {
            let r = pos[i] as usize;
            tts[r] = node.tt;
            arity[r] = node.inputs.len() as u32;
        }
        let mut off = Vec::with_capacity(nn + 1);
        off.push(0u32);
        for &a in &arity {
            off.push(off.last().unwrap() + a);
        }
        let mut slots = vec![0u32; *off.last().unwrap() as usize];
        for (i, node) in netlist.nodes.iter().enumerate() {
            let r = pos[i] as usize;
            for (j, &inp) in node.inputs.iter().enumerate() {
                slots[off[r] as usize + j] = slot_of(inp);
            }
        }
        let out_slots = netlist.outputs.iter().map(|&o| slot_of(o)).collect();
        // BRAM records grouped by execution level (stable within a level).
        let mut order: Vec<usize> = (0..netlist.brams.len()).collect();
        order.sort_by_key(|&bi| bram_level[bi]);
        let brams: Vec<BramRecord> = order
            .iter()
            .map(|&bi| {
                let b = &netlist.brams[bi];
                BramRecord {
                    addr_slots: b.inputs.iter().map(|&n| slot_of(n)).collect(),
                    out_slot: 2 + b.out_base,
                    out_bits: b.out_bits as u32,
                    content: b.content.clone(),
                }
            })
            .collect();
        let mut bram_ends = Vec::with_capacity(max_level as usize);
        let mut bi = 0usize;
        for lv in 1..=max_level {
            while bi < order.len() && bram_level[order[bi]] == lv {
                bi += 1;
            }
            bram_ends.push(bi as u32);
        }
        // Width heuristic for level-parallel single-chunk splitting.
        let mut max_width = 0u32;
        let mut prev = 0u32;
        for &e in &level_ends {
            max_width = max_width.max(e - prev);
            prev = e;
        }
        let threshold = level_par_threshold();
        let level_par = threshold != 0 && max_width as usize >= threshold;
        EvalPlan {
            num_inputs: netlist.num_inputs,
            tts,
            slots,
            off,
            out_slots,
            level_ends,
            brams,
            bram_ends,
            tier,
            level_par,
        }
    }

    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    pub fn num_outputs(&self) -> usize {
        self.out_slots.len()
    }

    pub fn num_luts(&self) -> usize {
        self.tts.len()
    }

    /// Number of BRAM records in the schedule.
    pub fn num_bram_records(&self) -> usize {
        self.brams.len()
    }

    /// The SIMD dispatch tier this plan was compiled for.
    pub fn tier(&self) -> SimdTier {
        self.tier
    }

    /// Whether the width heuristic enabled level-parallel single-chunk
    /// splitting ([`Self::eval_chunk_auto`]).
    pub fn level_parallel(&self) -> bool {
        self.level_par
    }

    /// Force the level-parallel verdict (tests and `bench_sim` calibrate
    /// both settings on the same plan).
    pub fn set_level_parallel(&mut self, on: bool) {
        self.level_par = on;
    }

    /// Topological depth of the schedule (number of levels).
    pub fn num_levels(&self) -> usize {
        self.level_ends.len()
    }

    /// Record count per level, cumulative (exclusive end indices).
    pub fn level_ends(&self) -> &[u32] {
        &self.level_ends
    }

    /// Value-array slots of the netlist outputs: after [`Self::eval_chunk`],
    /// output `o`'s chunk is `vals[out_slots[o]]`.
    pub fn output_slots(&self) -> &[u32] {
        &self.out_slots
    }

    /// Length of the value array [`Self::eval_chunk`] requires:
    /// 2 constants + one slot per primary input + one per record.
    pub fn vals_len(&self) -> usize {
        2 + self.num_inputs + self.tts.len()
    }

    /// Constants + hoisted primary-input plane reads — the value-array
    /// prelude shared by the serial and level-parallel chunk paths.
    fn load_chunk_inputs(&self, inputs: &BitMatrix, w0: usize, vals: &mut [Chunk]) {
        debug_assert_eq!(inputs.planes(), self.num_inputs, "input plane count");
        debug_assert_eq!(vals.len(), self.vals_len(), "value array length");
        let wpp = inputs.words_per_plane();
        let n = LANES.min(wpp - w0);
        vals[0] = [0u64; LANES];
        vals[1] = [u64::MAX; LANES];
        for i in 0..self.num_inputs {
            let plane = inputs.plane(i);
            let mut c = [0u64; LANES];
            c[..n].copy_from_slice(&plane[w0..w0 + n]);
            vals[2 + i] = c;
        }
    }

    /// Fire one BRAM record: the address chunks are gathered, each of the
    /// 256 samples' packed address is looked up, and the code bits are
    /// scattered into the pseudo-input slots.  The memory lookup is
    /// inherently per-sample; everything around it stays chunk-wide.
    fn eval_bram(&self, rec: &BramRecord, vals: &mut [Chunk]) {
        let k = rec.addr_slots.len();
        debug_assert!(k < 32 && rec.out_bits <= 32);
        let mut addr = [[0u64; LANES]; 32];
        for (j, &sl) in rec.addr_slots.iter().enumerate() {
            addr[j] = vals[sl as usize];
        }
        let ob = rec.out_bits as usize;
        let base = rec.out_slot as usize;
        for c in vals[base..base + ob].iter_mut() {
            *c = [0u64; LANES];
        }
        for l in 0..LANES {
            for s in 0..64usize {
                let mut idx = 0usize;
                for (j, a) in addr[..k].iter().enumerate() {
                    idx |= (((a[l] >> s) & 1) as usize) << j;
                }
                let code = rec.content[idx] as u64;
                for b in 0..ob {
                    vals[base + b][l] |= ((code >> b) & 1) << s;
                }
            }
        }
    }

    /// Evaluate every net over the words `w0 .. min(w0+LANES, wpp)` of the
    /// input planes.  On return `vals[slot]` holds each net's chunk —
    /// constants, hoisted primary-input reads, all node records, and every
    /// BRAM record's pseudo-input slots.  Lanes at or beyond the plane end
    /// read as zero and produce don't-care values (callers mask via
    /// `BitMatrix` tail handling).
    pub fn eval_chunk(&self, inputs: &BitMatrix, w0: usize, vals: &mut [Chunk]) {
        if obs::enabled() {
            chunks_counter().inc();
        }
        self.load_chunk_inputs(inputs, w0, vals);
        let base = 2 + self.num_inputs;
        let mut xs = [[0u64; LANES]; 6];
        if self.brams.is_empty() {
            // Flat fast path: one branch-free sweep over the whole arena.
            for r in 0..self.tts.len() {
                let (s, e) = (self.off[r] as usize, self.off[r + 1] as usize);
                for (j, &sl) in self.slots[s..e].iter().enumerate() {
                    xs[j] = vals[sl as usize];
                }
                vals[base + r] = lut_chunk_at(self.tier, self.tts[r], &xs[..e - s]);
            }
        } else {
            // Level walk: fire each level's BRAM records before its LUT
            // records (a BRAM's address operands sit at least one level
            // below it, its consumers at least one above).
            let (mut r0, mut b0) = (0usize, 0usize);
            for l in 0..self.level_ends.len() {
                let b1 = self.bram_ends[l] as usize;
                for rec in &self.brams[b0..b1] {
                    self.eval_bram(rec, vals);
                }
                b0 = b1;
                let r1 = self.level_ends[l] as usize;
                for r in r0..r1 {
                    let (s, e) = (self.off[r] as usize, self.off[r + 1] as usize);
                    for (j, &sl) in self.slots[s..e].iter().enumerate() {
                        xs[j] = vals[sl as usize];
                    }
                    vals[base + r] = lut_chunk_at(self.tier, self.tts[r], &xs[..e - s]);
                }
                r0 = r1;
            }
        }
    }

    /// [`Self::eval_chunk`], splitting wide levels across the pool when
    /// the compile-time width heuristic said it pays off and more than one
    /// thread is available.  This is the single-sample serve path's way to
    /// use the machine: a one-chunk batch has no chunk-level parallelism
    /// to exploit, but a wide netlist has thousands of independent records
    /// per level.
    pub fn eval_chunk_auto(&self, inputs: &BitMatrix, w0: usize, vals: &mut [Chunk]) {
        if self.level_par && pool::num_threads() > 1 {
            self.eval_chunk_level_par(inputs, w0, vals);
        } else {
            self.eval_chunk(inputs, w0, vals);
        }
    }

    /// Level-parallel chunk evaluation: the worker scope is spawned ONCE
    /// per chunk and a [`Barrier`] separates levels, so the per-level cost
    /// is a barrier round, not a spawn/join.  Workers write disjoint
    /// record slots within a level and only read slots written at earlier
    /// levels (or in the pre-spawn prelude); the barrier provides the
    /// happens-before edge between a level's writes and the next level's
    /// reads.  BRAM records are fired by worker 0 in an exclusive window
    /// (all other workers are between barriers doing nothing).
    fn eval_chunk_level_par(&self, inputs: &BitMatrix, w0: usize, vals: &mut [Chunk]) {
        if obs::enabled() {
            chunks_counter().inc();
        }
        self.load_chunk_inputs(inputs, w0, vals);
        let base = 2 + self.num_inputs;
        let nw = pool::num_threads().clamp(2, 16);
        let barrier = Barrier::new(nw);
        let sv = SharedVals(vals.as_mut_ptr());
        std::thread::scope(|scope| {
            for wid in 0..nw {
                let barrier = &barrier;
                scope.spawn(move || {
                    let mut xs = [[0u64; LANES]; 6];
                    let (mut r0, mut b0) = (0usize, 0usize);
                    for l in 0..self.level_ends.len() {
                        let b1 = self.bram_ends.get(l).map_or(b0, |&e| e as usize);
                        if b1 > b0 {
                            if wid == 0 {
                                // SAFETY: every other worker is parked
                                // between the previous level's barrier and
                                // the one below, touching nothing, so
                                // worker 0 has exclusive access to `vals`.
                                let all = unsafe {
                                    std::slice::from_raw_parts_mut(sv.0, self.vals_len())
                                };
                                for rec in &self.brams[b0..b1] {
                                    self.eval_bram(rec, all);
                                }
                            }
                            barrier.wait();
                        }
                        b0 = b1;
                        let r1 = self.level_ends[l] as usize;
                        let n = r1 - r0;
                        if n > 0 {
                            let per = n.div_ceil(nw);
                            let lo = r0 + (wid * per).min(n);
                            let hi = (lo + per).min(r1);
                            for r in lo..hi {
                                let (s, e) = (self.off[r] as usize, self.off[r + 1] as usize);
                                for (j, &sl) in self.slots[s..e].iter().enumerate() {
                                    // SAFETY: slot `sl` was written at an
                                    // earlier level (or pre-spawn) and no
                                    // one writes it during this level; the
                                    // barriers order those writes before
                                    // this read.
                                    xs[j] = unsafe { *sv.0.add(sl as usize) };
                                }
                                let out = lut_chunk_at(self.tier, self.tts[r], &xs[..e - s]);
                                // SAFETY: record `r` belongs to exactly one
                                // worker's sub-range, so slot `base + r` has
                                // a single writer and no reader this level.
                                unsafe { *sv.0.add(base + r) = out };
                            }
                        }
                        barrier.wait();
                        r0 = r1;
                    }
                });
            }
        });
    }

    /// Serial sweep over one chunk-aligned word range, writing the output
    /// planes into `ws.block` laid out `[output][word_in_range]`.  `auto`
    /// routes each chunk through [`Self::eval_chunk_auto`] — only the
    /// single-range inline path passes true (nested level-parallelism
    /// under an already-parallel range split would oversubscribe).
    fn eval_range(
        &self,
        inputs: &BitMatrix,
        range: std::ops::Range<usize>,
        ws: &mut WorkerScratch,
        auto: bool,
    ) {
        let len = range.len();
        ws.vals.resize(self.vals_len(), [0u64; LANES]);
        ws.block.resize(self.num_outputs() * len, 0);
        let mut w0 = range.start;
        while w0 < range.end {
            if auto {
                self.eval_chunk_auto(inputs, w0, &mut ws.vals);
            } else {
                self.eval_chunk(inputs, w0, &mut ws.vals);
            }
            let n = LANES.min(range.end - w0);
            for (o, &sl) in self.out_slots.iter().enumerate() {
                let v = &ws.vals[sl as usize];
                let dst = o * len + (w0 - range.start);
                ws.block[dst..dst + n].copy_from_slice(&v[..n]);
            }
            w0 += LANES;
        }
    }
}

/// Raw shared handle to the chunk value array for the level-parallel path.
/// Soundness rests on the schedule, not the type: within a level every
/// record slot has exactly one writer, and reads only target slots written
/// at earlier levels, with a `Barrier` round between levels establishing
/// the happens-before edges.
#[derive(Clone, Copy)]
struct SharedVals(*mut Chunk);

// SAFETY: see the struct docs — disjoint writes per level, barrier-ordered
// reads across levels.
unsafe impl Send for SharedVals {}
unsafe impl Sync for SharedVals {}

/// Reusable evaluation scratch: per-worker value buffers and output
/// blocks, grown on demand and reused across [`eval_plan`] calls (the
/// `ForwardScratch` pattern — repeated evaluations allocate nothing after
/// the first call).
#[derive(Debug, Default)]
pub struct SimScratch {
    workers: Vec<WorkerScratch>,
}

#[derive(Debug, Default)]
struct WorkerScratch {
    vals: Vec<Chunk>,
    block: Vec<u64>,
}

/// Wide-plane bitsliced evaluation of a compiled plan: 256 samples per
/// chunk per record, chunk-aligned word ranges distributed over the worker
/// pool (a single-range batch runs inline — no thread spawn for
/// router-sized batches — but may still split each chunk's levels across
/// the pool via [`EvalPlan::eval_chunk_auto`]).  All buffers live in
/// `scratch` and are reused across calls.
pub fn eval_plan(plan: &EvalPlan, inputs: &BitMatrix, scratch: &mut SimScratch) -> BitMatrix {
    assert_eq!(inputs.planes(), plan.num_inputs(), "input plane count");
    let samples = inputs.samples();
    let mut out = BitMatrix::new(plan.num_outputs(), samples);
    let wpp = inputs.words_per_plane();
    if wpp == 0 || plan.num_outputs() == 0 {
        return out;
    }
    let nchunks = wpp.div_ceil(LANES);
    let workers = pool::num_threads().min(nchunks).max(1);
    let per = nchunks.div_ceil(workers) * LANES;
    let ranges: Vec<std::ops::Range<usize>> =
        (0..wpp).step_by(per).map(|lo| lo..(lo + per).min(wpp)).collect();
    // Scratch-pool accounting happens before the inline/scoped split so a
    // single-range call is counted exactly like a scoped one.
    if obs::enabled() {
        if scratch.workers.len() < ranges.len() {
            scratch_misses_counter().inc();
        } else {
            scratch_hits_counter().inc();
        }
    }
    if scratch.workers.len() < ranges.len() {
        scratch.workers.resize_with(ranges.len(), WorkerScratch::default);
    }
    if ranges.len() == 1 {
        plan.eval_range(inputs, ranges[0].clone(), &mut scratch.workers[0], true);
    } else {
        std::thread::scope(|s| {
            for (range, ws) in ranges.iter().zip(scratch.workers.iter_mut()) {
                let range = range.clone();
                s.spawn(move || plan.eval_range(inputs, range, ws, false));
            }
        });
    }
    let tail = out.tail_mask();
    for (range, ws) in ranges.iter().zip(&scratch.workers) {
        let len = range.len();
        for o in 0..plan.num_outputs() {
            let plane = out.plane_mut(o);
            for (k, w) in range.clone().enumerate() {
                let mut word = ws.block[o * len + k];
                if w + 1 == wpp {
                    word &= tail;
                }
                plane[w] = word;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::netlist::{BramNeuron, LutNode};
    use crate::util::rng::Rng;

    fn and_or_netlist() -> Netlist {
        Netlist {
            num_inputs: 3,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b1000, level: 1 },
                LutNode { inputs: vec![Net::Node(0), Net::Input(2)], tt: 0b1110, level: 2 },
            ],
            outputs: vec![Net::Node(1), Net::Const1, Net::Const0, Net::Input(2), Net::Node(0)],
            brams: vec![],
            layer_depths: vec![2],
        }
    }

    #[test]
    fn compile_levelizes_and_maps_outputs() {
        let nl = and_or_netlist();
        let plan = EvalPlan::compile(&nl);
        assert_eq!(plan.num_inputs(), 3);
        assert_eq!(plan.num_luts(), 2);
        assert_eq!(plan.num_outputs(), 5);
        assert_eq!(plan.num_levels(), 2);
        assert_eq!(plan.level_ends(), &[1, 2]);
        // Slots: const0=0, const1=1, inputs 2..5, records 5..7.
        assert_eq!(plan.output_slots(), &[6, 1, 0, 4, 5]);
        assert_eq!(plan.vals_len(), 2 + 3 + 2);
        assert_eq!(plan.num_bram_records(), 0);
        // The compile-time tier is one the host is allowed to dispatch.
        assert!(SimdTier::supported().contains(&plan.tier()));
    }

    #[test]
    fn stale_level_fields_are_recomputed() {
        // An optimization pass may leave wrong `level` fields; the plan
        // must order by the real wiring, not the stored numbers.
        let mut nl = and_or_netlist();
        nl.nodes[0].level = 7;
        nl.nodes[1].level = 1;
        let plan = EvalPlan::compile(&nl);
        assert_eq!(plan.num_levels(), 2);
        assert_eq!(plan.level_ends(), &[1, 2]);
        // Behavior unchanged.
        let inputs = BitMatrix::all_patterns(3);
        let out = eval_plan(&plan, &inputs, &mut SimScratch::default());
        for idx in 0..8usize {
            let bits: Vec<bool> = (0..3).map(|v| (idx >> v) & 1 == 1).collect();
            assert_eq!(out.column(idx), nl.eval(&bits), "idx {idx}");
        }
    }

    #[test]
    fn plan_eval_matches_scalar_across_chunk_boundaries() {
        let nl = and_or_netlist();
        let plan = EvalPlan::compile(&nl);
        let mut scratch = SimScratch::default();
        for samples in [1usize, 63, 64, 65, 255, 256, 257, 300, 512] {
            let mut rng = Rng::new(samples as u64);
            let mut inputs = BitMatrix::new(3, samples);
            let rows: Vec<Vec<bool>> = (0..samples)
                .map(|s| {
                    let bits: Vec<bool> = (0..3).map(|_| rng.f64() < 0.5).collect();
                    inputs.set_column(s, &bits);
                    bits
                })
                .collect();
            let out = eval_plan(&plan, &inputs, &mut scratch);
            for (s, bits) in rows.iter().enumerate() {
                assert_eq!(out.column(s), nl.eval(bits), "samples={samples} s={s}");
            }
            // Tail invariant holds on every plane.
            let tail = out.tail_mask();
            for p in 0..out.planes() {
                assert_eq!(out.plane(p)[out.words_per_plane() - 1] & !tail, 0, "plane {p}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "not evaluable")]
    fn compile_rejects_forward_references() {
        let mut nl = and_or_netlist();
        nl.nodes[0].inputs[0] = Net::Node(1);
        let _ = EvalPlan::compile(&nl);
    }

    #[test]
    #[should_panic(expected = "not evaluable")]
    fn compile_rejects_opaque_brams() {
        let mut nl = and_or_netlist();
        nl.brams.push(BramNeuron::opaque(14, 2, 2));
        let _ = EvalPlan::compile(&nl);
    }

    #[test]
    fn empty_batch_and_empty_outputs() {
        let nl = and_or_netlist();
        let plan = EvalPlan::compile(&nl);
        let out = eval_plan(&plan, &BitMatrix::new(3, 0), &mut SimScratch::default());
        assert_eq!(out.samples(), 0);
        let mut no_out = nl.clone();
        no_out.outputs.clear();
        let plan = EvalPlan::compile(&no_out);
        let out = eval_plan(&plan, &BitMatrix::new(3, 300), &mut SimScratch::default());
        assert_eq!(out.planes(), 0);
        assert_eq!(out.samples(), 300);
    }

    /// A netlist whose middle stage is a content-bearing BRAM (LUT level
    /// feeds the address, LUTs consume the pseudo outputs): the wide plan
    /// must bit-match the scalar evaluator on every pattern, at every
    /// supported tier, with and without level-parallel splitting.
    #[test]
    fn bram_records_match_scalar_eval() {
        // Inputs 0..4 primary, inputs 4..6 pseudo (BRAM out_base 4).
        // n0 = XOR(in0, in1), n1 = AND(in2, in3) feed the BRAM address;
        // BRAM computes (a0 + 2*a1 + 1) mod 4; n2/n3 consume the pseudos.
        let content: Vec<u32> = (0..4u32).map(|a| (a + 1) % 4).collect();
        let nl = Netlist {
            num_inputs: 6,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b0110, level: 1 },
                LutNode { inputs: vec![Net::Input(2), Net::Input(3)], tt: 0b1000, level: 1 },
                LutNode { inputs: vec![Net::Input(4), Net::Input(5)], tt: 0b0110, level: 3 },
                LutNode { inputs: vec![Net::Node(2), Net::Input(4)], tt: 0b1000, level: 4 },
            ],
            outputs: vec![Net::Node(3), Net::Input(4), Net::Input(5), Net::Node(0)],
            brams: vec![BramNeuron {
                in_bits: 2,
                out_bits: 2,
                blocks: 1,
                inputs: vec![Net::Node(0), Net::Node(1)],
                out_base: 4,
                content,
            }],
            layer_depths: vec![4],
        };
        // Scalar reference over the 16 primary patterns (pseudo bits held
        // zero in the caller-provided vector; eval overwrites them).
        let mut inputs = BitMatrix::new(6, 16);
        let mut expect: Vec<Vec<bool>> = Vec::new();
        for s in 0..16usize {
            let mut bits = vec![false; 6];
            for v in 0..4 {
                bits[v] = (s >> v) & 1 == 1;
            }
            inputs.set_column(s, &bits);
            expect.push(nl.eval(&bits));
        }
        for tier in SimdTier::supported() {
            for level_par in [false, true] {
                let mut plan = EvalPlan::compile_with_tier(&nl, tier);
                assert_eq!(plan.num_bram_records(), 1);
                plan.set_level_parallel(level_par);
                let out = eval_plan(&plan, &inputs, &mut SimScratch::default());
                for (s, want) in expect.iter().enumerate() {
                    assert_eq!(
                        &out.column(s),
                        want,
                        "tier={} level_par={level_par} s={s}",
                        tier.name()
                    );
                }
            }
        }
    }

    /// Level-parallel splitting must be bit-exact against the serial chunk
    /// path on a netlist wide enough to actually split, at every tier.
    #[test]
    fn level_parallel_matches_serial() {
        // Two levels, 600 records each: level 1 mixes input pairs, level 2
        // mixes neighboring level-1 records.
        let mut rng = Rng::new(99);
        let mut nodes = Vec::new();
        for i in 0..600u32 {
            nodes.push(LutNode {
                inputs: vec![Net::Input(i % 24), Net::Input((i * 7 + 1) % 24)],
                tt: rng.next_u64(),
                level: 1,
            });
        }
        for i in 0..600u32 {
            nodes.push(LutNode {
                inputs: vec![Net::Node(i), Net::Node((i + 13) % 600), Net::Input(i % 24)],
                tt: rng.next_u64(),
                level: 2,
            });
        }
        let outputs: Vec<Net> = (0..40u32).map(|i| Net::Node(600 + i * 14)).collect();
        let nl = Netlist { num_inputs: 24, nodes, outputs, brams: vec![], layer_depths: vec![2] };
        // Single-chunk batches (<= 256 samples) are the ones that actually
        // route through the level-parallel splitter ([`eval_plan`]'s
        // multi-range scoped path passes `auto = false`); 257 rides along
        // to cover the chunk-boundary serial path under the same plan.
        for samples in [1usize, 64, 255, 256, 257] {
            let mut inputs = BitMatrix::new(24, samples);
            for s in 0..samples {
                for p in 0..24 {
                    inputs.set(p, s, rng.f64() < 0.5);
                }
            }
            for tier in SimdTier::supported() {
                let mut plan = EvalPlan::compile_with_tier(&nl, tier);
                plan.set_level_parallel(false);
                let serial = eval_plan(&plan, &inputs, &mut SimScratch::default());
                plan.set_level_parallel(true);
                let par = eval_plan(&plan, &inputs, &mut SimScratch::default());
                assert_eq!(serial, par, "tier={} samples={samples}", tier.name());
            }
        }
    }
}
