//! Levelized arena evaluation plan for the wide-plane simulator
//! (DESIGN.md §11).
//!
//! `eval_netlist` used to walk `Netlist::nodes` directly: per LUT it
//! matched every fan-in `Net` enum (branchy), chased node indices through
//! one buffer and input planes through another, and re-did all of that for
//! every word.  [`EvalPlan`] compiles a `Netlist` once into a flat arena —
//! per record one truth table plus pre-resolved *value-array slots* for its
//! fan-ins — so the inner loop is a branch-free sweep over contiguous
//! `(tt, slots)` records.  The value array is unified: slot 0/1 are the
//! constants, slots `2..2+num_inputs` are the primary-input chunks (loaded
//! once per chunk, hoisting the plane reads out of the per-LUT loop), and
//! the node records follow.  Records are stored in level order (levels are
//! recomputed from the wiring, so plans stay correct even if an
//! optimization pass left stale `LutNode::level` fields), which groups
//! same-depth LUTs contiguously for cache locality.
//!
//! Evaluation is chunk-at-a-time: one [`super::Chunk`] (`LANES` × `u64` =
//! 256 samples) per net, with all scratch owned by a caller-passed
//! [`SimScratch`] so repeated evaluations (serving, verification sweeps)
//! allocate nothing after warmup.

use super::{lut_chunk, BitMatrix, Chunk, LANES};
use crate::obs;
use crate::synth::netlist::{Net, Netlist};
use crate::util::pool;
use std::sync::{Arc, OnceLock};

/// Chunks-evaluated counter handle, cached so the per-chunk hot path is
/// one relaxed atomic add (no registry lookup).  One chunk = 256 samples
/// of work, so the overhead is far below the sim bench's 5% budget.
fn chunks_counter() -> &'static Arc<obs::Counter> {
    static CHUNKS: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    CHUNKS.get_or_init(|| obs::counter("sim.chunks_evaluated.count"))
}

/// A `Netlist` compiled to a level-ordered arena schedule.
#[derive(Debug, Clone)]
pub struct EvalPlan {
    num_inputs: usize,
    /// Truth table per record, in level order.
    tts: Vec<u64>,
    /// Flat fan-in arena: record `r` reads `slots[off[r]..off[r+1]]`.
    slots: Vec<u32>,
    off: Vec<u32>,
    /// Value-array slots of the netlist's output nets.
    out_slots: Vec<u32>,
    /// Exclusive record end index of each topological level (level `l`'s
    /// records are `level_ends[l-1]..level_ends[l]`, `level_ends[-1]` = 0).
    level_ends: Vec<u32>,
}

impl EvalPlan {
    /// Compile a netlist into the arena schedule.  The structural
    /// preconditions (topological node order, in-range references, K<=6
    /// fan-in) are checked via `synth::lint::evaluability_errors` — the
    /// same rule set every `synthesize`/`opt` gate enforces — so a violation
    /// panics here with the full finding list instead of an ad-hoc assert.
    /// BRAM ports are rejected at evaluation time, as before.
    pub fn compile(netlist: &Netlist) -> EvalPlan {
        obs::inc("sim.plan_compiles.count");
        assert!(netlist.brams.is_empty(), "netlist with BRAM ports is not evaluable");
        let errs = crate::synth::lint::evaluability_errors(netlist);
        assert!(
            errs.is_empty(),
            "netlist is not evaluable; design-rule findings:\n{}",
            crate::synth::lint::LintReport { findings: errs }.render()
        );
        let nn = netlist.nodes.len();
        let base = (2 + netlist.num_inputs) as u32;
        // Levels recomputed from the wiring (stored `LutNode::level` fields
        // may be stale); topo order was validated above.
        let mut level = vec![0u32; nn];
        let mut max_level = 0u32;
        for (i, node) in netlist.nodes.iter().enumerate() {
            let mut lv = 1u32;
            for &inp in &node.inputs {
                if let Net::Node(j) = inp {
                    debug_assert!((j as usize) < i);
                    lv = lv.max(level[j as usize] + 1);
                }
            }
            level[i] = lv;
            max_level = max_level.max(lv);
        }
        // Counting sort into level order (stable: within a level, records
        // keep netlist order).  `pos[i]` = record index of original node i.
        let mut counts = vec![0u32; max_level as usize + 1];
        for &lv in &level {
            counts[lv as usize] += 1;
        }
        let mut starts = vec![0u32; max_level as usize + 1];
        let mut acc = 0u32;
        let mut level_ends = Vec::with_capacity(max_level as usize);
        for lv in 1..=max_level as usize {
            starts[lv] = acc;
            acc += counts[lv];
            level_ends.push(acc);
        }
        let mut pos = vec![0u32; nn];
        for (i, &lv) in level.iter().enumerate() {
            pos[i] = starts[lv as usize];
            starts[lv as usize] += 1;
        }
        let slot_of = |net: Net| -> u32 {
            match net {
                Net::Const0 => 0,
                Net::Const1 => 1,
                Net::Input(i) => 2 + i,
                Net::Node(i) => base + pos[i as usize],
            }
        };
        let mut tts = vec![0u64; nn];
        let mut arity = vec![0u32; nn];
        for (i, node) in netlist.nodes.iter().enumerate() {
            let r = pos[i] as usize;
            tts[r] = node.tt;
            arity[r] = node.inputs.len() as u32;
        }
        let mut off = Vec::with_capacity(nn + 1);
        off.push(0u32);
        for &a in &arity {
            off.push(off.last().unwrap() + a);
        }
        let mut slots = vec![0u32; *off.last().unwrap() as usize];
        for (i, node) in netlist.nodes.iter().enumerate() {
            let r = pos[i] as usize;
            for (j, &inp) in node.inputs.iter().enumerate() {
                slots[off[r] as usize + j] = slot_of(inp);
            }
        }
        let out_slots = netlist.outputs.iter().map(|&o| slot_of(o)).collect();
        EvalPlan { num_inputs: netlist.num_inputs, tts, slots, off, out_slots, level_ends }
    }

    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    pub fn num_outputs(&self) -> usize {
        self.out_slots.len()
    }

    pub fn num_luts(&self) -> usize {
        self.tts.len()
    }

    /// Topological depth of the schedule (number of levels).
    pub fn num_levels(&self) -> usize {
        self.level_ends.len()
    }

    /// Record count per level, cumulative (exclusive end indices).
    pub fn level_ends(&self) -> &[u32] {
        &self.level_ends
    }

    /// Value-array slots of the netlist outputs: after [`Self::eval_chunk`],
    /// output `o`'s chunk is `vals[out_slots[o]]`.
    pub fn output_slots(&self) -> &[u32] {
        &self.out_slots
    }

    /// Length of the value array [`Self::eval_chunk`] requires:
    /// 2 constants + one slot per primary input + one per record.
    pub fn vals_len(&self) -> usize {
        2 + self.num_inputs + self.tts.len()
    }

    /// Evaluate every net over the words `w0 .. min(w0+LANES, wpp)` of the
    /// input planes.  On return `vals[slot]` holds each net's chunk —
    /// constants, hoisted primary-input reads, and all node records.  Lanes
    /// at or beyond the plane end read as zero and produce don't-care
    /// values (callers mask via `BitMatrix` tail handling).
    pub fn eval_chunk(&self, inputs: &BitMatrix, w0: usize, vals: &mut [Chunk]) {
        if obs::enabled() {
            chunks_counter().inc();
        }
        debug_assert_eq!(inputs.planes(), self.num_inputs, "input plane count");
        debug_assert_eq!(vals.len(), self.vals_len(), "value array length");
        let wpp = inputs.words_per_plane();
        let n = LANES.min(wpp - w0);
        vals[0] = [0u64; LANES];
        vals[1] = [u64::MAX; LANES];
        for i in 0..self.num_inputs {
            let plane = inputs.plane(i);
            let mut c = [0u64; LANES];
            c[..n].copy_from_slice(&plane[w0..w0 + n]);
            vals[2 + i] = c;
        }
        let base = 2 + self.num_inputs;
        let mut xs = [[0u64; LANES]; 6];
        for r in 0..self.tts.len() {
            let (s, e) = (self.off[r] as usize, self.off[r + 1] as usize);
            for (j, &sl) in self.slots[s..e].iter().enumerate() {
                xs[j] = vals[sl as usize];
            }
            vals[base + r] = lut_chunk(self.tts[r], &xs[..e - s]);
        }
    }

    /// Serial sweep over one chunk-aligned word range, writing the output
    /// planes into `ws.block` laid out `[output][word_in_range]`.
    fn eval_range(&self, inputs: &BitMatrix, range: std::ops::Range<usize>, ws: &mut WorkerScratch) {
        let len = range.len();
        ws.vals.resize(self.vals_len(), [0u64; LANES]);
        ws.block.resize(self.num_outputs() * len, 0);
        let mut w0 = range.start;
        while w0 < range.end {
            self.eval_chunk(inputs, w0, &mut ws.vals);
            let n = LANES.min(range.end - w0);
            for (o, &sl) in self.out_slots.iter().enumerate() {
                let v = &ws.vals[sl as usize];
                let dst = o * len + (w0 - range.start);
                ws.block[dst..dst + n].copy_from_slice(&v[..n]);
            }
            w0 += LANES;
        }
    }
}

/// Reusable evaluation scratch: per-worker value buffers and output
/// blocks, grown on demand and reused across [`eval_plan`] calls (the
/// `ForwardScratch` pattern — repeated evaluations allocate nothing after
/// the first call).
#[derive(Debug, Default)]
pub struct SimScratch {
    workers: Vec<WorkerScratch>,
}

#[derive(Debug, Default)]
struct WorkerScratch {
    vals: Vec<Chunk>,
    block: Vec<u64>,
}

/// Wide-plane bitsliced evaluation of a compiled plan: 256 samples per
/// chunk per record, chunk-aligned word ranges distributed over the worker
/// pool (a single-range batch runs inline — no thread spawn for
/// router-sized batches).  All buffers live in `scratch` and are reused
/// across calls.
pub fn eval_plan(plan: &EvalPlan, inputs: &BitMatrix, scratch: &mut SimScratch) -> BitMatrix {
    assert_eq!(inputs.planes(), plan.num_inputs(), "input plane count");
    let samples = inputs.samples();
    let mut out = BitMatrix::new(plan.num_outputs(), samples);
    let wpp = inputs.words_per_plane();
    if wpp == 0 || plan.num_outputs() == 0 {
        return out;
    }
    let nchunks = wpp.div_ceil(LANES);
    let workers = pool::num_threads().min(nchunks).max(1);
    let per = nchunks.div_ceil(workers) * LANES;
    let ranges: Vec<std::ops::Range<usize>> =
        (0..wpp).step_by(per).map(|lo| lo..(lo + per).min(wpp)).collect();
    if scratch.workers.len() < ranges.len() {
        scratch.workers.resize_with(ranges.len(), WorkerScratch::default);
    }
    if ranges.len() == 1 {
        plan.eval_range(inputs, ranges[0].clone(), &mut scratch.workers[0]);
    } else {
        std::thread::scope(|s| {
            for (range, ws) in ranges.iter().zip(scratch.workers.iter_mut()) {
                let range = range.clone();
                s.spawn(move || plan.eval_range(inputs, range, ws));
            }
        });
    }
    let tail = out.tail_mask();
    for (range, ws) in ranges.iter().zip(&scratch.workers) {
        let len = range.len();
        for o in 0..plan.num_outputs() {
            let plane = out.plane_mut(o);
            for (k, w) in range.clone().enumerate() {
                let mut word = ws.block[o * len + k];
                if w + 1 == wpp {
                    word &= tail;
                }
                plane[w] = word;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::netlist::LutNode;
    use crate::util::rng::Rng;

    fn and_or_netlist() -> Netlist {
        Netlist {
            num_inputs: 3,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b1000, level: 1 },
                LutNode { inputs: vec![Net::Node(0), Net::Input(2)], tt: 0b1110, level: 2 },
            ],
            outputs: vec![Net::Node(1), Net::Const1, Net::Const0, Net::Input(2), Net::Node(0)],
            brams: vec![],
            layer_depths: vec![2],
        }
    }

    #[test]
    fn compile_levelizes_and_maps_outputs() {
        let nl = and_or_netlist();
        let plan = EvalPlan::compile(&nl);
        assert_eq!(plan.num_inputs(), 3);
        assert_eq!(plan.num_luts(), 2);
        assert_eq!(plan.num_outputs(), 5);
        assert_eq!(plan.num_levels(), 2);
        assert_eq!(plan.level_ends(), &[1, 2]);
        // Slots: const0=0, const1=1, inputs 2..5, records 5..7.
        assert_eq!(plan.output_slots(), &[6, 1, 0, 4, 5]);
        assert_eq!(plan.vals_len(), 2 + 3 + 2);
    }

    #[test]
    fn stale_level_fields_are_recomputed() {
        // An optimization pass may leave wrong `level` fields; the plan
        // must order by the real wiring, not the stored numbers.
        let mut nl = and_or_netlist();
        nl.nodes[0].level = 7;
        nl.nodes[1].level = 1;
        let plan = EvalPlan::compile(&nl);
        assert_eq!(plan.num_levels(), 2);
        assert_eq!(plan.level_ends(), &[1, 2]);
        // Behavior unchanged.
        let inputs = BitMatrix::all_patterns(3);
        let out = eval_plan(&plan, &inputs, &mut SimScratch::default());
        for idx in 0..8usize {
            let bits: Vec<bool> = (0..3).map(|v| (idx >> v) & 1 == 1).collect();
            assert_eq!(out.column(idx), nl.eval(&bits), "idx {idx}");
        }
    }

    #[test]
    fn plan_eval_matches_scalar_across_chunk_boundaries() {
        let nl = and_or_netlist();
        let plan = EvalPlan::compile(&nl);
        let mut scratch = SimScratch::default();
        for samples in [1usize, 63, 64, 65, 255, 256, 257, 300, 512] {
            let mut rng = Rng::new(samples as u64);
            let mut inputs = BitMatrix::new(3, samples);
            let rows: Vec<Vec<bool>> = (0..samples)
                .map(|s| {
                    let bits: Vec<bool> = (0..3).map(|_| rng.f64() < 0.5).collect();
                    inputs.set_column(s, &bits);
                    bits
                })
                .collect();
            let out = eval_plan(&plan, &inputs, &mut scratch);
            for (s, bits) in rows.iter().enumerate() {
                assert_eq!(out.column(s), nl.eval(bits), "samples={samples} s={s}");
            }
            // Tail invariant holds on every plane.
            let tail = out.tail_mask();
            for p in 0..out.planes() {
                assert_eq!(out.plane(p)[out.words_per_plane() - 1] & !tail, 0, "plane {p}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "not evaluable")]
    fn compile_rejects_forward_references() {
        let mut nl = and_or_netlist();
        nl.nodes[0].inputs[0] = Net::Node(1);
        let _ = EvalPlan::compile(&nl);
    }

    #[test]
    fn empty_batch_and_empty_outputs() {
        let nl = and_or_netlist();
        let plan = EvalPlan::compile(&nl);
        let out = eval_plan(&plan, &BitMatrix::new(3, 0), &mut SimScratch::default());
        assert_eq!(out.samples(), 0);
        let mut no_out = nl.clone();
        no_out.outputs.clear();
        let plan = EvalPlan::compile(&no_out);
        let out = eval_plan(&plan, &BitMatrix::new(3, 300), &mut SimScratch::default());
        assert_eq!(out.planes(), 0);
        assert_eq!(out.samples(), 300);
    }
}
