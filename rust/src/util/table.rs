//! Aligned text-table printer for experiment output (the `logicnets table
//! 6.2`-style commands print the same rows the paper's tables report).

#[derive(Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl TextTable {
    pub fn new(title: &str, header: &[&str]) -> TextTable {
        TextTable {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn to_string(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1))));
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }

    /// CSV form, written next to the printed table for plotting (figures).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// f64 formatting helpers used across experiment tables.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f4(v: f64) -> String {
    format!("{v:.4}")
}

/// Human-readable LUT counts: 2112 -> "2112", 131072 -> "131.1k".
pub fn kfmt(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e4 {
        format!("{:.1}k", v / 1e3)
    } else {
        format!("{}", v.round() as i64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new("T", &["Model", "LUTs"]);
        t.row(vec!["A".into(), "2112".into()]);
        t.row(vec!["LongName".into(), "64".into()]);
        let s = t.to_string();
        assert!(s.contains("== T =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        // column 2 starts at the same offset in all rows
        let off = lines[1].find("LUTs").unwrap();
        assert_eq!(lines[3].find("2112").unwrap(), off);
        assert_eq!(lines[4].find("64").unwrap(), off);
    }

    #[test]
    fn csv_escapes() {
        let mut t = TextTable::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn kfmt_ranges() {
        assert_eq!(kfmt(2112.0), "2112");
        assert_eq!(kfmt(131072.0), "131.1k");
        assert_eq!(kfmt(2.5e6), "2.50M");
    }
}
