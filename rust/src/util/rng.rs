//! Deterministic PRNG (xoshiro256**) plus the sampling helpers the library
//! needs: uniform, normal (Box-Muller), permutations, subset selection.
//!
//! Everything in this repo that involves randomness — mask generation,
//! parameter init, synthetic datasets, property tests — goes through this
//! type with an explicit seed, so experiments are exactly reproducible.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (e.g. per layer, per worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.normal() as f32) * std + mean
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n), sorted — the per-neuron fan-in choice.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        if k * 3 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx.sort_unstable();
            idx
        } else {
            // sparse rejection sampling
            let mut seen = std::collections::BTreeSet::new();
            while seen.len() < k {
                seen.insert(self.below(n));
            }
            seen.into_iter().collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_k_distinct_sorted() {
        let mut r = Rng::new(3);
        for n in [5usize, 64, 784] {
            for k in [1usize, 2, n.min(7)] {
                let c = r.choose_k(n, k);
                assert_eq!(c.len(), k);
                assert!(c.windows(2).all(|w| w[0] < w[1]));
                assert!(c.iter().all(|&i| i < n));
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn uniform_in_unit() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
