//! Minimal JSON parser + emitter.
//!
//! The build environment has no network access, so serde is unavailable;
//! this module is the in-tree substrate used for model manifests
//! (`artifacts/*/manifest.json`), config files (`configs/models.json`) and
//! experiment output.  It supports the full JSON grammar with f64 numbers.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Field helpers that fail loudly with the key name.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow::anyhow!("key {key:?} not usize"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow::anyhow!("key {key:?} not number"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow::anyhow!("key {key:?} not string"))
    }

    pub fn opt_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.as_usize())
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    // ---- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- emission ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in our data; map lone
                            // surrogates to the replacement character.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one utf-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": null, "c": "x\ny", "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req_f64("a").is_err(), true);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"layers":[{"in":16,"out":64}]}"#).unwrap();
        let l0 = &v.get("layers").unwrap().as_arr().unwrap()[0];
        assert_eq!(l0.req_usize("in").unwrap(), 16);
        assert_eq!(l0.req_usize("out").unwrap(), 64);
    }
}
