//! Scoped data-parallel helpers over std::thread (no tokio offline).
//!
//! The hot users are truth-table generation (one neuron per task) and the
//! serving engine's worker pool; both are embarrassingly parallel with
//! chunky tasks, so a simple scoped fork-join is the right tool.

/// Number of worker threads to use (respects `LOGICNETS_THREADS`).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("LOGICNETS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f(i, &items[i]) -> R` over all items on up to `num_threads()`
/// workers; results are returned in input order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = num_threads().min(n);
    if workers <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = std::sync::Mutex::new(&mut results);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                // Each index is written exactly once; the mutex only guards
                // the Vec borrow, contention is negligible for chunky tasks.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    results.into_iter().map(|r| r.expect("worker filled slot")).collect()
}

/// Split `out` into near-equal contiguous chunks, one per worker, and run
/// `f(chunk_index, start_offset, chunk)` on each.  Every worker owns a
/// disjoint `&mut` sub-slice, so results are written in place with no lock
/// and no gather copy — this is the batch-inference output path.
pub fn par_chunks_mut<T, F>(out: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let workers = num_threads().min(n);
    if workers <= 1 {
        f(0, 0, out);
        return;
    }
    let per = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, chunk) in out.chunks_mut(per).enumerate() {
            let f = &f;
            s.spawn(move || f(w, w * per, chunk));
        }
    });
}

/// Run `f(chunk_index, range)` for `n` items split into near-equal ranges,
/// one per worker.  Used when the work wants big contiguous slices.
pub fn par_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = num_threads().min(n);
    let per = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let lo = w * per;
            let hi = ((w + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(w, lo..hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty() {
        let items: Vec<u32> = vec![];
        assert!(par_map(&items, |_, &x| x).is_empty());
    }

    #[test]
    fn par_chunks_mut_writes_disjoint_slices() {
        let n = 517;
        let mut out = vec![0usize; n];
        par_chunks_mut(&mut out, |_, start, chunk| {
            for (k, slot) in chunk.iter_mut().enumerate() {
                *slot = (start + k) * 3;
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i * 3));
        let mut empty: Vec<u8> = vec![];
        par_chunks_mut(&mut empty, |_, _, _| panic!("no chunks for empty input"));
    }

    #[test]
    fn par_chunks_covers_all() {
        let n = 1003;
        let hits = std::sync::Mutex::new(vec![0u8; n]);
        par_chunks(n, |_, range| {
            let mut g = hits.lock().unwrap();
            for i in range {
                g[i] += 1;
            }
        });
        assert!(hits.into_inner().unwrap().iter().all(|&h| h == 1));
    }
}
