//! Bit-vector utilities: packed truth tables, index packing, bit iteration.
//!
//! A LogicNets neuron's truth table maps `fanin * bw` input bits to `bw_out`
//! output bits.  Tables are stored packed: output *codes* (not dequantized
//! values) in a `Vec<u64>` with `bw_out` bits per entry.

/// Fixed-width packed array of `width`-bit codes.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedCodes {
    words: Vec<u64>,
    pub width: usize,
    pub len: usize,
}

impl PackedCodes {
    pub fn new(len: usize, width: usize) -> PackedCodes {
        assert!((1..=32).contains(&width), "width {width}");
        let bits = len * width;
        PackedCodes { words: vec![0; bits.div_ceil(64)], width, len }
    }

    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len);
        let bit = i * self.width;
        let (w, off) = (bit / 64, bit % 64);
        let mask = if self.width == 64 { u64::MAX } else { (1u64 << self.width) - 1 };
        let lo = self.words[w] >> off;
        let v = if off + self.width > 64 {
            lo | (self.words[w + 1] << (64 - off))
        } else {
            lo
        };
        (v & mask) as u32
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: u32) {
        debug_assert!(i < self.len);
        debug_assert!(self.width == 32 || (v as u64) < (1u64 << self.width), "code {v} too wide");
        let bit = i * self.width;
        let (w, off) = (bit / 64, bit % 64);
        let mask = if self.width == 64 { u64::MAX } else { (1u64 << self.width) - 1 };
        self.words[w] &= !(mask << off);
        self.words[w] |= (v as u64 & mask) << off;
        if off + self.width > 64 {
            let hi_bits = off + self.width - 64;
            let hi_mask = (1u64 << hi_bits) - 1;
            self.words[w + 1] &= !hi_mask;
            self.words[w + 1] |= (v as u64 & mask) >> (64 - off);
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// Pack per-input quantizer codes into a truth-table index.  Input j
/// occupies bits `[j*bw, (j+1)*bw)` — must match
/// python/compile/kernels/lut_lookup.py.
#[inline]
pub fn pack_index(codes: &[u32], bw: usize) -> usize {
    let mut idx = 0usize;
    for (j, &c) in codes.iter().enumerate() {
        debug_assert!((c as usize) < (1usize << bw));
        idx |= (c as usize) << (bw * j);
    }
    idx
}

/// Inverse of `pack_index`: unpack index into `fanin` codes of `bw` bits.
#[inline]
pub fn unpack_index(idx: usize, bw: usize, fanin: usize, out: &mut [u32]) {
    let mask = (1usize << bw) - 1;
    for (j, o) in out.iter_mut().enumerate().take(fanin) {
        *o = ((idx >> (bw * j)) & mask) as u32;
    }
}

/// Iterate the bits of `v` (LSB-first), up to `n` bits.
pub fn bits_lsb(v: u64, n: usize) -> impl Iterator<Item = bool> {
    (0..n).map(move |i| (v >> i) & 1 == 1)
}

/// Periodic bit-plane patterns of the first six index variables: bit `s` of
/// `VAR_MASKS[v]` equals `(s >> v) & 1`.  These are the word-level planes
/// used when an index space (minterms of a truth table, or the samples
/// `0..2^k` of an exhaustive enumeration) is packed 64 per `u64`.
pub const VAR_MASKS: [u64; 6] = [
    0xAAAA_AAAA_AAAA_AAAA,
    0xCCCC_CCCC_CCCC_CCCC,
    0xF0F0_F0F0_F0F0_F0F0,
    0xFF00_FF00_FF00_FF00,
    0xFFFF_0000_FFFF_0000,
    0xFFFF_FFFF_0000_0000,
];

/// Word `w` of index-variable `v`'s bit-plane over a packed index space:
/// bit `b` of the result equals `((64*w + b) >> v) & 1`.  Variables 0..5
/// toggle within a word (periodic masks); higher variables are constant
/// across a whole word.
#[inline]
pub fn var_word(v: usize, w: usize) -> u64 {
    if v < 6 {
        VAR_MASKS[v]
    } else if (w >> (v - 6)) & 1 == 1 {
        u64::MAX
    } else {
        0
    }
}

/// Population count of a packed boolean function given as u64 words over
/// `n_bits` valid bits.
pub fn popcount_words(words: &[u64], n_bits: usize) -> usize {
    let mut total = 0usize;
    let full = n_bits / 64;
    for w in &words[..full] {
        total += w.count_ones() as usize;
    }
    let rem = n_bits % 64;
    if rem > 0 {
        total += (words[full] & ((1u64 << rem) - 1)).count_ones() as usize;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_roundtrip_all_widths() {
        for width in [1usize, 2, 3, 4, 5, 7, 8, 13, 17, 32] {
            let len = 257;
            let mut p = PackedCodes::new(len, width);
            let mask = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
            for i in 0..len {
                p.set(i, (i as u32).wrapping_mul(2654435761) & mask);
            }
            for i in 0..len {
                assert_eq!(p.get(i), (i as u32).wrapping_mul(2654435761) & mask, "w={width} i={i}");
            }
        }
    }

    #[test]
    fn packed_set_overwrites() {
        let mut p = PackedCodes::new(10, 3);
        p.set(4, 7);
        p.set(4, 2);
        assert_eq!(p.get(4), 2);
        assert_eq!(p.get(3), 0);
        assert_eq!(p.get(5), 0);
    }

    #[test]
    fn pack_unpack_index() {
        let codes = [3u32, 0, 2, 1];
        let idx = pack_index(&codes, 2);
        assert_eq!(idx, 3 | (0 << 2) | (2 << 4) | (1 << 6));
        let mut out = [0u32; 4];
        unpack_index(idx, 2, 4, &mut out);
        assert_eq!(out, codes);
    }

    #[test]
    fn popcount() {
        assert_eq!(popcount_words(&[0b1011], 4), 3);
        assert_eq!(popcount_words(&[0b1011], 2), 2);
        assert_eq!(popcount_words(&[u64::MAX, 0b1], 65), 65);
    }

    #[test]
    fn var_word_matches_index_bits() {
        for v in 0..10usize {
            for w in 0..20usize {
                let word = var_word(v, w);
                for b in 0..64usize {
                    let idx = 64 * w + b;
                    let expect = (idx >> v) & 1 == 1;
                    assert_eq!((word >> b) & 1 == 1, expect, "v={v} w={w} b={b}");
                }
            }
        }
    }
}
