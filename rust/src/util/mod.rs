//! In-tree infrastructure substrates (the build environment has no network,
//! so serde/clap/tokio/criterion/proptest are replaced by these modules).

pub mod bench;
pub mod bits;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod table;
