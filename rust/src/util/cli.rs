//! Tiny argument parser (no clap offline): subcommand + `--key value` /
//! `--flag` options + positionals.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (after the subcommand).  `--key value`, `--key=value`
    /// and bare `--flag` (when followed by another option or nothing) are
    /// all accepted.
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(&argv(&["6.2", "--steps", "100", "--full", "--lr=0.5", "pos2"]));
        assert_eq!(a.positional, vec!["6.2", "pos2"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 0.5);
        assert!(a.has_flag("full"));
        assert!(!a.has_flag("steps"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(&[]));
        assert_eq!(a.get_or("name", "x"), "x");
        assert_eq!(a.get_usize("n", 7), 7);
    }
}
