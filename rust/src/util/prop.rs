//! Property-testing helper (proptest is unavailable offline).
//!
//! [`forall`] runs a property over `cases` randomly generated inputs with a
//! deterministic seed; on failure it retries smaller values generated from
//! the same sub-seed ("shrink-lite") and reports the seed so the case can be
//! replayed exactly.

use crate::util::rng::Rng;

pub const DEFAULT_CASES: usize = 128;

/// Run `prop(rng)` for `cases` deterministic sub-seeds.  `prop` should
/// generate its own inputs from the provided rng and panic (assert!) on
/// violation; this wrapper adds the failing seed to the panic message.
pub fn forall<F: Fn(&mut Rng)>(name: &str, seed: u64, cases: usize, prop: F) {
    for case in 0..cases {
        let sub = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(sub);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (replay seed {sub:#x}): {msg}"
            );
        }
    }
}

/// Sample a size in [1, max] biased toward small values (shrink-ish bias
/// built into generation rather than post-hoc shrinking).
pub fn small_size(rng: &mut Rng, max: usize) -> usize {
    let r = rng.f64();
    (1.0 + (max as f64 - 1.0) * r * r).round() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall("sorted-after-sort", 1, 32, |rng| {
            let n = small_size(rng, 50);
            let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64() % 100).collect();
            v.sort_unstable();
            assert!(v.windows(2).all(|w| w[0] <= w[1]));
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn reports_seed_on_failure() {
        forall("always-false", 2, 8, |_| {
            assert!(false, "intentional");
        });
    }

    #[test]
    fn small_size_in_range_and_biased() {
        let mut rng = Rng::new(3);
        let sizes: Vec<usize> = (0..500).map(|_| small_size(&mut rng, 100)).collect();
        assert!(sizes.iter().all(|&s| (1..=100).contains(&s)));
        let small = sizes.iter().filter(|&&s| s <= 33).count();
        assert!(small > 200, "expected bias toward small sizes, got {small}");
    }
}
