//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`bench`] /
//! [`bench_n`]: warmup, then timed iterations until a wall-clock budget is
//! reached, reporting min / median / mean / p95 per-iteration times and
//! optional throughput.  Deliberately simple but stable enough for the
//! §Perf before/after logs in EXPERIMENTS.md.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  min {:>12}  median {:>12}  mean {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
        );
    }

    /// Report with an items/sec throughput line (e.g. inferences/s).
    pub fn report_throughput(&self, items_per_iter: f64, unit: &str) {
        self.report();
        let per_sec = items_per_iter / (self.median_ns / 1e9);
        println!("{:<44} {:>17.3e} {unit}/s (median)", "", per_sec);
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` repeatedly for ~`budget` after a warmup; `f` is run once per
/// iteration sample.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup: at least one run, up to budget/10.
    let warm_deadline = Instant::now() + budget / 10;
    loop {
        f();
        if Instant::now() >= warm_deadline {
            break;
        }
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    let deadline = Instant::now() + budget;
    loop {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if Instant::now() >= deadline && samples_ns.len() >= 5 {
            break;
        }
        if samples_ns.len() >= 100_000 {
            break;
        }
    }
    summarize(name, samples_ns)
}

/// Fixed iteration-count variant for expensive bodies.
pub fn bench_n<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples_ns = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
    }
    summarize(name, samples_ns)
}

fn summarize(name: &str, mut samples_ns: Vec<f64>) -> BenchResult {
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        min_ns: samples_ns[0],
        median_ns: samples_ns[n / 2],
        mean_ns: mean,
        p95_ns: samples_ns[(n as f64 * 0.95) as usize % n.max(1)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench_n("noop-ish", 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 50);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with(" s"));
    }
}
