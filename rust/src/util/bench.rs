//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`bench`] /
//! [`bench_n`]: warmup, then timed iterations until a wall-clock budget is
//! reached, reporting min / median / mean / p95 / p99 per-iteration times
//! and optional throughput.  Deliberately simple but stable enough for the
//! §Perf before/after logs in EXPERIMENTS.md.
//!
//! [`BenchReport`] adds the machine-readable side: bench targets collect
//! scenarios into one report and `finish()` writes `BENCH_<name>.json`
//! (CI uploads these as artifacts, so the perf trajectory is trackable
//! across PRs) and enforces the regression gate against a checked-in
//! baseline.  Environment contract:
//!
//! * `BENCH_OUT` — output directory for `BENCH_<name>.json` (default `.`);
//! * `BENCH_BASELINE` — path to a baseline JSON; when set, any scenario
//!   whose `throughput_per_s` drops more than `BENCH_MAX_REGRESS`
//!   (default 0.20) below the baseline's same-named scenario fails the
//!   process (exit code 1).  Scenarios absent from the baseline are
//!   skipped, so new benches never block on an old baseline;
//! * `BENCH_QUICK` — bench targets shrink batch sizes / budgets so CI
//!   runs in seconds (the numbers are noisier; the gate is deliberately
//!   loose).

use crate::util::json::Json;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  min {:>12}  median {:>12}  mean {:>12}  p95 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.p99_ns),
        );
    }

    /// Report with an items/sec throughput line (e.g. inferences/s).
    pub fn report_throughput(&self, items_per_iter: f64, unit: &str) {
        self.report();
        let per_sec = items_per_iter / (self.median_ns / 1e9);
        println!("{:<44} {:>17.3e} {unit}/s (median)", "", per_sec);
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` repeatedly for ~`budget` after a warmup; `f` is run once per
/// iteration sample.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup: at least one run, up to budget/10.
    let warm_deadline = Instant::now() + budget / 10;
    loop {
        f();
        if Instant::now() >= warm_deadline {
            break;
        }
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    let deadline = Instant::now() + budget;
    loop {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if Instant::now() >= deadline && samples_ns.len() >= 5 {
            break;
        }
        if samples_ns.len() >= 100_000 {
            break;
        }
    }
    summarize(name, samples_ns)
}

/// Fixed iteration-count variant for expensive bodies.
pub fn bench_n<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples_ns = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
    }
    summarize(name, samples_ns)
}

fn summarize(name: &str, mut samples_ns: Vec<f64>) -> BenchResult {
    // total_cmp, not partial_cmp().unwrap(): elapsed-time samples are never
    // NaN, but a timing summary must not be able to abort a bench run
    // (clippy's disallowed-methods bans the panicking form crate-wide).
    samples_ns.sort_by(f64::total_cmp);
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        min_ns: samples_ns[0],
        median_ns: samples_ns[n / 2],
        mean_ns: mean,
        p95_ns: samples_ns[(n as f64 * 0.95) as usize % n.max(1)],
        p99_ns: samples_ns[(n as f64 * 0.99) as usize % n.max(1)],
    }
}

/// Collected machine-readable results of one bench target.
pub struct BenchReport {
    name: String,
    scenarios: Vec<Json>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport { name: name.to_string(), scenarios: Vec::new() }
    }

    /// Record one timed scenario; `items_per_iter` turns the median time
    /// into `throughput_per_s` (the quantity the regression gate tracks).
    pub fn add(&mut self, r: &BenchResult, items_per_iter: f64, unit: &str) {
        let throughput = items_per_iter / (r.median_ns / 1e9);
        self.scenarios.push(Json::obj(vec![
            ("name", Json::str(&r.name)),
            ("iters", Json::num(r.iters as f64)),
            ("min_ns", Json::num(r.min_ns)),
            ("p50_ns", Json::num(r.median_ns)),
            ("mean_ns", Json::num(r.mean_ns)),
            ("p95_ns", Json::num(r.p95_ns)),
            ("p99_ns", Json::num(r.p99_ns)),
            ("items_per_iter", Json::num(items_per_iter)),
            ("unit", Json::str(unit)),
            ("throughput_per_s", Json::num(throughput)),
        ]));
    }

    /// Record a scenario from externally measured fields (router latency
    /// percentiles etc.).  Include a `throughput_per_s` field to opt the
    /// scenario into the regression gate.
    pub fn add_with(&mut self, name: &str, mut fields: Vec<(&str, Json)>) {
        fields.insert(0, ("name", Json::str(name)));
        self.scenarios.push(Json::obj(fields));
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::str(&self.name)),
            ("meta", provenance_meta()),
            ("scenarios", Json::Arr(self.scenarios.clone())),
        ])
    }

    /// Write `BENCH_<name>.json` into `$BENCH_OUT` (default `.`) and, if
    /// `$BENCH_BASELINE` is set, enforce the throughput regression gate —
    /// printing every comparison and exiting non-zero on failure.
    pub fn finish(&self) {
        let dir = std::env::var("BENCH_OUT").unwrap_or_else(|_| ".".to_string());
        let path = format!("{dir}/BENCH_{}.json", self.name);
        std::fs::write(&path, self.to_json().to_string()).expect("write bench json");
        println!("wrote {path}");
        if let Ok(baseline_path) = std::env::var("BENCH_BASELINE") {
            let max_regress = std::env::var("BENCH_MAX_REGRESS")
                .ok()
                .and_then(|v| v.parse::<f64>().ok())
                .unwrap_or(0.20);
            let text = std::fs::read_to_string(&baseline_path)
                .unwrap_or_else(|e| panic!("read baseline {baseline_path}: {e}"));
            let baseline = Json::parse(&text).expect("parse baseline json");
            let failures = check_regressions(&self.to_json(), &baseline, max_regress);
            if !failures.is_empty() {
                for f in &failures {
                    eprintln!("PERF REGRESSION: {f}");
                }
                std::process::exit(1);
            }
        }
    }
}

/// Provenance block stamped into every `BENCH_*.json` under `"meta"`: two
/// artifacts from different commits or machines stop being silently
/// comparable.  The regression gate reads only `"scenarios"`, so baselines
/// with or without a meta block keep working unchanged.
pub fn provenance_meta() -> Json {
    // CI exports GITHUB_SHA (checkouts can be detached or shallow); local
    // runs ask git; neither available degrades to "unknown".
    let git_sha = std::env::var("GITHUB_SHA")
        .ok()
        .filter(|s| !s.is_empty())
        .or_else(git_head_sha)
        .unwrap_or_else(|| "unknown".to_string());
    Json::obj(vec![
        ("git_sha", Json::str(&git_sha)),
        ("lanes", Json::num(crate::sim::LANES as f64)),
        ("chunk_samples", Json::num((crate::sim::LANES * 64) as f64)),
        ("threads", Json::num(crate::util::pool::num_threads() as f64)),
        ("simd_tier", Json::str(crate::sim::SimdTier::detect().name())),
        ("quick", Json::Bool(std::env::var("BENCH_QUICK").is_ok())),
    ])
}

fn git_head_sha() -> Option<String> {
    let out = std::process::Command::new("git").args(["rev-parse", "HEAD"]).output().ok()?;
    if !out.status.success() {
        return None;
    }
    let sha = String::from_utf8(out.stdout).ok()?.trim().to_string();
    (!sha.is_empty()).then_some(sha)
}

/// Compare `report` against `baseline` (both in the `BENCH_*.json` shape;
/// the baseline may also be a flat union of several benches' scenarios).
/// Returns one message per scenario whose `throughput_per_s` fell more
/// than `max_regress` (fraction) below the baseline value.  Scenarios
/// missing from the baseline — or carrying no throughput on either side —
/// are skipped.
pub fn check_regressions(report: &Json, baseline: &Json, max_regress: f64) -> Vec<String> {
    let empty: Vec<Json> = Vec::new();
    let base_scenarios = baseline.get("scenarios").and_then(|s| s.as_arr()).unwrap_or(&empty);
    let base_of = |name: &str| -> Option<f64> {
        base_scenarios
            .iter()
            .find(|s| s.get("name").and_then(|n| n.as_str()) == Some(name))
            .and_then(|s| s.get("throughput_per_s"))
            .and_then(|t| t.as_f64())
    };
    let mut failures = Vec::new();
    for sc in report.get("scenarios").and_then(|s| s.as_arr()).unwrap_or(&empty) {
        let Some(name) = sc.get("name").and_then(|n| n.as_str()) else { continue };
        let Some(got) = sc.get("throughput_per_s").and_then(|t| t.as_f64()) else { continue };
        let Some(base) = base_of(name) else { continue };
        if base > 0.0 && got < base * (1.0 - max_regress) {
            failures.push(format!(
                "{name}: {got:.3e}/s vs baseline {base:.3e}/s ({:.1}% drop > {:.0}% allowed)",
                (1.0 - got / base) * 100.0,
                max_regress * 100.0
            ));
        } else {
            println!(
                "gate ok  {name}: {got:.3e}/s vs baseline {base:.3e}/s ({:+.1}%)",
                (got / base - 1.0) * 100.0
            );
        }
    }
    failures
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench_n("noop-ish", 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 50);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.p95_ns);
        assert!(r.p95_ns <= r.p99_ns);
    }

    fn report_json(scenarios: Vec<(&str, f64)>) -> Json {
        Json::obj(vec![
            ("bench", Json::str("t")),
            (
                "scenarios",
                Json::Arr(
                    scenarios
                        .into_iter()
                        .map(|(n, t)| {
                            Json::obj(vec![
                                ("name", Json::str(n)),
                                ("throughput_per_s", Json::num(t)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    #[test]
    fn regression_gate_flags_only_real_drops() {
        let baseline = report_json(vec![("a", 1000.0), ("b", 1000.0), ("c", 1000.0)]);
        // a: 10% drop (ok at 20%), b: 30% drop (fails), d: not in baseline.
        let report = report_json(vec![("a", 900.0), ("b", 700.0), ("d", 5.0)]);
        let failures = check_regressions(&report, &baseline, 0.20);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].starts_with("b:"), "{failures:?}");
        // Tighter gate catches both drops.
        assert_eq!(check_regressions(&report, &baseline, 0.05).len(), 2);
        // Improvements never fail.
        let report = report_json(vec![("a", 2000.0)]);
        assert!(check_regressions(&report, &baseline, 0.20).is_empty());
    }

    #[test]
    fn provenance_meta_has_stable_shape() {
        let m = provenance_meta();
        assert!(m.get("git_sha").and_then(|v| v.as_str()).is_some());
        assert_eq!(m.get("lanes").and_then(|v| v.as_f64()), Some(crate::sim::LANES as f64));
        assert!(m.get("threads").and_then(|v| v.as_f64()).unwrap_or(0.0) >= 1.0);
        // The dispatch tier is stamped so artifacts from AVX-512 and
        // portable hosts stop being silently comparable.
        let tier = m.get("simd_tier").and_then(|v| v.as_str()).unwrap();
        assert!(["portable", "avx2", "avx512"].contains(&tier), "{tier}");
        assert!(m.get("quick").and_then(|v| v.as_bool()).is_some());
        // The gate must keep reading reports that carry a meta block.
        let mut rep = BenchReport::new("meta-shape");
        rep.add_with("s", vec![("throughput_per_s", Json::num(100.0))]);
        let baseline = report_json(vec![("s", 100.0)]);
        assert!(check_regressions(&rep.to_json(), &baseline, 0.20).is_empty());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with(" s"));
    }
}
