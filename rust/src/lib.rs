//! # LogicNets-RS
//!
//! Reproduction of *"Exposing Hardware Building Blocks to Machine Learning
//! Frameworks"* (Akhauri, 2019/2020 — the LogicNets thesis): extremely
//! sparse, activation-quantized neural networks whose neurons are exported
//! as truth tables and mapped onto FPGA-style 6-input LUTs.
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L1** Pallas kernels + **L2** JAX model live under `python/` and are
//!   AOT-lowered once to HLO text artifacts (`make artifacts`).
//! * **L3** (this crate) is the coordinator: it drives training through the
//!   PJRT runtime, owns sparsity/pruning, exports neurons to truth tables,
//!   emits Verilog, synthesizes it with the in-tree logic-synthesis
//!   simulator (`synth`), optimizes the mapped netlist with a verified
//!   CSE/constant-sweep/don't-care pass pipeline (`synth::opt`), simulates
//!   the netlist bit-parallel 64 samples per word (`sim`), and serves
//!   either the truth tables or the (optimized) synthesized netlist itself
//!   at high throughput (`serve`).  On top of that pipeline sits an
//!   automated design-space exploration engine (`dse::search`): a
//!   cost-gated successive-halving topology search driven by the native
//!   pure-Rust trainer (`train::native`, no PJRT needed) that maintains a
//!   resumable Pareto archive and emits its frontier as verified,
//!   servable netlists (`logicnets explore`).

// Clippy policy: CI runs `cargo clippy --all-targets -- -D warnings`.
// The style lints this crate opts out of (index-based loops over several
// parallel slices, wide constructor argument lists, wide cost tuples) are
// allowed centrally in Cargo.toml's `[lints.clippy]` table so every
// target (lib, bin, tests, benches, examples) shares one policy;
// correctness lints stay enabled everywhere.

pub mod cost;
pub mod data;
pub mod dse;
pub mod experiments;
pub mod hep;
pub mod luts;
pub mod metrics;
pub mod mnist;
pub mod nn;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sparsity;
pub mod synth;
pub mod train;
pub mod util;
pub mod verilog;
