//! `logicnets` CLI — the L3 coordinator entry point.
//!
//! ```text
//! logicnets list                               available model configs
//! logicnets train   --model NAME [--method M] [--steps N] [--retrain]
//! logicnets table   <id>|all   [--full] [--retrain]     regenerate a paper table
//! logicnets figure  <id>|all   [--full] [--retrain]     regenerate a paper figure
//! logicnets synth   --model NAME [--no-registers] [--clock NS]
//! logicnets lint    --model NAME | --zoo PATH [--json] [--deny-warn]
//! logicnets verilog --model NAME --out DIR
//! logicnets verify  --model NAME [--samples N]   tables vs arithmetic mirror
//! logicnets serve   --model NAME [--requests N] [--workers W]
//! logicnets stats   <snapshot.json>            pretty-print a telemetry snapshot
//! ```

use anyhow::{bail, Context, Result};
use logicnets::experiments::{self, ExpCtx};
use logicnets::luts::ModelTables;
use logicnets::serve::{batch_accuracy, Backend, LutEngine, NetlistEngine, Server, ServerConfig};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{synthesize, OptLevel, SynthOpts};
use logicnets::util::cli::Args;
use logicnets::verilog::{generate, netlist_module, VerilogOpts};

fn parse_method(s: &str) -> Result<PruneMethod> {
    Ok(match s {
        "a-priori" | "apriori" => PruneMethod::APriori,
        "iterative" => PruneMethod::Iterative { every: 10 },
        "momentum" => PruneMethod::Momentum { every: 8, prune_rate: 0.3 },
        other => bail!("unknown pruning method {other}"),
    })
}

/// `--opt` (bare flag) enables the full pipeline; `--opt LEVEL` picks one
/// of none|structural|full.
fn parse_opt(args: &Args) -> Result<OptLevel> {
    if let Some(s) = args.get("opt") {
        match OptLevel::parse(s) {
            Some(l) => Ok(l),
            None => bail!("unknown opt level {s} (expected none|structural|full)"),
        }
    } else if args.has_flag("opt") {
        Ok(OptLevel::Full)
    } else {
        Ok(OptLevel::None)
    }
}

/// Telemetry hookup for `serve`: an optional periodic snapshot emitter
/// (`--stats-interval SECS`) plus a final snapshot on shutdown, optionally
/// written as JSON (`--stats-json PATH`, readable back via `stats`).
struct ObsSession {
    emitter: Option<(std::sync::Arc<std::sync::atomic::AtomicBool>, std::thread::JoinHandle<()>)>,
    json_path: Option<String>,
    print_final: bool,
}

impl ObsSession {
    fn from_args(args: &Args) -> ObsSession {
        use std::sync::atomic::{AtomicBool, Ordering};
        let interval = args.get_f64("stats-interval", 0.0);
        let json_path = args.get("stats-json").map(str::to_string);
        let emitter = (interval > 0.0).then(|| {
            let stop = std::sync::Arc::new(AtomicBool::new(false));
            let flag = stop.clone();
            let h = std::thread::spawn(move || {
                // Sleep in short ticks so shutdown never waits a full period.
                let tick = std::time::Duration::from_millis(100);
                let period = std::time::Duration::from_secs_f64(interval.max(0.1));
                let mut since = std::time::Duration::ZERO;
                while !flag.load(Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    since += tick;
                    if since >= period {
                        since = std::time::Duration::ZERO;
                        let snap = logicnets::obs::snapshot();
                        if !snap.is_empty() {
                            println!("--- telemetry snapshot ---");
                            print!("{}", snap.render());
                        }
                    }
                }
            });
            (stop, h)
        });
        ObsSession { emitter, json_path, print_final: interval > 0.0 }
    }

    /// Stop the emitter and emit the final snapshot (stdout and/or JSON).
    fn finish(self) -> Result<()> {
        if let Some((stop, h)) = self.emitter {
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            let _ = h.join();
        }
        let snap = logicnets::obs::snapshot();
        if self.print_final {
            println!("--- final telemetry snapshot ---");
            print!("{}", snap.render());
        }
        if let Some(p) = self.json_path {
            std::fs::write(&p, snap.to_json().to_string()).with_context(|| p.clone())?;
            println!("telemetry snapshot written to {p}");
        }
        Ok(())
    }
}

fn cmd_stats(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .context("snapshot JSON path required (produce one with `serve --stats-json PATH`)")?;
    let text = std::fs::read_to_string(path).with_context(|| path.clone())?;
    let j = logicnets::util::json::Json::parse(&text)?;
    let snap = logicnets::obs::SnapshotReport::from_json(&j)?;
    if snap.is_empty() {
        println!("{path}: empty telemetry snapshot");
    } else {
        print!("{}", snap.render());
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "list" => cmd_list(),
        "train" => cmd_train(&args),
        "table" => cmd_table(&args),
        "figure" => cmd_figure(&args),
        "synth" => cmd_synth(&args),
        "lint" => cmd_lint(&args),
        "verilog" => cmd_verilog(&args),
        "verify" => cmd_verify(&args),
        "serve" => cmd_serve(&args),
        "stats" => cmd_stats(&args),
        "score" => cmd_score(&args),
        "complexity" => cmd_complexity(&args),
        "pareto" => cmd_pareto(&args),
        "explore" => cmd_explore(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other} (try `logicnets help`)"),
    }
}

fn print_help() {
    println!("logicnets — LogicNets reproduction CLI");
    println!("  list                                   available model configs");
    println!("  train   --model NAME [--method a-priori|iterative|momentum] [--steps N]");
    println!("  table   <id>|all  [--full] [--retrain] regenerate a paper table");
    println!("  figure  <id>|all  [--full] [--retrain] regenerate a paper figure");
    println!("  synth   --model NAME [--no-registers] [--clock NS] [--bram-min-bits B] [--score]");
    println!("          [--opt [none|structural|full]]   netlist optimization pipeline");
    println!("  lint    --model NAME [--opt L] [--bram-min-bits B] [--json] [--deny-warn]");
    println!("  lint    --zoo reports/dse/zoo.json [--json] [--deny-warn]");
    println!("          netlist design-rule checker (structural static analysis)");
    println!("  verilog --model NAME [--out DIR] [--no-registers] [--opt]");
    println!("  verify  --model NAME [--samples N]");
    println!("  serve   --model NAME [--requests N] [--workers W] [--backend tables|netlist]");
    println!("          [--opt]   optimize the served netlist (netlist backend only)");
    println!("  serve   --zoo reports/dse/zoo.json [--requests N] [--workers W] [--budget-us US]");
    println!("          [--json]  per-model stats (routed/fallback/reject + latency) as JSON");
    println!("          budget-routed multi-model serving from an explore-emitted zoo");
    println!("  serve   ... [--stats-interval SECS] [--stats-json PATH]");
    println!("          periodic telemetry snapshots; final snapshot written to PATH");
    println!("  stats   <snapshot.json>            pretty-print a `--stats-json` snapshot");
    println!("  score   --models NAME[,NAME...] [--opt]  accuracy parity: mirror vs tables vs netlist");
    println!("  complexity --model NAME            minimized-logic heuristic (paper 5.5.1)");
    println!("  pareto  --csv reports/figure_6_7.csv   Pareto frontier of a sweep");
    println!("          [--name-col N --lut-col N --q-col N]  (default: header-detected)");
    println!("  explore --budget-luts N [--rungs R] [--seed S] [--resume]   automated DSE");
    println!("          [--candidates C] [--steps B] [--eta E] [--emit K] [--dataset jets]");
    println!("          [--emit-zoo]   calibrate emitted netlists + write zoo.json for serve --zoo");
    println!("          [--widths 16,32,64] [--depths 1,2] [--fanins 2,3,4] [--bws 1,2,3]");
    println!("          [--skips 0,1] [--shapes rect,taper50]   skip-concat + pyramid axes");
    println!("          [--conv-mode none,dense,dw] [--channels 4] [--kernel 3]");
    println!("          conv front-end axes (defaults none / 4 / 3): non-none modes add");
    println!("          stride-2 conv candidates on square task inputs; conv entries");
    println!("          carry their axes into archive.json/zoo.json and serve --zoo");
    println!("          rebuilds them bit-exactly (pre-conv archives stay resumable)");
    println!("          [--methods a-priori,iterative] [--out reports/dse]");
    println!("tables : {}", experiments::ALL_TABLES.join(" "));
    println!("figures: {}", experiments::ALL_FIGURES.join(" "));
}

fn ctx_from(args: &Args) -> Result<ExpCtx> {
    ExpCtx::new(!args.has_flag("full"), args.has_flag("retrain"))
}

fn cmd_list() -> Result<()> {
    let text = std::fs::read_to_string("configs/models.json").context("configs/models.json")?;
    let j = logicnets::util::json::Json::parse(&text)?;
    if let logicnets::util::json::Json::Obj(m) = j {
        println!("{} model configs:", m.len());
        for (name, v) in m {
            let kind = v.get("kind").and_then(|k| k.as_str()).unwrap_or("?");
            let ds = v.get("dataset").and_then(|k| k.as_str()).unwrap_or("?");
            println!("  {name:<22} {kind:<4} {ds}");
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let name = args.get("model").context("--model required")?.to_string();
    let method = parse_method(args.get_or("method", "a-priori"))?;
    let mut ctx = ctx_from(args)?;
    if let Some(steps) = args.get("steps").and_then(|s| s.parse().ok()) {
        ctx.step_cap = Some(steps);
    }
    let tr = ctx.trained(&name, method)?;
    println!(
        "model {name} ({}): accuracy {:.3}, avg AUC {:.3}",
        method.name(),
        tr.accuracy,
        tr.avg_auc()
    );
    let costs = logicnets::cost::manifest_cost(&tr.man);
    for c in &costs {
        println!("  {:<4} {:>10} LUTs (analytical)", c.name, c.luts);
    }
    println!("  total {:>9} LUTs", logicnets::cost::total_luts(&costs));
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let id = args.positional.first().context("table id required (e.g. 6.2 or all)")?;
    let mut ctx = ctx_from(args)?;
    if id == "all" {
        for t in experiments::ALL_TABLES {
            println!();
            experiments::run_table(&mut ctx, t)?;
        }
        Ok(())
    } else {
        experiments::run_table(&mut ctx, id)
    }
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args.positional.first().context("figure id required (e.g. 6.7 or all)")?;
    let mut ctx = ctx_from(args)?;
    if id == "all" {
        for f in experiments::ALL_FIGURES {
            println!();
            experiments::run_figure(&mut ctx, f)?;
        }
        Ok(())
    } else {
        experiments::run_figure(&mut ctx, id)
    }
}

fn cmd_synth(args: &Args) -> Result<()> {
    let name = args.get("model").context("--model required")?.to_string();
    let mut ctx = ctx_from(args)?;
    let tr = ctx.trained(&name, parse_method(args.get_or("method", "a-priori"))?)?;
    let ex = tr.export();
    let tables = ModelTables::generate(&ex)?;
    let opts = SynthOpts {
        registers: !args.has_flag("no-registers"),
        clock_ns: args.get_f64("clock", 5.0),
        bram_min_bits: args.get_usize("bram-min-bits", 13),
        opt: parse_opt(args)?,
    };
    let (netlist, rep) = synthesize(&ex, &tables, opts)?;
    println!(
        "synthesis report for {name} (registers={}, clock {} ns, opt {}):",
        opts.registers,
        opts.clock_ns,
        opts.opt.name()
    );
    println!("  analytical LUTs : {}", rep.analytical_luts);
    println!("  synthesized LUTs: {}  ({:.2}x reduction)", rep.luts, rep.reduction);
    if opts.opt.structural() {
        if rep.opt_rounds > 0 {
            println!(
                "  optimizer       : {} -> {} LUTs ({:.2}x, {} rounds, equivalence checked)",
                rep.pre_opt_luts, rep.luts, rep.opt_reduction, rep.opt_rounds
            );
        } else {
            // BRAM pseudo-ports make the netlist unverifiable, so the
            // pipeline (and don't-care pruning) refused to run.
            println!(
                "  optimizer       : skipped (BRAM-mapped neurons; rerun with --bram-min-bits 0)"
            );
        }
    }
    println!("  FF {}  BRAM {}  DSP {}", rep.ffs, rep.brams, rep.dsps);
    println!(
        "  depth {}  min period {:.3} ns  WNS {:+.3} ns",
        rep.depth, rep.min_period_ns, rep.wns_ns
    );
    println!("  netlist: {} nodes over {} inputs", netlist.num_luts(), netlist.num_inputs);
    if args.has_flag("score") {
        // Score the mapped netlist on the full test set through the
        // bitsliced simulator.  Content-bearing BRAM records evaluate in
        // place (the wide plan fires them like any other record), so the
        // reported netlist is reused as-is; only an opaque-port netlist
        // (no captured contents) still needs the BRAM-free remap.
        let (_, test) = ctx.dataset(&tr.man.dataset);
        let test = test.clone();
        let built = if netlist.brams_evaluable() {
            NetlistEngine::from_netlist(&ex, &tables, netlist)
        } else {
            println!("  (opaque BRAM ports present: scoring a BRAM-free remap)");
            NetlistEngine::build_opt(&ex, &tables, opts.opt)
        };
        match built {
            Ok(engine) => {
                let acc = batch_accuracy(&engine, &test.x, &test.y);
                println!(
                    "  netlist-backed accuracy on {} test samples: {:.3} (arithmetic {:.3})",
                    test.n, acc, tr.accuracy
                );
            }
            Err(e) => println!("  netlist scoring unavailable: {e}"),
        }
    }
    Ok(())
}

/// `lint` — run the netlist design-rule checker (`synth::lint`) over a
/// freshly synthesized model netlist or over every circuit a zoo manifest
/// would serve.  Exits non-zero on any Error finding, and on Warn findings
/// under `--deny-warn`.  Note the producers already gate on Errors, so a
/// synthesizable model reports at most warnings here; `--zoo` circuits are
/// `Full`-optimized and expected to be completely clean.
fn cmd_lint(args: &Args) -> Result<()> {
    use logicnets::synth::{lint_netlist, LintOptions, Netlist};
    use logicnets::util::json::Json;
    let as_json = args.has_flag("json");
    let deny_warn = args.has_flag("deny-warn");
    // (label, effective opt level, netlist) per circuit to check.
    let mut circuits: Vec<(String, OptLevel, Netlist)> = Vec::new();
    if let Some(zoo) = args.get("zoo") {
        use logicnets::serve::zoo::{rebuild_netlist, ZooManifest};
        let zoo_path = std::path::Path::new(zoo);
        let manifest = ZooManifest::load(zoo_path)?;
        let dir = zoo_path
            .parent()
            .filter(|p| !p.as_os_str().is_empty())
            .unwrap_or(std::path::Path::new("."));
        for e in &manifest.entries {
            let (_, _, netlist) = rebuild_netlist(e, dir)?;
            circuits.push((e.name.clone(), OptLevel::Full, netlist));
        }
    } else {
        let name = args.get("model").context("--model (or --zoo) required")?.to_string();
        let mut ctx = ctx_from(args)?;
        let tr = ctx.trained(&name, parse_method(args.get_or("method", "a-priori"))?)?;
        let ex = tr.export();
        let tables = ModelTables::generate(&ex)?;
        let opts = SynthOpts {
            registers: !args.has_flag("no-registers"),
            clock_ns: args.get_f64("clock", 5.0),
            bram_min_bits: args.get_usize("bram-min-bits", 13),
            opt: parse_opt(args)?,
        };
        let (netlist, _) = synthesize(&ex, &tables, opts)?;
        // BRAM-carrying netlists skip the opt pipeline, so redundancy
        // rules judge them at `None` (mirrors the gate in `synthesize`).
        let eff = if opts.opt.structural() && netlist.brams.is_empty() {
            opts.opt
        } else {
            OptLevel::None
        };
        circuits.push((name, eff, netlist));
    }
    let (mut errors, mut warnings) = (0usize, 0usize);
    let mut results = Vec::new();
    for (label, opt, netlist) in &circuits {
        let report = lint_netlist(netlist, &LintOptions { opt: *opt });
        errors += report.errors();
        warnings += report.warnings();
        if as_json {
            results.push(Json::obj(vec![
                ("model", Json::str(label)),
                ("opt", Json::str(opt.name())),
                ("lint", report.to_json()),
            ]));
        } else {
            println!(
                "lint {label} ({} LUTs, {} BRAM, opt {}):",
                netlist.num_luts(),
                netlist.num_brams(),
                opt.name()
            );
            for line in report.render().lines() {
                println!("  {line}");
            }
        }
    }
    if as_json {
        println!("{}", Json::Arr(results).to_string());
    }
    anyhow::ensure!(errors == 0, "lint: {errors} Error-severity finding(s)");
    if deny_warn {
        anyhow::ensure!(warnings == 0, "lint: {warnings} Warn-severity finding(s) (--deny-warn)");
    }
    Ok(())
}

fn cmd_verilog(args: &Args) -> Result<()> {
    let name = args.get("model").context("--model required")?.to_string();
    let out = args.get_or("out", "reports/verilog").to_string();
    let mut ctx = ctx_from(args)?;
    let tr = ctx.trained(&name, parse_method(args.get_or("method", "a-priori"))?)?;
    let ex = tr.export();
    let tables = ModelTables::generate(&ex)?;
    let proj = generate(&ex, &tables, VerilogOpts { registers: !args.has_flag("no-registers") })?;
    let dir = std::path::Path::new(&out).join(&name);
    proj.write_to(&dir)?;
    println!(
        "wrote {} files ({} bytes) to {}",
        proj.files.len(),
        proj.total_bytes,
        dir.display()
    );
    let opt = parse_opt(args)?;
    if opt.structural() {
        // Also emit the optimized flat LUT netlist as one structural module.
        let (netlist, rep) = synthesize(
            &ex,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 0, opt, ..SynthOpts::default() },
        )?;
        let text = netlist_module("LogicNetNetlist", &netlist)?;
        std::fs::write(dir.join("LogicNetNetlist.v"), &text)?;
        println!(
            "wrote LogicNetNetlist.v ({} LUTs, {} pre-opt, {:.2}x)",
            rep.luts, rep.pre_opt_luts, rep.opt_reduction
        );
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let name = args.get("model").context("--model required")?.to_string();
    let samples = args.get_usize("samples", 512);
    let mut ctx = ctx_from(args)?;
    let tr = ctx.trained(&name, parse_method(args.get_or("method", "a-priori"))?)?;
    let ex = tr.export();
    let tables = ModelTables::generate(&ex)?;
    let ds = match tr.man.dataset.as_str() {
        "jets" => logicnets::hep::jets(samples, 99),
        _ => logicnets::mnist::synth_digits(samples, 99),
    };
    let mism = tables.verify(&ex, &ds.x);
    println!(
        "functional verification ({samples} samples): {mism} mismatches between truth \
         tables and arithmetic mirror"
    );
    anyhow::ensure!(mism == 0, "verification failed");
    // HLO forward cross-check (tolerant: XLA may reorder f32 sums, moving
    // values that sit exactly on a quantizer boundary).
    let rust_logits = ex.forward_batch(&ds.x);
    let art = ctx.artifact(&name)?;
    let hlo_logits = logicnets::train::evaluate(art, &tr.state, &ds)?;
    let n_codes = hlo_logits.len();
    let mismatched = hlo_logits
        .iter()
        .zip(&rust_logits)
        .filter(|(a, b)| (*a - *b).abs() > 1e-4)
        .count();
    let pct = 100.0 * mismatched as f64 / n_codes as f64;
    println!("HLO vs Rust mirror: {mismatched}/{n_codes} logit mismatches ({pct:.3}%)");
    anyhow::ensure!(pct < 1.0, "HLO/Rust divergence too high");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(zoo) = args.get("zoo") {
        return cmd_serve_zoo(zoo, args);
    }
    let name = args.get("model").context("--model required")?.to_string();
    let requests = args.get_usize("requests", 50_000);
    let workers = args.get_usize("workers", logicnets::util::pool::num_threads().min(8));
    let backend = args.get_or("backend", "tables").to_string();
    let mut ctx = ctx_from(args)?;
    let tr = ctx.trained(&name, parse_method(args.get_or("method", "a-priori"))?)?;
    let ex = tr.export();
    let tables = ModelTables::generate(&ex)?;
    let ds = match tr.man.dataset.as_str() {
        "jets" => logicnets::hep::jets(4096, 7),
        _ => logicnets::mnist::synth_digits(1024, 7),
    };
    match backend.as_str() {
        "tables" => {
            if parse_opt(args)? != OptLevel::None {
                println!(
                    "note: --opt applies to the netlist backend only; the tables \
                     backend serves unoptimized truth tables"
                );
            }
            let engine = std::sync::Arc::new(LutEngine::build(&ex, &tables)?);
            serve_backend(engine, &ds, requests, workers, args)
        }
        "netlist" => {
            let opt = parse_opt(args)?;
            let engine = std::sync::Arc::new(NetlistEngine::build_opt(&ex, &tables, opt)?);
            println!("netlist backend ({} opt): {} LUTs", opt.name(), engine.num_luts());
            serve_backend(engine, &ds, requests, workers, args)
        }
        other => bail!("unknown backend {other} (expected tables|netlist)"),
    }
}

/// `serve --zoo zoo.json`: load an explore-emitted model zoo (each entry
/// rebuilt from its checkpoint, synthesized and machine-verified), start
/// one worker pool per model, and drive a mixed-budget request stream:
/// even requests carry no budget (routed to the best-quality model), odd
/// requests a strict latency budget (`--budget-us`, default: the cheapest
/// model's calibrated p99 — which that model always satisfies).
fn cmd_serve_zoo(path: &str, args: &Args) -> Result<()> {
    use logicnets::serve::router::Budget;
    use logicnets::serve::zoo::{serve_manifest, ZooManifest};
    let requests = args.get_usize("requests", 10_000);
    let workers = args.get_usize("workers", logicnets::util::pool::num_threads().min(4));
    let zoo_path = std::path::Path::new(path);
    let manifest = ZooManifest::load(zoo_path)?;
    println!(
        "zoo {} — {} registered model(s), dataset {}:",
        path,
        manifest.entries.len(),
        manifest.dataset
    );
    for e in &manifest.entries {
        println!(
            "  {:<28} {:>8} LUTs {:>3} BRAM  quality {:>6.2}  p50 {:>8.1}us  p99 {:>8.1}us",
            e.name, e.luts, e.brams, e.quality, e.p50_us, e.p99_us
        );
    }
    let zoo_dir = zoo_path
        .parent()
        .filter(|p| !p.as_os_str().is_empty())
        .unwrap_or(std::path::Path::new("."));
    let obs = ObsSession::from_args(args);
    let server = serve_manifest(
        &manifest,
        zoo_dir,
        &ServerConfig { workers, obs_prefix: Some("serve".to_string()), ..Default::default() },
    )?;
    let ds = match manifest.dataset.as_str() {
        "jets" => logicnets::hep::jets(4096, 7),
        "mnist" => logicnets::mnist::synth_digits(1024, 7),
        other => bail!(
            "zoo dataset {other:?} has no request stream here (expected one of {:?})",
            experiments::DATASET_KINDS
        ),
    };
    anyhow::ensure!(
        ds.d == server.in_features,
        "dataset width {} != zoo input width {}",
        ds.d,
        server.in_features
    );
    let budget_us = match args.get("budget-us") {
        Some(v) => v
            .parse::<f64>()
            .map_err(|e| anyhow::anyhow!("--budget-us {v:?}: {e}"))?,
        // Default: the cheapest model's calibrated p99, which that model
        // always satisfies (models() is sorted cheapest-first).
        None => server.models()[0].p99_us,
    };
    let strict = Budget::latency_us(budget_us);
    println!(
        "strict budget: p99 <= {budget_us:.1}us on odd requests; no budget on even requests"
    );
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        // Distribute the remainder so exactly `requests` are sent (a
        // plain /8 would drop the tail and serve nothing for tiny runs).
        let (base, extra) = (requests / 8, requests % 8);
        for t in 0..8usize {
            let server = &server;
            let ds = &ds;
            let strict = &strict;
            let n_t = base + usize::from(t < extra);
            s.spawn(move || {
                let mut rng = logicnets::util::rng::Rng::new(t as u64);
                for k in 0..n_t {
                    let i = rng.below(ds.n);
                    let budget = if k % 2 == 0 { Budget::none() } else { *strict };
                    let _ = server.infer(ds.row(i).to_vec(), &budget);
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let mut completed = 0u64;
    for ms in server.stats() {
        completed += ms.stats.completed;
    }
    if args.has_flag("json") {
        // Machine-readable per-model stats: routed/fallback/reject counters
        // plus exact-histogram latency and phase percentiles.
        println!("{}", server.stats_json().to_string());
    } else {
        println!("per-model stats (cheapest first):");
        for ms in server.stats() {
            println!(
                "  {:<28} routed {:>8}  completed {:>8}  live p50 {:>7.1}us  p99 {:>7.1}us  fill {:>5.1}",
                ms.name,
                ms.routed,
                ms.stats.completed,
                ms.stats.p50_us,
                ms.stats.p99_us,
                ms.stats.mean_batch
            );
        }
    }
    println!(
        "zoo throughput        : {:.0} inferences/s across {} model(s); {} fallback(s)",
        completed as f64 / elapsed,
        manifest.entries.len(),
        server.fallbacks()
    );
    server.shutdown();
    obs.finish()
}

fn serve_backend<B: Backend>(
    engine: std::sync::Arc<B>,
    ds: &logicnets::data::DataSet,
    requests: usize,
    workers: usize,
    args: &Args,
) -> Result<()> {
    let obs = ObsSession::from_args(args);
    println!("serving backend       : {}", engine.name());
    println!(
        "eval-set accuracy     : {:.3} ({} samples)",
        batch_accuracy(&*engine, &ds.x, &ds.y),
        ds.n
    );
    // Raw engine throughput (the FPGA initiation-interval-1 analogue).
    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    while done < requests {
        let n = (requests - done).min(ds.n);
        let _ = engine.infer_batch(&ds.x[..n * ds.d]);
        done += n;
    }
    let raw = requests as f64 / t0.elapsed().as_secs_f64();
    println!("raw engine throughput : {raw:.0} inferences/s (batch path)");

    let server = Server::start(
        engine,
        ServerConfig {
            workers,
            max_batch: 64,
            obs_prefix: Some("serve".to_string()),
            ..Default::default()
        },
    );
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        let per = requests / 8;
        for t in 0..8usize {
            let server = &server;
            let ds = &ds;
            s.spawn(move || {
                let mut rng = logicnets::util::rng::Rng::new(t as u64);
                for _ in 0..per {
                    let i = rng.below(ds.n);
                    let _ = server.infer(ds.row(i).to_vec());
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!(
        "router throughput     : {:.0} inferences/s ({} workers)",
        stats.completed as f64 / elapsed,
        workers
    );
    println!(
        "latency us            : p50 {:.1}  p95 {:.1}  p99 {:.1}",
        stats.p50_us, stats.p95_us, stats.p99_us
    );
    println!("mean batch fill       : {:.1}", stats.mean_batch);
    server.shutdown();
    obs.finish()
}

fn cmd_score(args: &Args) -> Result<()> {
    let models = args.get_or("models", "hep_c").to_string();
    let names: Vec<String> = models.split(',').map(|s| s.trim().to_string()).collect();
    let mut ctx = ctx_from(args)?;
    experiments::report_netlist_serving(&mut ctx, &names, parse_opt(args)?)
}

fn cmd_complexity(args: &Args) -> Result<()> {
    let name = args.get("model").context("--model required")?.to_string();
    let mut ctx = ctx_from(args)?;
    let tr = ctx.trained(&name, parse_method(args.get_or("method", "a-priori"))?)?;
    let ex = tr.export();
    let tables = ModelTables::generate(&ex)?;
    let layers = logicnets::synth::complexity::model_complexity(&tables);
    println!("logic-complexity heuristic for {name} (paper 5.5.1):");
    println!(
        "{:<6} {:>8} {:>12} {:>14} {:>11} {:>12} {:>12}",
        "layer", "neurons", "mean cubes", "mean literals", "const bits", "max support", "est density"
    );
    for l in &layers {
        println!(
            "{:<6} {:>8} {:>12.1} {:>14.1} {:>11} {:>12} {:>12.3}",
            l.layer, l.neurons, l.mean_cubes, l.mean_literals, l.const_bits, l.max_support, l.est_density
        );
    }
    Ok(())
}

fn cmd_pareto(args: &Args) -> Result<()> {
    let path = args.get_or("csv", "reports/figure_6_7.csv").to_string();
    let csv = std::fs::read_to_string(&path).with_context(|| path.clone())?;
    // Columns: explicit flags win, then header-name detection, then the
    // historical figure_6_7 defaults (name 0 / LUTs 4 / quality 5).
    let (det_name, det_lut, det_q) =
        logicnets::dse::detect_columns(csv.lines().next().unwrap_or(""));
    let explicit = |key: &str| args.get(key).and_then(|v| v.parse::<usize>().ok());
    let name_col = explicit("name-col").or(det_name).unwrap_or(0);
    let lut_col = explicit("lut-col").or(det_lut).unwrap_or(4);
    let q_col = explicit("q-col").or(det_q).unwrap_or(5);
    println!("[pareto] columns: name {name_col}, LUTs {lut_col}, quality {q_col}");
    let pts = logicnets::dse::points_from_csv(&csv, name_col, lut_col, q_col);
    anyhow::ensure!(!pts.is_empty(), "no points parsed from {path}");
    let frontier = logicnets::dse::pareto_frontier(&pts);
    let dominated = logicnets::dse::dominated(&pts).len();
    println!("{} design points, {} dominated; Pareto frontier:", pts.len(), dominated);
    for p in &frontier {
        println!("  {:<22} {:>10} LUTs   quality {:.2}", p.name, p.luts, p.quality);
    }
    for (name, mc) in logicnets::dse::marginal_cost(&frontier) {
        println!("  marginal cost at {name}: {mc:.0} LUTs per quality point");
    }
    Ok(())
}

fn parse_usize_list(s: &str) -> Vec<usize> {
    s.split(',').filter_map(|t| t.trim().parse().ok()).collect()
}

/// `explore` — the automated design-space search (dse::search): generate
/// topologies over the paper's axes, price them with the analytical cost
/// model, successive-halve the survivors through the native trainer, and
/// persist a resumable Pareto archive whose frontier is synthesized,
/// verified and scored through the netlist serving backend.
fn cmd_explore(args: &Args) -> Result<()> {
    use logicnets::dse::search::{run_search, SearchAxes, SearchOpts, SearchTask, WidthShape};
    fn axis(args: &Args, key: &str, slot: &mut Vec<usize>) {
        if let Some(s) = args.get(key) {
            let v = parse_usize_list(s);
            if !v.is_empty() {
                *slot = v;
            }
        }
    }
    let dataset = args.get_or("dataset", "jets").to_string();
    // dataset_split panics on unknown kinds (it backs the infallible
    // ExpCtx path); fail like every other CLI flag instead.
    anyhow::ensure!(
        experiments::DATASET_KINDS.contains(&dataset.as_str()),
        "unknown dataset {dataset} (expected one of {:?})",
        experiments::DATASET_KINDS
    );
    let mut axes = SearchAxes::jets_default();
    axis(args, "widths", &mut axes.widths);
    axis(args, "depths", &mut axes.depths);
    axis(args, "fanins", &mut axes.fanins);
    axis(args, "bws", &mut axes.bws);
    axis(args, "bram-min-bits", &mut axes.bram_min_bits);
    axis(args, "skips", &mut axes.skips);
    axis(args, "channels", &mut axes.channels);
    axis(args, "kernel", &mut axes.kernels);
    for &k in &axes.kernels {
        anyhow::ensure!(
            k >= 1 && k % 2 == 1,
            "--kernel sides must be odd (SAME padding), got {k}"
        );
    }
    if let Some(s) = args.get("conv-mode") {
        let mut modes = Vec::new();
        for t in s.split(',') {
            let t = t.trim();
            match t {
                "none" | "dense" | "dw" => modes.push(t.to_string()),
                other => bail!(
                    "unknown conv mode {other:?} (expected none, dense or dw; \
                     conv candidates view the task input as a square image)"
                ),
            }
        }
        if !modes.is_empty() {
            axes.conv_modes = modes;
        }
    }
    if let Some(s) = args.get("shapes") {
        let mut shapes = Vec::new();
        for t in s.split(',') {
            match WidthShape::parse(t) {
                Some(w) => shapes.push(w),
                None => bail!("unknown width shape {t:?} (expected rect or taper<1-100>)"),
            }
        }
        if !shapes.is_empty() {
            axes.shapes = shapes;
        }
    }
    if let Some(s) = args.get("methods") {
        let mut ms = Vec::new();
        for t in s.split(',') {
            ms.push(parse_method(t.trim())?);
        }
        if !ms.is_empty() {
            axes.methods = ms;
        }
    }
    let opts = SearchOpts {
        budget_luts: args.get_u64("budget-luts", 30_000),
        rungs: args.get_usize("rungs", 3),
        base_steps: args.get_usize("steps", 40),
        eta: args.get_usize("eta", 2),
        seed: args.get_u64("seed", 1),
        max_candidates: args.get_usize("candidates", 24),
        out_dir: std::path::PathBuf::from(args.get_or("out", "reports/dse")),
        resume: args.has_flag("resume"),
        emit: args.get_usize("emit", 1),
        emit_zoo: args.has_flag("emit-zoo"),
    };
    let t0 = std::time::Instant::now();
    let task = SearchTask::from_dataset(&dataset);
    let out = run_search(&task, &axes, &opts)?;
    println!(
        "explore: {} generated, {} admitted, {} gated; {} native steps trained this run{}",
        out.generated,
        out.admitted,
        out.gated,
        out.steps_trained,
        if opts.resume { " (archived rungs replayed without retraining)" } else { "" },
    );
    println!(
        "frontier: {} non-dominated points; {} emitted as verified netlists; \
         archive {} ({:.1}s total)",
        out.frontier.len(),
        out.emitted.len(),
        out.archive_path.display(),
        t0.elapsed().as_secs_f64(),
    );
    if let Some(zp) = &out.zoo_path {
        println!("zoo written: serve it with `logicnets serve --zoo {}`", zp.display());
    }
    Ok(())
}
