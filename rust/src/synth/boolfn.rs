//! Single-output boolean functions as packed truth tables — the synthesis
//! front-end representation.  A LogicNets neuron with `in_bits` inputs and
//! `out_bits` outputs contributes `out_bits` BoolFns.

/// Truth table of `f: B^nvars -> B`, bit `idx` of `words` = f(idx).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BoolFn {
    pub nvars: usize,
    pub words: Vec<u64>,
}

impl BoolFn {
    pub fn new(nvars: usize, words: Vec<u64>) -> BoolFn {
        let need = (1usize << nvars).div_ceil(64);
        assert_eq!(words.len(), need, "nvars={nvars}");
        let mut f = BoolFn { nvars, words };
        f.mask_tail();
        f
    }

    pub fn zeros(nvars: usize) -> BoolFn {
        BoolFn { nvars, words: vec![0; (1usize << nvars).div_ceil(64)] }
    }

    fn mask_tail(&mut self) {
        let bits = 1usize << self.nvars;
        if bits < 64 {
            self.words[0] &= (1u64 << bits) - 1;
        }
    }

    pub fn num_entries(&self) -> usize {
        1usize << self.nvars
    }

    #[inline]
    pub fn get(&self, idx: usize) -> bool {
        (self.words[idx / 64] >> (idx % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, idx: usize, v: bool) {
        if v {
            self.words[idx / 64] |= 1u64 << (idx % 64);
        } else {
            self.words[idx / 64] &= !(1u64 << (idx % 64));
        }
    }

    pub fn is_const(&self) -> Option<bool> {
        let bits = self.num_entries();
        let ones = crate::util::bits::popcount_words(&self.words, bits);
        if ones == 0 {
            Some(false)
        } else if ones == bits {
            Some(true)
        } else {
            None
        }
    }

    pub fn count_ones(&self) -> usize {
        crate::util::bits::popcount_words(&self.words, self.num_entries())
    }

    /// Does the function actually depend on variable `v`?
    pub fn depends_on(&self, v: usize) -> bool {
        let stride = 1usize << v;
        let n = self.num_entries();
        let mut idx = 0;
        while idx < n {
            // Compare blocks where bit v = 0 against their v = 1 partners.
            for i in idx..idx + stride {
                if self.get(i) != self.get(i + stride) {
                    return true;
                }
            }
            idx += stride * 2;
        }
        false
    }

    /// Indices of variables in the true support.
    pub fn support(&self) -> Vec<usize> {
        (0..self.nvars).filter(|&v| self.depends_on(v)).collect()
    }

    /// Project onto the given (sorted) variable subset, which must contain
    /// the true support: returns the function over `vars.len()` variables.
    pub fn compact(&self, vars: &[usize]) -> BoolFn {
        let k = vars.len();
        let mut out = BoolFn::zeros(k);
        for idx2 in 0..(1usize << k) {
            let mut idx = 0usize;
            for (j, &v) in vars.iter().enumerate() {
                if (idx2 >> j) & 1 == 1 {
                    idx |= 1 << v;
                }
            }
            out.set(idx2, self.get(idx));
        }
        out
    }

    /// Cofactor with variable `v` fixed to `val`; result has `nvars-1` vars
    /// (variables above `v` shift down by one).
    pub fn cofactor(&self, v: usize, val: bool) -> BoolFn {
        assert!(v < self.nvars);
        let mut out = BoolFn::zeros(self.nvars - 1);
        let lo_mask = (1usize << v) - 1;
        for idx2 in 0..out.num_entries() {
            let idx = (idx2 & lo_mask)
                | ((idx2 & !lo_mask) << 1)
                | ((val as usize) << v);
            out.set(idx2, self.get(idx));
        }
        out
    }

    /// Truth table as a single u64 (requires nvars <= 6); bits above
    /// 2^nvars are zero.
    pub fn tt6(&self) -> u64 {
        assert!(self.nvars <= 6);
        self.words[0]
    }

    /// Build from a u64 truth table over `nvars <= 6` variables.
    pub fn from_tt6(nvars: usize, tt: u64) -> BoolFn {
        assert!(nvars <= 6);
        BoolFn::new(nvars, vec![tt])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor3() -> BoolFn {
        let mut f = BoolFn::zeros(3);
        for idx in 0..8usize {
            f.set(idx, (idx.count_ones() % 2) == 1);
        }
        f
    }

    #[test]
    fn support_and_depends() {
        let f = xor3();
        assert_eq!(f.support(), vec![0, 1, 2]);
        // g(x0,x1,x2) = x1 (ignores x0, x2)
        let mut g = BoolFn::zeros(3);
        for idx in 0..8usize {
            g.set(idx, (idx >> 1) & 1 == 1);
        }
        assert_eq!(g.support(), vec![1]);
        let c = g.compact(&[1]);
        assert_eq!(c.nvars, 1);
        assert!(!c.get(0));
        assert!(c.get(1));
    }

    #[test]
    fn cofactor_shannon_identity() {
        let f = xor3();
        let f0 = f.cofactor(1, false);
        let f1 = f.cofactor(1, true);
        for idx in 0..8usize {
            let reduced = (idx & 1) | ((idx >> 2) & 1) << 1;
            let expect = if (idx >> 1) & 1 == 1 { f1.get(reduced) } else { f0.get(reduced) };
            assert_eq!(f.get(idx), expect, "idx {idx}");
        }
    }

    #[test]
    fn const_detection() {
        assert_eq!(BoolFn::zeros(4).is_const(), Some(false));
        let mut ones = BoolFn::zeros(4);
        for i in 0..16 {
            ones.set(i, true);
        }
        assert_eq!(ones.is_const(), Some(true));
        assert_eq!(xor3().is_const(), None);
    }

    #[test]
    fn tt6_roundtrip() {
        let f = xor3();
        let g = BoolFn::from_tt6(3, f.tt6());
        assert_eq!(f, g);
    }

    #[test]
    fn large_fn_ops() {
        // 10-var majority-ish function; support must be all 10 vars.
        let mut f = BoolFn::zeros(10);
        for idx in 0..1024usize {
            f.set(idx, idx.count_ones() >= 5);
        }
        assert_eq!(f.support().len(), 10);
        let c0 = f.cofactor(9, false);
        assert_eq!(c0.nvars, 9);
        assert!(c0.get(0b111110000) || !c0.get(0));
    }
}
