//! Two-level logic minimization: an Espresso-style EXPAND/IRREDUNDANT pass
//! over cube covers, plus exact verification against the source function.
//!
//! This is the piece that turns a raw 2^N-entry truth table into the small
//! sum-of-products that Vivado-class synthesis finds (paper Table 5.2: true
//! LUT cost is a fraction of the analytical bound).

use super::boolfn::BoolFn;
use crate::util::bits::var_word;

/// A product term: covers minterm m iff `(m & care) == val`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cube {
    pub care: u64,
    pub val: u64,
}

impl Cube {
    pub fn from_minterm(m: u64, nvars: usize) -> Cube {
        let care = if nvars >= 64 { u64::MAX } else { (1u64 << nvars) - 1 };
        Cube { care, val: m & care }
    }

    #[inline]
    pub fn covers(&self, m: u64) -> bool {
        (m & self.care) == self.val
    }

    /// True if `self`'s cube (as a set of minterms) contains `other`'s.
    pub fn contains(&self, other: &Cube) -> bool {
        (self.care & !other.care) == 0 && (other.val & self.care) == self.val
    }

    /// Number of literals.
    pub fn num_literals(&self) -> usize {
        self.care.count_ones() as usize
    }

    /// Iterate the minterms of this cube within `nvars` variables.
    pub fn minterms(&self, nvars: usize) -> impl Iterator<Item = u64> + '_ {
        let free: Vec<u64> = (0..nvars as u64)
            .filter(|b| (self.care >> b) & 1 == 0)
            .collect();
        let count = 1usize << free.len();
        let base = self.val;
        (0..count).map(move |k| {
            let mut m = base;
            for (j, &b) in free.iter().enumerate() {
                if (k >> j) & 1 == 1 {
                    m |= 1u64 << b;
                }
            }
            m
        })
    }
}

/// A sum-of-products cover.
#[derive(Debug, Clone, Default)]
pub struct Cover {
    pub nvars: usize,
    pub cubes: Vec<Cube>,
}

impl Cover {
    pub fn eval(&self, m: u64) -> bool {
        self.cubes.iter().any(|c| c.covers(m))
    }

    /// Word `w` (minterms `64w..64w+63`) of one cube's coverage plane: the
    /// AND over cared variables of that variable's (possibly inverted)
    /// index bit-plane.  Word-parallel — 64 minterms per call.
    fn cube_word(cube: &Cube, nvars: usize, w: usize) -> u64 {
        let mut acc = u64::MAX;
        for v in 0..nvars {
            if (cube.care >> v) & 1 == 0 {
                continue;
            }
            let plane = var_word(v, w);
            acc &= if (cube.val >> v) & 1 == 1 { plane } else { !plane };
            if acc == 0 {
                break;
            }
        }
        acc
    }

    /// Materialize the cover as a packed truth table (same word layout as
    /// [`BoolFn::words`]), OR-ing each cube's plane word by word.
    pub fn to_words(&self) -> Vec<u64> {
        let entries = 1usize << self.nvars;
        let wpp = entries.div_ceil(64);
        let mut words = vec![0u64; wpp];
        for cube in &self.cubes {
            for (w, word) in words.iter_mut().enumerate() {
                if *word != u64::MAX {
                    *word |= Self::cube_word(cube, self.nvars, w);
                }
            }
        }
        if entries < 64 {
            words[0] &= (1u64 << entries) - 1;
        }
        words
    }

    /// Exact equivalence against the source function, verified word-wise
    /// (64 minterms per compare) instead of one scalar eval per minterm.
    pub fn equals_fn(&self, f: &BoolFn) -> bool {
        self.nvars == f.nvars && self.to_words() == f.words
    }

    pub fn total_literals(&self) -> usize {
        self.cubes.iter().map(|c| c.num_literals()).sum()
    }
}

/// Minimize `f` into an irredundant cover.  Heuristic (not exact), but the
/// result is always verified exactly equivalent to `f` by construction:
/// every expansion step is validated against the off-set.
pub fn minimize(f: &BoolFn) -> Cover {
    let ones = BoolFn::new(f.nvars, vec![u64::MAX; f.words.len()]);
    let cover = minimize_dc(f, &ones);
    debug_assert!(cover.equals_fn(f), "minimized cover must stay equivalent");
    cover
}

/// Minimize `f` against a care set (the Espresso don't-care formulation):
/// the returned cover covers every minterm of the care on-set
/// (`f & care`), covers no minterm of the care off-set (`!f & care`), and
/// may freely cover don't-care minterms (`!care`) when that lets a cube
/// drop literals.  `minimize(f)` is the `care == 1` special case.  The
/// synthesizer's reachable-code pruning (`synth::opt`) feeds unreachable
/// truth-table entries in as don't-cares.
pub fn minimize_dc(f: &BoolFn, care: &BoolFn) -> Cover {
    assert_eq!(f.nvars, care.nvars, "care set arity mismatch");
    let nvars = f.nvars;
    let entries = f.num_entries();
    // A minterm may be covered iff it is on-set or don't-care.
    let allowed = |m: u64| f.get(m as usize) || !care.get(m as usize);
    // Minterms the cover is *required* to contain.
    let onset: Vec<u64> = (0..entries as u64)
        .filter(|&m| f.get(m as usize) && care.get(m as usize))
        .collect();
    if onset.is_empty() {
        return Cover { nvars, cubes: Vec::new() };
    }
    if (0..entries as u64).all(allowed) {
        return Cover { nvars, cubes: vec![Cube { care: 0, val: 0 }] };
    }

    // EXPAND: grow each required minterm's cube by dropping literals while
    // the cube stays inside the on-set ∪ don't-care set.
    let mut cubes: Vec<Cube> = Vec::new();
    let mut covered = vec![false; entries];
    for &m in &onset {
        if covered[m as usize] {
            continue;
        }
        let mut cube = Cube::from_minterm(m, nvars);
        // Greedy literal drop, LSB-first variable order.
        for v in 0..nvars {
            let bit = 1u64 << v;
            if cube.care & bit == 0 {
                continue;
            }
            let trial = Cube { care: cube.care & !bit, val: cube.val & !bit };
            // Valid iff the expanded cube never touches the care off-set.
            if trial.minterms(nvars).all(allowed) {
                cube = trial;
            }
        }
        for t in cube.minterms(nvars) {
            covered[t as usize] = true;
        }
        cubes.push(cube);
    }

    // Drop contained cubes.
    let mut keep: Vec<Cube> = Vec::new();
    'outer: for (i, c) in cubes.iter().enumerate() {
        for (j, d) in cubes.iter().enumerate() {
            if i != j && d.contains(c) && (d.num_literals() < c.num_literals() || j < i) {
                continue 'outer;
            }
        }
        keep.push(*c);
    }

    // IRREDUNDANT: greedy removal of cubes whose *required* minterms are
    // all covered by the others (largest cubes kept first).  Don't-care
    // minterms never pin a cube in place.
    keep.sort_by_key(|c| c.num_literals());
    let mut result: Vec<Cube> = Vec::new();
    let mut cover_count = vec![0u32; entries];
    let required = |t: u64| f.get(t as usize) && care.get(t as usize);
    for c in &keep {
        for t in c.minterms(nvars).filter(|&t| required(t)) {
            cover_count[t as usize] += 1;
        }
    }
    for c in &keep {
        let redundant =
            c.minterms(nvars).filter(|&t| required(t)).all(|t| cover_count[t as usize] > 1);
        if redundant {
            for t in c.minterms(nvars).filter(|&t| required(t)) {
                cover_count[t as usize] -= 1;
            }
        } else {
            result.push(*c);
        }
    }
    let cover = Cover { nvars, cubes: result };
    debug_assert!(
        (0..entries as u64).all(|m| {
            !care.get(m as usize) || cover.eval(m) == f.get(m as usize)
        }),
        "don't-care minimization must agree with f on every care minterm"
    );
    cover
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn fn_and(nvars: usize) -> BoolFn {
        let mut f = BoolFn::zeros(nvars);
        f.set((1usize << nvars) - 1, true);
        f
    }

    #[test]
    fn and_is_single_cube() {
        let f = fn_and(5);
        let c = minimize(&f);
        assert_eq!(c.cubes.len(), 1);
        assert_eq!(c.cubes[0].num_literals(), 5);
        assert!(c.equals_fn(&f));
    }

    #[test]
    fn threshold_function_compresses() {
        // f = 1 iff sum of bits >= 3 of 5: minimized cover must be far
        // smaller than its 16 minterms.
        let mut f = BoolFn::zeros(5);
        for m in 0..32usize {
            f.set(m, m.count_ones() >= 3);
        }
        let c = minimize(&f);
        assert!(c.equals_fn(&f));
        assert!(c.cubes.len() <= 10, "{} cubes", c.cubes.len());
    }

    #[test]
    fn const_covers() {
        let zero = BoolFn::zeros(4);
        assert!(minimize(&zero).cubes.is_empty());
        let mut one = BoolFn::zeros(4);
        for m in 0..16 {
            one.set(m, true);
        }
        let c = minimize(&one);
        assert_eq!(c.cubes.len(), 1);
        assert_eq!(c.cubes[0].num_literals(), 0);
    }

    #[test]
    fn prop_minimize_is_exact_on_random_functions() {
        forall("minimize-exact", 0xC0FFEE, 60, |rng: &mut Rng| {
            let nvars = 1 + rng.below(8);
            let mut f = BoolFn::zeros(nvars);
            for m in 0..f.num_entries() {
                f.set(m, rng.f64() < 0.4);
            }
            let c = minimize(&f);
            assert!(c.equals_fn(&f), "cover != fn for nvars={nvars}");
        });
    }

    #[test]
    fn prop_to_words_matches_scalar_eval() {
        // The word-parallel materialization must agree with per-minterm
        // scalar cube evaluation bit for bit.
        forall("cover-words", 0xBEEF, 40, |rng: &mut Rng| {
            let nvars = 1 + rng.below(8);
            let mut f = BoolFn::zeros(nvars);
            for m in 0..f.num_entries() {
                f.set(m, rng.f64() < 0.4);
            }
            let c = minimize(&f);
            let words = c.to_words();
            for m in 0..f.num_entries() {
                let bit = (words[m / 64] >> (m % 64)) & 1 == 1;
                assert_eq!(bit, c.eval(m as u64), "nvars={nvars} m={m}");
            }
        });
    }

    #[test]
    fn dont_cares_drop_literals() {
        // f(x1,x0): on-set {11}, off-set {00}, DC {01, 10}.  With the DC
        // entries free the single cube can drop to one literal.
        let mut f = BoolFn::zeros(2);
        f.set(3, true);
        let mut care = BoolFn::zeros(2);
        care.set(0, true);
        care.set(3, true);
        let c = minimize_dc(&f, &care);
        assert_eq!(c.cubes.len(), 1);
        assert_eq!(c.cubes[0].num_literals(), 1, "DC must enable a literal drop");
        assert!(c.eval(3));
        assert!(!c.eval(0));
        // Without don't-cares the cube keeps both literals.
        assert_eq!(minimize(&f).cubes[0].num_literals(), 2);
    }

    #[test]
    fn minimize_dc_full_care_equals_minimize() {
        forall("dc-full-care", 0xDC0, 40, |rng: &mut Rng| {
            let nvars = 1 + rng.below(7);
            let mut f = BoolFn::zeros(nvars);
            for m in 0..f.num_entries() {
                f.set(m, rng.f64() < 0.4);
            }
            let ones = BoolFn::new(nvars, vec![u64::MAX; f.words.len()]);
            let c = minimize_dc(&f, &ones);
            assert!(c.equals_fn(&f), "full-care DC minimization must be exact");
        });
    }

    #[test]
    fn prop_minimize_dc_respects_care_set() {
        forall("dc-care", 0xDC1, 60, |rng: &mut Rng| {
            let nvars = 1 + rng.below(7);
            let mut f = BoolFn::zeros(nvars);
            let mut care = BoolFn::zeros(nvars);
            for m in 0..f.num_entries() {
                f.set(m, rng.f64() < 0.4);
                care.set(m, rng.f64() < 0.7);
            }
            let c = minimize_dc(&f, &care);
            for m in 0..f.num_entries() as u64 {
                if care.get(m as usize) {
                    assert_eq!(c.eval(m), f.get(m as usize), "care minterm {m} diverged");
                }
            }
        });
    }

    #[test]
    fn cube_contains_and_minterms() {
        let a = Cube { care: 0b011, val: 0b001 }; // x0=1, x1=0
        let b = Cube { care: 0b111, val: 0b101 };
        assert!(a.contains(&b));
        assert!(!b.contains(&a));
        let ms: Vec<u64> = a.minterms(3).collect();
        assert_eq!(ms.len(), 2);
        assert!(ms.contains(&0b001) && ms.contains(&0b101));
    }
}
