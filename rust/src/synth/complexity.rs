//! Logic-complexity heuristic (paper §5.5.1): the paper proposes using
//! truth-table minimization (PyEDA) as a training-time cost signal to
//! discover neurons that synthesize far below the analytical bound.  This
//! module computes that signal from our own minimizer: the minimized cube
//! count and literal count per neuron, aggregated per layer.

use super::boolfn::BoolFn;
use super::cover::minimize;
use crate::luts::{ModelTables, NeuronTable};
use crate::util::pool::par_map;

/// Complexity of one neuron's boolean functions.
#[derive(Debug, Clone, Default)]
pub struct NeuronComplexity {
    /// Minimized cube count summed over output bits.
    pub cubes: usize,
    /// Literal count summed over output bits.
    pub literals: usize,
    /// Output bits that reduced to constants (free in hardware).
    pub const_bits: usize,
    /// True support size (inputs the neuron actually depends on), max over
    /// output bits.
    pub support: usize,
}

pub fn neuron_complexity(table: &NeuronTable) -> NeuronComplexity {
    let mut c = NeuronComplexity::default();
    for bit in 0..table.out_bits {
        let f = BoolFn::new(table.in_bits, table.output_bit_fn(bit));
        if f.is_const().is_some() {
            c.const_bits += 1;
            continue;
        }
        c.support = c.support.max(f.support().len());
        let cover = minimize(&f);
        c.cubes += cover.cubes.len();
        c.literals += cover.total_literals();
    }
    c
}

/// Per-layer aggregate.
#[derive(Debug, Clone, Default)]
pub struct LayerComplexity {
    pub layer: usize,
    pub neurons: usize,
    pub mean_cubes: f64,
    pub mean_literals: f64,
    pub const_bits: usize,
    pub max_support: usize,
    /// Fraction of the analytical per-layer bound that the cube counts
    /// suggest is actually needed (a cheap pre-synthesis estimate).
    pub est_density: f64,
}

pub fn model_complexity(tables: &ModelTables) -> Vec<LayerComplexity> {
    let mut out = Vec::new();
    for (li, lt) in tables.layers.iter().enumerate() {
        let Some(lt) = lt else { continue };
        let per: Vec<NeuronComplexity> = par_map(&lt.tables, |_, t| neuron_complexity(t));
        let n = per.len().max(1);
        let analytical: u64 = lt
            .tables
            .iter()
            .map(|t| crate::cost::lut_cost(t.in_bits, t.out_bits))
            .sum();
        let est_luts: f64 = per.iter().map(|c| (c.cubes as f64 / 5.0).max(0.0)).sum();
        out.push(LayerComplexity {
            layer: li,
            neurons: n,
            mean_cubes: per.iter().map(|c| c.cubes as f64).sum::<f64>() / n as f64,
            mean_literals: per.iter().map(|c| c.literals as f64).sum::<f64>() / n as f64,
            const_bits: per.iter().map(|c| c.const_bits).sum(),
            max_support: per.iter().map(|c| c.support).max().unwrap_or(0),
            est_density: if analytical == 0 { 0.0 } else { est_luts / analytical as f64 },
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::neuron_table;
    use crate::nn::{Neuron, QuantSpec};

    #[test]
    fn saturated_neuron_is_free() {
        let nr = Neuron {
            inputs: vec![0, 1, 2],
            weights: vec![0.1, 0.1, 0.1],
            bias: 0.0,
            g: 1.0,
            h: 100.0, // saturates the quantizer high
        };
        let t = neuron_table(&nr, QuantSpec::new(2, 1.0), QuantSpec::new(2, 2.0)).unwrap();
        let c = neuron_complexity(&t);
        assert_eq!(c.const_bits, 2);
        assert_eq!(c.cubes, 0);
    }

    #[test]
    fn strong_single_input_has_small_support() {
        // Only input 1 matters.
        let nr = Neuron {
            inputs: vec![0, 1, 2],
            weights: vec![0.0, 5.0, 0.0],
            bias: -2.5,
            g: 1.0,
            h: 0.0,
        };
        let t = neuron_table(&nr, QuantSpec::new(1, 1.0), QuantSpec::new(1, 1.0)).unwrap();
        let c = neuron_complexity(&t);
        assert!(c.support <= 1, "support {}", c.support);
        assert!(c.cubes <= 1);
    }

    #[test]
    fn random_neuron_has_nontrivial_complexity() {
        let nr = Neuron {
            inputs: vec![0, 1, 2, 3],
            weights: vec![1.0, -0.7, 0.9, -1.2],
            bias: 0.1,
            g: 1.3,
            h: 0.05,
        };
        let t = neuron_table(&nr, QuantSpec::new(2, 1.0), QuantSpec::new(2, 2.0)).unwrap();
        let c = neuron_complexity(&t);
        assert!(c.cubes > 0 && c.literals >= c.cubes);
        assert!(c.support >= 3);
    }
}
