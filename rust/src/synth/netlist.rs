//! Mapped LUT netlist: the synthesis result.  Nodes are K<=6-input LUTs
//! over primary inputs, constants or other nodes; neurons mapped to BRAM
//! are tracked separately (the paper observed Vivado spilling wide-fan-in
//! neurons into BRAMs, §5.4).

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Net {
    Const0,
    Const1,
    Input(u32),
    Node(u32),
}

// Hand-written ordering with the exact semantics the derive produced
// (variant order, then index) — the mapper's `canonical_order` sorts
// fan-in nets with it, so changing it would renumber every emitted
// netlist.  Written out because clippy's disallowed-methods bans raw
// `partial_cmp` call sites crate-wide and derive expansions are not
// exempt.
impl Ord for Net {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        fn rank(n: &Net) -> u8 {
            match n {
                Net::Const0 => 0,
                Net::Const1 => 1,
                Net::Input(_) => 2,
                Net::Node(_) => 3,
            }
        }
        match (self, other) {
            (Net::Input(a), Net::Input(b)) | (Net::Node(a), Net::Node(b)) => a.cmp(b),
            _ => rank(self).cmp(&rank(other)),
        }
    }
}

impl PartialOrd for Net {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Net {
    pub fn key(&self) -> u64 {
        match self {
            Net::Const0 => 0,
            Net::Const1 => 1,
            Net::Input(i) => 2 + 2 * (*i as u64),
            Net::Node(i) => 3 + 2 * (*i as u64),
        }
    }
}

/// One mapped K-LUT (K <= 6): output = tt bit at the packed input index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LutNode {
    pub inputs: Vec<Net>,
    pub tt: u64,
    /// Logic level (1 + max level of inputs); inputs/constants are level 0.
    pub level: u32,
}

/// A neuron kept as a memory block instead of logic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BramNeuron {
    pub in_bits: usize,
    pub out_bits: usize,
    /// 18Kb BRAM blocks consumed.
    pub blocks: usize,
}

/// Structural equality (`PartialEq`) compares node lists, outputs, BRAMs
/// and depths verbatim — `synth::opt` uses it to detect its fixed point.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Netlist {
    pub num_inputs: usize,
    pub nodes: Vec<LutNode>,
    pub outputs: Vec<Net>,
    pub brams: Vec<BramNeuron>,
    /// Per-layer combinational depth (for registered-timing analysis):
    /// `layer_depths[i]` = LUT levels layer i added while mapping, so the
    /// total [`Self::depth`] never exceeds their sum (`synth::lint`
    /// enforces this).
    pub layer_depths: Vec<u32>,
}

impl Netlist {
    pub fn num_luts(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_brams(&self) -> usize {
        self.brams.iter().map(|b| b.blocks).sum()
    }

    /// Stored logic level of a net.  Out-of-range `Node` ids report level
    /// 0 instead of panicking — `synth::lint` flags them as structural
    /// errors, and depth queries must stay usable on netlists being
    /// diagnosed.
    pub fn level_of(&self, net: Net) -> u32 {
        match net {
            Net::Node(i) => self.nodes.get(i as usize).map_or(0, |n| n.level),
            _ => 0,
        }
    }

    /// Node levels recomputed from the wiring alone (1 + max level over
    /// `Node` fan-ins, ignoring any fan-in that is not a valid backward
    /// reference) — the ground truth the stored `LutNode::level` fields
    /// are checked against.
    pub fn recomputed_levels(&self) -> Vec<u32> {
        let mut levels = vec![0u32; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            let mut lv = 1u32;
            for &inp in &node.inputs {
                if let Net::Node(j) = inp {
                    if (j as usize) < i {
                        lv = lv.max(levels[j as usize] + 1);
                    }
                }
            }
            levels[i] = lv;
        }
        levels
    }

    /// Overwrite every stored `LutNode::level` with its recomputed value,
    /// so [`Self::depth`] and `period_for_depth` report the real wiring.
    /// `synth::opt::optimize` calls this after its fixed point.
    pub fn relevel(&mut self) {
        let levels = self.recomputed_levels();
        for (node, lv) in self.nodes.iter_mut().zip(levels) {
            node.level = lv;
        }
    }

    /// Combinational depth to the outputs.
    pub fn depth(&self) -> u32 {
        self.outputs.iter().map(|&o| self.level_of(o)).max().unwrap_or(0)
    }

    /// Compile this netlist into a level-ordered arena evaluation plan for
    /// the wide-plane simulator (`crate::sim::plan`).  Hot callers compile
    /// once and reuse the plan (plus a `SimScratch`) across batches.
    pub fn compile_plan(&self) -> crate::sim::EvalPlan {
        crate::sim::EvalPlan::compile(self)
    }

    /// Scalar reference evaluation on one primary-input bit vector.  Batch
    /// workloads (equivalence sweeps, accuracy scoring, netlist-backed
    /// serving) should use the bitsliced simulator instead —
    /// `crate::sim::eval_netlist` evaluates 256 samples per chunk per core
    /// over a levelized plan and is cross-checked against this
    /// implementation (and the 64-way `eval_netlist_64` oracle) by
    /// property tests.
    pub fn eval(&self, inputs: &[bool]) -> Vec<bool> {
        assert_eq!(inputs.len(), self.num_inputs);
        // A structurally invalid reference is a hard error, never a silent
        // `false`: a forward `Node` reference used to read the not-yet-
        // computed default and corrupt results without failing.  The same
        // rules are statically checkable via `lint::evaluability_errors`.
        let get = |values: &[bool], net: Net, site: usize| -> bool {
            match net {
                Net::Const0 => false,
                Net::Const1 => true,
                Net::Input(i) => {
                    assert!(
                        (i as usize) < self.num_inputs,
                        "net at node/output {site} reads out-of-range Input({i})"
                    );
                    inputs[i as usize]
                }
                Net::Node(i) => {
                    assert!(
                        (i as usize) < values.len(),
                        "net at node/output {site} reads Node({i}) before it is computed \
                         (forward or out-of-range reference)"
                    );
                    values[i as usize]
                }
            }
        };
        let mut values = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let mut idx = 0usize;
            for (j, &inp) in node.inputs.iter().enumerate() {
                if get(&values, inp, i) {
                    idx |= 1 << j;
                }
            }
            values.push((node.tt >> idx) & 1 == 1);
        }
        self.outputs.iter().enumerate().map(|(o, &net)| get(&values, net, o)).collect()
    }
}

/// Timing model constants (UltraScale+-flavored; see DESIGN.md
/// §Hardware-Adaptation — calibrated so a 1-level design lands near the
/// paper's 0.768 ns minimum period).
pub const T_REG_NS: f64 = 0.30;
pub const T_LUT_NS: f64 = 0.15;
pub const T_NET_NS: f64 = 0.40;

pub fn period_for_depth(depth: u32) -> f64 {
    T_REG_NS + depth as f64 * (T_LUT_NS + T_NET_NS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_simple_and_or() {
        // n0 = AND(in0, in1); n1 = OR(n0, in2)
        let netlist = Netlist {
            num_inputs: 3,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b1000, level: 1 },
                LutNode { inputs: vec![Net::Node(0), Net::Input(2)], tt: 0b1110, level: 2 },
            ],
            outputs: vec![Net::Node(1)],
            brams: vec![],
            layer_depths: vec![2],
        };
        assert_eq!(netlist.eval(&[true, true, false]), vec![true]);
        assert_eq!(netlist.eval(&[false, true, false]), vec![false]);
        assert_eq!(netlist.eval(&[false, false, true]), vec![true]);
        assert_eq!(netlist.depth(), 2);
    }

    #[test]
    fn period_grows_with_depth() {
        assert!(period_for_depth(1) < period_for_depth(3));
        assert!((period_for_depth(1) - 0.85).abs() < 1e-9);
    }

    #[test]
    fn net_ordering_matches_the_old_derive() {
        let mut v = vec![
            Net::Node(1),
            Net::Input(7),
            Net::Const1,
            Net::Node(0),
            Net::Input(0),
            Net::Const0,
        ];
        v.sort();
        assert_eq!(
            v,
            vec![
                Net::Const0,
                Net::Const1,
                Net::Input(0),
                Net::Input(7),
                Net::Node(0),
                Net::Node(1),
            ]
        );
        assert!(Net::Input(u32::MAX) < Net::Node(0), "variant order beats index");
    }

    #[test]
    fn level_of_tolerates_out_of_range_nodes() {
        let netlist = Netlist { num_inputs: 1, ..Netlist::default() };
        assert_eq!(netlist.level_of(Net::Node(12345)), 0);
        assert_eq!(netlist.level_of(Net::Input(99)), 0);
        assert_eq!(netlist.depth(), 0);
    }

    #[test]
    fn eval_rejects_forward_references() {
        // n0 reads n1: silently false before, now a structural panic.
        let netlist = Netlist {
            num_inputs: 1,
            nodes: vec![
                LutNode { inputs: vec![Net::Node(1)], tt: 0b10, level: 1 },
                LutNode { inputs: vec![Net::Input(0)], tt: 0b10, level: 1 },
            ],
            outputs: vec![Net::Node(0)],
            brams: vec![],
            layer_depths: vec![1],
        };
        let err = std::panic::catch_unwind(move || netlist.eval(&[true])).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("Node(1)"), "{msg}");
    }

    #[test]
    fn relevel_restores_wiring_truth() {
        let mut netlist = Netlist {
            num_inputs: 2,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b1000, level: 9 },
                LutNode { inputs: vec![Net::Node(0), Net::Input(1)], tt: 0b0110, level: 1 },
            ],
            outputs: vec![Net::Node(1)],
            brams: vec![],
            layer_depths: vec![2],
        };
        assert_eq!(netlist.recomputed_levels(), vec![1, 2]);
        netlist.relevel();
        assert_eq!(netlist.nodes[0].level, 1);
        assert_eq!(netlist.nodes[1].level, 2);
        assert_eq!(netlist.depth(), 2);
    }
}
