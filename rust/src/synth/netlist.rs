//! Mapped LUT netlist: the synthesis result.  Nodes are K<=6-input LUTs
//! over primary inputs, constants or other nodes; neurons mapped to BRAM
//! are tracked separately (the paper observed Vivado spilling wide-fan-in
//! neurons into BRAMs, §5.4).

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Net {
    Const0,
    Const1,
    Input(u32),
    Node(u32),
}

// Hand-written ordering with the exact semantics the derive produced
// (variant order, then index) — the mapper's `canonical_order` sorts
// fan-in nets with it, so changing it would renumber every emitted
// netlist.  Written out because clippy's disallowed-methods bans raw
// `partial_cmp` call sites crate-wide and derive expansions are not
// exempt.
impl Ord for Net {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        fn rank(n: &Net) -> u8 {
            match n {
                Net::Const0 => 0,
                Net::Const1 => 1,
                Net::Input(_) => 2,
                Net::Node(_) => 3,
            }
        }
        match (self, other) {
            (Net::Input(a), Net::Input(b)) | (Net::Node(a), Net::Node(b)) => a.cmp(b),
            _ => rank(self).cmp(&rank(other)),
        }
    }
}

impl PartialOrd for Net {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Net {
    pub fn key(&self) -> u64 {
        match self {
            Net::Const0 => 0,
            Net::Const1 => 1,
            Net::Input(i) => 2 + 2 * (*i as u64),
            Net::Node(i) => 3 + 2 * (*i as u64),
        }
    }
}

/// One mapped K-LUT (K <= 6): output = tt bit at the packed input index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LutNode {
    pub inputs: Vec<Net>,
    pub tt: u64,
    /// Logic level (1 + max level of inputs); inputs/constants are level 0.
    pub level: u32,
}

/// A neuron kept as a memory block instead of logic.
///
/// Content-bearing BRAMs (what `synth::synthesize` emits at the
/// `bram_min_bits` threshold) carry their address wiring and full lookup
/// table, so they are simulator-evaluable: the neuron's `out_bits` output
/// bits surface as pseudo primary inputs
/// `Input(out_base .. out_base + out_bits)` that every evaluator
/// overwrites once the address nets are available.  [`BramNeuron::opaque`]
/// builds the legacy content-less form (area accounting only, not
/// evaluable).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BramNeuron {
    pub in_bits: usize,
    pub out_bits: usize,
    /// 18Kb BRAM blocks consumed.
    pub blocks: usize,
    /// Address nets, LSB-first (`inputs[j]` drives address bit j).  Empty
    /// for opaque BRAMs.
    pub inputs: Vec<Net>,
    /// First pseudo primary-input id carrying this neuron's output bits.
    pub out_base: u32,
    /// Output codes indexed by packed address: `1 << in_bits` entries when
    /// evaluable, empty for opaque BRAMs.
    pub content: Vec<u32>,
}

impl BramNeuron {
    /// Legacy content-less BRAM record: shape/area accounting only.  A
    /// netlist carrying one cannot be evaluated directly — its pseudo
    /// inputs stay caller-provided.
    pub fn opaque(in_bits: usize, out_bits: usize, blocks: usize) -> Self {
        BramNeuron { in_bits, out_bits, blocks, inputs: Vec::new(), out_base: 0, content: Vec::new() }
    }

    /// True when the record carries enough to evaluate: full address
    /// wiring and a `1 << in_bits` lookup table.
    pub fn is_evaluable(&self) -> bool {
        self.in_bits > 0
            && self.in_bits < 32
            && self.out_bits > 0
            && self.out_bits <= 32
            && self.inputs.len() == self.in_bits
            && self.content.len() == 1usize << self.in_bits
    }
}

/// Structural equality (`PartialEq`) compares node lists, outputs, BRAMs
/// and depths verbatim — `synth::opt` uses it to detect its fixed point.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Netlist {
    pub num_inputs: usize,
    pub nodes: Vec<LutNode>,
    pub outputs: Vec<Net>,
    pub brams: Vec<BramNeuron>,
    /// Per-layer combinational depth (for registered-timing analysis):
    /// `layer_depths[i]` = LUT levels layer i added while mapping, so the
    /// total [`Self::depth`] never exceeds their sum (`synth::lint`
    /// enforces this).
    pub layer_depths: Vec<u32>,
}

impl Netlist {
    pub fn num_luts(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_brams(&self) -> usize {
        self.brams.iter().map(|b| b.blocks).sum()
    }

    /// Stored logic level of a net.  Out-of-range `Node` ids report level
    /// 0 instead of panicking — `synth::lint` flags them as structural
    /// errors, and depth queries must stay usable on netlists being
    /// diagnosed.
    pub fn level_of(&self, net: Net) -> u32 {
        match net {
            Net::Node(i) => self.nodes.get(i as usize).map_or(0, |n| n.level),
            _ => 0,
        }
    }

    /// Node levels recomputed from the wiring alone (1 + max level over
    /// `Node` fan-ins, ignoring any fan-in that is not a valid backward
    /// reference) — the ground truth the stored `LutNode::level` fields
    /// are checked against.
    pub fn recomputed_levels(&self) -> Vec<u32> {
        let mut levels = vec![0u32; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            let mut lv = 1u32;
            for &inp in &node.inputs {
                if let Net::Node(j) = inp {
                    if (j as usize) < i {
                        lv = lv.max(levels[j as usize] + 1);
                    }
                }
            }
            levels[i] = lv;
        }
        levels
    }

    /// Overwrite every stored `LutNode::level` with its recomputed value,
    /// so [`Self::depth`] and `period_for_depth` report the real wiring.
    /// `synth::opt::optimize` calls this after its fixed point.
    pub fn relevel(&mut self) {
        let levels = self.recomputed_levels();
        for (node, lv) in self.nodes.iter_mut().zip(levels) {
            node.level = lv;
        }
    }

    /// Combinational depth to the outputs.
    pub fn depth(&self) -> u32 {
        self.outputs.iter().map(|&o| self.level_of(o)).max().unwrap_or(0)
    }

    /// True when every BRAM record is content-bearing, i.e. the netlist
    /// can be evaluated (scalar, 64-way, or wide plan) without a
    /// BRAM-free remap.  Vacuously true for BRAM-free netlists.
    pub fn brams_evaluable(&self) -> bool {
        self.brams.iter().all(|b| b.is_evaluable())
    }

    /// Earliest node index each BRAM can fire at: its address operands
    /// (`Node` fan-ins, plus pseudo inputs of list-earlier BRAMs) are all
    /// available once `triggers[b]` nodes have been computed.  Evaluators
    /// fire BRAM b before computing node `triggers[b]`; `synth::lint`
    /// checks that every consumer of b's pseudo inputs sits at a node
    /// index >= the trigger.  Opaque (content-less) BRAMs report 0 and
    /// are never fired.
    pub fn bram_triggers(&self) -> Vec<usize> {
        let mut triggers: Vec<usize> = Vec::with_capacity(self.brams.len());
        for (bi, b) in self.brams.iter().enumerate() {
            let mut at = 0usize;
            for &net in &b.inputs {
                match net {
                    Net::Node(i) => at = at.max(i as usize + 1),
                    Net::Input(p) => {
                        // An address tapping an earlier BRAM's pseudo
                        // range chains the triggers: b cannot fire before
                        // that BRAM has.
                        for (ci, c) in self.brams[..bi].iter().enumerate() {
                            let lo = c.out_base as usize;
                            if (lo..lo + c.out_bits).contains(&(p as usize)) {
                                at = at.max(triggers[ci]);
                            }
                        }
                    }
                    Net::Const0 | Net::Const1 => {}
                }
            }
            triggers.push(at);
        }
        triggers
    }

    /// Compile this netlist into a level-ordered arena evaluation plan for
    /// the wide-plane simulator (`crate::sim::plan`).  Hot callers compile
    /// once and reuse the plan (plus a `SimScratch`) across batches.
    pub fn compile_plan(&self) -> crate::sim::EvalPlan {
        crate::sim::EvalPlan::compile(self)
    }

    /// Scalar reference evaluation on one primary-input bit vector.  Batch
    /// workloads (equivalence sweeps, accuracy scoring, netlist-backed
    /// serving) should use the bitsliced simulator instead —
    /// `crate::sim::eval_netlist` evaluates 256 samples per chunk per core
    /// over a levelized plan and is cross-checked against this
    /// implementation (and the 64-way `eval_netlist_64` oracle) by
    /// property tests.
    pub fn eval(&self, inputs: &[bool]) -> Vec<bool> {
        assert_eq!(inputs.len(), self.num_inputs);
        // A structurally invalid reference is a hard error, never a silent
        // `false`: a forward `Node` reference used to read the not-yet-
        // computed default and corrupt results without failing.  The same
        // rules are statically checkable via `lint::evaluability_errors`.
        let read = |ins: &[bool], values: &[bool], net: Net, site: usize| -> bool {
            match net {
                Net::Const0 => false,
                Net::Const1 => true,
                Net::Input(i) => {
                    assert!(
                        (i as usize) < self.num_inputs,
                        "net at node/output {site} reads out-of-range Input({i})"
                    );
                    ins[i as usize]
                }
                Net::Node(i) => {
                    assert!(
                        (i as usize) < values.len(),
                        "net at node/output {site} reads Node({i}) before it is computed \
                         (forward or out-of-range reference)"
                    );
                    values[i as usize]
                }
            }
        };
        // Content-bearing BRAMs overwrite their pseudo-input positions the
        // moment their address operands are available; opaque BRAMs are
        // skipped (their pseudo inputs stay caller-provided, the legacy
        // behavior).
        let mut ins = inputs.to_vec();
        let triggers = self.bram_triggers();
        let mut fired = vec![false; self.brams.len()];
        let mut values: Vec<bool> = Vec::with_capacity(self.nodes.len());
        for i in 0..=self.nodes.len() {
            for (bi, b) in self.brams.iter().enumerate() {
                if fired[bi] || !b.is_evaluable() || triggers[bi] > i {
                    continue;
                }
                let mut idx = 0usize;
                for (j, &net) in b.inputs.iter().enumerate() {
                    if read(&ins, &values, net, i) {
                        idx |= 1 << j;
                    }
                }
                let code = b.content[idx];
                for ob in 0..b.out_bits {
                    ins[b.out_base as usize + ob] = (code >> ob) & 1 == 1;
                }
                fired[bi] = true;
            }
            if i == self.nodes.len() {
                break;
            }
            let node = &self.nodes[i];
            let mut idx = 0usize;
            for (j, &inp) in node.inputs.iter().enumerate() {
                if read(&ins, &values, inp, i) {
                    idx |= 1 << j;
                }
            }
            values.push((node.tt >> idx) & 1 == 1);
        }
        self.outputs.iter().enumerate().map(|(o, &net)| read(&ins, &values, net, o)).collect()
    }
}

/// Timing model constants (UltraScale+-flavored; see DESIGN.md
/// §Hardware-Adaptation — calibrated so a 1-level design lands near the
/// paper's 0.768 ns minimum period).
pub const T_REG_NS: f64 = 0.30;
pub const T_LUT_NS: f64 = 0.15;
pub const T_NET_NS: f64 = 0.40;

pub fn period_for_depth(depth: u32) -> f64 {
    T_REG_NS + depth as f64 * (T_LUT_NS + T_NET_NS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_simple_and_or() {
        // n0 = AND(in0, in1); n1 = OR(n0, in2)
        let netlist = Netlist {
            num_inputs: 3,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b1000, level: 1 },
                LutNode { inputs: vec![Net::Node(0), Net::Input(2)], tt: 0b1110, level: 2 },
            ],
            outputs: vec![Net::Node(1)],
            brams: vec![],
            layer_depths: vec![2],
        };
        assert_eq!(netlist.eval(&[true, true, false]), vec![true]);
        assert_eq!(netlist.eval(&[false, true, false]), vec![false]);
        assert_eq!(netlist.eval(&[false, false, true]), vec![true]);
        assert_eq!(netlist.depth(), 2);
    }

    #[test]
    fn period_grows_with_depth() {
        assert!(period_for_depth(1) < period_for_depth(3));
        assert!((period_for_depth(1) - 0.85).abs() < 1e-9);
    }

    #[test]
    fn net_ordering_matches_the_old_derive() {
        let mut v = vec![
            Net::Node(1),
            Net::Input(7),
            Net::Const1,
            Net::Node(0),
            Net::Input(0),
            Net::Const0,
        ];
        v.sort();
        assert_eq!(
            v,
            vec![
                Net::Const0,
                Net::Const1,
                Net::Input(0),
                Net::Input(7),
                Net::Node(0),
                Net::Node(1),
            ]
        );
        assert!(Net::Input(u32::MAX) < Net::Node(0), "variant order beats index");
    }

    #[test]
    fn level_of_tolerates_out_of_range_nodes() {
        let netlist = Netlist { num_inputs: 1, ..Netlist::default() };
        assert_eq!(netlist.level_of(Net::Node(12345)), 0);
        assert_eq!(netlist.level_of(Net::Input(99)), 0);
        assert_eq!(netlist.depth(), 0);
    }

    #[test]
    fn eval_rejects_forward_references() {
        // n0 reads n1: silently false before, now a structural panic.
        let netlist = Netlist {
            num_inputs: 1,
            nodes: vec![
                LutNode { inputs: vec![Net::Node(1)], tt: 0b10, level: 1 },
                LutNode { inputs: vec![Net::Input(0)], tt: 0b10, level: 1 },
            ],
            outputs: vec![Net::Node(0)],
            brams: vec![],
            layer_depths: vec![1],
        };
        let err = std::panic::catch_unwind(move || netlist.eval(&[true])).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("Node(1)"), "{msg}");
    }

    #[test]
    fn eval_fires_content_bearing_brams() {
        // Input 2 is the pseudo output of a BRAM computing XOR of inputs
        // 0 and 1; node 0 inverts it.  The caller-provided value at the
        // pseudo position must be overwritten before node 0 reads it.
        let netlist = Netlist {
            num_inputs: 3,
            nodes: vec![LutNode { inputs: vec![Net::Input(2)], tt: 0b01, level: 1 }],
            outputs: vec![Net::Node(0), Net::Input(2)],
            brams: vec![BramNeuron {
                in_bits: 2,
                out_bits: 1,
                blocks: 1,
                inputs: vec![Net::Input(0), Net::Input(1)],
                out_base: 2,
                content: vec![0, 1, 1, 0],
            }],
            layer_depths: vec![1],
        };
        assert!(netlist.brams_evaluable());
        assert_eq!(netlist.bram_triggers(), vec![0]);
        for (a, b) in [(false, false), (true, false), (false, true), (true, true)] {
            // The stale `true` at the pseudo slot must not leak through.
            let out = netlist.eval(&[a, b, true]);
            assert_eq!(out, vec![!(a ^ b), a ^ b], "a={a} b={b}");
        }
        assert!(!BramNeuron::opaque(14, 2, 2).is_evaluable());
    }

    #[test]
    fn relevel_restores_wiring_truth() {
        let mut netlist = Netlist {
            num_inputs: 2,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b1000, level: 9 },
                LutNode { inputs: vec![Net::Node(0), Net::Input(1)], tt: 0b0110, level: 1 },
            ],
            outputs: vec![Net::Node(1)],
            brams: vec![],
            layer_depths: vec![2],
        };
        assert_eq!(netlist.recomputed_levels(), vec![1, 2]);
        netlist.relevel();
        assert_eq!(netlist.nodes[0].level, 1);
        assert_eq!(netlist.nodes[1].level, 2);
        assert_eq!(netlist.depth(), 2);
    }
}
