//! Mapped LUT netlist: the synthesis result.  Nodes are K<=6-input LUTs
//! over primary inputs, constants or other nodes; neurons mapped to BRAM
//! are tracked separately (the paper observed Vivado spilling wide-fan-in
//! neurons into BRAMs, §5.4).

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Net {
    Const0,
    Const1,
    Input(u32),
    Node(u32),
}

impl Net {
    pub fn key(&self) -> u64 {
        match self {
            Net::Const0 => 0,
            Net::Const1 => 1,
            Net::Input(i) => 2 + 2 * (*i as u64),
            Net::Node(i) => 3 + 2 * (*i as u64),
        }
    }
}

/// One mapped K-LUT (K <= 6): output = tt bit at the packed input index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LutNode {
    pub inputs: Vec<Net>,
    pub tt: u64,
    /// Logic level (1 + max level of inputs); inputs/constants are level 0.
    pub level: u32,
}

/// A neuron kept as a memory block instead of logic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BramNeuron {
    pub in_bits: usize,
    pub out_bits: usize,
    /// 18Kb BRAM blocks consumed.
    pub blocks: usize,
}

/// Structural equality (`PartialEq`) compares node lists, outputs, BRAMs
/// and depths verbatim — `synth::opt` uses it to detect its fixed point.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Netlist {
    pub num_inputs: usize,
    pub nodes: Vec<LutNode>,
    pub outputs: Vec<Net>,
    pub brams: Vec<BramNeuron>,
    /// Output nets grouped per layer (for registered-timing analysis);
    /// `layer_bounds[i]` = node count when layer i finished mapping.
    pub layer_depths: Vec<u32>,
}

impl Netlist {
    pub fn num_luts(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_brams(&self) -> usize {
        self.brams.iter().map(|b| b.blocks).sum()
    }

    pub fn level_of(&self, net: Net) -> u32 {
        match net {
            Net::Node(i) => self.nodes[i as usize].level,
            _ => 0,
        }
    }

    /// Combinational depth to the outputs.
    pub fn depth(&self) -> u32 {
        self.outputs.iter().map(|&o| self.level_of(o)).max().unwrap_or(0)
    }

    /// Compile this netlist into a level-ordered arena evaluation plan for
    /// the wide-plane simulator (`crate::sim::plan`).  Hot callers compile
    /// once and reuse the plan (plus a `SimScratch`) across batches.
    pub fn compile_plan(&self) -> crate::sim::EvalPlan {
        crate::sim::EvalPlan::compile(self)
    }

    /// Scalar reference evaluation on one primary-input bit vector.  Batch
    /// workloads (equivalence sweeps, accuracy scoring, netlist-backed
    /// serving) should use the bitsliced simulator instead —
    /// `crate::sim::eval_netlist` evaluates 256 samples per chunk per core
    /// over a levelized plan and is cross-checked against this
    /// implementation (and the 64-way `eval_netlist_64` oracle) by
    /// property tests.
    pub fn eval(&self, inputs: &[bool]) -> Vec<bool> {
        assert_eq!(inputs.len(), self.num_inputs);
        let mut values = vec![false; self.nodes.len()];
        let get = |values: &Vec<bool>, net: Net| -> bool {
            match net {
                Net::Const0 => false,
                Net::Const1 => true,
                Net::Input(i) => inputs[i as usize],
                Net::Node(i) => values[i as usize],
            }
        };
        for (i, node) in self.nodes.iter().enumerate() {
            let mut idx = 0usize;
            for (j, &inp) in node.inputs.iter().enumerate() {
                if get(&values, inp) {
                    idx |= 1 << j;
                }
            }
            values[i] = (node.tt >> idx) & 1 == 1;
        }
        self.outputs.iter().map(|&o| get(&values, o)).collect()
    }
}

/// Timing model constants (UltraScale+-flavored; see DESIGN.md
/// §Hardware-Adaptation — calibrated so a 1-level design lands near the
/// paper's 0.768 ns minimum period).
pub const T_REG_NS: f64 = 0.30;
pub const T_LUT_NS: f64 = 0.15;
pub const T_NET_NS: f64 = 0.40;

pub fn period_for_depth(depth: u32) -> f64 {
    T_REG_NS + depth as f64 * (T_LUT_NS + T_NET_NS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_simple_and_or() {
        // n0 = AND(in0, in1); n1 = OR(n0, in2)
        let netlist = Netlist {
            num_inputs: 3,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b1000, level: 1 },
                LutNode { inputs: vec![Net::Node(0), Net::Input(2)], tt: 0b1110, level: 2 },
            ],
            outputs: vec![Net::Node(1)],
            brams: vec![],
            layer_depths: vec![2],
        };
        assert_eq!(netlist.eval(&[true, true, false]), vec![true]);
        assert_eq!(netlist.eval(&[false, true, false]), vec![false]);
        assert_eq!(netlist.eval(&[false, false, true]), vec![true]);
        assert_eq!(netlist.depth(), 2);
    }

    #[test]
    fn period_grows_with_depth() {
        assert!(period_for_depth(1) < period_for_depth(3));
        assert!((period_for_depth(1) - 0.85).abs() < 1e-9);
    }
}
