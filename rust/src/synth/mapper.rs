//! Technology mapper: boolean function -> network of 6-input LUTs.
//!
//! Strategy per function (after support reduction):
//! * <= 6 support vars: one LUT, structurally hashed so identical functions
//!   over identical nets are shared across neurons and layers.
//! * otherwise: choose the cheaper of
//!   - Shannon decomposition (1 select var if the support is odd-sized,
//!     2 select vars packed as a 4:1 mux LUT otherwise — this worst-cases
//!     to exactly the paper's closed form eq. 2.3), and
//!   - a sum-of-products build from the Espresso-minimized cover (AND trees
//!     per cube + OR tree), which wins when training produced simple logic.
//!
//! Structural hashing + support reduction + cover minimization are what
//! reproduce the paper's Table 5.2 observation (synthesized LUTs << the
//! analytical bound).

use super::boolfn::BoolFn;
use super::cover::{minimize, Cover};
use super::netlist::{LutNode, Net, Netlist};
use std::collections::HashMap;

/// Decomposition strategy — `ShannonOnly` disables the cover-based SOP
/// path (ablation for the DESIGN.md design-choice study; `bench_synth`
/// compares both).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MapStrategy {
    #[default]
    Hybrid,
    ShannonOnly,
}

pub struct Mapper {
    pub netlist: Netlist,
    pub strategy: MapStrategy,
    /// Structural hash: (tt, input nets) -> existing node.
    cache: HashMap<(u64, Vec<Net>), Net>,
    /// Function cache: (compact truth table, support nets) -> net.
    fn_cache: HashMap<(Vec<u64>, Vec<Net>), Net>,
}

impl Mapper {
    pub fn new(num_inputs: usize) -> Mapper {
        Mapper {
            netlist: Netlist { num_inputs, ..Default::default() },
            strategy: MapStrategy::Hybrid,
            cache: HashMap::new(),
            fn_cache: HashMap::new(),
        }
    }

    pub fn with_strategy(num_inputs: usize, strategy: MapStrategy) -> Mapper {
        Mapper { strategy, ..Mapper::new(num_inputs) }
    }

    /// Map function `f` whose variable i is driven by `nets[i]`.
    pub fn map_fn(&mut self, f: &BoolFn, nets: &[Net]) -> Net {
        assert_eq!(f.nvars, nets.len());
        if let Some(c) = f.is_const() {
            return if c { Net::Const1 } else { Net::Const0 };
        }
        // Support reduction.
        let supp = f.support();
        let (g, gnets): (BoolFn, Vec<Net>) = if supp.len() == f.nvars {
            (f.clone(), nets.to_vec())
        } else {
            (f.compact(&supp), supp.iter().map(|&v| nets[v]).collect())
        };
        // Single-variable passthrough / inverter-free wire.
        if g.nvars == 1 && g.get(1) && !g.get(0) {
            return gnets[0];
        }
        let key = (g.words.clone(), gnets.clone());
        if let Some(&net) = self.fn_cache.get(&key) {
            return net;
        }
        let net = if g.nvars <= 6 {
            self.emit_lut(&g, &gnets)
        } else {
            // Try Shannon; compare with cover-based SOP when the cover is
            // promising, picking whichever uses fewer new nodes.
            let cover_cheap = if self.strategy == MapStrategy::ShannonOnly {
                false
            } else {
                true
            } && {
                let cover = minimize(&g);
                estimate_cover_cost(&cover) + 1 < super_shannon_cost(g.nvars)
            };
            if cover_cheap {
                let cover = minimize(&g);
                self.build_cover(&cover, &gnets)
            } else {
                self.shannon(&g, &gnets)
            }
        };
        self.fn_cache.insert(key, net);
        net
    }

    /// Shannon decomposition on the top variable(s).
    fn shannon(&mut self, f: &BoolFn, nets: &[Net]) -> Net {
        let n = f.nvars;
        debug_assert!(n > 6);
        if n % 2 == 1 {
            // split one var (the highest)
            let v = n - 1;
            let f0 = f.cofactor(v, false);
            let f1 = f.cofactor(v, true);
            let sub: Vec<Net> = nets[..v].to_vec();
            let n0 = self.map_fn(&f0, &sub);
            let n1 = self.map_fn(&f1, &sub);
            if n0 == n1 {
                return n0;
            }
            // mux(sel, n0, n1): 3-input LUT, inputs [n0, n1, sel]
            let mut mux = BoolFn::zeros(3);
            for idx in 0..8usize {
                let sel = (idx >> 2) & 1 == 1;
                let d = if sel { (idx >> 1) & 1 == 1 } else { idx & 1 == 1 };
                mux.set(idx, d);
            }
            self.emit_lut(&mux, &[n0, n1, nets[v]])
        } else {
            // split two vars -> 4 cofactors + 4:1 mux in one LUT6
            let (va, vb) = (n - 2, n - 1);
            let mut data = Vec::with_capacity(4);
            let sub: Vec<Net> = nets[..va].to_vec();
            for s in 0..4usize {
                let fa = f.cofactor(vb, (s >> 1) & 1 == 1);
                let f2 = fa.cofactor(va, s & 1 == 1);
                data.push(self.map_fn(&f2, &sub));
            }
            if data.iter().all(|&d| d == data[0]) {
                return data[0];
            }
            // LUT6: inputs [d0, d1, d2, d3, sa, sb]
            let mut mux = BoolFn::zeros(6);
            for idx in 0..64usize {
                let sa = (idx >> 4) & 1;
                let sb = (idx >> 5) & 1;
                let sel = sa | (sb << 1);
                mux.set(idx, (idx >> sel) & 1 == 1);
            }
            self.emit_lut(
                &mux,
                &[data[0], data[1], data[2], data[3], nets[va], nets[vb]],
            )
        }
    }

    /// Build an AND/OR tree for a minimized cover.
    fn build_cover(&mut self, cover: &Cover, nets: &[Net]) -> Net {
        let mut terms: Vec<Net> = Vec::with_capacity(cover.cubes.len());
        for cube in &cover.cubes {
            // Gather (net, polarity) literals.
            let lits: Vec<(Net, bool)> = (0..cover.nvars)
                .filter(|&v| (cube.care >> v) & 1 == 1)
                .map(|v| (nets[v], (cube.val >> v) & 1 == 1))
                .collect();
            terms.push(self.and_tree(&lits));
        }
        self.or_tree(&terms)
    }

    fn and_tree(&mut self, lits: &[(Net, bool)]) -> Net {
        if lits.is_empty() {
            return Net::Const1;
        }
        if lits.len() == 1 && lits[0].1 {
            return lits[0].0;
        }
        let mut current: Vec<(Net, bool)> = lits.to_vec();
        loop {
            if current.len() <= 6 {
                let k = current.len();
                let mut tt = BoolFn::zeros(k);
                for idx in 0..(1usize << k) {
                    let all = (0..k).all(|j| ((idx >> j) & 1 == 1) == current[j].1);
                    tt.set(idx, all);
                }
                let nets: Vec<Net> = current.iter().map(|&(n, _)| n).collect();
                return self.emit_lut(&tt, &nets);
            }
            // Reduce 6 at a time into positive-polarity intermediate nets.
            let mut next: Vec<(Net, bool)> = Vec::new();
            for chunk in current.chunks(6) {
                if chunk.len() == 1 {
                    next.push(chunk[0]);
                    continue;
                }
                let k = chunk.len();
                let mut tt = BoolFn::zeros(k);
                for idx in 0..(1usize << k) {
                    let all = (0..k).all(|j| ((idx >> j) & 1 == 1) == chunk[j].1);
                    tt.set(idx, all);
                }
                let nets: Vec<Net> = chunk.iter().map(|&(n, _)| n).collect();
                let out = self.emit_lut(&tt, &nets);
                next.push((out, true));
            }
            current = next;
        }
    }

    fn or_tree(&mut self, terms: &[Net]) -> Net {
        if terms.is_empty() {
            return Net::Const0;
        }
        let mut current: Vec<Net> = terms.to_vec();
        while current.len() > 1 {
            let mut next = Vec::new();
            for chunk in current.chunks(6) {
                if chunk.len() == 1 {
                    next.push(chunk[0]);
                    continue;
                }
                let k = chunk.len();
                let mut tt = BoolFn::zeros(k);
                for idx in 1..(1usize << k) {
                    tt.set(idx, true);
                }
                next.push(self.emit_lut(&tt, chunk));
            }
            current = next;
        }
        current[0]
    }

    /// Emit (or reuse) a <=6-input LUT node.  Handles constant inputs,
    /// duplicate input nets and support reduction of the small function.
    pub fn emit_lut(&mut self, f: &BoolFn, nets: &[Net]) -> Net {
        debug_assert!(f.nvars <= 6);
        debug_assert_eq!(f.nvars, nets.len());
        // Fold constant inputs.
        if let Some(pos) = nets.iter().position(|n| matches!(n, Net::Const0 | Net::Const1)) {
            let val = matches!(nets[pos], Net::Const1);
            let g = f.cofactor(pos, val);
            let mut sub = nets.to_vec();
            sub.remove(pos);
            return self.emit_small(&g, &sub);
        }
        // Merge duplicate nets.
        for i in 0..nets.len() {
            for j in (i + 1)..nets.len() {
                if nets[i] == nets[j] {
                    // Restrict to x_i == x_j by building the merged function.
                    let k = f.nvars - 1;
                    let mut g = BoolFn::zeros(k);
                    for idx2 in 0..(1usize << k) {
                        // reinsert bit j equal to bit i
                        let low_mask = (1usize << j) - 1;
                        let base = (idx2 & low_mask) | ((idx2 & !low_mask) << 1);
                        let bi = if i < j { (idx2 >> i) & 1 } else { (idx2 >> (i - 1)) & 1 };
                        let idx = base | (bi << j);
                        g.set(idx2, f.get(idx));
                    }
                    let mut sub = nets.to_vec();
                    sub.remove(j);
                    return self.emit_small(&g, &sub);
                }
            }
        }
        self.emit_small(f, nets)
    }

    fn emit_small(&mut self, f: &BoolFn, nets: &[Net]) -> Net {
        if let Some(c) = f.is_const() {
            return if c { Net::Const1 } else { Net::Const0 };
        }
        let supp = f.support();
        let (g, gnets): (BoolFn, Vec<Net>) = if supp.len() == f.nvars {
            (f.clone(), nets.to_vec())
        } else {
            (f.compact(&supp), supp.iter().map(|&v| nets[v]).collect())
        };
        if g.nvars == 1 {
            if g.get(1) && !g.get(0) {
                return gnets[0];
            }
        }
        // Canonical input order: sort nets, permute tt accordingly.
        let (tt, sorted_nets) = canonical_order(&g, &gnets);
        let key = (tt, sorted_nets.clone());
        if let Some(&n) = self.cache.get(&key) {
            return n;
        }
        let level = 1 + sorted_nets
            .iter()
            .map(|&n| self.netlist.level_of(n))
            .max()
            .unwrap_or(0);
        let id = self.netlist.nodes.len() as u32;
        self.netlist.nodes.push(LutNode { inputs: sorted_nets, tt, level });
        let net = Net::Node(id);
        self.cache.insert(key, net);
        net
    }
}

/// Permute a <=6-var function so its input nets are in ascending order;
/// returns the permuted u64 truth table and sorted nets.  Shared with the
/// post-mapping optimizer (`synth::opt`), whose CSE pass must hash nodes
/// exactly the way the mapper does.
pub(crate) fn canonical_order(f: &BoolFn, nets: &[Net]) -> (u64, Vec<Net>) {
    let k = f.nvars;
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by_key(|&i| nets[i]);
    let mut tt = 0u64;
    for idx in 0..(1usize << k) {
        // idx indexes the *sorted* inputs; map back to original variable
        // positions.
        let mut orig = 0usize;
        for (newpos, &oldpos) in order.iter().enumerate() {
            if (idx >> newpos) & 1 == 1 {
                orig |= 1 << oldpos;
            }
        }
        if f.get(orig) {
            tt |= 1u64 << idx;
        }
    }
    (tt, order.iter().map(|&i| nets[i]).collect())
}

/// Worst-case Shannon cost (the analytical closed form, eq. 2.3, M=1).
fn super_shannon_cost(nvars: usize) -> usize {
    crate::cost::lut_cost(nvars, 1) as usize
}

/// Optimistic node count of a cover build (used only to pick a strategy).
fn estimate_cover_cost(cover: &Cover) -> usize {
    let mut cost = 0usize;
    for cube in &cover.cubes {
        let k = cube.num_literals();
        if k > 6 {
            cost += k.div_ceil(6) + 1;
        } else {
            cost += 1;
        }
    }
    if cover.cubes.len() > 1 {
        cost += (cover.cubes.len() - 1).div_ceil(5);
    }
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn nets(n: usize) -> Vec<Net> {
        (0..n as u32).map(Net::Input).collect()
    }

    /// Exhaustive table-vs-netlist equivalence: enumerate all `2^k` input
    /// patterns as bit-planes and compare one bitsliced pass against the
    /// source function (64 patterns per word instead of one scalar
    /// `Netlist::eval` — and one netlist clone — per pattern).
    fn check_equiv(f: &BoolFn, mapper: &Mapper, out: Net, num_inputs: usize) {
        use crate::sim::{eval_netlist, BitMatrix};
        assert_eq!(f.nvars, num_inputs);
        let mut nl = mapper.netlist.clone();
        nl.outputs = vec![out];
        let patterns = BitMatrix::all_patterns(num_inputs);
        let got = eval_netlist(&nl, &patterns);
        for idx in 0..f.num_entries() {
            assert_eq!(got.get(0, idx), f.get(idx), "idx {idx}");
        }
    }

    #[test]
    fn small_fn_is_single_lut() {
        let mut f = BoolFn::zeros(4);
        for idx in 0..16usize {
            f.set(idx, idx.count_ones() % 2 == 1);
        }
        let mut m = Mapper::new(4);
        let out = m.map_fn(&f, &nets(4));
        assert_eq!(m.netlist.num_luts(), 1);
        check_equiv(&f, &m, out, 4);
    }

    #[test]
    fn shared_function_maps_once() {
        let mut f = BoolFn::zeros(3);
        f.set(7, true);
        let mut m = Mapper::new(3);
        let a = m.map_fn(&f, &nets(3));
        let b = m.map_fn(&f, &nets(3));
        assert_eq!(a, b);
        assert_eq!(m.netlist.num_luts(), 1);
    }

    #[test]
    fn wide_xor_maps_correctly() {
        // 9-var XOR: worst case for covers, exercises Shannon path.
        let mut f = BoolFn::zeros(9);
        for idx in 0..512usize {
            f.set(idx, idx.count_ones() % 2 == 1);
        }
        let mut m = Mapper::new(9);
        let out = m.map_fn(&f, &nets(9));
        assert!(m.netlist.num_luts() <= super_shannon_cost(9) + 2, "{}", m.netlist.num_luts());
        check_equiv(&f, &m, out, 9);
    }

    #[test]
    fn wide_and_uses_cover_path() {
        // 12-var AND: cover = 1 cube -> ~3 LUTs, vs Shannon bound 85.
        let mut f = BoolFn::zeros(12);
        f.set((1usize << 12) - 1, true);
        let mut m = Mapper::new(12);
        let out = m.map_fn(&f, &nets(12));
        assert!(m.netlist.num_luts() <= 4, "{}", m.netlist.num_luts());
        check_equiv(&f, &m, out, 12);
    }

    #[test]
    fn prop_mapper_equivalent_on_random_functions() {
        forall("mapper-equiv", 0xAB, 40, |rng: &mut Rng| {
            let nvars = 1 + rng.below(9);
            let mut f = BoolFn::zeros(nvars);
            for idx in 0..f.num_entries() {
                f.set(idx, rng.f64() < 0.5);
            }
            let mut m = Mapper::new(nvars);
            let out = m.map_fn(&f, &nets(nvars));
            check_equiv(&f, &m, out, nvars);
        });
    }

    #[test]
    fn constant_inputs_fold() {
        // f(a, b) = a AND b with b = const1 -> passthrough of a, no LUT.
        let mut f = BoolFn::zeros(2);
        f.set(3, true);
        let mut m = Mapper::new(1);
        let out = m.emit_lut(&f, &[Net::Input(0), Net::Const1]);
        assert_eq!(out, Net::Input(0));
        assert_eq!(m.netlist.num_luts(), 0);
    }
}
