//! Netlist design-rule checker: structural static analysis over mapped
//! [`Netlist`]s (DESIGN.md §12).
//!
//! The functional safety net (`verify_netlist`, `netlists_equivalent`)
//! samples behavior; it cannot see structural rot that happens to evaluate
//! correctly today — stale `LutNode::level` annotations, truth-table bits
//! above `2^k`, degenerate LUTs inflating the counts the DSE and zoo
//! router price against, or forward references that the scalar evaluator
//! used to read as `false`.  This module is the complementary *structural*
//! check: a fixed catalogue of machine-checked rules ([`RULES`]), each
//! with a stable id and severity, producing a [`LintReport`] with a
//! machine-readable JSON form.
//!
//! Severity policy:
//! - **Error**: the netlist is not evaluable (dangling/forward references,
//!   fan-in beyond the 6-LUT kernel) or not a shippable artifact
//!   (no outputs, inconsistent BRAM accounting).  Every producer gates on
//!   these: `synthesize`, each `synth/opt` pass (tests/debug builds),
//!   `sim::plan::EvalPlan::compile`, zoo load, DSE frontier emit.
//! - **Warn**: evaluable but structurally dirty — redundancy the optimizer
//!   is expected to have removed, or metadata (levels, layer depths) that
//!   misreports timing.  `OptLevel::Full` artifacts must be warning-free
//!   (the zoo/DSE gates deny warnings); intermediate pass outputs may
//!   legitimately carry them (CSE exposes duplicate fan-ins for Sweep).
//! - **Info**: notable but not wrong (BRAM-mapped neurons make a netlist
//!   non-simulable by design).
//!
//! Rules that need to *walk* node references (level recomputation,
//! reachability) only run once the reference-validity rules passed, so
//! [`lint_netlist`] never panics, even on maximally corrupt inputs.

use super::boolfn::BoolFn;
use super::netlist::Netlist;
use super::opt::OptLevel;
use crate::synth::netlist::Net;
use crate::util::json::Json;

/// Fan-in bound of the LUT kernel (`sim::lut_chunk` unpacks at most 6).
pub const MAX_FANIN: usize = 6;

/// Bits per BRAM block the synthesizer's spill heuristic assumes.
pub const BRAM_BLOCK_BITS: u128 = 18 * 1024;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }

    fn rank(self) -> u8 {
        match self {
            Severity::Info => 0,
            Severity::Warn => 1,
            Severity::Error => 2,
        }
    }
}

// Hand-written ordering (see `Net` in `netlist.rs`): the crate bans raw
// `partial_cmp` call sites via clippy's disallowed-methods and derive
// expansions are not exempt.
impl Ord for Severity {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.rank().cmp(&other.rank())
    }
}

impl PartialOrd for Severity {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// One design rule: stable id, fixed severity, human description.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rule {
    pub id: &'static str,
    pub severity: Severity,
    pub desc: &'static str,
}

pub const INPUT_OUT_OF_RANGE: Rule = Rule {
    id: "input-out-of-range",
    severity: Severity::Error,
    desc: "a Net::Input index is >= num_inputs",
};
pub const NODE_OUT_OF_RANGE: Rule = Rule {
    id: "node-out-of-range",
    severity: Severity::Error,
    desc: "a Net::Node index is >= the node count",
};
pub const FORWARD_REFERENCE: Rule = Rule {
    id: "forward-reference",
    severity: Severity::Error,
    desc: "node i reads Node(j) with j >= i (topological order violated)",
};
pub const FANIN_TOO_WIDE: Rule = Rule {
    id: "fanin-too-wide",
    severity: Severity::Error,
    desc: "LUT fan-in exceeds the K=6 kernel bound",
};
pub const EMPTY_OUTPUTS: Rule = Rule {
    id: "empty-outputs",
    severity: Severity::Error,
    desc: "netlist has live nodes but no outputs",
};
pub const BRAM_SHAPE: Rule = Rule {
    id: "bram-shape",
    severity: Severity::Error,
    desc: "BRAM port bits are degenerate or blocks != ceil(2^in_bits * out_bits / 18Kb)",
};
pub const TT_GARBAGE: Rule = Rule {
    id: "tt-garbage",
    severity: Severity::Warn,
    desc: "truth-table bits set at or above 2^k for a k-input LUT",
};
pub const STALE_LEVEL: Rule = Rule {
    id: "stale-level",
    severity: Severity::Warn,
    desc: "stored LutNode::level disagrees with the level recomputed from the wiring",
};
pub const DUPLICATE_INPUT: Rule = Rule {
    id: "duplicate-input",
    severity: Severity::Warn,
    desc: "one net appears twice in a LUT's fan-in",
};
pub const CONST_LUT: Rule = Rule {
    id: "const-lut",
    severity: Severity::Warn,
    desc: "truth table is constant over its 2^k entries",
};
pub const WIRE_LUT: Rule = Rule {
    id: "wire-lut",
    severity: Severity::Warn,
    desc: "1-input LUT is a positive passthrough of its fan-in net",
};
pub const VACUOUS_INPUT: Rule = Rule {
    id: "vacuous-input",
    severity: Severity::Warn,
    desc: "truth table ignores at least one fan-in variable",
};
pub const LAYER_DEPTHS_UNDERSTATE: Rule = Rule {
    id: "layer-depths-understate",
    severity: Severity::Warn,
    desc: "recomputed combinational depth exceeds the sum of layer_depths",
};
pub const DEAD_LUT: Rule = Rule {
    id: "dead-lut",
    severity: Severity::Warn,
    desc: "node unreachable from every output survived a structural opt level",
};
pub const BRAM_PORTS: Rule = Rule {
    id: "bram-ports",
    severity: Severity::Info,
    desc: "netlist carries BRAM-mapped neurons (opaque ports are not simulator-evaluable)",
};
pub const CONV_RF_OUT_OF_RANGE: Rule = Rule {
    id: "conv-rf-out-of-range",
    severity: Severity::Error,
    desc: "a conv neuron reads an input outside its receptive-field window (or the layer)",
};
pub const CONV_WINDOW_INCONSISTENT: Rule = Rule {
    id: "conv-window-inconsistent",
    severity: Severity::Error,
    desc: "a conv neuron's kept taps differ from the shared per-channel window subset",
};

/// The complete rule catalogue, in severity-then-pipeline order.
pub const RULES: &[Rule] = &[
    INPUT_OUT_OF_RANGE,
    NODE_OUT_OF_RANGE,
    FORWARD_REFERENCE,
    FANIN_TOO_WIDE,
    EMPTY_OUTPUTS,
    BRAM_SHAPE,
    CONV_RF_OUT_OF_RANGE,
    CONV_WINDOW_INCONSISTENT,
    TT_GARBAGE,
    STALE_LEVEL,
    DUPLICATE_INPUT,
    CONST_LUT,
    WIRE_LUT,
    VACUOUS_INPUT,
    LAYER_DEPTHS_UNDERSTATE,
    DEAD_LUT,
    BRAM_PORTS,
];

/// Where a finding points.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Span {
    Node(usize),
    Output(usize),
    Bram(usize),
    /// (layer, neuron) in the pre-mapping model view ([`lint_conv_model`]).
    Neuron(usize, usize),
    Netlist,
}

impl Span {
    fn render(&self) -> String {
        match self {
            Span::Node(i) => format!("node {i}"),
            Span::Output(i) => format!("output {i}"),
            Span::Bram(i) => format!("bram {i}"),
            Span::Neuron(l, i) => format!("layer {l} neuron {i}"),
            Span::Netlist => "netlist".to_string(),
        }
    }
}

/// One rule violation at one span.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: Rule,
    pub span: Span,
    pub message: String,
}

/// Context the linter needs beyond the netlist itself: the opt level the
/// producer claims to have applied.  Redundancy-elimination rules
/// (currently [`DEAD_LUT`]) only fire when that level promises the
/// redundancy is gone — unused cone outputs are legitimate at
/// `OptLevel::None`.
#[derive(Debug, Clone, Copy, Default)]
pub struct LintOptions {
    pub opt: OptLevel,
}

impl LintOptions {
    pub fn at(opt: OptLevel) -> LintOptions {
        LintOptions { opt }
    }
}

/// The analyzer's result: every finding, in node/output/bram scan order.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
}

impl LintReport {
    fn count(&self, s: Severity) -> usize {
        self.findings.iter().filter(|f| f.rule.severity == s).count()
    }

    pub fn errors(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warnings(&self) -> usize {
        self.count(Severity::Warn)
    }

    pub fn infos(&self) -> usize {
        self.count(Severity::Info)
    }

    /// No findings at any severity.
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable form (`lint --json`).
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                let (kind, idx, layer) = match f.span {
                    Span::Node(i) => ("node", Some(i), None),
                    Span::Output(i) => ("output", Some(i), None),
                    Span::Bram(i) => ("bram", Some(i), None),
                    Span::Neuron(l, i) => ("neuron", Some(i), Some(l)),
                    Span::Netlist => ("netlist", None, None),
                };
                let mut pairs = vec![
                    ("rule", Json::str(f.rule.id)),
                    ("severity", Json::str(f.rule.severity.name())),
                    ("span", Json::str(kind)),
                    ("message", Json::str(&f.message)),
                ];
                if let Some(i) = idx {
                    pairs.push(("index", Json::num(i as f64)));
                }
                if let Some(l) = layer {
                    pairs.push(("layer", Json::num(l as f64)));
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            ("errors", Json::num(self.errors() as f64)),
            ("warnings", Json::num(self.warnings() as f64)),
            ("infos", Json::num(self.infos() as f64)),
            ("findings", Json::Arr(findings)),
        ])
    }

    /// Human-readable multi-line form (gate failure messages, CLI).
    pub fn render(&self) -> String {
        if self.findings.is_empty() {
            return "clean: no findings".to_string();
        }
        let mut s = String::new();
        for f in &self.findings {
            s.push_str(&format!(
                "{}[{}] {}: {}\n",
                f.rule.severity.name(),
                f.rule.id,
                f.span.render(),
                f.message
            ));
        }
        s.push_str(&format!(
            "{} error(s), {} warning(s), {} info(s)",
            self.errors(),
            self.warnings(),
            self.infos()
        ));
        s
    }
}

fn finding(rule: Rule, span: Span, message: String) -> Finding {
    Finding { rule, span, message }
}

fn check_net(nl: &Netlist, net: Net, span: Span, self_idx: Option<usize>, out: &mut Vec<Finding>) {
    match net {
        Net::Const0 | Net::Const1 => {}
        Net::Input(i) => {
            if i as usize >= nl.num_inputs {
                out.push(finding(
                    INPUT_OUT_OF_RANGE,
                    span,
                    format!("reads Input({i}) but the netlist has {} inputs", nl.num_inputs),
                ));
            }
        }
        Net::Node(j) => {
            if j as usize >= nl.nodes.len() {
                out.push(finding(
                    NODE_OUT_OF_RANGE,
                    span,
                    format!("reads Node({j}) but the netlist has {} nodes", nl.nodes.len()),
                ));
            } else if let Some(i) = self_idx {
                if j as usize >= i {
                    out.push(finding(
                        FORWARD_REFERENCE,
                        span,
                        format!("node {i} reads Node({j}); topological order requires {j} < {i}"),
                    ));
                }
            }
        }
    }
}

/// The reference/shape rules a netlist must pass to be *evaluable* at all
/// — exactly the preconditions `sim::plan::EvalPlan::compile` (and
/// `Netlist::eval`) assume: in-range input and node references,
/// topological node order, and fan-in within the LUT kernel.  A netlist
/// can fail other Error rules (e.g. [`EMPTY_OUTPUTS`]) and still be
/// evaluable, so the plan compiler gates on this subset, not on
/// [`lint_netlist`].
pub fn evaluability_errors(nl: &Netlist) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, node) in nl.nodes.iter().enumerate() {
        if node.inputs.len() > MAX_FANIN {
            out.push(finding(
                FANIN_TOO_WIDE,
                Span::Node(i),
                format!("{} fan-ins exceed the K={MAX_FANIN} LUT kernel", node.inputs.len()),
            ));
        }
        for &inp in &node.inputs {
            check_net(nl, inp, Span::Node(i), Some(i), &mut out);
        }
    }
    for (o, &net) in nl.outputs.iter().enumerate() {
        check_net(nl, net, Span::Output(o), None, &mut out);
    }
    // Content-bearing BRAM records must be schedulable: coherent
    // nets/content shape, pseudo outputs inside the input bus, addresses
    // drawing only on earlier-listed BRAMs, and every pseudo consumer at
    // or after the BRAM's trigger index.  Opaque ports (no nets, no
    // content) skip all of this — their pseudo inputs are caller-provided.
    for (bi, b) in nl.brams.iter().enumerate() {
        if b.inputs.is_empty() && b.content.is_empty() {
            continue;
        }
        if !b.is_evaluable() {
            out.push(finding(
                BRAM_SHAPE,
                Span::Bram(bi),
                format!(
                    "content-bearing BRAM needs in_bits address nets and 2^in_bits codes \
                     (got {} nets, {} codes for a {}x{} port)",
                    b.inputs.len(),
                    b.content.len(),
                    b.in_bits,
                    b.out_bits
                ),
            ));
            continue;
        }
        if b.out_base as usize + b.out_bits > nl.num_inputs {
            out.push(finding(
                BRAM_SHAPE,
                Span::Bram(bi),
                format!(
                    "pseudo outputs {}..{} exceed the {}-bit input bus",
                    b.out_base,
                    b.out_base as usize + b.out_bits,
                    nl.num_inputs
                ),
            ));
        }
        for &net in &b.inputs {
            check_net(nl, net, Span::Bram(bi), None, &mut out);
            if let Net::Input(p) = net {
                for (ci, c) in nl.brams.iter().enumerate().skip(bi) {
                    if is_pseudo_of(c, p) {
                        out.push(finding(
                            FORWARD_REFERENCE,
                            Span::Bram(bi),
                            format!(
                                "address reads Input({p}), a pseudo output of BRAM {ci} \
                                 which does not fire earlier"
                            ),
                        ));
                    }
                }
            }
        }
    }
    if nl.brams_evaluable() && !nl.brams.is_empty() && out.is_empty() {
        // Trigger ordering needs valid references, so it only runs once
        // everything above passed.
        let triggers = nl.bram_triggers();
        for (i, node) in nl.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                if let Net::Input(p) = inp {
                    for (bi, b) in nl.brams.iter().enumerate() {
                        if is_pseudo_of(b, p) && i < triggers[bi] {
                            out.push(finding(
                                FORWARD_REFERENCE,
                                Span::Node(i),
                                format!(
                                    "node {i} reads Input({p}), a pseudo output of BRAM {bi} \
                                     whose address is only ready at node {}",
                                    triggers[bi]
                                ),
                            ));
                        }
                    }
                }
            }
        }
    }
    out
}

/// Whether primary-input id `p` is one of `b`'s pseudo output bits
/// (content-bearing BRAMs only; opaque `out_base` is meaningless).
fn is_pseudo_of(b: &crate::synth::netlist::BramNeuron, p: u32) -> bool {
    !b.content.is_empty() && p >= b.out_base && (p - b.out_base) < b.out_bits as u32
}

/// Run the full rule catalogue.  Never panics: rules that must chase node
/// references (level recomputation, reachability) are skipped when any
/// reference-validity Error fired.
pub fn lint_netlist(nl: &Netlist, opts: &LintOptions) -> LintReport {
    let mut findings = evaluability_errors(nl);

    // Per-node truth-table hygiene — pure tt/fan-in checks, safe on any
    // input.
    for (i, node) in nl.nodes.iter().enumerate() {
        let k = node.inputs.len();
        if let Some(dup) = first_duplicate(&node.inputs) {
            findings.push(finding(
                DUPLICATE_INPUT,
                Span::Node(i),
                format!("fan-in positions {} and {} read the same net", dup.0, dup.1),
            ));
        }
        if k > MAX_FANIN {
            continue; // tt checks are meaningless past the kernel bound
        }
        let mask = if k == MAX_FANIN { u64::MAX } else { (1u64 << (1usize << k)) - 1 };
        if node.tt & !mask != 0 {
            findings.push(finding(
                TT_GARBAGE,
                Span::Node(i),
                format!("tt {:#x} has bits set at or above 2^{k} entries", node.tt),
            ));
        }
        let f = BoolFn::from_tt6(k, node.tt & mask);
        if let Some(c) = f.is_const() {
            findings.push(finding(
                CONST_LUT,
                Span::Node(i),
                format!("truth table is constant {}", c as u8),
            ));
        } else if k == 1 && node.tt & mask == 0b10 {
            findings.push(finding(
                WIRE_LUT,
                Span::Node(i),
                "1-input LUT is a positive wire to its fan-in".to_string(),
            ));
        } else if f.support().len() < k {
            findings.push(finding(
                VACUOUS_INPUT,
                Span::Node(i),
                format!("truth table depends on only {} of {k} fan-ins", f.support().len()),
            ));
        }
    }

    if nl.outputs.is_empty() && !nl.nodes.is_empty() {
        findings.push(finding(
            EMPTY_OUTPUTS,
            Span::Netlist,
            format!("{} live nodes but no outputs", nl.nodes.len()),
        ));
    }

    for (bi, b) in nl.brams.iter().enumerate() {
        if b.in_bits == 0 || b.out_bits == 0 || b.in_bits >= 64 {
            findings.push(finding(
                BRAM_SHAPE,
                Span::Bram(bi),
                format!("degenerate port shape {}x{}", b.in_bits, b.out_bits),
            ));
        } else {
            let bits = (1u128 << b.in_bits) * b.out_bits as u128;
            let expect = bits.div_ceil(BRAM_BLOCK_BITS);
            if b.blocks as u128 != expect {
                findings.push(finding(
                    BRAM_SHAPE,
                    Span::Bram(bi),
                    format!(
                        "{} blocks recorded, {expect} required for a {}x{} port",
                        b.blocks, b.in_bits, b.out_bits
                    ),
                ));
            }
        }
    }
    if !nl.brams.is_empty() {
        let msg = if nl.brams_evaluable() {
            format!(
                "{} BRAM-mapped neurons with captured contents; simulated via pseudo inputs",
                nl.brams.len()
            )
        } else {
            format!(
                "{} BRAM-mapped neurons with opaque ports; logic simulation unavailable",
                nl.brams.len()
            )
        };
        findings.push(finding(BRAM_PORTS, Span::Netlist, msg));
    }

    // Reference-chasing rules only run on reference-valid netlists.
    if !findings.iter().any(|f| f.rule.severity == Severity::Error) {
        let levels = nl.recomputed_levels();
        for (i, node) in nl.nodes.iter().enumerate() {
            if node.level != levels[i] {
                findings.push(finding(
                    STALE_LEVEL,
                    Span::Node(i),
                    format!("stored level {} but the wiring gives {}", node.level, levels[i]),
                ));
            }
        }
        let depth = nl
            .outputs
            .iter()
            .map(|&o| match o {
                Net::Node(j) => levels[j as usize],
                _ => 0,
            })
            .max()
            .unwrap_or(0);
        let budget: u64 = nl.layer_depths.iter().map(|&d| d as u64).sum();
        if depth as u64 > budget {
            findings.push(finding(
                LAYER_DEPTHS_UNDERSTATE,
                Span::Netlist,
                format!("recomputed depth {depth} exceeds sum(layer_depths) = {budget}"),
            ));
        }
        if opts.opt.structural() {
            let reach = super::opt::reachable(nl);
            for (i, &r) in reach.iter().enumerate() {
                if !r {
                    findings.push(finding(
                        DEAD_LUT,
                        Span::Node(i),
                        format!("unreachable from every output at opt level {}", opts.opt.name()),
                    ));
                }
            }
        }
    }

    let report = LintReport { findings };
    if crate::obs::enabled() {
        crate::obs::add("synth.lint.errors.count", report.errors() as u64);
        crate::obs::add("synth.lint.warns.count", report.warnings() as u64);
        crate::obs::add("synth.lint.infos.count", report.infos() as u64);
    }
    report
}

/// Model-level conv design rules, run on the pre-mapping exported view
/// (where per-neuron receptive fields are still visible; the lowered
/// netlist has lost the layer/window structure).  Checks every conv
/// neuron's fan-in against the deterministic geometry
/// ([`crate::runtime::ConvGeom::neuron_windows`]):
///
/// - [`CONV_RF_OUT_OF_RANGE`]: an input index outside the neuron's
///   receptive-field window (or the layer's input width entirely),
/// - [`CONV_WINDOW_INCONSISTENT`]: inputs inside the window but differing
///   from the kept subset shared by every pixel of the output channel —
///   i.e. the weight-sharing structure was corrupted.
///
/// Errs only when the manifest's conv extras themselves are inconsistent
/// (the parse-time validation conditions); structural deviations in the
/// model are reported as findings so producers gate on `errors()` like
/// they do for [`lint_netlist`].
pub fn lint_conv_model(
    man: &crate::runtime::Manifest,
    model: &crate::nn::ExportedModel,
) -> anyhow::Result<LintReport> {
    let geoms = man.conv_geoms()?;
    let mut findings = Vec::new();
    for (li, g) in geoms.iter().enumerate() {
        let Some(layer) = model.layers.get(li) else {
            findings.push(finding(
                CONV_WINDOW_INCONSISTENT,
                Span::Netlist,
                format!("conv layer {li} missing: model has {} layers", model.layers.len()),
            ));
            continue;
        };
        let expect = g.mask_rows();
        if layer.neurons.len() != expect.len() || layer.in_f != g.in_f() {
            findings.push(finding(
                CONV_WINDOW_INCONSISTENT,
                Span::Netlist,
                format!(
                    "conv layer {li} shape {}x{} but geometry lowers to {}x{}",
                    layer.in_f,
                    layer.neurons.len(),
                    g.in_f(),
                    expect.len()
                ),
            ));
            continue;
        }
        // Full (un-subsampled) in-bounds window per neuron, for classifying
        // a bad tap as out-of-window vs. wrong-subset.
        let full = {
            let mut gg = g.clone();
            gg.window_fanin = gg.window();
            gg.mask_rows()
        };
        for (o, nr) in layer.neurons.iter().enumerate() {
            let win = &full[o];
            let mut bad_rf = false;
            for &j in &nr.inputs {
                if j >= g.in_f() || !win.contains(&j) {
                    findings.push(finding(
                        CONV_RF_OUT_OF_RANGE,
                        Span::Neuron(li, o),
                        format!(
                            "input {j} is outside the receptive field of output pixel \
                             ({}, {}) channel {}",
                            o / g.c_out / g.h_out,
                            (o / g.c_out) % g.h_out,
                            o % g.c_out
                        ),
                    ));
                    bad_rf = true;
                }
            }
            if !bad_rf && nr.inputs != expect[o] {
                findings.push(finding(
                    CONV_WINDOW_INCONSISTENT,
                    Span::Neuron(li, o),
                    format!(
                        "kept taps {:?} differ from the channel-{}-shared subset {:?}",
                        nr.inputs,
                        o % g.c_out,
                        expect[o]
                    ),
                ));
            }
        }
    }
    let report = LintReport { findings };
    if crate::obs::enabled() {
        crate::obs::add("synth.lint.errors.count", report.errors() as u64);
        crate::obs::add("synth.lint.warns.count", report.warnings() as u64);
    }
    Ok(report)
}

fn first_duplicate(inputs: &[Net]) -> Option<(usize, usize)> {
    for (a, &na) in inputs.iter().enumerate() {
        for (boff, &nb) in inputs[a + 1..].iter().enumerate() {
            if na == nb {
                return Some((a, a + 1 + boff));
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::netlist::{BramNeuron, LutNode};

    fn clean_netlist() -> Netlist {
        // n0 = AND(in0, in1); n1 = OR(n0, in2); all metadata truthful.
        Netlist {
            num_inputs: 3,
            nodes: vec![
                LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b1000, level: 1 },
                LutNode { inputs: vec![Net::Node(0), Net::Input(2)], tt: 0b1110, level: 2 },
            ],
            outputs: vec![Net::Node(1)],
            brams: vec![],
            layer_depths: vec![2],
        }
    }

    fn ids(report: &LintReport) -> Vec<&'static str> {
        report.findings.iter().map(|f| f.rule.id).collect()
    }

    #[test]
    fn registry_is_consistent() {
        assert_eq!(RULES.len(), 17);
        for (i, r) in RULES.iter().enumerate() {
            assert!(!r.id.is_empty() && !r.desc.is_empty(), "rule {i}");
            assert!(r.id.chars().all(|c| c.is_ascii_lowercase() || c == '-'), "{}", r.id);
            for other in &RULES[i + 1..] {
                assert_ne!(r.id, other.id, "duplicate rule id");
            }
        }
        assert!(Severity::Info < Severity::Warn && Severity::Warn < Severity::Error);
    }

    #[test]
    fn clean_netlist_has_no_findings() {
        for opt in [OptLevel::None, OptLevel::Structural, OptLevel::Full] {
            let report = lint_netlist(&clean_netlist(), &LintOptions::at(opt));
            assert!(report.is_clean(), "opt {}: {}", opt.name(), report.render());
        }
        assert!(evaluability_errors(&clean_netlist()).is_empty());
    }

    #[test]
    fn reference_rules_fire() {
        let mut nl = clean_netlist();
        nl.nodes[0].inputs[0] = Net::Input(99);
        nl.nodes[1].inputs[0] = Net::Node(1); // self-reference
        nl.outputs.push(Net::Node(42));
        let report = lint_netlist(&nl, &LintOptions::default());
        let got = ids(&report);
        assert!(got.contains(&"input-out-of-range"), "{got:?}");
        assert!(got.contains(&"forward-reference"), "{got:?}");
        assert!(got.contains(&"node-out-of-range"), "{got:?}");
        // Same three findings are the evaluability preconditions.
        assert_eq!(evaluability_errors(&nl).len(), 3);
        // Reference-chasing rules must have been skipped, not panicked.
        assert!(!got.contains(&"stale-level"));
    }

    #[test]
    fn fanin_and_tt_rules_fire() {
        let mut nl = clean_netlist();
        nl.nodes[0].inputs = vec![Net::Input(0); 7];
        let report = lint_netlist(&nl, &LintOptions::default());
        let got = ids(&report);
        assert!(got.contains(&"fanin-too-wide"), "{got:?}");
        assert!(got.contains(&"duplicate-input"), "{got:?}");

        let mut nl = clean_netlist();
        nl.nodes[0].tt |= 1u64 << 4; // k=2 => entries end at bit 3
        let report = lint_netlist(&nl, &LintOptions::default());
        assert!(ids(&report).contains(&"tt-garbage"), "{}", report.render());
        // Garbage bits are a Warn: the netlist still evaluates.
        assert_eq!(report.errors(), 0);
        assert!(evaluability_errors(&nl).is_empty());
    }

    #[test]
    fn degenerate_lut_rules_fire() {
        let mut nl = clean_netlist();
        nl.nodes[1].tt = 0; // const 0
        let report = lint_netlist(&nl, &LintOptions::default());
        assert!(ids(&report).contains(&"const-lut"), "{}", report.render());

        let mut nl = clean_netlist();
        nl.nodes[1] = LutNode { inputs: vec![Net::Node(0)], tt: 0b10, level: 2 };
        let report = lint_netlist(&nl, &LintOptions::default());
        assert!(ids(&report).contains(&"wire-lut"), "{}", report.render());

        let mut nl = clean_netlist();
        nl.nodes[1].tt = 0b1010; // depends only on fan-in 0
        let report = lint_netlist(&nl, &LintOptions::default());
        assert!(ids(&report).contains(&"vacuous-input"), "{}", report.render());
    }

    #[test]
    fn metadata_rules_fire() {
        let mut nl = clean_netlist();
        nl.nodes[0].level = 5;
        let report = lint_netlist(&nl, &LintOptions::default());
        assert!(ids(&report).contains(&"stale-level"), "{}", report.render());

        let mut nl = clean_netlist();
        nl.layer_depths = vec![1];
        let report = lint_netlist(&nl, &LintOptions::default());
        assert!(ids(&report).contains(&"layer-depths-understate"), "{}", report.render());

        let mut nl = clean_netlist();
        nl.outputs.clear();
        let report = lint_netlist(&nl, &LintOptions::default());
        assert!(ids(&report).contains(&"empty-outputs"), "{}", report.render());
        assert_eq!(report.errors(), 1);
        // ... but an empty-output netlist is still evaluable (sim tests
        // rely on compiling one).
        assert!(evaluability_errors(&nl).is_empty());
    }

    #[test]
    fn dead_lut_gated_on_opt_level() {
        let mut nl = clean_netlist();
        nl.nodes.push(LutNode { inputs: vec![Net::Input(2)], tt: 0b01, level: 1 });
        let relaxed = lint_netlist(&nl, &LintOptions::at(OptLevel::None));
        assert!(!ids(&relaxed).contains(&"dead-lut"), "{}", relaxed.render());
        for opt in [OptLevel::Structural, OptLevel::Full] {
            let strict = lint_netlist(&nl, &LintOptions::at(opt));
            assert!(ids(&strict).contains(&"dead-lut"), "{}", strict.render());
        }
    }

    #[test]
    fn bram_rules_fire() {
        let mut nl = clean_netlist();
        // 14x2 bits = 32768 bits = 2 blocks of 18Kb, not 1.
        nl.brams.push(BramNeuron::opaque(14, 2, 1));
        let report = lint_netlist(&nl, &LintOptions::default());
        let got = ids(&report);
        assert!(got.contains(&"bram-shape"), "{got:?}");
        assert!(got.contains(&"bram-ports"), "{got:?}");
        assert_eq!(report.infos(), 1);

        let mut nl = clean_netlist();
        nl.brams.push(BramNeuron::opaque(14, 2, 2));
        let report = lint_netlist(&nl, &LintOptions::default());
        assert!(!ids(&report).contains(&"bram-shape"), "{}", report.render());
        assert_eq!(report.errors(), 0);
        assert_eq!(report.infos(), 1);
        // Opaque ports stay out of the evaluability subset entirely.
        assert!(evaluability_errors(&nl).is_empty());
    }

    /// A coherent content-bearing BRAM between LUT levels: 2-bit address
    /// from Node(0)/Input(2), pseudo outputs Input(3)/Input(4).
    fn bram_netlist() -> Netlist {
        let mut nl = clean_netlist();
        nl.num_inputs = 5;
        nl.nodes[1].inputs = vec![Net::Input(3), Net::Input(4)];
        nl.brams.push(BramNeuron {
            in_bits: 2,
            out_bits: 2,
            blocks: 1,
            inputs: vec![Net::Node(0), Net::Input(2)],
            out_base: 3,
            content: vec![0, 3, 1, 2],
        });
        nl
    }

    #[test]
    fn bram_evaluability_rules_fire() {
        let nl = bram_netlist();
        assert!(nl.brams_evaluable());
        assert!(evaluability_errors(&nl).is_empty(), "clean bram netlist");
        let report = lint_netlist(&nl, &LintOptions::at(OptLevel::None));
        assert_eq!(report.errors(), 0, "{}", report.render());
        assert_eq!(report.infos(), 1);

        // Content length disagreeing with in_bits: shape error.
        let mut nl = bram_netlist();
        nl.brams[0].content.pop();
        let errs = evaluability_errors(&nl);
        assert!(errs.iter().any(|f| f.rule.id == "bram-shape"), "{errs:?}");

        // Pseudo outputs spilling past the input bus: shape error.
        let mut nl = bram_netlist();
        nl.brams[0].out_base = 4;
        let errs = evaluability_errors(&nl);
        assert!(errs.iter().any(|f| f.rule.id == "bram-shape"), "{errs:?}");

        // Address reading its own pseudo output: forward reference.
        let mut nl = bram_netlist();
        nl.brams[0].inputs[1] = Net::Input(3);
        let errs = evaluability_errors(&nl);
        assert!(errs.iter().any(|f| f.rule.id == "forward-reference"), "{errs:?}");

        // A node consuming the pseudo before the BRAM's trigger (the
        // address needs Node(0), so node 0 itself must not read it).
        let mut nl = bram_netlist();
        nl.nodes[0].inputs[1] = Net::Input(3);
        let errs = evaluability_errors(&nl);
        assert!(errs.iter().any(|f| f.rule.id == "forward-reference"), "{errs:?}");
    }

    #[test]
    fn conv_model_rules_fire_and_clean_passes() {
        use crate::runtime::Manifest;
        use crate::sparsity::prune::PruneMethod;
        use crate::train::ModelState;

        let man = Manifest::synthetic_conv(
            "lint_c", "jets", 4, 1, 5, &[3], 3, "dense", Some(4), None, &[8], 3, 2,
        )
        .unwrap();
        let st = ModelState::init(&man, 3, PruneMethod::APriori);
        let model = crate::nn::ExportedModel::from_state(&man, &st);
        let clean = lint_conv_model(&man, &model).unwrap();
        assert!(clean.is_clean(), "{}", clean.render());

        // Corrupt one tap to a different *in-window* index not in the kept
        // subset: shared-window consistency violated.
        let g = &man.conv_geoms().unwrap()[0];
        let full = {
            let mut gg = g.clone();
            gg.window_fanin = gg.window();
            gg.mask_rows()
        };
        let mut tampered = model.clone();
        // interior neuron: full window in-bounds, kept subset is proper
        let o = (g.h_out + 1) * g.c_out;
        let kept: &Vec<usize> = &tampered.layers[0].neurons[o].inputs;
        let substitute = *full[o].iter().find(|j| !kept.contains(j)).expect("spare tap");
        tampered.layers[0].neurons[o].inputs[0] = substitute;
        tampered.layers[0].neurons[o].inputs.sort_unstable();
        let report = lint_conv_model(&man, &tampered).unwrap();
        assert_eq!(report.errors(), 1, "{}", report.render());
        assert_eq!(report.findings[0].rule.id, "conv-window-inconsistent");
        assert!(matches!(report.findings[0].span, Span::Neuron(0, n) if n == o));

        // An index outside the receptive field entirely: RF range error.
        let mut out_of_rf = model.clone();
        out_of_rf.layers[0].neurons[0].inputs[0] = g.in_f() - 1; // corner RF can't reach it
        out_of_rf.layers[0].neurons[0].inputs.sort_unstable();
        let report = lint_conv_model(&man, &out_of_rf).unwrap();
        assert!(report.errors() >= 1, "{}", report.render());
        assert!(report.findings.iter().any(|f| f.rule.id == "conv-rf-out-of-range"));

        // MLP manifests trivially lint clean (no conv layers to check).
        let mlp = Manifest::synthetic_mlp("m", "jets", 16, 5, &[8], 3, 2);
        let mst = ModelState::init(&mlp, 1, PruneMethod::APriori);
        let mmodel = crate::nn::ExportedModel::from_state(&mlp, &mst);
        assert!(lint_conv_model(&mlp, &mmodel).unwrap().is_clean());
    }

    #[test]
    fn json_emit_round_trips() {
        let mut nl = clean_netlist();
        nl.nodes[0].level = 9;
        nl.outputs.push(Net::Node(42));
        let report = lint_netlist(&nl, &LintOptions::default());
        let text = report.to_json().to_string();
        let parsed = Json::parse(&text).expect("lint JSON must parse");
        assert_eq!(parsed.req_usize("errors").unwrap(), report.errors());
        assert_eq!(parsed.req_usize("warnings").unwrap(), report.warnings());
        let arr = parsed.req("findings").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), report.findings.len());
        for (j, f) in arr.iter().zip(&report.findings) {
            assert_eq!(j.req_str("rule").unwrap(), f.rule.id);
            assert_eq!(j.req_str("severity").unwrap(), f.rule.severity.name());
        }
        // Render names every finding and the summary line.
        let rendered = report.render();
        assert!(rendered.contains("node-out-of-range") && rendered.contains("error(s)"));
    }
}
