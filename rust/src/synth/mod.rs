//! Logic-synthesis simulator (the Vivado substitution; DESIGN.md
//! §Hardware-Adaptation).
//!
//! Pipeline: truth tables → two-level minimization (`cover`) → technology
//! mapping onto 6-input LUTs with structural hashing (`mapper`) → netlist
//! with static timing (`netlist`) → resource report, with equivalence
//! checking against the truth-table path running through the bitsliced
//! simulator (`crate::sim`, 64 samples per word).  Reproduces the shape
//! of the paper's Tables 5.2/5.3: synthesized LUT counts are a fraction of
//! the analytical bound, WNS degrades as fan-in bits grow, and wide-fan-in
//! neurons spill into BRAMs.

pub mod boolfn;
pub mod complexity;
pub mod cover;
pub mod lint;
pub mod mapper;
pub mod netlist;
pub mod opt;

use crate::luts::ModelTables;
use crate::nn::ExportedModel;
use crate::obs;
use anyhow::{ensure, Result};
pub use boolfn::BoolFn;
pub use lint::{lint_conv_model, lint_netlist, LintOptions, LintReport};
pub use mapper::Mapper;
pub use netlist::{BramNeuron, LutNode, Net, Netlist, period_for_depth};
pub use opt::OptLevel;

#[derive(Debug, Clone, Copy)]
pub struct SynthOpts {
    /// Registers at input and between layers (Fig. 5.1).  Affects FF count
    /// and the timing model (per-stage vs whole-cone critical path).
    pub registers: bool,
    /// Target clock in ns (paper used 5 ns).
    pub clock_ns: f64,
    /// Neurons with at least this many truth-table input bits are mapped to
    /// BRAM instead of LUTs (0 disables BRAM mapping).
    pub bram_min_bits: usize,
    /// Netlist optimization level (DESIGN.md §Netlist-Optimization): the
    /// CSE + constant/dead-sweep pipeline over the mapped netlist, and at
    /// `Full` additionally reachable-code don't-care pruning at map time.
    pub opt: OptLevel,
}

impl Default for SynthOpts {
    fn default() -> Self {
        SynthOpts { registers: true, clock_ns: 5.0, bram_min_bits: 13, opt: OptLevel::None }
    }
}

#[derive(Debug, Clone)]
pub struct SynthReport {
    pub luts: usize,
    pub ffs: usize,
    pub brams: usize,
    pub dsps: usize,
    pub depth: u32,
    pub min_period_ns: f64,
    pub wns_ns: f64,
    pub analytical_luts: u64,
    /// analytical / synthesized (the paper's "Reduction" column, T5.2).
    pub reduction: f64,
    /// LUTs the mapper produced before the optimization pipeline ran
    /// (equals `luts` when `SynthOpts::opt` is `OptLevel::None`).
    pub pre_opt_luts: usize,
    /// pre-opt / post-opt LUT ratio (1.0 when optimization is off or the
    /// pipeline changed nothing).
    pub opt_reduction: f64,
    /// CSE+sweep rounds the pipeline ran to reach its fixed point.
    pub opt_rounds: usize,
    /// Layers included in the netlist (sparse layers only).
    pub layers: Vec<usize>,
}

/// Synthesize every table-mapped (sparse) layer of the model into one LUT
/// netlist.  Dense heads stay arithmetic (costed by eq. 4.1) exactly as in
/// the paper's tool-flow.
pub fn synthesize(
    model: &ExportedModel,
    tables: &ModelTables,
    opts: SynthOpts,
) -> Result<(Netlist, SynthReport)> {
    let _span = obs::Span::named("synth.synthesize.ns");
    obs::inc("synth.netlists.count");
    let emitted: Vec<usize> = tables
        .layers
        .iter()
        .enumerate()
        .filter(|(_, t)| t.is_some())
        .map(|(i, _)| i)
        .collect();
    ensure!(!emitted.is_empty(), "no sparse layers to synthesize");
    // Skip wiring re-consumes earlier activations by *act index*, so the
    // emitted layers must be the contiguous prefix starting at layer 0
    // (which every skip manifest's sparse-hidden + dense-head layout is).
    if model.skips > 0 {
        ensure!(
            emitted.iter().enumerate().all(|(k, &li)| k == li),
            "skip wiring requires a contiguous table-mapped prefix from layer 0"
        );
    }
    // Bit-level nets of each activation (input + each emitted layer).
    let first = emitted[0];
    let in_bw = tables.layers[first].as_ref().unwrap().quant_in.bw;
    let in_bus = model.layers[first].in_f * in_bw;
    let mut mapper = Mapper::new(in_bus);

    let mut acts_nets: Vec<Vec<Net>> =
        vec![(0..in_bus as u32).map(Net::Input).collect()];
    let mut layer_depths: Vec<u32> = Vec::new();
    let mut analytical: u64 = 0;

    // Reachable-code tracking for don't-care pruning (OptLevel::Full).
    // `acts_masks` parallels `acts_nets`: one producible-code bitmask per
    // neuron/feature of each activation, or `None` when tracking is off
    // for that activation (wide codes).  Gated to skip-free models whose
    // tables all stay under the BRAM threshold: BRAM-carrying netlists
    // skip the optimization pipeline (and its equivalence re-check), so a
    // don't-care rewrite would ship unverified, while a merely *enabled*
    // threshold that nothing reaches (the CLI default) must not downgrade
    // the requested level.
    let will_spill = opts.bram_min_bits > 0
        && emitted.iter().any(|&li| {
            let lt = tables.layers[li].as_ref().unwrap();
            lt.tables.iter().any(|t| t.in_bits >= opts.bram_min_bits)
        });
    let track_dc = opts.opt.dont_cares() && model.skips == 0 && !will_spill;
    let mut acts_masks: Vec<Option<Vec<u64>>> = vec![if track_dc
        && in_bw <= opt::DC_MAX_CODE_BITS
    {
        Some(vec![opt::full_code_mask(in_bw); model.layers[first].in_f])
    } else {
        None
    }];

    for (k, &li) in emitted.iter().enumerate() {
        let lt = tables.layers[li].as_ref().unwrap();
        let layer = &model.layers[li];
        let bw = lt.quant_in.bw;
        // Input nets with skip wiring (newest-first concat, bit level).
        let inp_nets: Vec<Net> = if li == 0 || model.skips == 0 {
            acts_nets.last().unwrap().clone()
        } else {
            let lo = li.saturating_sub(model.skips);
            let mut v = Vec::new();
            for j in (lo..acts_nets.len()).rev() {
                v.extend_from_slice(&acts_nets[j]);
            }
            v
        };
        ensure!(
            inp_nets.len() == layer.in_f * bw,
            "layer {li}: net bus {} != in_f {} * bw {bw}",
            inp_nets.len(),
            layer.in_f
        );
        let base_level: u32 = inp_nets
            .iter()
            .map(|&n| mapper.netlist.level_of(n))
            .max()
            .unwrap_or(0);
        // Producible-code masks of this layer's input positions (aligned
        // with the bit-group order of `inp_nets`); `None` disables pruning
        // for this layer.
        let inp_masks: Option<&Vec<u64>> = acts_masks
            .last()
            .and_then(|m| m.as_ref())
            .filter(|ms| track_dc && ms.len() == layer.in_f);
        // Masks this layer's neurons produce, for the next layer's pruning.
        // A layer whose codes are too wide drops out of tracking entirely.
        let mut out_masks: Option<Vec<u64>> =
            (track_dc && lt.quant_out.bw <= opt::DC_MAX_CODE_BITS).then(Vec::new);
        let mut layer_out: Vec<Net> = Vec::with_capacity(lt.tables.len() * lt.quant_out.bw);
        for (nj, table) in lt.tables.iter().enumerate() {
            let nr = &layer.neurons[nj];
            analytical += crate::cost::lut_cost(table.in_bits, table.out_bits);
            // Gather the neuron's input nets in pack_index order.
            let nets: Vec<Net> = nr
                .inputs
                .iter()
                .flat_map(|&j| (0..bw).map(move |b| (j, b)))
                .map(|(j, b)| inp_nets[j * bw + b])
                .collect();
            if opts.bram_min_bits > 0 && table.in_bits >= opts.bram_min_bits {
                // Spill to BRAM: 18Kb blocks.  The record keeps its address
                // wiring and full table content, so simulators fire it in
                // place (scalar eval, the 64-way oracle and the wide
                // `EvalPlan` all schedule it by `Netlist::bram_triggers`).
                let bits = (1u64 << table.in_bits) * table.out_bits as u64;
                let blocks = bits.div_ceil(18 * 1024) as usize;
                let out_base = mapper.netlist.num_inputs as u32;
                mapper.netlist.brams.push(BramNeuron {
                    in_bits: table.in_bits,
                    out_bits: table.out_bits,
                    blocks,
                    inputs: nets,
                    out_base,
                    content: (0..table.num_entries()).map(|e| table.lookup(e)).collect(),
                });
                // BRAM outputs behave like registered ports: fresh pseudo
                // inputs, overwritten by the evaluators once the address
                // operands are available.
                for _ in 0..table.out_bits {
                    let id = mapper.netlist.num_inputs as u32;
                    mapper.netlist.num_inputs += 1;
                    layer_out.push(Net::Input(id));
                }
                if let Some(om) = out_masks.as_mut() {
                    // A memory port can emit any code.
                    om.push(opt::full_code_mask(table.out_bits));
                }
                continue;
            }
            // Reachable-code don't-cares: truth-table entries whose input
            // codes the previous layer can never produce.  `None` when the
            // whole entry space is reachable (e.g. the first layer).
            let care: Option<BoolFn> = match inp_masks {
                Some(ms) if table.in_bits <= opt::DC_MAX_TABLE_BITS => {
                    let src: Vec<u64> = nr.inputs.iter().map(|&j| ms[j]).collect();
                    // All sources unconstrained (e.g. the first layer):
                    // the care set would be constant-true, so skip the
                    // 2^in_bits enumeration outright.
                    if src.iter().all(|&m| m == opt::full_code_mask(bw)) {
                        None
                    } else {
                        let c = opt::care_fn(&src, bw);
                        if c.is_const() == Some(true) {
                            None
                        } else {
                            Some(c)
                        }
                    }
                }
                _ => None,
            };
            for bit in 0..table.out_bits {
                let f = BoolFn::new(table.in_bits, table.output_bit_fn(bit));
                let f = match &care {
                    Some(c) => opt::dc_simplify(&f, c),
                    None => f,
                };
                layer_out.push(mapper.map_fn(&f, &nets));
            }
            match out_masks.as_mut() {
                Some(om) if table.in_bits <= opt::DC_MAX_TABLE_BITS => {
                    let img = match &care {
                        Some(c) => opt::reachable_image(table, c),
                        None => opt::table_image(table),
                    };
                    om.push(img);
                }
                // Table too wide to enumerate: over-approximate.
                Some(om) => om.push(opt::full_code_mask(table.out_bits)),
                None => {}
            }
        }
        let out_level: u32 = layer_out
            .iter()
            .map(|&n| mapper.netlist.level_of(n))
            .max()
            .unwrap_or(base_level);
        layer_depths.push(out_level.saturating_sub(base_level));
        acts_nets.push(layer_out);
        acts_masks.push(out_masks);
    }

    // Registered-flow FF model (Fig. 5.1): activation slot j is registered
    // at the entry of every stage that consumes it — stages
    // j ..= min(j + skips, S-1) — so a skip-consumed activation is
    // re-registered once per extra stage it rides through the pipeline.
    // With skips == 0 this reduces to the classic count: the input bus
    // plus each intermediate layer output once (the last stage's output
    // leaves the netlist combinationally, as does the output bus's
    // earlier-activation share for skip models).
    let ff_bits: usize = if opts.registers {
        let s_last = emitted.len() - 1;
        acts_nets[..emitted.len()]
            .iter()
            .enumerate()
            .map(|(j, nets)| ((j + model.skips).min(s_last) - j + 1) * nets.len())
            .sum()
    } else {
        0
    };

    // Output bus: the last emitted layer's codes — or, with skip wiring
    // feeding a later (dense) layer, the full newest-first concat bus that
    // layer consumes (`output_bus_acts`), so every downstream surface
    // (verifiers, `serve::NetlistEngine`) can evaluate the model end to
    // end without re-entering the netlist for earlier activations.
    let mut outputs: Vec<Net> = Vec::new();
    for &j in &output_bus_acts(model, &emitted) {
        outputs.extend_from_slice(&acts_nets[j]);
    }

    mapper.netlist.outputs = outputs;
    mapper.netlist.layer_depths = layer_depths.clone();
    let pre_netlist = mapper.netlist;
    let pre_opt_luts = pre_netlist.num_luts();

    // Netlist optimization pipeline (CSE + constant/dead sweep to a fixed
    // point), then machine-check the result with the bitsliced simulator.
    let (netlist, opt_stats) = if opts.opt.structural() && pre_netlist.brams.is_empty() {
        let (optimized, stats) = opt::optimize(&pre_netlist, opts.opt);
        // The pipeline output must match the unoptimized netlist over the
        // primary-input space (exhaustive for small buses, a deterministic
        // 4096-sample sweep otherwise).
        ensure!(
            opt::netlists_equivalent(&pre_netlist, &optimized, 0x0D0C_5EED),
            "netlist optimization changed circuit behavior"
        );
        // And match the truth-table forward pass (the checkers walk the
        // same newest-first skip-concat wiring the mapper does, so skip
        // models are covered too).
        let mism = if optimized.num_inputs <= 16 {
            verify_netlist_exhaustive(model, tables, &optimized)?
        } else {
            verify_netlist(model, tables, &optimized, 2048, 0x0D0C_5EED)?
        };
        ensure!(
            mism == 0,
            "optimized netlist diverged from the truth tables ({mism} mismatches)"
        );
        (optimized, stats)
    } else {
        // Optimization off (or BRAM records present — the structural
        // optimizer rewrites LUT cones only and would not preserve BRAM
        // address wiring): the mapped netlist ships as-is.
        let stats = opt::OptStats {
            pre_luts: pre_opt_luts,
            post_luts: pre_opt_luts,
            ..opt::OptStats::default()
        };
        (pre_netlist, stats)
    };

    // Structural design-rule gate: no synthesized netlist ships with an
    // Error-severity finding (dangling/forward references, wide fan-in,
    // missing outputs, inconsistent BRAM accounting).  The effective opt
    // level tells lint whether redundancy rules like dead-LUT apply —
    // BRAM-carrying netlists skip the pipeline above, so they are judged
    // at `None` regardless of what the caller asked for.
    let lint_opts = lint::LintOptions {
        opt: if opts.opt.structural() && netlist.brams.is_empty() {
            opts.opt
        } else {
            OptLevel::None
        },
    };
    let lint_report = lint::lint_netlist(&netlist, &lint_opts);
    ensure!(
        lint_report.errors() == 0,
        "synthesized netlist fails structural design rules:\n{}",
        lint_report.render()
    );

    // Per-layer depths are measured during mapping; optimization can only
    // shorten cones, so for registered timing they are a (tight in
    // practice) upper bound.  Combinational depth is recomputed from the
    // optimized netlist.
    let depth = if opts.registers {
        layer_depths.iter().copied().max().unwrap_or(0)
    } else {
        netlist.depth()
    };
    let min_period = period_for_depth(depth.max(1));
    let luts = netlist.num_luts();
    let report = SynthReport {
        luts,
        ffs: ff_bits,
        brams: netlist.num_brams(),
        dsps: 0,
        depth,
        min_period_ns: min_period,
        wns_ns: opts.clock_ns - min_period,
        analytical_luts: analytical,
        reduction: analytical as f64 / luts.max(1) as f64,
        pre_opt_luts,
        opt_reduction: opt_stats.reduction(),
        opt_rounds: opt_stats.rounds,
        layers: emitted,
    };
    Ok((netlist, report))
}

/// The single source of truth for the netlist's output-bus layout:
/// activation slots to emit, newest first.  Without skip wiring (or when
/// every layer is table-mapped) the bus is the last emitted layer's
/// output — slot `emitted.len()`.  With skip wiring and a following
/// (dense) layer, the bus is every activation that layer consumes —
/// act indices `(head-skips ..= head)` newest-first, where
/// `head = last+1` (valid because skip support requires the emitted
/// prefix to be contiguous from layer 0, so slot and act index agree).
/// `synthesize` wires the bus from this, the verifiers reproduce it from
/// the truth tables, and `NetlistEngine` sizes its decode from it.
pub(crate) fn output_bus_acts(model: &ExportedModel, emitted: &[usize]) -> Vec<usize> {
    let last = *emitted.last().expect("at least one emitted layer");
    if model.skips > 0 && last + 1 < model.num_layers() {
        let head = last + 1;
        let lo = head.saturating_sub(model.skips);
        (lo..=head).rev().collect()
    } else {
        vec![emitted.len()]
    }
}

/// Indices of the table-mapped (sparse) layers, plus the shared
/// preconditions every netlist-executing surface needs (equivalence
/// checkers here, `serve::NetlistEngine` for serving): every BRAM record
/// content-bearing (opaque ports are not evaluable), at least one emitted
/// layer, and — for skip wiring — a contiguous prefix from layer 0 with
/// one uniform code width (the bus the skip concat interleaves).  Returns
/// the emitted layer indices, the first emitted layer's tables, and the
/// output code width.
pub(crate) fn verify_plan<'a>(
    model: &ExportedModel,
    tables: &'a ModelTables,
    netlist: &Netlist,
) -> Result<(Vec<usize>, &'a crate::luts::LayerTables, usize)> {
    ensure!(
        netlist.brams_evaluable(),
        "netlist carries opaque (content-less) BRAM ports and is not evaluable"
    );
    let emitted: Vec<usize> = tables
        .layers
        .iter()
        .enumerate()
        .filter(|(_, t)| t.is_some())
        .map(|(i, _)| i)
        .collect();
    ensure!(!emitted.is_empty(), "no table-mapped layers to verify");
    let last = *emitted.last().unwrap();
    let out_bw = tables.layers[last].as_ref().unwrap().quant_out.bw;
    if model.skips > 0 {
        ensure!(
            emitted.iter().enumerate().all(|(k, &li)| k == li),
            "skip wiring requires a contiguous table-mapped prefix from layer 0"
        );
        for &li in &emitted {
            let lt = tables.layers[li].as_ref().unwrap();
            ensure!(
                lt.quant_in.bw == out_bw && lt.quant_out.bw == out_bw,
                "skip wiring requires a uniform code width (layer {li})"
            );
        }
    }
    let lt_first = tables.layers[emitted[0]].as_ref().unwrap();
    Ok((emitted, lt_first, out_bw))
}

/// Table-path reference: propagate one sample's input codes through the
/// emitted sparse layers with newest-first skip-concat wiring, producing
/// the codes of the netlist's output bus — the last emitted layer's codes,
/// or, with skip wiring and a following dense layer, the concat bus that
/// layer consumes (mirroring `synthesize`'s output-bus rule).  All buffers
/// are caller-owned and reused across samples; the result lands in `out`.
fn table_forward_codes(
    model: &ExportedModel,
    tables: &ModelTables,
    emitted: &[usize],
    input: &[u32],
    acts: &mut Vec<Vec<u32>>,
    concat: &mut Vec<u32>,
    gathered: &mut Vec<u32>,
    out: &mut Vec<u32>,
) {
    if acts.len() < emitted.len() + 1 {
        acts.resize_with(emitted.len() + 1, Vec::new);
    }
    acts[0].clear();
    acts[0].extend_from_slice(input);
    for (k, &li) in emitted.iter().enumerate() {
        let lt = tables.layers[li].as_ref().unwrap();
        // With skips > 0 the emitted prefix is contiguous (verify_plan), so
        // position k equals act index li and history indexing is direct.
        concat.clear();
        if li == 0 || model.skips == 0 {
            concat.extend_from_slice(&acts[k]);
        } else {
            let lo = li.saturating_sub(model.skips);
            for j in (lo..=li).rev() {
                concat.extend_from_slice(&acts[j]);
            }
        }
        let mut next = std::mem::take(&mut acts[k + 1]);
        next.clear();
        for (nj, t) in lt.tables.iter().enumerate() {
            let nr = &model.layers[li].neurons[nj];
            gathered.clear();
            gathered.extend(nr.inputs.iter().map(|&j| concat[j]));
            next.push(t.lookup(crate::util::bits::pack_index(gathered, lt.quant_in.bw)));
        }
        acts[k + 1] = next;
    }
    out.clear();
    for &j in &output_bus_acts(model, emitted) {
        out.extend_from_slice(&acts[j]);
    }
}

/// Equivalence check: run `samples` random input vectors through both the
/// truth-table forward and the synthesized netlist; returns mismatches.
/// The netlist side is one bitsliced pass over the whole batch (64 samples
/// per word, `crate::sim`); [`verify_netlist_scalar`] keeps the original
/// one-sample-at-a-time path for cross-checking the simulator itself.
/// Neurons spilled to BRAM are fine — their records carry content and the
/// simulators fire them in place; only opaque BRAM ports are rejected.
pub fn verify_netlist(
    model: &ExportedModel,
    tables: &ModelTables,
    netlist: &Netlist,
    samples: usize,
    seed: u64,
) -> Result<usize> {
    let (emitted, lt_first, out_bw) = verify_plan(model, tables, netlist)?;
    let bw_in = lt_first.quant_in.bw;
    let in_f = model.layers[emitted[0]].in_f;
    // Draw all random input codes up front (same RNG stream order as the
    // scalar checker: sample-major, then feature) and encode them as
    // bit-planes.
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut codes = vec![0u32; samples * in_f];
    for c in codes.iter_mut() {
        *c = rng.below(1 << bw_in) as u32;
    }
    let mut inputs = crate::sim::BitMatrix::new(netlist.num_inputs, samples);
    for s in 0..samples {
        for j in 0..in_f {
            inputs.set_code(j * bw_in, bw_in, s, codes[s * in_f + j]);
        }
    }
    let out = crate::sim::eval_netlist(netlist, &inputs);
    let (mut acts, mut concat, mut gathered, mut expect) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let mut mismatches = 0usize;
    for s in 0..samples {
        table_forward_codes(
            model,
            tables,
            &emitted,
            &codes[s * in_f..(s + 1) * in_f],
            &mut acts,
            &mut concat,
            &mut gathered,
            &mut expect,
        );
        debug_assert_eq!(expect.len() * out_bw, netlist.outputs.len());
        let ok = expect
            .iter()
            .enumerate()
            .all(|(k, &c)| out.get_code(k * out_bw, out_bw, s) == c);
        if !ok {
            mismatches += 1;
        }
    }
    Ok(mismatches)
}

/// The original scalar equivalence check (`Netlist::eval` per sample).
/// Kept as the cross-check oracle for the bitsliced path: on any inputs the
/// two must return identical mismatch counts.
pub fn verify_netlist_scalar(
    model: &ExportedModel,
    tables: &ModelTables,
    netlist: &Netlist,
    samples: usize,
    seed: u64,
) -> Result<usize> {
    let (emitted, lt_first, out_bw) = verify_plan(model, tables, netlist)?;
    let bw_in = lt_first.quant_in.bw;
    let in_f = model.layers[emitted[0]].in_f;
    let mut rng = crate::util::rng::Rng::new(seed);
    let (mut acts, mut concat, mut gathered, mut expect) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let mut mismatches = 0usize;
    for _ in 0..samples {
        // Random input codes.
        let codes: Vec<u32> = (0..in_f).map(|_| rng.below(1 << bw_in) as u32).collect();
        // Netlist input bits.
        let mut bits = vec![false; netlist.num_inputs];
        for (j, &c) in codes.iter().enumerate() {
            for b in 0..bw_in {
                bits[j * bw_in + b] = (c >> b) & 1 == 1;
            }
        }
        let net_out = netlist.eval(&bits);
        table_forward_codes(
            model,
            tables,
            &emitted,
            &codes,
            &mut acts,
            &mut concat,
            &mut gathered,
            &mut expect,
        );
        let mut expect_bits = Vec::with_capacity(expect.len() * out_bw);
        for &c in &expect {
            for b in 0..out_bw {
                expect_bits.push((c >> b) & 1 == 1);
            }
        }
        if net_out != expect_bits {
            mismatches += 1;
        }
    }
    Ok(mismatches)
}

/// Exhaustive equivalence over the *whole* primary-input space: all
/// `2^(in_f*bw)` patterns are enumerated as bit-planes (64 patterns per
/// word — `BitMatrix::all_patterns` produces exactly the netlist's input
/// bus layout, bit `j*bw+b` = bit `b` of feature `j`'s code) and checked in
/// one bitsliced pass.  Returns the number of mismatching patterns.
pub fn verify_netlist_exhaustive(
    model: &ExportedModel,
    tables: &ModelTables,
    netlist: &Netlist,
) -> Result<usize> {
    let (emitted, lt_first, out_bw) = verify_plan(model, tables, netlist)?;
    let bw_in = lt_first.quant_in.bw;
    let in_f = model.layers[emitted[0]].in_f;
    let in_bits = in_f * bw_in;
    let pseudo_bits: usize = netlist.brams.iter().map(|b| b.out_bits).sum();
    ensure!(in_bits + pseudo_bits == netlist.num_inputs, "input bus width mismatch");
    ensure!(in_bits <= 22, "exhaustive space 2^{in_bits} too large");
    let pats = crate::sim::BitMatrix::all_patterns(in_bits);
    let inputs = if pseudo_bits == 0 {
        pats
    } else {
        // BRAM pseudo planes ride along zeroed; the evaluators overwrite
        // them before anything reads them.
        let mut m = crate::sim::BitMatrix::new(netlist.num_inputs, pats.samples());
        for p in 0..in_bits {
            m.plane_mut(p).copy_from_slice(pats.plane(p));
        }
        m
    };
    let out = crate::sim::eval_netlist(netlist, &inputs);
    let mut in_codes = vec![0u32; in_f];
    let (mut acts, mut concat, mut gathered, mut expect) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let mut mismatches = 0usize;
    for idx in 0..(1usize << in_bits) {
        crate::util::bits::unpack_index(idx, bw_in, in_f, &mut in_codes);
        table_forward_codes(
            model,
            tables,
            &emitted,
            &in_codes,
            &mut acts,
            &mut concat,
            &mut gathered,
            &mut expect,
        );
        let ok = expect
            .iter()
            .enumerate()
            .all(|(k, &c)| out.get_code(k * out_bw, out_bw, idx) == c);
        if !ok {
            mismatches += 1;
        }
    }
    Ok(mismatches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
    use crate::util::rng::Rng;

    fn random_model(seed: u64, in_f: usize, widths: &[usize], fanin: usize, bw: usize) -> ExportedModel {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        let mut prev = in_f;
        for (k, &w) in widths.iter().enumerate() {
            let qi = if k == 0 { QuantSpec::new(bw, 1.0) } else { QuantSpec::new(bw, 2.0) };
            let qo = QuantSpec::new(bw, 2.0);
            let neurons = (0..w)
                .map(|_| {
                    let inputs = rng.choose_k(prev, fanin.min(prev));
                    let weights =
                        inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect();
                    Neuron { inputs, weights, bias: rng.normal_f32(0.0, 0.1), g: 1.0, h: 0.0 }
                })
                .collect();
            layers.push(ExportedLayer::uniform(neurons, prev, qi, qo, true));
            prev = w;
        }
        ExportedModel {
            layers,
            in_features: in_f,
            classes: *widths.last().unwrap(),
            skips: 0,
            act_widths: std::iter::once(in_f).chain(widths.iter().copied()).collect(),
        }
    }

    #[test]
    fn synthesized_beats_analytical() {
        let model = random_model(1, 16, &[32, 16], 3, 2);
        let tables = crate::luts::ModelTables::generate(&model).unwrap();
        let (netlist, report) =
            synthesize(&model, &tables, SynthOpts { registers: false, ..Default::default() })
                .unwrap();
        assert!(report.luts > 0);
        assert!(
            (report.luts as u64) <= report.analytical_luts,
            "synth {} > analytical {}",
            report.luts,
            report.analytical_luts
        );
        assert!(report.reduction >= 1.0);
        assert_eq!(netlist.num_brams(), 0);
    }

    #[test]
    fn netlist_equivalent_to_tables() {
        let model = random_model(2, 12, &[24, 8], 3, 2);
        let tables = crate::luts::ModelTables::generate(&model).unwrap();
        let (netlist, _) =
            synthesize(&model, &tables, SynthOpts { registers: false, ..Default::default() })
                .unwrap();
        let mism = verify_netlist(&model, &tables, &netlist, 200, 7).unwrap();
        assert_eq!(mism, 0, "netlist must be functionally identical");
    }

    /// Complement the node driving the first node-driven output: that
    /// output bit is wrong on *every* pattern, so corruption detection is
    /// deterministic regardless of sampling.
    fn corrupt(netlist: &Netlist) -> Netlist {
        let mut bad = netlist.clone();
        let node = bad
            .outputs
            .iter()
            .find_map(|o| match o {
                Net::Node(i) => Some(*i as usize),
                _ => None,
            })
            .expect("a node-driven output");
        bad.nodes[node].tt = !bad.nodes[node].tt;
        bad
    }

    #[test]
    fn bitsliced_verify_agrees_with_scalar() {
        // Identical pass/fail (and identical mismatch counts) on both a
        // correct netlist and a deliberately corrupted one — the RNG stream
        // is shared, so the two checkers see the very same samples.
        let model = random_model(9, 10, &[16, 6], 3, 2);
        let tables = crate::luts::ModelTables::generate(&model).unwrap();
        let (netlist, _) = synthesize(
            &model,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
        )
        .unwrap();
        for (label, nl) in [("clean", netlist.clone()), ("corrupt", corrupt(&netlist))] {
            for (samples, seed) in [(1usize, 1u64), (63, 2), (64, 3), (200, 4)] {
                let fast = verify_netlist(&model, &tables, &nl, samples, seed).unwrap();
                let slow = verify_netlist_scalar(&model, &tables, &nl, samples, seed).unwrap();
                assert_eq!(fast, slow, "{label}: samples={samples} seed={seed}");
            }
        }
        let mism = verify_netlist(&model, &tables, &corrupt(&netlist), 200, 4).unwrap();
        assert_eq!(mism, 200, "an inverted output cone must miss every sample");
    }

    #[test]
    fn exhaustive_verify_covers_whole_input_space() {
        // Small enough to enumerate: 6 features x 2 bits = 4096 patterns.
        let model = random_model(10, 6, &[10, 4], 3, 2);
        let tables = crate::luts::ModelTables::generate(&model).unwrap();
        let (netlist, _) = synthesize(
            &model,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
        )
        .unwrap();
        assert_eq!(verify_netlist_exhaustive(&model, &tables, &netlist).unwrap(), 0);
        assert_eq!(
            verify_netlist_exhaustive(&model, &tables, &corrupt(&netlist)).unwrap(),
            4096,
            "an inverted output cone must miss every pattern"
        );
    }

    #[test]
    fn registered_timing_uses_max_layer_depth() {
        let model = random_model(3, 16, &[32, 32, 16], 4, 2);
        let tables = crate::luts::ModelTables::generate(&model).unwrap();
        let (_, reg) =
            synthesize(&model, &tables, SynthOpts { registers: true, ..SynthOpts::default() })
                .unwrap();
        let (_, comb) =
            synthesize(&model, &tables, SynthOpts { registers: false, ..SynthOpts::default() })
                .unwrap();
        assert!(reg.depth <= comb.depth);
        assert!(reg.ffs > 0 && comb.ffs == 0);
        assert!(reg.wns_ns >= comb.wns_ns);
    }

    #[test]
    fn bram_spill_for_wide_neurons() {
        let model = random_model(4, 20, &[8], 7, 2); // 14 input bits
        let tables = crate::luts::ModelTables::generate(&model).unwrap();
        let (netlist, report) = synthesize(
            &model,
            &tables,
            SynthOpts { registers: true, bram_min_bits: 14, ..SynthOpts::default() },
        )
        .unwrap();
        assert!(report.brams > 0, "wide neurons must spill to BRAM");
        assert_eq!(report.luts, 0);
        assert!(!netlist.brams.is_empty());
        // Spilled records carry their wiring and content, so the netlist
        // stays evaluable end to end and must match the table forward.
        assert!(netlist.brams_evaluable());
        let mism = verify_netlist_scalar(&model, &tables, &netlist, 64, 7).unwrap();
        assert_eq!(mism, 0, "BRAM netlist diverged from the truth tables");
        let mism = verify_netlist(&model, &tables, &netlist, 300, 7).unwrap();
        assert_eq!(mism, 0, "bitsliced BRAM eval diverged from the truth tables");
    }

    #[test]
    fn deeper_fanin_degrades_wns() {
        let small = random_model(5, 16, &[16], 3, 2); // 6-bit tables
        let large = random_model(6, 16, &[16], 5, 2); // 10-bit tables
        let ts = crate::luts::ModelTables::generate(&small).unwrap();
        let tl = crate::luts::ModelTables::generate(&large).unwrap();
        let (_, rs) = synthesize(&small, &ts, SynthOpts::default()).unwrap();
        let (_, rl) = synthesize(&large, &tl, SynthOpts::default()).unwrap();
        assert!(rl.depth >= rs.depth);
        assert!(rl.wns_ns <= rs.wns_ns);
    }

    #[test]
    fn optimized_synthesis_stays_equivalent() {
        // Full optimization is machine-checked internally (synthesize
        // errors on divergence); here we also re-verify externally and
        // check the report wiring.
        for level in [OptLevel::Structural, OptLevel::Full] {
            let model = random_model(11, 6, &[12, 6], 3, 2); // 12-bit bus
            let tables = crate::luts::ModelTables::generate(&model).unwrap();
            let base = SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() };
            let (_, plain) = synthesize(&model, &tables, base).unwrap();
            let (netlist, rep) =
                synthesize(&model, &tables, SynthOpts { opt: level, ..base }).unwrap();
            assert_eq!(verify_netlist_exhaustive(&model, &tables, &netlist).unwrap(), 0);
            if level == OptLevel::Structural {
                // Structural levels map exactly what the plain flow maps.
                assert_eq!(rep.pre_opt_luts, plain.luts);
            }
            assert!(rep.luts <= rep.pre_opt_luts, "{level:?}");
            assert!(rep.opt_reduction >= 1.0 && rep.opt_rounds >= 1, "{level:?}");
            assert_eq!(netlist.num_luts(), rep.luts);
        }
    }

    #[test]
    fn unoptimized_report_has_identity_opt_fields() {
        let model = random_model(12, 8, &[10], 3, 2);
        let tables = crate::luts::ModelTables::generate(&model).unwrap();
        let (_, rep) = synthesize(&model, &tables, SynthOpts::default()).unwrap();
        assert_eq!(rep.pre_opt_luts, rep.luts);
        assert!((rep.opt_reduction - 1.0).abs() < 1e-12);
        assert_eq!(rep.opt_rounds, 0);
    }

    #[test]
    fn skip_model_netlist_round_trip() {
        // A trained-shape skip topology (skips=1, pyramid widths): the
        // netlist's output bus is the dense head's newest-first concat
        // input, and every checker (sampled, scalar, exhaustive) agrees
        // with the truth-table path.
        use crate::runtime::Manifest;
        use crate::sparsity::prune::PruneMethod;
        let man = Manifest::synthetic_topology("synth_skip", "jets", 8, 3, &[10, 6], 3, 2, 1);
        let st = crate::train::ModelState::init(&man, 3, PruneMethod::APriori);
        let ex = crate::nn::ExportedModel::from_state(&man, &st);
        let tables = crate::luts::ModelTables::generate(&ex).unwrap();
        let base = SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() };
        let (netlist, rep) = synthesize(&ex, &tables, base).unwrap();
        // Head input = [act_2 (6 wide), act_1 (10 wide)] at 2 bits/code.
        assert_eq!(netlist.outputs.len(), (6 + 10) * 2);
        assert_eq!(verify_netlist(&ex, &tables, &netlist, 300, 5).unwrap(), 0);
        assert_eq!(verify_netlist_scalar(&ex, &tables, &netlist, 300, 5).unwrap(), 0);
        assert_eq!(verify_netlist_exhaustive(&ex, &tables, &netlist).unwrap(), 0);
        // The optimization pipeline re-verifies internally and must still
        // hold externally.
        let (onet, orep) =
            synthesize(&ex, &tables, SynthOpts { opt: OptLevel::Full, ..base }).unwrap();
        assert!(orep.luts <= rep.luts);
        assert_eq!(verify_netlist_exhaustive(&ex, &tables, &onet).unwrap(), 0);
    }

    /// A model whose first layer saturates to the two extreme codes
    /// (`ExportedLayer::saturate_binary`): the second layer then has
    /// unreachable input patterns that only the don't-care pass can
    /// exploit (each bit of a {0,3}-valued code is individually
    /// non-constant, so the plain mapper keeps full cones).
    fn binary_activation_model(seed: u64) -> ExportedModel {
        let mut model = random_model(seed, 8, &[16, 8], 4, 2);
        model.layers[0].saturate_binary();
        model
    }

    #[test]
    fn dont_care_pruning_strictly_reduces_saturated_models() {
        let model = binary_activation_model(13);
        let tables = crate::luts::ModelTables::generate(&model).unwrap();
        let base = SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() };
        let (_, plain) = synthesize(&model, &tables, base).unwrap();
        let (netlist, full) =
            synthesize(&model, &tables, SynthOpts { opt: OptLevel::Full, ..base }).unwrap();
        assert_eq!(verify_netlist_exhaustive(&model, &tables, &netlist).unwrap(), 0);
        assert!(
            full.luts < plain.luts,
            "don't-care pruning must strictly reduce: {} vs {}",
            full.luts,
            plain.luts
        );
    }
}
