//! Netlist optimization: a fixed-point pipeline of equivalence-preserving
//! passes over the mapped [`Netlist`] (DESIGN.md §Netlist-Optimization).
//!
//! The mapper already hashes structurally while it builds, but it works one
//! boolean function at a time: it cannot see sharing that only appears
//! after another neuron's cone folds, and it cannot use cross-layer
//! reachability.  The pipeline closes that gap with three passes:
//!
//! 1. **CSE** ([`Pass::Cse`]): global structural hashing — two LUTs with
//!    the same canonical truth table over the same (rewritten) fan-in nets
//!    merge into one, across neurons and layers.
//! 2. **Sweep** ([`Pass::Sweep`]): constant propagation and dead-LUT
//!    removal — constant inputs are cofactored away, duplicate fan-in nets
//!    merged, tables that ignore an input get their support reduced,
//!    constant tables and wire-passthrough tables are replaced by their
//!    driving net, and every node unreachable from an output is dropped.
//! 3. **Reachable-code don't-care pruning** (map-time, [`care_fn`] +
//!    [`dc_simplify`]): only activation codes the previous layer can
//!    actually produce reach a neuron, so unreachable truth-table entries
//!    are don't-cares fed back into [`cover::minimize_dc`].  This runs
//!    inside `synthesize` (it needs the layer tables), before the netlist
//!    passes.
//!
//! Every pass emits a freshly renumbered netlist in topological order and
//! can only merge, shrink or drop nodes, so the LUT count is monotonically
//! non-increasing per pass and the [`optimize`] loop terminates at an
//! idempotent fixed point.  `synthesize` machine-checks the optimized
//! result against the truth-table forward pass with the bitsliced
//! simulator (exhaustively when the input bus permits).

use super::boolfn::BoolFn;
use super::cover;
use super::mapper::canonical_order;
use super::netlist::{LutNode, Net, Netlist};
use crate::obs;
use crate::sim::{eval_netlist, BitMatrix};
use std::collections::HashMap;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// How hard `synthesize` optimizes the mapped netlist.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OptLevel {
    /// No optimization: the netlist is exactly what the mapper produced.
    #[default]
    None,
    /// Netlist passes only (CSE + constant/dead sweep to a fixed point).
    Structural,
    /// Netlist passes plus reachable-code don't-care pruning at map time.
    Full,
}

impl OptLevel {
    pub fn parse(s: &str) -> Option<OptLevel> {
        match s {
            "none" | "off" | "0" => Some(OptLevel::None),
            "structural" | "struct" | "1" => Some(OptLevel::Structural),
            "full" | "2" => Some(OptLevel::Full),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OptLevel::None => "none",
            OptLevel::Structural => "structural",
            OptLevel::Full => "full",
        }
    }

    /// Run the netlist pass pipeline at all?
    pub fn structural(self) -> bool {
        !matches!(self, OptLevel::None)
    }

    /// Apply reachable-code don't-care pruning at map time?
    pub fn dont_cares(self) -> bool {
        matches!(self, OptLevel::Full)
    }
}

/// One netlist pass of the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    Cse,
    Sweep,
}

/// What [`optimize`] did, for reporting and for the monotonicity tests.
#[derive(Debug, Clone, Default)]
pub struct OptStats {
    pub pre_luts: usize,
    pub post_luts: usize,
    /// LUT count after each executed pass, in pipeline order.
    pub pass_luts: Vec<usize>,
    /// CSE+sweep rounds until the fixed point.
    pub rounds: usize,
}

impl OptStats {
    /// pre/post LUT ratio (>= 1.0; 1.0 when nothing changed).
    pub fn reduction(&self) -> f64 {
        self.pre_luts.max(1) as f64 / self.post_luts.max(1) as f64
    }
}

/// Cap on fixed-point rounds — a pure safety net: every productive round
/// strictly lowers the node count, so real inputs converge far earlier.
const MAX_ROUNDS: usize = 64;

/// Per-pass wall-time histogram, handle cached so the hot fixed-point loop
/// never takes the registry lock.
fn pass_hist(pass: Pass) -> &'static Arc<obs::Histogram> {
    static CSE: OnceLock<Arc<obs::Histogram>> = OnceLock::new();
    static SWEEP: OnceLock<Arc<obs::Histogram>> = OnceLock::new();
    match pass {
        Pass::Cse => CSE.get_or_init(|| obs::histogram("synth.pass.cse.ns")),
        Pass::Sweep => SWEEP.get_or_init(|| obs::histogram("synth.pass.sweep.ns")),
    }
}

/// Run the CSE+sweep pipeline to its fixed point.  Netlists with BRAM
/// pseudo-ports are returned unchanged: their pseudo-input wiring cannot be
/// re-verified by the simulator, and BRAM-mapped designs are never served.
pub fn optimize(netlist: &Netlist, level: OptLevel) -> (Netlist, OptStats) {
    let pre = netlist.num_luts();
    let mut stats = OptStats { pre_luts: pre, post_luts: pre, ..OptStats::default() };
    if !level.structural() || !netlist.brams.is_empty() {
        return (netlist.clone(), stats);
    }
    let mut cur = netlist.clone();
    loop {
        let t_cse = Instant::now();
        let a = run_pass(&cur, Pass::Cse);
        if obs::enabled() {
            pass_hist(Pass::Cse).record_duration(t_cse.elapsed());
        }
        stats.pass_luts.push(a.num_luts());
        let t_sweep = Instant::now();
        let b = run_pass(&a, Pass::Sweep);
        if obs::enabled() {
            pass_hist(Pass::Sweep).record_duration(t_sweep.elapsed());
        }
        stats.pass_luts.push(b.num_luts());
        stats.rounds += 1;
        let fixed = b == cur;
        cur = b;
        if fixed || stats.rounds >= MAX_ROUNDS {
            break;
        }
    }
    // Pin post-opt levels to the wiring so `Netlist::depth` /
    // `period_for_depth` report the optimized truth (the passes already
    // recompute levels while rebuilding; this keeps that an invariant
    // rather than an accident, and `lint`'s stale-level rule enforces it).
    cur.relevel();
    stats.post_luts = cur.num_luts();
    if obs::enabled() {
        obs::add(
            "synth.opt.luts_removed.count",
            stats.pre_luts.saturating_sub(stats.post_luts) as u64,
        );
        obs::add("synth.opt.rounds.count", stats.rounds as u64);
    }
    (cur, stats)
}

/// Execute one pass: rebuild the netlist in topological order, keeping only
/// nodes reachable from an output.  Both passes renumber compactly, so a
/// pass that changes nothing reproduces its input verbatim (the fixed-point
/// test in [`optimize`] relies on this).
pub fn run_pass(nl: &Netlist, pass: Pass) -> Netlist {
    let reach = reachable(nl);
    let mut out = Netlist {
        num_inputs: nl.num_inputs,
        brams: nl.brams.clone(),
        layer_depths: nl.layer_depths.clone(),
        ..Netlist::default()
    };
    let mut cache: HashMap<(u64, Vec<Net>), Net> = HashMap::new();
    // Old node id -> its replacement net in the rebuilt netlist.
    let mut map: Vec<Net> = vec![Net::Const0; nl.nodes.len()];
    for (i, node) in nl.nodes.iter().enumerate() {
        if !reach[i] {
            continue;
        }
        let inputs: Vec<Net> = node.inputs.iter().map(|&n| resolve(&map, n)).collect();
        let f = BoolFn::from_tt6(inputs.len(), node.tt);
        map[i] = match pass {
            Pass::Cse => emit_hashed(&mut out, &mut cache, &f, &inputs),
            Pass::Sweep => emit_simplified(&mut out, &mut cache, &f, &inputs),
        };
    }
    out.outputs = nl.outputs.iter().map(|&n| resolve(&map, n)).collect();
    // Every pass output must be structurally evaluable; in tests and debug
    // builds the full Error rule set gates here, so any future pass (e.g.
    // a rewrite engine) inherits the design-rule check for free.  Warns
    // are legal mid-pipeline: CSE exposes duplicate fan-ins that only the
    // following Sweep folds.
    #[cfg(any(test, debug_assertions))]
    {
        let report = super::lint::lint_netlist(&out, &super::lint::LintOptions::default());
        assert_eq!(
            report.errors(),
            0,
            "{:?} pass emitted a structurally invalid netlist:\n{}",
            pass,
            report.render()
        );
    }
    out
}

fn resolve(map: &[Net], n: Net) -> Net {
    match n {
        Net::Node(i) => map[i as usize],
        other => other,
    }
}

/// Nodes reachable from the output nets (also used by `lint`'s dead-LUT
/// rule; requires in-range node references).
pub(crate) fn reachable(nl: &Netlist) -> Vec<bool> {
    let mut reach = vec![false; nl.nodes.len()];
    let mut stack: Vec<usize> = nl
        .outputs
        .iter()
        .filter_map(|&o| match o {
            Net::Node(i) => Some(i as usize),
            _ => None,
        })
        .collect();
    while let Some(i) = stack.pop() {
        if reach[i] {
            continue;
        }
        reach[i] = true;
        for &inp in &nl.nodes[i].inputs {
            if let Net::Node(j) = inp {
                if !reach[j as usize] {
                    stack.push(j as usize);
                }
            }
        }
    }
    reach
}

/// CSE emit: canonicalize and hash, merging identical (truth table, fan-in
/// nets) pairs.  No boolean simplification beyond constant-table detection
/// (which only fires on tables an upstream sweep just folded).
fn emit_hashed(
    out: &mut Netlist,
    cache: &mut HashMap<(u64, Vec<Net>), Net>,
    f: &BoolFn,
    nets: &[Net],
) -> Net {
    if let Some(c) = f.is_const() {
        return if c { Net::Const1 } else { Net::Const0 };
    }
    let (tt, sorted) = canonical_order(f, nets);
    let key = (tt, sorted.clone());
    if let Some(&n) = cache.get(&key) {
        return n;
    }
    let level = 1 + sorted.iter().map(|&n| out.level_of(n)).max().unwrap_or(0);
    let id = out.nodes.len() as u32;
    out.nodes.push(LutNode { inputs: sorted, tt, level });
    cache.insert(key, Net::Node(id));
    Net::Node(id)
}

/// Sweep emit: fold constant inputs, merge duplicate fan-in nets, reduce
/// the support, replace constant tables and wire passthroughs, then hash.
/// Mirrors `Mapper::emit_lut`, but rebuilding an existing netlist instead
/// of mapping fresh functions.
fn emit_simplified(
    out: &mut Netlist,
    cache: &mut HashMap<(u64, Vec<Net>), Net>,
    f: &BoolFn,
    nets: &[Net],
) -> Net {
    // Fold constant inputs.
    if let Some(pos) = nets.iter().position(|n| matches!(n, Net::Const0 | Net::Const1)) {
        let val = matches!(nets[pos], Net::Const1);
        let g = f.cofactor(pos, val);
        let mut sub = nets.to_vec();
        sub.remove(pos);
        return emit_simplified(out, cache, &g, &sub);
    }
    // Merge duplicate nets (restrict to x_i == x_j).
    for i in 0..nets.len() {
        for j in (i + 1)..nets.len() {
            if nets[i] == nets[j] {
                let k = f.nvars - 1;
                let mut g = BoolFn::zeros(k);
                for idx2 in 0..(1usize << k) {
                    // Reinsert bit j equal to bit i; i < j always holds
                    // here, so position i is unshifted in the reduced index.
                    let low_mask = (1usize << j) - 1;
                    let base = (idx2 & low_mask) | ((idx2 & !low_mask) << 1);
                    let idx = base | (((idx2 >> i) & 1) << j);
                    g.set(idx2, f.get(idx));
                }
                let mut sub = nets.to_vec();
                sub.remove(j);
                return emit_simplified(out, cache, &g, &sub);
            }
        }
    }
    if let Some(c) = f.is_const() {
        return if c { Net::Const1 } else { Net::Const0 };
    }
    // Support reduction.
    let supp = f.support();
    let (g, gnets): (BoolFn, Vec<Net>) = if supp.len() == f.nvars {
        (f.clone(), nets.to_vec())
    } else {
        (f.compact(&supp), supp.iter().map(|&v| nets[v]).collect())
    };
    // Positive single-variable passthrough is a wire.
    if g.nvars == 1 && g.get(1) && !g.get(0) {
        return gnets[0];
    }
    emit_hashed(out, cache, &g, &gnets)
}

// ---------------------------------------------------------------------------
// Reachable-code don't-care support (used by `synthesize` at map time)
// ---------------------------------------------------------------------------

/// Code-set masks only track quantizers up to this many bits (mask fits a
/// u64).  Every paper configuration uses 1-3 bit activations.
pub const DC_MAX_CODE_BITS: usize = 6;

/// Truth tables larger than this skip the don't-care pass (the care-set
/// enumeration is linear in table size, same as table generation itself).
pub const DC_MAX_TABLE_BITS: usize = 20;

/// All-codes mask for a `bw`-bit quantizer (`bw <= 6`).
pub fn full_code_mask(bw: usize) -> u64 {
    debug_assert!(bw <= DC_MAX_CODE_BITS);
    let ncodes = 1usize << bw;
    if ncodes >= 64 {
        u64::MAX
    } else {
        (1u64 << ncodes) - 1
    }
}

/// Care function of one neuron: entry `idx` is reachable iff every fan-in
/// position's unpacked code is in that source's producible-code mask.
/// `src_masks` are in pack order (one per fan-in position), each over
/// `bw`-bit codes.
pub fn care_fn(src_masks: &[u64], bw: usize) -> BoolFn {
    let fanin = src_masks.len();
    let in_bits = fanin * bw;
    debug_assert!(in_bits <= DC_MAX_TABLE_BITS);
    let mut care = BoolFn::zeros(in_bits);
    let mut codes = vec![0u32; fanin];
    for idx in 0..(1usize << in_bits) {
        crate::util::bits::unpack_index(idx, bw, fanin, &mut codes);
        let ok = codes.iter().zip(src_masks).all(|(&c, &m)| (m >> c) & 1 == 1);
        care.set(idx, ok);
    }
    care
}

/// Producible-code mask of one neuron: the image of its truth table over
/// the care entries.  Requires `table.out_bits <= 6` so codes fit the mask.
pub fn reachable_image(table: &crate::luts::NeuronTable, care: &BoolFn) -> u64 {
    debug_assert!(table.out_bits <= DC_MAX_CODE_BITS);
    debug_assert_eq!(1usize << care.nvars, table.num_entries());
    let mut img = 0u64;
    for idx in 0..table.num_entries() {
        if care.get(idx) {
            img |= 1u64 << table.lookup(idx);
        }
    }
    img
}

/// Producible-code mask over *all* table entries — the seed of the
/// reachability chain when nothing upstream constrains the inputs (e.g.
/// the first emitted layer, whose primary inputs are free).
pub fn table_image(table: &crate::luts::NeuronTable) -> u64 {
    debug_assert!(table.out_bits <= DC_MAX_CODE_BITS);
    let mut img = 0u64;
    for idx in 0..table.num_entries() {
        img |= 1u64 << table.lookup(idx);
    }
    img
}

/// Re-specify one output-bit function against its care set: unreachable
/// entries become don't-cares for [`cover::minimize_dc`], and the cover's
/// completely-specified function replaces `f`.  The replacement agrees
/// with `f` on every reachable entry, so the swap is invisible to any
/// input the circuit can actually see while often shrinking the support
/// the mapper has to implement.  The cover *can* trade a true-support
/// variable for one `f` ignores (a cube may keep a literal on an ignored
/// variable when its expansion is blocked by the care off-set), so the
/// guard below enforces supp(g) ⊆ supp(f) — callers may rely on pruning
/// never adding a wire dependency.
pub fn dc_simplify(f: &BoolFn, care: &BoolFn) -> BoolFn {
    if care.is_const() == Some(true) {
        return f.clone();
    }
    let cov = cover::minimize_dc(f, care);
    let g = BoolFn::new(f.nvars, cov.to_words());
    let supp_f = f.support();
    let supp_g = g.support();
    if supp_g.len() <= supp_f.len() && supp_g.iter().all(|v| supp_f.contains(v)) {
        g
    } else {
        f.clone()
    }
}

/// Equivalence of two netlists over the primary-input space, via the
/// bitsliced simulator: exhaustive when the bus is small enough, otherwise
/// a deterministic random sample.  This is the machine check each pass (and
/// the whole pipeline) is gated on inside `synthesize`.
pub fn netlists_equivalent(a: &Netlist, b: &Netlist, seed: u64) -> bool {
    const EXHAUSTIVE_MAX_BITS: usize = 16;
    const SAMPLES: usize = 4096;
    if a.num_inputs != b.num_inputs
        || a.outputs.len() != b.outputs.len()
        || !a.brams.is_empty()
        || !b.brams.is_empty()
    {
        return false;
    }
    let inputs = if a.num_inputs <= EXHAUSTIVE_MAX_BITS {
        BitMatrix::all_patterns(a.num_inputs)
    } else {
        // SAMPLES is a multiple of 64, so every word is fully valid.
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut m = BitMatrix::new(a.num_inputs, SAMPLES);
        for p in 0..a.num_inputs {
            for w in m.plane_mut(p).iter_mut() {
                *w = rng.next_u64();
            }
        }
        m
    };
    eval_netlist(a, &inputs) == eval_netlist(b, &inputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lut(inputs: Vec<Net>, tt: u64, level: u32) -> LutNode {
        LutNode { inputs, tt, level }
    }

    /// A netlist with one duplicated AND pair (CSE fodder), a constant-fed
    /// node (sweep fodder) and a dead node.
    fn messy_netlist() -> Netlist {
        Netlist {
            num_inputs: 3,
            nodes: vec![
                // n0 = AND(in0, in1)
                lut(vec![Net::Input(0), Net::Input(1)], 0b1000, 1),
                // n1 = AND(in0, in1)  (duplicate of n0)
                lut(vec![Net::Input(0), Net::Input(1)], 0b1000, 1),
                // n2 = OR(n0, n1) == n0 after CSE, a wire after sweep
                lut(vec![Net::Node(0), Net::Node(1)], 0b1110, 2),
                // n3 = XOR(n2, Const0) == n2, another wire
                lut(vec![Net::Node(2), Net::Const0], 0b0110, 3),
                // n4 = dead (never reaches an output)
                lut(vec![Net::Input(2)], 0b01, 1),
            ],
            outputs: vec![Net::Node(3), Net::Input(2)],
            brams: vec![],
            layer_depths: vec![3],
        }
    }

    #[test]
    fn cse_merges_and_drops_dead() {
        let nl = messy_netlist();
        let after = run_pass(&nl, Pass::Cse);
        // n4 dead, n1 merged into n0.
        assert!(after.num_luts() <= 3, "{}", after.num_luts());
        assert!(netlists_equivalent(&nl, &after, 1));
    }

    #[test]
    fn sweep_folds_constants_and_wires() {
        let nl = messy_netlist();
        let a = run_pass(&nl, Pass::Cse);
        let b = run_pass(&a, Pass::Sweep);
        // After CSE, n2 = OR(n0, n0) -> wire to n0; n3 = XOR(n0, 0) -> wire.
        assert_eq!(b.num_luts(), 1, "only the AND survives");
        assert!(netlists_equivalent(&nl, &b, 2));
    }

    #[test]
    fn optimize_reaches_fixed_point() {
        let nl = messy_netlist();
        let (o1, s1) = optimize(&nl, OptLevel::Structural);
        assert_eq!(s1.pre_luts, 5);
        assert_eq!(s1.post_luts, o1.num_luts());
        assert!(s1.pass_luts.windows(2).all(|w| w[1] <= w[0]), "{:?}", s1.pass_luts);
        let (o2, s2) = optimize(&o1, OptLevel::Structural);
        assert_eq!(o1, o2, "fixed point must be idempotent");
        assert_eq!(s2.pre_luts, s2.post_luts);
        assert!(netlists_equivalent(&nl, &o1, 3));
    }

    #[test]
    fn opt_level_none_is_identity() {
        let nl = messy_netlist();
        let (o, s) = optimize(&nl, OptLevel::None);
        assert_eq!(o, nl);
        assert_eq!(s.pre_luts, s.post_luts);
        assert!(s.pass_luts.is_empty());
    }

    #[test]
    fn opt_level_parse_roundtrip() {
        for l in [OptLevel::None, OptLevel::Structural, OptLevel::Full] {
            assert_eq!(OptLevel::parse(l.name()), Some(l));
        }
        assert_eq!(OptLevel::parse("bogus"), None);
        assert!(OptLevel::Full.dont_cares() && OptLevel::Full.structural());
        assert!(!OptLevel::Structural.dont_cares() && OptLevel::Structural.structural());
        assert!(!OptLevel::None.structural());
    }

    #[test]
    fn care_fn_and_image() {
        // Two 2-bit sources; source 0 produces {0,3}, source 1 everything.
        let care = care_fn(&[0b1001, 0b1111], 2);
        assert_eq!(care.nvars, 4);
        for idx in 0..16usize {
            let c0 = idx & 0b11;
            assert_eq!(care.get(idx), c0 == 0 || c0 == 3, "idx {idx}");
        }
        assert_eq!(full_code_mask(2), 0b1111);
        assert_eq!(full_code_mask(1), 0b11);
        // A steep neuron saturates: its image over the full input space is
        // the two extreme codes only.
        let nr = crate::nn::Neuron {
            inputs: vec![0, 1],
            weights: vec![1.0, -1.0],
            bias: -0.1,
            g: 100.0,
            h: 0.0,
        };
        let q = crate::nn::QuantSpec::new(2, 2.0);
        let t = crate::luts::neuron_table(&nr, q, q).unwrap();
        let full = care_fn(&[0b1111, 0b1111], 2);
        let img = reachable_image(&t, &full);
        assert_eq!(img, 0b1001, "steep neuron must produce only codes 0 and 3");
    }

    #[test]
    fn dc_simplify_collapses_correlated_bits() {
        // f = XOR of one 2-bit source's bits.  With the source confined to
        // {0b00, 0b11} (a saturating upstream neuron) the XOR is constant 0
        // on every reachable entry — DC pruning must fold the whole cone.
        let mut f = BoolFn::zeros(2);
        f.set(1, true);
        f.set(2, true);
        let care = care_fn(&[0b1001], 2);
        let g = dc_simplify(&f, &care);
        assert_eq!(g.is_const(), Some(false), "XOR collapses to const on {{0,3}}");
        // The XNOR dual collapses to const 1.
        let mut h = BoolFn::zeros(2);
        h.set(0, true);
        h.set(3, true);
        let g1 = dc_simplify(&h, &care);
        assert_eq!(g1.is_const(), Some(true));
    }

    #[test]
    fn full_care_is_a_no_op() {
        let mut f = BoolFn::zeros(4);
        for idx in 0..16usize {
            f.set(idx, idx.count_ones() % 2 == 1);
        }
        let care = care_fn(&[0b1111, 0b1111], 2);
        assert_eq!(dc_simplify(&f, &care), f);
    }

    #[test]
    fn netlists_equivalent_detects_corruption() {
        let nl = messy_netlist();
        let (opt, _) = optimize(&nl, OptLevel::Structural);
        let mut bad = opt.clone();
        bad.nodes[0].tt = !bad.nodes[0].tt & 0b1111;
        assert!(!netlists_equivalent(&nl, &bad, 4));
    }
}
