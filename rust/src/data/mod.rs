//! Dataset container shared by all workloads (jets, MNIST, synthetic).

use crate::util::rng::Rng;

/// Flat row-major dataset: `x` is `[n, d]`, `y` holds class labels.
#[derive(Debug, Clone)]
pub struct DataSet {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub d: usize,
    pub classes: usize,
}

impl DataSet {
    pub fn new(x: Vec<f32>, y: Vec<i32>, d: usize, classes: usize) -> DataSet {
        assert_eq!(x.len() % d, 0);
        let n = x.len() / d;
        assert_eq!(y.len(), n);
        DataSet { x, y, n, d, classes }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Gather rows by index into contiguous buffers (a training batch).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut bx = Vec::with_capacity(idx.len() * self.d);
        let mut by = Vec::with_capacity(idx.len());
        for &i in idx {
            bx.extend_from_slice(self.row(i));
            by.push(self.y[i]);
        }
        (bx, by)
    }

    /// Sample a batch of `bsz` rows with replacement.
    pub fn sample_batch(&self, bsz: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let idx: Vec<usize> = (0..bsz).map(|_| rng.below(self.n)).collect();
        self.gather(&idx)
    }

    /// Contiguous chunk `[start, start+len)`, padded by repeating row 0 so
    /// fixed-batch HLO executables can consume the tail of a test set.
    pub fn chunk_padded(&self, start: usize, len: usize) -> (Vec<f32>, Vec<i32>, usize) {
        let real = len.min(self.n.saturating_sub(start));
        let mut bx = Vec::with_capacity(len * self.d);
        let mut by = Vec::with_capacity(len);
        for i in 0..len {
            let src = if i < real { start + i } else { 0 };
            bx.extend_from_slice(self.row(src));
            by.push(self.y[src]);
        }
        (bx, by, real)
    }

    /// Split into (train, test) with `test_frac` of rows held out.
    pub fn split(mut self, test_frac: f64, rng: &mut Rng) -> (DataSet, DataSet) {
        let mut idx: Vec<usize> = (0..self.n).collect();
        rng.shuffle(&mut idx);
        let n_test = ((self.n as f64) * test_frac).round() as usize;
        let (test_idx, train_idx) = idx.split_at(n_test);
        let (tx, ty) = self.gather(test_idx);
        let (rx, ry) = self.gather(train_idx);
        let (d, c) = (self.d, self.classes);
        self.x.clear();
        (DataSet::new(rx, ry, d, c), DataSet::new(tx, ty, d, c))
    }

    /// Min-max normalize each feature column to [0, 1] (the input quantizer
    /// contract: maxv_in = 1.0).
    pub fn normalize_unit(&mut self) {
        for j in 0..self.d {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for i in 0..self.n {
                let v = self.x[i * self.d + j];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let span = (hi - lo).max(1e-9);
            for i in 0..self.n {
                let v = &mut self.x[i * self.d + j];
                *v = (*v - lo) / span;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> DataSet {
        DataSet::new(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0], vec![0, 1, 0], 2, 2)
    }

    #[test]
    fn gather_and_row() {
        let d = tiny();
        assert_eq!(d.row(1), &[2.0, 3.0]);
        let (bx, by) = d.gather(&[2, 0]);
        assert_eq!(bx, vec![4.0, 5.0, 0.0, 1.0]);
        assert_eq!(by, vec![0, 0]);
    }

    #[test]
    fn chunk_padding() {
        let d = tiny();
        let (bx, by, real) = d.chunk_padded(2, 4);
        assert_eq!(real, 1);
        assert_eq!(bx.len(), 8);
        assert_eq!(&bx[0..2], &[4.0, 5.0]);
        assert_eq!(&bx[2..4], &[0.0, 1.0]); // padded with row 0
        assert_eq!(by[0], 0);
    }

    #[test]
    fn normalize_unit_bounds() {
        let mut d = tiny();
        d.normalize_unit();
        for v in &d.x {
            assert!((0.0..=1.0).contains(v));
        }
        assert_eq!(d.x[0], 0.0);
        assert_eq!(d.x[4], 1.0);
    }

    #[test]
    fn split_partitions() {
        let mut rng = crate::util::rng::Rng::new(1);
        let d = DataSet::new((0..200).map(|i| i as f32).collect(), vec![0; 100], 2, 2);
        let (tr, te) = d.split(0.25, &mut rng);
        assert_eq!(te.n, 25);
        assert_eq!(tr.n, 75);
    }
}
