//! Classification metrics: accuracy, softmax, confusion matrix, ROC / AUC.
//!
//! AUC is computed with the rank statistic (Mann-Whitney U), which is exact
//! and O(n log n); ROC curves are produced by threshold sweep over the
//! predicted score of the signal class vs an equal admixture of the others
//! (the paper's convention, Fig. 6.5).

/// Row-major logits `[n, c]` -> predicted class per row.
///
/// NaN policy (the old `partial_cmp().unwrap()` aborted on the first NaN
/// logit): NaN entries are excluded from the argmax — a diverged logit can
/// never become the predicted class — and an all-NaN (or empty) row
/// deterministically predicts class 0.  Ties between real logits keep the
/// highest index, matching the engines' `max_by_key` tie-break.
pub fn argmax_rows(logits: &[f32], c: usize) -> Vec<usize> {
    logits
        .chunks(c)
        .map(|row| {
            row.iter()
                .enumerate()
                .filter(|(_, v)| !v.is_nan())
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

pub fn accuracy(logits: &[f32], y: &[i32], c: usize) -> f64 {
    let pred = argmax_rows(logits, c);
    let correct = pred.iter().zip(y).filter(|(p, y)| **p == **y as usize).count();
    correct as f64 / y.len().max(1) as f64
}

/// In-place softmax over each row of `[n, c]`.
pub fn softmax_rows(logits: &[f32], c: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(logits.len());
    for row in logits.chunks(c) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
        let s: f32 = exps.iter().sum();
        out.extend(exps.iter().map(|e| e / s));
    }
    out
}

/// Confusion matrix `[true][pred]`, row-normalized if `normalize`.
pub fn confusion(logits: &[f32], y: &[i32], c: usize, normalize: bool) -> Vec<Vec<f64>> {
    let pred = argmax_rows(logits, c);
    let mut m = vec![vec![0f64; c]; c];
    for (p, t) in pred.iter().zip(y) {
        m[*t as usize][*p] += 1.0;
    }
    if normalize {
        for row in m.iter_mut() {
            let s: f64 = row.iter().sum();
            if s > 0.0 {
                for v in row.iter_mut() {
                    *v /= s;
                }
            }
        }
    }
    m
}

/// Exact binary AUC via the rank statistic.  `scores[i]` is the predicted
/// probability/score of the positive class, `pos[i]` marks positives.
pub fn auc_binary(scores: &[f32], pos: &[bool]) -> f64 {
    assert_eq!(scores.len(), pos.len());
    // NaN policy: every NaN score ranks as the most-positive prediction
    // (and ties with other NaNs) — the old partial_cmp().unwrap()
    // panicked on the first one.  NaNs are canonicalized first because
    // the IEEE total order is sign-sensitive: runtime divergence (e.g.
    // 0.0/0.0 on x86) yields sign-*negative* NaNs, which total_cmp alone
    // would rank below every real score.
    let scores: Vec<f32> =
        scores.iter().map(|&v| if v.is_nan() { f32::NAN } else { v }).collect();
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    // midranks for ties (NaN == NaN is false, but NaN scores are mutually
    // indistinguishable, so they tie with each other)
    let tied = |a: f32, b: f32| a == b || (a.is_nan() && b.is_nan());
    let mut ranks = vec![0f64; scores.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && tied(scores[idx[j + 1]], scores[idx[i]]) {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = mid;
        }
        i = j + 1;
    }
    let n_pos = pos.iter().filter(|&&p| p).count();
    let n_neg = pos.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let rank_sum: f64 = ranks.iter().zip(pos).filter(|(_, &p)| p).map(|(r, _)| r).sum();
    (rank_sum - (n_pos * (n_pos + 1)) as f64 / 2.0) / (n_pos as f64 * n_neg as f64)
}

/// One-vs-rest AUC per class from `[n, c]` scores.
pub fn auc_ovr(scores: &[f32], y: &[i32], c: usize) -> Vec<f64> {
    (0..c)
        .map(|k| {
            let s: Vec<f32> = scores.chunks(c).map(|row| row[k]).collect();
            let p: Vec<bool> = y.iter().map(|&t| t as usize == k).collect();
            auc_binary(&s, &p)
        })
        .collect()
}

/// ROC curve points (fpr, tpr) for class `k` one-vs-rest, sorted by fpr.
///
/// Curve points are emitted only at *distinct-score boundaries*.  Quantized
/// logits take a handful of values, so long runs of tied scores are the
/// norm; a point inside a tied run would depend on how the sort happened to
/// interleave positives and negatives within the run, biasing the curve
/// (the tied region must be a straight segment, not a staircase).
/// `points` downsamples long curves, but a tied group is never split.
pub fn roc_curve(scores: &[f32], y: &[i32], c: usize, k: usize, points: usize) -> Vec<(f64, f64)> {
    // Canonicalize NaN scores (see `auc_binary`): sign-negative runtime
    // NaNs would otherwise sort at the *bottom* of the descending sweep
    // instead of the documented most-positive rank.
    let s: Vec<f32> = scores
        .chunks(c)
        .map(|row| {
            let v = row[k];
            if v.is_nan() {
                f32::NAN
            } else {
                v
            }
        })
        .collect();
    let pos: Vec<bool> = y.iter().map(|&t| t as usize == k).collect();
    let n_pos = pos.iter().filter(|&&p| p).count().max(1) as f64;
    let n_neg = (pos.len() - pos.iter().filter(|&&p| p).count()).max(1) as f64;
    let mut order: Vec<usize> = (0..s.len()).collect();
    // Descending IEEE total order: NaN scores rank above every real score
    // and are consumed first, as one tied group (mutually
    // indistinguishable).  No input can panic the sweep; the curve stays
    // monotone.
    order.sort_by(|&a, &b| s[b].total_cmp(&s[a]));
    let tied = |a: f32, b: f32| a == b || (a.is_nan() && b.is_nan());
    let mut out = vec![(0.0, 0.0)];
    let (mut tp, mut fp) = (0usize, 0usize);
    let stride = (order.len() / points.max(1)).max(1);
    let mut next_emit = stride;
    let mut i = 0;
    while i < order.len() {
        // Consume the whole tied-score group before considering a point.
        let mut j = i;
        while j + 1 < order.len() && tied(s[order[j + 1]], s[order[i]]) {
            j += 1;
        }
        for &idx in &order[i..=j] {
            if pos[idx] {
                tp += 1;
            } else {
                fp += 1;
            }
        }
        if j + 1 == order.len() || j + 1 >= next_emit {
            out.push((fp as f64 / n_neg, tp as f64 / n_pos));
            next_emit = j + 1 + stride;
        }
        i = j + 1;
    }
    if out.last() != Some(&(1.0, 1.0)) {
        out.push((1.0, 1.0));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        let logits = vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0];
        let y = vec![0, 1, 1];
        assert!((accuracy(&logits, &y, 2) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let p = softmax_rows(&[1.0, 2.0, 3.0, -1.0, 0.0, 1.0], 3);
        for row in p.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn auc_perfect_and_random() {
        let s = vec![0.9, 0.8, 0.2, 0.1];
        let p = vec![true, true, false, false];
        assert!((auc_binary(&s, &p) - 1.0).abs() < 1e-12);
        let p_inv = vec![false, false, true, true];
        assert!((auc_binary(&s, &p_inv) - 0.0).abs() < 1e-12);
        // all-tied scores -> 0.5
        let s_tied = vec![0.5; 4];
        assert!((auc_binary(&s_tied, &p) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn confusion_rows_normalize() {
        let logits = vec![1.0, 0.0, 0.0, 1.0, 0.0, 1.0];
        let y = vec![0, 0, 1];
        let m = confusion(&logits, &y, 2, true);
        assert!((m[0][0] - 0.5).abs() < 1e-12);
        assert!((m[0][1] - 0.5).abs() < 1e-12);
        assert!((m[1][1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn roc_monotone() {
        let s = vec![0.9, 0.7, 0.6, 0.4, 0.3, 0.1];
        let y = vec![1, 1, 0, 1, 0, 0];
        let roc = roc_curve(&s.iter().flat_map(|&v| [1.0 - v, v]).collect::<Vec<_>>(), &y, 2, 1, 10);
        for w in roc.windows(2) {
            assert!(w[1].0 >= w[0].0 && w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn accuracy_survives_nan_logits() {
        // Regression: the argmax's partial_cmp().unwrap() aborted on the
        // first NaN logit.  Documented ordering: NaN never wins, all-NaN
        // rows predict class 0.
        let logits = vec![
            f32::NAN, 1.0, 0.0, // NaN excluded -> class 1
            2.0, f32::NAN, 0.0, // NaN excluded -> class 0
            f32::NAN, f32::NAN, f32::NAN, // all NaN -> class 0
        ];
        let pred = argmax_rows(&logits, 3);
        assert_eq!(pred, vec![1, 0, 0]);
        let y = vec![1, 0, 0];
        assert!((accuracy(&logits, &y, 3) - 1.0).abs() < 1e-12);
        // -inf is a real value and may win only against smaller reals.
        let pred = argmax_rows(&[f32::NEG_INFINITY, f32::NAN], 2);
        assert_eq!(pred, vec![0]);
    }

    #[test]
    fn auc_survives_nan_scores() {
        // NaN scores sort as the most-positive predictions (IEEE total
        // order); no panic, result stays a valid AUC.
        let s = vec![0.9, f32::NAN, 0.2, 0.1];
        let p = vec![true, true, false, false];
        let auc = auc_binary(&s, &p);
        assert!((0.0..=1.0).contains(&auc), "{auc}");
        // A NaN on a positive ranks it top: perfect separation preserved.
        assert!((auc - 1.0).abs() < 1e-12);
        // Sign-negative NaN (what 0.0/0.0 produces at runtime on x86)
        // must follow the same most-positive policy, not sort below -inf.
        let s = vec![0.9, -f32::NAN, 0.2, 0.1];
        assert!((auc_binary(&s, &p) - 1.0).abs() < 1e-12);
        // All-NaN (mixed signs): every score tied -> midranks -> 0.5.
        let s = vec![f32::NAN, -f32::NAN, f32::NAN, -f32::NAN];
        assert!((auc_binary(&s, &p) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn roc_survives_nan_scores() {
        // NaN scores (either sign) must not panic the sweep; the curve
        // stays a monotone (0,0) -> (1,1) staircase.
        let s = vec![0.9, f32::NAN, 0.6, -f32::NAN, 0.3, 0.1];
        let y = vec![1, 1, 0, 1, 0, 0];
        let logits: Vec<f32> = s.iter().flat_map(|&v| [1.0 - v, v]).collect();
        let roc = roc_curve(&logits, &y, 2, 1, 100);
        assert_eq!(roc.first(), Some(&(0.0, 0.0)));
        assert_eq!(roc.last(), Some(&(1.0, 1.0)));
        for w in roc.windows(2) {
            assert!(w[1].0 >= w[0].0 && w[1].1 >= w[0].1, "{roc:?}");
        }
    }

    fn roc_of(s: &[f32], y: &[i32], points: usize) -> Vec<(f64, f64)> {
        let logits: Vec<f32> = s.iter().flat_map(|&v| [1.0 - v, v]).collect();
        roc_curve(&logits, y, 2, 1, points)
    }

    #[test]
    fn roc_all_tied_scores_is_the_diagonal() {
        // Regression: every score identical (the extreme quantized-logit
        // case).  The old point-per-sample sweep emitted a staircase whose
        // shape depended on sort order; the only honest curve is the
        // straight diagonal with no interior points.
        let s = vec![0.5f32; 6];
        let y = vec![1, 0, 1, 0, 1, 0];
        let roc = roc_of(&s, &y, 10);
        assert_eq!(roc, vec![(0.0, 0.0), (1.0, 1.0)]);
    }

    #[test]
    fn roc_never_splits_a_tied_group() {
        // Positives and negatives interleaved inside one tied group: the
        // curve must jump across the whole group in one segment.
        let s = vec![0.9, 0.5, 0.5, 0.5, 0.1];
        let y = vec![1, 1, 0, 1, 0];
        let roc = roc_of(&s, &y, 100);
        // Boundaries: after 0.9 (tp=1), after the 0.5 group (tp=3, fp=1),
        // after 0.1 (fp=2).
        assert_eq!(
            roc,
            vec![(0.0, 0.0), (0.0, 1.0 / 3.0), (0.5, 1.0), (1.0, 1.0)]
        );
        // No sort order of the tied group can change the curve: reversing
        // the sample order must give the identical point list.
        let s_rev: Vec<f32> = s.iter().rev().cloned().collect();
        let y_rev: Vec<i32> = y.iter().rev().cloned().collect();
        assert_eq!(roc_of(&s_rev, &y_rev, 100), roc);
    }
}
