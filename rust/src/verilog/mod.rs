//! VERILOG code generation (paper §5.2) and a subset parser for round-trip
//! verification.
//!
//! The generator mirrors the paper's module structure exactly (Listings
//! 5.2-5.6): a `LogicNetModule` top, one `LUTLayer<i>` per sparse layer
//! wiring neuron input slices, and one `LUT_L<i>_N<j>` case-statement module
//! per neuron.  No LUT primitives are instantiated — the whole truth table
//! is written out and logic synthesis (`crate::synth`) is left to discover
//! the optimal hardware building block, exactly as the paper argues.
//!
//! Bit layout contract (matches `util::bits::pack_index`): element `j` of a
//! layer's activation vector occupies bus bits `[j*bw, (j+1)*bw)`.

pub mod gen;
pub mod parse;

pub use gen::{generate, netlist_module, neuron_module, VerilogOpts, VerilogProject};
pub use parse::{parse_project, ParsedNeuron};
