//! Verilog emitter.

use crate::luts::{ModelTables, NeuronTable};
use crate::nn::ExportedModel;
use anyhow::{ensure, Result};

#[derive(Debug, Clone, Copy)]
pub struct VerilogOpts {
    /// Insert registers at the input and between layers (Fig. 5.1).  When
    /// false the circuit is purely combinational (Table 5.2 regime).
    pub registers: bool,
}

impl Default for VerilogOpts {
    fn default() -> Self {
        VerilogOpts { registers: true }
    }
}

/// A generated project: (file name, contents) pairs plus summary stats.
#[derive(Debug, Clone, Default)]
pub struct VerilogProject {
    pub files: Vec<(String, String)>,
    pub total_bytes: usize,
    /// Layers actually emitted (sparse layers only; dense heads are costed
    /// with eq. 4.1 and stay arithmetic, as in the paper).
    pub emitted_layers: Vec<usize>,
}

impl VerilogProject {
    pub fn write_to(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for (name, text) in &self.files {
            std::fs::write(dir.join(name), text)?;
        }
        Ok(())
    }

    pub fn file(&self, name: &str) -> Option<&str> {
        self.files.iter().find(|(n, _)| n == name).map(|(_, t)| t.as_str())
    }
}

/// Emit the case-statement module for one neuron (Listing 5.4).
pub fn neuron_module(name: &str, table: &NeuronTable) -> String {
    let in_bits = table.in_bits;
    let out_bits = table.out_bits;
    let entries = table.num_entries();
    // Preallocate: each case line is ~20-30 bytes; this is the hot loop of
    // Table 5.1 (file size/time explode exponentially with fan-in bits).
    let mut s = String::with_capacity(64 + entries * (16 + in_bits / 3 + out_bits));
    s.push_str(&format!(
        "module {name} ( input [{}:0] M0, output [{}:0] M1 );\n",
        in_bits - 1,
        out_bits - 1
    ));
    s.push_str(&format!("  reg [{}:0] M1;\n", out_bits - 1));
    s.push_str("  always @ (M0) begin\n    case (M0)\n");
    for idx in 0..entries {
        let code = table.lookup(idx);
        s.push_str(&format!(
            "      {in_bits}'d{idx}: M1 = {out_bits}'b{code:0width$b};\n",
            width = out_bits
        ));
    }
    s.push_str("    endcase\n  end\nendmodule\n");
    s
}

/// Emit a mapped (and typically optimized, `synth::opt`) LUT netlist as one
/// flat structural module: every `LutNode` becomes a truth-table constant
/// indexed by the concatenation of its input nets.  This is the
/// post-synthesis counterpart of the behavioral case-statement modules —
/// what the circuit looks like *after* the in-tree logic synthesis, LUT
/// for LUT.
pub fn netlist_module(name: &str, netlist: &crate::synth::Netlist) -> Result<String> {
    use crate::synth::Net;
    ensure!(
        netlist.brams.is_empty(),
        "BRAM-mapped neurons cannot be emitted as a flat LUT netlist"
    );
    ensure!(netlist.num_inputs > 0, "netlist has no primary inputs");
    ensure!(!netlist.outputs.is_empty(), "netlist has no outputs");
    let net_ref = |n: Net| -> String {
        match n {
            Net::Const0 => "1'b0".into(),
            Net::Const1 => "1'b1".into(),
            Net::Input(i) => format!("M0[{i}]"),
            Net::Node(i) => format!("n{i}"),
        }
    };
    let mut s = String::new();
    s.push_str(&format!(
        "module {name} ( input [{}:0] M0, output [{}:0] M1 );\n",
        netlist.num_inputs - 1,
        netlist.outputs.len() - 1
    ));
    for (i, node) in netlist.nodes.iter().enumerate() {
        let k = node.inputs.len();
        ensure!((1..=6).contains(&k), "node {i}: arity {k} out of range");
        let bits = 1usize << k;
        let mask = if bits >= 64 { u64::MAX } else { (1u64 << bits) - 1 };
        // Verilog concatenation is MSB-first: the highest-index variable of
        // the packed truth table is listed first.
        let sel: Vec<String> = node.inputs.iter().rev().map(|&n| net_ref(n)).collect();
        s.push_str(&format!(
            "  wire [{}:0] t{i} = {}'h{:x};\n  wire n{i} = t{i}[{{{}}}];\n",
            bits - 1,
            bits,
            node.tt & mask,
            sel.join(", ")
        ));
    }
    for (oi, &o) in netlist.outputs.iter().enumerate() {
        s.push_str(&format!("  assign M1[{oi}] = {};\n", net_ref(o)));
    }
    s.push_str("endmodule\n");
    Ok(s)
}

/// Emit the layer module wiring neuron input slices (Listing 5.3).
fn layer_module(
    li: usize,
    model: &ExportedModel,
    tables: &crate::luts::LayerTables,
) -> String {
    let layer = &model.layers[li];
    let bw = tables.quant_in.bw;
    let in_bus = layer.in_f * bw;
    let out_bw = tables.quant_out.bw;
    let out_bus = layer.neurons.len() * out_bw;
    let mut s = String::new();
    s.push_str(&format!(
        "module LUTLayer{li} (input [{}:0] M0, output [{}:0] M1);\n\n",
        in_bus - 1,
        out_bus - 1
    ));
    for (nj, nr) in layer.neurons.iter().enumerate() {
        let fanin = nr.fanin();
        let wire_bits = fanin * bw;
        // Concatenation is MSB-first in Verilog; pack_index puts input j at
        // bits [j*bw, (j+1)*bw), so list inputs highest-j first.
        let mut parts = Vec::with_capacity(fanin);
        for &j in nr.inputs.iter().rev() {
            if bw == 1 {
                parts.push(format!("M0[{}]", j));
            } else {
                parts.push(format!("M0[{}:{}]", (j + 1) * bw - 1, j * bw));
            }
        }
        s.push_str(&format!(
            "  wire [{}:0] inpWire{li}_{nj} = {{{}}};\n",
            wire_bits - 1,
            parts.join(", ")
        ));
        let (hi, lo) = ((nj + 1) * out_bw - 1, nj * out_bw);
        s.push_str(&format!(
            "  LUT_L{li}_N{nj} LUT_L{li}_N{nj}_inst (.M0(inpWire{li}_{nj}), .M1(M1[{hi}:{lo}]));\n\n"
        ));
    }
    s.push_str("endmodule\n");
    s
}

/// Generate the full project for every *sparse* layer of the model.
pub fn generate(
    model: &ExportedModel,
    tables: &ModelTables,
    opts: VerilogOpts,
) -> Result<VerilogProject> {
    let mut proj = VerilogProject::default();
    let mut emitted: Vec<usize> = Vec::new();
    for (li, lt) in tables.layers.iter().enumerate() {
        let Some(lt) = lt else { continue };
        ensure!(
            model.layers[li].sparse,
            "layer {li} has tables but is not sparse"
        );
        // One file per neuron module (paper: parallel generation unit), one
        // per layer.
        for (nj, t) in lt.tables.iter().enumerate() {
            let name = format!("LUT_L{li}_N{nj}");
            proj.files.push((format!("{name}.v"), neuron_module(&name, t)));
        }
        proj.files.push((format!("LUTLayer{li}.v"), layer_module(li, model, lt)));
        emitted.push(li);
    }
    ensure!(!emitted.is_empty(), "no sparse layers to emit");

    // Top module (Listing 5.2), with optional registers (Fig. 5.1).
    let first = emitted[0];
    let last = *emitted.last().unwrap();
    let in_bus = model.layers[first].in_f * tables.layers[first].as_ref().unwrap().quant_in.bw;
    let out_bus = model.layers[last].neurons.len()
        * tables.layers[last].as_ref().unwrap().quant_out.bw;
    let mut top = String::new();
    if opts.registers {
        top.push_str(&format!(
            "module LogicNetModule (input clk, input [{}:0] M0, output [{}:0] M1);\n",
            in_bus - 1,
            out_bus - 1
        ));
        top.push_str(&format!("  reg [{}:0] stage_in;\n", in_bus - 1));
        top.push_str("  always @(posedge clk) stage_in <= M0;\n");
    } else {
        top.push_str(&format!(
            "module LogicNetModule (input [{}:0] M0, output [{}:0] M1);\n",
            in_bus - 1,
            out_bus - 1
        ));
    }
    let mut prev = if opts.registers { "stage_in".to_string() } else { "M0".to_string() };
    for (k, &li) in emitted.iter().enumerate() {
        let lt = tables.layers[li].as_ref().unwrap();
        let w = model.layers[li].neurons.len() * lt.quant_out.bw;
        let wire = format!("act{li}");
        top.push_str(&format!("  wire [{}:0] {wire};\n", w - 1));
        top.push_str(&format!(
            "  LUTLayer{li} LUTLayer{li}_inst (.M0({prev}), .M1({wire}));\n"
        ));
        if k + 1 < emitted.len() && opts.registers {
            let reg = format!("reg{li}");
            top.push_str(&format!("  reg [{}:0] {reg};\n", w - 1));
            top.push_str(&format!("  always @(posedge clk) {reg} <= {wire};\n"));
            prev = reg;
        } else {
            prev = wire;
        }
    }
    top.push_str(&format!("  assign M1 = {prev};\nendmodule\n"));
    proj.files.push(("LogicNetModule.v".to_string(), top));

    proj.total_bytes = proj.files.iter().map(|(_, t)| t.len()).sum();
    proj.emitted_layers = emitted;
    Ok(proj)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::luts::{neuron_table, ModelTables};
    use crate::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};

    pub(crate) fn tiny_model() -> ExportedModel {
        let qi = QuantSpec::new(1, 1.0);
        let qo = QuantSpec::new(1, 1.0);
        let mk = |inputs: Vec<usize>, weights: Vec<f32>| Neuron {
            inputs,
            weights,
            bias: 0.0,
            g: 1.0,
            h: 0.0,
        };
        let layer = ExportedLayer::uniform(
            vec![
                mk(vec![0, 2, 4], vec![1.0, -1.0, 0.5]),
                mk(vec![1, 2, 3], vec![1.0, 1.0, -2.0]),
                mk(vec![0, 1, 2], vec![-1.0, 1.0, 1.0]),
            ],
            5,
            qi,
            qo,
            true,
        );
        ExportedModel {
            layers: vec![layer],
            in_features: 5,
            classes: 3,
            skips: 0,
            act_widths: vec![5],
        }
    }

    #[test]
    fn generates_paper_structure() {
        let model = tiny_model();
        let tables = ModelTables::generate(&model).unwrap();
        let proj = generate(&model, &tables, VerilogOpts { registers: false }).unwrap();
        assert_eq!(proj.files.len(), 5); // 3 neurons + layer + top
        let top = proj.file("LogicNetModule.v").unwrap();
        assert!(top.contains("module LogicNetModule (input [4:0] M0, output [2:0] M1)"));
        let layer = proj.file("LUTLayer0.v").unwrap();
        // MSB-first concat of inputs {4,2,0} for neuron 0
        assert!(layer.contains("wire [2:0] inpWire0_0 = {M0[4], M0[2], M0[0]};"), "{layer}");
        let n0 = proj.file("LUT_L0_N0.v").unwrap();
        assert!(n0.contains("case (M0)"));
        assert!(n0.contains("3'd0: M1 = 1'b"));
        assert!(n0.contains("3'd7: M1 = 1'b"));
    }

    #[test]
    fn registered_top_has_clock() {
        let model = tiny_model();
        let tables = ModelTables::generate(&model).unwrap();
        let proj = generate(&model, &tables, VerilogOpts { registers: true }).unwrap();
        let top = proj.file("LogicNetModule.v").unwrap();
        assert!(top.contains("input clk"));
        assert!(top.contains("always @(posedge clk) stage_in <= M0;"));
    }

    #[test]
    fn netlist_module_emits_structural_luts() {
        use crate::synth::{synthesize, OptLevel, SynthOpts};
        let model = tiny_model();
        let tables = ModelTables::generate(&model).unwrap();
        let (netlist, rep) = synthesize(
            &model,
            &tables,
            SynthOpts {
                registers: false,
                bram_min_bits: 0,
                opt: OptLevel::Full,
                ..SynthOpts::default()
            },
        )
        .unwrap();
        let text = netlist_module("LogicNetNetlist", &netlist).unwrap();
        assert!(text.contains("module LogicNetNetlist ( input [4:0] M0, output [2:0] M1 );"));
        // One truth-table wire pair per LUT, one assign per output bit.
        assert_eq!(text.matches("wire n").count(), rep.luts);
        assert_eq!(text.matches("assign M1[").count(), netlist.outputs.len());
        assert!(text.ends_with("endmodule\n"));
        // BRAM-mapped netlists are rejected.
        let mut with_bram = netlist.clone();
        with_bram.brams.push(crate::synth::BramNeuron::opaque(14, 2, 2));
        assert!(netlist_module("X", &with_bram).is_err());
    }

    #[test]
    fn neuron_module_size_scales_with_bits() {
        // Table 5.1 regime: the .v text grows ~2x per extra input bit.
        let qi = QuantSpec::new(1, 1.0);
        let qo = QuantSpec::new(1, 1.0);
        let mk = |f: usize| Neuron {
            inputs: (0..f).collect(),
            weights: (0..f).map(|i| if i % 2 == 0 { 1.0 } else { -0.5 }).collect(),
            bias: 0.1,
            g: 1.0,
            h: 0.0,
        };
        let t10 = neuron_table(&mk(10), qi, qo).unwrap();
        let t12 = neuron_table(&mk(12), qi, qo).unwrap();
        let s10 = neuron_module("N", &t10).len();
        let s12 = neuron_module("N", &t12).len();
        assert!(s12 > 3 * s10, "s10={s10} s12={s12}");
    }
}
