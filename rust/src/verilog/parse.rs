//! Parser for the generated Verilog subset — used for round-trip testing
//! (generate → parse → compare tables) and as the synthesis front-end's
//! netlist reader in `logicnets synth --from-verilog`.

use crate::util::bits::PackedCodes;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// A parsed `LUT_L<i>_N<j>` case module.
#[derive(Debug, Clone)]
pub struct ParsedNeuron {
    pub layer: usize,
    pub index: usize,
    pub in_bits: usize,
    pub out_bits: usize,
    pub codes: PackedCodes,
    /// Input element indices recovered from the layer wiring (filled by
    /// [`parse_project`] when the layer file is present).
    pub inputs: Vec<usize>,
}

/// Parse all files of a generated project.  Returns neurons grouped by
/// layer, each with its recovered input wiring.
pub fn parse_project(files: &[(String, String)]) -> Result<BTreeMap<usize, Vec<ParsedNeuron>>> {
    let mut neurons: BTreeMap<(usize, usize), ParsedNeuron> = BTreeMap::new();
    for (name, text) in files {
        if let Some(rest) = name.strip_prefix("LUT_L") {
            let stem = rest.strip_suffix(".v").unwrap_or(rest);
            let (li, nj) = stem
                .split_once("_N")
                .ok_or_else(|| anyhow!("bad neuron file name {name}"))?;
            let layer: usize = li.parse().context("layer idx")?;
            let index: usize = nj.parse().context("neuron idx")?;
            let mut nr = parse_neuron_module(text)?;
            nr.layer = layer;
            nr.index = index;
            neurons.insert((layer, index), nr);
        }
    }
    // Recover wiring from layer files.
    for (name, text) in files {
        if let Some(rest) = name.strip_prefix("LUTLayer") {
            let li: usize = rest
                .strip_suffix(".v")
                .unwrap_or(rest)
                .parse()
                .context("layer file idx")?;
            for (nj, lo_bits) in parse_layer_wiring(text)? {
                if let Some(nr) = neurons.get_mut(&(li, nj)) {
                    // bw is unambiguous from the neuron module: in_bits
                    // divided by the number of concatenated elements.
                    ensure!(!lo_bits.is_empty() && nr.in_bits % lo_bits.len() == 0);
                    let bw = nr.in_bits / lo_bits.len();
                    nr.inputs = lo_bits.iter().map(|&lo| lo / bw).collect();
                }
            }
        }
    }
    let mut by_layer: BTreeMap<usize, Vec<ParsedNeuron>> = BTreeMap::new();
    for ((layer, _), nr) in neurons {
        by_layer.entry(layer).or_default().push(nr);
    }
    for v in by_layer.values_mut() {
        v.sort_by_key(|n| n.index);
    }
    Ok(by_layer)
}

/// Parse one neuron case module.
pub fn parse_neuron_module(text: &str) -> Result<ParsedNeuron> {
    // header: module NAME ( input [N:0] M0, output [M:0] M1 );
    let hdr = text
        .lines()
        .find(|l| l.trim_start().starts_with("module "))
        .ok_or_else(|| anyhow!("no module header"))?;
    let in_bits = bus_width(hdr, "input").context("input bus")?;
    let out_bits = bus_width_after(hdr, "output").context("output bus")?;
    let entries = 1usize << in_bits;
    let mut codes = PackedCodes::new(entries, out_bits);
    let mut seen = 0usize;
    for line in text.lines() {
        let line = line.trim();
        // e.g. `6'd13: M1 = 2'b01;`
        let Some((lhs, rhs)) = line.split_once(": M1 = ") else { continue };
        let idx: usize = lhs
            .split_once("'d")
            .ok_or_else(|| anyhow!("bad case index {lhs:?}"))?
            .1
            .parse()
            .context("case index")?;
        let bin = rhs
            .split_once("'b")
            .ok_or_else(|| anyhow!("bad case value {rhs:?}"))?
            .1
            .trim_end_matches(';');
        let code = u32::from_str_radix(bin, 2).context("case value bits")?;
        ensure!(idx < entries, "case index {idx} out of range");
        codes.set(idx, code);
        seen += 1;
    }
    ensure!(seen == entries, "case statement incomplete: {seen}/{entries}");
    Ok(ParsedNeuron { layer: 0, index: 0, in_bits, out_bits, codes, inputs: Vec::new() })
}

/// Parse `wire [..] inpWire<l>_<n> = {M0[hi:lo], ...};` lines into the
/// low bit of each slice, undoing the MSB-first ordering.
fn parse_layer_wiring(text: &str) -> Result<Vec<(usize, Vec<usize>)>> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if !line.starts_with("wire ") || !line.contains("inpWire") {
            continue;
        }
        let nj: usize = line
            .split("inpWire")
            .nth(1)
            .and_then(|s| s.split(&['_', ' '][..]).nth(1))
            .and_then(|s| s.split(' ').next())
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad inpWire line {line:?}"))?;
        let body = line
            .split_once('{')
            .and_then(|(_, r)| r.split_once('}'))
            .map(|(b, _)| b)
            .ok_or_else(|| anyhow!("no concat in {line:?}"))?;
        let mut elems = Vec::new();
        for part in body.split(',') {
            let part = part.trim();
            let inner = part
                .strip_prefix("M0[")
                .and_then(|s| s.strip_suffix(']'))
                .ok_or_else(|| anyhow!("bad slice {part:?}"))?;
            let lo_bit: usize = match inner.split_once(':') {
                Some((_, lo)) => lo.parse().context("slice lo")?,
                None => inner.parse().context("slice bit")?,
            };
            elems.push(lo_bit);
        }
        // The concat was emitted highest element first.
        elems.reverse();
        out.push((nj, elems));
    }
    if out.is_empty() {
        bail!("no inpWire lines found");
    }
    Ok(out)
}

fn bus_width(line: &str, kw: &str) -> Result<usize> {
    let pos = line.find(kw).ok_or_else(|| anyhow!("no {kw}"))?;
    let rest = &line[pos..];
    let hi: usize = rest
        .split_once('[')
        .and_then(|(_, r)| r.split_once(':'))
        .map(|(h, _)| h.trim())
        .ok_or_else(|| anyhow!("no bus in {line:?}"))?
        .parse()
        .context("bus hi")?;
    Ok(hi + 1)
}

fn bus_width_after(line: &str, kw: &str) -> Result<usize> {
    bus_width(line, kw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::ModelTables;
    use crate::verilog::gen::{generate, VerilogOpts};

    #[test]
    fn roundtrip_generated_project() {
        let model = crate::verilog::gen::tests::tiny_model();
        let tables = ModelTables::generate(&model).unwrap();
        let proj = generate(&model, &tables, VerilogOpts { registers: false }).unwrap();
        let parsed = parse_project(&proj.files).unwrap();
        let layer0 = &parsed[&0];
        assert_eq!(layer0.len(), 3);
        let lt = tables.layers[0].as_ref().unwrap();
        for (nj, nr) in layer0.iter().enumerate() {
            assert_eq!(nr.in_bits, lt.tables[nj].in_bits);
            assert_eq!(nr.out_bits, lt.tables[nj].out_bits);
            for idx in 0..lt.tables[nj].num_entries() {
                assert_eq!(nr.codes.get(idx), lt.tables[nj].lookup(idx), "n{nj} idx{idx}");
            }
            assert_eq!(nr.inputs, model.layers[0].neurons[nj].inputs);
        }
    }

    #[test]
    fn rejects_incomplete_case() {
        let text = "module X ( input [2:0] M0, output [0:0] M1 );\n\
                    reg [0:0] M1;\nalways @ (M0) begin\ncase (M0)\n\
                    3'd0: M1 = 1'b1;\nendcase\nend\nendmodule\n";
        assert!(parse_neuron_module(text).is_err());
    }
}
