//! Experiment drivers: one generator per table and figure in the paper's
//! evaluation (see DESIGN.md §4 for the index).  Each prints the same rows
//! the paper reports and writes a CSV under `reports/`.
//!
//! Trained models are cached as checkpoints in `reports/ckpt/`, so tables
//! that share a model train it once; `--retrain` forces fresh training.

use crate::cost;
use crate::data::DataSet;
use crate::hep;
use crate::luts::ModelTables;
use crate::metrics;
use crate::mnist;
use crate::nn::ExportedModel;
use crate::runtime::{artifacts_dir, Artifact, Manifest, Runtime};
use crate::sparsity::prune::PruneMethod;
use crate::synth::{synthesize, SynthOpts};
use crate::train::{self, checkpoint, evaluate, ModelState, TrainOpts};
use crate::util::table::{f2, kfmt, TextTable};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

pub struct ExpCtx {
    pub rt: Runtime,
    pub artifacts: PathBuf,
    /// Cap on training steps (fast mode); `None` = use manifest steps.
    pub step_cap: Option<usize>,
    pub retrain: bool,
    pub seed: u64,
    datasets: HashMap<String, (DataSet, DataSet)>,
    artifacts_cache: HashMap<String, Artifact>,
}

impl ExpCtx {
    pub fn new(fast: bool, retrain: bool) -> Result<ExpCtx> {
        Ok(ExpCtx {
            rt: Runtime::cpu()?,
            artifacts: artifacts_dir(),
            step_cap: if fast { Some(300) } else { None },
            retrain,
            seed: 0xEC0,
            datasets: HashMap::new(),
            artifacts_cache: HashMap::new(),
        })
    }

    pub fn artifact(&mut self, name: &str) -> Result<&Artifact> {
        if !self.artifacts_cache.contains_key(name) {
            let art = Artifact::load(&self.rt, &self.artifacts, name)
                .with_context(|| format!("artifact {name} (run `make artifacts`)"))?;
            self.artifacts_cache.insert(name.to_string(), art);
        }
        Ok(&self.artifacts_cache[name])
    }

    /// (train, test) split for the manifest's dataset.
    pub fn dataset(&mut self, kind: &str) -> &(DataSet, DataSet) {
        let seed = self.seed;
        self.datasets.entry(kind.to_string()).or_insert_with(|| dataset_split(kind, seed))
    }

    fn ckpt_path(&self, name: &str, method: PruneMethod) -> PathBuf {
        PathBuf::from("reports/ckpt").join(format!("{name}_{}.bin", method.name()))
    }

    /// Train (or load cached) model; returns the state and test metrics.
    pub fn trained(&mut self, name: &str, method: PruneMethod) -> Result<Trained> {
        let path = self.ckpt_path(name, method);
        let man = self.artifact(name)?.manifest.clone();
        let mut state = if !self.retrain && path.exists() {
            checkpoint::load(&path)?
        } else {
            let mut opts = TrainOpts::from_manifest(&man);
            opts.method = method;
            opts.seed = self.seed ^ name.len() as u64;
            if let Some(cap) = self.step_cap {
                // Synthetic digits converge much faster than the jet task;
                // spend the fast-mode budget where it matters.
                let cap = if man.dataset == "mnist" { cap.min(120) } else { cap.min(300) };
                opts.steps = opts.steps.min(cap.max(1));
            }
            let (train_set, _) = self.dataset(&man.dataset).clone();
            let mut st = ModelState::init(&man, self.seed, method);
            let art = self.artifact(name)?;
            let log = train::train(art, &mut st, &train_set, &opts)?;
            eprintln!(
                "[train] {name} ({}) {} steps, loss {:.3} -> {:.3}, {:.1}s",
                method.name(),
                log.steps,
                log.losses.first().map(|l| l.1).unwrap_or(0.0),
                log.final_loss,
                log.seconds
            );
            checkpoint::save(&st, &path)?;
            st
        };
        // Iterative pruning may leave masks above target on short runs;
        // enforce the target so export/LUT costs are honest.
        if let PruneMethod::Iterative { .. } = method {
            for (i, l) in man.layers.iter().enumerate() {
                if let Some(f) = l.fanin {
                    crate::sparsity::prune::magnitude_prune(&state.ws[i].clone(), &mut state.masks[i], f);
                    state.apply_mask(i);
                }
            }
        }
        let (_, test_set) = self.dataset(&man.dataset).clone();
        let art = self.artifact(name)?;
        let logits = evaluate(art, &state, &test_set)?;
        let accuracy = metrics::accuracy(&logits, &test_set.y, man.classes);
        Ok(Trained { man, state, logits, test_y: test_set.y.clone(), accuracy })
    }
}

/// Dataset kinds [`dataset_split`] understands — the list CLI validation
/// (e.g. `explore --dataset`) checks against, so adding a kind below is
/// one edit.
pub const DATASET_KINDS: &[&str] = &["jets", "mnist"];

/// Deterministic (train, test) split for a dataset kind — the single
/// source of truth shared by `ExpCtx` (paper tables/figures) and the DSE
/// search engine (`dse::search`), so a searched candidate's quality is
/// measured on exactly the split the hand-enumerated experiments use.
/// `ExpCtx` passes its own seed (`0xEC0` by default).  Panics on kinds
/// outside [`DATASET_KINDS`] (it backs the infallible `ExpCtx` path);
/// fallible callers validate against the list first.
pub fn dataset_split(kind: &str, seed: u64) -> (DataSet, DataSet) {
    match kind {
        "jets" => {
            let mut rng = crate::util::rng::Rng::new(seed ^ 1);
            hep::jets(24_000, 42).split(0.2, &mut rng)
        }
        "mnist" => mnist::load_or_synth(9_000, 1_800, 42),
        other => panic!("unknown dataset {other}"),
    }
}

pub struct Trained {
    pub man: Manifest,
    pub state: ModelState,
    pub logits: Vec<f32>,
    pub test_y: Vec<i32>,
    pub accuracy: f64,
}

impl Trained {
    pub fn auc_per_class(&self) -> Vec<f64> {
        let probs = metrics::softmax_rows(&self.logits, self.man.classes);
        metrics::auc_ovr(&probs, &self.test_y, self.man.classes)
    }

    pub fn avg_auc(&self) -> f64 {
        let a = self.auc_per_class();
        a.iter().sum::<f64>() / a.len() as f64
    }

    pub fn export(&self) -> ExportedModel {
        ExportedModel::from_state(&self.man, &self.state)
    }
}

fn save_table(t: &TextTable, name: &str) -> Result<()> {
    t.print();
    t.save_csv(&format!("reports/{name}.csv"))?;
    println!("[saved reports/{name}.csv]");
    Ok(())
}

// ---------------------------------------------------------------------------
// Chapter 1/2 tables (static)
// ---------------------------------------------------------------------------

pub fn table_1_1() -> Result<()> {
    let mut t = TextTable::new(
        "Table 1.1 — Xilinx UltraScale resources",
        &["Device", "CLB LUTs", "BRAMs (18Kb)", "DSP Slices"],
    );
    for (d, l, b, s) in [
        ("KU025", 145_440u64, 720u64, 1_152u64),
        ("KU060", 331_680, 2_160, 2_760),
        ("XCVU065", 358_080, 2_520, 600),
        ("KU115", 663_360, 4_320, 5_520),
        ("XCVU440", 2_532_960, 5_040, 2_880),
    ] {
        t.row(vec![d.into(), l.to_string(), b.to_string(), s.to_string()]);
    }
    save_table(&t, "table_1_1")
}

pub fn table_2_1() -> Result<()> {
    let mut t = TextTable::new(
        "Table 2.1 — static mapping cost to 6:1 LUTs",
        &["Fan-In", "Number of 6-LUTs", "Truth table bits", "LUT config bits", "% utilized"],
    );
    for fan_in in 6..=11 {
        let r = cost::static_map_row(fan_in);
        t.row(vec![
            fan_in.to_string(),
            r.num_6luts.to_string(),
            r.truth_table_bits.to_string(),
            r.lut_config_bits.to_string(),
            format!("{:.2}%", r.pct_utilized),
        ]);
    }
    save_table(&t, "table_2_1")
}

// ---------------------------------------------------------------------------
// Chapter 5 (design automation)
// ---------------------------------------------------------------------------

pub fn table_5_1() -> Result<()> {
    use crate::luts::neuron_table;
    use crate::nn::{Neuron, QuantSpec};
    let mut t = TextTable::new(
        "Table 5.1 — truth-table Verilog size/time per neuron",
        &["Bits", "File Size (MB)", "Time (seconds)"],
    );
    let mut rng = crate::util::rng::Rng::new(51);
    for bits in [15usize, 16, 18, 20] {
        let nr = Neuron {
            inputs: (0..bits).collect(),
            weights: (0..bits).map(|_| rng.normal_f32(0.0, 0.5)).collect(),
            bias: 0.05,
            g: 1.0,
            h: 0.0,
        };
        let t0 = std::time::Instant::now();
        let table = neuron_table(&nr, QuantSpec::new(1, 1.0), QuantSpec::new(1, 1.0))?;
        let text = crate::verilog::neuron_module("LUT_T51", &table);
        let secs = t0.elapsed().as_secs_f64();
        t.row(vec![
            bits.to_string(),
            format!("{:.2}", text.len() as f64 / 1e6),
            format!("{secs:.2}"),
        ]);
    }
    save_table(&t, "table_5_1")
}

pub fn table_5_2(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 5.2 — analytical vs synthesized LUT cost (combinational)",
        &["Model", "Analytical LUT cost", "LUTs After Synthesis", "Reduction", "Optimized", "Opt x"],
    );
    for name in ["hep_c", "t53_b", "t52_big"] {
        let tr = ctx.trained(name, PruneMethod::APriori)?;
        let ex = tr.export();
        let tables = ModelTables::generate(&ex)?;
        let base = SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() };
        let (_, rep) = synthesize(&ex, &tables, base)?;
        // The pipeline's extra reduction on top of the mapper's (the
        // Constantinides-2019 point: LUT-native nets win exactly when
        // logic optimization exploits their don't-cares).
        let (_, orep) = synthesize(
            &ex,
            &tables,
            SynthOpts { opt: crate::synth::OptLevel::Full, ..base },
        )?;
        t.row(vec![
            name.into(),
            rep.analytical_luts.to_string(),
            rep.luts.to_string(),
            format!("{:.2}x", rep.reduction),
            orep.luts.to_string(),
            format!("{:.2}x", rep.luts as f64 / orep.luts.max(1) as f64),
        ]);
    }
    save_table(&t, "table_5_2")
}

pub fn table_5_3(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 5.3 — resources with inter-layer registers (5 ns clock)",
        &["X", "BW", "HL", "Analytical LUTs", "LUT", "FF", "DSP", "BRAM", "WNS"],
    );
    for name in ["hep_c", "t53_b", "t53_c", "t53_d", "t53_e"] {
        let tr = ctx.trained(name, PruneMethod::APriori)?;
        let ex = tr.export();
        let tables = ModelTables::generate(&ex)?;
        let (_, rep) = synthesize(&ex, &tables, SynthOpts::default())?;
        let man = &tr.man;
        let hl = man.hidden.iter().map(|h| h.to_string()).collect::<Vec<_>>().join(", ");
        let analytical = cost::total_luts(&cost::manifest_cost(man));
        t.row(vec![
            man.fanin.to_string(),
            man.bw.to_string(),
            hl,
            analytical.to_string(),
            rep.luts.to_string(),
            rep.ffs.to_string(),
            rep.dsps.to_string(),
            rep.brams.to_string(),
            format!("{:.2}", rep.wns_ns),
        ]);
    }
    save_table(&t, "table_5_3")
}

// ---------------------------------------------------------------------------
// Chapter 6 (FPGA4HEP)
// ---------------------------------------------------------------------------

const HEP_MODELS: [&str; 5] = ["hep_a", "hep_b", "hep_c", "hep_d", "hep_e"];

pub fn table_6_1(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 6.1 — FPGA4HEP model descriptions",
        &["Model", "HL", "BW", "X", "Xfc", "BWfc", "LUTL1", "LUTL2", "LUTL3", "LUTL4"],
    );
    for (label, name) in ["A", "B", "C", "D", "E"].iter().zip(HEP_MODELS) {
        let man = ctx.artifact(name)?.manifest.clone();
        let costs = cost::manifest_cost(&man);
        let hl = format!("({})", man.hidden.iter().map(|h| h.to_string()).collect::<Vec<_>>().join(", "));
        let mut row = vec![
            label.to_string(),
            hl,
            man.bw.to_string(),
            man.fanin.to_string(),
            man.fanin_fc.map(|f| f.to_string()).unwrap_or("-".into()),
            man.bw_out.to_string(),
        ];
        for i in 0..4 {
            row.push(costs.get(i).map(|c| c.luts.to_string()).unwrap_or("-".into()));
        }
        t.row(row);
    }
    save_table(&t, "table_6_1")
}

pub fn table_6_2(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 6.2 — FPGA4HEP AUC-ROC and LUT cost",
        &["Model", "g", "q", "W", "Z", "t", "Avg AUC-ROC", "Acc", "LUTs", "% FC"],
    );
    for (label, name) in ["A", "B", "C", "D", "E"].iter().zip(HEP_MODELS) {
        let tr = ctx.trained(name, PruneMethod::APriori)?;
        let aucs = tr.auc_per_class();
        let costs = cost::manifest_cost(&tr.man);
        let total = cost::total_luts(&costs);
        let fc_pct = if tr.man.fanin_fc.is_none() {
            100.0 * costs.last().unwrap().luts as f64 / total as f64
        } else {
            100.0 * costs.last().unwrap().luts as f64 / total as f64
        };
        let mut row = vec![label.to_string()];
        row.extend(aucs.iter().map(|a| f2(100.0 * a)));
        row.push(f2(100.0 * tr.avg_auc()));
        row.push(f2(100.0 * tr.accuracy));
        row.push(total.to_string());
        row.push(f2(fc_pct));
        t.row(row);
    }
    save_table(&t, "table_6_2")
}

pub fn table_6_3(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 6.3 — a-priori fixed sparsity vs iterative pruning (avg AUC)",
        &["Model", "LUTs", "A-Priori Fixed Sparsity", "Iterative Pruning"],
    );
    for name in ["hep_c", "hep_d", "hep_e"] {
        let ap = ctx.trained(name, PruneMethod::APriori)?;
        let it = ctx.trained(name, PruneMethod::Iterative { every: 10 })?;
        let luts = cost::total_luts(&cost::manifest_cost(&ap.man));
        t.row(vec![
            name.into(),
            luts.to_string(),
            f2(100.0 * ap.avg_auc()),
            f2(100.0 * it.avg_auc()),
        ]);
    }
    save_table(&t, "table_6_3")
}

pub fn figure_6_5(ctx: &mut ExpCtx) -> Result<()> {
    let tr = ctx.trained("hep_a", PruneMethod::APriori)?;
    let probs = metrics::softmax_rows(&tr.logits, tr.man.classes);
    let mut t = TextTable::new(
        "Figure 6.5 — ROC points (model A, one-vs-rest)",
        &["class", "fpr", "tpr"],
    );
    for (k, cname) in hep::CLASS_NAMES.iter().enumerate() {
        for (fpr, tpr) in metrics::roc_curve(&probs, &tr.test_y, tr.man.classes, k, 40) {
            t.row(vec![cname.to_string(), format!("{fpr:.4}"), format!("{tpr:.4}")]);
        }
    }
    t.save_csv("reports/figure_6_5_roc.csv")?;
    println!("[saved reports/figure_6_5_roc.csv — {} points]", t.to_csv().lines().count() - 1);
    // Confusion matrix.
    let cm = metrics::confusion(&tr.logits, &tr.test_y, tr.man.classes, true);
    let mut ct = TextTable::new(
        "Figure 6.5 — normalized confusion matrix (model A)",
        &["true\\pred", "g", "q", "W", "Z", "t"],
    );
    for (k, row) in cm.iter().enumerate() {
        let mut cells = vec![hep::CLASS_NAMES[k].to_string()];
        cells.extend(row.iter().map(|v| f2(*v)));
        ct.row(cells);
    }
    save_table(&ct, "figure_6_5_confusion")
}

pub fn figure_6_6(ctx: &mut ExpCtx) -> Result<()> {
    let tr = ctx.trained("hep_a", PruneMethod::APriori)?;
    let probs = metrics::softmax_rows(&tr.logits, tr.man.classes);
    let raw_auc = metrics::auc_ovr(&tr.logits, &tr.test_y, tr.man.classes);
    let sm_auc = metrics::auc_ovr(&probs, &tr.test_y, tr.man.classes);
    let mut t = TextTable::new(
        "Figure 6.6 — AUC with and without final SoftMax (model A)",
        &["class", "AUC no softmax", "AUC with softmax"],
    );
    for (k, cname) in hep::CLASS_NAMES.iter().enumerate() {
        t.row(vec![cname.to_string(), f2(100.0 * raw_auc[k]), f2(100.0 * sm_auc[k])]);
    }
    save_table(&t, "figure_6_6")
}

pub fn figure_6_7(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Figure 6.7 — accuracy vs analytical LUT cost (HEP grid)",
        &["model", "bw", "fanin", "hidden", "LUTs", "avg AUC", "accuracy"],
    );
    for bw in 1..=3usize {
        for x in 3..=5usize {
            for h in 0..=1usize {
                let name = format!("hep_s_b{bw}_x{x}_h{h}");
                let tr = ctx.trained(&name, PruneMethod::APriori)?;
                let luts = cost::total_luts(&cost::manifest_cost(&tr.man));
                t.row(vec![
                    name.clone(),
                    bw.to_string(),
                    x.to_string(),
                    format!("{:?}", tr.man.hidden),
                    luts.to_string(),
                    f2(100.0 * tr.avg_auc()),
                    f2(100.0 * tr.accuracy),
                ]);
            }
        }
    }
    save_table(&t, "figure_6_7")
}

pub fn figure_6_8(ctx: &mut ExpCtx) -> Result<()> {
    // Aggregates figure_6_7's sweep by bit-width.
    let mut t = TextTable::new(
        "Figure 6.8 — accuracy vs activation bit-width (HEP grid)",
        &["bw", "mean avg-AUC", "max avg-AUC"],
    );
    for bw in 1..=3usize {
        let mut aucs = Vec::new();
        for x in 3..=5usize {
            for h in 0..=1usize {
                let name = format!("hep_s_b{bw}_x{x}_h{h}");
                aucs.push(ctx.trained(&name, PruneMethod::APriori)?.avg_auc());
            }
        }
        let mean = aucs.iter().sum::<f64>() / aucs.len() as f64;
        let max = aucs.iter().cloned().fold(0.0, f64::max);
        t.row(vec![bw.to_string(), f2(100.0 * mean), f2(100.0 * max)]);
    }
    save_table(&t, "figure_6_8")
}

// ---------------------------------------------------------------------------
// Chapter 7 (MNIST)
// ---------------------------------------------------------------------------

pub fn table_7_1(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 7.1 — MNIST MLPs: analytical LUT breakdown and accuracy",
        &["HL", "BW", "X", "LUTL1", "LUTL2", "LUTL3", "LUTL4", "LUTs", "Accuracy"],
    );
    for w in [512usize, 1024, 2048] {
        for d in [1usize, 2, 3] {
            let name = format!("mnist_w{w}_d{d}");
            let tr = ctx.trained(&name, PruneMethod::APriori)?;
            let costs = cost::manifest_cost(&tr.man);
            let total = cost::total_luts(&costs);
            let mut row = vec![
                format!("({w})x{d}"),
                tr.man.bw.to_string(),
                tr.man.fanin.to_string(),
            ];
            for i in 0..4 {
                row.push(costs.get(i).map(|c| kfmt(c.luts as f64)).unwrap_or("-".into()));
            }
            row.push(kfmt(total as f64));
            row.push(f2(100.0 * tr.accuracy));
            t.row(row);
        }
    }
    save_table(&t, "table_7_1")
}

pub fn figure_7_1(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Figure 7.1 — analytical LUT cost vs accuracy (MNIST MLPs)",
        &["model", "LUTs", "accuracy"],
    );
    let mut names: Vec<String> = Vec::new();
    for w in [512usize, 1024, 2048] {
        for d in [1usize, 2, 3] {
            names.push(format!("mnist_w{w}_d{d}"));
        }
    }
    names.extend(["mnist_x4", "mnist_x6", "mnist_bw1", "mnist_bw3"].map(String::from));
    for name in names {
        let tr = ctx.trained(&name, PruneMethod::APriori)?;
        let luts = cost::total_luts(&cost::manifest_cost(&tr.man));
        t.row(vec![name, luts.to_string(), f2(100.0 * tr.accuracy)]);
    }
    save_table(&t, "figure_7_1")
}

pub fn figure_7_2(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Figure 7.2 — accuracy vs bit-width (3-layer 1024 MLP)",
        &["bw", "accuracy"],
    );
    for (bw, name) in [(1usize, "mnist_bw1"), (2, "mnist_w1024_d3"), (3, "mnist_bw3")] {
        let tr = ctx.trained(name, PruneMethod::APriori)?;
        t.row(vec![bw.to_string(), f2(100.0 * tr.accuracy)]);
    }
    save_table(&t, "figure_7_2")
}

pub fn table_7_2(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 7.2 — pruning techniques on MNIST (accuracy)",
        &["Model", "A-Priori Fixed Sparsity", "Momentum Sparsity", "Iterative Pruning"],
    );
    for (label, name) in [
        ("A", "mnist_w512_d3"),
        ("B", "mnist_w1024_d2"),
        ("C", "mnist_w512_d1"),
    ] {
        let ap = ctx.trained(name, PruneMethod::APriori)?;
        let mo = ctx.trained(name, PruneMethod::Momentum { every: 8, prune_rate: 0.3 })?;
        let it = ctx.trained(name, PruneMethod::Iterative { every: 8 })?;
        t.row(vec![
            format!("{label} ({name})"),
            f2(100.0 * ap.accuracy),
            f2(100.0 * mo.accuracy),
            f2(100.0 * it.accuracy),
        ]);
    }
    save_table(&t, "table_7_2")
}

pub fn table_7_3(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 7.3 — skip connections on 3-layer MLPs (accuracy)",
        &["Model", "No Skip", "1 Skip", "2 Skips"],
    );
    for tag in ["a", "b", "c", "d"] {
        let mut row = vec![format!("mnist_skip{tag}")];
        for s in 0..3 {
            let tr = ctx.trained(&format!("mnist_skip{tag}_s{s}"), PruneMethod::APriori)?;
            row.push(f2(100.0 * tr.accuracy));
        }
        t.row(row);
    }
    save_table(&t, "table_7_3")
}

pub fn table_7_4(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 7.4 — convolution variants (accuracy)",
        &["Variant", "A", "B", "C"],
    );
    for (label, mtag) in [
        ("FP", "fp"),
        ("FP_DW", "fpdw"),
        ("FP_X_DW", "fpxdw"),
        ("QUANT_X_DW", "qxdw"),
    ] {
        let mut row = vec![label.to_string()];
        for m in ["a", "b", "c"] {
            let tr = ctx.trained(&format!("cnn_{m}_{mtag}"), PruneMethod::APriori)?;
            row.push(f2(100.0 * tr.accuracy));
        }
        t.row(row);
    }
    save_table(&t, "table_7_4")
}

pub fn table_7_5(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 7.5 — CNN LUT cost and accuracy",
        &["Model", "BW", "X (Xk,Xs)", "LUTs", "Accuracy"],
    );
    for (label, name) in [("A", "cnn_t75_a"), ("B", "cnn_t75_b"), ("C", "cnn_t75_c"), ("D", "cnn_t75_d")] {
        let tr = ctx.trained(name, PruneMethod::APriori)?;
        let man = &tr.man;
        let h1 = (man.image_hw + 1) / 2;
        let h2 = (h1 + 1) / 2;
        let (c1o, f1o, f2o) = (man.channels[0], man.channels[1], man.channels[2]);
        let xk = man.fanin_dw.unwrap_or(0);
        let xs = man.fanin_pw.unwrap_or(0);
        let luts = cost::conv_dw_cost(h1 * h1, man.bw, c1o, xk, man.bw_in)
            + cost::conv_pw_cost(h1 * h1, man.bw, f1o, xs, man.bw)
            + cost::conv_dw_cost(h2 * h2, man.bw, f1o, xk, man.bw)
            + cost::conv_pw_cost(h2 * h2, man.bw, f2o, xs, man.bw)
            + cost::dense_layer_cost(man.classes, h2 * h2 * f2o, man.bw, cost::DENSE_BW_WT);
        t.row(vec![
            label.into(),
            man.bw.to_string(),
            format!("({xk},{xs})"),
            kfmt(luts as f64),
            f2(100.0 * tr.accuracy),
        ]);
    }
    save_table(&t, "table_7_5")
}

pub fn table_7_6(ctx: &mut ExpCtx) -> Result<()> {
    let mut t = TextTable::new(
        "Table 7.6 — skip connections on LogicNet CNNs (accuracy)",
        &["Model", "No Skip", "1 Skip", "2 Skips"],
    );
    for m in ["a", "b", "c"] {
        let mut row = vec![format!("cnn_{m}")];
        let s0 = ctx.trained(&format!("cnn_{m}_qxdw"), PruneMethod::APriori)?;
        row.push(f2(100.0 * s0.accuracy));
        for s in 1..=2 {
            let tr = ctx.trained(&format!("cnn_{m}_qxdw_s{s}"), PruneMethod::APriori)?;
            row.push(f2(100.0 * tr.accuracy));
        }
        t.row(row);
    }
    save_table(&t, "table_7_6")
}

// ---------------------------------------------------------------------------
// Netlist-backed serving (bitsliced simulation surface)
// ---------------------------------------------------------------------------

/// Score mapped designs on their full test set through every execution
/// surface: the arithmetic mirror, the truth-table engine, and the
/// synthesized netlist run by the bitsliced simulator.  The three accuracy
/// columns must agree — this is functional verification at dataset scale,
/// which the one-sample scalar `Netlist::eval` path made impractically
/// slow.  `opt` runs the netlist-optimization pipeline before serving (the
/// accuracy parity then also validates the optimizer at dataset scale).
/// Models whose topology the netlist backend cannot serve (skip wiring,
/// non-prefix sparse layers) report `-`.
pub fn report_netlist_serving(
    ctx: &mut ExpCtx,
    names: &[String],
    opt: crate::synth::OptLevel,
) -> Result<()> {
    use crate::serve::{batch_accuracy, LutEngine, NetlistEngine};
    let mut t = TextTable::new(
        "Netlist-backed serving — accuracy parity and mapped size",
        &["Model", "Arithmetic acc", "Table engine acc", "Netlist acc", "Mapped LUTs"],
    );
    for name in names {
        let tr = ctx.trained(name, PruneMethod::APriori)?;
        let ex = tr.export();
        let tables = ModelTables::generate(&ex)?;
        let (_, test) = ctx.dataset(&tr.man.dataset);
        let test = test.clone();
        let lut_acc = match LutEngine::build(&ex, &tables) {
            Ok(engine) => f2(100.0 * batch_accuracy(&engine, &test.x, &test.y)),
            Err(_) => "-".into(),
        };
        let (net_acc, luts) = match NetlistEngine::build_opt(&ex, &tables, opt) {
            Ok(engine) => (
                f2(100.0 * batch_accuracy(&engine, &test.x, &test.y)),
                engine.num_luts().to_string(),
            ),
            Err(_) => ("-".into(), "-".into()),
        };
        t.row(vec![name.clone(), f2(100.0 * tr.accuracy), lut_acc, net_acc, luts]);
    }
    save_table(&t, "netlist_serving")
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

pub fn run_table(ctx: &mut ExpCtx, id: &str) -> Result<()> {
    match id {
        "1.1" => table_1_1(),
        "2.1" => table_2_1(),
        "5.1" => table_5_1(),
        "5.2" => table_5_2(ctx),
        "5.3" => table_5_3(ctx),
        "6.1" => table_6_1(ctx),
        "6.2" => table_6_2(ctx),
        "6.3" => table_6_3(ctx),
        "7.1" => table_7_1(ctx),
        "7.2" => table_7_2(ctx),
        "7.3" => table_7_3(ctx),
        "7.4" => table_7_4(ctx),
        "7.5" => table_7_5(ctx),
        "7.6" => table_7_6(ctx),
        other => bail!("unknown table {other}"),
    }
}

pub fn run_figure(ctx: &mut ExpCtx, id: &str) -> Result<()> {
    match id {
        "6.5" => figure_6_5(ctx),
        "6.6" => figure_6_6(ctx),
        "6.7" => figure_6_7(ctx),
        "6.8" => figure_6_8(ctx),
        "7.1" => figure_7_1(ctx),
        "7.2" => figure_7_2(ctx),
        other => bail!("unknown figure {other}"),
    }
}

pub const ALL_TABLES: [&str; 14] = [
    "1.1", "2.1", "5.1", "5.2", "5.3", "6.1", "6.2", "6.3", "7.1", "7.2", "7.3", "7.4",
    "7.5", "7.6",
];
pub const ALL_FIGURES: [&str; 6] = ["6.5", "6.6", "6.7", "6.8", "7.1", "7.2"];
