//! Truth-table generation (paper ch. 5) and functional verification.
//!
//! After training, every sparse neuron is enumerated into its truth table:
//! for all `2^(fanin*bw_in)` input code patterns, dequantize, run the folded
//! neuron, and quantize the response into the output code.  Dense layers
//! (the classifier head) stay arithmetic — the paper costs them with
//! eq. 4.1 and does not tabulate them.
//!
//! `forward_codes` executes the model *through the tables* (the paper's
//! `use_table=True` functional-verification path) and must agree exactly
//! with `ExportedModel::forward`, because both evaluate the identical
//! folded-neuron math.

use crate::nn::{ExportedModel, QuantSpec};
use crate::util::bits::{pack_index, unpack_index, PackedCodes};
use crate::util::pool::par_map;
use anyhow::{ensure, Result};

/// Hard cap on a single neuron's truth-table input bits (2^24 entries).
pub const MAX_IN_BITS: usize = 24;

/// One neuron's truth table: output codes indexed by packed input codes.
#[derive(Debug, Clone)]
pub struct NeuronTable {
    pub in_bits: usize,
    pub out_bits: usize,
    pub fanin: usize,
    pub bw_in: usize,
    pub codes: PackedCodes,
}

impl NeuronTable {
    #[inline]
    pub fn lookup(&self, idx: usize) -> u32 {
        self.codes.get(idx)
    }

    pub fn num_entries(&self) -> usize {
        1usize << self.in_bits
    }

    pub fn size_bytes(&self) -> usize {
        self.codes.size_bytes()
    }

    /// Extract one output bit as a packed boolean function (for synthesis).
    pub fn output_bit_fn(&self, bit: usize) -> Vec<u64> {
        assert!(bit < self.out_bits);
        let n = self.num_entries();
        let mut words = vec![0u64; n.div_ceil(64)];
        for idx in 0..n {
            if (self.codes.get(idx) >> bit) & 1 == 1 {
                words[idx / 64] |= 1u64 << (idx % 64);
            }
        }
        words
    }
}

/// Generate the truth table of one exported neuron whose whole input comes
/// from one quantizer.
pub fn neuron_table(
    nr: &crate::nn::Neuron,
    quant_in: QuantSpec,
    quant_out: QuantSpec,
) -> Result<NeuronTable> {
    let specs = vec![quant_in; nr.fanin()];
    neuron_table_specs(nr, &specs, quant_out)
}

/// Generate the truth table with a per-fan-in-position input quantizer
/// (skip connections concatenate segments with different scales; all specs
/// must share one bit-width so packing stays uniform).
pub fn neuron_table_specs(
    nr: &crate::nn::Neuron,
    specs: &[QuantSpec],
    quant_out: QuantSpec,
) -> Result<NeuronTable> {
    let fanin = nr.fanin();
    ensure!(specs.len() == fanin, "one quant spec per fan-in position");
    let bw_in = specs.first().map(|s| s.bw).unwrap_or(1);
    ensure!(specs.iter().all(|s| s.bw == bw_in), "mixed input bit-widths");
    let in_bits = fanin * bw_in;
    ensure!(
        in_bits <= MAX_IN_BITS,
        "neuron truth table too large: {in_bits} input bits (fanin {fanin} x bw {bw_in})"
    );
    let entries = 1usize << in_bits;
    let mut codes = PackedCodes::new(entries, quant_out.bw);
    // Dequantized value per (position, code), precomputed once.
    let ncodes = 1usize << bw_in;
    let mut dequant = vec![0f32; fanin * ncodes];
    for (j, s) in specs.iter().enumerate() {
        for c in 0..ncodes as u32 {
            dequant[j * ncodes + c as usize] = s.dequant(c);
        }
    }
    let mut in_codes = vec![0u32; fanin];
    let mut vals = vec![0f32; fanin];
    for idx in 0..entries {
        unpack_index(idx, bw_in, fanin, &mut in_codes);
        for (j, (v, &c)) in vals.iter_mut().zip(&in_codes).enumerate() {
            *v = dequant[j * ncodes + c as usize];
        }
        let y = nr.respond(&vals);
        codes.set(idx, quant_out.code(y));
    }
    Ok(NeuronTable { in_bits, out_bits: quant_out.bw, fanin, bw_in, codes })
}

#[derive(Debug, Clone)]
pub struct LayerTables {
    pub tables: Vec<NeuronTable>,
    pub quant_in: QuantSpec,
    pub quant_out: QuantSpec,
}

impl LayerTables {
    pub fn size_bytes(&self) -> usize {
        self.tables.iter().map(|t| t.size_bytes()).sum()
    }
}

/// All table-mapped layers of a model (`None` = dense layer, kept
/// arithmetic).
#[derive(Debug, Clone)]
pub struct ModelTables {
    pub layers: Vec<Option<LayerTables>>,
}

impl ModelTables {
    /// Generate tables for every sparse layer, neurons in parallel.
    pub fn generate(model: &ExportedModel) -> Result<ModelTables> {
        let which: Vec<usize> = model
            .layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.sparse)
            .map(|(i, _)| i)
            .collect();
        Self::generate_layers(model, &which)
    }

    /// Generate for specific layers only (paper: per-layer generation for
    /// inspection of large models).
    pub fn generate_layers(model: &ExportedModel, which: &[usize]) -> Result<ModelTables> {
        let mut layers: Vec<Option<LayerTables>> =
            (0..model.num_layers()).map(|_| None).collect();
        for &i in which {
            let layer = &model.layers[i];
            ensure!(layer.sparse, "layer {i} is dense; tables not applicable");
            let results = par_map(&layer.neurons, |_, nr| {
                let specs: Vec<QuantSpec> =
                    nr.inputs.iter().map(|&j| layer.input_specs[j]).collect();
                neuron_table_specs(nr, &specs, layer.quant_out)
            });
            let mut tables = Vec::with_capacity(results.len());
            for r in results {
                tables.push(r?);
            }
            layers[i] = Some(LayerTables {
                tables,
                quant_in: layer.quant_in,
                quant_out: layer.quant_out,
            });
        }
        Ok(ModelTables { layers })
    }

    pub fn size_bytes(&self) -> usize {
        self.layers.iter().flatten().map(|l| l.size_bytes()).sum()
    }

    pub fn num_tables(&self) -> usize {
        self.layers.iter().flatten().map(|l| l.tables.len()).sum()
    }

    /// Forward pass *through the truth tables* on one sample.  Sparse layers
    /// are evaluated by table lookup on codes; dense layers arithmetically.
    /// Returns final quantized logit values.
    pub fn forward_codes(&self, model: &ExportedModel, x: &[f32]) -> Vec<f32> {
        let mut scratch = ForwardScratch::default();
        self.forward_codes_with(model, x, &mut scratch).to_vec()
    }

    /// Allocation-reusing forward pass: all per-layer activation code
    /// vectors, the skip-concat input, the gathered fan-in, and the output
    /// values live in `scratch` — after the first call, repeated
    /// verification never allocates.  Activations are tracked as codes
    /// (the code domain mirrors the value domain: value = dequant(code)).
    pub fn forward_codes_with<'a>(
        &self,
        model: &ExportedModel,
        x: &[f32],
        scratch: &'a mut ForwardScratch,
    ) -> &'a [f32] {
        let n = model.num_layers();
        let q0 = model.layers[0].quant_in;
        if scratch.acts.len() < n {
            scratch.acts.resize_with(n, Vec::new);
        }
        {
            let a = &mut scratch.acts[0];
            a.clear();
            a.extend(x.iter().map(|&v| q0.code(v)));
        }
        for i in 0..n {
            let layer = &model.layers[i];
            // Skip wiring: newest-first concat of the last skips+1 acts.
            scratch.input.clear();
            if i == 0 || model.skips == 0 {
                scratch.input.extend_from_slice(&scratch.acts[i]);
            } else {
                let lo = i.saturating_sub(model.skips);
                for j in (lo..=i).rev() {
                    scratch.input.extend_from_slice(&scratch.acts[j]);
                }
            }
            debug_assert_eq!(scratch.input.len(), layer.in_f);
            let is_last = i + 1 == n;
            let mut out_codes = if is_last {
                std::mem::take(&mut scratch.last)
            } else {
                std::mem::take(&mut scratch.acts[i + 1])
            };
            out_codes.clear();
            let input = &scratch.input;
            match &self.layers[i] {
                Some(lt) => {
                    for (nr, tbl) in layer.neurons.iter().zip(&lt.tables) {
                        scratch.gathered.clear();
                        scratch.gathered.extend(nr.inputs.iter().map(|&j| input[j]));
                        let idx = pack_index(&scratch.gathered, lt.quant_in.bw);
                        out_codes.push(tbl.lookup(idx));
                    }
                }
                None => {
                    // Dense (or un-tabulated) layer: arithmetic on values,
                    // dequantizing each element with its own source spec.
                    scratch.vals.clear();
                    scratch.vals.extend(
                        input.iter().enumerate().map(|(e, &c)| layer.input_specs[e].dequant(c)),
                    );
                    for nr in &layer.neurons {
                        let y = nr.respond_gather(&scratch.vals);
                        out_codes.push(layer.quant_out.code(y));
                    }
                }
            }
            if is_last {
                scratch.out.clear();
                scratch.out.extend(out_codes.iter().map(|&c| layer.quant_out.dequant(c)));
                scratch.last = out_codes;
            } else {
                scratch.acts[i + 1] = out_codes;
            }
        }
        &scratch.out
    }

    /// Functional verification (paper §4.2): run `xs` through both the
    /// tables and the arithmetic mirror; returns the number of samples whose
    /// outputs differ anywhere.  Samples are split across the worker pool
    /// in contiguous chunks; each worker owns one reusable
    /// [`ForwardScratch`], so the sweep is allocation-light and lock-free
    /// (one atomic add per chunk).
    pub fn verify(&self, model: &ExportedModel, xs: &[f32]) -> usize {
        let d = model.in_features;
        assert_eq!(xs.len() % d, 0, "xs length must be a multiple of in_features");
        let n = xs.len() / d;
        let mismatches = std::sync::atomic::AtomicUsize::new(0);
        crate::util::pool::par_chunks(n, |_, range| {
            let mut scratch = ForwardScratch::default();
            let mut local = 0usize;
            for i in range {
                let row = &xs[i * d..(i + 1) * d];
                let a = self.forward_codes_with(model, row, &mut scratch);
                let b = model.forward(row);
                if a != b.as_slice() {
                    local += 1;
                }
            }
            mismatches.fetch_add(local, std::sync::atomic::Ordering::Relaxed);
        });
        mismatches.into_inner()
    }
}

/// Reusable buffers for [`ModelTables::forward_codes_with`].
#[derive(Default)]
pub struct ForwardScratch {
    /// `acts[i]` holds stage i's input activation codes.
    acts: Vec<Vec<u32>>,
    /// Skip-concatenated input of the current layer.
    input: Vec<u32>,
    /// Gathered fan-in codes of the current neuron.
    gathered: Vec<u32>,
    /// Dequantized input values for dense layers.
    vals: Vec<f32>,
    /// Final-layer codes.
    last: Vec<u32>,
    /// Final dequantized logit values (the returned slice).
    out: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Neuron;

    fn neuron() -> Neuron {
        Neuron {
            inputs: vec![0, 1, 2],
            weights: vec![1.0, -0.5, 0.25],
            bias: 0.1,
            g: 1.2,
            h: -0.3,
        }
    }

    #[test]
    fn table_matches_direct_eval() {
        let qi = QuantSpec::new(2, 1.0);
        let qo = QuantSpec::new(2, 2.0);
        let nr = neuron();
        let t = neuron_table(&nr, qi, qo).unwrap();
        assert_eq!(t.in_bits, 6);
        assert_eq!(t.num_entries(), 64);
        let mut codes = [0u32; 3];
        for idx in 0..64 {
            unpack_index(idx, 2, 3, &mut codes);
            let vals: Vec<f32> = codes.iter().map(|&c| qi.dequant(c)).collect();
            let expect = qo.code(nr.respond(&vals));
            assert_eq!(t.lookup(idx), expect, "idx {idx}");
        }
    }

    #[test]
    fn output_bit_fn_consistent() {
        let qi = QuantSpec::new(2, 1.0);
        let qo = QuantSpec::new(2, 2.0);
        let t = neuron_table(&neuron(), qi, qo).unwrap();
        let bit0 = t.output_bit_fn(0);
        let bit1 = t.output_bit_fn(1);
        for idx in 0..t.num_entries() {
            let c = t.lookup(idx);
            assert_eq!((bit0[idx / 64] >> (idx % 64)) & 1, (c & 1) as u64);
            assert_eq!((bit1[idx / 64] >> (idx % 64)) & 1, ((c >> 1) & 1) as u64);
        }
    }

    #[test]
    fn rejects_oversized_tables() {
        let nr = Neuron {
            inputs: (0..13).collect(),
            weights: vec![0.1; 13],
            bias: 0.0,
            g: 1.0,
            h: 0.0,
        };
        let qi = QuantSpec::new(2, 1.0); // 26 bits > 24
        assert!(neuron_table(&nr, qi, QuantSpec::new(2, 2.0)).is_err());
    }

    #[test]
    fn mixed_input_specs_table() {
        // Regression: skip wiring mixes quantizer scales (maxv 1.0 input
        // segment vs 2.0 hidden segment); the table must dequantize each
        // position with its own spec.
        let qo = QuantSpec::new(2, 2.0);
        let nr = Neuron {
            inputs: vec![0, 1],
            weights: vec![1.0, 1.0],
            bias: 0.0,
            g: 1.0,
            h: 0.0,
        };
        let specs = [QuantSpec::new(2, 2.0), QuantSpec::new(2, 1.0)];
        let t = neuron_table_specs(&nr, &specs, qo).unwrap();
        let uniform = neuron_table(&nr, QuantSpec::new(2, 2.0), qo).unwrap();
        // Some entry must differ because position 1 has half the scale.
        let differs = (0..t.num_entries()).any(|i| t.lookup(i) != uniform.lookup(i));
        assert!(differs);
        // Spot-check: codes (3, 3) -> values (2.0, 1.0) -> y = 3.0 -> code 3
        let idx = crate::util::bits::pack_index(&[3, 3], 2);
        assert_eq!(t.lookup(idx), qo.code(3.0));
    }

    #[test]
    fn bit1_hardtanh_table() {
        let qi = QuantSpec::new(1, 1.0);
        let qo = QuantSpec::new(1, 1.0);
        // y = x0 (identity on the single input's sign)
        let nr = Neuron { inputs: vec![0], weights: vec![1.0], bias: 0.0, g: 1.0, h: 0.0 };
        let t = neuron_table(&nr, qi, qo).unwrap();
        assert_eq!(t.num_entries(), 2);
        assert_eq!(t.lookup(0), 0); // input -1 -> negative -> code 0
        assert_eq!(t.lookup(1), 1);
    }
}
