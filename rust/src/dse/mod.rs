//! Design-space exploration (paper §6 "axes of exploration" and §3.3).
//!
//! Operates on (cost, quality) points produced by the experiment sweeps:
//! Pareto-frontier extraction, dominated-point analysis and the
//! Erdős–Rényi "ensembling" arithmetic of §3.3.2 (how many sparse small
//! layers can be afforded for the LUT budget of one larger layer).

use crate::cost;

/// One evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub name: String,
    pub luts: u64,
    /// Higher is better (accuracy or avg AUC, in percent).
    pub quality: f64,
}

/// Pareto-optimal subset (minimal LUTs, maximal quality), sorted by cost.
/// Ties on cost keep the best quality.
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<DesignPoint> {
    let mut sorted: Vec<&DesignPoint> = points.iter().collect();
    sorted.sort_by(|a, b| a.luts.cmp(&b.luts).then(b.quality.partial_cmp(&a.quality).unwrap()));
    let mut out: Vec<DesignPoint> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for p in sorted {
        if p.quality > best {
            out.push(p.clone());
            best = p.quality;
        }
    }
    out
}

/// Points strictly dominated by some other point (≥ cost and ≤ quality,
/// with at least one strict) — the paper's "million-LUT models that barely
/// beat 2.5k-LUT models" (Fig. 6.7 discussion).
pub fn dominated<'a>(points: &'a [DesignPoint]) -> Vec<&'a DesignPoint> {
    points
        .iter()
        .filter(|p| {
            points.iter().any(|q| {
                (q.luts <= p.luts && q.quality > p.quality)
                    || (q.luts < p.luts && q.quality >= p.quality)
            })
        })
        .collect()
}

/// For each frontier point, LUTs spent per extra quality point relative to
/// the previous frontier point (the "knee" detector).
pub fn marginal_cost(frontier: &[DesignPoint]) -> Vec<(String, f64)> {
    frontier
        .windows(2)
        .map(|w| {
            let dl = (w[1].luts - w[0].luts) as f64;
            let dq = (w[1].quality - w[0].quality).max(1e-9);
            (w[1].name.clone(), dl / dq)
        })
        .collect()
}

/// §3.3.2: how many layers of (n2 neurons, b2 fan-in bits, m out bits) can
/// be "ensembled" within the LUT budget of one (n1, b1, m) layer.
pub fn ensemble_count(
    n1: usize,
    b1_bits: usize,
    n2: usize,
    b2_bits: usize,
    m_bits: usize,
) -> f64 {
    let c1 = cost::lut_cost(b1_bits, m_bits) as f64 * n1 as f64;
    let c2 = cost::lut_cost(b2_bits, m_bits) as f64 * n2 as f64;
    if c2 <= 0.0 {
        return f64::INFINITY;
    }
    c1 / c2
}

/// Load design points from an experiment CSV with columns containing
/// "LUTs"-like and quality-like headers (figure_6_7 / figure_7_1 outputs).
pub fn points_from_csv(csv: &str, name_col: usize, lut_col: usize, q_col: usize) -> Vec<DesignPoint> {
    let mut out = Vec::new();
    for (i, line) in csv.lines().enumerate() {
        if i == 0 {
            continue; // header
        }
        let cells: Vec<&str> = line.split(',').collect();
        if cells.len() <= q_col.max(lut_col).max(name_col) {
            continue;
        }
        let (Ok(luts), Ok(q)) = (
            cells[lut_col].trim().parse::<f64>(),
            cells[q_col].trim().parse::<f64>(),
        ) else {
            continue;
        };
        out.push(DesignPoint {
            name: cells[name_col].trim().to_string(),
            luts: luts as u64,
            quality: q,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Vec<DesignPoint> {
        [
            ("a", 100u64, 80.0),
            ("b", 200, 85.0),
            ("c", 150, 70.0),  // dominated by a (cheaper, better)... no: a cheaper AND better? a=100/80 vs c=150/70: dominated.
            ("d", 1000, 86.0),
            ("e", 1000, 84.0), // dominated by d
            ("f", 50, 60.0),
        ]
        .into_iter()
        .map(|(n, l, q)| DesignPoint { name: n.into(), luts: l, quality: q })
        .collect()
    }

    #[test]
    fn frontier_is_monotone_and_minimal() {
        let f = pareto_frontier(&pts());
        let names: Vec<&str> = f.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["f", "a", "b", "d"]);
        assert!(f.windows(2).all(|w| w[0].luts <= w[1].luts && w[0].quality < w[1].quality));
    }

    #[test]
    fn dominated_points_found() {
        let pts = pts();
        let d = dominated(&pts);
        let names: Vec<&str> = d.iter().map(|p| p.name.as_str()).collect();
        assert!(names.contains(&"c"));
        assert!(names.contains(&"e"));
        assert!(!names.contains(&"a"));
    }

    #[test]
    fn marginal_cost_grows_at_the_tail() {
        let f = pareto_frontier(&pts());
        let mc = marginal_cost(&f);
        // d costs far more per quality point than b
        let b = mc.iter().find(|(n, _)| n == "b").unwrap().1;
        let d = mc.iter().find(|(n, _)| n == "d").unwrap().1;
        assert!(d > b);
    }

    #[test]
    fn ensemble_arithmetic() {
        // One 64-neuron 12-bit layer buys ~4 x 64-neuron 10-bit layers
        // (lut_cost(12,2)=170 vs lut_cost(10,2)=42).
        let k = ensemble_count(64, 12, 64, 10, 2);
        assert!(k > 3.9 && k < 4.2, "{k}");
    }

    #[test]
    fn csv_parsing() {
        let csv = "model,bw,fanin,hidden,LUTs,avg AUC,accuracy\n\
                   m1,2,3,[32],100,85.2,60.0\n\
                   bad,row\n\
                   m2,2,4,[64],200,88.0,63.0\n";
        let pts = points_from_csv(csv, 0, 4, 5);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].name, "m2");
        assert_eq!(pts[1].luts, 200);
    }
}
