//! Design-space exploration (paper §6 "axes of exploration" and §3.3).
//!
//! Two halves:
//!
//! * this module — (cost, quality) point tooling shared by the experiment
//!   sweeps and the search engine: Pareto-frontier extraction,
//!   dominated-point analysis, the Erdős–Rényi "ensembling" arithmetic of
//!   §3.3.2 (how many sparse small layers can be afforded for the LUT
//!   budget of one larger layer), and CSV ingestion;
//! * [`search`] — the automated search driver itself (topology generator →
//!   cost gate → successive-halving trainer → persistent Pareto archive).

pub mod search;

use crate::cost;

/// One evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub name: String,
    pub luts: u64,
    /// Higher is better (accuracy or avg AUC, in percent).
    pub quality: f64,
}

/// Pareto-optimal subset (minimal LUTs, maximal quality), sorted by cost.
/// Ties on cost keep the best quality.  NaN-quality points (a diverged
/// training run, a malformed CSV row) are dropped with a warning — the old
/// `partial_cmp(..).unwrap()` sort aborted the whole analysis on the first
/// NaN — and the remaining comparisons use the IEEE total order so the
/// sort is safe for any float input.
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<DesignPoint> {
    let n_nan = points.iter().filter(|p| p.quality.is_nan()).count();
    if n_nan > 0 {
        eprintln!("[dse] warning: ignoring {n_nan} NaN-quality point(s) in frontier");
    }
    let mut sorted: Vec<&DesignPoint> =
        points.iter().filter(|p| !p.quality.is_nan()).collect();
    sorted.sort_by(|a, b| a.luts.cmp(&b.luts).then(b.quality.total_cmp(&a.quality)));
    let mut out: Vec<DesignPoint> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for p in sorted {
        if p.quality > best {
            out.push(p.clone());
            best = p.quality;
        }
    }
    out
}

/// One calibrated serving-zoo point: two cost axes (LUTs, measured p99
/// serving latency — both minimized) plus quality (maximized).  This is the
/// multi-objective extension of [`DesignPoint`] used by the DSE→serving
/// handoff: the emitted zoo must be non-dominated in all three dimensions,
/// not just the (LUTs, quality) plane the search archive ranks on.
#[derive(Debug, Clone, PartialEq)]
pub struct ZooPoint {
    pub name: String,
    pub luts: u64,
    /// Higher is better (100 × avg AUC, like [`DesignPoint::quality`]).
    pub quality: f64,
    /// Measured p99 single-request latency in microseconds (lower is
    /// better).
    pub latency_us: f64,
}

/// `a` dominates `b` in the 3-D (LUTs ↓, quality ↑, latency ↓) order:
/// no worse on every axis and strictly better on at least one.  Callers
/// must filter NaN axes first (NaN compares false everywhere here, so a
/// NaN point would spuriously look non-dominated).
pub fn dominates_3d(a: &ZooPoint, b: &ZooPoint) -> bool {
    let no_worse =
        a.luts <= b.luts && a.quality >= b.quality && a.latency_us <= b.latency_us;
    let better = a.luts < b.luts || a.quality > b.quality || a.latency_us < b.latency_us;
    no_worse && better
}

/// 3-D Pareto frontier over (LUTs ↓, quality ↑, measured latency ↓),
/// sorted by LUTs.  Same NaN policy as [`pareto_frontier`]: a point with a
/// NaN quality *or* NaN latency (a failed calibration pass) is dropped
/// with a warning instead of aborting the sort, and all float comparisons
/// use the IEEE total order.  Duplicate points (identical on every axis)
/// are all kept — neither dominates the other.
pub fn pareto_frontier_3d(points: &[ZooPoint]) -> Vec<ZooPoint> {
    let n_nan = points
        .iter()
        .filter(|p| p.quality.is_nan() || p.latency_us.is_nan())
        .count();
    if n_nan > 0 {
        eprintln!("[dse] warning: ignoring {n_nan} NaN-axis point(s) in 3-D frontier");
    }
    let valid: Vec<&ZooPoint> = points
        .iter()
        .filter(|p| !p.quality.is_nan() && !p.latency_us.is_nan())
        .collect();
    let mut out: Vec<ZooPoint> = Vec::new();
    for p in &valid {
        if !valid.iter().any(|q| dominates_3d(q, p)) {
            out.push((*p).clone());
        }
    }
    out.sort_by(|a, b| {
        a.luts
            .cmp(&b.luts)
            .then(b.quality.total_cmp(&a.quality))
            .then(a.latency_us.total_cmp(&b.latency_us))
            .then(a.name.cmp(&b.name))
    });
    out
}

/// Points strictly dominated by some other point (≥ cost and ≤ quality,
/// with at least one strict) — the paper's "million-LUT models that barely
/// beat 2.5k-LUT models" (Fig. 6.7 discussion).
pub fn dominated<'a>(points: &'a [DesignPoint]) -> Vec<&'a DesignPoint> {
    points
        .iter()
        .filter(|p| {
            points.iter().any(|q| {
                (q.luts <= p.luts && q.quality > p.quality)
                    || (q.luts < p.luts && q.quality >= p.quality)
            })
        })
        .collect()
}

/// For each frontier point, LUTs spent per extra quality point relative to
/// the previous frontier point (the "knee" detector).
pub fn marginal_cost(frontier: &[DesignPoint]) -> Vec<(String, f64)> {
    frontier
        .windows(2)
        .map(|w| {
            let dl = (w[1].luts - w[0].luts) as f64;
            let dq = (w[1].quality - w[0].quality).max(1e-9);
            (w[1].name.clone(), dl / dq)
        })
        .collect()
}

/// §3.3.2: how many layers of (n2 neurons, b2 fan-in bits, m out bits) can
/// be "ensembled" within the LUT budget of one (n1, b1, m) layer.
///
/// `lut_cost` saturates at `u64::MAX` past N ≈ 70 fan-in bits; a saturated
/// cost is a *lower bound*, so the true ratio is unknowable and the old
/// silent `as f64` conversion produced a meaningless number.  Sentinels
/// instead: if the *denominator* layer saturates the budget buys zero of
/// them (`0.0`, also when both saturate — conservative); if only the
/// numerator saturates, its budget is unbounded relative to a finite
/// denominator (`f64::INFINITY`).
pub fn ensemble_count(
    n1: usize,
    b1_bits: usize,
    n2: usize,
    b2_bits: usize,
    m_bits: usize,
) -> f64 {
    let c1 = cost::lut_cost(b1_bits, m_bits).saturating_mul(n1 as u64);
    let c2 = cost::lut_cost(b2_bits, m_bits).saturating_mul(n2 as u64);
    if c2 == u64::MAX {
        return 0.0;
    }
    if c1 == u64::MAX {
        return f64::INFINITY;
    }
    if c2 == 0 {
        return f64::INFINITY;
    }
    c1 as f64 / c2 as f64
}

/// Detected `(name_col, lut_col, quality_col)` from a CSV header line.
/// Each slot is `None` when no header cell matches, so callers can fall
/// back per-column (explicit CLI flags override all of this).
///
/// Matching (case-insensitive): the cost column is the first cell
/// containing `lut`; the quality column prefers `auc`, then `acc`(uracy),
/// then `quality`; the name column is the first cell containing `model`
/// or `name`.  This covers every sweep CSV the experiments emit
/// (`figure_6_7`: `model,...,LUTs,avg AUC,accuracy`; `figure_7_1`:
/// `model,LUTs,accuracy`; the DSE archive report).
pub fn detect_columns(header_line: &str) -> (Option<usize>, Option<usize>, Option<usize>) {
    let cells: Vec<String> =
        header_line.split(',').map(|c| c.trim().to_lowercase()).collect();
    let name = cells.iter().position(|c| c.contains("model") || c.contains("name"));
    let lut = cells.iter().position(|c| c.contains("lut"));
    let q = cells
        .iter()
        .position(|c| c.contains("auc"))
        .or_else(|| cells.iter().position(|c| c.contains("acc")))
        .or_else(|| cells.iter().position(|c| c.contains("quality")));
    (name, lut, q)
}

/// Load design points from an experiment CSV with columns containing
/// "LUTs"-like and quality-like headers (figure_6_7 / figure_7_1 outputs).
pub fn points_from_csv(csv: &str, name_col: usize, lut_col: usize, q_col: usize) -> Vec<DesignPoint> {
    let mut out = Vec::new();
    for (i, line) in csv.lines().enumerate() {
        if i == 0 {
            continue; // header
        }
        let cells: Vec<&str> = line.split(',').collect();
        if cells.len() <= q_col.max(lut_col).max(name_col) {
            continue;
        }
        let (Ok(luts), Ok(q)) = (
            cells[lut_col].trim().parse::<f64>(),
            cells[q_col].trim().parse::<f64>(),
        ) else {
            continue;
        };
        out.push(DesignPoint {
            name: cells[name_col].trim().to_string(),
            luts: luts as u64,
            quality: q,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Vec<DesignPoint> {
        [
            ("a", 100u64, 80.0),
            ("b", 200, 85.0),
            ("c", 150, 70.0),  // dominated by a (cheaper, better)... no: a cheaper AND better? a=100/80 vs c=150/70: dominated.
            ("d", 1000, 86.0),
            ("e", 1000, 84.0), // dominated by d
            ("f", 50, 60.0),
        ]
        .into_iter()
        .map(|(n, l, q)| DesignPoint { name: n.into(), luts: l, quality: q })
        .collect()
    }

    #[test]
    fn frontier_is_monotone_and_minimal() {
        let f = pareto_frontier(&pts());
        let names: Vec<&str> = f.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["f", "a", "b", "d"]);
        assert!(f.windows(2).all(|w| w[0].luts <= w[1].luts && w[0].quality < w[1].quality));
    }

    #[test]
    fn dominated_points_found() {
        let pts = pts();
        let d = dominated(&pts);
        let names: Vec<&str> = d.iter().map(|p| p.name.as_str()).collect();
        assert!(names.contains(&"c"));
        assert!(names.contains(&"e"));
        assert!(!names.contains(&"a"));
    }

    #[test]
    fn marginal_cost_grows_at_the_tail() {
        let f = pareto_frontier(&pts());
        let mc = marginal_cost(&f);
        // d costs far more per quality point than b
        let b = mc.iter().find(|(n, _)| n == "b").unwrap().1;
        let d = mc.iter().find(|(n, _)| n == "d").unwrap().1;
        assert!(d > b);
    }

    #[test]
    fn ensemble_arithmetic() {
        // One 64-neuron 12-bit layer buys ~4 x 64-neuron 10-bit layers
        // (lut_cost(12,2)=170 vs lut_cost(10,2)=42).
        let k = ensemble_count(64, 12, 64, 10, 2);
        assert!(k > 3.9 && k < 4.2, "{k}");
    }

    #[test]
    fn frontier_survives_nan_quality() {
        // Regression: the sort's partial_cmp(..).unwrap() aborted on any
        // NaN point; NaN must be dropped, not panic, and never appear in
        // the frontier.
        let mut p = pts();
        p.push(DesignPoint { name: "nan".into(), luts: 10, quality: f64::NAN });
        p.push(DesignPoint { name: "nan2".into(), luts: 200, quality: f64::NAN });
        let f = pareto_frontier(&p);
        let names: Vec<&str> = f.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["f", "a", "b", "d"]);
        assert!(f.iter().all(|p| !p.quality.is_nan()));
        // All-NaN input: empty frontier, no panic.
        let all_nan =
            vec![DesignPoint { name: "x".into(), luts: 1, quality: f64::NAN }];
        assert!(pareto_frontier(&all_nan).is_empty());
    }

    #[test]
    fn ensemble_saturation_sentinels() {
        // N ≈ 90 regime (PR 2's cross-check range): per-bit cost saturates
        // at u64::MAX from N = 70 on.
        use crate::cost::lut_cost;
        assert_eq!(lut_cost(90, 2), u64::MAX, "premise: N=90 saturates");
        // Saturated numerator, finite denominator: unbounded budget.
        assert_eq!(ensemble_count(1, 90, 64, 10, 2), f64::INFINITY);
        // Saturated denominator: the budget buys zero such layers.
        assert_eq!(ensemble_count(64, 10, 1, 90, 2), 0.0);
        // Both saturated: unknowable ratio, conservative 0.0.
        assert_eq!(ensemble_count(1, 90, 1, 90, 2), 0.0);
        // n * per-neuron product overflow (not just per-bit): lut_cost(68,1)
        // fits but a huge neuron count pushes the product past u64.
        assert!(lut_cost(68, 1) < u64::MAX);
        assert_eq!(ensemble_count(1_000_000, 68, 64, 10, 2), f64::INFINITY);
        // Finite regime unchanged.
        let k = ensemble_count(64, 12, 64, 10, 2);
        assert!(k > 3.9 && k < 4.2, "{k}");
    }

    fn zp(name: &str, luts: u64, quality: f64, latency_us: f64) -> ZooPoint {
        ZooPoint { name: name.into(), luts, quality, latency_us }
    }

    #[test]
    fn frontier_3d_keeps_latency_tradeoffs_2d_would_drop() {
        // b is 2-D dominated by a (same LUTs, worse quality) but serves
        // strictly faster — in 3-D it is a real trade-off and must stay.
        let pts = vec![
            zp("a", 100, 90.0, 50.0),
            zp("b", 100, 85.0, 10.0),
            zp("c", 100, 85.0, 60.0), // dominated by both a and b
            zp("d", 50, 80.0, 40.0),
            zp("e", 200, 80.0, 45.0), // dominated by d on every axis
        ];
        let f = pareto_frontier_3d(&pts);
        let names: Vec<&str> = f.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["d", "a", "b"]);
        // Non-domination is exhaustive: no kept point dominated by any input.
        for p in &f {
            for q in &pts {
                assert!(!dominates_3d(q, p), "{} dominated by {}", p.name, q.name);
            }
        }
        // Every dropped finite point is dominated by some kept point.
        for q in &pts {
            if !names.contains(&q.name.as_str()) {
                assert!(
                    f.iter().any(|p| dominates_3d(p, q)),
                    "{} dropped but undominated",
                    q.name
                );
            }
        }
    }

    #[test]
    fn frontier_3d_drops_nan_axes_without_panicking() {
        let pts = vec![
            zp("ok", 100, 80.0, 20.0),
            zp("nan_q", 10, f64::NAN, 5.0),
            zp("nan_l", 10, 99.0, f64::NAN),
        ];
        let f = pareto_frontier_3d(&pts);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].name, "ok");
        // All-NaN input: empty frontier, no panic.
        assert!(pareto_frontier_3d(&[zp("x", 1, f64::NAN, f64::NAN)]).is_empty());
    }

    #[test]
    fn dominance_3d_needs_one_strict_axis() {
        let a = zp("a", 100, 80.0, 20.0);
        assert!(!dominates_3d(&a, &a), "a point never dominates itself");
        // Equal on two axes, strictly better on one: dominates.
        assert!(dominates_3d(&zp("b", 100, 80.0, 19.0), &a));
        assert!(dominates_3d(&zp("c", 99, 80.0, 20.0), &a));
        assert!(dominates_3d(&zp("d", 100, 80.5, 20.0), &a));
        // Better on one axis, worse on another: incomparable both ways.
        let e = zp("e", 50, 70.0, 20.0);
        assert!(!dominates_3d(&e, &a) && !dominates_3d(&a, &e));
    }

    #[test]
    fn header_detection_matches_experiment_csvs() {
        // figure_6_7 shape.
        let (n, l, q) = detect_columns("model,bw,fanin,hidden,LUTs,avg AUC,accuracy");
        assert_eq!((n, l, q), (Some(0), Some(4), Some(5)));
        // figure_7_1 shape (no AUC column: falls back to accuracy).
        let (n, l, q) = detect_columns("model,LUTs,accuracy");
        assert_eq!((n, l, q), (Some(0), Some(1), Some(2)));
        // Case-insensitive, name-keyed.
        let (n, l, q) = detect_columns("Name,lut cost,Quality");
        assert_eq!((n, l, q), (Some(0), Some(1), Some(2)));
        // Nothing matches: all None (caller falls back to explicit flags).
        let (n, l, q) = detect_columns("a,b,c");
        assert_eq!((n, l, q), (None, None, None));
    }

    #[test]
    fn csv_parsing() {
        let csv = "model,bw,fanin,hidden,LUTs,avg AUC,accuracy\n\
                   m1,2,3,[32],100,85.2,60.0\n\
                   bad,row\n\
                   m2,2,4,[64],200,88.0,63.0\n";
        let pts = points_from_csv(csv, 0, 4, 5);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].name, "m2");
        assert_eq!(pts[1].luts, 200);
    }
}
