//! Automated design-space exploration: the paper's second pillar ("how to
//! *automate* such a strategy of neural network design") as a control
//! plane over the train → synth → serve pipeline.
//!
//! Dataflow (DESIGN.md §8):
//!
//! ```text
//! SearchAxes ──generate──▶ Candidate* ──CostGate──▶ admitted
//!                                         │ (over budget: archived as
//!                                         ▼  "gated", never trained)
//!                              successive halving over rungs
//!                         rung r: train base_steps·2^r more steps
//!                         (util::pool, warm-started from rung r-1,
//!                          checkpointed) → quality on the held-out
//!                         split → keep the top 1/eta fraction
//!                                         │
//!                                         ▼
//!                   Pareto archive (reports/dse/archive.json, resumable)
//!                                         │
//!                                         ▼
//!              frontier emit: synthesize --opt → NetlistEngine (verified)
//! ```
//!
//! The gate prices every candidate with the analytical model
//! (`cost::lut_cost` family, exactly `cost::manifest_cost`) *before* any
//! training — the paper built the worst-case cost model "to aid faster
//! prototyping", and here it screens tens of thousands of candidates per
//! second so search cost is dominated by training, never by pricing
//! (`bench_dse` measures this).  Training runs through the native
//! pure-Rust trainer (`train::native`), so a search works offline with no
//! HLO artifact, and a finished search ends with servable, LUT-priced
//! netlists.
//!
//! The searched space covers the whole MLP layer-graph family the paper
//! explored: besides width/depth/fan-in/bits/method/BRAM threshold, the
//! generator sweeps **skip-connection counts** and **pyramid width
//! schedules** ([`WidthShape`]), whose candidates train through the
//! skip-concat forward/backward and serve as skip netlists end to end
//! (DESIGN.md §10), plus **conv front-ends** ([`ConvSpec`]): a stride-2
//! conv stage on the task input viewed as a square image, lowered to
//! per-pixel boolean neurons and priced with the exact per-window
//! geometry (`ConvGeom::lut_cost`, DESIGN.md §14).

use super::{marginal_cost, pareto_frontier, pareto_frontier_3d, DesignPoint};
use crate::cost;
use crate::data::DataSet;
use crate::luts::ModelTables;
use crate::metrics;
use crate::nn::ExportedModel;
use crate::obs;
use crate::runtime::Manifest;
use crate::serve::zoo::{calibrate_latency, ZooEntry, ZooManifest, CALIBRATION_ITERS};
use crate::serve::{batch_accuracy, NetlistEngine};
use crate::sparsity::prune::PruneMethod;
use crate::synth::{synthesize, verify_netlist, OptLevel, SynthOpts};
use crate::train::{checkpoint, native, ModelState, TrainOpts};
use crate::util::json::Json;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::table::{f2, TextTable};
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// Axes and candidates
// ---------------------------------------------------------------------------

/// Hidden-width schedule of a candidate: how a base width maps to the
/// per-layer width vector at a given depth.  The paper's best topologies
/// taper ("pyramid") their hidden layers instead of keeping a rectangle;
/// this is that choice as a first-class search axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WidthShape {
    /// Uniform base width at every depth (the original rectangle family).
    Rect,
    /// Pyramid taper: each layer is `pct`% of the previous one, floored at
    /// [`MIN_TAPER_WIDTH`].
    Taper { pct: usize },
}

/// Narrowest layer a taper schedule may produce (below this the layer
/// stops being a useful feature bottleneck and fan-in clamps dominate).
pub const MIN_TAPER_WIDTH: usize = 4;

impl WidthShape {
    /// Per-layer widths for base width `w` at `depth` layers.
    pub fn widths(&self, w: usize, depth: usize) -> Vec<usize> {
        match *self {
            WidthShape::Rect => vec![w; depth],
            WidthShape::Taper { pct } => {
                let mut out = Vec::with_capacity(depth);
                let mut cur = w;
                for _ in 0..depth {
                    out.push(cur);
                    cur = (cur * pct / 100).max(MIN_TAPER_WIDTH);
                }
                out
            }
        }
    }

    /// Stable axis-key / CLI token.
    pub fn name(&self) -> String {
        match *self {
            WidthShape::Rect => "rect".to_string(),
            WidthShape::Taper { pct } => format!("taper{pct}"),
        }
    }

    /// Parse a CLI token: `rect` or `taper<PCT>` (e.g. `taper50`).
    pub fn parse(s: &str) -> Option<WidthShape> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("rect") {
            return Some(WidthShape::Rect);
        }
        let pct = s.strip_prefix("taper")?.parse::<usize>().ok()?;
        if (1..=100).contains(&pct) {
            Some(WidthShape::Taper { pct })
        } else {
            None
        }
    }
}

/// The search space: one choice per axis of the paper's exploration
/// chapter — hidden width/depth, width schedule (rectangle vs pyramid
/// taper), skip-connection count, conv front-end (mode × channels ×
/// kernel), per-layer fan-in γ, activation bits β, sparsity method, and
/// the BRAM-spill threshold used when the winner is synthesized.
#[derive(Debug, Clone)]
pub struct SearchAxes {
    pub widths: Vec<usize>,
    pub depths: Vec<usize>,
    pub fanins: Vec<usize>,
    pub bws: Vec<usize>,
    pub methods: Vec<PruneMethod>,
    pub bram_min_bits: Vec<usize>,
    /// Newest-first skip-concat counts (`0` = plain feed-forward).
    pub skips: Vec<usize>,
    /// Hidden-width schedules applied to each (width, depth) pair.
    pub shapes: Vec<WidthShape>,
    /// Conv front-end modes: `"none"` (pure MLP), `"dense"` (one stride-2
    /// full-window stage) or `"dw"` (depthwise + pointwise stage pair).
    pub conv_modes: Vec<String>,
    /// Conv out-channel counts, swept only for non-`"none"` modes.
    pub channels: Vec<usize>,
    /// Conv kernel sides (odd, SAME padding), swept only for non-`"none"`
    /// modes.
    pub kernels: Vec<usize>,
}

impl SearchAxes {
    /// Default grid for the jet-substructure task: brackets the paper's
    /// hand-enumerated figure-6.7 sweep (bw 1–3, fan-in 2–4) with width
    /// and depth choices around the hep_a…e family, plus the skip and
    /// pyramid-taper axes the paper's best topologies use.
    pub fn jets_default() -> SearchAxes {
        SearchAxes {
            widths: vec![16, 32, 64],
            depths: vec![1, 2],
            fanins: vec![2, 3, 4],
            bws: vec![1, 2, 3],
            methods: vec![PruneMethod::APriori],
            bram_min_bits: vec![13],
            skips: vec![0, 1],
            shapes: vec![WidthShape::Rect, WidthShape::Taper { pct: 50 }],
            conv_modes: vec!["none".to_string()],
            channels: vec![4],
            kernels: vec![3],
        }
    }

    /// Size of the full cross product (before duplicate-topology pruning
    /// in [`generate`]: e.g. rectangle and taper coincide at depth 1).
    pub fn num_candidates(&self) -> usize {
        self.widths.len()
            * self.depths.len()
            * self.fanins.len()
            * self.bws.len()
            * self.methods.len()
            * self.bram_min_bits.len()
            * self.skips.len()
            * self.shapes.len()
            * self.conv_modes.len()
            * self.channels.len()
            * self.kernels.len()
    }

    /// Compact fingerprint of the whole search space.  Stored in the
    /// archive and compared on `--resume`: two runs over different axes
    /// generate different candidate pools, so replaying one against the
    /// other's archive would silently break the zero-retraining contract.
    /// The skip/shape/conv sections are appended only when non-default,
    /// so archives written before those axes existed keep their key and
    /// stay resumable with the defaults.
    pub fn key(&self) -> String {
        let join = |v: &[usize]| {
            v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("-")
        };
        let methods: Vec<&str> = self.methods.iter().map(|m| m.name()).collect();
        let mut k = format!(
            "w{}_d{}_f{}_b{}_m{}_r{}",
            join(&self.widths),
            join(&self.depths),
            join(&self.fanins),
            join(&self.bws),
            methods.join("-"),
            join(&self.bram_min_bits),
        );
        if self.skips != [0] {
            k.push_str(&format!("_s{}", join(&self.skips)));
        }
        if self.shapes != [WidthShape::Rect] {
            let shapes: Vec<String> = self.shapes.iter().map(|s| s.name()).collect();
            k.push_str(&format!("_y{}", shapes.join("-")));
        }
        if self.conv_modes != ["none"] {
            k.push_str(&format!("_c{}", self.conv_modes.join("-")));
        }
        if self.channels != [4] {
            k.push_str(&format!("_n{}", join(&self.channels)));
        }
        if self.kernels != [3] {
            k.push_str(&format!("_k{}", join(&self.kernels)));
        }
        k
    }
}

/// Conv front-end of a candidate: one stride-2 stage on the task input
/// interpreted as a 1-channel square image (`Manifest::conv_image_side`).
/// `mode` is `"dense"` or `"dw"`; the stage's window fan-in is the
/// candidate's γ capped at the table-width limit, exactly as
/// [`Manifest::synthetic_conv_for_task`] builds it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvSpec {
    pub mode: String,
    pub channels: usize,
    pub kernel: usize,
}

/// One topology candidate: everything needed to build its `Manifest`.
/// `hidden` carries the realized per-layer widths (so pyramid schedules
/// need no extra state), `skips` the newest-first skip-concat count, and
/// `conv` the optional conv front-end (conv manifests are skip-free).
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub hidden: Vec<usize>,
    pub fanin: usize,
    pub bw: usize,
    pub method: PruneMethod,
    pub bram_min_bits: usize,
    pub skips: usize,
    pub conv: Option<ConvSpec>,
}

impl Candidate {
    /// Stable identifier: axes only, so the same point re-identifies
    /// itself across runs (the archive is keyed by this).  Skip-free
    /// candidates keep their pre-skip-axis names, and conv-free
    /// candidates their pre-conv-axis names, so old archives re-identify
    /// the same points.
    pub fn name(&self) -> String {
        let hl: Vec<String> = self.hidden.iter().map(|h| h.to_string()).collect();
        let tag = match self.method {
            PruneMethod::APriori => "ap",
            PruneMethod::Iterative { .. } => "it",
            PruneMethod::Momentum { .. } => "mo",
        };
        let mut n = format!("dse_h{}_f{}_b{}_{}", hl.join("-"), self.fanin, self.bw, tag);
        if self.skips != 0 {
            n.push_str(&format!("_s{}", self.skips));
        }
        if self.bram_min_bits != 13 {
            n.push_str(&format!("_r{}", self.bram_min_bits));
        }
        if let Some(cv) = &self.conv {
            n.push_str(&format!("_c{}{}k{}", cv.mode, cv.channels, cv.kernel));
        }
        n
    }

    /// Full manifest for this candidate on the given task shape.  Errs
    /// only for conv candidates whose geometry is impossible on the task
    /// (non-square `in_features`, kernel larger than the image side).
    pub fn manifest(&self, dataset: &str, in_features: usize, classes: usize) -> Result<Manifest> {
        match &self.conv {
            Some(cv) => Manifest::synthetic_conv_for_task(
                &self.name(),
                dataset,
                in_features,
                classes,
                &self.hidden,
                self.fanin,
                self.bw,
                &cv.mode,
                cv.channels,
                cv.kernel,
            ),
            None => Ok(Manifest::synthetic_topology(
                &self.name(),
                dataset,
                in_features,
                classes,
                &self.hidden,
                self.fanin,
                self.bw,
                self.skips,
            )),
        }
    }

    /// Analytical LUT cost of the whole model — the gate's fast path.
    /// Must agree exactly with `cost::total_luts(cost::manifest_cost(m))`
    /// for this candidate's manifest (property-tested in
    /// `tests/dse_search.rs`): sparse hidden layers at eq. 2.3, dense
    /// head at eq. 4.1, every layer priced at its skip-widened `in_f`
    /// (shared with the manifest via `Manifest::skip_in_widths`, so gate
    /// and exact pricing cannot diverge).  Conv candidates price their
    /// stages with the exact per-window geometry (`ConvGeom::lut_cost`
    /// over the same lowered geometries the manifest uses); an
    /// impossible geometry saturates to `u64::MAX`, which no budget
    /// admits.
    pub fn analytical_luts(&self, in_features: usize, classes: usize) -> u64 {
        if let Some(cv) = &self.conv {
            return match self.conv_prefix_luts(cv, in_features) {
                Some((prefix, head_in)) => prefix.saturating_add(cost::dense_layer_cost(
                    classes,
                    head_in,
                    self.bw,
                    cost::DENSE_BW_WT,
                )),
                None => u64::MAX,
            };
        }
        let in_widths = Manifest::skip_in_widths(in_features, &self.hidden, self.skips);
        self.sparse_prefix_luts_with(&in_widths).saturating_add(cost::dense_layer_cost(
            classes,
            in_widths[self.hidden.len()],
            self.bw,
            cost::DENSE_BW_WT,
        ))
    }

    /// Analytical cost of the sparse (table-mapped) prefix only — what
    /// `synthesize` reports as `analytical_luts` for this model.  For
    /// conv candidates the prefix is the conv stages plus the sparse
    /// hidden stack (all table-mapped); `u64::MAX` when the geometry is
    /// impossible on this task.
    pub fn sparse_prefix_luts(&self, in_features: usize) -> u64 {
        if let Some(cv) = &self.conv {
            return self
                .conv_prefix_luts(cv, in_features)
                .map(|(prefix, _)| prefix)
                .unwrap_or(u64::MAX);
        }
        self.sparse_prefix_luts_with(&Manifest::skip_in_widths(
            in_features,
            &self.hidden,
            self.skips,
        ))
    }

    /// Conv-candidate prefix price and the head's input width: the conv
    /// stages at their exact per-window cost followed by the sparse
    /// hidden stack, over the same geometries
    /// [`Manifest::synthetic_conv_for_task`] lowers (same γ cap, same
    /// subsample seeds), so gate and exact pricing cannot diverge.
    /// `None` when `in_features` is not a square image or the kernel
    /// does not fit it.
    fn conv_prefix_luts(&self, cv: &ConvSpec, in_features: usize) -> Option<(u64, usize)> {
        let hw = Manifest::conv_image_side(in_features)?;
        let cap = (crate::luts::MAX_IN_BITS / self.bw.max(1)).max(1);
        let f = self.fanin.min(cap);
        let geoms = Manifest::conv_stage_geoms(
            hw,
            1,
            &[cv.channels],
            cv.kernel,
            &cv.mode,
            Some(f),
            Some(f),
        )
        .ok()?;
        let mut total = 0u64;
        for g in &geoms {
            total = total.saturating_add(g.lut_cost(self.bw, self.bw));
        }
        let mut width = geoms.last().map(|g| g.out_f()).unwrap_or(in_features);
        for &h in &self.hidden {
            total = total
                .saturating_add(cost::sparse_layer_cost(h, self.fanin.min(width), self.bw, self.bw));
            width = h;
        }
        Some((total, width))
    }

    /// Prefix pricing over precomputed skip-widened input widths, so the
    /// gate's whole-model price builds the width vector once.
    fn sparse_prefix_luts_with(&self, in_widths: &[usize]) -> u64 {
        let mut total = 0u64;
        for (&h, &inw) in self.hidden.iter().zip(in_widths) {
            let f = self.fanin.min(inw);
            total = total.saturating_add(cost::sparse_layer_cost(h, f, self.bw, self.bw));
        }
        total
    }
}

/// Deterministic candidate generator: the full axis cross product in a
/// fixed order, duplicate topologies dropped (rectangle and taper
/// schedules coincide at depth 1; `skips` clamps at the depth — a
/// skips-2 single-hidden-layer model IS the skips-1 model; the `"none"`
/// conv mode collapses the channel/kernel axes, and conv candidates
/// canonicalize to skip-free), seed-shuffled, truncated to `max`.  Same
/// (axes, seed, max) → same candidate list, which is what makes whole
/// searches replayable.
pub fn generate(axes: &SearchAxes, seed: u64, max: usize) -> Vec<Candidate> {
    let mut out = Vec::with_capacity(axes.num_candidates());
    let mut seen = std::collections::BTreeSet::new();
    for &d in &axes.depths {
        for &shape in &axes.shapes {
            for &w in &axes.widths {
                for &f in &axes.fanins {
                    for &bw in &axes.bws {
                        for &m in &axes.methods {
                            for &bram in &axes.bram_min_bits {
                                for &s in &axes.skips {
                                    for cm in &axes.conv_modes {
                                        for &cc in &axes.channels {
                                            for &ck in &axes.kernels {
                                                let conv =
                                                    (cm.as_str() != "none").then(|| ConvSpec {
                                                        mode: cm.clone(),
                                                        channels: cc,
                                                        kernel: ck,
                                                    });
                                                let c = Candidate {
                                                    hidden: shape.widths(w, d),
                                                    fanin: f,
                                                    bw,
                                                    method: m,
                                                    bram_min_bits: bram,
                                                    // Every layer clamps its
                                                    // history at min(skips, i),
                                                    // so skips > depth duplicates
                                                    // the clamped topology; conv
                                                    // manifests are skip-free by
                                                    // contract.  Canonicalize so
                                                    // dedup catches both.
                                                    skips: if conv.is_some() {
                                                        0
                                                    } else {
                                                        s.min(d)
                                                    },
                                                    conv,
                                                };
                                                if seen.insert(c.name()) {
                                                    out.push(c);
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    let mut rng = Rng::new(seed ^ 0x6473_6531); // "dse1"
    rng.shuffle(&mut out);
    out.truncate(max);
    out
}

// ---------------------------------------------------------------------------
// Cost gate
// ---------------------------------------------------------------------------

/// Prices candidates with the analytical model and rejects over-budget
/// points before any training happens.
#[derive(Debug, Clone, Copy)]
pub struct CostGate {
    pub budget_luts: u64,
}

impl CostGate {
    /// Exact analytical price (see [`Candidate::analytical_luts`]).
    pub fn price(&self, c: &Candidate, in_features: usize, classes: usize) -> u64 {
        c.analytical_luts(in_features, classes)
    }

    /// Admission is monotone in the exact price: a candidate is rejected
    /// *only* when its exact analytical cost exceeds the budget, so the
    /// gate can never reject a point the exact pricing would accept.
    pub fn admits(&self, luts: u64) -> bool {
        luts <= self.budget_luts
    }
}

/// Screening-rate floor the gate must sustain (candidates priced/sec):
/// below this, pricing would start to matter next to training cost.
/// Asserted by `bench_dse` and the `examples/dse_search.rs` CI gate.
pub const GATE_RATE_FLOOR: f64 = 10_000.0;

/// Measure the gate's screening rate over a wall-clock window by looping
/// price+admit across `cands`.  One shared implementation so the bench
/// and the CI smoke gate cannot drift apart.
pub fn gate_screen_rate(
    cands: &[Candidate],
    gate: &CostGate,
    in_features: usize,
    classes: usize,
    window: std::time::Duration,
) -> f64 {
    assert!(!cands.is_empty(), "need candidates to screen");
    let t0 = std::time::Instant::now();
    let mut priced = 0usize;
    let mut admitted = 0usize;
    while t0.elapsed() < window {
        for c in cands {
            priced += 1;
            if gate.admits(gate.price(c, in_features, classes)) {
                admitted += 1;
            }
        }
    }
    std::hint::black_box(admitted);
    priced as f64 / t0.elapsed().as_secs_f64()
}

// ---------------------------------------------------------------------------
// Task and options
// ---------------------------------------------------------------------------

/// The workload a search optimizes over: dataset splits plus shape.
pub struct SearchTask {
    pub dataset: String,
    pub in_features: usize,
    pub classes: usize,
    pub train: DataSet,
    pub test: DataSet,
}

impl SearchTask {
    /// The experiment-standard split (`experiments::dataset_split` with
    /// `ExpCtx`'s seed), so searched quality is measured exactly like the
    /// hand-enumerated tables.
    pub fn from_dataset(kind: &str) -> SearchTask {
        let (train, test) = crate::experiments::dataset_split(kind, 0xEC0);
        SearchTask::from_splits(kind, train, test)
    }

    /// Small jets task for smoke tests and CI (same generator, fewer
    /// samples).
    pub fn jets_small(n: usize, seed: u64) -> SearchTask {
        let mut rng = Rng::new(seed ^ 1);
        let (train, test) = crate::hep::jets(n, 42).split(0.2, &mut rng);
        SearchTask::from_splits("jets", train, test)
    }

    pub fn from_splits(kind: &str, train: DataSet, test: DataSet) -> SearchTask {
        let (in_features, classes) = (train.d, train.classes);
        SearchTask { dataset: kind.to_string(), in_features, classes, train, test }
    }
}

#[derive(Debug, Clone)]
pub struct SearchOpts {
    /// Gate budget: candidates above this analytical LUT cost never train.
    pub budget_luts: u64,
    /// Successive-halving rungs; rung r trains `base_steps * 2^r` *more*
    /// steps on top of the previous rungs (warm start).
    pub rungs: usize,
    pub base_steps: usize,
    /// Promotion divisor: the top `ceil(n/eta)` survivors reach rung r+1.
    pub eta: usize,
    pub seed: u64,
    /// Cap on generated candidates (after the deterministic shuffle).
    pub max_candidates: usize,
    /// Archive/checkpoint/report directory.
    pub out_dir: PathBuf,
    /// Reuse an existing archive: archived rung qualities replay without
    /// retraining; checkpoints resume training past the archived rungs.
    pub resume: bool,
    /// Synthesize + verify the top-N frontier models after the search.
    pub emit: usize,
    /// After emitting, calibrate each emitted netlist's serving latency
    /// and write the `zoo.json` manifest (the DSE→serving handoff for
    /// `serve --zoo`), keeping only 3-D (LUTs, quality, latency)
    /// non-dominated models.
    pub emit_zoo: bool,
}

impl Default for SearchOpts {
    fn default() -> SearchOpts {
        SearchOpts {
            budget_luts: 30_000,
            rungs: 3,
            base_steps: 40,
            eta: 2,
            seed: 1,
            max_candidates: 24,
            out_dir: PathBuf::from("reports/dse"),
            resume: false,
            emit: 1,
            emit_zoo: false,
        }
    }
}

// ---------------------------------------------------------------------------
// Persistent Pareto archive
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ArchiveEntry {
    pub name: String,
    pub hidden: Vec<usize>,
    pub fanin: usize,
    pub bw: usize,
    pub method: String,
    pub bram_min_bits: usize,
    /// Newest-first skip-concat count (0 = plain feed-forward; archives
    /// written before this axis existed load as 0).
    pub skips: usize,
    /// Conv front-end mode (`None` = pure MLP; archives written before
    /// the conv axes existed load as `None`, as do their JSON files —
    /// the keys are only emitted for conv candidates).
    pub conv_mode: Option<String>,
    pub conv_channels: Option<usize>,
    pub conv_kernel: Option<usize>,
    /// Analytical whole-model LUT cost (the frontier's cost axis).
    pub luts: u64,
    /// "gated" (rejected before training) or "trained".
    pub status: String,
    /// Quality (100 × avg AUC-ROC) after each completed rung.
    pub qualities: Vec<f64>,
    /// Test accuracy at the last completed rung.
    pub accuracy: f64,
    /// Cumulative native-trainer steps across all rungs/runs.
    pub trained_steps: usize,
    /// Post-synthesis LUTs of the emitted netlist (frontier models only).
    pub mapped_luts: Option<u64>,
    pub netlist_accuracy: Option<f64>,
}

impl ArchiveEntry {
    fn from_candidate(c: &Candidate, luts: u64, status: &str) -> ArchiveEntry {
        ArchiveEntry {
            name: c.name(),
            hidden: c.hidden.clone(),
            fanin: c.fanin,
            bw: c.bw,
            method: c.method.name().to_string(),
            bram_min_bits: c.bram_min_bits,
            skips: c.skips,
            conv_mode: c.conv.as_ref().map(|cv| cv.mode.clone()),
            conv_channels: c.conv.as_ref().map(|cv| cv.channels),
            conv_kernel: c.conv.as_ref().map(|cv| cv.kernel),
            luts,
            status: status.to_string(),
            qualities: Vec::new(),
            accuracy: 0.0,
            trained_steps: 0,
            mapped_luts: None,
            netlist_accuracy: None,
        }
    }

    /// Quality at the deepest completed rung (`None` for gated points).
    pub fn final_quality(&self) -> Option<f64> {
        self.qualities.last().copied()
    }
}

/// The resumable search state on disk: parameters + one entry per
/// candidate ever priced.  `reports/dse/archive.json` by default.
#[derive(Debug, Clone)]
pub struct Archive {
    pub dataset: String,
    pub budget_luts: u64,
    pub seed: u64,
    pub rungs: usize,
    pub base_steps: usize,
    pub eta: usize,
    pub max_candidates: usize,
    /// `SearchAxes::key()` of the run that produced this archive.
    pub axes_key: String,
    pub entries: BTreeMap<String, ArchiveEntry>,
}

impl Archive {
    pub fn new(task: &SearchTask, axes: &SearchAxes, opts: &SearchOpts) -> Archive {
        Archive {
            dataset: task.dataset.clone(),
            budget_luts: opts.budget_luts,
            seed: opts.seed,
            rungs: opts.rungs,
            base_steps: opts.base_steps,
            eta: opts.eta,
            max_candidates: opts.max_candidates,
            axes_key: axes.key(),
            entries: BTreeMap::new(),
        }
    }

    /// A resumed archive must have been produced by the same search
    /// parameters — including the axes and the candidate cap, which
    /// determine the candidate pool and every promotion cut — otherwise
    /// replayed selections would silently diverge.  Each refusal names
    /// the exact parameter (or axis) that differs.
    pub fn check_compatible(
        &self,
        task: &SearchTask,
        axes: &SearchAxes,
        opts: &SearchOpts,
    ) -> Result<()> {
        let params = [
            ("dataset", self.dataset.clone(), task.dataset.clone()),
            ("budget (--budget-luts)", self.budget_luts.to_string(), opts.budget_luts.to_string()),
            ("seed (--seed)", self.seed.to_string(), opts.seed.to_string()),
            ("rung count (--rungs)", self.rungs.to_string(), opts.rungs.to_string()),
            ("base steps (--steps)", self.base_steps.to_string(), opts.base_steps.to_string()),
            ("promotion divisor (--eta)", self.eta.to_string(), opts.eta.to_string()),
            (
                "candidate cap (--max-candidates)",
                self.max_candidates.to_string(),
                opts.max_candidates.to_string(),
            ),
        ];
        for (what, archived, requested) in params {
            ensure!(
                archived == requested,
                "archive was produced with {what} {archived} but this run asks for \
                 {requested}; rerun without --resume or delete the archive"
            );
        }
        let key = axes.key();
        ensure!(
            self.axes_key == key,
            "archive axes differ on the {} axis (archived key {}, requested key {key}); \
             rerun without --resume or delete the archive",
            first_axis_mismatch(&self.axes_key, &key),
            self.axes_key
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .values()
            .map(|e| {
                let opt_num = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
                let mut fields = vec![
                    ("name", Json::str(&e.name)),
                    (
                        "hidden",
                        Json::Arr(e.hidden.iter().map(|&h| Json::Num(h as f64)).collect()),
                    ),
                    ("fanin", Json::num(e.fanin as f64)),
                    ("bw", Json::num(e.bw as f64)),
                    ("method", Json::str(&e.method)),
                    ("bram_min_bits", Json::num(e.bram_min_bits as f64)),
                    ("skips", Json::num(e.skips as f64)),
                    // String like the top-level u64s: gated entries can
                    // carry saturated (u64::MAX) costs that f64 would round.
                    ("luts", Json::str(&e.luts.to_string())),
                    ("status", Json::str(&e.status)),
                    ("qualities", Json::arr_f64(&e.qualities)),
                    ("accuracy", Json::num(e.accuracy)),
                    ("trained_steps", Json::num(e.trained_steps as f64)),
                    ("mapped_luts", opt_num(e.mapped_luts.map(|v| v as f64))),
                    ("netlist_accuracy", opt_num(e.netlist_accuracy)),
                ];
                // Conv keys only for conv candidates, so pre-conv readers
                // (and diff-friendly archives) see byte-identical entries
                // for the MLP family.
                if let (Some(m), Some(cc), Some(ck)) =
                    (&e.conv_mode, e.conv_channels, e.conv_kernel)
                {
                    fields.push(("conv_mode", Json::str(m)));
                    fields.push(("conv_channels", Json::num(cc as f64)));
                    fields.push(("conv_kernel", Json::num(ck as f64)));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("dataset", Json::str(&self.dataset)),
            // u64 parameters go through strings: the JSON layer is f64 and
            // would round values above 2^53, making a resumed archive fail
            // its own compatibility check.
            ("budget_luts", Json::str(&self.budget_luts.to_string())),
            ("seed", Json::str(&self.seed.to_string())),
            ("rungs", Json::num(self.rungs as f64)),
            ("base_steps", Json::num(self.base_steps as f64)),
            ("eta", Json::num(self.eta as f64)),
            ("max_candidates", Json::num(self.max_candidates as f64)),
            ("axes_key", Json::str(&self.axes_key)),
            ("entries", Json::Arr(entries)),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("write {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Archive> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let version = j.req_usize("version")?;
        ensure!(version == 1, "archive version {version} != 1");
        let mut entries = BTreeMap::new();
        for e in j.req("entries")?.as_arr().unwrap_or(&[]) {
            let hidden: Vec<usize> = e
                .req("hidden")?
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                .unwrap_or_default();
            let qualities: Vec<f64> = e
                .req("qualities")?
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
                .unwrap_or_default();
            let entry = ArchiveEntry {
                name: e.req_str("name")?.to_string(),
                hidden,
                fanin: e.req_usize("fanin")?,
                bw: e.req_usize("bw")?,
                method: e.req_str("method")?.to_string(),
                bram_min_bits: e.req_usize("bram_min_bits")?,
                // Absent in archives written before the skip axis existed:
                // those points were all skip-free.
                skips: e.opt_usize("skips").unwrap_or(0),
                // Absent for the MLP family and in pre-conv archives.
                conv_mode: e.get("conv_mode").and_then(|v| v.as_str()).map(str::to_string),
                conv_channels: e.opt_usize("conv_channels"),
                conv_kernel: e.opt_usize("conv_kernel"),
                luts: e
                    .req_str("luts")?
                    .parse::<u64>()
                    .map_err(|err| anyhow::anyhow!("archive entry luts: {err}"))?,
                status: e.req_str("status")?.to_string(),
                qualities,
                accuracy: e.opt_f64("accuracy", 0.0),
                trained_steps: e.opt_usize("trained_steps").unwrap_or(0),
                mapped_luts: e
                    .get("mapped_luts")
                    .and_then(|v| v.as_f64())
                    .map(|v| v as u64),
                netlist_accuracy: e.get("netlist_accuracy").and_then(|v| v.as_f64()),
            };
            entries.insert(entry.name.clone(), entry);
        }
        let parse_u64 = |key: &str| -> Result<u64> {
            j.req_str(key)?
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("archive key {key}: {e}"))
        };
        Ok(Archive {
            dataset: j.req_str("dataset")?.to_string(),
            budget_luts: parse_u64("budget_luts")?,
            seed: parse_u64("seed")?,
            rungs: j.req_usize("rungs")?,
            base_steps: j.req_usize("base_steps")?,
            eta: j.req_usize("eta")?,
            max_candidates: j.req_usize("max_candidates")?,
            axes_key: j.req_str("axes_key")?.to_string(),
            entries,
        })
    }

    /// Trained design points (for the frontier).
    pub fn design_points(&self) -> Vec<DesignPoint> {
        self.entries
            .values()
            .filter(|e| e.status == "trained")
            .filter_map(|e| {
                e.final_quality().map(|q| DesignPoint {
                    name: e.name.clone(),
                    luts: e.luts,
                    quality: q,
                })
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Successive-halving driver
// ---------------------------------------------------------------------------

/// Per-candidate running state inside one search.
#[derive(Clone)]
struct Runner {
    cand: Candidate,
    name: String,
    man: Manifest,
    seed: u64,
    luts: u64,
    /// Rung qualities replayed from the archive (resume path).
    archived_qualities: Vec<f64>,
    archived_accuracy: f64,
    state: Option<ModelState>,
    /// Rungs whose training is reflected in `state`.
    completed: usize,
    quality: f64,
    accuracy: f64,
}

/// Name the first axis on which two [`SearchAxes::key`] fingerprints
/// disagree, so a `--resume` refusal tells the user which CLI axis to
/// fix.  Keys are `_`-separated sections, each tagged by its leading
/// character; a section present on only one side is a default-vs-explicit
/// mismatch on that same axis.
fn first_axis_mismatch(archived: &str, requested: &str) -> &'static str {
    fn sections(key: &str) -> BTreeMap<char, &str> {
        key.split('_')
            .filter_map(|s| {
                let mut ch = s.chars();
                ch.next().map(|tag| (tag, ch.as_str()))
            })
            .collect()
    }
    let (a, b) = (sections(archived), sections(requested));
    for tag in "wdfbmrsynck".chars() {
        if a.get(&tag) != b.get(&tag) {
            return match tag {
                'w' => "hidden-width (--widths)",
                'd' => "depth (--depths)",
                'f' => "fan-in (--fanins)",
                'b' => "bit-width (--bws)",
                'm' => "sparsity-method (--methods)",
                'r' => "bram-threshold (--bram-min-bits)",
                's' => "skip-count (--skips)",
                'y' => "width-shape (--shapes)",
                'c' => "conv-mode (--conv-mode)",
                'n' => "conv-channels (--channels)",
                'k' => "conv-kernel (--kernel)",
                _ => unreachable!(),
            };
        }
    }
    "axes-key"
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Checkpoint path for a candidate after `rungs_done` completed rungs.
/// The rung count is part of the file name so a checkpoint can never be
/// replayed against the wrong rung (e.g. a crash between the checkpoint
/// write and the archive write would otherwise double-train that rung on
/// resume).
fn ckpt_file(out_dir: &Path, name: &str, rungs_done: usize) -> PathBuf {
    out_dir.join("ckpt").join(format!("{name}.r{rungs_done}.bin"))
}

/// Quality metric: 100 × average one-vs-rest AUC (the paper's headline
/// number), with accuracy alongside.  Non-finite logits (a diverged run)
/// floor to quality 0 instead of poisoning rank statistics with NaN.
fn quality_of(logits: &[f32], y: &[i32], classes: usize) -> (f64, f64) {
    if logits.is_empty() || !logits.iter().all(|v| v.is_finite()) {
        return (0.0, 0.0);
    }
    let probs = metrics::softmax_rows(logits, classes);
    let aucs = metrics::auc_ovr(&probs, y, classes);
    let q = 100.0 * aucs.iter().sum::<f64>() / aucs.len().max(1) as f64;
    let acc = metrics::accuracy(logits, y, classes);
    (q, acc)
}

/// Short rungs can leave Iterative masks above the target fan-in (no
/// prune event fired yet); enforce the target at each rung boundary,
/// exactly like `ExpCtx::trained` does after short runs, so the archived
/// quality and the analytical cost describe the same sparse model — and
/// so the emitted truth tables stay within `luts::MAX_IN_BITS`.
fn enforce_target_fanin(man: &Manifest, method: PruneMethod, st: &mut ModelState) {
    if !matches!(method, PruneMethod::Iterative { .. }) {
        return;
    }
    // Conv layers (the manifest prefix) keep their structured
    // receptive-field mask: magnitude-pruning them would break the
    // shared-window invariant `lint_conv_model` enforces.  A manifest
    // that reached training has already validated its conv extras, so
    // the error fallback only covers the already-rejected case.
    let n_conv = man.conv_geoms().map(|g| g.len()).unwrap_or(0);
    for (i, l) in man.layers.iter().enumerate().skip(n_conv) {
        if let Some(f) = l.fanin {
            crate::sparsity::prune::magnitude_prune(&st.ws[i], &mut st.masks[i], f);
            st.apply_mask(i);
        }
    }
}

/// Advance one runner through rung `rung`: replay the archived quality if
/// this rung is already recorded, otherwise (warm-)train `base_steps·2^r`
/// steps and evaluate.  Returns the updated runner plus the steps trained
/// now (0 on pure replay).  Runs inside `util::pool::par_map`.
fn advance_runner(
    task: &SearchTask,
    opts: &SearchOpts,
    runner: &Runner,
    rung: usize,
) -> Result<(Runner, usize)> {
    let mut ru = runner.clone();
    if ru.archived_qualities.len() > rung {
        ru.quality = ru.archived_qualities[rung];
        // Accuracy is "latest known" — keep the archived value on replay
        // so intermediate rungs never clobber it with a zero.
        ru.accuracy = ru.archived_accuracy;
        obs::inc("dse.archive.replay_hits.count");
        return Ok((ru, 0));
    }
    let mut trained_now = 0usize;
    if ru.state.is_none() {
        // A checkpoint written after the archive's last recorded rung can
        // seed this rung exactly (the rung count is in the file name, so a
        // newer orphaned checkpoint can never be replayed against an older
        // archive); anything else restarts from scratch and catches up
        // deterministically.
        let k = ru.archived_qualities.len();
        if k == rung && rung > 0 {
            let ck = ckpt_file(&opts.out_dir, &ru.name, rung);
            if ck.exists() {
                if let Ok(st) = checkpoint::load(&ck) {
                    if st.num_layers() == ru.man.num_layers() {
                        ru.state = Some(st);
                        ru.completed = rung;
                    }
                }
            }
        }
        if ru.state.is_none() {
            ru.state = Some(ModelState::init(&ru.man, ru.seed, ru.cand.method));
            ru.completed = 0;
        }
    }
    while ru.completed <= rung {
        let steps = opts.base_steps << ru.completed;
        let mut topts = TrainOpts::from_manifest(&ru.man);
        topts.steps = steps;
        topts.method = ru.cand.method;
        topts.seed = ru.seed ^ (ru.completed as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        native::train_native(&ru.man, ru.state.as_mut().expect("state"), &task.train, &topts)?;
        // Enforce at *every* rung boundary (not once after catch-up), so a
        // crash-recovery catch-up walks the exact mask trajectory of an
        // uninterrupted run.
        enforce_target_fanin(&ru.man, ru.cand.method, ru.state.as_mut().expect("state"));
        trained_now += steps;
        ru.completed += 1;
    }
    let logits =
        native::evaluate_native(&ru.man, ru.state.as_ref().expect("state"), &task.test);
    let (q, acc) = quality_of(&logits, &task.test.y, task.classes);
    ru.quality = q;
    ru.accuracy = acc;
    if trained_now > 0 {
        checkpoint::save(
            ru.state.as_ref().expect("state"),
            &ckpt_file(&opts.out_dir, &ru.name, ru.completed),
        )?;
    }
    Ok((ru, trained_now))
}

/// One emitted frontier model: synthesized, optimized, machine-verified
/// and scored through the netlist serving backend.
#[derive(Debug, Clone)]
pub struct EmitResult {
    pub name: String,
    pub analytical_luts: u64,
    pub mapped_luts: usize,
    pub brams: usize,
    pub opt_reduction: f64,
    pub netlist_accuracy: f64,
}

/// Search outcome summary (the archive on disk is the full record).
pub struct SearchOutcome {
    pub generated: usize,
    pub admitted: usize,
    pub gated: usize,
    /// Native-trainer steps actually run in this invocation (0 on a full
    /// resume — the acceptance contract for `--resume`).
    pub steps_trained: usize,
    pub frontier: Vec<DesignPoint>,
    pub emitted: Vec<EmitResult>,
    pub archive_path: PathBuf,
    /// `zoo.json` path when `emit_zoo` produced one.
    pub zoo_path: Option<PathBuf>,
}

/// Run a cost-gated successive-halving search and persist the archive.
pub fn run_search(
    task: &SearchTask,
    axes: &SearchAxes,
    opts: &SearchOpts,
) -> Result<SearchOutcome> {
    ensure!(opts.rungs >= 1, "need at least one rung");
    ensure!(opts.base_steps >= 1, "need at least one step per rung");
    ensure!(opts.eta >= 2, "eta must be >= 2 (got {})", opts.eta);
    std::fs::create_dir_all(&opts.out_dir)?;
    let archive_path = opts.out_dir.join("archive.json");
    let mut archive = if opts.resume && archive_path.exists() {
        let a = Archive::load(&archive_path)?;
        a.check_compatible(task, axes, opts)?;
        println!(
            "[dse] resuming archive {} ({} entries)",
            archive_path.display(),
            a.entries.len()
        );
        a
    } else {
        Archive::new(task, axes, opts)
    };

    // ---- generate + gate --------------------------------------------------
    let mut candidates = generate(axes, opts.seed, opts.max_candidates);
    // Conv candidates need the task input to read as a square image with
    // the kernel fitting it; drop impossible geometries up front (the
    // check is deterministic, so resumed runs replay the same pool) with
    // a line naming the reason instead of failing mid-search.
    candidates.retain(|c| {
        if c.conv.is_none() {
            return true;
        }
        match c.manifest(&task.dataset, task.in_features, task.classes) {
            Ok(_) => true,
            Err(err) => {
                println!("[dse] dropped conv candidate {}: {err:#}", c.name());
                false
            }
        }
    });
    let generated = candidates.len();
    let gate = CostGate { budget_luts: opts.budget_luts };
    let mut admitted: Vec<(Candidate, u64)> = Vec::new();
    let mut gated = 0usize;
    for c in candidates {
        let luts = gate.price(&c, task.in_features, task.classes);
        if gate.admits(luts) {
            admitted.push((c, luts));
        } else {
            gated += 1;
            archive
                .entries
                .entry(c.name())
                .or_insert_with(|| ArchiveEntry::from_candidate(&c, luts, "gated"));
        }
    }
    ensure!(
        !admitted.is_empty(),
        "cost gate rejected all {generated} candidates (budget {} LUTs)",
        opts.budget_luts
    );
    println!(
        "[dse] {generated} candidates generated; gate admitted {} / rejected {gated} \
         (budget {} LUTs)",
        admitted.len(),
        opts.budget_luts
    );
    obs::add("dse.candidates.generated.count", generated as u64);
    obs::add("dse.candidates.gated.count", gated as u64);
    obs::add("dse.candidates.admitted.count", admitted.len() as u64);

    // ---- successive halving ----------------------------------------------
    let mut survivors: Vec<Runner> = Vec::with_capacity(admitted.len());
    for (c, luts) in &admitted {
        let name = c.name();
        // Admitted candidates have already built a probe manifest above
        // (conv) or cannot fail (MLP); the context covers future axes.
        let man = c
            .manifest(&task.dataset, task.in_features, task.classes)
            .with_context(|| format!("building manifest for candidate {name}"))?;
        let (aq, aa) = archive
            .entries
            .get(&name)
            .filter(|e| e.status == "trained")
            .map(|e| (e.qualities.clone(), e.accuracy))
            .unwrap_or_default();
        survivors.push(Runner {
            seed: opts.seed ^ fnv1a(name.as_bytes()),
            cand: c.clone(),
            name,
            man,
            luts: *luts,
            archived_qualities: aq,
            archived_accuracy: aa,
            state: None,
            completed: 0,
            quality: 0.0,
            accuracy: 0.0,
        });
    }

    let mut steps_trained = 0usize;
    for rung in 0..opts.rungs {
        let rung_span = obs::Span::named("dse.rung.ns");
        let results: Vec<Result<(Runner, usize)>> =
            pool::par_map(&survivors, |_, ru| advance_runner(task, opts, ru, rung));
        let mut next: Vec<Runner> = Vec::with_capacity(results.len());
        let mut rung_steps = 0usize;
        for r in results {
            let (ru, steps) = r?;
            rung_steps += steps;
            next.push(ru);
        }
        drop(rung_span);
        obs::inc("dse.rungs.count");
        obs::add("dse.steps_trained.count", rung_steps as u64);
        steps_trained += rung_steps;
        // Record this rung into the archive.
        for ru in &next {
            let e = archive
                .entries
                .entry(ru.name.clone())
                .or_insert_with(|| ArchiveEntry::from_candidate(&ru.cand, ru.luts, "trained"));
            e.status = "trained".to_string();
            if e.qualities.len() == rung {
                e.qualities.push(ru.quality);
            }
            e.accuracy = ru.accuracy;
            e.trained_steps = e.trained_steps.max(cumulative_steps(opts, e.qualities.len()));
        }
        archive.save(&archive_path)?;
        // Promote the top fraction (deterministic total order).
        next.sort_by(|a, b| {
            b.quality
                .total_cmp(&a.quality)
                .then(a.luts.cmp(&b.luts))
                .then(a.name.cmp(&b.name))
        });
        let keep = if rung + 1 == opts.rungs {
            next.len()
        } else {
            next.len().div_ceil(opts.eta).max(1)
        };
        println!(
            "[dse] rung {rung}: {} candidates, +{} steps each planned, {} promoted \
             (best {} @ {:.2})",
            next.len(),
            opts.base_steps << rung,
            keep.min(next.len()),
            next.first().map(|r| r.name.as_str()).unwrap_or("-"),
            next.first().map(|r| r.quality).unwrap_or(0.0),
        );
        next.truncate(keep);
        survivors = next;
    }

    // ---- frontier + report ------------------------------------------------
    let points = archive.design_points();
    let frontier = pareto_frontier(&points);
    print_search_report(&archive, &frontier, &opts.out_dir)?;

    // ---- emit: frontier → synthesize --opt → NetlistEngine ---------------
    let mut emitted = Vec::new();
    // Engines kept alongside (same index) so the zoo calibration pass can
    // reuse them instead of re-running the whole synthesis pipeline.
    let mut emitted_engines: Vec<NetlistEngine> = Vec::new();
    if opts.emit > 0 {
        // Highest-quality frontier points first.  Eliminated-early frontier
        // points are emittable too: their last checkpoint is on disk.
        let mut targets: Vec<&DesignPoint> = frontier.iter().collect();
        targets.sort_by(|a, b| b.quality.total_cmp(&a.quality));
        for p in targets.into_iter().take(opts.emit) {
            let entry = archive.entries.get(&p.name).expect("frontier point archived").clone();
            let state = survivors
                .iter()
                .find(|r| r.name == p.name)
                .and_then(|r| r.state.clone());
            match emit_model(task, opts, &entry, state) {
                Ok((res, engine)) => {
                    if let Some(e) = archive.entries.get_mut(&res.name) {
                        e.mapped_luts = Some(res.mapped_luts as u64);
                        e.netlist_accuracy = Some(res.netlist_accuracy);
                    }
                    emitted.push(res);
                    emitted_engines.push(engine);
                }
                Err(err) => eprintln!("[dse] emit {} failed: {err:#}", p.name),
            }
        }
        archive.save(&archive_path)?;
    }

    // ---- zoo: the DSE→serving handoff ------------------------------------
    let mut zoo_path = None;
    if opts.emit_zoo {
        if emitted.is_empty() {
            eprintln!("[dse] emit-zoo requested but nothing was emitted; no zoo written");
        } else {
            let zoo = build_zoo(task, opts, &archive, &emitted, &emitted_engines)?;
            let path = opts.out_dir.join("zoo.json");
            zoo.save(&path)?;
            println!(
                "[dse] zoo: {} budget-servable model(s) -> {}",
                zoo.entries.len(),
                path.display()
            );
            zoo_path = Some(path);
        }
    }

    Ok(SearchOutcome {
        generated,
        admitted: admitted.len(),
        gated,
        steps_trained,
        frontier,
        emitted,
        archive_path,
        zoo_path,
    })
}

/// Build the serving zoo from this run's emitted frontier models:
/// calibrate each emitted engine's single-request latency on the task's
/// test rows (the engine is the exact circuit `serve --zoo` will rebuild —
/// `emit_model`'s serving synthesis uses the same BRAM-free
/// `OptLevel::Full` options as `serve::zoo::build_engine`) and register
/// only the models that are non-dominated under the 3-D (mapped LUTs ↓,
/// quality ↑, p99 latency ↓) check — a dominated model is never the right
/// routing answer for any budget.
fn build_zoo(
    task: &SearchTask,
    opts: &SearchOpts,
    archive: &Archive,
    emitted: &[EmitResult],
    engines: &[NetlistEngine],
) -> Result<ZooManifest> {
    debug_assert_eq!(emitted.len(), engines.len());
    let mut entries: Vec<ZooEntry> = Vec::new();
    for (res, engine) in emitted.iter().zip(engines) {
        let e = archive.entries.get(&res.name).expect("emitted model archived");
        // The last recorded rung names the checkpoint that produced the
        // archived quality (same rule as `emit_model`'s reload).  `serve
        // --zoo` rebuilds from this file, so refuse to register a model
        // whose checkpoint is not on disk.
        let checkpoint = format!("ckpt/{}.r{}.bin", e.name, e.qualities.len());
        let ck = opts.out_dir.join(&checkpoint);
        if !ck.exists() {
            eprintln!("[dse] zoo: skipping {} (no checkpoint at {})", res.name, ck.display());
            continue;
        }
        let (p50, p99) = calibrate_latency(engine, &task.test.x, CALIBRATION_ITERS);
        println!(
            "[dse] zoo calibration {}: {} mapped LUTs, p50 {p50:.1}us p99 {p99:.1}us",
            res.name, res.mapped_luts
        );
        entries.push(ZooEntry {
            name: e.name.clone(),
            dataset: task.dataset.clone(),
            in_features: task.in_features,
            classes: task.classes,
            hidden: e.hidden.clone(),
            fanin: e.fanin,
            bw: e.bw,
            skips: e.skips,
            conv_mode: e.conv_mode.clone(),
            conv_channels: e.conv_channels,
            conv_kernel: e.conv_kernel,
            checkpoint,
            luts: res.mapped_luts as u64,
            brams: res.brams,
            quality: e.final_quality().unwrap_or(0.0),
            netlist_accuracy: res.netlist_accuracy,
            p50_us: p50,
            p99_us: p99,
        });
    }
    ensure!(!entries.is_empty(), "no emitted model could be calibrated for the zoo");
    let points: Vec<_> = entries.iter().map(|e| e.point()).collect();
    let keep: std::collections::BTreeSet<String> =
        pareto_frontier_3d(&points).into_iter().map(|p| p.name).collect();
    let before = entries.len();
    entries.retain(|e| keep.contains(&e.name));
    if entries.len() < before {
        println!(
            "[dse] zoo: dropped {} 3-D-dominated model(s); {} registered",
            before - entries.len(),
            entries.len()
        );
    }
    Ok(ZooManifest { dataset: task.dataset.clone(), entries })
}

/// Total steps after `rungs_done` completed rungs (base·(2^r − 1) sum).
fn cumulative_steps(opts: &SearchOpts, rungs_done: usize) -> usize {
    (0..rungs_done).map(|r| opts.base_steps << r).sum()
}

/// `PruneMethod` from its archived `name()` tag (mirrors the CLI parser's
/// default hyper-parameters).
fn method_from_name(s: &str) -> PruneMethod {
    match s {
        "iterative" => PruneMethod::Iterative { every: 10 },
        "momentum" => PruneMethod::Momentum { every: 8, prune_rate: 0.3 },
        _ => PruneMethod::APriori,
    }
}

/// Synthesize one frontier model with the full optimization pipeline,
/// machine-verify it, and score the served netlist on the task's test
/// split — "a search ends with servable, LUT-priced artifacts".  `state`
/// is the in-memory survivor state when available; eliminated-early
/// frontier points reload their last rung checkpoint instead.
fn emit_model(
    task: &SearchTask,
    opts: &SearchOpts,
    entry: &ArchiveEntry,
    state: Option<ModelState>,
) -> Result<(EmitResult, NetlistEngine)> {
    let conv = match (&entry.conv_mode, entry.conv_channels, entry.conv_kernel) {
        (Some(m), Some(cc), Some(ck)) => {
            Some(ConvSpec { mode: m.clone(), channels: cc, kernel: ck })
        }
        _ => None,
    };
    let cand = Candidate {
        hidden: entry.hidden.clone(),
        fanin: entry.fanin,
        bw: entry.bw,
        method: method_from_name(&entry.method),
        bram_min_bits: entry.bram_min_bits,
        skips: entry.skips,
        conv,
    };
    let man = cand.manifest(&task.dataset, task.in_features, task.classes)?;
    let state = match state {
        Some(st) => st,
        None => {
            // The last recorded rung names the checkpoint that produced
            // the archived quality.
            let ck = ckpt_file(&opts.out_dir, &entry.name, entry.qualities.len());
            checkpoint::load(&ck)
                .with_context(|| format!("frontier model {} has no checkpoint", entry.name))?
        }
    };
    ensure!(
        state.num_layers() == man.num_layers(),
        "checkpoint/manifest shape mismatch for {}",
        entry.name
    );
    let ex = ExportedModel::from_state(&man, &state);
    let tables = ModelTables::generate(&ex)?;
    // One synthesis at the candidate's own BRAM threshold: content-bearing
    // BRAM records evaluate in place (wide plan + fused engine), so the
    // deployment-flavored netlist is also the served one — every
    // `--bram-min-bits` axis point ships the circuit it reported, instead
    // of the old BRAM-free re-synthesis.
    let report_opts = SynthOpts {
        registers: false,
        bram_min_bits: cand.bram_min_bits,
        opt: OptLevel::Full,
        ..SynthOpts::default()
    };
    let (netlist, srep) = synthesize(&ex, &tables, report_opts)?;
    let mism = verify_netlist(&ex, &tables, &netlist, 2048, opts.seed)?;
    ensure!(mism == 0, "{mism} netlist/table mismatches on {}", entry.name);
    // Structural complement to the functional check above.  A BRAM-free
    // frontier artifact is `Full`-optimized, so any finding at all
    // (deny-warn) means the pipeline shipped redundancy or bad metadata.
    // A BRAM-carrying netlist skips the opt pipeline and is judged at
    // `None`; it legitimately reports the `bram-ports` Info finding, so
    // the gate there is no Errors and no Warns.
    let lint_report = if netlist.brams.is_empty() {
        crate::synth::lint_netlist(&netlist, &crate::synth::LintOptions { opt: OptLevel::Full })
    } else {
        crate::synth::lint_netlist(&netlist, &crate::synth::LintOptions { opt: OptLevel::None })
    };
    let lint_ok = if netlist.brams.is_empty() {
        lint_report.is_clean()
    } else {
        lint_report.errors() == 0 && lint_report.warnings() == 0
    };
    ensure!(
        lint_ok,
        "frontier model {} fails design-rule lint:\n{}",
        entry.name,
        lint_report.render()
    );
    // Conv candidates additionally prove the receptive-field contract:
    // every exported neuron reads exactly its shared per-channel window
    // (trivially clean for the MLP family).
    let conv_report = crate::synth::lint_conv_model(&man, &ex)?;
    ensure!(
        conv_report.is_clean(),
        "frontier model {} fails conv receptive-field lint:\n{}",
        entry.name,
        conv_report.render()
    );
    let engine = NetlistEngine::from_netlist(&ex, &tables, netlist)?;
    let acc = batch_accuracy(&engine, &task.test.x, &task.test.y);
    println!(
        "[dse] emitted {}: {} analytical -> {} mapped LUTs ({} BRAM, {:.2}x opt), \
         netlist accuracy {:.3}",
        entry.name, entry.luts, srep.luts, srep.brams, srep.opt_reduction, acc
    );
    Ok((
        EmitResult {
            name: entry.name.clone(),
            analytical_luts: entry.luts,
            mapped_luts: srep.luts,
            brams: srep.brams,
            opt_reduction: srep.opt_reduction,
            netlist_accuracy: acc,
        },
        engine,
    ))
}

/// Print + save the search report table (the "search section" companion
/// to the synth report), then the frontier and its marginal costs.
fn print_search_report(
    archive: &Archive,
    frontier: &[DesignPoint],
    out_dir: &Path,
) -> Result<()> {
    let on_frontier: std::collections::BTreeSet<&str> =
        frontier.iter().map(|p| p.name.as_str()).collect();
    let mut t = TextTable::new(
        "DSE search report — cost-gated successive halving",
        &["candidate", "LUTs", "rungs", "steps", "avg AUC", "accuracy", "status", "frontier"],
    );
    let mut rows: Vec<&ArchiveEntry> = archive.entries.values().collect();
    rows.sort_by(|a, b| a.luts.cmp(&b.luts).then(a.name.cmp(&b.name)));
    for e in rows {
        t.row(vec![
            e.name.clone(),
            e.luts.to_string(),
            e.qualities.len().to_string(),
            e.trained_steps.to_string(),
            e.final_quality().map(f2).unwrap_or_else(|| "-".into()),
            if e.status == "trained" { f2(100.0 * e.accuracy) } else { "-".into() },
            e.status.clone(),
            if on_frontier.contains(e.name.as_str()) { "*".into() } else { "".into() },
        ]);
    }
    t.print();
    let csv_path = out_dir.join("search_report.csv");
    t.save_csv(csv_path.to_str().unwrap_or("reports/dse/search_report.csv"))?;
    println!("[saved {}]", csv_path.display());
    println!("Pareto frontier ({} points):", frontier.len());
    for p in frontier {
        println!("  {:<28} {:>8} LUTs   quality {:.2}", p.name, p.luts, p.quality);
    }
    for (name, mc) in marginal_cost(frontier) {
        println!("  marginal cost at {name}: {mc:.0} LUTs per quality point");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_and_capped() {
        let axes = SearchAxes::jets_default();
        let a = generate(&axes, 7, 10);
        let b = generate(&axes, 7, 10);
        assert_eq!(a, b);
        assert_eq!(a.len(), 10);
        let full = generate(&axes, 7, usize::MAX);
        // Duplicate topologies (rect vs taper at depth 1) are pruned, so
        // the pool is bounded by — and here strictly under — the raw
        // cross product.
        assert!(full.len() <= axes.num_candidates());
        assert!(full.len() > axes.num_candidates() / 2);
        // Different seed, different order.
        let c = generate(&axes, 8, 10);
        assert_ne!(a, c);
        // Names are unique across the full product.
        let names: std::collections::BTreeSet<String> =
            full.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), full.len());
        // The new axes are really in the pool: skip and tapered candidates
        // both appear.
        assert!(full.iter().any(|c| c.skips > 0));
        assert!(full.iter().any(|c| c.hidden.windows(2).any(|w| w[0] != w[1])));
        // Default conv axes ("none") leave the pool conv-free.
        assert!(full.iter().all(|c| c.conv.is_none()));
    }

    #[test]
    fn generator_sweeps_conv_axes_and_canonicalizes() {
        let mut axes = SearchAxes::jets_default();
        axes.conv_modes = vec!["none".into(), "dense".into(), "dw".into()];
        axes.channels = vec![2, 4];
        axes.kernels = vec![3];
        let full = generate(&axes, 7, usize::MAX);
        // Both conv modes appear, MLP candidates survive alongside, and
        // every conv candidate is skip-free (the manifest contract).
        assert!(full.iter().any(|c| matches!(&c.conv, Some(cv) if cv.mode == "dense")));
        assert!(full.iter().any(|c| matches!(&c.conv, Some(cv) if cv.mode == "dw")));
        assert!(full.iter().any(|c| c.conv.is_none()));
        assert!(full.iter().filter(|c| c.conv.is_some()).all(|c| c.skips == 0));
        // Names stay unique: the conv suffix separates the new points.
        let names: std::collections::BTreeSet<String> =
            full.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), full.len());
        // "none" collapses the channel/kernel axes — the conv-free subset
        // is exactly the pool the default axes generate.
        let mut defaults = SearchAxes::jets_default();
        defaults.conv_modes = vec!["none".into()];
        let base: std::collections::BTreeSet<String> =
            generate(&defaults, 7, usize::MAX).iter().map(|c| c.name()).collect();
        let mlp: std::collections::BTreeSet<String> =
            full.iter().filter(|c| c.conv.is_none()).map(|c| c.name()).collect();
        assert_eq!(mlp, base);
    }

    #[test]
    fn width_shapes_schedule_and_parse() {
        assert_eq!(WidthShape::Rect.widths(32, 3), vec![32, 32, 32]);
        assert_eq!(WidthShape::Taper { pct: 50 }.widths(32, 3), vec![32, 16, 8]);
        // Floor: tapers never go below MIN_TAPER_WIDTH.
        assert_eq!(WidthShape::Taper { pct: 25 }.widths(16, 3), vec![16, 4, 4]);
        assert_eq!(WidthShape::parse("rect"), Some(WidthShape::Rect));
        assert_eq!(WidthShape::parse("taper50"), Some(WidthShape::Taper { pct: 50 }));
        assert_eq!(WidthShape::parse(" taper75 "), Some(WidthShape::Taper { pct: 75 }));
        assert_eq!(WidthShape::parse("taper0"), None);
        assert_eq!(WidthShape::parse("taper101"), None);
        assert_eq!(WidthShape::parse("cone"), None);
    }

    #[test]
    fn axes_key_is_backward_compatible_for_default_new_axes() {
        // With the pre-skip defaults the key must be byte-identical to the
        // pre-skip format, so old archives stay resumable.
        let mut axes = SearchAxes::jets_default();
        axes.skips = vec![0];
        axes.shapes = vec![WidthShape::Rect];
        assert_eq!(axes.key(), "w16-32-64_d1-2_f2-3-4_b1-2-3_ma-priori_r13");
        // Non-default new axes extend the key (and so trip the resume
        // compatibility check against old archives).
        axes.skips = vec![0, 1];
        assert!(axes.key().ends_with("_s0-1"));
        axes.shapes = vec![WidthShape::Rect, WidthShape::Taper { pct: 50 }];
        assert!(axes.key().ends_with("_s0-1_yrect-taper50"));
        // Conv axes extend the key only when swept away from their
        // defaults, in a fixed section order.
        axes.conv_modes = vec!["none".into(), "dense".into()];
        assert!(axes.key().ends_with("_cnone-dense"));
        axes.channels = vec![4, 8];
        axes.kernels = vec![3, 5];
        assert!(axes.key().ends_with("_cnone-dense_n4-8_k3-5"));
    }

    #[test]
    fn resume_refusals_name_the_offending_axis() {
        let task = SearchTask::jets_small(200, 11);
        let opts = SearchOpts::default();
        let axes = SearchAxes::jets_default();
        let archive = Archive::new(&task, &axes, &opts);
        let mut conv_axes = axes.clone();
        conv_axes.conv_modes = vec!["none".into(), "dense".into()];
        let err = archive.check_compatible(&task, &conv_axes, &opts).unwrap_err();
        assert!(err.to_string().contains("conv-mode"), "got: {err}");
        let mut width_axes = axes.clone();
        width_axes.widths.push(128);
        let err = archive.check_compatible(&task, &width_axes, &opts).unwrap_err();
        assert!(err.to_string().contains("hidden-width"), "got: {err}");
        let mut kernel_axes = axes.clone();
        kernel_axes.kernels = vec![3, 5];
        let err = archive.check_compatible(&task, &kernel_axes, &opts).unwrap_err();
        assert!(err.to_string().contains("conv-kernel"), "got: {err}");
        // Parameter mismatches name the parameter, not the axes.
        let other = SearchOpts { eta: opts.eta + 1, ..opts.clone() };
        let err = archive.check_compatible(&task, &axes, &other).unwrap_err();
        assert!(err.to_string().contains("--eta"), "got: {err}");
    }

    #[test]
    fn gate_pricing_matches_manifest_cost() {
        let mut axes = SearchAxes::jets_default();
        // Sweep the conv axes too: 16 features = a 4x4 image, so both
        // conv modes lower to real geometries here.
        axes.conv_modes = vec!["none".into(), "dense".into(), "dw".into()];
        axes.channels = vec![2, 4];
        let cands = generate(&axes, 3, usize::MAX);
        assert!(cands.iter().any(|c| c.conv.is_some()), "conv candidates in the pool");
        for c in cands {
            let man = c.manifest("jets", 16, 5).unwrap();
            let exact = cost::total_luts(&cost::manifest_cost(&man));
            assert_eq!(c.analytical_luts(16, 5), exact, "{}", c.name());
        }
    }

    #[test]
    fn conv_pricing_saturates_on_impossible_geometry() {
        let cv = Candidate {
            hidden: vec![16],
            fanin: 3,
            bw: 2,
            method: PruneMethod::APriori,
            bram_min_bits: 13,
            skips: 0,
            conv: Some(ConvSpec { mode: "dense".into(), channels: 4, kernel: 3 }),
        };
        // 17 features is not a square image: never admissible.
        assert_eq!(cv.analytical_luts(17, 5), u64::MAX);
        assert!(cv.manifest("jets", 17, 5).is_err());
        // Kernel larger than the image side likewise.
        let big = Candidate {
            conv: Some(ConvSpec { mode: "dense".into(), channels: 4, kernel: 5 }),
            ..cv.clone()
        };
        assert_eq!(big.analytical_luts(16, 5), u64::MAX);
        assert!(big.manifest("jets", 16, 5).is_err());
        // A valid geometry prices strictly under saturation.
        assert!(cv.analytical_luts(16, 5) < u64::MAX);
    }

    #[test]
    fn archive_roundtrips_through_json() {
        let task = SearchTask::jets_small(200, 3);
        let opts = SearchOpts::default();
        let axes = SearchAxes::jets_default();
        let mut a = Archive::new(&task, &axes, &opts);
        let c = Candidate {
            hidden: vec![32, 16],
            fanin: 3,
            bw: 2,
            method: PruneMethod::APriori,
            bram_min_bits: 13,
            skips: 1,
            conv: None,
        };
        let mut e = ArchiveEntry::from_candidate(&c, 1234, "trained");
        e.qualities = vec![55.5, 60.25];
        e.accuracy = 0.625;
        e.trained_steps = 120;
        e.mapped_luts = Some(321);
        e.netlist_accuracy = Some(0.61);
        a.entries.insert(e.name.clone(), e);
        let g = Candidate { hidden: vec![64], bw: 3, ..c.clone() };
        a.entries.insert(g.name(), ArchiveEntry::from_candidate(&g, 99_999, "gated"));
        let cv = Candidate {
            hidden: vec![16],
            skips: 0,
            conv: Some(ConvSpec { mode: "dw".into(), channels: 4, kernel: 3 }),
            ..c.clone()
        };
        a.entries.insert(cv.name(), ArchiveEntry::from_candidate(&cv, 2_345, "trained"));
        let dir = std::env::temp_dir().join("lnck_dse_archive_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("archive.json");
        a.save(&path).unwrap();
        let back = Archive::load(&path).unwrap();
        assert_eq!(back.entries.len(), 3);
        let be = &back.entries[&c.name()];
        assert_eq!(be.hidden, vec![32, 16]);
        assert_eq!(be.skips, 1, "skip axis must round-trip");
        assert_eq!(be.qualities, vec![55.5, 60.25]);
        assert_eq!(be.luts, 1234);
        assert_eq!(be.mapped_luts, Some(321));
        assert_eq!(be.status, "trained");
        // MLP entries round-trip conv-free (their JSON carries no conv
        // keys at all).
        assert_eq!(be.conv_mode, None);
        let bg = &back.entries[&g.name()];
        assert_eq!(bg.status, "gated");
        assert_eq!(bg.mapped_luts, None);
        // Conv axes must round-trip on conv entries.
        let bc = &back.entries[&cv.name()];
        assert_eq!(bc.conv_mode.as_deref(), Some("dw"));
        assert_eq!(bc.conv_channels, Some(4));
        assert_eq!(bc.conv_kernel, Some(3));
        assert_eq!(back.budget_luts, a.budget_luts);
        assert_eq!(back.axes_key, axes.key());
        // Compatibility check trips on a parameter, axes, or cap change.
        let mut other = SearchOpts::default();
        other.seed += 1;
        assert!(back.check_compatible(&task, &axes, &opts).is_ok());
        assert!(back.check_compatible(&task, &axes, &other).is_err());
        let mut other_axes = axes.clone();
        other_axes.widths.push(128);
        assert!(back.check_compatible(&task, &other_axes, &opts).is_err());
        let mut other_cap = SearchOpts::default();
        other_cap.max_candidates += 1;
        assert!(back.check_compatible(&task, &axes, &other_cap).is_err());
    }

    #[test]
    fn archive_u64_params_survive_beyond_f64_precision() {
        // 2^53 + 1 is not representable in f64; the string round-trip must
        // preserve it exactly or resume would refuse its own archive.
        let task = SearchTask::jets_small(200, 5);
        let axes = SearchAxes::jets_default();
        let opts = SearchOpts {
            seed: (1u64 << 53) + 1,
            budget_luts: u64::MAX - 1,
            ..SearchOpts::default()
        };
        let mut a = Archive::new(&task, &axes, &opts);
        // Entry costs must survive too: a saturated gated candidate sits
        // at exactly u64::MAX.
        let c = Candidate {
            hidden: vec![8],
            fanin: 2,
            bw: 1,
            method: PruneMethod::APriori,
            bram_min_bits: 13,
            skips: 0,
            conv: None,
        };
        a.entries
            .insert(c.name(), ArchiveEntry::from_candidate(&c, u64::MAX, "gated"));
        let dir = std::env::temp_dir().join("lnck_dse_archive_u64_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("archive.json");
        a.save(&path).unwrap();
        let back = Archive::load(&path).unwrap();
        assert_eq!(back.seed, (1u64 << 53) + 1);
        assert_eq!(back.budget_luts, u64::MAX - 1);
        assert_eq!(back.entries[&c.name()].luts, u64::MAX);
        assert!(back.check_compatible(&task, &axes, &opts).is_ok());
    }

    #[test]
    fn cumulative_steps_sums_rung_budgets() {
        let opts = SearchOpts { base_steps: 40, ..SearchOpts::default() };
        assert_eq!(cumulative_steps(&opts, 0), 0);
        assert_eq!(cumulative_steps(&opts, 1), 40);
        assert_eq!(cumulative_steps(&opts, 3), 40 + 80 + 160);
    }
}
