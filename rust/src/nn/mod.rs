//! The hardware-facing view of a trained LogicNet: quantizers, folded
//! batch-norm, sparse per-neuron rows, and a pure-Rust forward mirror used
//! by truth-table export and functional verification.

pub mod export;
pub mod quant;

pub use export::{ExportedLayer, ExportedModel, Neuron};
pub use quant::QuantSpec;
