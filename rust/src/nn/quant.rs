//! Rust mirror of the activation quantizers — must match
//! `python/compile/kernels/quantize.py` bit-for-bit.
//!
//! Both sides use round-half-to-even (`jnp.round` / `f32::round_ties_even`),
//! so quantizer codes computed here during truth-table export agree exactly
//! with what the JAX training graph produced.

/// A uniform activation quantizer: QuantHardTanh for 1 bit, QuantReLU
/// otherwise (paper §3.1.2 / §4.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantSpec {
    pub bw: usize,
    pub maxv: f32,
}

impl QuantSpec {
    pub fn new(bw: usize, maxv: f32) -> QuantSpec {
        assert!((1..=16).contains(&bw), "bw {bw}");
        QuantSpec { bw, maxv }
    }

    /// Number of representable codes.
    pub fn num_codes(&self) -> usize {
        1usize << self.bw
    }

    pub fn levels(&self) -> f32 {
        (self.num_codes() - 1) as f32
    }

    pub fn step(&self) -> f32 {
        self.maxv / self.levels()
    }

    /// Quantize to the representable value (dequantized representation).
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        if self.bw == 1 {
            if x >= 0.0 {
                self.maxv
            } else {
                -self.maxv
            }
        } else {
            let step = self.step();
            let c = (x / step).round_ties_even().clamp(0.0, self.levels());
            c * step
        }
    }

    /// Integer code of the quantizer (truth-table input/output bits).
    #[inline]
    pub fn code(&self, x: f32) -> u32 {
        if self.bw == 1 {
            (x >= 0.0) as u32
        } else {
            let step = self.step();
            (x / step).round_ties_even().clamp(0.0, self.levels()) as u32
        }
    }

    /// Representable value of a code.
    #[inline]
    pub fn dequant(&self, c: u32) -> f32 {
        if self.bw == 1 {
            (2.0 * c as f32 - 1.0) * self.maxv
        } else {
            c as f32 * self.step()
        }
    }

    /// Quantize a slice in place.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = self.quantize(*x);
        }
    }

    pub fn codes_slice(&self, xs: &[f32], out: &mut [u32]) {
        for (o, &x) in out.iter_mut().zip(xs) {
            *o = self.code(x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hardtanh_bit1() {
        let q = QuantSpec::new(1, 1.61);
        assert_eq!(q.quantize(0.3), 1.61);
        assert_eq!(q.quantize(-0.3), -1.61);
        assert_eq!(q.quantize(0.0), 1.61); // x >= 0 convention, as in JAX
        assert_eq!(q.code(-5.0), 0);
        assert_eq!(q.code(5.0), 1);
        assert_eq!(q.dequant(0), -1.61);
        assert_eq!(q.dequant(1), 1.61);
    }

    #[test]
    fn quant_relu_grid() {
        let q = QuantSpec::new(2, 3.0); // levels 3, step 1.0
        assert_eq!(q.quantize(-1.0), 0.0);
        assert_eq!(q.quantize(0.4), 0.0);
        assert_eq!(q.quantize(0.6), 1.0);
        assert_eq!(q.quantize(2.2), 2.0);
        assert_eq!(q.quantize(9.0), 3.0);
        assert_eq!(q.code(2.2), 2);
    }

    #[test]
    fn round_ties_even_matches_jnp() {
        let q = QuantSpec::new(3, 7.0); // step 1.0
        // jnp.round(0.5) == 0.0, jnp.round(1.5) == 2.0, jnp.round(2.5) == 2.0
        assert_eq!(q.quantize(0.5), 0.0);
        assert_eq!(q.quantize(1.5), 2.0);
        assert_eq!(q.quantize(2.5), 2.0);
        assert_eq!(q.quantize(3.5), 4.0);
    }

    #[test]
    fn code_dequant_roundtrip() {
        for bw in 1..=8usize {
            let q = QuantSpec::new(bw, 2.0);
            for c in 0..q.num_codes() as u32 {
                assert_eq!(q.code(q.dequant(c)), c, "bw={bw} c={c}");
            }
        }
    }
}
