//! Export path: collapse a trained `ModelState` into the hardware view.
//!
//! Batch-norm is folded into a per-neuron affine using the EMA running
//! statistics (`g = gamma / sqrt(var + eps)`, `h = beta - g * mean`), each
//! neuron keeps only its fan-in weights, and every layer carries its input
//! and output quantizer specs.  From here a neuron *is* the boolean function
//!
//! ```text
//! codes_in -> quant_out( g * (w . dequant(codes_in) + b) + h )
//! ```
//!
//! which `crate::luts` enumerates into truth tables.

use super::quant::QuantSpec;
use crate::runtime::Manifest;
use crate::train::ModelState;

/// One neuron: fan-in indices into the layer input vector plus folded
/// affine parameters.
#[derive(Debug, Clone)]
pub struct Neuron {
    pub inputs: Vec<usize>,
    pub weights: Vec<f32>,
    /// bias + folded BN shift, pre-multiplied: y = g*(w.x + b) + h
    pub bias: f32,
    pub g: f32,
    pub h: f32,
}

impl Neuron {
    /// Pre-activation response for the given (already dequantized) input
    /// values gathered at `self.inputs`.
    #[inline]
    pub fn respond(&self, vals: &[f32]) -> f32 {
        debug_assert_eq!(vals.len(), self.weights.len());
        let mut z = self.bias;
        for (w, v) in self.weights.iter().zip(vals) {
            z += w * v;
        }
        self.g * z + self.h
    }

    /// Response gathering inputs from the full layer input vector.
    #[inline]
    pub fn respond_gather(&self, input: &[f32]) -> f32 {
        let mut z = self.bias;
        for (w, &i) in self.weights.iter().zip(&self.inputs) {
            z += w * input[i];
        }
        self.g * z + self.h
    }

    pub fn fanin(&self) -> usize {
        self.inputs.len()
    }
}

#[derive(Debug, Clone)]
pub struct ExportedLayer {
    pub neurons: Vec<Neuron>,
    pub in_f: usize,
    pub quant_in: QuantSpec,
    pub quant_out: QuantSpec,
    /// Truth-table input bits per neuron (fanin * quant_in.bw); only
    /// meaningful for sparse layers.
    pub sparse: bool,
    /// Quantizer spec of every *element* of the input vector.  With skip
    /// connections the concatenated segments come from different
    /// quantizers (the raw input uses maxv_in, hidden activations
    /// maxv_hidden), so dequantization is per-element.  All specs share
    /// `quant_in.bw` (asserted at export) so the bit packing stays uniform.
    pub input_specs: Vec<QuantSpec>,
}

impl ExportedLayer {
    /// Layer whose whole input comes from a single quantizer.
    pub fn uniform(
        neurons: Vec<Neuron>,
        in_f: usize,
        quant_in: QuantSpec,
        quant_out: QuantSpec,
        sparse: bool,
    ) -> ExportedLayer {
        ExportedLayer {
            neurons,
            in_f,
            quant_in,
            quant_out,
            sparse,
            input_specs: vec![quant_in; in_f],
        }
    }
}

impl ExportedLayer {
    pub fn in_bits(&self) -> usize {
        self.neurons.iter().map(|n| n.fanin()).max().unwrap_or(0) * self.quant_in.bw
    }

    /// Pin every neuron of this layer to the two extreme output codes:
    /// alternating ±1 weights and a small negative bias keep each
    /// pre-activation at least 0.05/3 away from zero on quantized inputs,
    /// so the 200x gain saturates the output quantizer either way.  This
    /// is the trained-LogicNets regime (activation saturation) in its
    /// purest form; the don't-care-pruning tests, the optimizer example
    /// and the CI LUT-reduction gate all share this one recipe so they
    /// exercise the same saturation behavior.
    pub fn saturate_binary(&mut self) {
        for nr in self.neurons.iter_mut() {
            nr.g = 200.0;
            nr.h = 0.0;
            nr.bias = -0.05;
            for (wi, w) in nr.weights.iter_mut().enumerate() {
                *w = if wi % 2 == 0 { 1.0 } else { -1.0 };
            }
        }
    }
}

/// The full exported model plus the skip wiring needed to mirror the JAX
/// forward pass exactly.
#[derive(Debug, Clone)]
pub struct ExportedModel {
    pub layers: Vec<ExportedLayer>,
    pub in_features: usize,
    pub classes: usize,
    pub skips: usize,
    /// Activation widths `[in_features, hidden...]` used for skip concat.
    pub act_widths: Vec<usize>,
}

impl ExportedModel {
    pub fn from_state(man: &Manifest, state: &ModelState) -> ExportedModel {
        let n = man.num_layers();
        let mut layers = Vec::with_capacity(n);
        for i in 0..n {
            let spec = &man.layers[i];
            let bw_out = if i + 1 == n { man.bw_out } else { man.bw };
            let maxv_out = if i + 1 == n { man.maxv_out } else { man.maxv_hidden };
            let mut neurons = Vec::with_capacity(spec.out_f);
            for o in 0..spec.out_f {
                let row = &state.masks[i].rows[o];
                let weights: Vec<f32> =
                    row.iter().map(|&j| state.ws[i][o * spec.in_f + j]).collect();
                let var = state.rvars[i][o];
                let g = state.gammas[i][o] / (var + man.bn_eps).sqrt();
                let h = state.betas[i][o] - g * state.rmeans[i][o];
                neurons.push(Neuron {
                    inputs: row.clone(),
                    weights,
                    bias: state.bs[i][o],
                    g,
                    h,
                });
            }
            // Per-element input specs, honoring skip concatenation
            // (newest-first segments; segment j==0 is the raw input).
            let quant_in = QuantSpec::new(spec.bw_in, spec.maxv_in);
            let in_spec = QuantSpec::new(man.bw_in, man.maxv_in);
            let hid_spec = QuantSpec::new(man.bw, man.maxv_hidden);
            let mut act_widths = vec![man.in_features];
            act_widths.extend(man.hidden.iter().copied());
            let mut input_specs: Vec<QuantSpec> = Vec::with_capacity(spec.in_f);
            if i == 0 || man.skips == 0 {
                input_specs.extend(std::iter::repeat(quant_in).take(spec.in_f));
            } else {
                let lo = i.saturating_sub(man.skips);
                for j in (lo..=i).rev() {
                    let s = if j == 0 { in_spec } else { hid_spec };
                    input_specs.extend(std::iter::repeat(s).take(act_widths[j]));
                }
            }
            assert_eq!(input_specs.len(), spec.in_f, "layer {i} input spec width");
            if man.skips > 0 {
                assert!(
                    input_specs.iter().all(|s| s.bw == quant_in.bw),
                    "skip wiring requires uniform input bit-width"
                );
            }
            layers.push(ExportedLayer {
                neurons,
                in_f: spec.in_f,
                quant_in,
                quant_out: QuantSpec::new(bw_out, maxv_out),
                sparse: spec.fanin.is_some(),
                input_specs,
            });
        }
        let mut act_widths = vec![man.in_features];
        act_widths.extend(man.hidden.iter().copied());
        ExportedModel {
            layers,
            in_features: man.in_features,
            classes: man.classes,
            skips: man.skips,
            act_widths,
        }
    }

    /// Mirror of python `_skip_input`: layer `i`'s input vector is the
    /// concatenation of the newest `min(skips, i)+1` activations,
    /// newest-first.
    pub fn skip_input(&self, acts: &[Vec<f32>], i: usize) -> Vec<f32> {
        if i == 0 || self.skips == 0 {
            return acts[acts.len() - 1].clone();
        }
        let lo = i.saturating_sub(self.skips);
        let mut out = Vec::new();
        for j in (lo..acts.len()).rev() {
            out.extend_from_slice(&acts[j]);
        }
        out
    }

    /// Pure-Rust forward pass on one sample (dequantized values all the way
    /// through).  Returns the final-layer quantized logits.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_features);
        let q0 = self.layers[0].quant_in;
        let mut a: Vec<f32> = x.iter().map(|&v| q0.quantize(v)).collect();
        let mut acts: Vec<Vec<f32>> = vec![a.clone()];
        let n = self.layers.len();
        for (i, layer) in self.layers.iter().enumerate() {
            let inp = self.skip_input(&acts, i);
            debug_assert_eq!(inp.len(), layer.in_f, "layer {i} input width");
            let mut out = Vec::with_capacity(layer.neurons.len());
            for nr in &layer.neurons {
                let y = nr.respond_gather(&inp);
                out.push(layer.quant_out.quantize(y));
            }
            a = out;
            if i + 1 < n {
                acts.push(a.clone());
            }
        }
        a
    }

    /// Batch forward returning row-major logits.
    pub fn forward_batch(&self, xs: &[f32]) -> Vec<f32> {
        let d = self.in_features;
        assert_eq!(xs.len() % d, 0);
        let mut out = Vec::with_capacity(xs.len() / d * self.classes);
        for row in xs.chunks(d) {
            out.extend(self.forward(row));
        }
        out
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total neurons in sparse (table-mapped) layers.
    pub fn sparse_neurons(&self) -> usize {
        self.layers.iter().filter(|l| l.sparse).map(|l| l.neurons.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use crate::sparsity::prune::PruneMethod;

    fn man() -> Manifest {
        Manifest::parse(
            r#"{
          "name":"t","kind":"mlp","in_features":4,"classes":3,"hidden":[6],
          "bw":2,"bw_in":2,"bw_out":2,"fanin":2,"fanin_fc":null,"skips":0,
          "batch":8,"eval_batch":8,"dataset":"jets",
          "maxv_in":1.0,"maxv_hidden":2.0,"maxv_out":4.0,"bn_eps":1e-05,
          "layers":[{"in":4,"out":6,"fanin":2,"bw_in":2,"maxv_in":1.0},
                    {"in":6,"out":3,"fanin":null,"bw_in":2,"maxv_in":2.0}]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn export_shapes_and_fold() {
        let m = man();
        let st = ModelState::init(&m, 5, PruneMethod::APriori);
        let ex = ExportedModel::from_state(&m, &st);
        assert_eq!(ex.num_layers(), 2);
        assert_eq!(ex.layers[0].neurons.len(), 6);
        assert!(ex.layers[0].sparse);
        assert!(!ex.layers[1].sparse);
        assert!(ex.layers[0].neurons.iter().all(|n| n.fanin() == 2));
        // Fresh state: gamma=1, beta=0, rmean=0, rvar=1 => g = 1/sqrt(1+eps)
        let g = ex.layers[0].neurons[0].g;
        assert!((g - 1.0 / (1.0f32 + 1e-5).sqrt()).abs() < 1e-6);
        assert_eq!(ex.layers[0].neurons[0].h, 0.0);
    }

    #[test]
    fn forward_outputs_on_quantizer_grid() {
        let m = man();
        let st = ModelState::init(&m, 6, PruneMethod::APriori);
        let ex = ExportedModel::from_state(&m, &st);
        let logits = ex.forward(&[0.2, 0.9, 0.0, 0.5]);
        assert_eq!(logits.len(), 3);
        let q = QuantSpec::new(m.bw_out, m.maxv_out);
        for &v in &logits {
            assert_eq!(q.quantize(v), v, "logit {v} must be a fixed point of the quantizer");
        }
    }

    #[test]
    fn respond_matches_gather() {
        let nr = Neuron {
            inputs: vec![1, 3],
            weights: vec![0.5, -2.0],
            bias: 0.25,
            g: 2.0,
            h: -0.1,
        };
        let input = [9.0, 1.0, 9.0, 0.5];
        let gathered = [1.0, 0.5];
        assert_eq!(nr.respond(&gathered), nr.respond_gather(&input));
        let expect = 2.0 * (0.25 + 0.5 * 1.0 + (-2.0) * 0.5) + (-0.1);
        assert!((nr.respond(&gathered) - expect).abs() < 1e-6);
    }

    #[test]
    fn skip_input_order_newest_first() {
        let m = man();
        let st = ModelState::init(&m, 7, PruneMethod::APriori);
        let mut ex = ExportedModel::from_state(&m, &st);
        ex.skips = 1;
        let acts = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let inp = ex.skip_input(&acts, 1);
        assert_eq!(inp, vec![3.0, 4.0, 1.0, 2.0]);
    }
}
