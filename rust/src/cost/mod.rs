//! Analytical LUT-cost model (paper ch. 2 & 4).
//!
//! A neuron seen as a boolean function `f: B^N -> B^M` (N fan-in bits, M
//! output bits) decomposes into 6:1 LUTs with cost (eq. 2.3):
//!
//! ```text
//! LUT(N, M) = M * (2^(N-4) - (-1)^N) / 3        (N >= 6)
//! ```
//!
//! Dense (unsparsified) layers use the empirical fit of eq. 4.1 and
//! depthwise-separable convolutions use eqs. 4.3/4.4.  These analytical
//! numbers are deliberately *pessimistic*; the synthesis simulator
//! (`crate::synth`) reproduces the paper's Table 5.2 observation that true
//! post-synthesis costs are a fraction of them.

/// Closed-form 6-LUT cost of one neuron, eq. 2.3.  For N <= 6 a single LUT
/// per output bit suffices.  Saturates at `u64::MAX` instead of
/// overflowing — by N = 70 the *per-output-bit* cost alone exceeds u64
/// (the paper's ch. 1 point: such a neuron is unimplementable on any
/// fabric), and [`lut_cost_recursive`] saturates identically.
pub fn lut_cost(n_bits: usize, m_bits: usize) -> u64 {
    if n_bits == 0 || m_bits == 0 {
        return 0;
    }
    if n_bits <= 6 {
        return m_bits as u64;
    }
    if n_bits >= 72 {
        // (2^(N-4) ∓ 1)/3 > u64::MAX from N = 70 on; cut well before the
        // i128 shift itself could overflow (N - 4 >= 127).
        return u64::MAX;
    }
    let sign: i128 = if n_bits % 2 == 0 { 1 } else { -1 };
    let per_bit = ((1i128 << (n_bits - 4)) - sign) / 3;
    u64::try_from((m_bits as i128).saturating_mul(per_bit)).unwrap_or(u64::MAX)
}

/// Recursive form, eq. 2.1 — used to cross-check the closed form.  The
/// per-output-bit recursion runs in i128 and clamps to `u64::MAX`
/// (mirroring [`lut_cost`]'s saturation): the old i64 arithmetic wrapped
/// negative past N ≈ 66 and the cross-check diverged.
pub fn lut_cost_recursive(n_bits: usize, m_bits: usize) -> u64 {
    if n_bits == 0 || m_bits == 0 {
        return 0;
    }
    if n_bits <= 6 {
        return m_bits as u64;
    }
    const CAP: i128 = u64::MAX as i128;
    // L(N, 1): one level of eq. 2.1 over the saturating per-bit cost.
    let prev = lut_cost_recursive(n_bits - 1, 1) as i128;
    let sign: i128 = if n_bits % 2 == 0 { 1 } else { -1 };
    let per_bit = if prev >= CAP { CAP } else { 2 * prev - sign };
    u64::try_from(per_bit.saturating_mul(m_bits as i128)).unwrap_or(u64::MAX)
}

/// One row of the paper's Table 2.1 static-mapping cost.
#[derive(Debug, Clone, PartialEq)]
pub struct StaticMapRow {
    pub fan_in: usize,
    pub num_6luts: u64,
    pub truth_table_bits: u64,
    pub lut_config_bits: u64,
    pub pct_utilized: f64,
}

/// Static mapping cost of an N:1 truth table onto 6:1 LUTs (Table 2.1).
pub fn static_map_row(fan_in: usize) -> StaticMapRow {
    let num = lut_cost(fan_in, 1);
    let tt_bits = 1u64 << fan_in;
    let cfg_bits = num * 64;
    StaticMapRow {
        fan_in,
        num_6luts: num,
        truth_table_bits: tt_bits,
        lut_config_bits: cfg_bits,
        pct_utilized: 100.0 * tt_bits as f64 / cfg_bits as f64,
    }
}

/// Dense quantized layer cost, eq. 4.1 (empirical Vivado fit):
/// `n(O) * (n(I) * BW_in * BW_wt * 1.0699 + 10.779)`.
pub fn dense_layer_cost(n_out: usize, n_in: usize, bw_in: usize, bw_wt: usize) -> u64 {
    let per = n_in as f64 * bw_in as f64 * bw_wt as f64 * 1.0699 + 10.779;
    (n_out as f64 * per).round() as u64
}

/// Hardware weight bit-width assumed for dense layers (paper's fit hovers
/// around 4-bit weights; see DESIGN.md §Substitutions).
pub const DENSE_BW_WT: usize = 4;

/// Sparse layer cost: every neuron is a `fanin*bw_in -> bw_out` table.
/// Saturating like [`lut_cost`] itself: a saturated per-neuron cost times
/// the layer width must stay pinned at `u64::MAX` (the DSE cost gate
/// compares this against finite budgets), not wrap.
pub fn sparse_layer_cost(n_out: usize, fanin: usize, bw_in: usize, bw_out: usize) -> u64 {
    (n_out as u64).saturating_mul(lut_cost(fanin * bw_in, bw_out))
}

/// Storage bits of the raw truth table of one neuron (paper ch. 3:
/// `2^ip * (op)` output bits; with the input enumeration column it is
/// `2^ip * (op + ip)`).
pub fn truth_table_bits(in_bits: usize, out_bits: usize, with_inputs: bool) -> u64 {
    let rows = 1u64 << in_bits;
    if with_inputs {
        rows * (out_bits as u64 + in_bits as u64)
    } else {
        rows * out_bits as u64
    }
}

// ---------------------------------------------------------------------------
// Convolution costs (eqs. 4.2-4.4)
// ---------------------------------------------------------------------------

/// Fully-unfolded dense convolution, eq. 4.2.
pub fn conv_dense_cost(
    out_pix: usize,
    o_bits: usize,
    n_ofm: usize,
    n_ifm: usize,
    k: usize,
    i_bits: usize,
) -> u64 {
    (out_pix as u64)
        .saturating_mul(o_bits as u64)
        .saturating_mul(n_ofm as u64)
        .saturating_mul(lut_cost(n_ifm * k * k * i_bits, 1))
}

/// Depthwise stage, eq. 4.3: each output pixel/channel is a table over the
/// `fanin_dw` surviving kernel taps.
pub fn conv_dw_cost(out_pix: usize, o_bits: usize, n_ofm: usize, fanin_dw: usize, i_bits: usize) -> u64 {
    (out_pix as u64)
        .saturating_mul(o_bits as u64)
        .saturating_mul(n_ofm as u64)
        .saturating_mul(lut_cost(fanin_dw * i_bits, 1))
}

/// Pointwise stage, eq. 4.4.
pub fn conv_pw_cost(out_pix: usize, o_bits: usize, n_ofm: usize, fanin_pw: usize, i_bits: usize) -> u64 {
    (out_pix as u64)
        .saturating_mul(o_bits as u64)
        .saturating_mul(n_ofm as u64)
        .saturating_mul(lut_cost(fanin_pw * i_bits, 1))
}

// ---------------------------------------------------------------------------
// Whole-model cost breakdown
// ---------------------------------------------------------------------------

/// Cost description of one layer for [`mlp_cost`] / [`manifest_cost`].
#[derive(Debug, Clone)]
pub struct LayerCost {
    pub name: String,
    pub luts: u64,
}

/// Per-layer breakdown for an MLP manifest-like description.
/// `layers` = (n_out, fanin synapses or None=dense, bw_in, bw_out).
pub fn mlp_cost(layers: &[(usize, Option<usize>, usize, usize, usize)]) -> Vec<LayerCost> {
    // tuple: (n_out, fanin, bw_in, bw_out, n_in)
    layers
        .iter()
        .enumerate()
        .map(|(i, &(n_out, fanin, bw_in, bw_out, n_in))| {
            let luts = match fanin {
                Some(f) => sparse_layer_cost(n_out, f, bw_in, bw_out),
                None => dense_layer_cost(n_out, n_in, bw_in, DENSE_BW_WT),
            };
            LayerCost { name: format!("L{}", i + 1), luts }
        })
        .collect()
}

/// Cost from a runtime manifest (the canonical entry point).  Layer
/// classification goes through [`crate::runtime::Manifest::layer_kinds`]
/// — the accounting shared with the DSE gate — so conv layers are priced
/// by their exact per-neuron truncated windows ([`crate::runtime::ConvGeom::lut_cost`])
/// and can never diverge from what `synth::synthesize` reports.
pub fn manifest_cost(man: &crate::runtime::Manifest) -> Vec<LayerCost> {
    use crate::runtime::LayerKind;
    let n = man.num_layers();
    let kinds = match man.layer_kinds() {
        Ok(k) => k,
        // Inconsistent conv extras are rejected at parse/construction time;
        // fall back to the fanin-based view rather than panicking here.
        Err(_) => man
            .layers
            .iter()
            .map(|l| match l.fanin {
                Some(f) => LayerKind::Sparse { fanin: f.min(l.in_f) },
                None => LayerKind::Dense,
            })
            .collect(),
    };
    man.layers
        .iter()
        .zip(&kinds)
        .enumerate()
        .map(|(i, (l, kind))| {
            let bw_out = if i + 1 == n { man.bw_out } else { man.bw };
            let luts = match kind {
                LayerKind::Sparse { fanin } => sparse_layer_cost(l.out_f, *fanin, l.bw_in, bw_out),
                LayerKind::Dense => dense_layer_cost(l.out_f, l.in_f, l.bw_in, DENSE_BW_WT),
                LayerKind::Conv(g) => g.lut_cost(l.bw_in, bw_out),
            };
            LayerCost { name: format!("L{}", i + 1), luts }
        })
        .collect()
}

/// Whole-model LUT total.  Saturating: a single saturated layer cost
/// (`u64::MAX`, see [`lut_cost`]) must pin the total at `u64::MAX`, not
/// wrap the sum — the DSE cost gate compares this against finite budgets.
pub fn total_luts(costs: &[LayerCost]) -> u64 {
    costs.iter().fold(0u64, |acc, c| acc.saturating_add(c.luts))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_2_1_static_mapping() {
        // Paper Table 2.1 exactly.
        let expect = [
            (6usize, 1u64, 64u64, 64u64),
            (7, 3, 128, 192),
            (8, 5, 256, 320),
            (9, 11, 512, 704),
            (10, 21, 1024, 1344),
            (11, 43, 2048, 2752),
        ];
        for (fan_in, luts, tt, cfg) in expect {
            let r = static_map_row(fan_in);
            assert_eq!(r.num_6luts, luts, "fan_in={fan_in}");
            assert_eq!(r.truth_table_bits, tt);
            assert_eq!(r.lut_config_bits, cfg);
        }
        assert!((static_map_row(7).pct_utilized - 66.67).abs() < 0.01);
        assert!((static_map_row(9).pct_utilized - 72.73).abs() < 0.01);
    }

    #[test]
    fn closed_form_matches_recursive() {
        // All the way across the saturation boundary: exact values up to
        // ~N=69, u64::MAX beyond.  The old i64 recursion wrapped negative
        // here and the cross-check diverged.
        for n in 1..=90 {
            for m in 1..=5 {
                assert_eq!(lut_cost(n, m), lut_cost_recursive(n, m), "n={n} m={m}");
            }
        }
    }

    #[test]
    fn lut_cost_saturates_instead_of_wrapping() {
        // Exact just below the per-bit boundary...
        let n65 = lut_cost(65, 1);
        assert_eq!(n65, (((1i128 << 61) + 1) / 3) as u64);
        assert!(n65 < u64::MAX);
        // ...saturated at and beyond it, for both forms, never negative-ish
        // (the wrap bug produced huge-but-wrong values via `as u64`).
        for n in [70usize, 72, 80, 120, 200] {
            assert_eq!(lut_cost(n, 1), u64::MAX, "n={n}");
            assert_eq!(lut_cost_recursive(n, 1), u64::MAX, "n={n}");
        }
        // m scaling saturates too when the product (but not the per-bit
        // cost) overflows: per_bit(68) = (2^64-1)/3 fits, 5x does not.
        assert!(lut_cost(68, 1) < u64::MAX);
        assert_eq!(lut_cost(68, 5), u64::MAX);
        assert_eq!(lut_cost_recursive(68, 5), u64::MAX);
    }

    #[test]
    fn paper_model_a_layer_costs() {
        // Table 6.1 model A: HL (64,64,64), BW 3, X 3 -> per-layer 2112.
        assert_eq!(sparse_layer_cost(64, 3, 3, 3), 2112);
        // Model C: BW 2, X 3 -> layer1 (64 neurons) = 128, layer2/3 (32) = 64.
        assert_eq!(sparse_layer_cost(64, 3, 2, 2), 128);
        assert_eq!(sparse_layer_cost(32, 3, 2, 2), 64);
        // Model E: BW 2, X 4 -> (64 neurons) = 640.
        assert_eq!(sparse_layer_cost(64, 4, 2, 2), 640);
    }

    #[test]
    fn dense_cost_formula() {
        // Model A final layer: 5 classes, 64 inputs, bw 3, wt 4 -> ~4176
        // (paper rounds to 4125 with a slightly different BW_wt fit).
        let c = dense_layer_cost(5, 64, 3, 4);
        assert!((4100..=4250).contains(&c), "{c}");
    }

    #[test]
    fn truth_table_storage_growth() {
        // Table 5.1 regime: fan-in bits 15..20 explode exponentially.
        let b15 = truth_table_bits(15, 1, true);
        let b20 = truth_table_bits(20, 1, true);
        assert!(b20 > 16 * b15);
        assert_eq!(truth_table_bits(3, 1, false), 8);
        assert_eq!(truth_table_bits(3, 1, true), 32);
    }

    #[test]
    fn conv_costs_scale_with_sparsity() {
        let dense = conv_dense_cost(26 * 26, 2, 16, 8, 3, 2);
        assert_eq!(dense, u64::MAX, "dense unfolded conv saturates");
        let dw = conv_dw_cost(26 * 26, 2, 16, 5, 2);
        let pw = conv_pw_cost(26 * 26, 2, 16, 5, 2);
        assert!(dw + pw < dense / 10, "dw+pw={} dense={}", dw + pw, dense);
    }

    #[test]
    fn sparse_layer_cost_saturates() {
        // 24 synapses * 3 bits = 72 table input bits: per-neuron cost is
        // already u64::MAX, and the layer-width product must stay pinned
        // there (the old plain multiply wrapped in release / panicked in
        // debug).
        assert_eq!(lut_cost(72, 3), u64::MAX);
        assert_eq!(sparse_layer_cost(16, 24, 3, 3), u64::MAX);
        // Finite regime unchanged (Table 6.1 model A).
        assert_eq!(sparse_layer_cost(64, 3, 3, 3), 2112);
    }

    #[test]
    fn total_luts_saturates() {
        let costs = vec![
            LayerCost { name: "a".into(), luts: u64::MAX },
            LayerCost { name: "b".into(), luts: 100 },
        ];
        assert_eq!(total_luts(&costs), u64::MAX);
        let finite = vec![
            LayerCost { name: "a".into(), luts: 3 },
            LayerCost { name: "b".into(), luts: 4 },
        ];
        assert_eq!(total_luts(&finite), 7);
    }

    #[test]
    fn manifest_cost_prices_conv_by_exact_windows() {
        let man = crate::runtime::Manifest::synthetic_conv(
            "c", "jets", 6, 1, 5, &[3], 3, "dense", Some(4), None, &[8], 3, 2,
        )
        .unwrap();
        let costs = manifest_cost(&man);
        assert_eq!(costs.len(), 3);
        let geoms = man.conv_geoms().unwrap();
        assert_eq!(costs[0].luts, geoms[0].lut_cost(2, 2), "conv layer priced per-neuron");
        assert_eq!(costs[1].luts, sparse_layer_cost(8, 3, 2, 2));
        assert_eq!(costs[2].luts, dense_layer_cost(5, 8, 2, DENSE_BW_WT));
        // border truncation makes the exact price strictly cheaper than the
        // uniform full-fanin bound at bw where table size is fanin-sensitive
        let uniform = sparse_layer_cost(geoms[0].out_f(), geoms[0].window_fanin, 2, 2);
        assert!(costs[0].luts <= uniform);
    }

    #[test]
    fn lut_cost_monotone_in_n() {
        for m in 1..4 {
            let mut prev = 0;
            for n in 1..=20 {
                let c = lut_cost(n, m);
                assert!(c >= prev);
                prev = c;
            }
        }
    }
}
