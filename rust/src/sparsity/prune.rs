//! Pruning strategies operating on (weights, momentum, mask) between train
//! steps.  The trainer calls `Pruner::on_step` after every optimizer update;
//! whenever the mask changes, the trainer re-uploads it (masks are runtime
//! inputs of the HLO train step, so no recompilation is needed).
//!
//! * `APriori` — fixed random expander, never changes (paper §3.1.1).
//! * `Iterative` — magnitude pruning with a per-neuron decay schedule: the
//!   allowed fan-in shrinks geometrically from dense to the target during
//!   the middle of training (paper §3.1.1, Training Pipeline fig. 3.2).
//! * `Momentum` — modified sparse-momentum learning (Alg. 1): per neuron,
//!   prune the smallest-magnitude weights and regrow the same number of
//!   connections where the exponentially-smoothed gradient magnitude is
//!   largest.  Fan-in stays exactly constant per neuron.

use super::Mask;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PruneMethod {
    APriori,
    Iterative { every: usize },
    Momentum { every: usize, prune_rate: f64 },
}

impl PruneMethod {
    pub fn name(&self) -> &'static str {
        match self {
            PruneMethod::APriori => "a-priori",
            PruneMethod::Iterative { .. } => "iterative",
            PruneMethod::Momentum { .. } => "momentum",
        }
    }
}

/// Per-layer pruning state.
pub struct Pruner {
    pub method: PruneMethod,
    /// Target per-neuron fan-in (None = layer stays dense).
    pub target_fanin: Option<usize>,
}

/// Fraction of training during which iterative pruning is active.
const PRUNE_START: f64 = 0.15;
const PRUNE_END: f64 = 0.75;

impl Pruner {
    pub fn new(method: PruneMethod, target_fanin: Option<usize>) -> Pruner {
        Pruner { method, target_fanin }
    }

    /// Allowed fan-in at `step` of `total` under the iterative schedule:
    /// geometric interpolation from `in_f` down to `target`.
    pub fn allowed_fanin(&self, step: usize, total: usize, in_f: usize) -> usize {
        let target = match self.target_fanin {
            Some(t) => t.min(in_f),
            None => return in_f,
        };
        let p = step as f64 / total.max(1) as f64;
        if p <= PRUNE_START {
            return in_f;
        }
        if p >= PRUNE_END {
            return target;
        }
        let t = (p - PRUNE_START) / (PRUNE_END - PRUNE_START);
        let f = (in_f as f64) * ((target as f64) / (in_f as f64)).powf(t);
        (f.round() as usize).clamp(target, in_f)
    }

    /// Returns true if the mask changed (trainer must re-upload + re-mask
    /// weights/velocities).
    pub fn on_step(
        &self,
        step: usize,
        total: usize,
        w: &[f32],
        momentum: &[f32],
        mask: &mut Mask,
    ) -> bool {
        let target = match self.target_fanin {
            Some(t) => t,
            None => return false,
        };
        match self.method {
            PruneMethod::APriori => false,
            PruneMethod::Iterative { every } => {
                if step == 0 || step % every != 0 {
                    return false;
                }
                let allowed = self.allowed_fanin(step, total, mask.in_f);
                magnitude_prune(w, mask, allowed)
            }
            PruneMethod::Momentum { every, prune_rate } => {
                if step == 0 || step % every != 0 {
                    return false;
                }
                // Anneal the prune rate to zero over training (sparse
                // momentum paper) so connectivity settles before the end.
                let p = prune_rate * (1.0 - step as f64 / total.max(1) as f64);
                momentum_prune_regrow(w, momentum, mask, target, p)
            }
        }
    }
}

/// Magnitude-ranking score: |w|, with a NaN weight (a diverged run)
/// demoted below every real magnitude so it is always pruned first and
/// never kept or regrown.  Keeps every ranking sort total-ordered — the
/// old `partial_cmp().unwrap()` sorts aborted training on the first NaN.
fn rank_mag(v: f32) -> f32 {
    let m = v.abs();
    if m.is_nan() {
        f32::NEG_INFINITY
    } else {
        m
    }
}

/// Keep the `allowed` largest-|w| connections of each neuron; drop the rest.
pub fn magnitude_prune(w: &[f32], mask: &mut Mask, allowed: usize) -> bool {
    let mut changed = false;
    let in_f = mask.in_f;
    for (o, row) in mask.rows.iter_mut().enumerate() {
        if row.len() <= allowed {
            continue;
        }
        let mut scored: Vec<(f32, usize)> =
            row.iter().map(|&i| (rank_mag(w[o * in_f + i]), i)).collect();
        scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        scored.truncate(allowed);
        let mut keep: Vec<usize> = scored.into_iter().map(|(_, i)| i).collect();
        keep.sort_unstable();
        *row = keep;
        changed = true;
    }
    changed
}

/// Alg. 1: per neuron, prune `ceil(p * fanin)` smallest-|w| synapses and
/// regrow the same number at the free positions with the largest |momentum|.
pub fn momentum_prune_regrow(
    w: &[f32],
    momentum: &[f32],
    mask: &mut Mask,
    target_fanin: usize,
    p: f64,
) -> bool {
    if p <= 0.0 {
        return false;
    }
    let in_f = mask.in_f;
    let mut changed = false;
    for (o, row) in mask.rows.iter_mut().enumerate() {
        let fanin = row.len().min(target_fanin.max(1));
        let k = ((fanin as f64 * p).ceil() as usize).min(row.len().saturating_sub(1));
        if k == 0 {
            continue;
        }
        // Prune: k smallest |w| inside the mask (NaN ranks smallest, so a
        // diverged weight is pruned first).
        let mut scored: Vec<(f32, usize)> =
            row.iter().map(|&i| (rank_mag(w[o * in_f + i]), i)).collect();
        scored.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let pruned: Vec<usize> = scored.iter().take(k).map(|&(_, i)| i).collect();
        let kept: Vec<usize> = scored.iter().skip(k).map(|&(_, i)| i).collect();
        // Regrow: k largest |momentum| outside the mask (and not just pruned).
        let in_mask: std::collections::BTreeSet<usize> = row.iter().copied().collect();
        let mut free: Vec<(f32, usize)> = (0..in_f)
            .filter(|i| !in_mask.contains(i))
            .map(|i| (rank_mag(momentum[o * in_f + i]), i))
            .collect();
        // NaN momentum ranks smallest: a diverged gradient never regrows.
        free.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut new_row = kept;
        new_row.extend(free.iter().take(k).map(|&(_, i)| i));
        // If there were not enough free positions, keep some pruned ones so
        // the fan-in is preserved exactly.
        let mut need = row.len().saturating_sub(new_row.len());
        for i in pruned {
            if need == 0 {
                break;
            }
            if !new_row.contains(&i) {
                new_row.push(i);
                need -= 1;
            }
        }
        new_row.sort_unstable();
        new_row.dedup();
        if new_row != *row {
            *row = new_row;
            changed = true;
        }
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn iterative_schedule_monotone() {
        let p = Pruner::new(PruneMethod::Iterative { every: 10 }, Some(4));
        let total = 100;
        let mut prev = usize::MAX;
        for step in 0..=total {
            let a = p.allowed_fanin(step, total, 64);
            assert!(a <= prev, "schedule must be non-increasing");
            assert!(a >= 4 && a <= 64);
            prev = a;
        }
        assert_eq!(p.allowed_fanin(0, total, 64), 64);
        assert_eq!(p.allowed_fanin(total, total, 64), 4);
    }

    #[test]
    fn magnitude_prune_keeps_largest() {
        let mut mask = Mask::dense(1, 6);
        let w = vec![0.1, -0.9, 0.3, -0.05, 0.7, 0.2];
        assert!(magnitude_prune(&w, &mut mask, 3));
        assert_eq!(mask.rows[0], vec![1, 2, 4]);
    }

    #[test]
    fn momentum_regrow_preserves_fanin() {
        let mut rng = Rng::new(9);
        let (out_f, in_f, fanin) = (8, 32, 4);
        let mut mask = Mask::random(out_f, in_f, fanin, &mut rng);
        let w: Vec<f32> = (0..out_f * in_f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let m: Vec<f32> = (0..out_f * in_f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let before = mask.clone();
        let changed = momentum_prune_regrow(&w, &m, &mut mask, fanin, 0.5);
        assert!(changed);
        assert!(mask.rows.iter().all(|r| r.len() == fanin), "fan-in preserved");
        assert_ne!(before, mask);
    }

    #[test]
    fn magnitude_prune_survives_nan_weights() {
        // Regression: the ranking sort's partial_cmp().unwrap() aborted on
        // the first NaN weight.  Documented ordering: NaN magnitudes rank
        // smallest, so they are pruned first and never kept.
        let mut mask = Mask::dense(1, 6);
        let w = vec![0.1, f32::NAN, 0.3, f32::NAN, 0.7, 0.2];
        assert!(magnitude_prune(&w, &mut mask, 3));
        assert_eq!(mask.rows[0], vec![2, 4, 5]);
        // All-NaN row: no panic, deterministic keep of the lowest indices.
        let mut mask = Mask::dense(1, 4);
        let w = vec![f32::NAN; 4];
        assert!(magnitude_prune(&w, &mut mask, 2));
        assert_eq!(mask.rows[0], vec![0, 1]);
    }

    #[test]
    fn momentum_prune_survives_nan_scores() {
        // NaN weights prune first; NaN momentum never regrows; fan-in is
        // preserved exactly and nothing panics.
        let mut rng = Rng::new(17);
        let (out_f, in_f, fanin) = (4, 16, 4);
        let mut mask = Mask::random(out_f, in_f, fanin, &mut rng);
        let mut w: Vec<f32> = (0..out_f * in_f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut m: Vec<f32> = (0..out_f * in_f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // Poison one masked weight per neuron and a handful of momenta.
        for o in 0..out_f {
            let i = mask.rows[o][0];
            w[o * in_f + i] = f32::NAN;
            m[o * in_f + (i + 1) % in_f] = f32::NAN;
        }
        let poisoned: Vec<usize> = (0..out_f).map(|o| mask.rows[o][0]).collect();
        let changed = momentum_prune_regrow(&w, &m, &mut mask, fanin, 0.25);
        assert!(changed);
        assert!(mask.rows.iter().all(|r| r.len() == fanin), "fan-in preserved");
        // ceil(0.25 * 4) = 1 prune per neuron: the NaN weight is the one
        // pruned (unless it had to be kept back for lack of free slots,
        // impossible here with in_f >> fanin).
        for (o, &i) in poisoned.iter().enumerate() {
            assert!(!mask.rows[o].contains(&i), "NaN weight survived in neuron {o}");
        }
    }

    #[test]
    fn apriori_never_changes() {
        let mut rng = Rng::new(1);
        let mut mask = Mask::random(4, 16, 3, &mut rng);
        let before = mask.clone();
        let p = Pruner::new(PruneMethod::APriori, Some(3));
        let w = vec![1.0; 64];
        let m = vec![1.0; 64];
        for step in 0..50 {
            assert!(!p.on_step(step, 50, &w, &m, &mut mask));
        }
        assert_eq!(before, mask);
    }
}
