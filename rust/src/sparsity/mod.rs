//! Sparsity: per-neuron fan-in masks and the three pruning strategies of the
//! paper (§3.1): A-Priori Fixed Sparsity (random expander), Iterative
//! Pruning (magnitude, per-neuron decay schedule), and modified Sparse
//! Momentum learning (Alg. 1: per-neuron magnitude prune + momentum regrow).
//!
//! A mask is the structural object of LogicNets: each output neuron keeps
//! exactly `fanin` incoming synapses, which bounds its truth-table input
//! width to `fanin * bw_in` bits.

pub mod prune;

use crate::util::rng::Rng;

/// A per-neuron connectivity mask for a linear layer `[out_f, in_f]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Mask {
    pub out_f: usize,
    pub in_f: usize,
    /// For each output neuron, the sorted input indices it connects to.
    pub rows: Vec<Vec<usize>>,
}

impl Mask {
    /// Fully dense mask (used for final classifier layers, `fanin_fc=None`).
    pub fn dense(out_f: usize, in_f: usize) -> Mask {
        Mask { out_f, in_f, rows: vec![(0..in_f).collect(); out_f] }
    }

    /// A-priori fixed random sparsity: every neuron draws `fanin` distinct
    /// inputs uniformly (a random bipartite expander of degree `fanin`,
    /// paper §3.1.1).
    pub fn random(out_f: usize, in_f: usize, fanin: usize, rng: &mut Rng) -> Mask {
        let fanin = fanin.min(in_f);
        let rows = (0..out_f).map(|_| rng.choose_k(in_f, fanin)).collect();
        Mask { out_f, in_f, rows }
    }

    /// Build from an explicit 0/1 dense matrix (row-major `[out_f, in_f]`).
    pub fn from_dense(out_f: usize, in_f: usize, dense: &[f32]) -> Mask {
        assert_eq!(dense.len(), out_f * in_f);
        let rows = (0..out_f)
            .map(|o| {
                (0..in_f).filter(|&i| dense[o * in_f + i] != 0.0).collect::<Vec<_>>()
            })
            .collect();
        Mask { out_f, in_f, rows }
    }

    /// Dense row-major 0/1 f32 matrix — the HLO artifact input form.
    pub fn to_dense_f32(&self) -> Vec<f32> {
        let mut m = vec![0f32; self.out_f * self.in_f];
        for (o, row) in self.rows.iter().enumerate() {
            for &i in row {
                m[o * self.in_f + i] = 1.0;
            }
        }
        m
    }

    pub fn is_dense(&self) -> bool {
        self.rows.iter().all(|r| r.len() == self.in_f)
    }

    /// Fan-in (synapses) of neuron `o`.
    pub fn fanin(&self, o: usize) -> usize {
        self.rows[o].len()
    }

    pub fn max_fanin(&self) -> usize {
        self.rows.iter().map(|r| r.len()).max().unwrap_or(0)
    }

    /// Number of non-zero connections.
    pub fn nnz(&self) -> usize {
        self.rows.iter().map(|r| r.len()).sum()
    }
}

/// Erdős–Rényi layer-sparsity allocation (paper §3.3.1): layer l gets
/// sparsity scaling with `1 - (n_{l-1} + n_l) / (n_{l-1} * n_l)`; larger
/// layers are made sparser.  Returns a per-layer density multiplier that is
/// normalized so the mean density equals `base_density`.
pub fn erdos_renyi_densities(widths: &[usize], base_density: f64) -> Vec<f64> {
    assert!(widths.len() >= 2);
    let raw: Vec<f64> = widths
        .windows(2)
        .map(|w| {
            let (a, b) = (w[0] as f64, w[1] as f64);
            (a + b) / (a * b)
        })
        .collect();
    let mean = raw.iter().sum::<f64>() / raw.len() as f64;
    raw.iter().map(|r| (base_density * r / mean).min(1.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_mask_has_exact_fanin() {
        let mut rng = Rng::new(1);
        let m = Mask::random(64, 16, 3, &mut rng);
        assert_eq!(m.rows.len(), 64);
        assert!(m.rows.iter().all(|r| r.len() == 3));
        assert!(m.rows.iter().all(|r| r.windows(2).all(|w| w[0] < w[1])));
        assert_eq!(m.nnz(), 64 * 3);
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(2);
        let m = Mask::random(8, 10, 4, &mut rng);
        let d = m.to_dense_f32();
        assert_eq!(Mask::from_dense(8, 10, &d), m);
    }

    #[test]
    fn fanin_clamped_to_input_width() {
        let mut rng = Rng::new(3);
        let m = Mask::random(4, 3, 7, &mut rng);
        assert!(m.rows.iter().all(|r| r.len() == 3));
        assert!(m.is_dense());
    }

    #[test]
    fn er_densities_mean_preserved() {
        let d = erdos_renyi_densities(&[784, 1024, 1024, 10], 0.01);
        let mean = d.iter().sum::<f64>() / d.len() as f64;
        assert!((mean - 0.01).abs() < 1e-3, "{d:?}");
        // Larger layer pair (1024x1024) must be sparser than (1024x10).
        assert!(d[1] < d[2]);
    }
}
