//! MNIST workload: a procedural stroke-rendered digit generator (offline
//! substitute, DESIGN.md §Substitutions) plus an idx-format loader that
//! transparently uses the real MNIST files when present under
//! `data/mnist/` (train-images-idx3-ubyte etc.).
//!
//! The synthetic digits preserve what matters for the paper's MNIST
//! chapters: flattened images have strong spatial structure, so a-priori
//! *random* sparsity underperforms learned sparsity (Table 7.2), and
//! accuracy scales with width/depth/bit-width (Table 7.1, Figs. 7.1/7.2).

use crate::data::DataSet;
use crate::util::rng::Rng;

pub const IMG: usize = 28;
pub const NUM_PIXELS: usize = IMG * IMG;
pub const NUM_CLASSES: usize = 10;

type Pt = (f32, f32);

/// Stroke polylines per digit in unit coordinates (x right, y down).
fn strokes(digit: usize) -> Vec<Vec<Pt>> {
    fn circle(cx: f32, cy: f32, rx: f32, ry: f32) -> Vec<Pt> {
        (0..=14)
            .map(|i| {
                let t = i as f32 / 14.0 * std::f32::consts::TAU;
                (cx + rx * t.cos(), cy + ry * t.sin())
            })
            .collect()
    }
    match digit {
        0 => vec![circle(0.5, 0.5, 0.18, 0.3)],
        1 => vec![vec![(0.5, 0.15), (0.5, 0.85)], vec![(0.36, 0.3), (0.5, 0.15)]],
        2 => vec![vec![
            (0.3, 0.3),
            (0.38, 0.18),
            (0.6, 0.18),
            (0.7, 0.3),
            (0.64, 0.45),
            (0.3, 0.82),
            (0.72, 0.82),
        ]],
        3 => vec![vec![
            (0.3, 0.2),
            (0.6, 0.18),
            (0.68, 0.32),
            (0.5, 0.48),
            (0.68, 0.64),
            (0.6, 0.8),
            (0.3, 0.8),
        ]],
        4 => vec![vec![(0.62, 0.85), (0.62, 0.15), (0.3, 0.6), (0.74, 0.6)]],
        5 => vec![vec![
            (0.68, 0.18),
            (0.35, 0.18),
            (0.33, 0.46),
            (0.56, 0.45),
            (0.68, 0.6),
            (0.6, 0.8),
            (0.32, 0.8),
        ]],
        6 => vec![
            vec![(0.62, 0.15), (0.42, 0.35), (0.34, 0.6), (0.42, 0.8)],
            circle(0.5, 0.65, 0.16, 0.16),
        ],
        7 => vec![vec![(0.3, 0.18), (0.7, 0.18), (0.45, 0.85)]],
        8 => vec![circle(0.5, 0.33, 0.15, 0.14), circle(0.5, 0.66, 0.18, 0.17)],
        9 => vec![circle(0.52, 0.33, 0.16, 0.15), vec![(0.68, 0.35), (0.6, 0.85)]],
        _ => unreachable!(),
    }
}

fn dist_to_segment(p: Pt, a: Pt, b: Pt) -> f32 {
    let (vx, vy) = (b.0 - a.0, b.1 - a.1);
    let (wx, wy) = (p.0 - a.0, p.1 - a.1);
    let len2 = vx * vx + vy * vy;
    let t = if len2 <= 1e-12 { 0.0 } else { ((wx * vx + wy * vy) / len2).clamp(0.0, 1.0) };
    let (dx, dy) = (p.0 - (a.0 + t * vx), p.1 - (a.1 + t * vy));
    (dx * dx + dy * dy).sqrt()
}

/// Render one jittered digit into a 28x28 grayscale image in [0,1].
pub fn render_digit(digit: usize, rng: &mut Rng) -> Vec<f32> {
    let theta = rng.range_f64(-0.18, 0.18) as f32;
    let scale = rng.range_f64(0.85, 1.12) as f32;
    let (dx, dy) = (rng.range_f64(-0.07, 0.07) as f32, rng.range_f64(-0.07, 0.07) as f32);
    let shear = rng.range_f64(-0.12, 0.12) as f32;
    let thickness = rng.range_f64(0.035, 0.06) as f32;
    let (sin, cos) = (theta.sin(), theta.cos());
    let tf = |p: Pt| -> Pt {
        // center, shear, rotate, scale, translate
        let (x, y) = (p.0 - 0.5, p.1 - 0.5);
        let x = x + shear * y;
        let (xr, yr) = (cos * x - sin * y, sin * x + cos * y);
        (xr * scale + 0.5 + dx, yr * scale + 0.5 + dy)
    };
    let segs: Vec<(Pt, Pt)> = strokes(digit)
        .iter()
        .flat_map(|poly| {
            poly.windows(2)
                .map(|w| (tf(w[0]), tf(w[1])))
                .collect::<Vec<_>>()
        })
        .collect();
    let mut img = vec![0f32; NUM_PIXELS];
    for py in 0..IMG {
        for px in 0..IMG {
            let p = ((px as f32 + 0.5) / IMG as f32, (py as f32 + 0.5) / IMG as f32);
            let mut d = f32::INFINITY;
            for &(a, b) in &segs {
                d = d.min(dist_to_segment(p, a, b));
            }
            let v = 1.0 - ((d - thickness) / 0.02).clamp(0.0, 1.0);
            let noise = rng.normal_f32(0.0, 0.04);
            img[py * IMG + px] = (v + noise).clamp(0.0, 1.0);
        }
    }
    img
}

/// Generate `n` synthetic digits with balanced classes.
pub fn synth_digits(n: usize, seed: u64) -> DataSet {
    let mut rng = Rng::new(seed ^ 0x4d4e_4953); // "MNIS"
    let mut x = Vec::with_capacity(n * NUM_PIXELS);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % NUM_CLASSES;
        x.extend(render_digit(c, &mut rng));
        y.push(c as i32);
    }
    DataSet::new(x, y, NUM_PIXELS, NUM_CLASSES)
}

/// Load real MNIST idx files when available; fall back to synthetic.
pub fn load_or_synth(n_train: usize, n_test: usize, seed: u64) -> (DataSet, DataSet) {
    let base = std::path::Path::new("data/mnist");
    if let (Ok(tr), Ok(te)) = (
        load_idx_pair(
            &base.join("train-images-idx3-ubyte"),
            &base.join("train-labels-idx1-ubyte"),
            n_train,
        ),
        load_idx_pair(
            &base.join("t10k-images-idx3-ubyte"),
            &base.join("t10k-labels-idx1-ubyte"),
            n_test,
        ),
    ) {
        return (tr, te);
    }
    let all = synth_digits(n_train + n_test, seed);
    let mut rng = Rng::new(seed ^ 1);
    let (tr, te) = all.split(n_test as f64 / (n_train + n_test) as f64, &mut rng);
    (tr, te)
}

fn read_be_u32(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Parse the MNIST idx image+label file pair, limited to `limit` samples.
pub fn load_idx_pair(
    images: &std::path::Path,
    labels: &std::path::Path,
    limit: usize,
) -> std::io::Result<DataSet> {
    let ib = std::fs::read(images)?;
    let lb = std::fs::read(labels)?;
    let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
    if ib.len() < 16 || read_be_u32(&ib, 0) != 0x0803 {
        return Err(err("bad image magic"));
    }
    if lb.len() < 8 || read_be_u32(&lb, 0) != 0x0801 {
        return Err(err("bad label magic"));
    }
    let n = (read_be_u32(&ib, 4) as usize).min(read_be_u32(&lb, 4) as usize).min(limit);
    let rows = read_be_u32(&ib, 8) as usize;
    let cols = read_be_u32(&ib, 12) as usize;
    if rows != IMG || cols != IMG {
        return Err(err("unexpected image size"));
    }
    if ib.len() < 16 + n * NUM_PIXELS || lb.len() < 8 + n {
        return Err(err("truncated idx file"));
    }
    let mut x = Vec::with_capacity(n * NUM_PIXELS);
    for i in 0..n * NUM_PIXELS {
        x.push(ib[16 + i] as f32 / 255.0);
    }
    let y: Vec<i32> = (0..n).map(|i| lb[8 + i] as i32).collect();
    Ok(DataSet::new(x, y, NUM_PIXELS, NUM_CLASSES))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_render_distinctly() {
        let mut rng = Rng::new(1);
        // Mean image of each class must differ substantially from others.
        let mut means = Vec::new();
        for d in 0..NUM_CLASSES {
            let mut acc = vec![0f32; NUM_PIXELS];
            for _ in 0..8 {
                for (a, v) in acc.iter_mut().zip(render_digit(d, &mut rng)) {
                    *a += v / 8.0;
                }
            }
            means.push(acc);
        }
        for a in 0..NUM_CLASSES {
            for b in (a + 1)..NUM_CLASSES {
                let dist: f32 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!(dist > 1.0, "classes {a}/{b} too similar: {dist}");
            }
        }
    }

    #[test]
    fn synth_balanced_and_bounded() {
        let ds = synth_digits(200, 3);
        assert_eq!(ds.n, 200);
        assert_eq!(ds.d, NUM_PIXELS);
        assert!(ds.x.iter().all(|v| (0.0..=1.0).contains(v)));
        let c0 = ds.y.iter().filter(|&&c| c == 0).count();
        assert_eq!(c0, 20);
    }

    #[test]
    fn idx_loader_rejects_garbage() {
        let dir = std::env::temp_dir().join("logicnets_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let img = dir.join("img");
        let lab = dir.join("lab");
        std::fs::write(&img, [0u8; 20]).unwrap();
        std::fs::write(&lab, [0u8; 10]).unwrap();
        assert!(load_idx_pair(&img, &lab, 10).is_err());
    }

    #[test]
    fn idx_loader_roundtrip() {
        // Hand-build a 2-sample idx pair and parse it back.
        let dir = std::env::temp_dir().join("logicnets_idx_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let mut ib = Vec::new();
        ib.extend(0x0803u32.to_be_bytes());
        ib.extend(2u32.to_be_bytes());
        ib.extend(28u32.to_be_bytes());
        ib.extend(28u32.to_be_bytes());
        ib.extend(std::iter::repeat(128u8).take(2 * NUM_PIXELS));
        let mut lb = Vec::new();
        lb.extend(0x0801u32.to_be_bytes());
        lb.extend(2u32.to_be_bytes());
        lb.extend([7u8, 3u8]);
        let img = dir.join("img");
        let lab = dir.join("lab");
        std::fs::write(&img, &ib).unwrap();
        std::fs::write(&lab, &lb).unwrap();
        let ds = load_idx_pair(&img, &lab, 10).unwrap();
        assert_eq!(ds.n, 2);
        assert_eq!(ds.y, vec![7, 3]);
        assert!((ds.x[0] - 128.0 / 255.0).abs() < 1e-6);
    }
}
