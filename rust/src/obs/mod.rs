//! Crate-wide telemetry: counters, gauges, latency histograms, span timers.
//!
//! The paper's pitch is deterministic sub-microsecond inference, and the
//! trigger literature it leans on treats latency accounting as a
//! first-class deliverable — so the serving/sim/synth/DSE stack needs to
//! be able to observe itself without pulling in a metrics crate (the
//! build is fully offline).  This module is that substrate:
//!
//! * [`Counter`] — monotonically increasing, sharded across cache-line
//!   padded atomics so concurrent workers never contend on one line;
//! * [`Gauge`] — a signed instantaneous level (queue depth, pool size);
//! * [`Histogram`] — log2-bucketed value distribution with a fixed
//!   64-bucket layout.  Counts are **exact** (every sample lands in
//!   exactly one bucket); values are bucketed to a power-of-two range, so
//!   any percentile estimate is off by at most one bucket boundary.
//!   Snapshots ([`HistogramSnapshot`]) are plain data and merge
//!   associatively, so per-worker or per-model histograms can be summed.
//!   This replaces the serving router's lossy latency reservoir as the
//!   *primary* percentile source (the reservoir stays as a cross-check:
//!   exact values, sampled stream — vs exact stream, bucketed values);
//! * [`Span`] — RAII timer recording into a histogram on drop, a no-op
//!   (not even a clock read) when telemetry is disabled;
//! * a process-wide [`Registry`] mapping `subsystem.metric.unit` names to
//!   metric handles, snapshotted into a [`SnapshotReport`] with a human
//!   `render()` and a stable JSON form (same conventions as
//!   `util::bench::BenchReport`: BTreeMap-ordered keys, integers emitted
//!   without a decimal point).
//!
//! Naming convention: `subsystem.metric.unit`, e.g. `serve.queue_wait.ns`
//! (histogram of nanoseconds), `sim.chunks_evaluated.count` (counter),
//! `serve.queue.depth` (gauge).  Histograms of durations record
//! **nanoseconds** — at sub-microsecond serving latencies, microsecond
//! resolution would collapse the interesting buckets.
//!
//! Overhead budget: a counter bump is one relaxed `fetch_add` on a
//! thread-private cache line; a histogram record is five relaxed atomics;
//! a span adds two `Instant::now()` reads.  Instrumentation on per-chunk
//! or coarser paths (≥ 256 samples of work per record) stays well under
//! the 5% throughput budget enforced by the `sim256/jets-default` bench
//! gate.  Purely observational sites additionally check [`enabled`] so a
//! scenario can switch telemetry off; stats-bearing metrics the serving
//! API reports from (request latency, completion counts) record
//! unconditionally.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Enable flag
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Is telemetry recording enabled?  Purely observational instrumentation
/// sites (span timers, pipeline counters) check this before recording;
/// stats-bearing metrics (the serving router's latency histogram and
/// completion counters, which back `ServerStats`) do not.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Globally enable/disable observational telemetry.  Affects every thread;
/// intended for scenario setup (CLI flag, bench harness), not for toggling
/// around individual operations.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Counter / Gauge
// ---------------------------------------------------------------------------

const COUNTER_SHARDS: usize = 16;

#[repr(align(64))]
#[derive(Default)]
struct Shard(AtomicU64);

/// Each thread gets a sticky shard index from a round-robin dispenser, so
/// steady-state increments from distinct threads hit distinct cache lines.
fn shard_id() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
    }
    SHARD.with(|c| {
        let mut v = c.get();
        if v == usize::MAX {
            v = NEXT.fetch_add(1, Ordering::Relaxed) % COUNTER_SHARDS;
            c.set(v);
        }
        v
    })
}

/// Monotonic event counter, sharded to keep concurrent writers off a
/// shared cache line.  Reads sum the shards (exact, but not a point-in-time
/// atomic snapshot across concurrent writers — fine for telemetry).
#[derive(Default)]
pub struct Counter {
    shards: [Shard; COUNTER_SHARDS],
}

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.shards[shard_id()].0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Signed instantaneous level (queue depth, pool occupancy).
#[derive(Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Histogram
// ---------------------------------------------------------------------------

/// Fixed bucket count of the log2 layout.  Bucket 0 holds the value 0,
/// bucket `i` (1 ≤ i < 63) holds `[2^(i-1), 2^i)`, and the last bucket
/// holds everything from `2^62` up.  For nanosecond durations that spans
/// 1 ns .. ~146 years, so no realistic latency ever clips.
pub const BUCKETS: usize = 64;

/// Bucket a value lands in: 0 for 0, else its bit length, clamped to the
/// top bucket.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// `[lo, hi)` value range of bucket `i` (the top bucket is closed at
/// `u64::MAX`).
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    match i {
        0 => (0, 1),
        _ if i < BUCKETS - 1 => (1u64 << (i - 1), 1u64 << i),
        _ => (1u64 << (BUCKETS - 2), u64::MAX),
    }
}

/// Log2-bucketed distribution with exact counts.  Thread-safe and
/// lock-free: `record` is four relaxed atomic RMWs.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    /// `u64::MAX` while empty.
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds (the unit all `*.ns` histograms
    /// use).
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Total samples recorded (exact).
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Plain-data copy for merging / percentile math / serialization.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    /// Percentile estimate of the recorded distribution; `None` when
    /// empty.  See [`HistogramSnapshot::percentile`].
    pub fn percentile(&self, p: f64) -> Option<f64> {
        self.snapshot().percentile(p)
    }
}

/// Immutable copy of a [`Histogram`]: mergeable, serializable, and the
/// place percentile math lives.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub buckets: [u64; BUCKETS],
    pub sum: u64,
    /// `u64::MAX` when empty.
    pub min: u64,
    pub max: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot { buckets: [0; BUCKETS], sum: 0, min: u64::MAX, max: 0 }
    }
}

impl HistogramSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Pointwise sum of two snapshots.  Associative and commutative, so
    /// per-worker / per-model histograms can be folded in any order.
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i] + other.buckets[i]),
            sum: self.sum + other.sum,
            min: self.min.min(other.min),
            max: self.max.max(other.max),
        }
    }

    pub fn mean(&self) -> Option<f64> {
        match self.count() {
            0 => None,
            n => Some(self.sum as f64 / n as f64),
        }
    }

    /// Percentile estimate: finds the bucket holding the rank-`p` sample
    /// (counts are exact, so the bucket is exact) and interpolates
    /// linearly inside it, with the bucket range clamped to the observed
    /// global min/max.  The estimate is therefore always inside the
    /// correct bucket — off by at most one power-of-two boundary from the
    /// true value — and exact for single-valued distributions.
    ///
    /// An empty histogram has **no** percentiles: `None`, never a
    /// fabricated 0.0 (same contract as `serve::router::percentile`).
    pub fn percentile(&self, p: f64) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            return None;
        }
        let p = p.clamp(0.0, 1.0);
        // 1-based rank of the sample the percentile describes (nearest
        // rank on the 0..n-1 index scale used by the reservoir path).
        let target = (p * (n - 1) as f64).floor() as u64 + 1;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= target {
                let (lo, hi) = bucket_bounds(i);
                let lo = lo.max(self.min) as f64;
                let hi = hi.min(self.max) as f64;
                // Midpoint convention: the j-th of c samples in a bucket
                // sits at fraction (j - 0.5)/c, so estimates stay strictly
                // inside the bucket and a single-valued distribution
                // (lo == hi after clamping) is reported exactly.
                let frac = ((target - cum) as f64 - 0.5) / c as f64;
                return Some(lo + (hi - lo).max(0.0) * frac);
            }
            cum += c;
        }
        Some(self.max as f64)
    }

    /// JSON form: exact fields plus derived percentiles for convenience
    /// (`from_json` ignores the derived ones).  Buckets are emitted
    /// sparsely as `[index, count]` pairs.
    ///
    /// Precision: `util::json::Json` numbers are f64, so integer fields
    /// (counts, sums) round-trip exactly only up to 2^53.  Counts can't
    /// realistically get there (2^53 events ≈ 285 years at 1M req/s), but
    /// a nanosecond `sum` crosses it after ~104 cumulative days of
    /// recorded time — past that, persisted snapshots round the sum (and
    /// thus `mean()`) to the nearest representable f64; bucket counts,
    /// and therefore percentiles, stay exact.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| Json::Arr(vec![Json::num(i as f64), Json::num(c as f64)]))
            .collect();
        let n = self.count();
        let pct = |p: f64| self.percentile(p).unwrap_or(0.0);
        Json::obj(vec![
            ("count", Json::num(n as f64)),
            ("sum", Json::num(self.sum as f64)),
            ("min", Json::num(if n == 0 { 0.0 } else { self.min as f64 })),
            ("max", Json::num(self.max as f64)),
            ("p50", Json::num(pct(0.50))),
            ("p95", Json::num(pct(0.95))),
            ("p99", Json::num(pct(0.99))),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<HistogramSnapshot> {
        let mut s = HistogramSnapshot {
            sum: j.req_f64("sum")? as u64,
            max: j.req_f64("max")? as u64,
            ..HistogramSnapshot::default()
        };
        for pair in j.req("buckets")?.as_arr().unwrap_or(&[]) {
            let p = pair.as_arr().filter(|p| p.len() == 2);
            let p = p.ok_or_else(|| anyhow::anyhow!("histogram bucket not an [index,count] pair"))?;
            let i = p[0].as_usize().ok_or_else(|| anyhow::anyhow!("bucket index not usize"))?;
            anyhow::ensure!(i < BUCKETS, "bucket index {i} out of range");
            s.buckets[i] = p[1].as_f64().unwrap_or(0.0) as u64;
        }
        if s.count() > 0 {
            s.min = j.req_f64("min")? as u64;
        }
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Span
// ---------------------------------------------------------------------------

/// RAII timer: records the elapsed nanoseconds into a histogram when
/// dropped.  Constructing one while telemetry is disabled is free — no
/// clock read, no allocation, nothing recorded on drop.
pub struct Span {
    live: Option<(Instant, Arc<Histogram>)>,
}

impl Span {
    /// Time into an owned histogram handle.
    pub fn start(h: &Arc<Histogram>) -> Span {
        if enabled() {
            Span { live: Some((Instant::now(), h.clone())) }
        } else {
            Span { live: None }
        }
    }

    /// Time into the global registry histogram `name` (created on first
    /// use).  The registry lookup is skipped entirely when disabled.
    pub fn named(name: &str) -> Span {
        if enabled() {
            Span { live: Some((Instant::now(), histogram(name))) }
        } else {
            Span { live: None }
        }
    }

    /// A span that records nothing (for callers threading an optional
    /// span through).
    pub fn disabled() -> Span {
        Span { live: None }
    }

    /// Will this span record on drop?
    pub fn is_live(&self) -> bool {
        self.live.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((t0, h)) = self.live.take() {
            h.record_duration(t0.elapsed());
        }
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Name → metric map.  Registration is the cold path (mutex + BTreeMap);
/// the returned `Arc` handles are the hot path and touch no lock.  Hot
/// call sites should cache the handle (e.g. in a `OnceLock`) instead of
/// re-looking-up per record.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create a counter.  A name already registered as a different
    /// metric kind is replaced (last writer wins — a kind clash is a
    /// programmer error, and telemetry must never panic the process).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        if let Some(Metric::Counter(c)) = m.get(name) {
            return c.clone();
        }
        let c = Arc::new(Counter::new());
        m.insert(name.to_string(), Metric::Counter(c.clone()));
        c
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        if let Some(Metric::Gauge(g)) = m.get(name) {
            return g.clone();
        }
        let g = Arc::new(Gauge::new());
        m.insert(name.to_string(), Metric::Gauge(g.clone()));
        g
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        if let Some(Metric::Histogram(h)) = m.get(name) {
            return h.clone();
        }
        let h = Arc::new(Histogram::new());
        m.insert(name.to_string(), Metric::Histogram(h.clone()));
        h
    }

    /// Publish an externally owned metric under `name` (replacing any
    /// previous registration).  This is how the serving router exposes its
    /// per-server histograms without giving up ownership.
    pub fn publish_histogram(&self, name: &str, h: Arc<Histogram>) {
        self.metrics.lock().unwrap().insert(name.to_string(), Metric::Histogram(h));
    }

    pub fn publish_counter(&self, name: &str, c: Arc<Counter>) {
        self.metrics.lock().unwrap().insert(name.to_string(), Metric::Counter(c));
    }

    pub fn publish_gauge(&self, name: &str, g: Arc<Gauge>) {
        self.metrics.lock().unwrap().insert(name.to_string(), Metric::Gauge(g));
    }

    /// Point-in-time copy of every registered metric, name-sorted.
    pub fn snapshot(&self) -> SnapshotReport {
        let m = self.metrics.lock().unwrap();
        let mut r = SnapshotReport::default();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => r.counters.push((name.clone(), c.get())),
                Metric::Gauge(g) => r.gauges.push((name.clone(), g.get())),
                Metric::Histogram(h) => r.histograms.push((name.clone(), h.snapshot())),
            }
        }
        r
    }
}

fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Get-or-create a counter in the process-wide registry.
pub fn counter(name: &str) -> Arc<Counter> {
    global().counter(name)
}

/// Get-or-create a gauge in the process-wide registry.
pub fn gauge(name: &str) -> Arc<Gauge> {
    global().gauge(name)
}

/// Get-or-create a histogram in the process-wide registry.
pub fn histogram(name: &str) -> Arc<Histogram> {
    global().histogram(name)
}

/// Publish an externally owned histogram process-wide.
pub fn publish_histogram(name: &str, h: Arc<Histogram>) {
    global().publish_histogram(name, h);
}

/// Publish an externally owned counter process-wide.
pub fn publish_counter(name: &str, c: Arc<Counter>) {
    global().publish_counter(name, c);
}

/// Publish an externally owned gauge process-wide.
pub fn publish_gauge(name: &str, g: Arc<Gauge>) {
    global().publish_gauge(name, g);
}

/// Convenience: bump a registry counter by `n` if telemetry is enabled.
/// Does a registry lookup per call — use only on coarse paths; hot paths
/// cache the `Arc<Counter>` handle.
#[inline]
pub fn add(name: &str, n: u64) {
    if enabled() {
        counter(name).add(n);
    }
}

/// `add(name, 1)`.
#[inline]
pub fn inc(name: &str) {
    add(name, 1);
}

/// Snapshot of the process-wide registry.
pub fn snapshot() -> SnapshotReport {
    global().snapshot()
}

// ---------------------------------------------------------------------------
// SnapshotReport
// ---------------------------------------------------------------------------

/// Point-in-time copy of a registry: what the `serve --stats-interval`
/// emitter prints, what `logicnets stats` pretty-prints, and what CI
/// uploads next to the bench reports.
#[derive(Default, Debug, Clone)]
pub struct SnapshotReport {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

impl SnapshotReport {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Histogram by exact name.
    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Counter value by exact name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    /// Human-readable table.  Durations (histograms named `*.ns`) are
    /// pretty-printed with time units; everything else is raw.
    pub fn render(&self) -> String {
        use crate::util::bench::fmt_ns;
        let mut out = String::new();
        out.push_str("== telemetry snapshot ==\n");
        if self.is_empty() {
            out.push_str("(no metrics registered)\n");
            return out;
        }
        if !self.counters.is_empty() {
            out.push_str("counters:\n");
            for (name, v) in &self.counters {
                out.push_str(&format!("  {name:<44} {v:>14}\n"));
            }
        }
        if !self.gauges.is_empty() {
            out.push_str("gauges:\n");
            for (name, v) in &self.gauges {
                out.push_str(&format!("  {name:<44} {v:>14}\n"));
            }
        }
        if !self.histograms.is_empty() {
            out.push_str(&format!(
                "histograms:\n  {:<44} {:>10} {:>12} {:>12} {:>12} {:>12}\n",
                "name", "count", "mean", "p50", "p99", "max"
            ));
            for (name, h) in &self.histograms {
                let n = h.count();
                let is_ns = name.ends_with(".ns");
                let f = |v: f64| if is_ns { fmt_ns(v) } else { format!("{v:.1}") };
                if n == 0 {
                    out.push_str(&format!("  {name:<44} {n:>10} {:>12}\n", "-"));
                } else {
                    out.push_str(&format!(
                        "  {name:<44} {n:>10} {:>12} {:>12} {:>12} {:>12}\n",
                        f(h.mean().unwrap_or(0.0)),
                        f(h.percentile(0.50).unwrap_or(0.0)),
                        f(h.percentile(0.99).unwrap_or(0.0)),
                        f(h.max as f64),
                    ));
                }
            }
        }
        out
    }

    /// Stable JSON: `{"obs":"snapshot","version":1,"counters":{...},
    /// "gauges":{...},"histograms":{name:{count,sum,min,max,p50,p95,p99,
    /// buckets:[[i,c],...]}}}`.  Object keys are BTreeMap-ordered, so the
    /// output is byte-stable for a given snapshot.  Integer fields are
    /// carried as f64 JSON numbers and round-trip exactly up to 2^53 (see
    /// [`HistogramSnapshot::to_json`]).
    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> =
            self.counters.iter().map(|(n, v)| (n.clone(), Json::num(*v as f64))).collect();
        let gauges: BTreeMap<String, Json> =
            self.gauges.iter().map(|(n, v)| (n.clone(), Json::num(*v as f64))).collect();
        let histograms: BTreeMap<String, Json> =
            self.histograms.iter().map(|(n, h)| (n.clone(), h.to_json())).collect();
        Json::obj(vec![
            ("obs", Json::str("snapshot")),
            ("version", Json::num(1.0)),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(histograms)),
        ])
    }

    /// Parse a snapshot previously emitted by [`SnapshotReport::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<SnapshotReport> {
        anyhow::ensure!(
            j.get("obs").and_then(|v| v.as_str()) == Some("snapshot"),
            "not a telemetry snapshot (missing obs=snapshot marker)"
        );
        let mut r = SnapshotReport::default();
        if let Some(Json::Obj(m)) = j.get("counters") {
            for (n, v) in m {
                r.counters.push((
                    n.clone(),
                    v.as_f64().ok_or_else(|| anyhow::anyhow!("counter {n} not a number"))? as u64,
                ));
            }
        }
        if let Some(Json::Obj(m)) = j.get("gauges") {
            for (n, v) in m {
                r.gauges.push((
                    n.clone(),
                    v.as_f64().ok_or_else(|| anyhow::anyhow!("gauge {n} not a number"))? as i64,
                ));
            }
        }
        if let Some(Json::Obj(m)) = j.get("histograms") {
            for (n, v) in m {
                r.histograms.push((n.clone(), HistogramSnapshot::from_json(v)?));
            }
        }
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sums_across_threads() {
        let c = Arc::new(Counter::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn gauge_tracks_level() {
        let g = Gauge::new();
        g.add(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
        g.set(-7);
        assert_eq!(g.get(), -7);
    }

    #[test]
    fn bucket_layout_is_log2_exact() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        for k in 1..62 {
            // 2^k opens bucket k+1; 2^k - 1 still belongs to bucket k.
            assert_eq!(bucket_index(1u64 << k), k + 1);
            assert_eq!(bucket_index((1u64 << k) - 1), k);
            let (lo, hi) = bucket_bounds(k + 1);
            assert_eq!(lo, 1u64 << k);
            assert_eq!(hi, 1u64 << (k + 1));
        }
        // Top bucket absorbs everything past 2^62.
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_bounds(BUCKETS - 1).1, u64::MAX);
    }

    #[test]
    fn histogram_percentiles_and_merge() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.5), None, "empty histogram has no percentiles");
        for v in [100u64, 200, 400, 800, 1600] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        let s = h.snapshot();
        // Estimates always land inside the bucket holding the true rank
        // sample, and are monotone in p.
        let p0 = s.percentile(0.0).unwrap();
        let p100 = s.percentile(1.0).unwrap();
        assert!((100.0..128.0).contains(&p0), "p0 {p0} outside bucket of 100");
        assert!((1024.0..=1600.0).contains(&p100), "p100 {p100} outside bucket of 1600");
        let mut prev = p0;
        for i in 1..=20 {
            let v = s.percentile(i as f64 / 20.0).unwrap();
            assert!(v >= prev, "percentile must be monotone in p");
            prev = v;
        }
        // Merge is associative.
        let a = s.clone();
        let mut b = HistogramSnapshot::default();
        b.buckets[3] = 7;
        b.sum = 42;
        b.min = 4;
        b.max = 7;
        let c = {
            let h2 = Histogram::new();
            h2.record(1 << 20);
            h2.snapshot()
        };
        assert_eq!(a.merge(&b).merge(&c), a.merge(&b.merge(&c)));
        assert_eq!(a.merge(&b).count(), 12);
    }

    #[test]
    fn span_records_into_histogram() {
        let h = Arc::new(Histogram::new());
        {
            let _sp = Span::start(&h);
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
        assert_eq!(h.count(), 1);
        // One sample: the clamped-bucket estimate is exact, and sleep
        // guarantees at least 100µs elapsed.
        assert!(h.percentile(0.5).unwrap() >= 100_000.0);
        // A statically disabled span records nothing.
        {
            let sp = Span::disabled();
            assert!(!sp.is_live());
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn registry_reuses_handles_and_snapshots() {
        let r = Registry::new();
        let c1 = r.counter("t.a.count");
        let c2 = r.counter("t.a.count");
        c1.add(3);
        c2.add(4);
        assert_eq!(r.counter("t.a.count").get(), 7, "same name must share one counter");
        r.gauge("t.b.depth").set(9);
        r.histogram("t.c.ns").record(1000);
        let snap = r.snapshot();
        assert_eq!(snap.counter("t.a.count"), Some(7));
        assert_eq!(snap.histogram("t.c.ns").unwrap().count(), 1);
        assert!(snap.render().contains("t.b.depth"));
    }

    #[test]
    fn snapshot_json_roundtrip() {
        let r = Registry::new();
        r.counter("x.events.count").add(12);
        r.gauge("x.depth").set(-3);
        let h = r.histogram("x.lat.ns");
        for v in [10u64, 1000, 100_000, 10_000_000] {
            h.record(v);
        }
        let snap = r.snapshot();
        let j = snap.to_json();
        let text = j.to_string();
        let back = SnapshotReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.counter("x.events.count"), Some(12));
        assert_eq!(back.gauges, vec![("x.depth".to_string(), -3)]);
        let orig = snap.histogram("x.lat.ns").unwrap();
        let got = back.histogram("x.lat.ns").unwrap();
        assert_eq!(orig, got, "histogram must survive the JSON roundtrip exactly");
        // Stable output: re-serializing the parsed form is byte-identical.
        assert_eq!(back.to_json().to_string(), text);
    }
}
