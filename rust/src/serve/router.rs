//! Request router + dynamic batcher over a serving backend.
//!
//! Architecture (vLLM-router-flavored, scaled to this workload): clients
//! submit single samples through a channel; a batcher thread coalesces up
//! to `max_batch` requests (or whatever arrived within `batch_timeout`) and
//! hands the batch to a worker pool; each worker re-packs its batch into
//! one contiguous buffer and runs a single `Backend::infer_batch` call, so
//! backends that are batch-native (the wide-plane `NetlistEngine` computes
//! 256 samples per chunk) get full batches, and the table engine keeps its
//! allocation-free scratch reuse internally.  The backend is selected at
//! `Server::start` — any `Arc<impl Backend>` works.  Latency is tracked per
//! request (enqueue -> response) in a fixed-size reservoir for percentile
//! reporting.
//!
//! [`ZooServer`] stacks a budget router on top: one `Server` (worker pool,
//! queue, stats) per registered model, each request carrying an optional
//! latency/LUT [`Budget`] dispatched to the cheapest model whose
//! *calibrated* metadata satisfies it (best-quality fallback otherwise).
//! `serve::zoo` builds one from a DSE-emitted `zoo.json` manifest.

use super::engine::Backend;
use crate::obs::{self, Counter, Gauge, Histogram};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub queue_depth: usize,
    /// When set, the server publishes its telemetry (latency breakdown
    /// histograms, queue gauge, completion counters) into the process-wide
    /// `obs` registry under `<prefix>.<metric>.<unit>` names — e.g.
    /// `serve.queue_wait.ns`.  `None` (the default) keeps the metrics
    /// private to the [`Server`] handle, so tests and embedded servers
    /// never collide in the global namespace.
    pub obs_prefix: Option<String>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: crate::util::pool::num_threads().min(8),
            // One full evaluation chunk of the wide-plane simulator (256
            // samples): a maximal batch fills every lane of one chunk
            // instead of leaving 3/4 of the wide pass masked off.
            max_batch: 256,
            batch_timeout: Duration::from_micros(50),
            queue_depth: 4096,
            obs_prefix: None,
        }
    }
}

struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    resp: SyncSender<usize>,
}

/// Latency samples kept for percentile reporting.
const LATENCY_RESERVOIR: usize = 100_000;

/// Algorithm-R reservoir sample over the latency stream: every request is
/// a candidate with uniform probability for the whole lifetime of the
/// server.  (The previous "reservoir" stopped recording once full, so
/// p50/p95/p99 only ever described the first 100k requests — startup
/// traffic, cold caches and all.)  Each worker offers samples with its own
/// private RNG; only the stream index is shared, via an atomic counter.
struct Reservoir {
    cap: usize,
    /// Total samples offered (0-based stream index dispenser).
    seen: AtomicU64,
    samples: Mutex<Vec<f64>>,
}

impl Reservoir {
    fn new(cap: usize) -> Reservoir {
        Reservoir { cap: cap.max(1), seen: AtomicU64::new(0), samples: Mutex::new(Vec::new()) }
    }

    /// Offer one sample; `rng` must be private to the calling thread.
    fn offer(&self, v: f64, rng: &mut Rng) {
        let t = self.seen.fetch_add(1, Ordering::Relaxed) as usize;
        if t < self.cap {
            self.samples.lock().unwrap().push(v);
        } else {
            // Keep with probability cap/(t+1), evicting a uniform victim.
            let j = rng.below(t + 1);
            if j < self.cap {
                let mut s = self.samples.lock().unwrap();
                if j < s.len() {
                    s[j] = v;
                }
            }
        }
    }

    fn seen(&self) -> u64 {
        self.seen.load(Ordering::Relaxed)
    }

    fn snapshot(&self) -> Vec<f64> {
        self.samples.lock().unwrap().clone()
    }
}

/// Exact per-server telemetry: the per-request latency breakdown, batch
/// fill distribution and queue gauge.  All handles are `Arc`s shared with
/// the batcher/worker threads — clone freely and read any time.  Every
/// completed request records exactly one sample into each of
/// `queue_wait_ns`, `eval_ns`, `tail_ns` and `latency_ns`, so the four
/// counts always equal `ServerStats::completed` on a quiesced server.
#[derive(Clone, Default)]
pub struct ServerMetrics {
    /// Enqueue → batch dequeue by a worker, per request (nanoseconds).
    pub queue_wait_ns: Arc<Histogram>,
    /// Backend `infer_batch` wall time, recorded once per request in the
    /// batch — the eval cost each request in that batch experienced.
    pub eval_ns: Arc<Histogram>,
    /// Fused-tail segment: end of batch eval → this request's response
    /// delivered (prediction unpack + fan-out), per request.
    pub tail_ns: Arc<Histogram>,
    /// Full enqueue → response latency per request: the exact-count
    /// primary source behind the `ServerStats` percentiles.
    pub latency_ns: Arc<Histogram>,
    /// Requests per dispatched batch.
    pub batch_fill: Arc<Histogram>,
    /// Requests admitted to the ingress queue and not yet responded to.
    pub queue_depth: Arc<Gauge>,
}

struct StatsInner {
    lat: Reservoir,
    completed: Arc<Counter>,
    batches: Arc<Counter>,
    batch_fill_sum: Arc<Counter>,
    rejected: Arc<Counter>,
    m: ServerMetrics,
}

impl Default for StatsInner {
    fn default() -> Self {
        StatsInner {
            lat: Reservoir::new(LATENCY_RESERVOIR),
            completed: Arc::new(Counter::new()),
            batches: Arc::new(Counter::new()),
            batch_fill_sum: Arc::new(Counter::new()),
            rejected: Arc::new(Counter::new()),
            m: ServerMetrics::default(),
        }
    }
}

impl StatsInner {
    /// Publish this server's metrics into the global registry under
    /// `<prefix>.<metric>.<unit>` (replacing any previous registration of
    /// the same names — a restarted server takes over its slot).
    fn publish(&self, prefix: &str) {
        obs::publish_histogram(&format!("{prefix}.queue_wait.ns"), self.m.queue_wait_ns.clone());
        obs::publish_histogram(&format!("{prefix}.eval.ns"), self.m.eval_ns.clone());
        obs::publish_histogram(&format!("{prefix}.tail.ns"), self.m.tail_ns.clone());
        obs::publish_histogram(&format!("{prefix}.latency.ns"), self.m.latency_ns.clone());
        obs::publish_histogram(&format!("{prefix}.batch_fill.samples"), self.m.batch_fill.clone());
        obs::publish_gauge(&format!("{prefix}.queue.depth"), self.m.queue_depth.clone());
        obs::publish_counter(&format!("{prefix}.completed.count"), self.completed.clone());
        obs::publish_counter(&format!("{prefix}.batches.count"), self.batches.clone());
        obs::publish_counter(&format!("{prefix}.rejected.count"), self.rejected.clone());
    }
}

/// Interpolated percentile of an ascending-sorted sample (linear between
/// closest ranks).  The truncating nearest-rank it replaces rounded *down*,
/// which on small samples could report p99 == p50.
///
/// An empty sample has **no** percentiles: this returns `None` rather than
/// a fabricated number.  (The old signature silently returned `0.0`, which
/// read as a real — impossibly good — latency to anything recording the
/// value, e.g. a zoo calibration pass run before any request completed.)
pub fn percentile(sorted: &[f64], p: f64) -> Option<f64> {
    match sorted.len() {
        0 => None,
        n => {
            let rank = (n - 1) as f64 * p;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            Some(sorted[lo] + (sorted[hi] - sorted[lo]) * (rank - lo as f64))
        }
    }
}

/// Snapshot of server statistics.
///
/// `p50_us`/`p95_us`/`p99_us` come from the **exact-count** log2
/// histogram over every completed request (`ServerMetrics::latency_ns`);
/// `res_*` are the Algorithm-R reservoir's estimates over a uniform
/// sample of the same stream (exact values, sampled stream) and serve as
/// a cross-check — the two should agree to within one log2 bucket.  All
/// percentile fields are `0.0` until the first request completes — check
/// `completed > 0` before treating them as measurements (never NaN
/// either way).
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub completed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    /// Latency samples currently in the reservoir backing the `res_*`
    /// cross-check percentiles (0 ⇒ all percentile fields are
    /// placeholders, not measurements).
    pub lat_samples: usize,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Reservoir cross-check percentiles (lossy sample, exact values).
    pub res_p50_us: f64,
    pub res_p95_us: f64,
    pub res_p99_us: f64,
    pub rejected: usize,
}

pub struct Server {
    tx: SyncSender<Request>,
    stats: Arc<StatsInner>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub in_features: usize,
}

impl Server {
    /// Start the router over any serving backend (`LutEngine`,
    /// `NetlistEngine`, ...).
    pub fn start<B: Backend>(engine: Arc<B>, cfg: ServerConfig) -> Server {
        Server::start_dyn(engine as Arc<dyn Backend>, cfg)
    }

    /// [`Server::start`] for an already-erased backend — what the
    /// multi-model zoo server uses, since its engines are heterogeneous.
    pub fn start_dyn(engine: Arc<dyn Backend>, cfg: ServerConfig) -> Server {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let stats = Arc::new(StatsInner::default());
        if let Some(prefix) = &cfg.obs_prefix {
            stats.publish(prefix);
        }
        // Batcher thread: coalesce, then fan batches to workers round-robin.
        let mut worker_txs = Vec::new();
        let mut handles = Vec::new();
        for wi in 0..cfg.workers.max(1) {
            let (wtx, wrx) = sync_channel::<Vec<Request>>(8);
            worker_txs.push(wtx);
            let engine = engine.clone();
            let stats = stats.clone();
            handles.push(std::thread::spawn(move || worker_loop(engine, wrx, stats, wi)));
        }
        let in_features = engine.in_features();
        let stats2 = stats.clone();
        let max_batch = cfg.max_batch.max(1);
        let timeout = cfg.batch_timeout;
        handles.push(std::thread::spawn(move || {
            batcher_loop(rx, worker_txs, max_batch, timeout, stats2)
        }));
        Server { tx, stats, handles, in_features }
    }

    /// Blocking single inference through the full router path.
    pub fn infer(&self, x: Vec<f32>) -> Option<usize> {
        if x.len() != self.in_features {
            // Malformed request: never let it scramble a packed batch.
            self.stats.rejected.inc();
            return None;
        }
        let (rtx, rrx) = sync_channel(1);
        let req = Request { x, enqueued: Instant::now(), resp: rtx };
        // Gauge up before the request becomes visible to the batcher: if it
        // went up after try_send, a fast worker could decrement first and a
        // concurrent snapshot would read the gauge negative.
        self.stats.m.queue_depth.add(1);
        if self.tx.try_send(req).is_err() {
            self.stats.m.queue_depth.add(-1);
            self.stats.rejected.inc();
            return None;
        }
        rrx.recv().ok()
    }

    /// Handles to this server's exact telemetry (latency breakdown
    /// histograms, batch fill, queue gauge).
    pub fn metrics(&self) -> ServerMetrics {
        self.stats.m.clone()
    }

    pub fn stats(&self) -> ServerStats {
        let mut lats = self.stats.lat.snapshot();
        // IEEE total order: measured latencies are always finite, but a
        // NaN in the reservoir must never abort a stats read (the old
        // partial_cmp().unwrap() here was the same panic family PR 3
        // fixed in pareto_frontier).
        lats.sort_by(f64::total_cmp);
        let res = |p: f64| percentile(&lats, p).unwrap_or(0.0);
        // Primary percentiles from the exact-count histogram: every
        // completed request is in it, not just a 100k-sample reservoir.
        let hist = self.stats.m.latency_ns.snapshot();
        let pct = |p: f64| hist.percentile(p).map(|ns| ns / 1e3).unwrap_or(0.0);
        let batches = self.stats.batches.get();
        let fill = self.stats.batch_fill_sum.get();
        ServerStats {
            completed: self.stats.completed.get(),
            batches,
            mean_batch: if batches == 0 { 0.0 } else { fill as f64 / batches as f64 },
            lat_samples: lats.len(),
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            res_p50_us: res(0.50),
            res_p95_us: res(0.95),
            res_p99_us: res(0.99),
            rejected: self.stats.rejected.get() as usize,
        }
    }

    /// Shut down: drop the ingress, join all threads.
    pub fn shutdown(self) {
        drop(self.tx);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    worker_txs: Vec<SyncSender<Vec<Request>>>,
    max_batch: usize,
    timeout: Duration,
    stats: Arc<StatsInner>,
) {
    let mut next_worker = 0usize;
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + timeout;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.batches.inc();
        stats.batch_fill_sum.add(batch.len() as u64);
        stats.m.batch_fill.record(batch.len() as u64);
        // Round-robin dispatch; if a worker queue is full, rotate.
        let mut sent = false;
        for k in 0..worker_txs.len() {
            let w = (next_worker + k) % worker_txs.len();
            match worker_txs[w].try_send(batch) {
                Ok(()) => {
                    next_worker = (w + 1) % worker_txs.len();
                    sent = true;
                    batch = Vec::new();
                    break;
                }
                Err(std::sync::mpsc::TrySendError::Full(b)) => batch = b,
                Err(std::sync::mpsc::TrySendError::Disconnected(b)) => batch = b,
            }
        }
        if !sent {
            // All queues full: apply backpressure by blocking on one.
            let _ = worker_txs[next_worker].send(batch);
            next_worker = (next_worker + 1) % worker_txs.len();
        }
    }
}

fn worker_loop(
    engine: Arc<dyn Backend>,
    rx: Receiver<Vec<Request>>,
    stats: Arc<StatsInner>,
    worker: usize,
) {
    // Private sampling stream per worker: Algorithm R needs an RNG on every
    // post-fill offer, and sharing one behind a lock would serialize the
    // hot path.
    let mut rng = Rng::new(0x5EED_0A11 ^ (worker as u64).wrapping_mul(0x9E3779B97F4A7C15));
    // One reusable pack buffer per worker: requests are copied into a
    // contiguous [batch, d] matrix so the backend sees a single batch call.
    let mut xs: Vec<f32> = Vec::new();
    while let Ok(batch) = rx.recv() {
        // Per-request latency decomposition: queue wait (enqueue → this
        // dequeue), eval (the batch's infer_batch call — every request in
        // the batch experienced that cost), and the fused tail (end of
        // eval → this response delivered).  One sample per request in
        // each histogram, so their counts all equal `completed`.
        let t_dequeue = Instant::now();
        xs.clear();
        for req in &batch {
            stats.m.queue_wait_ns.record_duration(t_dequeue.duration_since(req.enqueued));
            xs.extend_from_slice(&req.x);
        }
        let t_eval0 = Instant::now();
        let preds = engine.infer_batch(&xs);
        let t_eval_end = Instant::now();
        let eval = t_eval_end.duration_since(t_eval0);
        debug_assert_eq!(preds.len(), batch.len());
        for (req, class) in batch.into_iter().zip(preds) {
            stats.m.eval_ns.record_duration(eval);
            let lat = req.enqueued.elapsed().as_secs_f64() * 1e6;
            // Same value into both latency trackers: the exact histogram
            // (primary) and the reservoir (sampled cross-check).
            stats.m.latency_ns.record((lat * 1e3) as u64);
            stats.lat.offer(lat, &mut rng);
            stats.completed.inc();
            // All bookkeeping lands before the response is sent: once a
            // client's infer() returns, the tail histogram already holds
            // this request and the queue gauge is back down, so snapshots
            // taken "after all calls returned" are exact, not racy.
            stats.m.tail_ns.record_duration(t_eval_end.elapsed());
            stats.m.queue_depth.add(-1);
            let _ = req.resp.send(class);
        }
    }
}

// ---------------------------------------------------------------------------
// Budget-routed multi-model serving (the DSE→serving handoff)
// ---------------------------------------------------------------------------

/// Metadata a model registers with the budget router: its serving cost
/// axes (mapped LUTs, BRAMs, *calibrated* p50/p99 request latency) and its
/// quality.  Routing decisions read only this — never live latency — so a
/// given (zoo, budget) pair always routes to the same model.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    /// Mapped (synthesized, optimized) LUT count of the served netlist.
    pub luts: u64,
    pub brams: usize,
    /// Higher is better (100 × avg AUC).
    pub quality: f64,
    /// Calibrated single-request latency percentiles, microseconds.
    pub p50_us: f64,
    pub p99_us: f64,
}

/// Optional per-request budget.  `None` axes are unconstrained; a fully
/// unconstrained budget routes to the best-quality model.
#[derive(Debug, Clone, Copy, Default)]
pub struct Budget {
    /// Max acceptable p99 latency (µs), compared against the calibrated
    /// `ModelMeta::p99_us`.
    pub max_latency_us: Option<f64>,
    /// Max acceptable mapped-LUT cost.
    pub max_luts: Option<u64>,
}

impl Budget {
    pub fn none() -> Budget {
        Budget::default()
    }

    pub fn latency_us(us: f64) -> Budget {
        Budget { max_latency_us: Some(us), max_luts: None }
    }

    pub fn luts(luts: u64) -> Budget {
        Budget { max_latency_us: None, max_luts: Some(luts) }
    }

    pub fn is_unbounded(&self) -> bool {
        self.max_latency_us.is_none() && self.max_luts.is_none()
    }

    /// Does `m` fit this budget?  Unset axes always admit.
    pub fn admits(&self, m: &ModelMeta) -> bool {
        self.max_latency_us.map_or(true, |lim| m.p99_us <= lim)
            && self.max_luts.map_or(true, |lim| m.luts <= lim)
    }
}

struct ZooModel {
    meta: ModelMeta,
    server: Server,
    /// Requests this model was chosen for (routing decisions, not
    /// completions — completions live in the per-model `ServerStats`).
    routed: Arc<Counter>,
}

/// Per-model stats snapshot from a [`ZooServer`].
#[derive(Debug, Clone)]
pub struct ZooModelStats {
    pub name: String,
    pub luts: u64,
    pub quality: f64,
    /// Calibrated p99 the router budgets against (not the live p99 —
    /// that's in `stats`).
    pub budget_p99_us: f64,
    pub routed: u64,
    pub stats: ServerStats,
}

/// Multi-model budget router: every registered model runs behind its own
/// [`Server`] (private worker pool, queue and latency reservoir), and each
/// request carries an optional [`Budget`].  Dispatch rule:
///
/// * budgeted request → the **cheapest** (fewest mapped LUTs, ties to the
///   better quality) model whose calibrated metadata satisfies the budget;
///   if *no* model fits, fall back to the best-quality model and count the
///   miss (`fallbacks`);
/// * unbudgeted request → the best-quality model (ties to fewer LUTs).
pub struct ZooServer {
    /// Sorted cheapest-first (LUTs asc, quality desc, name asc), so budget
    /// dispatch is a first-admitted scan.
    models: Vec<ZooModel>,
    /// Index of the best-quality model (the unbudgeted/fallback target).
    best: usize,
    fallbacks: Arc<Counter>,
    pub in_features: usize,
}

impl ZooServer {
    /// Start one [`Server`] per registered model.  All models must share
    /// the input width (they serve the same request stream); quality and
    /// latency metadata must be finite (a NaN would poison every routing
    /// comparison) — the zoo manifest loader enforces the same invariant.
    pub fn start(
        entries: Vec<(ModelMeta, Arc<dyn Backend>)>,
        cfg: &ServerConfig,
    ) -> anyhow::Result<ZooServer> {
        anyhow::ensure!(!entries.is_empty(), "zoo server needs at least one model");
        let in_features = entries[0].1.in_features();
        for (meta, engine) in &entries {
            anyhow::ensure!(
                engine.in_features() == in_features,
                "model {} input width {} != {}",
                meta.name,
                engine.in_features(),
                in_features
            );
            anyhow::ensure!(
                meta.quality.is_finite() && meta.p50_us.is_finite() && meta.p99_us.is_finite(),
                "model {} has non-finite routing metadata",
                meta.name
            );
        }
        let mut models: Vec<ZooModel> = entries
            .into_iter()
            .map(|(meta, engine)| {
                // Per-model telemetry namespace: `serve` as the base
                // prefix yields `serve.<model>.queue_wait.ns` etc.
                let mut mcfg = cfg.clone();
                if let Some(base) = &cfg.obs_prefix {
                    mcfg.obs_prefix = Some(format!("{base}.{}", meta.name));
                }
                let routed = Arc::new(Counter::new());
                if let Some(base) = &cfg.obs_prefix {
                    obs::publish_counter(&format!("{base}.{}.routed.count", meta.name), routed.clone());
                }
                ZooModel { server: Server::start_dyn(engine, mcfg), meta, routed }
            })
            .collect();
        models.sort_by(|a, b| {
            a.meta
                .luts
                .cmp(&b.meta.luts)
                .then(b.meta.quality.total_cmp(&a.meta.quality))
                .then(a.meta.name.cmp(&b.meta.name))
        });
        let best = models
            .iter()
            .enumerate()
            .max_by(|a, b| {
                a.1.meta
                    .quality
                    .total_cmp(&b.1.meta.quality)
                    // Quality ties break to the *cheaper* model.
                    .then(b.1.meta.luts.cmp(&a.1.meta.luts))
            })
            .map(|(i, _)| i)
            .unwrap_or(0);
        let fallbacks = Arc::new(Counter::new());
        if let Some(base) = &cfg.obs_prefix {
            obs::publish_counter(&format!("{base}.fallbacks.count"), fallbacks.clone());
        }
        Ok(ZooServer { models, best, fallbacks, in_features })
    }

    /// Routing decision: `(model index, fallback?)` — fallback means no
    /// model satisfied a bounded budget and the best-quality model stands
    /// in.  Pure in the registered metadata.
    fn dispatch(&self, budget: &Budget) -> (usize, bool) {
        if !budget.is_unbounded() {
            for (i, m) in self.models.iter().enumerate() {
                if budget.admits(&m.meta) {
                    return (i, false);
                }
            }
            // Nothing satisfies the budget: serve the best model rather
            // than failing the request.
            return (self.best, true);
        }
        (self.best, false)
    }

    /// Index of the model a request with this budget is dispatched to
    /// (deterministic in the registered metadata).  Pure inspection: does
    /// not count toward `fallbacks` — only [`ZooServer::infer`] does.
    pub fn route(&self, budget: &Budget) -> usize {
        self.dispatch(budget).0
    }

    /// Blocking inference routed by `budget`; returns the predicted class
    /// and the name of the model that served it.
    pub fn infer(&self, x: Vec<f32>, budget: &Budget) -> Option<(usize, &str)> {
        let (i, fallback) = self.dispatch(budget);
        if fallback {
            self.fallbacks.inc();
        }
        let m = &self.models[i];
        m.routed.inc();
        let class = m.server.infer(x)?;
        Some((class, m.meta.name.as_str()))
    }

    /// Registered models, cheapest-first.
    pub fn models(&self) -> Vec<&ModelMeta> {
        self.models.iter().map(|m| &m.meta).collect()
    }

    /// Name of the model unbudgeted requests go to.
    pub fn best_model(&self) -> &str {
        self.models[self.best].meta.name.as_str()
    }

    /// Budgeted requests no model could satisfy (served by the best-quality
    /// fallback).
    pub fn fallbacks(&self) -> u64 {
        self.fallbacks.get()
    }

    /// Per-model statistics, cheapest-first.
    pub fn stats(&self) -> Vec<ZooModelStats> {
        self.models
            .iter()
            .map(|m| ZooModelStats {
                name: m.meta.name.clone(),
                luts: m.meta.luts,
                quality: m.meta.quality,
                budget_p99_us: m.meta.p99_us,
                routed: m.routed.get(),
                stats: m.server.stats(),
            })
            .collect()
    }

    /// Telemetry handles per model, cheapest-first (name, metrics).
    pub fn model_metrics(&self) -> Vec<(String, ServerMetrics)> {
        self.models.iter().map(|m| (m.meta.name.clone(), m.server.metrics())).collect()
    }

    /// Full per-model statistics as stable JSON — the `serve --zoo
    /// --json` payload.  Includes everything the human table shows plus
    /// the fields it elides: routing metadata, fallback and reject
    /// counts, the reservoir cross-check percentiles and the exact
    /// queue-wait / eval / fused-tail p99 breakdown.
    pub fn stats_json(&self) -> Json {
        let pct_us = |h: &Arc<Histogram>, p: f64| h.percentile(p).map(|ns| ns / 1e3).unwrap_or(0.0);
        let models: Vec<Json> = self
            .models
            .iter()
            .map(|m| {
                let st = m.server.stats();
                let mm = m.server.metrics();
                Json::obj(vec![
                    ("name", Json::str(&m.meta.name)),
                    ("luts", Json::num(m.meta.luts as f64)),
                    ("brams", Json::num(m.meta.brams as f64)),
                    ("quality", Json::num(m.meta.quality)),
                    ("budget_p50_us", Json::num(m.meta.p50_us)),
                    ("budget_p99_us", Json::num(m.meta.p99_us)),
                    ("routed", Json::num(m.routed.get() as f64)),
                    ("completed", Json::num(st.completed as f64)),
                    ("batches", Json::num(st.batches as f64)),
                    ("mean_batch", Json::num(st.mean_batch)),
                    ("lat_samples", Json::num(st.lat_samples as f64)),
                    ("p50_us", Json::num(st.p50_us)),
                    ("p95_us", Json::num(st.p95_us)),
                    ("p99_us", Json::num(st.p99_us)),
                    ("res_p50_us", Json::num(st.res_p50_us)),
                    ("res_p95_us", Json::num(st.res_p95_us)),
                    ("res_p99_us", Json::num(st.res_p99_us)),
                    ("queue_wait_p99_us", Json::num(pct_us(&mm.queue_wait_ns, 0.99))),
                    ("eval_p99_us", Json::num(pct_us(&mm.eval_ns, 0.99))),
                    ("tail_p99_us", Json::num(pct_us(&mm.tail_ns, 0.99))),
                    ("rejected", Json::num(st.rejected as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("zoo", Json::str("stats")),
            ("best_model", Json::str(self.best_model())),
            ("fallbacks", Json::num(self.fallbacks() as f64)),
            ("models", Json::Arr(models)),
        ])
    }

    /// Shut down every per-model server.
    pub fn shutdown(self) {
        for m in self.models {
            m.server.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::ModelTables;
    use crate::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
    use crate::serve::engine::{LutEngine, NetlistEngine};
    use crate::util::rng::Rng;

    fn model_and_tables() -> (ExportedModel, ModelTables) {
        let mut rng = Rng::new(3);
        let neurons = (0..8)
            .map(|_| {
                let inputs = rng.choose_k(6, 3);
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                    bias: 0.0,
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        let model = ExportedModel {
            layers: vec![ExportedLayer::uniform(neurons, 6, QuantSpec::new(2, 1.0), QuantSpec::new(2, 2.0), true)],
            in_features: 6,
            classes: 8,
            skips: 0,
            act_widths: vec![6],
        };
        let tables = ModelTables::generate(&model).unwrap();
        (model, tables)
    }

    fn engine() -> Arc<LutEngine> {
        let (model, tables) = model_and_tables();
        Arc::new(LutEngine::build(&model, &tables).unwrap())
    }

    #[test]
    fn server_roundtrip_and_stats() {
        let eng = engine();
        let server = Server::start(
            eng.clone(),
            ServerConfig { workers: 2, max_batch: 8, ..Default::default() },
        );
        let mut rng = Rng::new(11);
        for _ in 0..100 {
            let x: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
            let direct = eng.infer_batch(&x)[0];
            let via_server = server.infer(x).expect("server response");
            assert_eq!(direct, via_server);
        }
        let stats = server.stats();
        assert_eq!(stats.completed, 100);
        assert!(stats.batches >= 1);
        assert!(stats.p50_us > 0.0 && stats.p99_us >= stats.p50_us);
        // Exact breakdown: every completed request contributes one sample
        // to each phase histogram.
        let m = server.metrics();
        assert_eq!(m.queue_wait_ns.count(), 100);
        assert_eq!(m.eval_ns.count(), 100);
        assert_eq!(m.tail_ns.count(), 100);
        assert_eq!(m.latency_ns.count(), 100);
        assert_eq!(m.queue_depth.get(), 0, "all admitted requests responded");
        // Reservoir held the full stream here, so the exact-histogram
        // percentiles and the reservoir cross-check must agree to within
        // one log2 bucket.
        assert_eq!(stats.lat_samples, 100);
        for (hist, res) in [(stats.p50_us, stats.res_p50_us), (stats.p99_us, stats.res_p99_us)] {
            let d = crate::obs::bucket_index((hist * 1e3) as u64) as i64
                - crate::obs::bucket_index((res * 1e3) as u64) as i64;
            assert!(d.abs() <= 1, "histogram {hist}us vs reservoir {res}us disagree by {d} buckets");
        }
        server.shutdown();
    }

    #[test]
    fn netlist_backend_serves_identically() {
        // Backend selection: the same router must serve straight from the
        // synthesized netlist and agree with the table engine per request.
        let (model, tables) = model_and_tables();
        let lut = LutEngine::build(&model, &tables).unwrap();
        let net = Arc::new(NetlistEngine::build(&model, &tables).unwrap());
        let server = Server::start(
            net,
            ServerConfig { workers: 2, max_batch: 8, ..Default::default() },
        );
        let mut rng = Rng::new(21);
        for _ in 0..100 {
            let x: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
            let direct = lut.infer_batch(&x)[0];
            assert_eq!(server.infer(x).expect("server response"), direct);
        }
        server.shutdown();
    }

    #[test]
    fn reservoir_keeps_sampling_past_capacity() {
        // Regression: the old buffer froze once full; Algorithm R must keep
        // admitting late samples and stay a uniform sample of the stream.
        let r = Reservoir::new(50);
        let mut rng = Rng::new(9);
        let n = 5_000usize;
        for i in 0..n {
            r.offer(i as f64, &mut rng);
        }
        assert_eq!(r.seen(), n as u64);
        let s = r.snapshot();
        assert_eq!(s.len(), 50, "reservoir must stay at capacity");
        assert!(
            s.iter().any(|&v| v >= (n / 2) as f64),
            "late samples must be admitted (old bug: only the first 50 survive)"
        );
        // Uniformity sanity: the sample mean tracks the stream mean.
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        let stream_mean = (n - 1) as f64 / 2.0;
        assert!(
            (mean - stream_mean).abs() < stream_mean * 0.4,
            "mean {mean} vs stream {stream_mean}"
        );
    }

    #[test]
    fn percentiles_interpolate_between_ranks() {
        // An empty sample has no percentiles — None, never a fake 0.0 a
        // calibration pass could record as a real latency.
        assert_eq!(percentile(&[], 0.5), None);
        assert_eq!(percentile(&[], 0.99), None);
        assert_eq!(percentile(&[7.0], 0.99), Some(7.0));
        let two = [0.0, 10.0];
        assert!((percentile(&two, 0.5).unwrap() - 5.0).abs() < 1e-12);
        assert!((percentile(&two, 0.95).unwrap() - 9.5).abs() < 1e-12);
        // The old truncating nearest-rank collapsed p99 onto p50 here.
        assert!(percentile(&two, 0.99).unwrap() > percentile(&two, 0.5).unwrap());
        let many: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert!((percentile(&many, 0.95).unwrap() - 95.0).abs() < 1e-12);
    }

    #[test]
    fn stats_before_any_request_are_flagged_not_faked() {
        // Regression (zoo-calibration hazard): a server that has completed
        // nothing must say so (lat_samples == 0) instead of reporting
        // percentiles of an empty reservoir as real 0.0 latencies.
        let server = Server::start(engine(), ServerConfig::default());
        let st = server.stats();
        assert_eq!(st.completed, 0);
        assert_eq!(st.lat_samples, 0);
        assert!(st.p50_us == 0.0 && st.p95_us == 0.0 && st.p99_us == 0.0);
        assert!(st.res_p50_us == 0.0 && st.res_p99_us == 0.0);
        assert!(!st.p50_us.is_nan() && !st.p99_us.is_nan());
        // After one request the percentiles are measurements (both the
        // exact histogram and the reservoir cross-check).
        assert!(server.infer(vec![0.1; 6]).is_some());
        let st = server.stats();
        assert_eq!(st.lat_samples, 1);
        assert!(st.p50_us > 0.0);
        assert!(st.res_p50_us > 0.0);
        server.shutdown();
    }

    #[test]
    fn rejects_malformed_width() {
        let server = Server::start(engine(), ServerConfig::default());
        assert!(server.infer(vec![0.0; 3]).is_none());
        assert_eq!(server.stats().rejected, 1);
        server.shutdown();
    }

    fn meta(name: &str, luts: u64, quality: f64, p99_us: f64) -> ModelMeta {
        ModelMeta { name: name.into(), luts, brams: 0, quality, p50_us: p99_us / 2.0, p99_us }
    }

    #[test]
    fn zoo_routes_by_budget_and_falls_back() {
        let eng = engine();
        let cheap = meta("cheap", 100, 60.0, 50.0);
        let best = meta("best", 1000, 90.0, 500.0);
        // Registration order must not matter: insert best first.
        let zoo = ZooServer::start(
            vec![
                (best, engine() as Arc<dyn Backend>),
                (cheap, engine() as Arc<dyn Backend>),
            ],
            &ServerConfig { workers: 1, max_batch: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(zoo.in_features, 6);
        assert_eq!(zoo.best_model(), "best");
        let x: Vec<f32> = (0..6).map(|i| i as f32 / 6.0).collect();
        let direct = eng.infer_batch(&x)[0];

        // Unbudgeted -> best-quality model.
        let (class, m) = zoo.infer(x.clone(), &Budget::none()).unwrap();
        assert_eq!((class, m), (direct, "best"));
        // Latency budget between the two calibrated p99s -> cheapest
        // admitted model.
        let (class, m) = zoo.infer(x.clone(), &Budget::latency_us(100.0)).unwrap();
        assert_eq!((class, m), (direct, "cheap"));
        // A budget both models satisfy still picks the cheapest.
        assert_eq!(zoo.route(&Budget::latency_us(10_000.0)), 0);
        // LUT budget excluding `best` -> cheap.
        let (_, m) = zoo.infer(x.clone(), &Budget::luts(100)).unwrap();
        assert_eq!(m, "cheap");
        // Unsatisfiable budget -> best-quality fallback, counted.
        assert_eq!(zoo.fallbacks(), 0);
        let (_, m) = zoo.infer(x.clone(), &Budget::latency_us(1.0)).unwrap();
        assert_eq!(m, "best");
        assert_eq!(zoo.fallbacks(), 1);

        // Per-model stats, cheapest-first, with routing counts.
        let st = zoo.stats();
        assert_eq!(st.len(), 2);
        assert_eq!(st[0].name, "cheap");
        assert_eq!(st[1].name, "best");
        assert_eq!(st[0].routed, 2);
        assert_eq!(st[1].routed, 2);
        assert_eq!(st[0].stats.completed, 2);
        assert_eq!(st[1].stats.completed, 2);
        assert!(st[0].stats.lat_samples > 0);

        // The --json payload carries the full per-model stats, including
        // the fields the human table elides.
        let j = zoo.stats_json();
        assert_eq!(j.get("zoo").and_then(|v| v.as_str()), Some("stats"));
        assert_eq!(j.req_f64("fallbacks").unwrap(), 1.0);
        let models = j.get("models").and_then(|m| m.as_arr()).unwrap();
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].req_str("name").unwrap(), "cheap");
        assert_eq!(models[0].req_f64("routed").unwrap(), 2.0);
        assert_eq!(models[0].req_f64("rejected").unwrap(), 0.0);
        assert!(models[0].req_f64("p99_us").unwrap() > 0.0);
        assert!(models[0].req_f64("res_p99_us").unwrap() > 0.0);
        assert!(models[0].req_f64("queue_wait_p99_us").unwrap() >= 0.0);
        // Round-trips through the JSON emitter/parser.
        let text = j.to_string();
        assert!(crate::util::json::Json::parse(&text).is_ok());
        zoo.shutdown();
    }

    #[test]
    fn zoo_rejects_bad_registrations() {
        // NaN routing metadata would poison every dispatch comparison.
        let bad = ModelMeta {
            name: "nan".into(),
            luts: 10,
            brams: 0,
            quality: f64::NAN,
            p50_us: 1.0,
            p99_us: 2.0,
        };
        assert!(ZooServer::start(
            vec![(bad, engine() as Arc<dyn Backend>)],
            &ServerConfig::default()
        )
        .is_err());
        assert!(ZooServer::start(Vec::new(), &ServerConfig::default()).is_err());
    }

    #[test]
    fn concurrent_clients() {
        let eng = engine();
        let server = Arc::new(Server::start(
            eng,
            ServerConfig { workers: 4, max_batch: 16, ..Default::default() },
        ));
        std::thread::scope(|s| {
            for t in 0..8 {
                let server = server.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(100 + t);
                    for _ in 0..200 {
                        let x: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
                        assert!(server.infer(x).is_some());
                    }
                });
            }
        });
        let stats = server.stats();
        assert_eq!(stats.completed, 1600);
    }
}
