//! Request router + dynamic batcher over a serving backend.
//!
//! Architecture (vLLM-router-flavored, scaled to this workload): clients
//! submit single samples through a channel; a batcher thread coalesces up
//! to `max_batch` requests (or whatever arrived within `batch_timeout`) and
//! hands the batch to a worker pool; each worker re-packs its batch into
//! one contiguous buffer and runs a single `Backend::infer_batch` call, so
//! backends that are batch-native (the bitsliced `NetlistEngine` computes
//! 64 samples per word) get full batches, and the table engine keeps its
//! allocation-free scratch reuse internally.  The backend is selected at
//! `Server::start` — any `Arc<impl Backend>` works.  Latency is tracked per
//! request (enqueue -> response) in a fixed-size reservoir for percentile
//! reporting.

use super::engine::Backend;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub struct ServerConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: crate::util::pool::num_threads().min(8),
            max_batch: 64,
            batch_timeout: Duration::from_micros(50),
            queue_depth: 4096,
        }
    }
}

struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    resp: SyncSender<usize>,
}

/// Latency samples kept for percentile reporting.
const LATENCY_RESERVOIR: usize = 100_000;

/// Algorithm-R reservoir sample over the latency stream: every request is
/// a candidate with uniform probability for the whole lifetime of the
/// server.  (The previous "reservoir" stopped recording once full, so
/// p50/p95/p99 only ever described the first 100k requests — startup
/// traffic, cold caches and all.)  Each worker offers samples with its own
/// private RNG; only the stream index is shared, via an atomic counter.
struct Reservoir {
    cap: usize,
    /// Total samples offered (0-based stream index dispenser).
    seen: AtomicU64,
    samples: Mutex<Vec<f64>>,
}

impl Reservoir {
    fn new(cap: usize) -> Reservoir {
        Reservoir { cap: cap.max(1), seen: AtomicU64::new(0), samples: Mutex::new(Vec::new()) }
    }

    /// Offer one sample; `rng` must be private to the calling thread.
    fn offer(&self, v: f64, rng: &mut Rng) {
        let t = self.seen.fetch_add(1, Ordering::Relaxed) as usize;
        if t < self.cap {
            self.samples.lock().unwrap().push(v);
        } else {
            // Keep with probability cap/(t+1), evicting a uniform victim.
            let j = rng.below(t + 1);
            if j < self.cap {
                let mut s = self.samples.lock().unwrap();
                if j < s.len() {
                    s[j] = v;
                }
            }
        }
    }

    fn seen(&self) -> u64 {
        self.seen.load(Ordering::Relaxed)
    }

    fn snapshot(&self) -> Vec<f64> {
        self.samples.lock().unwrap().clone()
    }
}

struct StatsInner {
    lat: Reservoir,
    completed: AtomicU64,
    batches: AtomicU64,
    batch_fill: AtomicU64,
    rejected: AtomicUsize,
}

impl Default for StatsInner {
    fn default() -> Self {
        StatsInner {
            lat: Reservoir::new(LATENCY_RESERVOIR),
            completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_fill: AtomicU64::new(0),
            rejected: AtomicUsize::new(0),
        }
    }
}

/// Interpolated percentile of an ascending-sorted sample (linear between
/// closest ranks).  The truncating nearest-rank it replaces rounded *down*,
/// which on small samples could report p99 == p50.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    match sorted.len() {
        0 => 0.0,
        n => {
            let rank = (n - 1) as f64 * p;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            sorted[lo] + (sorted[hi] - sorted[lo]) * (rank - lo as f64)
        }
    }
}

/// Snapshot of server statistics.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub completed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub rejected: usize,
}

pub struct Server {
    tx: SyncSender<Request>,
    stats: Arc<StatsInner>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub in_features: usize,
}

impl Server {
    /// Start the router over any serving backend (`LutEngine`,
    /// `NetlistEngine`, ...).
    pub fn start<B: Backend>(engine: Arc<B>, cfg: ServerConfig) -> Server {
        let engine: Arc<dyn Backend> = engine;
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let stats = Arc::new(StatsInner::default());
        // Batcher thread: coalesce, then fan batches to workers round-robin.
        let mut worker_txs = Vec::new();
        let mut handles = Vec::new();
        for wi in 0..cfg.workers.max(1) {
            let (wtx, wrx) = sync_channel::<Vec<Request>>(8);
            worker_txs.push(wtx);
            let engine = engine.clone();
            let stats = stats.clone();
            handles.push(std::thread::spawn(move || worker_loop(engine, wrx, stats, wi)));
        }
        let in_features = engine.in_features();
        let stats2 = stats.clone();
        let max_batch = cfg.max_batch.max(1);
        let timeout = cfg.batch_timeout;
        handles.push(std::thread::spawn(move || {
            batcher_loop(rx, worker_txs, max_batch, timeout, stats2)
        }));
        Server { tx, stats, handles, in_features }
    }

    /// Blocking single inference through the full router path.
    pub fn infer(&self, x: Vec<f32>) -> Option<usize> {
        if x.len() != self.in_features {
            // Malformed request: never let it scramble a packed batch.
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let (rtx, rrx) = sync_channel(1);
        let req = Request { x, enqueued: Instant::now(), resp: rtx };
        if self.tx.try_send(req).is_err() {
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        rrx.recv().ok()
    }

    pub fn stats(&self) -> ServerStats {
        let mut lats = self.stats.lat.snapshot();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| percentile(&lats, p);
        let batches = self.stats.batches.load(Ordering::Relaxed);
        let fill = self.stats.batch_fill.load(Ordering::Relaxed);
        ServerStats {
            completed: self.stats.completed.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 { 0.0 } else { fill as f64 / batches as f64 },
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            rejected: self.stats.rejected.load(Ordering::Relaxed),
        }
    }

    /// Shut down: drop the ingress, join all threads.
    pub fn shutdown(self) {
        drop(self.tx);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    worker_txs: Vec<SyncSender<Vec<Request>>>,
    max_batch: usize,
    timeout: Duration,
    stats: Arc<StatsInner>,
) {
    let mut next_worker = 0usize;
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + timeout;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.batch_fill.fetch_add(batch.len() as u64, Ordering::Relaxed);
        // Round-robin dispatch; if a worker queue is full, rotate.
        let mut sent = false;
        for k in 0..worker_txs.len() {
            let w = (next_worker + k) % worker_txs.len();
            match worker_txs[w].try_send(batch) {
                Ok(()) => {
                    next_worker = (w + 1) % worker_txs.len();
                    sent = true;
                    batch = Vec::new();
                    break;
                }
                Err(std::sync::mpsc::TrySendError::Full(b)) => batch = b,
                Err(std::sync::mpsc::TrySendError::Disconnected(b)) => batch = b,
            }
        }
        if !sent {
            // All queues full: apply backpressure by blocking on one.
            let _ = worker_txs[next_worker].send(batch);
            next_worker = (next_worker + 1) % worker_txs.len();
        }
    }
}

fn worker_loop(
    engine: Arc<dyn Backend>,
    rx: Receiver<Vec<Request>>,
    stats: Arc<StatsInner>,
    worker: usize,
) {
    // Private sampling stream per worker: Algorithm R needs an RNG on every
    // post-fill offer, and sharing one behind a lock would serialize the
    // hot path.
    let mut rng = Rng::new(0x5EED_0A11 ^ (worker as u64).wrapping_mul(0x9E3779B97F4A7C15));
    // One reusable pack buffer per worker: requests are copied into a
    // contiguous [batch, d] matrix so the backend sees a single batch call.
    let mut xs: Vec<f32> = Vec::new();
    while let Ok(batch) = rx.recv() {
        xs.clear();
        for req in &batch {
            xs.extend_from_slice(&req.x);
        }
        let preds = engine.infer_batch(&xs);
        debug_assert_eq!(preds.len(), batch.len());
        for (req, class) in batch.into_iter().zip(preds) {
            let lat = req.enqueued.elapsed().as_secs_f64() * 1e6;
            stats.lat.offer(lat, &mut rng);
            stats.completed.fetch_add(1, Ordering::Relaxed);
            let _ = req.resp.send(class);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::ModelTables;
    use crate::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
    use crate::serve::engine::{LutEngine, NetlistEngine};
    use crate::util::rng::Rng;

    fn model_and_tables() -> (ExportedModel, ModelTables) {
        let mut rng = Rng::new(3);
        let neurons = (0..8)
            .map(|_| {
                let inputs = rng.choose_k(6, 3);
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                    bias: 0.0,
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        let model = ExportedModel {
            layers: vec![ExportedLayer::uniform(neurons, 6, QuantSpec::new(2, 1.0), QuantSpec::new(2, 2.0), true)],
            in_features: 6,
            classes: 8,
            skips: 0,
            act_widths: vec![6],
        };
        let tables = ModelTables::generate(&model).unwrap();
        (model, tables)
    }

    fn engine() -> Arc<LutEngine> {
        let (model, tables) = model_and_tables();
        Arc::new(LutEngine::build(&model, &tables).unwrap())
    }

    #[test]
    fn server_roundtrip_and_stats() {
        let eng = engine();
        let server = Server::start(
            eng.clone(),
            ServerConfig { workers: 2, max_batch: 8, ..Default::default() },
        );
        let mut rng = Rng::new(11);
        for _ in 0..100 {
            let x: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
            let direct = eng.infer_batch(&x)[0];
            let via_server = server.infer(x).expect("server response");
            assert_eq!(direct, via_server);
        }
        let stats = server.stats();
        assert_eq!(stats.completed, 100);
        assert!(stats.batches >= 1);
        assert!(stats.p50_us >= 0.0 && stats.p99_us >= stats.p50_us);
        server.shutdown();
    }

    #[test]
    fn netlist_backend_serves_identically() {
        // Backend selection: the same router must serve straight from the
        // synthesized netlist and agree with the table engine per request.
        let (model, tables) = model_and_tables();
        let lut = LutEngine::build(&model, &tables).unwrap();
        let net = Arc::new(NetlistEngine::build(&model, &tables).unwrap());
        let server = Server::start(
            net,
            ServerConfig { workers: 2, max_batch: 8, ..Default::default() },
        );
        let mut rng = Rng::new(21);
        for _ in 0..100 {
            let x: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
            let direct = lut.infer_batch(&x)[0];
            assert_eq!(server.infer(x).expect("server response"), direct);
        }
        server.shutdown();
    }

    #[test]
    fn reservoir_keeps_sampling_past_capacity() {
        // Regression: the old buffer froze once full; Algorithm R must keep
        // admitting late samples and stay a uniform sample of the stream.
        let r = Reservoir::new(50);
        let mut rng = Rng::new(9);
        let n = 5_000usize;
        for i in 0..n {
            r.offer(i as f64, &mut rng);
        }
        assert_eq!(r.seen(), n as u64);
        let s = r.snapshot();
        assert_eq!(s.len(), 50, "reservoir must stay at capacity");
        assert!(
            s.iter().any(|&v| v >= (n / 2) as f64),
            "late samples must be admitted (old bug: only the first 50 survive)"
        );
        // Uniformity sanity: the sample mean tracks the stream mean.
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        let stream_mean = (n - 1) as f64 / 2.0;
        assert!(
            (mean - stream_mean).abs() < stream_mean * 0.4,
            "mean {mean} vs stream {stream_mean}"
        );
    }

    #[test]
    fn percentiles_interpolate_between_ranks() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        let two = [0.0, 10.0];
        assert!((percentile(&two, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&two, 0.95) - 9.5).abs() < 1e-12);
        // The old truncating nearest-rank collapsed p99 onto p50 here.
        assert!(percentile(&two, 0.99) > percentile(&two, 0.5));
        let many: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert!((percentile(&many, 0.95) - 95.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_malformed_width() {
        let server = Server::start(engine(), ServerConfig::default());
        assert!(server.infer(vec![0.0; 3]).is_none());
        assert_eq!(server.stats().rejected, 1);
        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let eng = engine();
        let server = Arc::new(Server::start(
            eng,
            ServerConfig { workers: 4, max_batch: 16, ..Default::default() },
        ));
        std::thread::scope(|s| {
            for t in 0..8 {
                let server = server.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(100 + t);
                    for _ in 0..200 {
                        let x: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
                        assert!(server.infer(x).is_some());
                    }
                });
            }
        });
        let stats = server.stats();
        assert_eq!(stats.completed, 1600);
    }
}
