//! Model zoo: the DSE→serving handoff.
//!
//! `dse::search::run_search` with `emit_zoo` writes a `zoo.json` manifest
//! next to its archive: one entry per emitted frontier netlist, carrying
//! everything needed to rebuild the servable engine (topology axes +
//! checkpoint path) plus the calibrated routing metadata (mapped LUTs,
//! BRAMs, measured p50/p99 request latency, quality).  The emitted set is
//! the true multi-objective frontier: every entry is non-dominated under
//! the 3-D (LUTs ↓, quality ↑, latency ↓) check (`dse::pareto_frontier_3d`).
//!
//! This module loads such a manifest back into a running
//! [`ZooServer`](crate::serve::router::ZooServer): each entry's checkpoint
//! is re-exported, synthesized with the full optimization pipeline,
//! machine-verified against its truth tables, and registered behind its
//! own worker pool — so `logicnets serve --zoo reports/dse/zoo.json` turns
//! a finished search directly into budget-aware serving.

use crate::dse::ZooPoint;
use crate::luts::ModelTables;
use crate::nn::ExportedModel;
use crate::runtime::Manifest;
use crate::serve::engine::{Backend, NetlistEngine};
use crate::serve::router::{percentile, ModelMeta, ServerConfig, ZooServer};
use crate::synth::{
    lint_netlist, synthesize, verify_netlist, LintOptions, Netlist, OptLevel, SynthOpts,
};
use crate::train::checkpoint;
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Single-request inferences per model during latency calibration —
/// enough for a stable p99 at a few tens of µs per call.
pub const CALIBRATION_ITERS: usize = 256;

/// One registered model in the zoo manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ZooEntry {
    pub name: String,
    pub dataset: String,
    pub in_features: usize,
    pub classes: usize,
    /// Topology axes, enough to rebuild the `Manifest`
    /// (`Manifest::synthetic_topology`, or
    /// `Manifest::synthetic_conv_for_task` for conv entries): per-layer
    /// hidden widths (pyramid schedules included), fan-in, activation
    /// bits, and the newest-first skip-concat count.
    pub hidden: Vec<usize>,
    pub fanin: usize,
    pub bw: usize,
    /// Skip-connection count (manifests written before this axis existed
    /// load as 0).
    pub skips: usize,
    /// Conv front-end axes (`None` = pure MLP).  Present together or not
    /// at all; manifests written before the conv axes existed load as
    /// `None` and rebuild through the MLP path unchanged.
    pub conv_mode: Option<String>,
    pub conv_channels: Option<usize>,
    pub conv_kernel: Option<usize>,
    /// Trained-state checkpoint, relative to the manifest's directory.
    pub checkpoint: String,
    /// Mapped (synthesized, `OptLevel::Full`) LUT count — the routing
    /// cost axis.
    pub luts: u64,
    /// BRAM blocks at the candidate's deployment threshold (the serving
    /// netlist itself is BRAM-free).
    pub brams: usize,
    /// 100 × avg AUC at the deepest completed rung.
    pub quality: f64,
    /// Netlist-backed accuracy on the search's test split.
    pub netlist_accuracy: f64,
    /// Calibrated single-request latency percentiles (µs) through
    /// `NetlistEngine`.
    pub p50_us: f64,
    pub p99_us: f64,
}

impl ZooEntry {
    /// Routing metadata for the budget router.
    pub fn meta(&self) -> ModelMeta {
        ModelMeta {
            name: self.name.clone(),
            luts: self.luts,
            brams: self.brams,
            quality: self.quality,
            p50_us: self.p50_us,
            p99_us: self.p99_us,
        }
    }

    /// This entry as a 3-D frontier point (p99 is the latency axis).
    pub fn point(&self) -> ZooPoint {
        ZooPoint {
            name: self.name.clone(),
            luts: self.luts,
            quality: self.quality,
            latency_us: self.p99_us,
        }
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("dataset", Json::str(&self.dataset)),
            ("in_features", Json::num(self.in_features as f64)),
            ("classes", Json::num(self.classes as f64)),
            (
                "hidden",
                Json::Arr(self.hidden.iter().map(|&h| Json::Num(h as f64)).collect()),
            ),
            ("fanin", Json::num(self.fanin as f64)),
            ("bw", Json::num(self.bw as f64)),
            ("skips", Json::num(self.skips as f64)),
            ("checkpoint", Json::str(&self.checkpoint)),
            // String like the DSE archive's u64s: f64 JSON numbers round
            // above 2^53.
            ("luts", Json::str(&self.luts.to_string())),
            ("brams", Json::num(self.brams as f64)),
            ("quality", Json::num(self.quality)),
            ("netlist_accuracy", Json::num(self.netlist_accuracy)),
            ("p50_us", Json::num(self.p50_us)),
            ("p99_us", Json::num(self.p99_us)),
        ];
        // Conv keys only for conv entries, so MLP manifests stay
        // byte-compatible with pre-conv readers.
        if let (Some(m), Some(cc), Some(ck)) =
            (&self.conv_mode, self.conv_channels, self.conv_kernel)
        {
            fields.push(("conv_mode", Json::str(m)));
            fields.push(("conv_channels", Json::num(cc as f64)));
            fields.push(("conv_kernel", Json::num(ck as f64)));
        }
        Json::obj(fields)
    }

    fn from_json(j: &Json) -> Result<ZooEntry> {
        // Strict like every other field: a malformed hidden list must fail
        // here with a manifest error, not later as a checkpoint/manifest
        // shape mismatch.
        let arr = j
            .req("hidden")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("zoo entry hidden: not an array"))?;
        let mut hidden = Vec::with_capacity(arr.len());
        for v in arr {
            hidden.push(
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("zoo entry hidden: non-integer element"))?,
            );
        }
        Ok(ZooEntry {
            name: j.req_str("name")?.to_string(),
            dataset: j.req_str("dataset")?.to_string(),
            in_features: j.req_usize("in_features")?,
            classes: j.req_usize("classes")?,
            hidden,
            fanin: j.req_usize("fanin")?,
            bw: j.req_usize("bw")?,
            skips: j.opt_usize("skips").unwrap_or(0),
            // Absent for MLP entries and in pre-conv manifests.
            conv_mode: j.get("conv_mode").and_then(|v| v.as_str()).map(str::to_string),
            conv_channels: j.opt_usize("conv_channels"),
            conv_kernel: j.opt_usize("conv_kernel"),
            checkpoint: j.req_str("checkpoint")?.to_string(),
            luts: j
                .req_str("luts")?
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("zoo entry luts: {e}"))?,
            brams: j.req_usize("brams")?,
            quality: j.req_f64("quality")?,
            netlist_accuracy: j.req_f64("netlist_accuracy")?,
            p50_us: j.req_f64("p50_us")?,
            p99_us: j.req_f64("p99_us")?,
        })
    }
}

/// The on-disk zoo manifest (`zoo.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct ZooManifest {
    pub dataset: String,
    pub entries: Vec<ZooEntry>,
}

impl ZooManifest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("dataset", Json::str(&self.dataset)),
            ("entries", Json::Arr(self.entries.iter().map(|e| e.to_json()).collect())),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("write {}", path.display()))?;
        Ok(())
    }

    /// Load and validate: calibrated latencies must be finite, positive
    /// measurements (a pre-traffic 0.0 or a NaN would corrupt every
    /// routing decision), quality finite.
    pub fn load(path: &Path) -> Result<ZooManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let version = j.req_usize("version")?;
        ensure!(version == 1, "zoo manifest version {version} != 1");
        let mut entries = Vec::new();
        for e in j.req("entries")?.as_arr().unwrap_or(&[]) {
            let entry = ZooEntry::from_json(e)?;
            ensure!(
                entry.quality.is_finite(),
                "zoo entry {} has non-finite quality",
                entry.name
            );
            ensure!(
                entry.p50_us.is_finite()
                    && entry.p99_us.is_finite()
                    && entry.p50_us > 0.0
                    && entry.p99_us > 0.0,
                "zoo entry {} has uncalibrated latency (p50 {}, p99 {})",
                entry.name,
                entry.p50_us,
                entry.p99_us
            );
            entries.push(entry);
        }
        Ok(ZooManifest {
            dataset: j.req_str("dataset")?.to_string(),
            entries,
        })
    }

    /// All entries as 3-D frontier points.
    pub fn points(&self) -> Vec<ZooPoint> {
        self.entries.iter().map(|e| e.point()).collect()
    }
}

/// Rebuild one zoo entry's circuit: checkpoint → export → truth tables →
/// `synthesize` (`OptLevel::Full`, BRAM-free).  `zoo_dir` is the directory
/// the manifest lives in (checkpoint paths are relative to it).  Split out
/// of [`build_engine`] so diagnostics (the `lint` CLI) can inspect the
/// exact netlist serving would load without constructing an engine.
pub fn rebuild_netlist(
    entry: &ZooEntry,
    zoo_dir: &Path,
) -> Result<(ExportedModel, ModelTables, Netlist)> {
    // Conv entries rebuild through the same constructor the DSE candidate
    // used (`Manifest::synthetic_conv_for_task`), so the served circuit is
    // bit-exactly the searched one.
    let man = match (&entry.conv_mode, entry.conv_channels, entry.conv_kernel) {
        (Some(mode), Some(channels), Some(kernel)) => Manifest::synthetic_conv_for_task(
            &entry.name,
            &entry.dataset,
            entry.in_features,
            entry.classes,
            &entry.hidden,
            entry.fanin,
            entry.bw,
            mode,
            channels,
            kernel,
        )
        .with_context(|| format!("zoo model {}: conv manifest", entry.name))?,
        (None, None, None) => Manifest::synthetic_topology(
            &entry.name,
            &entry.dataset,
            entry.in_features,
            entry.classes,
            &entry.hidden,
            entry.fanin,
            entry.bw,
            entry.skips,
        ),
        _ => bail!(
            "zoo model {}: conv fields must be present together or not at all \
             (conv_mode {:?}, conv_channels {:?}, conv_kernel {:?})",
            entry.name,
            entry.conv_mode,
            entry.conv_channels,
            entry.conv_kernel
        ),
    };
    let ck = zoo_dir.join(&entry.checkpoint);
    let state = checkpoint::load(&ck)
        .with_context(|| format!("zoo model {}: checkpoint {}", entry.name, ck.display()))?;
    ensure!(
        state.num_layers() == man.num_layers(),
        "zoo model {}: checkpoint/manifest shape mismatch",
        entry.name
    );
    let ex = ExportedModel::from_state(&man, &state);
    // Conv entries prove the receptive-field contract before synthesis:
    // a checkpoint whose masks drifted from the shared per-channel
    // windows must fail here with pixel coordinates, not serve silently.
    let conv_report = crate::synth::lint_conv_model(&man, &ex)?;
    ensure!(
        conv_report.is_clean(),
        "zoo model {}: checkpoint violates the conv receptive-field contract:\n{}",
        entry.name,
        conv_report.render()
    );
    let tables = ModelTables::generate(&ex)?;
    let (netlist, _) = synthesize(
        &ex,
        &tables,
        SynthOpts { registers: false, bram_min_bits: 0, opt: OptLevel::Full, ..SynthOpts::default() },
    )?;
    Ok((ex, tables, netlist))
}

/// Rebuild the servable engine for one zoo entry: [`rebuild_netlist`] →
/// machine-verify (functional) → design-rule lint (structural, deny-warn:
/// a `Full`-optimized serving netlist must be completely clean) →
/// [`NetlistEngine`].
pub fn build_engine(entry: &ZooEntry, zoo_dir: &Path) -> Result<NetlistEngine> {
    let (ex, tables, netlist) = rebuild_netlist(entry, zoo_dir)?;
    let mism = verify_netlist(&ex, &tables, &netlist, 1024, 0x500)?;
    ensure!(mism == 0, "zoo model {}: {mism} netlist/table mismatches", entry.name);
    let report = lint_netlist(&netlist, &LintOptions { opt: OptLevel::Full });
    ensure!(
        report.is_clean(),
        "zoo model {}: serving netlist fails design-rule lint:\n{}",
        entry.name,
        report.render()
    );
    NetlistEngine::from_netlist(&ex, &tables, netlist)
}

/// Measure single-request serving latency percentiles of a backend:
/// `iters` one-sample `infer_batch` calls (the router's per-request
/// shape), cycling through the rows of `xs`, each wall-clocked.  Returns
/// `(p50_us, p99_us)`.  A short warm-up is excluded so cold caches don't
/// land in the percentiles.
pub fn calibrate_latency<B: Backend + ?Sized>(
    engine: &B,
    xs: &[f32],
    iters: usize,
) -> (f64, f64) {
    let d = engine.in_features();
    assert!(d > 0 && xs.len() >= d, "need at least one calibration row");
    assert!(iters > 0, "need at least one calibration iteration");
    let n = xs.len() / d;
    for i in 0..8usize.min(iters) {
        let row = &xs[(i % n) * d..(i % n) * d + d];
        std::hint::black_box(engine.infer_batch(row));
    }
    let mut lats = Vec::with_capacity(iters);
    for i in 0..iters {
        let row = &xs[(i % n) * d..(i % n) * d + d];
        let t0 = std::time::Instant::now();
        std::hint::black_box(engine.infer_batch(row));
        lats.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    lats.sort_by(f64::total_cmp);
    // `lats` is non-empty by the iters assert, so the percentiles exist.
    (
        percentile(&lats, 0.50).expect("non-empty"),
        percentile(&lats, 0.99).expect("non-empty"),
    )
}

/// Start the budget-routed multi-model server from an already-loaded
/// manifest whose checkpoint paths are relative to `dir`: one verified
/// `NetlistEngine` + worker pool per entry.
pub fn serve_manifest(zoo: &ZooManifest, dir: &Path, cfg: &ServerConfig) -> Result<ZooServer> {
    ensure!(!zoo.entries.is_empty(), "zoo manifest has no entries");
    let mut models: Vec<(ModelMeta, Arc<dyn Backend>)> = Vec::with_capacity(zoo.entries.len());
    for e in &zoo.entries {
        let engine = build_engine(e, dir)?;
        models.push((e.meta(), Arc::new(engine) as Arc<dyn Backend>));
    }
    ZooServer::start(models, cfg)
}

/// [`serve_manifest`] straight from a `zoo.json` path.
pub fn serve_zoo(path: &Path, cfg: &ServerConfig) -> Result<ZooServer> {
    let zoo = ZooManifest::load(path)?;
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty()).unwrap_or(Path::new("."));
    serve_manifest(&zoo, dir, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(name: &str, luts: u64, quality: f64, p99: f64) -> ZooEntry {
        ZooEntry {
            name: name.into(),
            dataset: "jets".into(),
            in_features: 16,
            classes: 5,
            hidden: vec![16, 16],
            fanin: 3,
            bw: 2,
            skips: 0,
            conv_mode: None,
            conv_channels: None,
            conv_kernel: None,
            checkpoint: format!("ckpt/{name}.r2.bin"),
            luts,
            brams: 0,
            quality,
            netlist_accuracy: 0.6,
            p50_us: p99 / 2.0,
            p99_us: p99,
        }
    }

    #[test]
    fn manifest_roundtrips_through_json() {
        let mut zoo = ZooManifest {
            dataset: "jets".into(),
            entries: vec![entry("a", 120, 61.5, 40.0), entry("b", u64::MAX - 1, 90.0, 250.0)],
        };
        // Skip/pyramid topology axes must survive the round trip.
        zoo.entries[1].skips = 1;
        zoo.entries[1].hidden = vec![32, 16];
        let dir = std::env::temp_dir().join("lnck_zoo_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("zoo.json");
        zoo.save(&path).unwrap();
        let back = ZooManifest::load(&path).unwrap();
        assert_eq!(back, zoo);
        // u64 LUT counts survive beyond f64 precision (string-encoded).
        assert_eq!(back.entries[1].luts, u64::MAX - 1);
        assert_eq!(back.entries[1].skips, 1);
        // MLP entries carry no conv keys at all, so pre-conv readers see
        // byte-identical records...
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.contains("conv_mode"), "MLP entries must stay conv-key-free");
        // ...and a manifest written before the skip axis existed (no
        // "skips" field) loads as skip-free.
        let legacy = text.replace(",\"skips\":1", "").replace(",\"skips\":0", "");
        assert!(!legacy.contains("skips"), "field must be stripped: {legacy}");
        let lpath = dir.join("zoo_legacy.json");
        std::fs::write(&lpath, legacy).unwrap();
        let old = ZooManifest::load(&lpath).unwrap();
        assert!(old.entries.iter().all(|e| e.skips == 0));
        assert!(old.entries.iter().all(|e| e.conv_mode.is_none()));
    }

    #[test]
    fn conv_entries_roundtrip_and_partial_fields_refuse_rebuild() {
        let mut zoo =
            ZooManifest { dataset: "jets".into(), entries: vec![entry("cv", 200, 70.0, 55.0)] };
        zoo.entries[0].conv_mode = Some("dense".into());
        zoo.entries[0].conv_channels = Some(4);
        zoo.entries[0].conv_kernel = Some(3);
        zoo.entries[0].skips = 0;
        let dir = std::env::temp_dir().join("lnck_zoo_conv_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("zoo.json");
        zoo.save(&path).unwrap();
        let back = ZooManifest::load(&path).unwrap();
        assert_eq!(back, zoo);
        assert_eq!(back.entries[0].conv_mode.as_deref(), Some("dense"));
        assert_eq!(back.entries[0].conv_channels, Some(4));
        assert_eq!(back.entries[0].conv_kernel, Some(3));
        // An entry with only some conv fields is corrupt: rebuilding must
        // refuse it with a message naming the fields, never guess.
        let mut partial = back.entries[0].clone();
        partial.conv_kernel = None;
        let err = rebuild_netlist(&partial, &dir).unwrap_err();
        assert!(
            format!("{err:#}").contains("conv fields"),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn load_rejects_uncalibrated_or_nan_entries() {
        let dir = std::env::temp_dir().join("lnck_zoo_reject_test");
        std::fs::create_dir_all(&dir).unwrap();
        // p99 == 0.0 is the empty-reservoir sentinel the percentile fix
        // exists to keep out of manifests; loading must refuse it.
        let mut zoo = ZooManifest { dataset: "jets".into(), entries: vec![entry("z", 10, 50.0, 40.0)] };
        zoo.entries[0].p99_us = 0.0;
        let path = dir.join("zoo_zero.json");
        zoo.save(&path).unwrap();
        assert!(ZooManifest::load(&path).is_err());
        // NaN quality likewise.
        let mut zoo = ZooManifest { dataset: "jets".into(), entries: vec![entry("n", 10, 50.0, 40.0)] };
        zoo.entries[0].quality = f64::NAN;
        let path = dir.join("zoo_nan.json");
        zoo.save(&path).unwrap();
        assert!(ZooManifest::load(&path).is_err());
    }
}
