//! The two serving backends behind the router, plus their shared pieces.
//!
//! [`LutEngine`] is flattened, allocation-free inference over truth tables;
//! [`NetlistEngine`] serves the *synthesized LUT netlist* itself through
//! the wide-plane bitsliced simulator (`crate::sim::plan`, 256 samples per
//! chunk) with the code-decode + dequant + dense-head pass fused into the
//! chunk sweep (DESIGN.md §11).  Both implement [`Backend`], so
//! `serve::router::Server` can batch over either.
//!
//! Layout decisions (this is the measured hot path of `bench_serve`):
//! * per layer, all neuron fan-in indices live in one contiguous `Vec<u32>`
//!   with offsets, and all tables in one contiguous `Vec<u8>` (codes are at
//!   most 8 bits in any paper configuration);
//! * activations stay in the *code* domain end to end; only the dense head
//!   dequantizes, through a per-layer precomputed code->value table;
//! * scratch buffers are reused across samples via `InferScratch`.

use crate::luts::ModelTables;
use crate::nn::{ExportedLayer, ExportedModel, QuantSpec};
use crate::sim::{BitMatrix, Chunk, EvalPlan, LANES};
use crate::synth::{synthesize, Netlist, OptLevel, SynthOpts};
use anyhow::{ensure, Result};
use std::sync::Mutex;

/// Samples per evaluation chunk of the wide simulator.
const CHUNK_SAMPLES: usize = 64 * LANES;

enum Stage {
    /// Table-mapped sparse layer.
    Lut {
        /// Neuron j's fan-in indices: idx[off[j]..off[j+1]].
        idx: Vec<u32>,
        off: Vec<u32>,
        /// Neuron j's table: tab[tab_off[j] + packed_code].
        tab: Vec<u8>,
        tab_off: Vec<u32>,
        bw_in: usize,
        num_out: usize,
    },
    /// Arithmetic (dense classifier head) layer.
    Dense(DenseStage),
}

/// A folded arithmetic layer in code domain, shared by both backends:
/// `LutEngine` uses it for un-tabulated layers, `NetlistEngine` for the
/// dense tail after the synthesized netlist — one implementation means the
/// two backends are bit-identical on the arithmetic path.
struct DenseStage {
    /// Row-major [out, in] folded weights (g pre-multiplied).
    w: Vec<f32>,
    /// Folded bias per neuron: g*b + h.
    b: Vec<f32>,
    in_f: usize,
    num_out: usize,
    /// Dequant value per (element, code): dequant[e*ncodes + c].  Skip
    /// wiring makes the scale per-element.
    dequant: Vec<f32>,
    ncodes: usize,
    quant_out: QuantSpec,
}

impl DenseStage {
    fn build(layer: &ExportedLayer) -> DenseStage {
        let in_f = layer.in_f;
        let num_out = layer.neurons.len();
        let mut w = vec![0f32; num_out * in_f];
        let mut b = vec![0f32; num_out];
        for (o, nr) in layer.neurons.iter().enumerate() {
            for (wt, &j) in nr.weights.iter().zip(&nr.inputs) {
                w[o * in_f + j] = nr.g * wt;
            }
            b[o] = nr.g * nr.bias + nr.h;
        }
        let ncodes = layer.quant_in.num_codes();
        let mut dequant = vec![0f32; in_f * ncodes];
        for (e, spec) in layer.input_specs.iter().enumerate() {
            for c in 0..ncodes as u32 {
                dequant[e * ncodes + c as usize] = spec.dequant(c);
            }
        }
        DenseStage { w, b, in_f, num_out, dequant, ncodes, quant_out: layer.quant_out }
    }

    /// One sample: input codes -> appended output codes (+ raw logits into
    /// the caller's reusable buffer).
    fn eval(&self, input: &[u8], out: &mut Vec<u8>, logits: &mut Vec<f32>) {
        logits.clear();
        for o in 0..self.num_out {
            let row = &self.w[o * self.in_f..(o + 1) * self.in_f];
            let mut z = self.b[o];
            for (e, (wt, &c)) in row.iter().zip(input.iter()).enumerate() {
                z += wt * self.dequant[e * self.ncodes + c as usize];
            }
            logits.push(z);
            out.push(self.quant_out.code(z) as u8);
        }
    }
}

pub struct LutEngine {
    stages: Vec<Stage>,
    in_quant: QuantSpec,
    pub in_features: usize,
    pub classes: usize,
    skips: usize,
}

/// Reusable per-thread scratch to keep the hot loop allocation-free.
/// `acts[i]` holds stage i's input activation codes (acts[0] = quantized
/// model input); `out` holds the final stage's codes.
#[derive(Default)]
pub struct InferScratch {
    acts: Vec<Vec<u8>>,
    input: Vec<u8>,
    out: Vec<u8>,
    logits: Vec<f32>,
}

impl LutEngine {
    pub fn build(model: &ExportedModel, tables: &ModelTables) -> Result<LutEngine> {
        let mut stages = Vec::with_capacity(model.num_layers());
        for (li, layer) in model.layers.iter().enumerate() {
            match &tables.layers[li] {
                Some(lt) => {
                    ensure!(lt.quant_out.bw <= 8, "engine supports <=8-bit codes");
                    let mut idx = Vec::new();
                    let mut off = vec![0u32];
                    let mut tab = Vec::new();
                    let mut tab_off = vec![0u32];
                    for (nj, t) in lt.tables.iter().enumerate() {
                        let nr = &layer.neurons[nj];
                        idx.extend(nr.inputs.iter().map(|&i| i as u32));
                        off.push(idx.len() as u32);
                        for e in 0..t.num_entries() {
                            tab.push(t.lookup(e) as u8);
                        }
                        tab_off.push(tab.len() as u32);
                    }
                    stages.push(Stage::Lut {
                        idx,
                        off,
                        tab,
                        tab_off,
                        bw_in: lt.quant_in.bw,
                        num_out: lt.tables.len(),
                    });
                }
                None => stages.push(Stage::Dense(DenseStage::build(layer))),
            }
        }
        Ok(LutEngine {
            stages,
            in_quant: model.layers[0].quant_in,
            in_features: model.in_features,
            classes: model.classes,
            skips: model.skips,
        })
    }

    /// Classify one sample; returns the argmax class.  All buffers live in
    /// `scratch` and are reused across calls — the loop is allocation-free
    /// after the first inference (§Perf, EXPERIMENTS.md).
    pub fn infer(&self, x: &[f32], scratch: &mut InferScratch) -> usize {
        debug_assert_eq!(x.len(), self.in_features);
        let n = self.stages.len();
        if scratch.acts.len() < n {
            scratch.acts.resize_with(n, Vec::new);
        }
        {
            let a = &mut scratch.acts[0];
            a.clear();
            a.extend(x.iter().map(|&v| self.in_quant.code(v) as u8));
        }
        for i in 0..n {
            let stage = &self.stages[i];
            // Skip wiring: newest-first concat of the last skips+1 acts.
            scratch.input.clear();
            if i == 0 || self.skips == 0 {
                scratch.input.extend_from_slice(&scratch.acts[i]);
            } else {
                let lo = i.saturating_sub(self.skips);
                for j in (lo..=i).rev() {
                    scratch.input.extend_from_slice(&scratch.acts[j]);
                }
            }
            let input = &scratch.input;
            // Output buffer: next stage's act slot, or the final `out`.
            let mut out = if i + 1 == n {
                std::mem::take(&mut scratch.out)
            } else {
                std::mem::take(&mut scratch.acts[i + 1])
            };
            out.clear();
            match stage {
                Stage::Lut { idx, off, tab, tab_off, bw_in, num_out } => {
                    out.reserve(*num_out);
                    for j in 0..*num_out {
                        let (s, e) = (off[j] as usize, off[j + 1] as usize);
                        let mut packed = 0usize;
                        let mut shift = 0;
                        for &inp in &idx[s..e] {
                            packed |= (input[inp as usize] as usize) << shift;
                            shift += bw_in;
                        }
                        out.push(tab[tab_off[j] as usize + packed]);
                    }
                }
                Stage::Dense(dense) => dense.eval(input, &mut out, &mut scratch.logits),
            }
            if i + 1 == n {
                scratch.out = out;
            } else {
                scratch.acts[i + 1] = out;
            }
        }
        // argmax over final codes (monotone in logits).
        scratch
            .out
            .iter()
            .enumerate()
            .max_by_key(|&(_, &c)| c)
            .map(|(k, _)| k)
            .unwrap_or(0)
    }

    /// Batch classify; returns predicted classes.
    pub fn infer_batch(&self, xs: &[f32]) -> Vec<usize> {
        let d = self.in_features;
        let mut scratch = InferScratch::default();
        xs.chunks(d).map(|row| self.infer(row, &mut scratch)).collect()
    }

    /// Multi-core batch classify.  The output vector is split into disjoint
    /// per-worker `&mut` slices up front, so every worker writes results in
    /// place — no mutex, no per-chunk gather copy (one scratch per worker).
    pub fn infer_batch_par(&self, xs: &[f32]) -> Vec<usize> {
        let d = self.in_features;
        assert_eq!(xs.len() % d, 0);
        let n = xs.len() / d;
        let mut out = vec![0usize; n];
        crate::util::pool::par_chunks_mut(&mut out, |_, start, chunk| {
            let mut scratch = InferScratch::default();
            for (k, slot) in chunk.iter_mut().enumerate() {
                let i = start + k;
                *slot = self.infer(&xs[i * d..(i + 1) * d], &mut scratch);
            }
        });
        out
    }

    /// Final-layer quantized codes for one sample (verification hook).
    pub fn infer_codes(&self, x: &[f32]) -> Vec<u8> {
        let mut scratch = InferScratch::default();
        self.infer(x, &mut scratch);
        scratch.out
    }
}

/// Common surface of the serving backends: classify a contiguous batch of
/// rows into argmax classes.  `serve::router::Server` is generic over this,
/// so the truth-table engine and the synthesized-netlist engine are
/// selectable behind the same batching router.
pub trait Backend: Send + Sync + 'static {
    fn in_features(&self) -> usize;
    fn classes(&self) -> usize;
    fn infer_batch(&self, xs: &[f32]) -> Vec<usize>;
    fn name(&self) -> &'static str;
}

impl Backend for LutEngine {
    fn in_features(&self) -> usize {
        self.in_features
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn infer_batch(&self, xs: &[f32]) -> Vec<usize> {
        LutEngine::infer_batch(self, xs)
    }

    fn name(&self) -> &'static str {
        "tables"
    }
}

/// Classification accuracy of any serving backend on a labeled test set —
/// the batch scoring hook the MNIST/HEP flows use to score a mapped
/// netlist (or the table engine) on a full test set.
pub fn batch_accuracy<B: Backend + ?Sized>(backend: &B, xs: &[f32], ys: &[i32]) -> f64 {
    let preds = backend.infer_batch(xs);
    assert_eq!(preds.len(), ys.len(), "sample/label count mismatch");
    if ys.is_empty() {
        return 0.0;
    }
    let hits = preds.iter().zip(ys).filter(|(p, y)| **p == **y as usize).count();
    hits as f64 / ys.len() as f64
}

/// Serving backend that executes the *synthesized LUT netlist* itself:
/// quantize → encode input bit-planes → fused chunk sweep (one
/// 256-sample-wide netlist pass per chunk, with code decode + dequant +
/// dense head + argmax run on each chunk's outputs while they are still in
/// cache) → predicted classes.  This is the software model of serving
/// straight from the mapped circuit, and a third functional-verification
/// surface: its predictions must match `LutEngine` exactly (and the
/// unfused 64-way oracle path, [`NetlistEngine::infer_batch_unfused`]).
pub struct NetlistEngine {
    netlist: Netlist,
    /// Level-ordered arena schedule of `netlist`, compiled once at build.
    plan: EvalPlan,
    /// Pool of reusable fused-pass scratch sets (`infer_batch` takes
    /// `&self`, so concurrent callers each pop their own set; steady-state
    /// serving allocates nothing per batch).
    scratch: Mutex<Vec<FusedScratch>>,
    /// Arithmetic layers after the synthesized prefix (classifier head).
    dense_tail: Vec<DenseStage>,
    in_quant: QuantSpec,
    pub in_features: usize,
    pub classes: usize,
    /// Bits per input feature code.
    bw_in: usize,
    /// Bits per netlist output code (last sparse layer's quant_out).
    out_bw: usize,
    /// Netlist output neurons (= output planes / out_bw).
    net_outs: usize,
}

/// All mutable state of one fused `infer_batch` call: the quantized input
/// planes plus per-worker buffers, reused across batches via the engine's
/// scratch pool.
#[derive(Default)]
struct FusedScratch {
    inputs: BitMatrix,
    workers: Vec<FusedWorker>,
}

/// Per-worker fused-pass buffers: the wide value array for one chunk and
/// the dense-tail ping/pong code + logit vectors.
#[derive(Default)]
struct FusedWorker {
    vals: Vec<Chunk>,
    codes: Vec<u8>,
    next: Vec<u8>,
    logits: Vec<f32>,
}

impl NetlistEngine {
    /// Synthesize the model's table-mapped prefix into a netlist and build
    /// the engine.  Wide neurons spill to content-bearing BRAM records at
    /// the default threshold; the simulator fires them in place, so the
    /// circuit stays end-to-end evaluable.
    pub fn build(model: &ExportedModel, tables: &ModelTables) -> Result<NetlistEngine> {
        Self::build_opt(model, tables, OptLevel::None)
    }

    /// Like [`NetlistEngine::build`], but run the netlist-optimization
    /// pipeline (`synth::opt`) at the given level first.  The optimized
    /// circuit serves fewer LUTs per sample while staying bit-identical to
    /// [`LutEngine`] — `synthesize` machine-checks the equivalence before
    /// the engine ever sees the netlist.
    pub fn build_opt(
        model: &ExportedModel,
        tables: &ModelTables,
        opt: OptLevel,
    ) -> Result<NetlistEngine> {
        let (netlist, _) = synthesize(
            model,
            tables,
            SynthOpts { registers: false, opt, ..SynthOpts::default() },
        )?;
        Self::from_netlist(model, tables, netlist)
    }

    /// Build from an already-synthesized netlist.  The table-mapped layers
    /// must form a contiguous prefix starting at layer 0 (so the netlist's
    /// input bus is the model input bus); every later layer stays
    /// arithmetic via the internal `DenseStage`.
    pub fn from_netlist(
        model: &ExportedModel,
        tables: &ModelTables,
        netlist: Netlist,
    ) -> Result<NetlistEngine> {
        // Shared executable-netlist preconditions (no opaque BRAM, emitted
        // layers present, uniform-width contiguous prefix for skip wiring)
        // live in synth::verify_plan; serving additionally needs the
        // prefix to start at layer 0 so the netlist's input bus is the
        // model input bus (plus any BRAM pseudo inputs, which the
        // simulator overwrites in place).
        let (emitted, lt_first, out_bw) = crate::synth::verify_plan(model, tables, &netlist)?;
        ensure!(
            emitted.iter().enumerate().all(|(k, &li)| k == li),
            "table-mapped layers must form a contiguous prefix"
        );
        let last = *emitted.last().unwrap();
        let bw_in = lt_first.quant_in.bw;
        let pseudo_bits: usize = netlist.brams.iter().map(|b| b.out_bits).sum();
        ensure!(
            netlist.num_inputs == model.layers[0].in_f * bw_in + pseudo_bits,
            "netlist input bus {} != in_f {} * bw {bw_in} + {pseudo_bits} BRAM pseudo bits",
            netlist.num_inputs,
            model.layers[0].in_f
        );
        ensure!(out_bw <= 8, "engine supports <=8-bit codes");
        if model.skips > 0 && last + 1 < model.num_layers() {
            ensure!(
                last + 2 == model.num_layers(),
                "skip wiring supports a single dense head after the netlist"
            );
        }
        // The output bus follows `synth::output_bus_acts` — the dense
        // head's full newest-first concat input with skip wiring, the last
        // sparse layer's codes otherwise — and the dense tail consumes it
        // verbatim.  Act slot 0 is the raw input; slot j is layer j-1's
        // output (slot == act index: the prefix is contiguous from 0).
        let net_outs: usize = crate::synth::output_bus_acts(model, &emitted)
            .iter()
            .map(|&j| {
                if j == 0 {
                    model.layers[0].in_f
                } else {
                    model.layers[j - 1].neurons.len()
                }
            })
            .sum();
        ensure!(
            netlist.outputs.len() == net_outs * out_bw,
            "netlist output bus {} != codes {net_outs} * bw {out_bw}",
            netlist.outputs.len()
        );
        let dense_tail: Vec<DenseStage> =
            model.layers[last + 1..].iter().map(DenseStage::build).collect();
        let plan = netlist.compile_plan();
        Ok(NetlistEngine {
            netlist,
            plan,
            scratch: Mutex::new(Vec::new()),
            dense_tail,
            in_quant: model.layers[0].quant_in,
            in_features: model.in_features,
            classes: model.classes,
            bw_in,
            out_bw,
            net_outs,
        })
    }

    pub fn num_luts(&self) -> usize {
        self.netlist.num_luts()
    }

    /// Decode netlist output codes for samples `start..start+chunk.len()`,
    /// run the dense tail, and write argmax classes into `chunk`.
    fn decode_range(&self, out: &BitMatrix, start: usize, chunk: &mut [usize]) {
        let mut codes: Vec<u8> = Vec::with_capacity(self.net_outs);
        let mut next: Vec<u8> = Vec::new();
        let mut logits: Vec<f32> = Vec::new();
        for (k, slot) in chunk.iter_mut().enumerate() {
            let s = start + k;
            codes.clear();
            for o in 0..self.net_outs {
                codes.push(out.get_code(o * self.out_bw, self.out_bw, s) as u8);
            }
            for stage in &self.dense_tail {
                next.clear();
                stage.eval(&codes, &mut next, &mut logits);
                std::mem::swap(&mut codes, &mut next);
            }
            // Same argmax (and tie-break) as `LutEngine::infer`.
            *slot = codes
                .iter()
                .enumerate()
                .max_by_key(|&(_, &c)| c)
                .map(|(i, _)| i)
                .unwrap_or(0);
        }
    }

    /// The pre-fusion serving path, kept as the oracle and `bench_serve`
    /// baseline: one 64-way bitsliced pass over the whole batch
    /// ([`crate::sim::eval_netlist_64`]), then per-sample bit extraction +
    /// dense tail + argmax over the materialized output matrix.
    pub fn infer_batch_unfused(&self, xs: &[f32]) -> Vec<usize> {
        const PAR_DECODE_MIN: usize = 512;
        let d = self.in_features;
        assert_eq!(xs.len() % d, 0);
        let n = xs.len() / d;
        if n == 0 {
            return Vec::new();
        }
        let mut inputs = BitMatrix::new(self.netlist.num_inputs, n);
        for (s, row) in xs.chunks(d).enumerate() {
            for (j, &v) in row.iter().enumerate() {
                inputs.set_code(j * self.bw_in, self.bw_in, s, self.in_quant.code(v));
            }
        }
        let out = crate::sim::eval_netlist_64(&self.netlist, &inputs);
        let mut preds = vec![0usize; n];
        if n < PAR_DECODE_MIN {
            self.decode_range(&out, 0, &mut preds);
        } else {
            crate::util::pool::par_chunks_mut(&mut preds, |_, start, chunk| {
                self.decode_range(&out, start, chunk)
            });
        }
        preds
    }

    /// Fused sweep over a chunk-aligned sample range: evaluate one
    /// 256-sample chunk of the plan, then immediately decode that chunk's
    /// output codes out of the wide value array, run the dense tail and
    /// argmax — the netlist outputs never leave cache as a whole-batch
    /// `BitMatrix`.  `start` (the global index of `preds[0]`) must be a
    /// multiple of `CHUNK_SAMPLES`.  `auto` lets each chunk split its
    /// levels across the pool ([`EvalPlan::eval_chunk_auto`]) — only the
    /// single-range inline caller passes true, so a batch that is already
    /// range-parallel never oversubscribes.
    fn fused_range(
        &self,
        inputs: &BitMatrix,
        start: usize,
        preds: &mut [usize],
        ws: &mut FusedWorker,
        auto: bool,
    ) {
        debug_assert_eq!(start % CHUNK_SAMPLES, 0);
        ws.vals.resize(self.plan.vals_len(), [0u64; LANES]);
        let out_slots = self.plan.output_slots();
        let mut done = 0usize;
        while done < preds.len() {
            let w0 = (start + done) / 64;
            if auto {
                self.plan.eval_chunk_auto(inputs, w0, &mut ws.vals);
            } else {
                self.plan.eval_chunk(inputs, w0, &mut ws.vals);
            }
            let in_chunk = CHUNK_SAMPLES.min(preds.len() - done);
            for k in 0..in_chunk {
                let (lane, bit) = (k / 64, k % 64);
                ws.codes.clear();
                for o in 0..self.net_outs {
                    let mut c = 0u8;
                    for b in 0..self.out_bw {
                        let v = &ws.vals[out_slots[o * self.out_bw + b] as usize];
                        c |= (((v[lane] >> bit) & 1) as u8) << b;
                    }
                    ws.codes.push(c);
                }
                for stage in &self.dense_tail {
                    ws.next.clear();
                    stage.eval(&ws.codes, &mut ws.next, &mut ws.logits);
                    std::mem::swap(&mut ws.codes, &mut ws.next);
                }
                // Same argmax (and tie-break) as `LutEngine::infer`.
                preds[done + k] = ws
                    .codes
                    .iter()
                    .enumerate()
                    .max_by_key(|&(_, &c)| c)
                    .map(|(i, _)| i)
                    .unwrap_or(0);
            }
            done += in_chunk;
        }
    }

    /// Batch classify through the fused wide path: quantize into reused
    /// input planes, then chunk-aligned sample ranges across the worker
    /// pool, each running `fused_range`.  Router-sized batches (one
    /// range) run inline — no thread spawn; all buffers come from the
    /// engine's scratch pool, so steady-state serving allocates only the
    /// returned prediction vector.
    pub fn infer_batch(&self, xs: &[f32]) -> Vec<usize> {
        let d = self.in_features;
        assert_eq!(xs.len() % d, 0);
        let n = xs.len() / d;
        if n == 0 {
            return Vec::new();
        }
        let mut fs = match self.scratch.lock().unwrap().pop() {
            Some(fs) => {
                crate::obs::add("sim.scratch_pool.hits.count", 1);
                fs
            }
            None => {
                crate::obs::add("sim.scratch_pool.misses.count", 1);
                FusedScratch::default()
            }
        };
        fs.inputs.reset(self.netlist.num_inputs, n);
        for (s, row) in xs.chunks(d).enumerate() {
            for (j, &v) in row.iter().enumerate() {
                fs.inputs.set_code(j * self.bw_in, self.bw_in, s, self.in_quant.code(v));
            }
        }
        let mut preds = vec![0usize; n];
        let nchunks = n.div_ceil(CHUNK_SAMPLES);
        let workers = crate::util::pool::num_threads().min(nchunks).max(1);
        let per = nchunks.div_ceil(workers) * CHUNK_SAMPLES;
        let nranges = n.div_ceil(per);
        if fs.workers.len() < nranges {
            fs.workers.resize_with(nranges, FusedWorker::default);
        }
        // Destructure so the threads borrow disjoint fields.
        let FusedScratch { inputs, workers: wss } = &mut fs;
        if nranges == 1 {
            self.fused_range(inputs, 0, &mut preds, &mut wss[0], true);
        } else {
            std::thread::scope(|s| {
                for (r, (chunk, ws)) in preds.chunks_mut(per).zip(wss.iter_mut()).enumerate() {
                    let inputs = &*inputs;
                    s.spawn(move || self.fused_range(inputs, r * per, chunk, ws, false));
                }
            });
        }
        self.scratch.lock().unwrap().push(fs);
        preds
    }
}

impl Backend for NetlistEngine {
    fn in_features(&self) -> usize {
        self.in_features
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn infer_batch(&self, xs: &[f32]) -> Vec<usize> {
        NetlistEngine::infer_batch(self, xs)
    }

    fn name(&self) -> &'static str {
        "netlist"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::ModelTables;
    use crate::nn::{ExportedLayer, ExportedModel, Neuron};
    use crate::util::rng::Rng;

    fn random_model(seed: u64) -> ExportedModel {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        let widths = [24usize, 16];
        let mut prev = 12usize;
        for (k, &w) in widths.iter().enumerate() {
            let qi = if k == 0 { QuantSpec::new(2, 1.0) } else { QuantSpec::new(2, 2.0) };
            let neurons = (0..w)
                .map(|_| {
                    let inputs = rng.choose_k(prev, 3);
                    Neuron {
                        inputs: inputs.clone(),
                        weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect(),
                        bias: rng.normal_f32(0.0, 0.1),
                        g: 1.0,
                        h: 0.0,
                    }
                })
                .collect();
            layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(2, 2.0), true));
            prev = w;
        }
        // dense head
        let neurons = (0..5)
            .map(|_| {
                let inputs: Vec<usize> = (0..prev).collect();
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.3)).collect(),
                    bias: 0.0,
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        layers.push(ExportedLayer::uniform(neurons, prev, QuantSpec::new(2, 2.0), QuantSpec::new(2, 4.0), false));
        ExportedModel {
            layers,
            in_features: 12,
            classes: 5,
            skips: 0,
            act_widths: vec![12, 24, 16],
        }
    }

    #[test]
    fn engine_matches_arithmetic_mirror() {
        let model = random_model(1);
        let tables = ModelTables::generate(&model).unwrap();
        let engine = LutEngine::build(&model, &tables).unwrap();
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            let x: Vec<f32> = (0..12).map(|_| rng.f32()).collect();
            let logits = model.forward(&x);
            // Reference argmax via the NaN-safe total order (the bare
            // partial_cmp().unwrap() here was the last of that panic
            // family on the serve path).
            let expect = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            let mut scratch = InferScratch::default();
            let got = engine.infer(&x, &mut scratch);
            // argmax ties can differ in order; compare logit values instead.
            assert_eq!(logits[got], logits[expect], "engine argmax must be maximal");
            // codes must match the quantized logits exactly
            let q = model.layers.last().unwrap().quant_out;
            let codes = engine.infer_codes(&x);
            let expect_codes: Vec<u8> = logits.iter().map(|&v| q.code(v) as u8).collect();
            assert_eq!(codes, expect_codes);
        }
    }

    #[test]
    fn engine_handles_skip_wiring_with_mixed_scales() {
        // Regression for the skip-connection quantizer-scale bug: build a
        // 2-hidden-layer model with skips=1 whose layer-1 input concatenates
        // maxv-2.0 hidden codes with maxv-1.0 input codes.
        let mut rng = Rng::new(4);
        let in_f = 6usize;
        let w1 = 8usize;
        let qi0 = QuantSpec::new(2, 1.0);
        let qh = QuantSpec::new(2, 2.0);
        let mk = |rng: &mut Rng, prev: usize, fanin: usize| Neuron {
            inputs: rng.choose_k(prev, fanin),
            weights: (0..fanin).map(|_| rng.normal_f32(0.0, 0.8)).collect(),
            bias: 0.0,
            g: 1.0,
            h: 0.0,
        };
        let l0 = ExportedLayer::uniform(
            (0..w1).map(|_| mk(&mut rng, in_f, 3)).collect(),
            in_f,
            qi0,
            qh,
            true,
        );
        // layer 1 input = [a_1 (w1, maxv 2.0), a_0 (in_f, maxv 1.0)]
        let mut specs = vec![qh; w1];
        specs.extend(vec![qi0; in_f]);
        let l1 = ExportedLayer {
            neurons: (0..4).map(|_| mk(&mut rng, w1 + in_f, 3)).collect(),
            in_f: w1 + in_f,
            quant_in: qh,
            quant_out: QuantSpec::new(2, 4.0),
            sparse: true,
            input_specs: specs,
        };
        let model = ExportedModel {
            layers: vec![l0, l1],
            in_features: in_f,
            classes: 4,
            skips: 1,
            act_widths: vec![in_f, w1],
        };
        let tables = ModelTables::generate(&model).unwrap();
        // tables == mirror
        let xs: Vec<f32> = (0..in_f * 50).map(|_| rng.f32()).collect();
        assert_eq!(tables.verify(&model, &xs), 0);
        // engine == mirror
        let engine = LutEngine::build(&model, &tables).unwrap();
        let q = model.layers.last().unwrap().quant_out;
        for row in xs.chunks(in_f) {
            let codes = engine.infer_codes(row);
            let expect: Vec<u8> =
                model.forward(row).iter().map(|&v| q.code(v) as u8).collect();
            assert_eq!(codes, expect);
        }
    }

    #[test]
    fn batch_matches_single() {
        let model = random_model(2);
        let tables = ModelTables::generate(&model).unwrap();
        let engine = LutEngine::build(&model, &tables).unwrap();
        let mut rng = Rng::new(5);
        let xs: Vec<f32> = (0..12 * 32).map(|_| rng.f32()).collect();
        let batch = engine.infer_batch(&xs);
        let mut scratch = InferScratch::default();
        for (i, row) in xs.chunks(12).enumerate() {
            assert_eq!(batch[i], engine.infer(row, &mut scratch));
        }
    }

    #[test]
    fn parallel_batch_matches_serial() {
        let model = random_model(6);
        let tables = ModelTables::generate(&model).unwrap();
        let engine = LutEngine::build(&model, &tables).unwrap();
        let mut rng = Rng::new(8);
        for n in [1usize, 7, 64, 257] {
            let xs: Vec<f32> = (0..12 * n).map(|_| rng.f32()).collect();
            assert_eq!(engine.infer_batch_par(&xs), engine.infer_batch(&xs), "n={n}");
        }
    }

    #[test]
    fn netlist_engine_matches_lut_engine() {
        // The bitsliced netlist backend must reproduce the table engine's
        // predictions exactly (incl. argmax tie-breaks), on batch sizes
        // around the 64-sample word boundary.
        let model = random_model(3);
        let tables = ModelTables::generate(&model).unwrap();
        let lut = LutEngine::build(&model, &tables).unwrap();
        let net = NetlistEngine::build(&model, &tables).unwrap();
        assert!(net.num_luts() > 0);
        assert_eq!(Backend::classes(&net), Backend::classes(&lut));
        let mut rng = Rng::new(77);
        for n in [1usize, 63, 64, 65, 200, 255, 256, 257, 600] {
            let xs: Vec<f32> = (0..12 * n).map(|_| rng.f32()).collect();
            let expect = lut.infer_batch(&xs);
            assert_eq!(net.infer_batch(&xs), expect, "fused n={n}");
            assert_eq!(net.infer_batch_unfused(&xs), expect, "unfused n={n}");
        }
    }

    #[test]
    fn fused_scratch_pool_reuses_and_stays_exact() {
        // Repeated batches of varying size through one engine must keep
        // agreeing with the oracle path — exercises `BitMatrix::reset`
        // reuse and the scratch pool handoff.
        let model = random_model(8);
        let tables = ModelTables::generate(&model).unwrap();
        let net = NetlistEngine::build(&model, &tables).unwrap();
        let mut rng = Rng::new(21);
        for n in [600usize, 1, 256, 64, 513, 2] {
            let xs: Vec<f32> = (0..12 * n).map(|_| rng.f32()).collect();
            assert_eq!(net.infer_batch(&xs), net.infer_batch_unfused(&xs), "n={n}");
        }
        assert!(net.scratch.lock().unwrap().len() <= 1, "pool must recycle one scratch set");
    }

    #[test]
    fn optimized_netlist_engine_bit_identical() {
        // Serving the *optimized* circuit must stay bit-identical to the
        // table engine at every optimization level.
        let model = random_model(5);
        let tables = ModelTables::generate(&model).unwrap();
        let lut = LutEngine::build(&model, &tables).unwrap();
        let plain = NetlistEngine::build(&model, &tables).unwrap();
        for opt in [OptLevel::Structural, OptLevel::Full] {
            let net = NetlistEngine::build_opt(&model, &tables, opt).unwrap();
            assert!(net.num_luts() <= plain.num_luts(), "{opt:?}");
            let mut rng = Rng::new(31);
            for n in [1usize, 63, 64, 65, 200] {
                let xs: Vec<f32> = (0..12 * n).map(|_| rng.f32()).collect();
                assert_eq!(net.infer_batch(&xs), lut.infer_batch(&xs), "{opt:?} n={n}");
            }
        }
    }

    #[test]
    fn netlist_engine_serves_skip_topologies() {
        // A skip/pyramid manifest end to end: the netlist output bus is the
        // dense head's concat input, and the served predictions must be
        // bit-identical to the table engine at every optimization level.
        use crate::runtime::Manifest;
        use crate::sparsity::prune::PruneMethod;
        let man = Manifest::synthetic_topology("eng_skip", "jets", 8, 3, &[12, 6], 3, 2, 1);
        let st = crate::train::ModelState::init(&man, 9, PruneMethod::APriori);
        let model = crate::nn::ExportedModel::from_state(&man, &st);
        let tables = ModelTables::generate(&model).unwrap();
        let lut = LutEngine::build(&model, &tables).unwrap();
        let mut rng = Rng::new(15);
        for opt in [OptLevel::None, OptLevel::Full] {
            let net = NetlistEngine::build_opt(&model, &tables, opt).unwrap();
            assert_eq!(Backend::classes(&net), 3);
            for n in [1usize, 63, 64, 65, 128] {
                let xs: Vec<f32> = (0..8 * n).map(|_| rng.f32()).collect();
                assert_eq!(net.infer_batch(&xs), lut.infer_batch(&xs), "{opt:?} n={n}");
            }
        }
    }

    #[test]
    fn netlist_engine_serves_bram_threshold_designs() {
        // Spill every table-mapped neuron to a content-bearing BRAM record
        // (fanin 3 x 2-bit codes = 6 address bits): the fused wide path
        // must fire BRAM->BRAM chains (layer-1 addresses read layer-0
        // pseudo outputs) bit-identically to the table engine, with no
        // scalar fallback or BRAM-free remap.
        let model = random_model(11);
        let tables = ModelTables::generate(&model).unwrap();
        let (netlist, report) = synthesize(
            &model,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 6, ..SynthOpts::default() },
        )
        .unwrap();
        assert!(report.brams > 0, "threshold must spill");
        assert!(netlist.brams_evaluable());
        let lut = LutEngine::build(&model, &tables).unwrap();
        let net = NetlistEngine::from_netlist(&model, &tables, netlist).unwrap();
        assert!(net.plan.num_bram_records() > 0, "wide plan must carry BRAM records");
        let mut rng = Rng::new(23);
        for n in [1usize, 63, 64, 65, 200, 257] {
            let xs: Vec<f32> = (0..12 * n).map(|_| rng.f32()).collect();
            let expect = lut.infer_batch(&xs);
            assert_eq!(net.infer_batch(&xs), expect, "fused n={n}");
            assert_eq!(net.infer_batch_unfused(&xs), expect, "unfused n={n}");
        }
    }

    #[test]
    fn batch_accuracy_counts_hits() {
        let model = random_model(4);
        let tables = ModelTables::generate(&model).unwrap();
        let engine = LutEngine::build(&model, &tables).unwrap();
        let mut rng = Rng::new(13);
        let xs: Vec<f32> = (0..12 * 50).map(|_| rng.f32()).collect();
        let preds = engine.infer_batch(&xs);
        // Label half the samples with the prediction, half off by one.
        let ys: Vec<i32> = preds
            .iter()
            .enumerate()
            .map(|(i, &p)| if i % 2 == 0 { p as i32 } else { (p as i32 + 1) % 5 })
            .collect();
        let acc = batch_accuracy(&engine, &xs, &ys);
        assert!((acc - 0.5).abs() < 1e-9, "acc {acc}");
    }
}
