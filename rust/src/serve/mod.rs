//! L3 serving engine: the "extreme-throughput trigger" story.
//!
//! The FPGA runs a LogicNet at initiation interval 1 — one inference per
//! clock.  This module is the software model of that datapath: a
//! cache-friendly truth-table inference engine (`LutEngine`) behind a
//! batching request router (`Server`) with worker threads, throughput
//! counters and latency percentiles.  It is also the second functional
//! verification surface: the engine must agree exactly with the arithmetic
//! mirror (`ExportedModel::forward`).

pub mod engine;
pub mod router;

pub use engine::LutEngine;
pub use router::{Server, ServerConfig, ServerStats};
