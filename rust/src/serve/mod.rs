//! L3 serving engine: the "extreme-throughput trigger" story.
//!
//! The FPGA runs a LogicNet at initiation interval 1 — one inference per
//! clock.  This module is the software model of that datapath, with two
//! selectable backends behind one batching router:
//! * [`LutEngine`] — cache-friendly truth-table inference (code-domain
//!   lookups, allocation-free scratch);
//! * [`NetlistEngine`] — the *synthesized LUT netlist itself*, executed by
//!   the bitsliced simulator (`crate::sim`) 64 samples per word.
//!
//! Both implement [`Backend`] and must agree exactly with the arithmetic
//! mirror (`ExportedModel::forward`) — serving doubles as functional
//! verification of the whole tool-flow.

//! [`zoo`] adds the DSE→serving handoff: a search-emitted `zoo.json`
//! manifest of calibrated frontier netlists loads into a
//! [`router::ZooServer`], where each request's optional latency/LUT
//! [`router::Budget`] picks the cheapest registered model that satisfies
//! it.

pub mod engine;
pub mod router;
pub mod zoo;

pub use engine::{batch_accuracy, Backend, LutEngine, NetlistEngine};
pub use router::{
    Budget, ModelMeta, Server, ServerConfig, ServerMetrics, ServerStats, ZooServer,
};
pub use zoo::{ZooEntry, ZooManifest};
