//! Binary checkpointing of `ModelState` (simple length-prefixed LE format).
//!
//! Experiments cache trained models under `reports/ckpt/` so that tables
//! sharing a model (e.g. T6.2 and T5.3) train it once.  Format:
//!
//! ```text
//! magic "LNCK" | version u32 | num_layers u32 |
//! per layer: out u32, in u32 |
//! then for each tensor group in a fixed order: f32 LE payloads |
//! masks as per-neuron index lists (u32 count + u32 indices)
//! ```

use super::state::ModelState;
use crate::sparsity::Mask;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"LNCK";
const VERSION: u32 = 2;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn u32(&mut self) -> Result<u32> {
        ensure!(self.i + 4 <= self.b.len(), "truncated checkpoint");
        let v = u32::from_le_bytes(self.b[self.i..self.i + 4].try_into().unwrap());
        self.i += 4;
        Ok(v)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        ensure!(self.i + 4 * n <= self.b.len(), "truncated checkpoint payload");
        let mut out = Vec::with_capacity(n);
        for k in 0..n {
            let off = self.i + 4 * k;
            out.push(f32::from_le_bytes(self.b[off..off + 4].try_into().unwrap()));
        }
        self.i += 4 * n;
        Ok(out)
    }
}

pub fn serialize(state: &ModelState) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, state.num_layers() as u32);
    for &(o, i) in &state.layer_dims {
        put_u32(&mut out, o as u32);
        put_u32(&mut out, i as u32);
    }
    for group in [
        &state.ws,
        &state.bs,
        &state.gammas,
        &state.betas,
        &state.vws,
        &state.vbs,
        &state.vgammas,
        &state.vbetas,
        &state.rmeans,
        &state.rvars,
        &state.momentum_m,
    ] {
        for t in group.iter() {
            put_f32s(&mut out, t);
        }
    }
    for m in &state.masks {
        put_u32(&mut out, m.rows.len() as u32);
        for row in &m.rows {
            put_u32(&mut out, row.len() as u32);
            for &idx in row {
                put_u32(&mut out, idx as u32);
            }
        }
    }
    out
}

pub fn deserialize(bytes: &[u8]) -> Result<ModelState> {
    if bytes.len() < 12 || &bytes[..4] != MAGIC {
        bail!("not a LNCK checkpoint");
    }
    let mut r = Reader { b: bytes, i: 4 };
    let version = r.u32()?;
    ensure!(version == VERSION, "checkpoint version {version} != {VERSION}");
    let n = r.u32()? as usize;
    let mut layer_dims = Vec::with_capacity(n);
    for _ in 0..n {
        let o = r.u32()? as usize;
        let i = r.u32()? as usize;
        layer_dims.push((o, i));
    }
    let mut groups: Vec<Vec<Vec<f32>>> = Vec::with_capacity(11);
    for _ in 0..11 {
        let mut g = Vec::with_capacity(n);
        for _ in 0..n {
            g.push(r.f32s()?);
        }
        groups.push(g);
    }
    let mut masks = Vec::with_capacity(n);
    for l in 0..n {
        let rows_n = r.u32()? as usize;
        ensure!(rows_n == layer_dims[l].0, "mask row count mismatch");
        let mut rows = Vec::with_capacity(rows_n);
        for _ in 0..rows_n {
            let k = r.u32()? as usize;
            let mut row = Vec::with_capacity(k);
            for _ in 0..k {
                row.push(r.u32()? as usize);
            }
            rows.push(row);
        }
        masks.push(Mask { out_f: layer_dims[l].0, in_f: layer_dims[l].1, rows });
    }
    let mut it = groups.into_iter();
    Ok(ModelState {
        layer_dims,
        ws: it.next().unwrap(),
        bs: it.next().unwrap(),
        gammas: it.next().unwrap(),
        betas: it.next().unwrap(),
        vws: it.next().unwrap(),
        vbs: it.next().unwrap(),
        vgammas: it.next().unwrap(),
        vbetas: it.next().unwrap(),
        rmeans: it.next().unwrap(),
        rvars: it.next().unwrap(),
        momentum_m: it.next().unwrap(),
        masks,
    })
}

pub fn save(state: &ModelState, path: &std::path::Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    f.write_all(&serialize(state))?;
    Ok(())
}

pub fn load(path: &std::path::Path) -> Result<ModelState> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open {path:?}"))?
        .read_to_end(&mut bytes)?;
    deserialize(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use crate::sparsity::prune::PruneMethod;

    fn man() -> Manifest {
        Manifest::parse(
            r#"{
          "name":"t","kind":"mlp","in_features":6,"classes":3,"hidden":[8],
          "bw":2,"bw_in":2,"bw_out":2,"fanin":2,"fanin_fc":null,
          "batch":4,"eval_batch":4,"dataset":"jets",
          "layers":[{"in":6,"out":8,"fanin":2,"bw_in":2,"maxv_in":1.0},
                    {"in":8,"out":3,"fanin":null,"bw_in":2,"maxv_in":2.0}]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let mut st = ModelState::init(&man(), 5, PruneMethod::APriori);
        st.ws[0][3] = 1.25;
        st.rvars[1][2] = 0.5;
        let bytes = serialize(&st);
        let back = deserialize(&bytes).unwrap();
        assert_eq!(back.ws, st.ws);
        assert_eq!(back.rvars, st.rvars);
        assert_eq!(back.masks, st.masks);
        assert_eq!(back.layer_dims, st.layer_dims);
    }

    #[test]
    fn rejects_corrupt() {
        let st = ModelState::init(&man(), 5, PruneMethod::APriori);
        let mut bytes = serialize(&st);
        bytes.truncate(bytes.len() / 2);
        assert!(deserialize(&bytes).is_err());
        assert!(deserialize(b"JUNKJUNKJUNK").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let st = ModelState::init(&man(), 7, PruneMethod::APriori);
        let path = std::env::temp_dir().join("lnck_test.bin");
        save(&st, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.ws, st.ws);
    }
}
