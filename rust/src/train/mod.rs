//! L3 training driver: drives the AOT-compiled `train_step` HLO from Rust.
//!
//! The coordinator owns everything XLA does not: batching, optimizer-state
//! buffers, EMA batch-norm statistics, the exponentially-smoothed gradient
//! (for sparse-momentum pruning) and the pruning schedules that rewrite the
//! connectivity masks between steps.  Masks are runtime *inputs* of the HLO
//! entry point, so pruning never recompiles anything.
//!
//! Perf note (§Perf in EXPERIMENTS.md): parameters and velocities circulate
//! as XLA `Literal`s — the tuple outputs of step t are fed directly as the
//! inputs of step t+1.  Host copies happen only for the small per-step
//! outputs (loss, batch stats), for weight gradients when the sparse-
//! momentum method needs them, and at pruning events; this removed the
//! 2×params/step host round-trip of the naive driver.

pub mod checkpoint;
pub mod native;
pub mod state;

use crate::data::DataSet;
use crate::runtime::{lit_f32, lit_i32, lit_scalar_f32, lit_to_f32, scalar_f32, Artifact};
use crate::sparsity::prune::{PruneMethod, Pruner};
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

pub use state::ModelState;

/// Logging cadence: log on every `log_every`-th step plus the final step.
/// `log_every == 0` is clamped to 1 (mirroring the router's
/// `max_batch.max(1)` idiom) — the raw `step % opts.log_every` it replaces
/// panicked with a division by zero.
#[inline]
pub fn should_log(step: usize, total_steps: usize, log_every: usize) -> bool {
    step % log_every.max(1) == 0 || step + 1 == total_steps
}

/// Pruning cadence for the `Iterative`/`Momentum` schedules: fire every
/// `every` steps, never on step 0, and never when `every == 0` (a zero
/// period means "no events", not a panic).
#[inline]
pub fn prune_event(step: usize, every: usize) -> bool {
    every > 0 && step > 0 && step % every == 0
}

#[derive(Debug, Clone)]
pub struct TrainOpts {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub method: PruneMethod,
    pub log_every: usize,
    /// EMA factor for running batch-norm stats (r = ema*r + (1-ema)*batch).
    pub bn_ema: f32,
    /// EMA factor for the sparse-momentum gradient buffer (Alg. 1's alpha).
    pub momentum_alpha: f32,
    pub verbose: bool,
}

impl TrainOpts {
    pub fn from_manifest(man: &crate::runtime::Manifest) -> TrainOpts {
        TrainOpts {
            steps: man.steps,
            lr: man.lr,
            seed: 0xC0DE,
            method: PruneMethod::APriori,
            log_every: 25,
            bn_ema: 0.9,
            momentum_alpha: 0.9,
            verbose: false,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    /// (step, loss) samples at `log_every` cadence.
    pub losses: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub mask_updates: usize,
    pub steps: usize,
    pub seconds: f64,
}

/// Run `opts.steps` optimizer steps of `art` on `train_set`.
pub fn train(
    art: &Artifact,
    state: &mut ModelState,
    train_set: &DataSet,
    opts: &TrainOpts,
) -> Result<TrainLog> {
    let man = &art.manifest;
    ensure!(train_set.d == man.in_features, "dataset width mismatch");
    ensure!(train_set.classes == man.classes, "dataset class mismatch");
    let n = man.num_layers();
    let mut rng = Rng::new(opts.seed ^ 0x7261696e);
    let pruners: Vec<Pruner> = (0..n)
        .map(|i| Pruner::new(opts.method, man.layers[i].fanin))
        .collect();
    let needs_grads = matches!(opts.method, PruneMethod::Momentum { .. });
    let mut log = TrainLog::default();
    let t0 = std::time::Instant::now();

    // Parameter/velocity literals in flat order (w,b,gamma,beta,vw,vb,vg,vbe
    // × layers each); fed back output->input without host round-trips.
    let mut plits: Vec<xla::Literal> = Vec::with_capacity(8 * n);
    for group in [&state.ws, &state.bs, &state.gammas, &state.betas] {
        for (i, v) in group.iter().enumerate() {
            plits.push(lit_f32(v, &state.shape(i, v.len()))?);
        }
    }
    for group in [&state.vws, &state.vbs, &state.vgammas, &state.vbetas] {
        for (i, v) in group.iter().enumerate() {
            plits.push(lit_f32(v, &state.shape(i, v.len()))?);
        }
    }
    let mut mask_lits: Vec<xla::Literal> = (0..n)
        .map(|i| {
            let l = &man.layers[i];
            lit_f32(&state.masks[i].to_dense_f32(), &[l.out_f as i64, l.in_f as i64])
        })
        .collect::<Result<_>>()?;

    for step in 0..opts.steps {
        let (bx, by) = train_set.sample_batch(man.batch, &mut rng);
        // Simple linear decay keeps the quantized logits stable late in
        // training.
        let lr = opts.lr * (1.0 - 0.9 * step as f32 / opts.steps.max(1) as f32);
        let x_lit = lit_f32(&bx, &[man.batch as i64, man.in_features as i64])?;
        let y_lit = lit_i32(&by, &[man.batch as i64])?;
        let lr_lit = lit_scalar_f32(lr);

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(9 * n + 3);
        inputs.extend(plits.iter());
        inputs.extend(mask_lits.iter());
        inputs.push(&x_lit);
        inputs.push(&y_lit);
        inputs.push(&lr_lit);

        let mut out = art.train_step.run(&inputs)?;
        ensure!(
            out.len() == 11 * n + 1,
            "train_step output arity {} != {}",
            out.len(),
            11 * n + 1
        );
        let rest = out.split_off(8 * n);
        plits = out;

        let loss = scalar_f32(&rest[0])?;
        for i in 0..n {
            if needs_grads {
                let gw = lit_to_f32(&rest[1 + i])?;
                let mm = &mut state.momentum_m[i];
                for (m, g) in mm.iter_mut().zip(&gw) {
                    *m = opts.momentum_alpha * *m + (1.0 - opts.momentum_alpha) * g;
                }
            }
            let mu = lit_to_f32(&rest[n + 1 + i])?;
            let var = lit_to_f32(&rest[2 * n + 1 + i])?;
            for (r, b) in state.rmeans[i].iter_mut().zip(&mu) {
                *r = opts.bn_ema * *r + (1.0 - opts.bn_ema) * b;
            }
            for (r, b) in state.rvars[i].iter_mut().zip(&var) {
                *r = opts.bn_ema * *r + (1.0 - opts.bn_ema) * b;
            }
        }

        // Pruning callbacks (may rewrite masks).  Host copies of the weight
        // tensors are made only at event steps.
        if !matches!(opts.method, PruneMethod::APriori) {
            for i in 0..n {
                let event = match opts.method {
                    PruneMethod::Iterative { every } | PruneMethod::Momentum { every, .. } => {
                        prune_event(step, every)
                    }
                    PruneMethod::APriori => false,
                };
                if !event {
                    continue;
                }
                let w = lit_to_f32(&plits[i])?;
                let changed = pruners[i].on_step(
                    step,
                    opts.steps,
                    &w,
                    &state.momentum_m[i],
                    &mut state.masks[i],
                );
                if changed {
                    // Zero off-mask weights + velocities and re-upload the
                    // three affected literals.
                    let l = &man.layers[i];
                    let dense = state.masks[i].to_dense_f32();
                    let mut w = w;
                    let mut vw = lit_to_f32(&plits[4 * n + i])?;
                    for (k, m) in dense.iter().enumerate() {
                        if *m == 0.0 {
                            w[k] = 0.0;
                            vw[k] = 0.0;
                        }
                    }
                    let dims = [l.out_f as i64, l.in_f as i64];
                    plits[i] = lit_f32(&w, &dims)?;
                    plits[4 * n + i] = lit_f32(&vw, &dims)?;
                    mask_lits[i] = lit_f32(&dense, &dims)?;
                    log.mask_updates += 1;
                }
            }
        }

        if should_log(step, opts.steps, opts.log_every) {
            log.losses.push((step, loss));
            if opts.verbose {
                eprintln!("step {step:5}  loss {loss:.4}  lr {lr:.4}");
            }
        }
        log.final_loss = loss;
    }

    // Materialize final parameters back into host state.
    for i in 0..n {
        state.ws[i] = lit_to_f32(&plits[i])?;
        state.bs[i] = lit_to_f32(&plits[n + i])?;
        state.gammas[i] = lit_to_f32(&plits[2 * n + i])?;
        state.betas[i] = lit_to_f32(&plits[3 * n + i])?;
        state.vws[i] = lit_to_f32(&plits[4 * n + i])?;
        state.vbs[i] = lit_to_f32(&plits[5 * n + i])?;
        state.vgammas[i] = lit_to_f32(&plits[6 * n + i])?;
        state.vbetas[i] = lit_to_f32(&plits[7 * n + i])?;
    }
    log.steps = opts.steps;
    log.seconds = t0.elapsed().as_secs_f64();
    Ok(log)
}

/// Evaluate `state` on `test` via the `forward` artifact; returns row-major
/// logits `[test.n, classes]`.
pub fn evaluate(art: &Artifact, state: &ModelState, test: &DataSet) -> Result<Vec<f32>> {
    let man = &art.manifest;
    let n = man.num_layers();
    let be = man.eval_batch;
    let mut logits = Vec::with_capacity(test.n * man.classes);

    // Static inputs (params + masks + running stats) are built once and
    // passed by reference for every chunk; only x changes.
    let mut static_inputs: Vec<xla::Literal> = Vec::with_capacity(7 * n);
    for group in [&state.ws, &state.bs, &state.gammas, &state.betas] {
        for (i, v) in group.iter().enumerate() {
            static_inputs.push(lit_f32(v, &state.shape(i, v.len()))?);
        }
    }
    for (i, m) in state.masks.iter().enumerate() {
        let l = &man.layers[i];
        static_inputs.push(lit_f32(&m.to_dense_f32(), &[l.out_f as i64, l.in_f as i64])?);
    }
    for group in [&state.rmeans, &state.rvars] {
        for (i, v) in group.iter().enumerate() {
            static_inputs.push(lit_f32(v, &state.shape(i, v.len()))?);
        }
    }

    let mut start = 0;
    while start < test.n {
        let (bx, _, real) = test.chunk_padded(start, be);
        let x_lit = lit_f32(&bx, &[be as i64, man.in_features as i64])?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(7 * n + 1);
        inputs.extend(static_inputs.iter());
        inputs.push(&x_lit);
        let out = art.forward.run(&inputs)?;
        ensure!(out.len() == 1, "forward output arity");
        let chunk = lit_to_f32(&out[0])?;
        logits.extend_from_slice(&chunk[..real * man.classes]);
        start += real;
    }
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_cadence_survives_zero_log_every() {
        // Regression: `step % opts.log_every` panicked when a manifest (or
        // caller) set log_every = 0.  Clamped, 0 behaves like 1: log every
        // step.
        for step in 0..10 {
            assert!(should_log(step, 10, 0));
            assert!(should_log(step, 10, 1));
        }
        // Normal cadence: multiples of the period plus the final step.
        assert!(should_log(0, 100, 25));
        assert!(should_log(50, 100, 25));
        assert!(!should_log(26, 100, 25));
        assert!(should_log(99, 100, 25), "final step always logs");
    }

    #[test]
    fn prune_cadence_survives_zero_period() {
        // `every == 0` must mean "no pruning events", not a div-by-zero on
        // the same modulo pattern.
        for step in 0..50 {
            assert!(!prune_event(step, 0));
        }
        assert!(!prune_event(0, 8), "never prune before the first step");
        assert!(prune_event(8, 8));
        assert!(prune_event(16, 8));
        assert!(!prune_event(9, 8));
    }
}
