//! Native pure-Rust training path — the PJRT-free mirror of [`super::train`].
//!
//! The AOT HLO `train_step` is the canonical trainer, but it needs a live
//! PJRT runtime and on-disk artifacts; the offline `xla` stub errors at
//! runtime.  The design-space-exploration engine (`crate::dse::search`)
//! must train *generated* candidates that have no artifact at all, so this
//! module reimplements the same training semantics directly on
//! [`ModelState`]:
//!
//! * quantized forward pass with **batch** batch-norm statistics
//!   (training mode), activation quantizers applied through a
//!   straight-through estimator (STE) in the backward pass,
//! * newest-first **skip-concat wiring** (`skips > 0`): layer `i`'s input
//!   is the concatenation of the last `min(skips, i) + 1` quantized
//!   activations, newest first — exactly the order `luts::forward_codes`,
//!   `serve::engine` and `nn::export::skip_input` execute — and the
//!   backward pass routes the concatenated input gradient back into every
//!   segment, so one activation accumulates gradient from every layer
//!   that consumes it before its own quantizer STE fires,
//! * **conv stages** (`kind = "cnv"`): the lowered per-pixel layers run
//!   through the same generic masked matmul (im2col in disguise), with
//!   shared-kernel weights kept exactly tied by gradient-sum accumulation
//!   over each tap's pixel group, and the receptive-field masks exempt
//!   from every pruning schedule,
//! * softmax cross-entropy on the *quantized* logits (the manifests'
//!   `train_softmax` convention),
//! * SGD with classical momentum and the same linear learning-rate decay
//!   as the HLO driver,
//! * EMA running-stat updates, smoothed-gradient buffer maintenance and
//!   the pruning schedules of `sparsity::prune` between steps.
//!
//! It intentionally does **not** promise bit-identity with the HLO path
//! (XLA reorders f32 sums); it promises the same *training dynamics* on
//! the same [`ModelState`] layout, so checkpoints, export, truth tables,
//! synthesis and serving all work unchanged downstream.

use super::{prune_event, should_log, ModelState, TrainLog, TrainOpts};
use crate::data::DataSet;
use crate::runtime::Manifest;
use crate::sparsity::prune::{PruneMethod, Pruner};
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

/// Per-tensor gradient L2-norm clip.  The quantized-STE loss surface is
/// piecewise constant in places and occasionally spikes; clipping keeps a
/// short-rung search from diverging on an unlucky batch.
const GRAD_CLIP: f32 = 5.0;

/// One layer's forward tape (everything the backward pass needs; the raw
/// pre-BN response is not kept — BN backward runs on `zhat`).
struct Tape {
    /// Layer input values `[b, in_f]`: the (skip-concatenated, quantized)
    /// activation values this layer consumed.
    a_in: Vec<f32>,
    /// Batch mean / biased variance per neuron.
    mu: Vec<f32>,
    var: Vec<f32>,
    /// Normalized response `[b, out_f]`.
    zhat: Vec<f32>,
    /// BN output (quantizer input) `[b, out_f]`.
    y: Vec<f32>,
}

/// STE pass-through mask: 1.0 where the activation quantizer's gradient
/// flows.  `bw == 1` is QuantHardTanh (pass inside `|y| <= maxv`), wider
/// widths are QuantReLU (pass inside `[0, maxv]`).
#[inline]
fn ste_gate(bw: usize, maxv: f32, y: f32) -> f32 {
    let pass = if bw == 1 { y.abs() <= maxv } else { (0.0..=maxv).contains(&y) };
    if pass {
        1.0
    } else {
        0.0
    }
}

/// Output quantizer spec of layer `i` (hidden vs final head), mirroring
/// `ExportedModel::from_state`.
fn quant_out_of(man: &Manifest, i: usize) -> crate::nn::QuantSpec {
    let last = i + 1 == man.num_layers();
    crate::nn::QuantSpec::new(
        if last { man.bw_out } else { man.bw },
        if last { man.maxv_out } else { man.maxv_hidden },
    )
}

fn clip_grad(g: &mut [f32]) {
    let norm = g.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32;
    if norm > GRAD_CLIP && norm.is_finite() {
        let s = GRAD_CLIP / norm;
        for v in g.iter_mut() {
            *v *= s;
        }
    }
}

/// Gradients of one layer, dense `[out_f, in_f]` like the state tensors.
struct LayerGrads {
    w: Vec<f32>,
    b: Vec<f32>,
    gamma: Vec<f32>,
    beta: Vec<f32>,
}

/// Run `opts.steps` native optimizer steps of the manifest's model on
/// `train_set`.  Same contract as [`super::train`]: mutates `state` in
/// place and returns the log.  Supports the whole heterogeneous layer
/// family — any per-layer MLP width schedule, newest-first skip
/// concatenation (`skips >= 0`), and conv manifests (`kind = "cnv"`):
/// the lowered conv layers run through the same generic matmul (the
/// structured mask makes it an im2col product), with kernel weight
/// sharing enforced by summing each tap's gradient over its pixel group
/// ([`crate::runtime::ConvGeom::neuron_windows`]) so tied weights receive
/// identical updates and velocities stay tied for the whole run.
pub fn train_native(
    man: &Manifest,
    state: &mut ModelState,
    train_set: &DataSet,
    opts: &TrainOpts,
) -> Result<TrainLog> {
    ensure!(train_set.d == man.in_features, "dataset width mismatch");
    ensure!(train_set.classes == man.classes, "dataset class mismatch");
    ensure!(
        man.kind == "mlp" || man.kind == "cnv",
        "native trainer supports kind=mlp and kind=cnv (got {})",
        man.kind
    );
    let n = man.num_layers();
    ensure!(state.num_layers() == n, "state/manifest layer count mismatch");
    // Conv weight-tying plan: per conv layer (always a manifest prefix),
    // the per-neuron (slot, input index) windows plus kernel shape.
    let conv_ties: Vec<(Vec<Vec<(usize, usize)>>, usize, usize)> = man
        .conv_geoms()?
        .iter()
        .map(|g| (g.neuron_windows(), g.c_out, g.window()))
        .collect();
    // Activation widths `[in_features, hidden...]` for skip concatenation
    // (act_0 = quantized input, act_{i+1} = layer i's quantized output),
    // validated against the canonical skip-widened rule
    // (`Manifest::skip_in_widths` — the same widths the DSE gate prices
    // and `ModelState::init` allocates).
    let act_widths: Vec<usize> = std::iter::once(man.in_features)
        .chain(man.layers.iter().take(n - 1).map(|l| l.out_f))
        .collect();
    let want = Manifest::skip_in_widths(man.in_features, &act_widths[1..], man.skips);
    for (i, l) in man.layers.iter().enumerate() {
        ensure!(
            l.in_f == want[i],
            "layer {i}: in_f {} != skip-concat width {} (skips {})",
            l.in_f,
            want[i],
            man.skips
        );
    }
    let b = man.batch.max(1);
    let mut rng = Rng::new(opts.seed ^ 0x6e617469); // "nati"
    let pruners: Vec<Pruner> =
        (0..n).map(|i| Pruner::new(opts.method, man.layers[i].fanin)).collect();
    let needs_grads = matches!(opts.method, PruneMethod::Momentum { .. });
    let mut log = TrainLog::default();
    let t0 = std::time::Instant::now();

    for step in 0..opts.steps {
        let (bx, by) = train_set.sample_batch(b, &mut rng);
        let lr = opts.lr * (1.0 - 0.9 * step as f32 / opts.steps.max(1) as f32);

        // ---------------- forward (batch BN stats, quantized acts) --------
        let mut tapes: Vec<Tape> = Vec::with_capacity(n);
        // Input quantizer of layer 0 (values domain, like nn::export).
        let q0 = crate::nn::QuantSpec::new(man.layers[0].bw_in, man.layers[0].maxv_in);
        // acts[j] = activation j (quantized values, `[b, act_widths[j]]`);
        // kept for the whole step so skip layers can re-consume them.
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n);
        acts.push(bx.iter().map(|&v| q0.quantize(v)).collect());
        // Final-layer quantized logits (`[b, classes]`).
        let mut logits: Vec<f32> = Vec::new();
        for i in 0..n {
            let l = &man.layers[i];
            let (out_f, in_f) = (l.out_f, l.in_f);
            // Layer input: newest-first concat of the last
            // `min(skips, i) + 1` activations (matches `luts::mod.rs` /
            // `serve/engine.rs` / `nn::export::skip_input` exactly).
            // Skip-free layers consume their activation exactly once, so
            // move it into the tape (no per-step clone on the old path);
            // with skips > 0 later layers re-read `acts`, and the concat
            // is a fresh buffer anyway.
            let act: Vec<f32> = if man.skips == 0 {
                std::mem::take(&mut acts[i])
            } else if i == 0 {
                acts[i].clone()
            } else {
                let lo = i.saturating_sub(man.skips);
                let mut v = Vec::with_capacity(b * in_f);
                for s in 0..b {
                    for j in (lo..=i).rev() {
                        let w = act_widths[j];
                        v.extend_from_slice(&acts[j][s * w..(s + 1) * w]);
                    }
                }
                v
            };
            debug_assert_eq!(act.len(), b * in_f, "layer {i} input width");
            let w = &state.ws[i];
            let mut z = vec![0f32; b * out_f];
            for s in 0..b {
                let xs = &act[s * in_f..(s + 1) * in_f];
                let zs = &mut z[s * out_f..(s + 1) * out_f];
                for (o, zo) in zs.iter_mut().enumerate() {
                    let row = &w[o * in_f..(o + 1) * in_f];
                    let mut acc = state.bs[i][o];
                    for (wv, xv) in row.iter().zip(xs) {
                        acc += wv * xv;
                    }
                    *zo = acc;
                }
            }
            // Batch statistics (biased variance, like standard BN training).
            let mut mu = vec![0f32; out_f];
            let mut var = vec![0f32; out_f];
            for s in 0..b {
                for o in 0..out_f {
                    mu[o] += z[s * out_f + o];
                }
            }
            for m in mu.iter_mut() {
                *m /= b as f32;
            }
            for s in 0..b {
                for o in 0..out_f {
                    let d = z[s * out_f + o] - mu[o];
                    var[o] += d * d;
                }
            }
            for v in var.iter_mut() {
                *v /= b as f32;
            }
            let mut zhat = vec![0f32; b * out_f];
            let mut y = vec![0f32; b * out_f];
            for o in 0..out_f {
                let inv = 1.0 / (var[o] + man.bn_eps).sqrt();
                let (g, be) = (state.gammas[i][o], state.betas[i][o]);
                for s in 0..b {
                    let zh = (z[s * out_f + o] - mu[o]) * inv;
                    zhat[s * out_f + o] = zh;
                    y[s * out_f + o] = g * zh + be;
                }
            }
            let q = quant_out_of(man, i);
            let next: Vec<f32> = y.iter().map(|&v| q.quantize(v)).collect();
            tapes.push(Tape { a_in: act, mu, var, zhat, y });
            if i + 1 < n {
                acts.push(next);
            } else {
                logits = next;
            }
        }

        // ---------------- loss on quantized logits -------------------------
        // Mirrors python/compile/model.py::loss_fn exactly: softmax CE at
        // the 8/maxv_out logit temperature (the quantized logit range is
        // narrow; the fixed positive scale keeps gradients healthy without
        // changing the argmax), or MSE against maxv_out-scaled one-hot
        // targets when the manifest disables the softmax head.
        let c = man.classes;
        debug_assert_eq!(logits.len(), b * c);
        let mut loss = 0f32;
        // dL/d(quantized logits), mean-reduced over the batch.
        let mut grad: Vec<f32> = vec![0.0; b * c];
        if man.train_softmax {
            let temp = 8.0 / man.maxv_out;
            for s in 0..b {
                let row = &logits[s * c..(s + 1) * c];
                let scaled: Vec<f32> = row.iter().map(|v| v * temp).collect();
                let m = scaled.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> = scaled.iter().map(|v| (v - m).exp()).collect();
                let sum: f32 = exps.iter().sum();
                let t = by[s] as usize;
                loss += -(exps[t] / sum).max(1e-12).ln();
                for k in 0..c {
                    let p = exps[k] / sum;
                    grad[s * c + k] = temp * (p - if k == t { 1.0 } else { 0.0 }) / b as f32;
                }
            }
        } else {
            for s in 0..b {
                let row = &logits[s * c..(s + 1) * c];
                let t = by[s] as usize;
                for k in 0..c {
                    let target = if k == t { man.maxv_out } else { 0.0 };
                    let d = row[k] - target;
                    loss += d * d;
                    grad[s * c + k] = 2.0 * d / b as f32;
                }
            }
        }
        loss /= b as f32;

        // ---------------- backward ----------------------------------------
        let mut grads: Vec<Option<LayerGrads>> = (0..n).map(|_| None).collect();
        // douts[i] accumulates dL/d(layer i's quantized output).  With skip
        // wiring one activation feeds several later layers; every consumer
        // sits at a higher index, so by the time layer i runs backward its
        // output gradient is fully accumulated.
        let mut douts: Vec<Vec<f32>> =
            man.layers[..n - 1].iter().map(|l| vec![0f32; b * l.out_f]).collect();
        douts.push(grad);
        for i in (0..n).rev() {
            let l = &man.layers[i];
            let (out_f, in_f) = (l.out_f, l.in_f);
            let tape = &tapes[i];
            let q = quant_out_of(man, i);
            // STE through the activation quantizer.
            let mut dy = std::mem::take(&mut douts[i]);
            for (g, &yv) in dy.iter_mut().zip(&tape.y) {
                *g *= ste_gate(q.bw, q.maxv, yv);
            }
            // BN backward (batch statistics).
            let mut dgamma = vec![0f32; out_f];
            let mut dbeta = vec![0f32; out_f];
            let mut dz = vec![0f32; b * out_f];
            for o in 0..out_f {
                let inv = 1.0 / (tape.var[o] + man.bn_eps).sqrt();
                let g = state.gammas[i][o];
                let mut sum_dzh = 0f32;
                let mut sum_dzh_zh = 0f32;
                for s in 0..b {
                    let dyv = dy[s * out_f + o];
                    dgamma[o] += dyv * tape.zhat[s * out_f + o];
                    dbeta[o] += dyv;
                    let dzh = dyv * g;
                    sum_dzh += dzh;
                    sum_dzh_zh += dzh * tape.zhat[s * out_f + o];
                }
                for s in 0..b {
                    let dzh = dy[s * out_f + o] * g;
                    dz[s * out_f + o] = inv
                        * (dzh - sum_dzh / b as f32
                            - tape.zhat[s * out_f + o] * sum_dzh_zh / b as f32);
                }
            }
            // Linear backward.
            let mut dw = vec![0f32; out_f * in_f];
            let mut db = vec![0f32; out_f];
            let mut dx = vec![0f32; b * in_f];
            let w = &state.ws[i];
            for s in 0..b {
                let xs = &tape.a_in[s * in_f..(s + 1) * in_f];
                let dzs = &dz[s * out_f..(s + 1) * out_f];
                let dxs = &mut dx[s * in_f..(s + 1) * in_f];
                for (o, &dzo) in dzs.iter().enumerate() {
                    db[o] += dzo;
                    let wrow = &w[o * in_f..(o + 1) * in_f];
                    let drow = &mut dw[o * in_f..(o + 1) * in_f];
                    for j in 0..in_f {
                        drow[j] += dzo * xs[j];
                        dxs[j] += dzo * wrow[j];
                    }
                }
            }
            // Off-mask weight gradients are structural zeros.
            let dense = state.masks[i].to_dense_f32();
            for (gv, m) in dw.iter_mut().zip(&dense) {
                if *m == 0.0 {
                    *gv = 0.0;
                }
            }
            // Conv weight sharing: sum each kernel tap's gradient over its
            // pixel group and scatter the sum back, so every tied weight
            // sees the identical gradient (and therefore identical velocity
            // and update — the group stays exactly tied all run).
            if let Some((wins, c_out, window)) = conv_ties.get(i) {
                let mut kg = vec![0f32; c_out * window];
                for (o, win) in wins.iter().enumerate() {
                    let oc = o % c_out;
                    for &(slot, j) in win {
                        kg[oc * window + slot] += dw[o * in_f + j];
                    }
                }
                for (o, win) in wins.iter().enumerate() {
                    let oc = o % c_out;
                    for &(slot, j) in win {
                        dw[o * in_f + j] = kg[oc * window + slot];
                    }
                }
            }
            clip_grad(&mut dw);
            clip_grad(&mut db);
            clip_grad(&mut dgamma);
            clip_grad(&mut dbeta);
            if needs_grads {
                let alpha = opts.momentum_alpha;
                for (m, g) in state.momentum_m[i].iter_mut().zip(&dw) {
                    *m = alpha * *m + (1.0 - alpha) * g;
                }
            }
            grads[i] = Some(LayerGrads { w: dw, b: db, gamma: dgamma, beta: dbeta });
            // Route the concatenated-input gradient back into each source
            // activation (same newest-first segment order as the forward
            // concat).  Segment j > 0 is layer j-1's quantized output;
            // segment 0 is the raw input, whose gradient is discarded.
            let lo = i.saturating_sub(man.skips);
            let mut off = 0usize;
            for j in (lo..=i).rev() {
                let w = act_widths[j];
                if j >= 1 {
                    let d = &mut douts[j - 1];
                    for s in 0..b {
                        for (t, &dv) in
                            dx[s * in_f + off..s * in_f + off + w].iter().enumerate()
                        {
                            d[s * w + t] += dv;
                        }
                    }
                }
                off += w;
            }
            debug_assert_eq!(off, in_f, "layer {i} segment split");
        }

        // ---------------- SGD + momentum update ---------------------------
        let mu_v = man.momentum;
        for i in 0..n {
            let g = grads[i].take().expect("layer grads");
            for ((wv, vv), gv) in
                state.ws[i].iter_mut().zip(state.vws[i].iter_mut()).zip(&g.w)
            {
                *vv = mu_v * *vv + gv;
                *wv -= lr * *vv;
            }
            for ((bv, vv), gv) in
                state.bs[i].iter_mut().zip(state.vbs[i].iter_mut()).zip(&g.b)
            {
                *vv = mu_v * *vv + gv;
                *bv -= lr * *vv;
            }
            for ((gm, vv), gv) in
                state.gammas[i].iter_mut().zip(state.vgammas[i].iter_mut()).zip(&g.gamma)
            {
                *vv = mu_v * *vv + gv;
                *gm -= lr * *vv;
            }
            for ((be, vv), gv) in
                state.betas[i].iter_mut().zip(state.vbetas[i].iter_mut()).zip(&g.beta)
            {
                *vv = mu_v * *vv + gv;
                *be -= lr * *vv;
            }
            state.apply_mask(i);
            // Running BN statistics (EMA over batch stats).
            for (r, bm) in state.rmeans[i].iter_mut().zip(&tapes[i].mu) {
                *r = opts.bn_ema * *r + (1.0 - opts.bn_ema) * bm;
            }
            for (r, bv) in state.rvars[i].iter_mut().zip(&tapes[i].var) {
                *r = opts.bn_ema * *r + (1.0 - opts.bn_ema) * bv;
            }
        }

        // ---------------- pruning schedules --------------------------------
        // Conv layers (indices < conv_ties.len()) are never pruned: their
        // structured receptive-field mask is the architecture itself.
        if !matches!(opts.method, PruneMethod::APriori) {
            for i in conv_ties.len()..n {
                let event = match opts.method {
                    PruneMethod::Iterative { every } | PruneMethod::Momentum { every, .. } => {
                        prune_event(step, every)
                    }
                    PruneMethod::APriori => false,
                };
                if !event {
                    continue;
                }
                // Split borrow: ws/momentum_m read-only, masks mutable —
                // disjoint fields, no tensor copies in the train loop.
                let changed = pruners[i].on_step(
                    step,
                    opts.steps,
                    &state.ws[i],
                    &state.momentum_m[i],
                    &mut state.masks[i],
                );
                if changed {
                    state.apply_mask(i);
                    log.mask_updates += 1;
                }
            }
        }

        if should_log(step, opts.steps, opts.log_every) {
            log.losses.push((step, loss));
            if opts.verbose {
                eprintln!("native step {step:5}  loss {loss:.4}  lr {lr:.4}");
            }
        }
        log.final_loss = loss;
    }

    log.steps = opts.steps;
    log.seconds = t0.elapsed().as_secs_f64();
    Ok(log)
}

/// Evaluate `state` on `test` through the exported pure-Rust mirror
/// (folded BN over *running* statistics — the same path truth tables,
/// synthesis and serving see).  Returns row-major logits `[n, classes]`.
pub fn evaluate_native(man: &Manifest, state: &ModelState, test: &DataSet) -> Vec<f32> {
    crate::nn::ExportedModel::from_state(man, state).forward_batch(&test.x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;

    fn man(hidden: &[usize], fanin: usize, bw: usize) -> Manifest {
        crate::runtime::Manifest::synthetic_mlp("native_t", "jets", 16, 5, hidden, fanin, bw)
    }

    fn man_skip(hidden: &[usize], fanin: usize, bw: usize, skips: usize) -> Manifest {
        crate::runtime::Manifest::synthetic_topology(
            "native_s", "jets", 16, 5, hidden, fanin, bw, skips,
        )
    }

    #[test]
    fn loss_decreases_on_jets() {
        let man = man(&[32], 3, 2);
        let ds = crate::hep::jets(2_000, 17);
        let mut st = ModelState::init(&man, 17, PruneMethod::APriori);
        let mut opts = TrainOpts::from_manifest(&man);
        opts.steps = 120;
        opts.log_every = 10;
        let log = train_native(&man, &mut st, &ds, &opts).unwrap();
        assert_eq!(log.steps, 120);
        let first = log.losses.first().unwrap().1;
        assert!(
            log.final_loss < first,
            "loss should drop: {first} -> {}",
            log.final_loss
        );
        assert!(log.final_loss.is_finite());
        // Training must beat chance on the training distribution.
        let logits = evaluate_native(&man, &st, &ds);
        let acc = metrics::accuracy(&logits, &ds.y, man.classes);
        assert!(acc > 0.30, "trained accuracy {acc} is not above chance");
    }

    #[test]
    fn masks_are_respected_throughout() {
        let man = man(&[24, 24], 3, 2);
        let ds = crate::hep::jets(600, 5);
        let mut st = ModelState::init(&man, 5, PruneMethod::APriori);
        let masks_before = st.masks.clone();
        let mut opts = TrainOpts::from_manifest(&man);
        opts.steps = 30;
        train_native(&man, &mut st, &ds, &opts).unwrap();
        // A-priori masks never move, and off-mask weights stay zero.
        assert_eq!(st.masks, masks_before);
        for i in 0..st.num_layers() {
            let dense = st.masks[i].to_dense_f32();
            for (w, m) in st.ws[i].iter().zip(&dense) {
                if *m == 0.0 {
                    assert_eq!(*w, 0.0, "off-mask weight updated in layer {i}");
                }
            }
        }
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let man = man(&[16], 2, 2);
        let ds = crate::hep::jets(400, 9);
        let run = |seed: u64| {
            let mut st = ModelState::init(&man, seed, PruneMethod::APriori);
            let mut opts = TrainOpts::from_manifest(&man);
            opts.steps = 25;
            opts.seed = seed;
            train_native(&man, &mut st, &ds, &opts).unwrap();
            st.ws.clone()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    #[test]
    fn skip_pyramid_training_learns() {
        // skips=1 over tapered widths: the region the paper's best
        // topologies live in.  The trainer must converge and beat chance
        // through the exact export path serving uses.
        let man = man_skip(&[32, 16], 3, 2, 1);
        assert_eq!(man.layers[1].in_f, 32 + 16, "skip-widened hidden input");
        let ds = crate::hep::jets(2_000, 23);
        let mut st = ModelState::init(&man, 23, PruneMethod::APriori);
        let mut opts = TrainOpts::from_manifest(&man);
        opts.steps = 120;
        opts.log_every = 10;
        let log = train_native(&man, &mut st, &ds, &opts).unwrap();
        let first = log.losses.first().unwrap().1;
        assert!(
            log.final_loss < first,
            "skip loss should drop: {first} -> {}",
            log.final_loss
        );
        assert!(log.final_loss.is_finite());
        let logits = evaluate_native(&man, &st, &ds);
        let acc = metrics::accuracy(&logits, &ds.y, man.classes);
        assert!(acc > 0.30, "skip-trained accuracy {acc} is not above chance");
    }

    #[test]
    fn skip_training_deterministic_and_mask_respecting() {
        let man = man_skip(&[16, 8], 2, 2, 2);
        let ds = crate::hep::jets(400, 11);
        let run = |seed: u64| {
            let mut st = ModelState::init(&man, seed, PruneMethod::APriori);
            let mut opts = TrainOpts::from_manifest(&man);
            opts.steps = 25;
            opts.seed = seed;
            train_native(&man, &mut st, &ds, &opts).unwrap();
            st
        };
        let a = run(6);
        assert_eq!(a.ws, run(6).ws);
        assert_ne!(a.ws, run(7).ws);
        for i in 0..a.num_layers() {
            let dense = a.masks[i].to_dense_f32();
            for (w, m) in a.ws[i].iter().zip(&dense) {
                if *m == 0.0 {
                    assert_eq!(*w, 0.0, "off-mask weight updated in layer {i}");
                }
            }
        }
    }

    #[test]
    fn rejects_manifest_with_stale_skip_widths() {
        // A manifest claiming skips=1 but carrying skip-free in_f must be
        // refused, not silently mis-wired.
        let mut man = man_skip(&[16, 16], 2, 2, 1);
        man.layers[1].in_f = 16;
        let ds = crate::hep::jets(100, 3);
        let mut st = ModelState::init(&man, 3, PruneMethod::APriori);
        let opts = TrainOpts::from_manifest(&man);
        assert!(train_native(&man, &mut st, &ds, &opts).is_err());
    }

    fn man_conv() -> Manifest {
        // jets' 16 features read as a 4x4 1-channel image: one dense-mode
        // conv stage (4 channels, 3x3 window subsampled to 4 taps), one
        // sparse hidden layer on the flattened map, dense head.
        Manifest::synthetic_conv(
            "native_c", "jets", 4, 1, 5, &[4], 3, "dense", Some(4), None, &[16], 3, 2,
        )
        .unwrap()
    }

    /// Assert layer 0's weights are exactly tied per (out-channel, slot)
    /// across all output pixels.
    fn assert_kernel_tied(man: &Manifest, st: &ModelState) {
        let g = &man.conv_geoms().unwrap()[0];
        let in_f = g.in_f();
        let mut by_slot = std::collections::HashMap::new();
        for (o, win) in g.neuron_windows().iter().enumerate() {
            let oc = o % g.c_out;
            for &(slot, j) in win {
                let w = st.ws[0][o * in_f + j];
                if let Some(p) = by_slot.insert((oc, slot), w) {
                    assert_eq!(p, w, "kernel untied after training (oc {oc} slot {slot})");
                }
            }
        }
    }

    #[test]
    fn conv_training_learns_and_stays_tied() {
        let man = man_conv();
        let ds = crate::hep::jets(2_000, 31);
        let mut st = ModelState::init(&man, 31, PruneMethod::APriori);
        let mut opts = TrainOpts::from_manifest(&man);
        opts.steps = 120;
        opts.log_every = 10;
        let log = train_native(&man, &mut st, &ds, &opts).unwrap();
        let first = log.losses.first().unwrap().1;
        assert!(log.final_loss < first, "conv loss should drop: {first} -> {}", log.final_loss);
        assert!(log.final_loss.is_finite());
        // Weight sharing held exactly through every update.
        assert_kernel_tied(&man, &st);
        // The structured mask never moved and off-mask weights stayed zero.
        let g = &man.conv_geoms().unwrap()[0];
        assert_eq!(st.masks[0].rows, g.mask_rows());
        let logits = evaluate_native(&man, &st, &ds);
        let acc = metrics::accuracy(&logits, &ds.y, man.classes);
        assert!(acc > 0.30, "conv-trained accuracy {acc} is not above chance");
    }

    #[test]
    fn conv_training_deterministic_and_never_pruned() {
        let man = man_conv();
        let ds = crate::hep::jets(400, 19);
        let run = |seed: u64, method: PruneMethod| {
            let mut st = ModelState::init(&man, seed, method);
            let mut opts = TrainOpts::from_manifest(&man);
            opts.steps = 25;
            opts.seed = seed;
            opts.method = method;
            train_native(&man, &mut st, &ds, &opts).unwrap();
            st
        };
        let a = run(6, PruneMethod::APriori);
        assert_eq!(a.ws, run(6, PruneMethod::APriori).ws);
        assert_ne!(a.ws, run(7, PruneMethod::APriori).ws);
        // Iterative pruning must leave the conv layer's structured mask
        // alone while still pruning the MLP layers toward target fan-in.
        let it = run(6, PruneMethod::Iterative { every: 5 });
        let g = &man.conv_geoms().unwrap()[0];
        assert_eq!(it.masks[0].rows, g.mask_rows(), "conv mask pruned");
        assert_kernel_tied(&man, &it);
    }

    #[test]
    fn iterative_pruning_reaches_target_fanin() {
        let man = man(&[16], 3, 2);
        let ds = crate::hep::jets(400, 13);
        let mut st = ModelState::init(&man, 13, PruneMethod::Iterative { every: 5 });
        assert!(st.masks[0].is_dense(), "iterative starts dense");
        let mut opts = TrainOpts::from_manifest(&man);
        opts.steps = 60;
        opts.method = PruneMethod::Iterative { every: 5 };
        let log = train_native(&man, &mut st, &ds, &opts).unwrap();
        assert!(log.mask_updates > 0, "iterative pruning must fire");
        assert!(st.masks[0].max_fanin() < 16, "fan-in must shrink from dense");
    }
}
