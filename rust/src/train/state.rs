//! Host-side model state: parameters, optimizer velocity, masks, running
//! batch-norm statistics and the smoothed-gradient buffer.  This is the
//! single source of truth between train steps; the HLO executables are pure
//! functions over it.

use crate::runtime::{ConvGeom, LayerKind, Manifest};
use crate::sparsity::prune::PruneMethod;
use crate::sparsity::Mask;
use crate::util::rng::Rng;

#[derive(Clone)]
pub struct ModelState {
    pub layer_dims: Vec<(usize, usize)>, // (out_f, in_f)
    pub ws: Vec<Vec<f32>>,
    pub bs: Vec<Vec<f32>>,
    pub gammas: Vec<Vec<f32>>,
    pub betas: Vec<Vec<f32>>,
    pub vws: Vec<Vec<f32>>,
    pub vbs: Vec<Vec<f32>>,
    pub vgammas: Vec<Vec<f32>>,
    pub vbetas: Vec<Vec<f32>>,
    pub masks: Vec<Mask>,
    pub rmeans: Vec<Vec<f32>>,
    pub rvars: Vec<Vec<f32>>,
    /// Exponentially smoothed |grad| buffer for sparse-momentum pruning.
    pub momentum_m: Vec<Vec<f32>>,
}

impl ModelState {
    /// Initialize parameters (He-style, scaled by effective fan-in) and the
    /// connectivity masks for the chosen pruning method:
    /// * `APriori` / `Momentum` — random expander masks at target fan-in,
    /// * `Iterative` — dense masks (pruned down during training),
    /// * conv layers (any method) — the *structured* receptive-field mask
    ///   from [`ConvGeom::mask_rows`] with weight-tied kernel init: every
    ///   output pixel of a channel starts from the same shared kernel, and
    ///   `train::native` keeps the group tied by summing its gradients.
    pub fn init(man: &Manifest, seed: u64, method: PruneMethod) -> ModelState {
        let mut rng = Rng::new(seed ^ 0x6c6f676e); // "logn"
        let n = man.num_layers();
        // Conv geometries per layer index (empty map for MLPs).  A manifest
        // that reaches init has already passed parse/construction-time conv
        // validation, so the unwrap-to-empty fallback only hides the
        // already-rejected case.
        let geoms: Vec<ConvGeom> = man
            .layer_kinds()
            .map(|kinds| {
                kinds
                    .into_iter()
                    .filter_map(|k| match k {
                        LayerKind::Conv(g) => Some(g),
                        _ => None,
                    })
                    .collect()
            })
            .unwrap_or_default();
        let mut st = ModelState {
            layer_dims: man.layers.iter().map(|l| (l.out_f, l.in_f)).collect(),
            ws: Vec::new(),
            bs: Vec::new(),
            gammas: Vec::new(),
            betas: Vec::new(),
            vws: Vec::new(),
            vbs: Vec::new(),
            vgammas: Vec::new(),
            vbetas: Vec::new(),
            masks: Vec::new(),
            rmeans: Vec::new(),
            rvars: Vec::new(),
            momentum_m: Vec::new(),
        };
        for i in 0..n {
            let l = &man.layers[i];
            let (out_f, in_f) = (l.out_f, l.in_f);
            // Conv layers (a manifest prefix) always get their structured
            // mask — the receptive field is the architecture, never pruned.
            let conv = geoms.get(i);
            let mask = match (conv, l.fanin, method) {
                (Some(g), _, _) => Mask { out_f, in_f, rows: g.mask_rows() },
                (None, None, _) => Mask::dense(out_f, in_f),
                (None, Some(_), PruneMethod::Iterative { .. }) => Mask::dense(out_f, in_f),
                (None, Some(f), _) => Mask::random(out_f, in_f, f, &mut rng.fork(i as u64)),
            };
            let eff_fanin = mask.rows.iter().map(|r| r.len()).max().unwrap_or(in_f);
            let std = (2.0 / eff_fanin.max(1) as f32).sqrt();
            let mut w = vec![0f32; out_f * in_f];
            // Initialize only on-mask entries; off-mask weights stay zero so
            // iterative pruning restarts cleanly from any mask.
            if let Some(g) = conv {
                // Weight tying: one shared kernel per output channel, drawn
                // once and written into every pixel of that channel (via the
                // slot -> input-index map, so truncated border rows reuse
                // the same taps' values).
                let mut lrng = rng.fork(i as u64);
                let kern: Vec<f32> = (0..g.c_out * g.window())
                    .map(|_| lrng.normal_f32(0.0, std))
                    .collect();
                for (o, win) in g.neuron_windows().iter().enumerate() {
                    let oc = o % g.c_out;
                    for &(slot, j) in win {
                        w[o * in_f + j] = kern[oc * g.window() + slot];
                    }
                }
            } else {
                for (o, row) in mask.rows.iter().enumerate() {
                    for &j in row {
                        w[o * in_f + j] = rng.normal_f32(0.0, std);
                    }
                }
            }
            st.ws.push(w);
            st.bs.push(vec![0.0; out_f]);
            st.gammas.push(vec![1.0; out_f]);
            st.betas.push(vec![0.0; out_f]);
            st.vws.push(vec![0.0; out_f * in_f]);
            st.vbs.push(vec![0.0; out_f]);
            st.vgammas.push(vec![0.0; out_f]);
            st.vbetas.push(vec![0.0; out_f]);
            st.rmeans.push(vec![0.0; out_f]);
            st.rvars.push(vec![1.0; out_f]);
            st.momentum_m.push(vec![0.0; out_f * in_f]);
            st.masks.push(mask);
        }
        st
    }

    /// Literal shape for the `layer`-th tensor of a parameter group, keyed by
    /// buffer length (weights are 2-D, everything else is 1-D).
    pub fn shape(&self, layer: usize, len: usize) -> Vec<i64> {
        let (out_f, in_f) = self.layer_dims[layer];
        if len == out_f * in_f && in_f != 1 {
            vec![out_f as i64, in_f as i64]
        } else {
            debug_assert_eq!(len, out_f);
            vec![out_f as i64]
        }
    }

    /// Zero every off-mask weight and velocity entry of layer `i` (called
    /// after a pruning step rewrites the mask).
    pub fn apply_mask(&mut self, i: usize) {
        let (out_f, in_f) = self.layer_dims[i];
        let dense = self.masks[i].to_dense_f32();
        debug_assert_eq!(dense.len(), out_f * in_f);
        for (idx, m) in dense.iter().enumerate() {
            if *m == 0.0 {
                self.ws[i][idx] = 0.0;
                self.vws[i][idx] = 0.0;
            }
        }
    }

    pub fn num_layers(&self) -> usize {
        self.layer_dims.len()
    }

    pub fn param_count(&self) -> usize {
        self.ws.iter().map(|w| w.len()).sum::<usize>()
            + self.bs.iter().map(|b| b.len()).sum::<usize>() * 3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn man() -> Manifest {
        Manifest::parse(
            r#"{
          "name":"t","kind":"mlp","in_features":16,"classes":5,"hidden":[32],
          "bw":2,"bw_in":2,"bw_out":2,"fanin":3,"fanin_fc":null,"skips":0,
          "batch":64,"eval_batch":128,"dataset":"jets",
          "layers":[{"in":16,"out":32,"fanin":3,"bw_in":2,"maxv_in":1.0},
                    {"in":32,"out":5,"fanin":null,"bw_in":2,"maxv_in":2.0}]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn init_respects_masks() {
        let st = ModelState::init(&man(), 1, PruneMethod::APriori);
        assert_eq!(st.num_layers(), 2);
        // layer 0: exactly 3 nonzero weights per neuron
        for o in 0..32 {
            let nz = (0..16).filter(|j| st.ws[0][o * 16 + j] != 0.0).count();
            assert_eq!(nz, 3);
        }
        // dense final layer: all weights initialized
        assert!(st.ws[1].iter().all(|&w| w != 0.0));
    }

    #[test]
    fn iterative_starts_dense() {
        let st = ModelState::init(
            &man(),
            1,
            PruneMethod::Iterative { every: 10 },
        );
        assert!(st.masks[0].is_dense());
    }

    #[test]
    fn apply_mask_zeroes_offmask() {
        let mut st = ModelState::init(&man(), 2, PruneMethod::APriori);
        st.ws[0].iter_mut().for_each(|w| *w = 1.0);
        st.vws[0].iter_mut().for_each(|v| *v = 1.0);
        st.apply_mask(0);
        let dense = st.masks[0].to_dense_f32();
        for (i, m) in dense.iter().enumerate() {
            if *m == 0.0 {
                assert_eq!(st.ws[0][i], 0.0);
                assert_eq!(st.vws[0][i], 0.0);
            } else {
                assert_eq!(st.ws[0][i], 1.0);
            }
        }
    }

    #[test]
    fn shapes() {
        let st = ModelState::init(&man(), 3, PruneMethod::APriori);
        assert_eq!(st.shape(0, 32 * 16), vec![32, 16]);
        assert_eq!(st.shape(0, 32), vec![32]);
    }

    #[test]
    fn conv_init_structured_mask_and_tied_kernels() {
        let cman = Manifest::synthetic_conv(
            "c", "jets", 6, 1, 5, &[3], 3, "dense", Some(4), None, &[8], 3, 2,
        )
        .unwrap();
        // The structured mask is installed for every prune method — the
        // receptive field is the architecture, not a prunable choice.
        for method in [PruneMethod::APriori, PruneMethod::Iterative { every: 10 }] {
            let st = ModelState::init(&cman, 7, method);
            let g = &cman.conv_geoms().unwrap()[0];
            assert_eq!(st.masks[0].rows, g.mask_rows());
            // Tied init: every output pixel of a channel shares the kernel —
            // same slot => same initial weight, across all pixels.
            let in_f = g.in_f();
            let wins = g.neuron_windows();
            let mut by_slot = std::collections::HashMap::new();
            for (o, win) in wins.iter().enumerate() {
                let oc = o % g.c_out;
                for &(slot, j) in win {
                    let w = st.ws[0][o * in_f + j];
                    assert!(w != 0.0, "on-mask conv weight initialized");
                    let prev = by_slot.insert((oc, slot), w);
                    if let Some(p) = prev {
                        assert_eq!(p, w, "kernel tied across pixels (oc {oc} slot {slot})");
                    }
                }
            }
            // Post-conv MLP layers keep their usual init.
            assert!(st.masks[1].rows.iter().all(|r| r.len() == 3));
        }
    }
}
