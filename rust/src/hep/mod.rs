//! Synthetic jet-substructure dataset (the FPGA4HEP substitution).
//!
//! The paper evaluates on the hls4ml LHC jet tagging set: 16 high-level
//! substructure observables, 5 classes (quark q, gluon g, W, Z, top t).
//! That data is not available offline, so we generate a class-conditional
//! Gaussian-mixture surrogate with the *same confusion structure*:
//!
//! * q and g are close (hardest pair — as in the paper's confusion matrix),
//! * W and Z are close (boson masses differ by ~11 GeV only),
//! * t is the most separable class,
//!
//! tuned so that a small trained model lands in the paper's 0.85-0.93
//! AUC-ROC band.  Features are min-max normalized to [0,1], matching the
//! input quantizer contract (maxv_in = 1.0).

use crate::data::DataSet;
use crate::util::rng::Rng;

pub const NUM_FEATURES: usize = 16;
pub const NUM_CLASSES: usize = 5;
pub const CLASS_NAMES: [&str; 5] = ["g", "q", "W", "Z", "t"];

/// Distance of class prototypes from the origin (separability knob).
const SEP: f32 = 2.0;
/// Offset within the (g,q) and (W,Z) confusable pairs.
const PAIR_OFF: f32 = 0.9;
/// Per-class residual covariance scale.
const NOISE: f32 = 0.95;

/// Class prototype means in feature space.
fn prototypes(rng: &mut Rng) -> Vec<[f32; NUM_FEATURES]> {
    // Draw three well-separated anchor directions (g/q pair, W/Z pair, t),
    // then split the pairs by a smaller offset.
    let mut anchor = |scale: f32| {
        let mut v = [0f32; NUM_FEATURES];
        for x in v.iter_mut() {
            *x = rng.normal_f32(0.0, 1.0);
        }
        let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
        for x in v.iter_mut() {
            *x *= scale / norm;
        }
        v
    };
    let a_qg = anchor(SEP);
    let a_wz = anchor(SEP);
    let a_t = anchor(SEP * 1.5);
    let d_qg = anchor(PAIR_OFF);
    let d_wz = anchor(PAIR_OFF);
    let add = |a: &[f32; NUM_FEATURES], b: &[f32; NUM_FEATURES], s: f32| {
        let mut v = [0f32; NUM_FEATURES];
        for i in 0..NUM_FEATURES {
            v[i] = a[i] + s * b[i];
        }
        v
    };
    vec![
        add(&a_qg, &d_qg, -0.5), // g
        add(&a_qg, &d_qg, 0.5),  // q
        add(&a_wz, &d_wz, -0.5), // W
        add(&a_wz, &d_wz, 0.5),  // Z
        a_t,                     // t
    ]
}

/// Generate `n` jets with balanced classes.  `seed` controls both the class
/// geometry and the sampling, so the same seed reproduces the same dataset.
pub fn jets(n: usize, seed: u64) -> DataSet {
    let mut rng = Rng::new(seed ^ 0x4a45_5453); // "JETS"
    let protos = prototypes(&mut rng.fork(1));
    // Shared mixing matrix: correlated features as in real substructure
    // observables (masses, N-subjettiness ratios, energy correlations).
    let mut mix = [[0f32; NUM_FEATURES]; NUM_FEATURES];
    let mut mrng = rng.fork(2);
    for (i, row) in mix.iter_mut().enumerate() {
        for (j, m) in row.iter_mut().enumerate() {
            *m = if i == j { 0.85 } else { mrng.normal_f32(0.0, 0.12) };
        }
    }
    let mut srng = rng.fork(3);
    let mut x = Vec::with_capacity(n * NUM_FEATURES);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % NUM_CLASSES;
        let mut z = [0f32; NUM_FEATURES];
        for zi in z.iter_mut() {
            *zi = srng.normal_f32(0.0, NOISE);
        }
        for r in 0..NUM_FEATURES {
            let mut v = protos[c][r];
            for (k, zk) in z.iter().enumerate() {
                v += mix[r][k] * zk;
            }
            // Heavier tails on a few "multiplicity-like" features.
            if r % 5 == 0 {
                v += 0.3 * z[r] * z[r].abs();
            }
            x.push(v);
        }
        y.push(c as i32);
    }
    let mut ds = DataSet::new(x, y, NUM_FEATURES, NUM_CLASSES);
    ds.normalize_unit();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_and_normalized() {
        let ds = jets(500, 7);
        assert_eq!(ds.n, 500);
        assert_eq!(ds.d, NUM_FEATURES);
        let mut counts = [0usize; NUM_CLASSES];
        for &c in &ds.y {
            counts[c as usize] += 1;
        }
        assert_eq!(counts, [100; 5]);
        assert!(ds.x.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = jets(100, 3);
        let b = jets(100, 3);
        assert_eq!(a.x, b.x);
        let c = jets(100, 4);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn classes_are_separable_by_centroid_rule() {
        // Nearest-centroid accuracy must be well above chance (0.2) but
        // below 1.0 — the paper's models live in the 0.7-0.8 accuracy band.
        let ds = jets(2000, 11);
        let mut cent = vec![vec![0f32; ds.d]; NUM_CLASSES];
        let mut cnt = [0f32; NUM_CLASSES];
        for i in 0..ds.n {
            let c = ds.y[i] as usize;
            cnt[c] += 1.0;
            for j in 0..ds.d {
                cent[c][j] += ds.row(i)[j];
            }
        }
        for c in 0..NUM_CLASSES {
            for j in 0..ds.d {
                cent[c][j] /= cnt[c];
            }
        }
        let mut correct = 0;
        for i in 0..ds.n {
            let mut best = (f32::INFINITY, 0);
            for (c, ce) in cent.iter().enumerate() {
                let d2: f32 =
                    ds.row(i).iter().zip(ce).map(|(a, b)| (a - b) * (a - b)).sum();
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            if best.1 == ds.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n as f64;
        assert!(acc > 0.55 && acc < 0.98, "centroid acc {acc}");
    }
}
