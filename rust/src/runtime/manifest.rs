//! Model manifest: the topology contract shared by every producer — the
//! HLO artifact path (`python/compile/aot.py`, parsed from
//! `artifacts/<model>/manifest.json`), the synthetic constructors the DSE
//! generates, and the zoo's rebuild path.
//!
//! The manifest pins the *flattened* input/output ordering of the entry
//! points (see the module docstring of python/compile/model.py) plus every
//! quantizer constant the export path (truth tables) must reproduce.  Two
//! layer families are first-class:
//!
//! * `kind = "mlp"` — sparse/dense linear layers with optional
//!   newest-first skip concatenation ([`Manifest::skip_in_widths`]).
//! * `kind = "cnv"` — convolutional stages lowered to per-output-pixel
//!   boolean neurons: each conv layer is *unrolled* in `layers` (one
//!   `LayerSpec` whose `in_f`/`out_f` are the flattened pixel×channel
//!   widths), and its weight sharing + local connectivity become a
//!   deterministic structured-sparsity mask ([`ConvGeom::neuron_windows`])
//!   feeding the exact same per-neuron truth-table enumeration as MLP
//!   layers.  The CNN extras (`conv_mode`, `image_hw`, `channels`,
//!   `kernel_size`, `fanin_dw`/`fanin_pw`) are validated at parse time and
//!   drive training, costing, synthesis and serving natively — they are no
//!   longer an HLO-artifact-only annotation.

use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, ensure, Context, Result};

/// Seed base for the deterministic conv window subsampling: every
/// reconstruction of the same manifest (trainer, cost model, DSE gate,
/// synth check, zoo rebuild) derives the identical kept-tap subsets.
const CONV_SUBSAMPLE_SEED: u64 = 0xC0_4Af0_1D;

/// Exact per-neuron geometry of one lowered convolutional layer.  A conv
/// stage is *unrolled*: every output pixel × channel becomes one boolean
/// neuron whose fan-in is the kept subset of its receptive-field window
/// (SAME padding, border taps truncated — equivalent to zero padding since
/// quantizer code 0 decodes to value 0).  Activations are pixel-major:
/// `idx = (y * h + x) * c + channel`.
///
/// Every reconstruction of this struct from the same manifest produces
/// byte-identical windows (seeded subsampling, deterministic slot order),
/// which is what the `CONV_WINDOW_INCONSISTENT` lint rule checks and what
/// lets the DSE's analytical pricing match `synthesize` exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvGeom {
    /// Input image side (square images only).
    pub h_in: usize,
    /// Output image side: SAME padding, `(h_in - 1) / stride + 1`.
    pub h_out: usize,
    pub c_in: usize,
    pub c_out: usize,
    /// Kernel side (odd, `<= h_in`).
    pub k: usize,
    pub stride: usize,
    /// Depthwise: each output channel reads only its own input channel.
    pub depthwise: bool,
    /// Kept taps per neuron after seeded subsampling (`<=` full window).
    pub window_fanin: usize,
    /// Seed of the per-output-channel tap subsets.
    pub seed: u64,
}

impl ConvGeom {
    /// Flattened input width of the lowered layer.
    pub fn in_f(&self) -> usize {
        self.h_in * self.h_in * self.c_in
    }

    /// Flattened output width (neuron count) of the lowered layer.
    pub fn out_f(&self) -> usize {
        self.h_out * self.h_out * self.c_out
    }

    /// Full receptive-field window size before subsampling.
    pub fn window(&self) -> usize {
        if self.depthwise {
            self.k * self.k
        } else {
            self.k * self.k * self.c_in
        }
    }

    /// Window slot -> (dy, dx, input channel).  Slots enumerate the window
    /// in (dy, dx, ci) lexicographic order, which maps monotonically onto
    /// pixel-major input indices — per-neuron rows come out sorted, the
    /// invariant `sparsity::Mask` requires.
    fn slot_coords(&self, slot: usize, oc: usize) -> (usize, usize, usize) {
        if self.depthwise {
            (slot / self.k, slot % self.k, oc)
        } else {
            let ci = slot % self.c_in;
            let pix = slot / self.c_in;
            (pix / self.k, pix % self.k, ci)
        }
    }

    /// Sorted kept slot indices (into the full window) for output channel
    /// `oc`.  Shared across every output pixel of that channel — this
    /// sharing *is* the weight-sharing invariant the conv lint rule checks.
    pub fn kept_slots(&self, oc: usize) -> Vec<usize> {
        let w = self.window();
        if self.window_fanin >= w {
            return (0..w).collect();
        }
        Rng::new(self.seed).fork(oc as u64).choose_k(w, self.window_fanin)
    }

    /// Per-neuron `(slot, input index)` pairs, neurons in pixel-major
    /// output order.  Border neurons have fewer taps (truncated window);
    /// interior neurons have exactly `window_fanin`.
    pub fn neuron_windows(&self) -> Vec<Vec<(usize, usize)>> {
        let pad = self.k / 2;
        let kept: Vec<Vec<usize>> = (0..self.c_out).map(|oc| self.kept_slots(oc)).collect();
        let mut rows = Vec::with_capacity(self.out_f());
        for oy in 0..self.h_out {
            for ox in 0..self.h_out {
                for (oc, slots) in kept.iter().enumerate() {
                    let mut row = Vec::with_capacity(slots.len());
                    for &slot in slots {
                        let (dy, dx, ci) = self.slot_coords(slot, oc);
                        let iy = (oy * self.stride + dy) as isize - pad as isize;
                        let ix = (ox * self.stride + dx) as isize - pad as isize;
                        if iy < 0 || ix < 0 || iy >= self.h_in as isize || ix >= self.h_in as isize
                        {
                            continue;
                        }
                        row.push((slot, (iy as usize * self.h_in + ix as usize) * self.c_in + ci));
                    }
                    rows.push(row);
                }
            }
        }
        rows
    }

    /// The structured sparsity mask rows (sorted input indices per neuron)
    /// — what `ModelState::init` installs in place of a random mask.
    pub fn mask_rows(&self) -> Vec<Vec<usize>> {
        self.neuron_windows()
            .into_iter()
            .map(|w| w.into_iter().map(|(_, idx)| idx).collect())
            .collect()
    }

    /// Exact analytical LUT cost of the lowered layer: the per-neuron sum
    /// of `cost::lut_cost(kept_in_bounds_taps * bw_in, bw_out)`.  By
    /// construction equal to what `synth::synthesize` reports for the
    /// generated tables (same truncated windows), saturating like
    /// `cost::lut_cost` itself.
    pub fn lut_cost(&self, bw_in: usize, bw_out: usize) -> u64 {
        let pad = self.k / 2;
        let mut total = 0u64;
        for oc in 0..self.c_out {
            let kept = self.kept_slots(oc);
            for oy in 0..self.h_out {
                for ox in 0..self.h_out {
                    let taps = kept
                        .iter()
                        .filter(|&&slot| {
                            let (dy, dx, _) = self.slot_coords(slot, oc);
                            let iy = (oy * self.stride + dy) as isize - pad as isize;
                            let ix = (ox * self.stride + dx) as isize - pad as isize;
                            iy >= 0
                                && ix >= 0
                                && (iy as usize) < self.h_in
                                && (ix as usize) < self.h_in
                        })
                        .count();
                    total = total.saturating_add(crate::cost::lut_cost(taps * bw_in, bw_out));
                }
            }
        }
        total
    }
}

/// Heterogeneous layer classification — the single width/pricing/mask
/// accounting shared by `cost::manifest_cost`, the DSE cost gate,
/// `train::state` and `synth`, so gate and exact pricing cannot diverge
/// (the PR 5 invariant, extended to conv).
#[derive(Debug, Clone)]
pub enum LayerKind {
    /// Random-mask sparse layer with a uniform per-neuron fan-in.
    Sparse { fanin: usize },
    /// Dense (unsparsified) layer — the classifier head.
    Dense,
    /// Lowered convolutional layer with a structured receptive-field mask.
    Conv(ConvGeom),
}

/// One linear (or conv stage) layer as seen by the HLO artifact.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    /// Input width (already accounts for skip concatenation).
    pub in_f: usize,
    /// Output width (neuron count).
    pub out_f: usize,
    /// Per-neuron fan-in in synapses; `None` = dense.
    pub fanin: Option<usize>,
    /// Bit-width of the quantizer applied to this layer's *input*.
    pub bw_in: usize,
    /// max_val of that input quantizer.
    pub maxv_in: f32,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub kind: String,
    pub in_features: usize,
    pub classes: usize,
    pub hidden: Vec<usize>,
    pub bw: usize,
    pub bw_in: usize,
    pub bw_out: usize,
    pub fanin: usize,
    pub fanin_fc: Option<usize>,
    pub skips: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub maxv_in: f32,
    pub maxv_hidden: f32,
    pub maxv_out: f32,
    pub momentum: f32,
    pub bn_eps: f32,
    pub dataset: String,
    pub train_softmax: bool,
    pub steps: usize,
    pub lr: f32,
    pub layers: Vec<LayerSpec>,
    // CNN extras (None for MLPs)
    pub conv_mode: Option<String>,
    pub image_hw: usize,
    pub channels: Vec<usize>,
    pub kernel_size: usize,
    pub fanin_dw: Option<usize>,
    pub fanin_pw: Option<usize>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest json")?;
        let layers = j
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow!("layers not array"))?
            .iter()
            .map(|l| {
                Ok(LayerSpec {
                    in_f: l.req_usize("in")?,
                    out_f: l.req_usize("out")?,
                    fanin: l.get("fanin").and_then(|v| v.as_usize()),
                    bw_in: l.req_usize("bw_in")?,
                    maxv_in: l.req_f64("maxv_in")? as f32,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let usv = |key: &str| -> Vec<usize> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default()
        };
        let man = Manifest {
            name: j.req_str("name")?.to_string(),
            kind: j.req_str("kind")?.to_string(),
            in_features: j.req_usize("in_features")?,
            classes: j.req_usize("classes")?,
            hidden: usv("hidden"),
            bw: j.req_usize("bw")?,
            bw_in: j.req_usize("bw_in")?,
            bw_out: j.req_usize("bw_out")?,
            fanin: j.req_usize("fanin")?,
            fanin_fc: j.get("fanin_fc").and_then(|v| v.as_usize()),
            skips: j.opt_usize("skips").unwrap_or(0),
            batch: j.req_usize("batch")?,
            eval_batch: j.req_usize("eval_batch")?,
            maxv_in: j.opt_f64("maxv_in", 1.0) as f32,
            maxv_hidden: j.opt_f64("maxv_hidden", 2.0) as f32,
            maxv_out: j.opt_f64("maxv_out", 4.0) as f32,
            momentum: j.opt_f64("momentum", 0.9) as f32,
            bn_eps: j.opt_f64("bn_eps", 1e-5) as f32,
            dataset: j.req_str("dataset")?.to_string(),
            train_softmax: j.opt_bool("train_softmax", true),
            steps: j.opt_usize("steps").unwrap_or(300),
            lr: j.opt_f64("lr", 0.02) as f32,
            layers,
            conv_mode: j.get("conv_mode").and_then(|v| v.as_str()).map(|s| s.to_string()),
            image_hw: j.opt_usize("image_hw").unwrap_or(28),
            channels: usv("channels"),
            kernel_size: j.opt_usize("kernel_size").unwrap_or(3),
            fanin_dw: j.get("fanin_dw").and_then(|v| v.as_usize()),
            fanin_pw: j.get("fanin_pw").and_then(|v| v.as_usize()),
        };
        if man.kind == "cnv" {
            man.validate_conv().context("conv manifest validation")?;
        }
        Ok(man)
    }

    pub fn load(path: &std::path::Path) -> Result<Manifest> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        Manifest::parse(&text)
    }

    /// Per-layer input widths of a skip-concat topology: layer `i`'s input
    /// is the newest-first concatenation of the last `min(skips, i) + 1`
    /// activations (`act_0` = the raw input, `act_j` = hidden layer `j-1`'s
    /// output), exactly the wiring `nn::export`, `luts::forward_codes` and
    /// `serve::engine` execute.  Returns one width per layer (hidden layers
    /// first, classifier head last).  This is the single source of truth
    /// for skip-widened `in_f`, shared by [`Manifest::synthetic_topology`]
    /// and the DSE cost gate so analytical pricing can never diverge from
    /// the manifest a candidate actually builds.
    pub fn skip_in_widths(in_features: usize, hidden: &[usize], skips: usize) -> Vec<usize> {
        let mut act_widths = Vec::with_capacity(hidden.len() + 1);
        act_widths.push(in_features);
        act_widths.extend_from_slice(hidden);
        (0..=hidden.len())
            .map(|i| {
                let lo = i.saturating_sub(skips);
                act_widths[lo..=i].iter().sum()
            })
            .collect()
    }

    /// [`Manifest::synthetic_topology`] without skip connections — the
    /// original uniform entry point, kept for callers that only speak the
    /// rectangle family.
    pub fn synthetic_mlp(
        name: &str,
        dataset: &str,
        in_features: usize,
        classes: usize,
        hidden: &[usize],
        fanin: usize,
        bw: usize,
    ) -> Manifest {
        Self::synthetic_topology(name, dataset, in_features, classes, hidden, fanin, bw, 0)
    }

    /// Build an in-memory MLP manifest with the repo's standard quantizer
    /// scales (maxv 1.0 / 2.0 / 4.0, as every hep/mnist config uses) — the
    /// entry point for *generated* models that have no artifact on disk.
    /// The design-space exploration engine (`crate::dse::search`) produces
    /// these, trains them through `train::native`, and feeds them into the
    /// exact same export → tables → synth → serve pipeline as artifact
    /// models.  Sparse hidden layers at `fanin`, dense classifier head.
    ///
    /// `hidden` may be any per-layer width schedule (rectangle, pyramid
    /// taper, …) and `skips` wires newest-first skip concatenation: each
    /// layer's `in_f` is widened by the earlier activations it consumes
    /// ([`Manifest::skip_in_widths`]), which is what `cost::manifest_cost`
    /// prices and `ModelState::init` allocates.
    pub fn synthetic_topology(
        name: &str,
        dataset: &str,
        in_features: usize,
        classes: usize,
        hidden: &[usize],
        fanin: usize,
        bw: usize,
        skips: usize,
    ) -> Manifest {
        let in_widths = Self::skip_in_widths(in_features, hidden, skips);
        let mut layers = Vec::with_capacity(hidden.len() + 1);
        for (i, &h) in hidden.iter().enumerate() {
            layers.push(LayerSpec {
                in_f: in_widths[i],
                out_f: h,
                fanin: Some(fanin.min(in_widths[i])),
                bw_in: bw,
                maxv_in: if i == 0 { 1.0 } else { 2.0 },
            });
        }
        layers.push(LayerSpec {
            in_f: in_widths[hidden.len()],
            out_f: classes,
            fanin: None,
            bw_in: bw,
            maxv_in: if hidden.is_empty() { 1.0 } else { 2.0 },
        });
        Manifest {
            name: name.to_string(),
            kind: "mlp".to_string(),
            in_features,
            classes,
            hidden: hidden.to_vec(),
            bw,
            bw_in: bw,
            bw_out: bw,
            fanin,
            fanin_fc: None,
            skips,
            batch: 64,
            eval_batch: 256,
            maxv_in: 1.0,
            maxv_hidden: 2.0,
            maxv_out: 4.0,
            momentum: 0.9,
            bn_eps: 1e-5,
            dataset: dataset.to_string(),
            train_softmax: true,
            steps: 300,
            lr: 0.03,
            layers,
            conv_mode: None,
            image_hw: 28,
            channels: Vec::new(),
            kernel_size: 3,
            fanin_dw: None,
            fanin_pw: None,
        }
    }

    /// The lowered conv-stage geometries for each stage listed in
    /// `channels` (empty for non-conv manifests).  `conv_mode = "dense"`
    /// lowers each stage to one stride-2 layer whose window is the full
    /// `k*k*c_in` receptive field (subsampled to `fanin_dw` taps);
    /// `"dw"` lowers to a depthwise stride-2 layer (`k*k` window, capped
    /// by `fanin_dw`) followed by a pointwise stride-1 layer (`c_in`
    /// window, capped by `fanin_pw`).
    pub fn conv_stage_geoms(
        image_hw: usize,
        in_c: usize,
        channels: &[usize],
        kernel: usize,
        conv_mode: &str,
        fanin_dw: Option<usize>,
        fanin_pw: Option<usize>,
    ) -> Result<Vec<ConvGeom>> {
        ensure!(
            !channels.is_empty(),
            "conv manifest needs a non-empty `channels` list (one out-channel count per stage)"
        );
        ensure!(kernel >= 1, "`kernel_size` must be >= 1, got {kernel}");
        ensure!(kernel % 2 == 1, "`kernel_size` must be odd for SAME padding, got {kernel}");
        let mut geoms: Vec<ConvGeom> = Vec::new();
        let (mut hw, mut c) = (image_hw, in_c);
        for (si, &c_out) in channels.iter().enumerate() {
            ensure!(c_out >= 1, "`channels[{si}]` must be >= 1, got 0");
            ensure!(
                kernel <= hw,
                "`kernel_size` {kernel} exceeds the stage-{si} image side {hw} \
                 (image_hw {image_hw} halves at each stride-2 stage)"
            );
            let h_mid = (hw - 1) / 2 + 1;
            match conv_mode {
                "dense" => {
                    let window = kernel * kernel * c;
                    geoms.push(ConvGeom {
                        h_in: hw,
                        h_out: h_mid,
                        c_in: c,
                        c_out,
                        k: kernel,
                        stride: 2,
                        depthwise: false,
                        window_fanin: fanin_dw.unwrap_or(window).min(window),
                        seed: CONV_SUBSAMPLE_SEED ^ geoms.len() as u64,
                    });
                }
                "dw" => {
                    let dw_window = kernel * kernel;
                    geoms.push(ConvGeom {
                        h_in: hw,
                        h_out: h_mid,
                        c_in: c,
                        c_out: c,
                        k: kernel,
                        stride: 2,
                        depthwise: true,
                        window_fanin: fanin_dw.unwrap_or(dw_window).min(dw_window),
                        seed: CONV_SUBSAMPLE_SEED ^ geoms.len() as u64,
                    });
                    geoms.push(ConvGeom {
                        h_in: h_mid,
                        h_out: h_mid,
                        c_in: c,
                        c_out,
                        k: 1,
                        stride: 1,
                        depthwise: false,
                        window_fanin: fanin_pw.unwrap_or(c).min(c),
                        seed: CONV_SUBSAMPLE_SEED ^ geoms.len() as u64,
                    });
                }
                other => bail!(
                    "unsupported `conv_mode` \"{other}\": expected \"dense\" \
                     (stride-2 full-window stage) or \"dw\" (depthwise + pointwise)"
                ),
            }
            hw = h_mid;
            c = c_out;
        }
        Ok(geoms)
    }

    /// The lowered conv geometries of this manifest — empty unless
    /// `kind == "cnv"`.
    pub fn conv_geoms(&self) -> Result<Vec<ConvGeom>> {
        if self.kind != "cnv" {
            return Ok(Vec::new());
        }
        let mode = self.conv_mode.as_deref().ok_or_else(|| {
            anyhow!("manifest kind \"cnv\" requires `conv_mode` (\"dense\" or \"dw\")")
        })?;
        ensure!(self.image_hw >= 1, "`image_hw` must be >= 1, got {}", self.image_hw);
        let hw2 = self.image_hw * self.image_hw;
        ensure!(
            self.in_features % hw2 == 0,
            "`in_features` {} is not divisible by image_hw^2 = {} — cannot infer input channels",
            self.in_features,
            hw2
        );
        Self::conv_stage_geoms(
            self.image_hw,
            self.in_features / hw2,
            &self.channels,
            self.kernel_size,
            mode,
            self.fanin_dw,
            self.fanin_pw,
        )
    }

    /// Classify every layer ([`LayerKind`]) — the shared accounting used
    /// by the cost model, the DSE gate, training and synthesis.  For conv
    /// manifests the leading layers are the lowered conv stages (validated
    /// against the declared dims); the rest are post-flatten MLP layers.
    pub fn layer_kinds(&self) -> Result<Vec<LayerKind>> {
        let geoms = self.conv_geoms()?;
        let mut kinds = Vec::with_capacity(self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            if let Some(g) = geoms.get(i) {
                ensure!(
                    l.in_f == g.in_f() && l.out_f == g.out_f(),
                    "conv layer {i}: declared {}x{} disagrees with geometry {}x{} \
                     (image_hw={}, kernel_size={}, channels={:?}, conv_mode={:?})",
                    l.in_f,
                    l.out_f,
                    g.in_f(),
                    g.out_f(),
                    self.image_hw,
                    self.kernel_size,
                    self.channels,
                    self.conv_mode
                );
                kinds.push(LayerKind::Conv(g.clone()));
            } else {
                kinds.push(match l.fanin {
                    Some(f) => LayerKind::Sparse { fanin: f.min(l.in_f) },
                    None => LayerKind::Dense,
                });
            }
        }
        Ok(kinds)
    }

    /// Parse-time validation of the CNN extras: every inconsistency fails
    /// here with an actionable message instead of deep inside synth.
    fn validate_conv(&self) -> Result<()> {
        let geoms = self.conv_geoms()?;
        ensure!(
            self.skips == 0,
            "conv manifests do not support skip connections (got skips={})",
            self.skips
        );
        let expect = geoms.len() + self.hidden.len() + 1;
        ensure!(
            self.layers.len() == expect,
            "conv manifest layer count mismatch: {} layers declared, but channels={:?} \
             (conv_mode {:?}) lowers to {} conv layers + {} hidden + 1 head = {}",
            self.layers.len(),
            self.channels,
            self.conv_mode,
            geoms.len(),
            self.hidden.len(),
            expect
        );
        for (i, g) in geoms.iter().enumerate() {
            let l = &self.layers[i];
            ensure!(
                l.in_f == g.in_f() && l.out_f == g.out_f(),
                "conv layer {i}: declared {}x{} but the geometry gives {}x{} \
                 (image_hw={}, kernel_size={}, channels={:?})",
                l.in_f,
                l.out_f,
                g.in_f(),
                g.out_f(),
                self.image_hw,
                self.kernel_size,
                self.channels
            );
            ensure!(
                l.fanin == Some(g.window_fanin),
                "conv layer {i}: `fanin` must equal the kept window fan-in {} (got {:?}) \
                 so the export path table-maps it",
                g.window_fanin,
                l.fanin
            );
            let in_bits = g.window_fanin * l.bw_in;
            ensure!(
                in_bits <= crate::luts::MAX_IN_BITS,
                "conv layer {i}: window fan-in {} x bw_in {} = {in_bits} table input bits \
                 exceeds the {}-bit enumeration cap — lower `fanin_dw`/`fanin_pw` or the \
                 bit-width",
                g.window_fanin,
                l.bw_in,
                crate::luts::MAX_IN_BITS
            );
        }
        Ok(())
    }

    /// Build an in-memory conv manifest (`kind = "cnv"`): `channels` conv
    /// stages lowered per [`ConvGeom`], then `hidden` sparse MLP layers on
    /// the flattened feature map, then a dense classifier head.  Conv
    /// `LayerSpec`s carry `fanin = Some(window_fanin)` so the export path
    /// table-maps them like any sparse layer; the *structured* mask itself
    /// is installed from [`ConvGeom::mask_rows`] at training time.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic_conv(
        name: &str,
        dataset: &str,
        image_hw: usize,
        in_c: usize,
        classes: usize,
        channels: &[usize],
        kernel: usize,
        conv_mode: &str,
        fanin_dw: Option<usize>,
        fanin_pw: Option<usize>,
        hidden: &[usize],
        fanin: usize,
        bw: usize,
    ) -> Result<Manifest> {
        let geoms =
            Self::conv_stage_geoms(image_hw, in_c, channels, kernel, conv_mode, fanin_dw, fanin_pw)?;
        let mut layers = Vec::with_capacity(geoms.len() + hidden.len() + 1);
        for (i, g) in geoms.iter().enumerate() {
            layers.push(LayerSpec {
                in_f: g.in_f(),
                out_f: g.out_f(),
                fanin: Some(g.window_fanin),
                bw_in: bw,
                maxv_in: if i == 0 { 1.0 } else { 2.0 },
            });
        }
        let mut width = geoms.last().map(|g| g.out_f()).unwrap_or(image_hw * image_hw * in_c);
        for &h in hidden {
            layers.push(LayerSpec {
                in_f: width,
                out_f: h,
                fanin: Some(fanin.min(width)),
                bw_in: bw,
                maxv_in: 2.0,
            });
            width = h;
        }
        layers.push(LayerSpec {
            in_f: width,
            out_f: classes,
            fanin: None,
            bw_in: bw,
            maxv_in: 2.0,
        });
        let man = Manifest {
            name: name.to_string(),
            kind: "cnv".to_string(),
            in_features: image_hw * image_hw * in_c,
            classes,
            hidden: hidden.to_vec(),
            bw,
            bw_in: bw,
            bw_out: bw,
            fanin,
            fanin_fc: None,
            skips: 0,
            batch: 64,
            eval_batch: 256,
            maxv_in: 1.0,
            maxv_hidden: 2.0,
            maxv_out: 4.0,
            momentum: 0.9,
            bn_eps: 1e-5,
            dataset: dataset.to_string(),
            train_softmax: true,
            steps: 300,
            lr: 0.03,
            layers,
            conv_mode: Some(conv_mode.to_string()),
            image_hw,
            channels: channels.to_vec(),
            kernel_size: kernel,
            fanin_dw,
            fanin_pw,
        };
        man.validate_conv()?;
        Ok(man)
    }

    /// Side length if `in_features` is a perfect square — conv stages
    /// interpret flat task inputs as a 1-channel `s x s` image.
    pub fn conv_image_side(in_features: usize) -> Option<usize> {
        let mut s = 0usize;
        while (s + 1) * (s + 1) <= in_features {
            s += 1;
        }
        (s >= 1 && s * s == in_features).then_some(s)
    }

    /// [`Manifest::synthetic_conv`] for a flat task input: interprets
    /// `in_features` as a 1-channel square image (errors when it is not a
    /// perfect square or the kernel does not fit) and caps the conv
    /// fan-in to what the table-width limit admits.  The single
    /// constructor shared by DSE conv candidates and zoo rebuilds, so a
    /// zoo entry always reproduces the candidate's manifest bit-exactly.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic_conv_for_task(
        name: &str,
        dataset: &str,
        in_features: usize,
        classes: usize,
        hidden: &[usize],
        fanin: usize,
        bw: usize,
        conv_mode: &str,
        channels: usize,
        kernel: usize,
    ) -> Result<Manifest> {
        let hw = Self::conv_image_side(in_features).ok_or_else(|| {
            anyhow!(
                "conv topology needs a square input: in_features {in_features} is not a \
                 perfect square"
            )
        })?;
        let cap = (crate::luts::MAX_IN_BITS / bw.max(1)).max(1);
        let f = fanin.min(cap);
        Self::synthetic_conv(
            name,
            dataset,
            hw,
            1,
            classes,
            &[channels],
            kernel,
            conv_mode,
            Some(f),
            Some(f),
            hidden,
            fanin,
            bw,
        )
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name":"t","kind":"mlp","in_features":16,"classes":5,"hidden":[32,32],
      "bw":2,"bw_in":2,"bw_out":2,"fanin":3,"fanin_fc":null,"skips":0,
      "batch":64,"eval_batch":128,"maxv_in":1.0,"maxv_hidden":2.0,"maxv_out":4.0,
      "momentum":0.9,"bn_eps":1e-05,"dataset":"jets","train_softmax":true,
      "steps":120,"lr":0.04,
      "layers":[{"in":16,"out":32,"fanin":3,"bw_in":2,"maxv_in":1.0},
                {"in":32,"out":32,"fanin":3,"bw_in":2,"maxv_in":2.0},
                {"in":32,"out":5,"fanin":null,"bw_in":2,"maxv_in":2.0}]
    }"#;

    #[test]
    fn synthetic_mlp_layer_wiring() {
        let m = Manifest::synthetic_mlp("g", "jets", 16, 5, &[32, 24], 3, 2);
        assert_eq!(m.num_layers(), 3);
        assert_eq!(m.layers[0].in_f, 16);
        assert_eq!(m.layers[0].out_f, 32);
        assert_eq!(m.layers[0].fanin, Some(3));
        assert_eq!(m.layers[0].maxv_in, 1.0);
        assert_eq!(m.layers[1].in_f, 32);
        assert_eq!(m.layers[1].maxv_in, 2.0);
        assert_eq!(m.layers[2].out_f, 5);
        assert_eq!(m.layers[2].fanin, None);
        assert_eq!(m.hidden, vec![32, 24]);
        assert_eq!(m.kind, "mlp");
        // Fan-in never exceeds the layer's input width.
        let wide = Manifest::synthetic_mlp("w", "jets", 4, 2, &[8], 7, 1);
        assert_eq!(wide.layers[0].fanin, Some(4));
    }

    #[test]
    fn synthetic_topology_skip_widened_wiring() {
        // skips=1, pyramid widths: layer 1 consumes [h0, input], the head
        // [h1, h0] — newest-first concat widths, matching nn::export.
        let m = Manifest::synthetic_topology("s", "jets", 16, 5, &[32, 16], 3, 2, 1);
        assert_eq!(m.skips, 1);
        assert_eq!(m.hidden, vec![32, 16]);
        assert_eq!(m.layers[0].in_f, 16);
        assert_eq!(m.layers[1].in_f, 32 + 16);
        assert_eq!(m.layers[2].in_f, 16 + 32);
        assert_eq!(m.layers[2].fanin, None);
        // skips larger than the depth clamps at the full history.
        let deep = Manifest::synthetic_topology("d", "jets", 8, 3, &[6, 4], 2, 1, 9);
        assert_eq!(deep.layers[1].in_f, 6 + 8);
        assert_eq!(deep.layers[2].in_f, 4 + 6 + 8);
        // skips=0 reduces to the plain constructor exactly.
        let a = Manifest::synthetic_topology("a", "jets", 16, 5, &[32, 24], 3, 2, 0);
        let b = Manifest::synthetic_mlp("a", "jets", 16, 5, &[32, 24], 3, 2);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!((la.in_f, la.out_f, la.fanin), (lb.in_f, lb.out_f, lb.fanin));
        }
        assert_eq!(b.skips, 0);
    }

    #[test]
    fn skip_in_widths_sums_newest_history() {
        assert_eq!(Manifest::skip_in_widths(16, &[32, 24], 0), vec![16, 32, 24]);
        assert_eq!(Manifest::skip_in_widths(16, &[32, 24], 1), vec![16, 48, 56]);
        assert_eq!(Manifest::skip_in_widths(16, &[32, 24], 2), vec![16, 48, 72]);
        assert_eq!(Manifest::skip_in_widths(10, &[], 3), vec![10]);
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.num_layers(), 3);
        assert_eq!(m.layers[2].fanin, None);
        assert_eq!(m.layers[1].in_f, 32);
        assert_eq!(m.fanin_fc, None);
        assert!((m.bn_eps - 1e-5).abs() < 1e-12);
    }

    const CNV_SAMPLE: &str = r#"{
      "name":"c","kind":"cnv","in_features":16,"classes":3,"hidden":[],
      "bw":2,"bw_in":2,"bw_out":2,"fanin":4,"skips":0,
      "batch":8,"eval_batch":8,"dataset":"jets",
      "layers":[{"in":16,"out":8,"fanin":4,"bw_in":2,"maxv_in":1.0},
                {"in":8,"out":3,"fanin":null,"bw_in":2,"maxv_in":2.0}],
      "conv_mode":"dense","image_hw":4,"channels":[2],"kernel_size":3,
      "fanin_dw":4,"fanin_pw":4
    }"#;

    #[test]
    fn parses_and_validates_cnv_sample() {
        let m = Manifest::parse(CNV_SAMPLE).unwrap();
        assert_eq!(m.kind, "cnv");
        let kinds = m.layer_kinds().unwrap();
        assert!(matches!(kinds[0], LayerKind::Conv(_)));
        assert!(matches!(kinds[1], LayerKind::Dense));
        let geoms = m.conv_geoms().unwrap();
        assert_eq!(geoms.len(), 1);
        assert_eq!((geoms[0].in_f(), geoms[0].out_f()), (16, 8));
    }

    #[test]
    fn cnv_parse_rejects_bad_extras_with_named_fields() {
        // Each broken field must fail at parse time with a message that
        // names it (satellite: no more silent load + deep-synth failure).
        for (needle, patch) in [
            ("kernel_size", (r#""kernel_size":3"#, r#""kernel_size":0"#)),
            ("odd", (r#""kernel_size":3"#, r#""kernel_size":4"#)),
            ("conv_mode", (r#""conv_mode":"dense""#, r#""conv_mode":"winograd""#)),
            ("channels", (r#""channels":[2]"#, r#""channels":[]"#)),
            ("layer count", (r#""hidden":[]"#, r#""hidden":[7]"#)),
            ("divisible", (r#""image_hw":4"#, r#""image_hw":3"#)),
            ("skip", (r#""skips":0"#, r#""skips":1"#)),
            ("fanin", (r#""in":16,"out":8,"fanin":4"#, r#""in":16,"out":8,"fanin":3"#)),
        ] {
            let text = CNV_SAMPLE.replace(patch.0, patch.1);
            let err = Manifest::parse(&text).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "patch {patch:?} -> {msg}");
        }
    }

    #[test]
    fn conv_geom_windows_shared_sorted_in_range() {
        let g = ConvGeom {
            h_in: 6,
            h_out: 3,
            c_in: 2,
            c_out: 3,
            k: 3,
            stride: 2,
            depthwise: false,
            window_fanin: 5,
            seed: 99,
        };
        assert_eq!(g.window(), 18);
        let rows = g.neuron_windows();
        assert_eq!(rows.len(), g.out_f());
        for (o, row) in rows.iter().enumerate() {
            let oc = o % g.c_out;
            let kept = g.kept_slots(oc);
            assert_eq!(kept, g.kept_slots(oc), "kept slots deterministic");
            assert!(row.len() <= g.window_fanin);
            // strictly increasing input indices (Mask invariant) in range
            assert!(row.windows(2).all(|w| w[0].1 < w[1].1), "neuron {o}");
            assert!(row.iter().all(|&(s, i)| kept.contains(&s) && i < g.in_f()));
        }
        // interior neuron (oy=1, ox=1) keeps the full subsampled window
        let interior = &rows[(g.h_out + 1) * g.c_out];
        assert_eq!(interior.len(), g.window_fanin);
        // pricing matches the explicit per-row sum
        let by_rows: u64 = rows
            .iter()
            .map(|r| crate::cost::lut_cost(r.len() * 2, 2))
            .fold(0, |a, c| a.saturating_add(c));
        assert_eq!(g.lut_cost(2, 2), by_rows);
    }

    #[test]
    fn conv_stage_geoms_dense_and_dw() {
        let dense = Manifest::conv_stage_geoms(8, 1, &[4, 6], 3, "dense", Some(5), None).unwrap();
        assert_eq!(dense.len(), 2);
        assert_eq!((dense[0].h_in, dense[0].h_out, dense[0].c_out), (8, 4, 4));
        assert_eq!((dense[1].h_in, dense[1].h_out, dense[1].c_in), (4, 2, 4));
        assert_eq!(dense[0].window_fanin, 5);
        let dw = Manifest::conv_stage_geoms(8, 1, &[4], 3, "dw", Some(6), Some(2)).unwrap();
        assert_eq!(dw.len(), 2, "dw lowers to depthwise + pointwise");
        assert!(dw[0].depthwise && !dw[1].depthwise);
        assert_eq!((dw[0].stride, dw[1].stride), (2, 1));
        assert_eq!((dw[0].c_out, dw[1].c_out), (1, 4));
        assert_eq!(dw[1].k, 1);
        assert!(Manifest::conv_stage_geoms(8, 1, &[4], 9, "dense", None, None).is_err());
    }

    #[test]
    fn synthetic_conv_wiring_and_task_entry() {
        let m = Manifest::synthetic_conv(
            "c", "jets", 4, 1, 5, &[3], 3, "dense", Some(4), None, &[8], 3, 2,
        )
        .unwrap();
        assert_eq!(m.kind, "cnv");
        assert_eq!(m.in_features, 16);
        assert_eq!(m.num_layers(), 3);
        assert_eq!((m.layers[0].in_f, m.layers[0].out_f), (16, 2 * 2 * 3));
        assert_eq!(m.layers[0].fanin, Some(4));
        assert_eq!((m.layers[1].in_f, m.layers[1].out_f), (12, 8));
        assert_eq!((m.layers[2].in_f, m.layers[2].out_f, m.layers[2].fanin), (8, 5, None));
        // task entry infers a 4x4 1-channel image from 16 flat features
        let t = Manifest::synthetic_conv_for_task("t", "jets", 16, 5, &[8], 3, 2, "dense", 3, 3)
            .unwrap();
        assert_eq!(t.image_hw, 4);
        assert_eq!(t.layers[0].out_f, 12);
        assert!(Manifest::synthetic_conv_for_task("t", "jets", 15, 5, &[8], 3, 2, "dense", 3, 3)
            .is_err());
        // conv fan-in is capped so tables stay enumerable
        let wide = Manifest::synthetic_conv_for_task("w", "jets", 16, 5, &[], 9, 4, "dense", 2, 3)
            .unwrap();
        assert!(wide.layers[0].fanin.unwrap() * wide.bw <= crate::luts::MAX_IN_BITS);
    }

    #[test]
    fn conv_image_side_exact_squares_only() {
        assert_eq!(Manifest::conv_image_side(16), Some(4));
        assert_eq!(Manifest::conv_image_side(784), Some(28));
        assert_eq!(Manifest::conv_image_side(15), None);
        assert_eq!(Manifest::conv_image_side(0), None);
    }
}
