//! Model manifest: the contract between `python/compile/aot.py` and the Rust
//! coordinator.  Parsed from `artifacts/<model>/manifest.json`.
//!
//! The manifest pins the *flattened* input/output ordering of the HLO
//! entry points (see the module docstring of python/compile/model.py) plus
//! every quantizer constant the export path (truth tables) must reproduce.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

/// One linear (or conv stage) layer as seen by the HLO artifact.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    /// Input width (already accounts for skip concatenation).
    pub in_f: usize,
    /// Output width (neuron count).
    pub out_f: usize,
    /// Per-neuron fan-in in synapses; `None` = dense.
    pub fanin: Option<usize>,
    /// Bit-width of the quantizer applied to this layer's *input*.
    pub bw_in: usize,
    /// max_val of that input quantizer.
    pub maxv_in: f32,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub kind: String,
    pub in_features: usize,
    pub classes: usize,
    pub hidden: Vec<usize>,
    pub bw: usize,
    pub bw_in: usize,
    pub bw_out: usize,
    pub fanin: usize,
    pub fanin_fc: Option<usize>,
    pub skips: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub maxv_in: f32,
    pub maxv_hidden: f32,
    pub maxv_out: f32,
    pub momentum: f32,
    pub bn_eps: f32,
    pub dataset: String,
    pub train_softmax: bool,
    pub steps: usize,
    pub lr: f32,
    pub layers: Vec<LayerSpec>,
    // CNN extras (None for MLPs)
    pub conv_mode: Option<String>,
    pub image_hw: usize,
    pub channels: Vec<usize>,
    pub kernel_size: usize,
    pub fanin_dw: Option<usize>,
    pub fanin_pw: Option<usize>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest json")?;
        let layers = j
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow!("layers not array"))?
            .iter()
            .map(|l| {
                Ok(LayerSpec {
                    in_f: l.req_usize("in")?,
                    out_f: l.req_usize("out")?,
                    fanin: l.get("fanin").and_then(|v| v.as_usize()),
                    bw_in: l.req_usize("bw_in")?,
                    maxv_in: l.req_f64("maxv_in")? as f32,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let usv = |key: &str| -> Vec<usize> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default()
        };
        Ok(Manifest {
            name: j.req_str("name")?.to_string(),
            kind: j.req_str("kind")?.to_string(),
            in_features: j.req_usize("in_features")?,
            classes: j.req_usize("classes")?,
            hidden: usv("hidden"),
            bw: j.req_usize("bw")?,
            bw_in: j.req_usize("bw_in")?,
            bw_out: j.req_usize("bw_out")?,
            fanin: j.req_usize("fanin")?,
            fanin_fc: j.get("fanin_fc").and_then(|v| v.as_usize()),
            skips: j.opt_usize("skips").unwrap_or(0),
            batch: j.req_usize("batch")?,
            eval_batch: j.req_usize("eval_batch")?,
            maxv_in: j.opt_f64("maxv_in", 1.0) as f32,
            maxv_hidden: j.opt_f64("maxv_hidden", 2.0) as f32,
            maxv_out: j.opt_f64("maxv_out", 4.0) as f32,
            momentum: j.opt_f64("momentum", 0.9) as f32,
            bn_eps: j.opt_f64("bn_eps", 1e-5) as f32,
            dataset: j.req_str("dataset")?.to_string(),
            train_softmax: j.opt_bool("train_softmax", true),
            steps: j.opt_usize("steps").unwrap_or(300),
            lr: j.opt_f64("lr", 0.02) as f32,
            layers,
            conv_mode: j.get("conv_mode").and_then(|v| v.as_str()).map(|s| s.to_string()),
            image_hw: j.opt_usize("image_hw").unwrap_or(28),
            channels: usv("channels"),
            kernel_size: j.opt_usize("kernel_size").unwrap_or(3),
            fanin_dw: j.get("fanin_dw").and_then(|v| v.as_usize()),
            fanin_pw: j.get("fanin_pw").and_then(|v| v.as_usize()),
        })
    }

    pub fn load(path: &std::path::Path) -> Result<Manifest> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        Manifest::parse(&text)
    }

    /// Per-layer input widths of a skip-concat topology: layer `i`'s input
    /// is the newest-first concatenation of the last `min(skips, i) + 1`
    /// activations (`act_0` = the raw input, `act_j` = hidden layer `j-1`'s
    /// output), exactly the wiring `nn::export`, `luts::forward_codes` and
    /// `serve::engine` execute.  Returns one width per layer (hidden layers
    /// first, classifier head last).  This is the single source of truth
    /// for skip-widened `in_f`, shared by [`Manifest::synthetic_topology`]
    /// and the DSE cost gate so analytical pricing can never diverge from
    /// the manifest a candidate actually builds.
    pub fn skip_in_widths(in_features: usize, hidden: &[usize], skips: usize) -> Vec<usize> {
        let mut act_widths = Vec::with_capacity(hidden.len() + 1);
        act_widths.push(in_features);
        act_widths.extend_from_slice(hidden);
        (0..=hidden.len())
            .map(|i| {
                let lo = i.saturating_sub(skips);
                act_widths[lo..=i].iter().sum()
            })
            .collect()
    }

    /// [`Manifest::synthetic_topology`] without skip connections — the
    /// original uniform entry point, kept for callers that only speak the
    /// rectangle family.
    pub fn synthetic_mlp(
        name: &str,
        dataset: &str,
        in_features: usize,
        classes: usize,
        hidden: &[usize],
        fanin: usize,
        bw: usize,
    ) -> Manifest {
        Self::synthetic_topology(name, dataset, in_features, classes, hidden, fanin, bw, 0)
    }

    /// Build an in-memory MLP manifest with the repo's standard quantizer
    /// scales (maxv 1.0 / 2.0 / 4.0, as every hep/mnist config uses) — the
    /// entry point for *generated* models that have no artifact on disk.
    /// The design-space exploration engine (`crate::dse::search`) produces
    /// these, trains them through `train::native`, and feeds them into the
    /// exact same export → tables → synth → serve pipeline as artifact
    /// models.  Sparse hidden layers at `fanin`, dense classifier head.
    ///
    /// `hidden` may be any per-layer width schedule (rectangle, pyramid
    /// taper, …) and `skips` wires newest-first skip concatenation: each
    /// layer's `in_f` is widened by the earlier activations it consumes
    /// ([`Manifest::skip_in_widths`]), which is what `cost::manifest_cost`
    /// prices and `ModelState::init` allocates.
    pub fn synthetic_topology(
        name: &str,
        dataset: &str,
        in_features: usize,
        classes: usize,
        hidden: &[usize],
        fanin: usize,
        bw: usize,
        skips: usize,
    ) -> Manifest {
        let in_widths = Self::skip_in_widths(in_features, hidden, skips);
        let mut layers = Vec::with_capacity(hidden.len() + 1);
        for (i, &h) in hidden.iter().enumerate() {
            layers.push(LayerSpec {
                in_f: in_widths[i],
                out_f: h,
                fanin: Some(fanin.min(in_widths[i])),
                bw_in: bw,
                maxv_in: if i == 0 { 1.0 } else { 2.0 },
            });
        }
        layers.push(LayerSpec {
            in_f: in_widths[hidden.len()],
            out_f: classes,
            fanin: None,
            bw_in: bw,
            maxv_in: if hidden.is_empty() { 1.0 } else { 2.0 },
        });
        Manifest {
            name: name.to_string(),
            kind: "mlp".to_string(),
            in_features,
            classes,
            hidden: hidden.to_vec(),
            bw,
            bw_in: bw,
            bw_out: bw,
            fanin,
            fanin_fc: None,
            skips,
            batch: 64,
            eval_batch: 256,
            maxv_in: 1.0,
            maxv_hidden: 2.0,
            maxv_out: 4.0,
            momentum: 0.9,
            bn_eps: 1e-5,
            dataset: dataset.to_string(),
            train_softmax: true,
            steps: 300,
            lr: 0.03,
            layers,
            conv_mode: None,
            image_hw: 28,
            channels: Vec::new(),
            kernel_size: 3,
            fanin_dw: None,
            fanin_pw: None,
        }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name":"t","kind":"mlp","in_features":16,"classes":5,"hidden":[32,32],
      "bw":2,"bw_in":2,"bw_out":2,"fanin":3,"fanin_fc":null,"skips":0,
      "batch":64,"eval_batch":128,"maxv_in":1.0,"maxv_hidden":2.0,"maxv_out":4.0,
      "momentum":0.9,"bn_eps":1e-05,"dataset":"jets","train_softmax":true,
      "steps":120,"lr":0.04,
      "layers":[{"in":16,"out":32,"fanin":3,"bw_in":2,"maxv_in":1.0},
                {"in":32,"out":32,"fanin":3,"bw_in":2,"maxv_in":2.0},
                {"in":32,"out":5,"fanin":null,"bw_in":2,"maxv_in":2.0}]
    }"#;

    #[test]
    fn synthetic_mlp_layer_wiring() {
        let m = Manifest::synthetic_mlp("g", "jets", 16, 5, &[32, 24], 3, 2);
        assert_eq!(m.num_layers(), 3);
        assert_eq!(m.layers[0].in_f, 16);
        assert_eq!(m.layers[0].out_f, 32);
        assert_eq!(m.layers[0].fanin, Some(3));
        assert_eq!(m.layers[0].maxv_in, 1.0);
        assert_eq!(m.layers[1].in_f, 32);
        assert_eq!(m.layers[1].maxv_in, 2.0);
        assert_eq!(m.layers[2].out_f, 5);
        assert_eq!(m.layers[2].fanin, None);
        assert_eq!(m.hidden, vec![32, 24]);
        assert_eq!(m.kind, "mlp");
        // Fan-in never exceeds the layer's input width.
        let wide = Manifest::synthetic_mlp("w", "jets", 4, 2, &[8], 7, 1);
        assert_eq!(wide.layers[0].fanin, Some(4));
    }

    #[test]
    fn synthetic_topology_skip_widened_wiring() {
        // skips=1, pyramid widths: layer 1 consumes [h0, input], the head
        // [h1, h0] — newest-first concat widths, matching nn::export.
        let m = Manifest::synthetic_topology("s", "jets", 16, 5, &[32, 16], 3, 2, 1);
        assert_eq!(m.skips, 1);
        assert_eq!(m.hidden, vec![32, 16]);
        assert_eq!(m.layers[0].in_f, 16);
        assert_eq!(m.layers[1].in_f, 32 + 16);
        assert_eq!(m.layers[2].in_f, 16 + 32);
        assert_eq!(m.layers[2].fanin, None);
        // skips larger than the depth clamps at the full history.
        let deep = Manifest::synthetic_topology("d", "jets", 8, 3, &[6, 4], 2, 1, 9);
        assert_eq!(deep.layers[1].in_f, 6 + 8);
        assert_eq!(deep.layers[2].in_f, 4 + 6 + 8);
        // skips=0 reduces to the plain constructor exactly.
        let a = Manifest::synthetic_topology("a", "jets", 16, 5, &[32, 24], 3, 2, 0);
        let b = Manifest::synthetic_mlp("a", "jets", 16, 5, &[32, 24], 3, 2);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!((la.in_f, la.out_f, la.fanin), (lb.in_f, lb.out_f, lb.fanin));
        }
        assert_eq!(b.skips, 0);
    }

    #[test]
    fn skip_in_widths_sums_newest_history() {
        assert_eq!(Manifest::skip_in_widths(16, &[32, 24], 0), vec![16, 32, 24]);
        assert_eq!(Manifest::skip_in_widths(16, &[32, 24], 1), vec![16, 48, 56]);
        assert_eq!(Manifest::skip_in_widths(16, &[32, 24], 2), vec![16, 48, 72]);
        assert_eq!(Manifest::skip_in_widths(10, &[], 3), vec![10]);
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.num_layers(), 3);
        assert_eq!(m.layers[2].fanin, None);
        assert_eq!(m.layers[1].in_f, 32);
        assert_eq!(m.fanin_fc, None);
        assert!((m.bn_eps - 1e-5).abs() < 1e-12);
    }
}
