//! L3 runtime: load AOT-compiled HLO text artifacts and execute them on the
//! PJRT CPU client (`xla` crate).
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! All entry points are lowered with `return_tuple=True`, so every execution
//! returns one tuple literal which we decompose into the flat output list
//! described by the model manifest.

pub mod manifest;

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

pub use manifest::{ConvGeom, LayerKind, LayerSpec, Manifest};

/// A PJRT client wrapper; create once, share everywhere.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file into an executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse hlo text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled HLO entry point.  `run` takes the flat input literals in
/// manifest order and returns the flat output literals.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<L>(inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {}: {e:?}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {}: {e:?}", self.name))
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "lit_f32 shape/data mismatch");
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "lit_i32 shape/data mismatch");
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| anyhow!("scalar f32: {e:?}"))
}

// ---------------------------------------------------------------------------
// Artifact bundle
// ---------------------------------------------------------------------------

/// A model's full AOT bundle on disk: manifest + compiled entry points.
pub struct Artifact {
    pub manifest: Manifest,
    pub train_step: Executable,
    pub forward: Executable,
    pub dir: PathBuf,
}

impl Artifact {
    /// Load `artifacts/<name>` relative to the repo root.
    pub fn load(rt: &Runtime, artifacts_dir: &Path, name: &str) -> Result<Artifact> {
        let dir = artifacts_dir.join(name);
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("artifact {name}"))?;
        let train_step = rt.load_hlo_text(&dir.join("train_step.hlo.txt"))?;
        let forward = rt.load_hlo_text(&dir.join("forward.hlo.txt"))?;
        Ok(Artifact { manifest, train_step, forward, dir })
    }

    pub fn exists(artifacts_dir: &Path, name: &str) -> bool {
        artifacts_dir.join(name).join("manifest.json").exists()
    }
}

/// Default artifacts directory: `$LOGICNETS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("LOGICNETS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
