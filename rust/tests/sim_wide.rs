//! Wide-plane simulation equivalence suite (ISSUE 6 tentpole + satellites).
//!
//! The 256-way levelized-plan evaluator (`sim::plan`) must be bit-exact
//! against both the 64-way word path (`eval_netlist_64`) and the scalar
//! `Netlist::eval` reference — on random synthesized netlists, on *trained*
//! skip/pyramid manifests (PR 5 topologies), and across the wide-plane edge
//! cases: batch sizes off the 256-sample chunk boundary, single-sample
//! batches, empty batches and empty-output netlists.  The fused
//! `NetlistEngine` serving pass is pinned against its unfused oracle and
//! `LutEngine` on the same manifests.

use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::runtime::Manifest;
use logicnets::serve::{LutEngine, NetlistEngine};
use logicnets::sim::{eval_netlist, eval_netlist_64, eval_plan, BitMatrix, EvalPlan, SimScratch};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{synthesize, Netlist, SynthOpts};
use logicnets::train::{native, ModelState, TrainOpts};
use logicnets::util::prop::forall;
use logicnets::util::rng::Rng;

/// Random skip/pyramid topology on the jets shape — the PR 5 manifold the
/// wide path must not regress.
fn random_topology(rng: &mut Rng) -> Manifest {
    let depth = 1 + rng.below(3);
    let skips = 1 + rng.below(2);
    let mut hidden = Vec::new();
    let mut w = 6 + rng.below(8);
    for _ in 0..depth {
        hidden.push(w);
        if rng.below(2) == 0 {
            w = (w / 2).max(3);
        }
    }
    let fanin = 2 + rng.below(2);
    let bw = 1 + rng.below(2);
    Manifest::synthetic_topology("sim_wide_prop", "jets", 16, 5, &hidden, fanin, bw, skips)
}

fn synthesized(man: &Manifest, seed: u64, train: bool) -> (ExportedModel, ModelTables, Netlist) {
    let mut st = ModelState::init(man, seed, PruneMethod::APriori);
    if train {
        let ds = logicnets::hep::jets(300, seed ^ 1);
        let mut opts = TrainOpts::from_manifest(man);
        opts.steps = 6;
        opts.seed = seed;
        native::train_native(man, &mut st, &ds, &opts).unwrap();
    }
    let ex = ExportedModel::from_state(man, &st);
    let tables = ModelTables::generate(&ex).unwrap();
    let (netlist, _) = synthesize(
        &ex,
        &tables,
        SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
    )
    .unwrap();
    (ex, tables, netlist)
}

fn random_inputs(netlist: &Netlist, samples: usize, seed: u64) -> (BitMatrix, Vec<Vec<bool>>) {
    let mut rng = Rng::new(seed);
    let mut inputs = BitMatrix::new(netlist.num_inputs, samples);
    let rows: Vec<Vec<bool>> = (0..samples)
        .map(|s| {
            let bits: Vec<bool> = (0..netlist.num_inputs).map(|_| rng.f64() < 0.5).collect();
            inputs.set_column(s, &bits);
            bits
        })
        .collect();
    (inputs, rows)
}

/// 256-way ≡ 64-way ≡ scalar on one netlist/batch, plus the whole-matrix
/// tail invariant (bits at or beyond `samples` stay zero on every plane).
fn check_all_paths(netlist: &Netlist, plan: &EvalPlan, scratch: &mut SimScratch, samples: usize) {
    let (inputs, rows) = random_inputs(netlist, samples, samples as u64 ^ 0x51de);
    let wide = eval_plan(plan, &inputs, scratch);
    let word = eval_netlist_64(netlist, &inputs);
    assert_eq!(wide, word, "wide != 64-way at samples={samples}");
    for (s, bits) in rows.iter().enumerate() {
        assert_eq!(wide.column(s), netlist.eval(bits), "wide != scalar at sample {s}");
    }
    if wide.words_per_plane() > 0 {
        let rem = samples % 64;
        let tail = if rem == 0 { u64::MAX } else { (1u64 << rem) - 1 };
        for p in 0..wide.planes() {
            assert_eq!(
                wide.plane(p)[wide.words_per_plane() - 1] & !tail,
                0,
                "tail bits set on plane {p} at samples={samples}"
            );
        }
    }
}

/// Chunk-boundary sweep on random *untrained* synthesized skip manifests
/// (fast; covers the structural space broadly).
#[test]
fn prop_wide_equals_64_and_scalar_on_synthesized_netlists() {
    forall("wide-vs-64-vs-scalar", 0x61, 8, |rng: &mut Rng| {
        let man = random_topology(rng);
        let (_, _, netlist) = synthesized(&man, rng.next_u64(), false);
        let plan = netlist.compile_plan();
        let mut scratch = SimScratch::default();
        let samples = [1usize, 63, 64, 65, 255, 256, 257, 300][rng.below(8)];
        check_all_paths(&netlist, &plan, &mut scratch, samples);
    });
}

/// Full edge-case sweep (every boundary size incl. 256 multiples and the
/// empty batch) on one trained skip topology — trained weights give
/// non-degenerate truth tables, exercising the non-constant chunk kernels.
#[test]
fn trained_skip_manifest_edge_case_sweep() {
    let man = Manifest::synthetic_topology("sim_wide_train", "jets", 16, 5, &[12, 6], 3, 2, 1);
    let (_, _, netlist) = synthesized(&man, 0x7ea1, true);
    let plan = netlist.compile_plan();
    let mut scratch = SimScratch::default();
    for samples in [1usize, 2, 63, 64, 65, 127, 128, 255, 256, 257, 300, 511, 512, 513, 1000] {
        check_all_paths(&netlist, &plan, &mut scratch, samples);
    }
    // Empty batch through both paths.
    let empty = BitMatrix::new(netlist.num_inputs, 0);
    assert_eq!(eval_plan(&plan, &empty, &mut scratch).samples(), 0);
    assert_eq!(eval_netlist_64(&netlist, &empty).samples(), 0);
}

/// Trained pyramid topologies (skips >= 1, tapering widths): property-test
/// the three evaluation tiers plus the convenience `eval_netlist` wrapper.
#[test]
fn prop_trained_pyramid_wide_equivalence() {
    forall("trained-pyramid-wide", 0x62, 4, |rng: &mut Rng| {
        let man = random_topology(rng);
        let (_, _, netlist) = synthesized(&man, rng.next_u64(), true);
        let plan = netlist.compile_plan();
        let mut scratch = SimScratch::default();
        let samples = [1usize, 65, 256, 300][rng.below(4)];
        check_all_paths(&netlist, &plan, &mut scratch, samples);
        // The wrapper (compile-on-the-fly) must agree with the reused-plan
        // path bit for bit.
        let (inputs, _) = random_inputs(&netlist, samples, 0xfeed);
        assert_eq!(
            eval_netlist(&netlist, &inputs),
            eval_plan(&plan, &inputs, &mut scratch),
            "wrapper != reused plan"
        );
    });
}

/// Empty-output netlists through the wide path at chunk-straddling sizes.
#[test]
fn empty_output_netlist_wide_path() {
    let man = Manifest::synthetic_topology("sim_wide_noout", "jets", 16, 5, &[8], 3, 2, 0);
    let (_, _, mut netlist) = synthesized(&man, 5, false);
    netlist.outputs.clear();
    let plan = netlist.compile_plan();
    let mut scratch = SimScratch::default();
    for samples in [1usize, 256, 300] {
        let (inputs, _) = random_inputs(&netlist, samples, 3);
        let out = eval_plan(&plan, &inputs, &mut scratch);
        assert_eq!((out.planes(), out.samples()), (0, samples));
    }
}

/// Fused serving pass ≡ unfused oracle ≡ LutEngine on trained skip
/// manifests, across chunk-boundary batch sizes.
#[test]
fn fused_engine_matches_unfused_and_lut_on_trained_skip_manifest() {
    let man = Manifest::synthetic_topology("sim_wide_fused", "jets", 16, 5, &[16, 8], 3, 2, 1);
    let mut st = ModelState::init(&man, 0xbeef, PruneMethod::APriori);
    let ds = logicnets::hep::jets(300, 0xbeef);
    let mut opts = TrainOpts::from_manifest(&man);
    opts.steps = 6;
    opts.seed = 0xbeef;
    native::train_native(&man, &mut st, &ds, &opts).unwrap();
    let ex = ExportedModel::from_state(&man, &st);
    let tables = ModelTables::generate(&ex).unwrap();
    let lut = LutEngine::build(&ex, &tables).unwrap();
    let net = NetlistEngine::build(&ex, &tables).unwrap();
    let mut rng = Rng::new(0x99);
    for n in [1usize, 63, 64, 65, 255, 256, 257, 600] {
        let xs: Vec<f32> = (0..16 * n).map(|_| rng.f32()).collect();
        let expect = lut.infer_batch(&xs);
        assert_eq!(net.infer_batch(&xs), expect, "fused != tables at n={n}");
        assert_eq!(net.infer_batch_unfused(&xs), expect, "unfused != tables at n={n}");
    }
    // Real-data batch (the full jets slice) for good measure.
    assert_eq!(net.infer_batch(&ds.x), lut.infer_batch(&ds.x));
}
