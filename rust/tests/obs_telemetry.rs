//! Telemetry-core integration tests (DESIGN.md §13): the log2-histogram
//! contract that `ServerStats` percentiles rely on — exact bucket edges,
//! mergeable snapshots, monotone percentiles, the empty-histogram `None`
//! contract, and the property that the histogram's estimates stay within
//! one bucket of the exact (reservoir-style) percentiles of the stream.

use logicnets::obs::{
    bucket_bounds, bucket_index, Histogram, HistogramSnapshot, SnapshotReport, Span, BUCKETS,
};
use logicnets::serve::router::percentile;
use logicnets::util::rng::Rng;

#[test]
fn bucket_boundaries_are_exact_powers_of_two() {
    assert_eq!(bucket_index(0), 0);
    assert_eq!(bucket_index(1), 1);
    for k in 1..63u32 {
        let v = 1u64 << k;
        // 2^k starts a new bucket; 2^k - 1 is the last value of the one below.
        assert_eq!(bucket_index(v), ((k + 1) as usize).min(BUCKETS - 1), "2^{k}");
        assert_eq!(bucket_index(v - 1), bucket_index(v) - 1, "2^{k} - 1");
        let (lo, hi) = bucket_bounds(bucket_index(v));
        assert!(lo <= v && (v < hi || bucket_index(v) == BUCKETS - 1), "2^{k} in [{lo},{hi})");
    }
    assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
}

#[test]
fn merge_is_associative_commutative_and_count_preserving() {
    let mut rng = Rng::new(0xA11CE);
    let hs: Vec<HistogramSnapshot> = (0..3)
        .map(|_| {
            let h = Histogram::new();
            for _ in 0..500 {
                h.record(rng.below(1 << 20) as u64);
            }
            h.snapshot()
        })
        .collect();
    let left = hs[0].merge(&hs[1]).merge(&hs[2]);
    let right = hs[0].merge(&hs[1].merge(&hs[2]));
    assert_eq!(left, right);
    assert_eq!(left.count(), 1500);
    assert_eq!(hs[0].merge(&hs[1]), hs[1].merge(&hs[0]));
}

#[test]
fn percentiles_are_monotone_and_empty_is_none() {
    let empty = Histogram::new();
    assert_eq!(empty.percentile(0.5), None);
    assert_eq!(empty.snapshot().percentile(0.99), None);
    assert_eq!(empty.snapshot().mean(), None);

    let mut rng = Rng::new(7);
    let h = Histogram::new();
    for _ in 0..2000 {
        h.record(1 + rng.below(1 << 24) as u64);
    }
    let s = h.snapshot();
    let mut prev = 0.0f64;
    for i in 0..=100 {
        let v = s.percentile(i as f64 / 100.0).unwrap();
        assert!(v >= prev, "p{i} = {v} went below {prev}");
        prev = v;
    }
    assert!(s.percentile(0.0).unwrap() >= s.min as f64);
    assert!(s.percentile(1.0).unwrap() <= s.max as f64);
}

/// Random latency streams: the histogram's p50/p99 must land within one
/// log2 bucket of the exact interpolated percentile over the full sorted
/// stream — which is also what the router's reservoir reports whenever the
/// stream fits its capacity, so this is exactly the serve-path cross-check.
#[test]
fn prop_histogram_percentiles_bracket_exact_stream() {
    for seed in [1u64, 7, 42, 0xBEEF] {
        let mut rng = Rng::new(seed);
        let h = Histogram::new();
        let mut stream: Vec<f64> = Vec::new();
        for _ in 0..3000 {
            // Log-uniform latencies, ~1us .. ~16ms in ns.
            let base = 1_000u64 << rng.below(14);
            let ns = base + rng.below(base as usize) as u64;
            h.record(ns);
            stream.push(ns as f64);
        }
        stream.sort_by(f64::total_cmp);
        let s = h.snapshot();
        assert_eq!(s.count(), 3000);
        for p in [0.5, 0.9, 0.99] {
            let exact = percentile(&stream, p).unwrap();
            let est = s.percentile(p).unwrap();
            let d =
                (bucket_index(est as u64) as i64 - bucket_index(exact as u64) as i64).abs();
            assert!(d <= 1, "seed {seed} p{p}: est {est} vs exact {exact}, {d} buckets apart");
        }
    }
}

#[test]
fn span_and_registry_roundtrip_through_snapshot_json() {
    let h = logicnets::obs::histogram("test.obs_telemetry.span.ns");
    {
        let _s = Span::start(&h);
        std::thread::sleep(std::time::Duration::from_micros(50));
    }
    assert!(h.count() >= 1);
    assert!(h.percentile(0.5).unwrap() >= 50_000.0, "span under the 50us sleep");

    let snap = logicnets::obs::snapshot();
    let js = snap.to_json();
    let back = SnapshotReport::from_json(&js).unwrap();
    assert_eq!(back.to_json().to_string(), js.to_string(), "snapshot JSON is byte-stable");
    assert!(back.histogram("test.obs_telemetry.span.ns").unwrap().count() >= 1);
    assert!(!back.render().is_empty());
}
