//! Bitsliced-simulation properties: the word-parallel evaluator
//! (`logicnets::sim`) must agree bit-for-bit with the scalar
//! `Netlist::eval` reference on randomized netlists and inputs — including
//! constant nets, input-passthrough outputs, unused (skipped) inputs, and
//! batch sizes off the 64-sample word boundary — and the netlist-backed
//! serving engine must reproduce the table engine's predictions exactly.

use logicnets::luts::ModelTables;
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::serve::{LutEngine, NetlistEngine};
use logicnets::sim::{eval_netlist, BitMatrix};
use logicnets::synth::netlist::LutNode;
use logicnets::synth::{synthesize, verify_netlist, verify_netlist_scalar};
use logicnets::synth::{Net, Netlist, SynthOpts};
use logicnets::util::prop::forall;
use logicnets::util::rng::Rng;

fn random_model(seed: u64, in_f: usize, widths: &[usize], fanin: usize, bw: usize) -> ExportedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut prev = in_f;
    for (k, &w) in widths.iter().enumerate() {
        let qi = QuantSpec::new(bw, if k == 0 { 1.0 } else { 2.0 });
        let neurons = (0..w)
            .map(|_| {
                let inputs = rng.choose_k(prev, fanin.min(prev));
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect(),
                    bias: rng.normal_f32(0.0, 0.1),
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(bw, 2.0), true));
        prev = w;
    }
    ExportedModel {
        layers,
        in_features: in_f,
        classes: *widths.last().unwrap(),
        skips: 0,
        act_widths: std::iter::once(in_f).chain(widths.iter().copied()).collect(),
    }
}

/// Random netlist straight from the synthesis flow, plus a scalar-vs-sim
/// comparison over a random batch.
#[test]
fn prop_bitsliced_matches_scalar_on_synthesized_netlists() {
    forall("sim-vs-scalar", 0x51, 12, |rng: &mut Rng| {
        let in_f = 6 + rng.below(8);
        let widths = [4 + rng.below(12), 2 + rng.below(6)];
        let bw = 1 + rng.below(2);
        let fanin = 2 + rng.below(2);
        let model = random_model(rng.next_u64(), in_f, &widths, fanin, bw);
        let tables = ModelTables::generate(&model).unwrap();
        let (netlist, _) = synthesize(
            &model,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
        )
        .unwrap();
        // Batch sizes straddling the word boundary, incl. tiny ones.
        let samples = [1usize, 63, 64, 65, 150][rng.below(5)];
        let mut inputs = BitMatrix::new(netlist.num_inputs, samples);
        let rows: Vec<Vec<bool>> = (0..samples)
            .map(|s| {
                let bits: Vec<bool> =
                    (0..netlist.num_inputs).map(|_| rng.f64() < 0.5).collect();
                inputs.set_column(s, &bits);
                bits
            })
            .collect();
        let out = eval_netlist(&netlist, &inputs);
        for (s, bits) in rows.iter().enumerate() {
            assert_eq!(out.column(s), netlist.eval(bits), "sample {s}");
        }
    });
}

/// Handcrafted netlist exercising every net kind the evaluator must
/// handle: constants, direct input passthrough, an input the logic never
/// reads (skipped input), and duplicate fan-in nets.
#[test]
fn handcrafted_nets_constants_and_skipped_inputs() {
    // 4 primary inputs; input 3 is never read by any node (skipped).
    let netlist = Netlist {
        num_inputs: 4,
        nodes: vec![
            // n0 = XOR(in0, in1)
            LutNode { inputs: vec![Net::Input(0), Net::Input(1)], tt: 0b0110, level: 1 },
            // n1 = MAJ(n0, in2, in2) == duplicate fan-in net
            LutNode {
                inputs: vec![Net::Node(0), Net::Input(2), Net::Input(2)],
                tt: 0b1110_1000,
                level: 2,
            },
        ],
        outputs: vec![
            Net::Node(1),
            Net::Const0,
            Net::Const1,
            Net::Input(3), // passthrough of the otherwise-skipped input
            Net::Input(0),
        ],
        brams: vec![],
        layer_depths: vec![2],
    };
    for samples in [1usize, 64, 100, 129] {
        let mut rng = Rng::new(samples as u64);
        let mut inputs = BitMatrix::new(4, samples);
        let rows: Vec<Vec<bool>> = (0..samples)
            .map(|s| {
                let bits: Vec<bool> = (0..4).map(|_| rng.f64() < 0.5).collect();
                inputs.set_column(s, &bits);
                bits
            })
            .collect();
        let out = eval_netlist(&netlist, &inputs);
        for (s, bits) in rows.iter().enumerate() {
            assert_eq!(out.column(s), netlist.eval(bits), "samples={samples} s={s}");
        }
    }
}

/// The two equivalence checkers in `synth` must produce identical
/// pass/fail results (they share one RNG stream, so the comparison is per
/// sample, not just in aggregate).
#[test]
fn prop_verify_netlist_bitsliced_equals_scalar() {
    forall("verify-parity", 0x52, 8, |rng: &mut Rng| {
        let model = random_model(rng.next_u64(), 8 + rng.below(6), &[12, 5], 3, 2);
        let tables = ModelTables::generate(&model).unwrap();
        let (netlist, _) = synthesize(
            &model,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
        )
        .unwrap();
        let samples = 1 + rng.below(130);
        let seed = rng.next_u64();
        let fast = verify_netlist(&model, &tables, &netlist, samples, seed).unwrap();
        let slow = verify_netlist_scalar(&model, &tables, &netlist, samples, seed).unwrap();
        assert_eq!(fast, slow);
        assert_eq!(fast, 0, "synthesized netlist must be equivalent");
    });
}

/// Regression: the netlist-backed serving engine agrees with the table
/// engine on a random model with a dense classifier head.
#[test]
fn netlist_engine_agrees_with_lut_engine_on_random_model() {
    let mut rng = Rng::new(0x53);
    let mut model = random_model(9, 14, &[24, 16], 3, 2);
    // Dense head: 5 classes, un-tabulated (sparse = false).
    let prev = 16usize;
    let neurons = (0..5)
        .map(|_| {
            let inputs: Vec<usize> = (0..prev).collect();
            Neuron {
                inputs: inputs.clone(),
                weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.3)).collect(),
                bias: 0.0,
                g: 1.0,
                h: 0.0,
            }
        })
        .collect();
    model.layers.push(ExportedLayer::uniform(
        neurons,
        prev,
        QuantSpec::new(2, 2.0),
        QuantSpec::new(4, 4.0),
        false,
    ));
    model.classes = 5;
    let tables = ModelTables::generate(&model).unwrap();
    let lut = LutEngine::build(&model, &tables).unwrap();
    let net = NetlistEngine::build(&model, &tables).unwrap();
    for n in [1usize, 63, 64, 65, 257] {
        let xs: Vec<f32> = (0..14 * n).map(|_| rng.f32()).collect();
        let expect = lut.infer_batch(&xs);
        assert_eq!(net.infer_batch(&xs), expect, "n={n}");
        assert_eq!(lut.infer_batch_par(&xs), expect, "par n={n}");
    }
}
