//! Integration: the full AOT roundtrip — JAX-lowered HLO artifacts loaded
//! and driven from Rust via PJRT.  Skips (with a notice) when artifacts have
//! not been built (`make artifacts`).

use logicnets::hep;
use logicnets::metrics;
use logicnets::runtime::{artifacts_dir, Artifact, Runtime};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::train::{evaluate, train, ModelState, TrainOpts};

fn artifact(name: &str) -> Option<(Runtime, Artifact)> {
    let dir = artifacts_dir();
    if !Artifact::exists(&dir, name) {
        eprintln!("SKIP: artifact {name:?} missing (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let art = Artifact::load(&rt, &dir, name).expect("load artifact");
    Some((rt, art))
}

#[test]
fn spike_train_step_reduces_loss() {
    let Some((_rt, art)) = artifact("spike_tiny") else { return };
    let man = &art.manifest;
    assert_eq!(man.num_layers(), 3);

    let ds = hep::jets(4000, 42);
    let mut rng = logicnets::util::rng::Rng::new(7);
    let (train_set, test_set) = ds.split(0.25, &mut rng);

    let mut state = ModelState::init(man, 1, PruneMethod::APriori);
    let mut opts = TrainOpts::from_manifest(man);
    opts.steps = 400;
    opts.verbose = std::env::var("LOGICNETS_VERBOSE").is_ok();
    let log = train(&art, &mut state, &train_set, &opts).expect("train");

    let first = log.losses.first().unwrap().1;
    let last = log.final_loss;
    assert!(
        last < first * 0.9,
        "loss should decrease: first {first} last {last}"
    );

    // Evaluation through the forward artifact must beat chance (0.2).
    let logits = evaluate(&art, &state, &test_set).expect("evaluate");
    assert_eq!(logits.len(), test_set.n * man.classes);
    let acc = metrics::accuracy(&logits, &test_set.y, man.classes);
    eprintln!("spike accuracy = {acc:.3}");
    assert!(acc > 0.35, "accuracy {acc} not above chance");
}

#[test]
fn forward_is_deterministic() {
    let Some((_rt, art)) = artifact("spike_tiny") else { return };
    let man = &art.manifest;
    let state = ModelState::init(man, 3, PruneMethod::APriori);
    let ds = hep::jets(man.eval_batch * 2, 5);
    let a = evaluate(&art, &state, &ds).expect("eval a");
    let b = evaluate(&art, &state, &ds).expect("eval b");
    assert_eq!(a, b, "forward pass must be bit-deterministic");
}

#[test]
fn logits_respect_output_quantizer_grid() {
    // Every logit must be a representable value of the bw_out quantizer:
    // c * maxv_out / (2^bw_out - 1) for integer c, within [0, maxv_out].
    let Some((_rt, art)) = artifact("spike_tiny") else { return };
    let man = &art.manifest;
    let state = ModelState::init(man, 9, PruneMethod::APriori);
    let ds = hep::jets(man.eval_batch, 6);
    let logits = evaluate(&art, &state, &ds).expect("eval");
    let levels = (1u32 << man.bw_out) - 1;
    let step = man.maxv_out / levels as f32;
    for &v in &logits {
        let c = v / step;
        assert!(
            (c - c.round()).abs() < 1e-4,
            "logit {v} not on the quantizer grid (step {step})"
        );
        assert!(v >= -1e-6 && v <= man.maxv_out + 1e-6);
    }
}
