//! Netlist-optimizer suite (ISSUE 2 tentpole): every pass is
//! equivalence-preserving on randomized models (exhaustive bitsliced check
//! where the input bus permits, sampled otherwise), LUT count is
//! monotonically non-increasing per pass, and the pipeline is idempotent at
//! its fixed point.

use logicnets::luts::ModelTables;
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::serve::{LutEngine, NetlistEngine};
use logicnets::synth::opt::{self, OptLevel, Pass};
use logicnets::synth::{
    synthesize, verify_netlist, verify_netlist_exhaustive, Netlist, SynthOpts,
};
use logicnets::util::rng::Rng;

fn random_model(seed: u64, in_f: usize, widths: &[usize], fanin: usize, bw: usize) -> ExportedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut prev = in_f;
    for (k, &w) in widths.iter().enumerate() {
        let qi = QuantSpec::new(bw, if k == 0 { 1.0 } else { 2.0 });
        let neurons = (0..w)
            .map(|_| {
                let inputs = rng.choose_k(prev, fanin.min(prev));
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect(),
                    bias: rng.normal_f32(0.0, 0.1),
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(bw, 2.0), true));
        prev = w;
    }
    ExportedModel {
        layers,
        in_features: in_f,
        classes: *widths.last().unwrap(),
        skips: 0,
        act_widths: std::iter::once(in_f).chain(widths.iter().copied()).collect(),
    }
}

fn comb_opts(opt: OptLevel) -> SynthOpts {
    SynthOpts { registers: false, bram_min_bits: 0, opt, ..SynthOpts::default() }
}

/// Equivalence of a netlist against the truth-table forward pass:
/// exhaustive when the input bus permits, sampled otherwise.
fn assert_equiv(model: &ExportedModel, tables: &ModelTables, nl: &Netlist, ctx: &str) {
    let mism = if nl.num_inputs <= 16 {
        verify_netlist_exhaustive(model, tables, nl).unwrap()
    } else {
        verify_netlist(model, tables, nl, 512, 0xE0).unwrap()
    };
    assert_eq!(mism, 0, "{ctx}: optimized netlist must match the tables");
}

#[test]
fn every_pass_is_equivalence_preserving_and_monotone() {
    // Small buses -> exhaustive; the last config (32-bit bus) -> sampled.
    for (seed, in_f, widths, fanin, bw) in [
        (1u64, 6usize, vec![12usize, 6], 3usize, 2usize),
        (2, 8, vec![16, 8], 4, 2),
        (3, 12, vec![10, 10, 4], 3, 1),
        (4, 16, vec![24, 12], 3, 2),
    ] {
        let model = random_model(seed, in_f, &widths, fanin, bw);
        let tables = ModelTables::generate(&model).unwrap();
        let (netlist, _) = synthesize(&model, &tables, comb_opts(OptLevel::None)).unwrap();
        let mut cur = netlist;
        let mut luts = cur.num_luts();
        for (step, pass) in [Pass::Cse, Pass::Sweep, Pass::Cse, Pass::Sweep]
            .into_iter()
            .enumerate()
        {
            let next = opt::run_pass(&cur, pass);
            assert!(
                next.num_luts() <= luts,
                "seed {seed} step {step}: {pass:?} grew {} -> {}",
                luts,
                next.num_luts()
            );
            assert!(
                opt::netlists_equivalent(&cur, &next, seed),
                "seed {seed} step {step}: {pass:?} changed behavior"
            );
            assert_equiv(&model, &tables, &next, &format!("seed {seed} step {step}"));
            luts = next.num_luts();
            cur = next;
        }
    }
}

#[test]
fn pipeline_is_idempotent_at_fixed_point() {
    for seed in [5u64, 6, 7] {
        let model = random_model(seed, 8, &[16, 8], 3, 2);
        let tables = ModelTables::generate(&model).unwrap();
        let (netlist, _) = synthesize(&model, &tables, comb_opts(OptLevel::None)).unwrap();
        let (o1, s1) = opt::optimize(&netlist, OptLevel::Structural);
        assert!(s1.post_luts <= s1.pre_luts, "seed {seed}");
        assert!(
            s1.pass_luts.windows(2).all(|w| w[1] <= w[0]),
            "seed {seed}: per-pass counts must be non-increasing: {:?}",
            s1.pass_luts
        );
        let (o2, s2) = opt::optimize(&o1, OptLevel::Structural);
        assert_eq!(o1, o2, "seed {seed}: a second run must be a no-op");
        assert_eq!(s2.pre_luts, s2.post_luts, "seed {seed}");
        assert_eq!(s2.rounds, 1, "seed {seed}: fixed point re-detected in one round");
        assert_equiv(&model, &tables, &o1, &format!("seed {seed} fixed point"));
    }
}

#[test]
fn full_opt_never_worse_and_always_equivalent() {
    for seed in [8u64, 9, 10] {
        let model = random_model(seed, 8, &[14, 6], 3, 2);
        let tables = ModelTables::generate(&model).unwrap();
        let (_, plain) = synthesize(&model, &tables, comb_opts(OptLevel::None)).unwrap();
        let (nl, rep) = synthesize(&model, &tables, comb_opts(OptLevel::Full)).unwrap();
        assert!(
            rep.luts <= plain.luts,
            "seed {seed}: full opt grew {} -> {}",
            plain.luts,
            rep.luts
        );
        assert_equiv(&model, &tables, &nl, &format!("seed {seed} full"));
    }
}

/// First layer saturates to the two extreme codes
/// (`ExportedLayer::saturate_binary`); every bit of a {0,3} code is
/// individually non-constant, so only reachable-code don't-care pruning
/// can exploit the correlation — and with fan-in 4 (8-bit tables) it must
/// strictly win.
#[test]
fn dont_cares_strictly_reduce_saturated_models() {
    let mut model = random_model(11, 8, &[16, 8], 4, 2);
    model.layers[0].saturate_binary();
    let tables = ModelTables::generate(&model).unwrap();
    let (_, plain) = synthesize(&model, &tables, comb_opts(OptLevel::None)).unwrap();
    let (nl, rep) = synthesize(&model, &tables, comb_opts(OptLevel::Full)).unwrap();
    assert!(
        rep.luts < plain.luts,
        "don't-care pruning must strictly reduce: {} vs {}",
        rep.luts,
        plain.luts
    );
    assert_eq!(
        verify_netlist_exhaustive(&model, &tables, &nl).unwrap(),
        0,
        "exhaustive equivalence over the whole 16-bit input space"
    );
}

#[test]
fn optimized_serving_is_bit_identical_to_tables() {
    // End-to-end: the router-facing engine built from the optimized
    // netlist must agree with the truth-table engine on every prediction.
    let mut rng = Rng::new(77);
    let model = random_model(12, 10, &[20, 10], 3, 2);
    let tables = ModelTables::generate(&model).unwrap();
    let lut = LutEngine::build(&model, &tables).unwrap();
    for level in [OptLevel::Structural, OptLevel::Full] {
        let net = NetlistEngine::build_opt(&model, &tables, level).unwrap();
        for n in [1usize, 63, 64, 65, 300] {
            let xs: Vec<f32> = (0..10 * n).map(|_| rng.f32()).collect();
            assert_eq!(
                net.infer_batch(&xs),
                lut.infer_batch(&xs),
                "{level:?} n={n}: optimized serving must stay bit-identical"
            );
        }
    }
}
