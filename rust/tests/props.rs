//! Property-based tests over coordinator invariants (util::prop is the
//! in-tree proptest substitute; every failure message carries a replay
//! seed).

use logicnets::luts::{neuron_table, ModelTables};
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::sparsity::prune::{magnitude_prune, momentum_prune_regrow};
use logicnets::sparsity::Mask;
use logicnets::synth::cover::minimize;
use logicnets::synth::BoolFn;
use logicnets::util::bits::{pack_index, unpack_index};
use logicnets::util::prop::{forall, small_size};
use logicnets::util::rng::Rng;

#[test]
fn prop_pack_unpack_roundtrip() {
    forall("pack-unpack", 0x11, 200, |rng: &mut Rng| {
        let bw = 1 + rng.below(6);
        let fanin = 1 + rng.below(8.min(24 / bw));
        let codes: Vec<u32> = (0..fanin).map(|_| rng.below(1 << bw) as u32).collect();
        let idx = pack_index(&codes, bw);
        assert!(idx < 1 << (bw * fanin));
        let mut out = vec![0u32; fanin];
        unpack_index(idx, bw, fanin, &mut out);
        assert_eq!(out, codes);
    });
}

#[test]
fn prop_quantizer_idempotent_and_monotone() {
    forall("quantizer", 0x22, 200, |rng: &mut Rng| {
        let bw = 1 + rng.below(6);
        let maxv = [1.0f32, 2.0, 4.0][rng.below(3)];
        let q = QuantSpec::new(bw, maxv);
        let x = rng.normal_f32(0.0, 3.0);
        let y = q.quantize(x);
        // idempotent
        assert_eq!(q.quantize(y), y);
        // code/dequant consistency
        assert_eq!(q.dequant(q.code(x)), y);
        // monotone
        let x2 = x + rng.f32().abs();
        assert!(q.quantize(x2) >= y);
    });
}

#[test]
fn prop_mask_pruning_invariants() {
    forall("mask-pruning", 0x33, 100, |rng: &mut Rng| {
        let in_f = 4 + small_size(rng, 60);
        let out_f = 1 + small_size(rng, 30);
        let fanin = 1 + rng.below(in_f.min(8));
        let mut mask = Mask::random(out_f, in_f, fanin, rng);
        let w: Vec<f32> = (0..out_f * in_f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let m: Vec<f32> = (0..out_f * in_f).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        // momentum prune/regrow keeps exact fan-in and index validity
        momentum_prune_regrow(&w, &m, &mut mask, fanin, 0.3 + rng.f64() * 0.5);
        for row in &mask.rows {
            assert_eq!(row.len(), fanin);
            assert!(row.windows(2).all(|p| p[0] < p[1]));
            assert!(row.iter().all(|&i| i < in_f));
        }

        // magnitude prune to a smaller target keeps the largest weights
        let target = 1.max(fanin / 2);
        magnitude_prune(&w, &mut mask, target);
        for (o, row) in mask.rows.iter().enumerate() {
            assert_eq!(row.len(), target);
            let kept_min = row
                .iter()
                .map(|&i| w[o * in_f + i].abs())
                .fold(f32::INFINITY, f32::min);
            // no discarded weight may be strictly larger than all kept ones
            let max_possible: f32 =
                (0..in_f).map(|i| w[o * in_f + i].abs()).fold(0.0, f32::max);
            assert!(kept_min <= max_possible);
        }
    });
}

#[test]
fn prop_neuron_table_consistent_with_eval() {
    forall("neuron-table", 0x44, 60, |rng: &mut Rng| {
        let bw_in = 1 + rng.below(3);
        let fanin = 1 + rng.below(4);
        let qi = QuantSpec::new(bw_in, [1.0f32, 2.0][rng.below(2)]);
        let qo = QuantSpec::new(1 + rng.below(3), 2.0);
        let nr = Neuron {
            inputs: (0..fanin).collect(),
            weights: (0..fanin).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            bias: rng.normal_f32(0.0, 0.2),
            g: 0.5 + rng.f32(),
            h: rng.normal_f32(0.0, 0.3),
        };
        let t = neuron_table(&nr, qi, qo).unwrap();
        // spot-check random entries
        for _ in 0..20 {
            let idx = rng.below(t.num_entries());
            let mut codes = vec![0u32; fanin];
            unpack_index(idx, bw_in, fanin, &mut codes);
            let vals: Vec<f32> = codes.iter().map(|&c| qi.dequant(c)).collect();
            assert_eq!(t.lookup(idx), qo.code(nr.respond(&vals)));
        }
    });
}

#[test]
fn prop_minimized_cover_equivalent() {
    forall("cover-equiv", 0x55, 40, |rng: &mut Rng| {
        let nvars = 1 + rng.below(9);
        let mut f = BoolFn::zeros(nvars);
        let density = rng.f64();
        for i in 0..f.num_entries() {
            f.set(i, rng.f64() < density);
        }
        let c = minimize(&f);
        assert!(c.equals_fn(&f));
        // cover never has more cubes than minterms
        assert!(c.cubes.len() <= f.count_ones().max(1));
    });
}

#[test]
fn prop_table_forward_equals_value_forward() {
    forall("tables-vs-values", 0x66, 25, |rng: &mut Rng| {
        let in_f = 6 + rng.below(10);
        let widths = [4 + rng.below(12), 2 + rng.below(6)];
        let bw = 1 + rng.below(2);
        let mut layers = Vec::new();
        let mut prev = in_f;
        for (k, &w) in widths.iter().enumerate() {
            let qi = QuantSpec::new(bw, if k == 0 { 1.0 } else { 2.0 });
            let neurons = (0..w)
                .map(|_| {
                    let inputs = rng.choose_k(prev, 3.min(prev));
                    Neuron {
                        inputs: inputs.clone(),
                        weights: inputs.iter().map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                        bias: rng.normal_f32(0.0, 0.2),
                        g: 1.0,
                        h: rng.normal_f32(0.0, 0.2),
                    }
                })
                .collect();
            layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(bw, 2.0), true));
            prev = w;
        }
        let model = ExportedModel {
            layers,
            in_features: in_f,
            classes: prev,
            skips: 0,
            act_widths: std::iter::once(in_f).chain(widths.iter().copied()).collect(),
        };
        let tables = ModelTables::generate(&model).unwrap();
        let xs: Vec<f32> = (0..in_f * 10).map(|_| rng.f32()).collect();
        assert_eq!(tables.verify(&model, &xs), 0);
    });
}
