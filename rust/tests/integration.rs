//! Cross-module integration: train (AOT HLO) → export → truth tables →
//! engine → Verilog → synthesis, all consistent with each other.
//! Artifact-dependent tests skip with a notice when `make artifacts` has
//! not been run.

use logicnets::cost;
use logicnets::hep;
use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::runtime::{artifacts_dir, Artifact, Runtime};
use logicnets::serve::LutEngine;
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{synthesize, verify_netlist, SynthOpts};
use logicnets::train::{evaluate, train, ModelState, TrainOpts};
use logicnets::verilog::{generate, parse_project, VerilogOpts};

fn trained_spike() -> Option<(Artifact, ModelState, logicnets::data::DataSet)> {
    let dir = artifacts_dir();
    if !Artifact::exists(&dir, "spike_tiny") {
        eprintln!("SKIP: spike_tiny artifact missing (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::cpu().expect("pjrt");
    let art = Artifact::load(&rt, &dir, "spike_tiny").expect("artifact");
    let man = art.manifest.clone();
    let mut rng = logicnets::util::rng::Rng::new(5);
    let (train_set, test_set) = hep::jets(6_000, 42).split(0.25, &mut rng);
    let mut state = ModelState::init(&man, 3, PruneMethod::APriori);
    let mut opts = TrainOpts::from_manifest(&man);
    opts.steps = 150;
    train(&art, &mut state, &train_set, &opts).expect("train");
    Some((art, state, test_set))
}

#[test]
fn full_flow_tables_engine_verilog_synth() {
    let Some((art, state, test_set)) = trained_spike() else { return };
    let man = &art.manifest;
    let model = ExportedModel::from_state(man, &state);
    let tables = ModelTables::generate(&model).expect("tables");

    // 1. Truth tables match the arithmetic mirror exactly.
    assert_eq!(tables.verify(&model, &test_set.x[..100 * test_set.d]), 0);

    // 2. Engine agrees with the mirror on final codes.
    let engine = LutEngine::build(&model, &tables).expect("engine");
    let q = model.layers.last().unwrap().quant_out;
    for row in test_set.x.chunks(test_set.d).take(100) {
        let codes = engine.infer_codes(row);
        let expect: Vec<u8> = model.forward(row).iter().map(|&v| q.code(v) as u8).collect();
        assert_eq!(codes, expect);
    }

    // 3. Verilog round-trip reproduces every table + wiring.
    let proj = generate(&model, &tables, VerilogOpts { registers: false }).expect("verilog");
    let parsed = parse_project(&proj.files).expect("parse");
    for (li, lt) in tables.layers.iter().enumerate() {
        let Some(lt) = lt else { continue };
        let layer = &parsed[&li];
        assert_eq!(layer.len(), lt.tables.len());
        for (nj, nr) in layer.iter().enumerate() {
            assert_eq!(nr.inputs, model.layers[li].neurons[nj].inputs);
            for idx in 0..lt.tables[nj].num_entries() {
                assert_eq!(nr.codes.get(idx), lt.tables[nj].lookup(idx));
            }
        }
    }

    // 4. Synthesized netlist is functionally identical and cheaper than the
    //    analytical bound.
    let (netlist, rep) = synthesize(
        &model,
        &tables,
        SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
    )
    .expect("synth");
    assert_eq!(verify_netlist(&model, &tables, &netlist, 300, 9).unwrap(), 0);
    assert!(rep.luts as u64 <= rep.analytical_luts);

    // 5. Analytical cost of the sparse layers matches the cost model.
    let manifest_costs = cost::manifest_cost(man);
    let sparse_total: u64 = manifest_costs.iter().take(2).map(|c| c.luts).sum();
    assert_eq!(sparse_total, rep.analytical_luts);
}

#[test]
fn hlo_eval_matches_rust_mirror() {
    let Some((art, state, test_set)) = trained_spike() else { return };
    let man = &art.manifest;
    let model = ExportedModel::from_state(man, &state);
    let hlo_logits = evaluate(&art, &state, &test_set).expect("evaluate");
    let rust_logits = model.forward_batch(&test_set.x);
    assert_eq!(hlo_logits.len(), rust_logits.len());
    // XLA may reorder f32 reductions; only boundary-sitting values may move
    // by one quantizer step, and they must be rare.
    let step = man.maxv_out / ((1u32 << man.bw_out) - 1) as f32;
    let mut mismatch = 0usize;
    for (a, b) in hlo_logits.iter().zip(&rust_logits) {
        let d = (a - b).abs();
        assert!(d < step + 1e-5, "divergence beyond one quantizer step: {a} vs {b}");
        if d > 1e-6 {
            mismatch += 1;
        }
    }
    let pct = mismatch as f64 / hlo_logits.len() as f64;
    assert!(pct < 0.01, "too many boundary mismatches: {pct}");
}

#[test]
fn pruning_methods_preserve_fanin_through_training() {
    let Some((art, _, _)) = trained_spike() else { return };
    let man = art.manifest.clone();
    let mut rng = logicnets::util::rng::Rng::new(8);
    let (train_set, _) = hep::jets(4_000, 43).split(0.25, &mut rng);
    for method in [
        PruneMethod::Momentum { every: 5, prune_rate: 0.4 },
        PruneMethod::Iterative { every: 5 },
    ] {
        let mut state = ModelState::init(&man, 11, method);
        let mut opts = TrainOpts::from_manifest(&man);
        opts.steps = 60;
        opts.method = method;
        let log = train(&art, &mut state, &train_set, &opts).expect("train");
        assert!(log.mask_updates > 0, "{method:?} must rewrite masks");
        for (i, spec) in man.layers.iter().enumerate() {
            if let Some(f) = spec.fanin {
                match method {
                    PruneMethod::Momentum { .. } => {
                        assert!(
                            state.masks[i].rows.iter().all(|r| r.len() == f),
                            "momentum must preserve exact fan-in"
                        );
                    }
                    _ => {
                        // iterative converges to <= target by 75% of training;
                        // with 60 steps the schedule reaches the target.
                        assert!(
                            state.masks[i].rows.iter().all(|r| r.len() <= spec.in_f),
                        );
                    }
                }
                // off-mask weights must be zero
                let dense = state.masks[i].to_dense_f32();
                for (k, m) in dense.iter().enumerate() {
                    if *m == 0.0 {
                        assert_eq!(state.ws[i][k], 0.0);
                    }
                }
            }
        }
    }
}

#[test]
fn skip_artifact_roundtrip() {
    // A skip-connection MNIST model must evaluate consistently between the
    // HLO forward and the Rust mirror (exercises the concat wiring).
    let dir = artifacts_dir();
    let name = "mnist_skipa_s2";
    if !Artifact::exists(&dir, name) {
        eprintln!("SKIP: {name} artifact missing");
        return;
    }
    let rt = Runtime::cpu().expect("pjrt");
    let art = Artifact::load(&rt, &dir, name).expect("artifact");
    let man = art.manifest.clone();
    let state = ModelState::init(&man, 3, PruneMethod::APriori);
    let ds = logicnets::mnist::synth_digits(man.eval_batch, 5);
    let hlo = evaluate(&art, &state, &ds).expect("evaluate");
    let model = ExportedModel::from_state(&man, &state);
    let rust = model.forward_batch(&ds.x);
    let step = man.maxv_out / ((1u32 << man.bw_out) - 1) as f32;
    for (a, b) in hlo.iter().zip(&rust) {
        assert!((a - b).abs() < step + 1e-5, "skip wiring mismatch: {a} vs {b}");
    }
    // tables must also agree through the skip path
    let tables = ModelTables::generate(&model).expect("tables");
    assert_eq!(tables.verify(&model, &ds.x[..20 * ds.d]), 0);
}
