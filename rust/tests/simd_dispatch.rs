//! SIMD dispatch + BRAM-capable plan equivalence suite (ISSUE 10).
//!
//! Pins the runtime-dispatched kernels against the portable oracle at
//! every level of the stack: the chunk kernel itself (`lut_chunk_at` vs
//! `lut_chunk` vs the 64-way `lut_word`, random truth tables at every
//! arity k <= 6), whole plans compiled at each supported [`SimdTier`]
//! (vs `eval_netlist_64` and scalar `Netlist::eval`), level-parallel
//! splitting vs the serial sweep, and BRAM-threshold designs — where a
//! *trained* manifest synthesized past the spill threshold must evaluate
//! bit-exactly through the wide plan, the 64-way path, and the fused
//! `NetlistEngine` serving pass.

use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::runtime::Manifest;
use logicnets::serve::{LutEngine, NetlistEngine};
use logicnets::sim::{
    eval_netlist_64, eval_plan, lut_chunk, lut_chunk_at, lut_word, BitMatrix, Chunk, EvalPlan,
    SimScratch, SimdTier, LANES,
};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{synthesize, Netlist, SynthOpts};
use logicnets::train::{native, ModelState, TrainOpts};
use logicnets::util::prop::forall;
use logicnets::util::rng::Rng;

fn random_chunk(rng: &mut Rng) -> Chunk {
    let mut c = [0u64; LANES];
    for w in c.iter_mut() {
        *w = rng.next_u64();
    }
    c
}

/// Every supported tier's chunk kernel ≡ the portable fold ≡ the 64-way
/// word kernel lane by lane, on random truth tables at every arity.
#[test]
fn prop_tier_kernels_match_portable_and_word_oracle() {
    let tiers = SimdTier::supported();
    assert!(tiers.contains(&SimdTier::Portable));
    forall("tier-kernel-equivalence", 0xd15a, 48, |rng: &mut Rng| {
        for k in 1..=6usize {
            // Random tables plus the constant corners (all-zeros /
            // all-ones short-circuit arms).
            let tts = [rng.next_u64(), 0, u64::MAX];
            let xs: Vec<Chunk> = (0..k).map(|_| random_chunk(rng)).collect();
            for tt in tts {
                let oracle = lut_chunk(tt, &xs);
                for &tier in &tiers {
                    assert_eq!(
                        lut_chunk_at(tier, tt, &xs),
                        oracle,
                        "{} != portable at k={k} tt={tt:#x}",
                        tier.name()
                    );
                }
                for l in 0..LANES {
                    let ws: Vec<u64> = xs.iter().map(|c| c[l]).collect();
                    assert_eq!(oracle[l], lut_word(tt, &ws), "lane {l} != word at k={k}");
                }
            }
        }
    });
}

fn trained_netlist(
    name: &str,
    hidden: &[usize],
    seed: u64,
    bram_min_bits: usize,
) -> (ExportedModel, ModelTables, Netlist) {
    let man = Manifest::synthetic_topology(name, "jets", 16, 5, hidden, 3, 2, 1);
    let mut st = ModelState::init(&man, seed, PruneMethod::APriori);
    let ds = logicnets::hep::jets(300, seed ^ 1);
    let mut opts = TrainOpts::from_manifest(&man);
    opts.steps = 6;
    opts.seed = seed;
    native::train_native(&man, &mut st, &ds, &opts).unwrap();
    let ex = ExportedModel::from_state(&man, &st);
    let tables = ModelTables::generate(&ex).unwrap();
    let (netlist, _) = synthesize(
        &ex,
        &tables,
        SynthOpts { registers: false, bram_min_bits, ..SynthOpts::default() },
    )
    .unwrap();
    (ex, tables, netlist)
}

fn random_inputs(netlist: &Netlist, samples: usize, seed: u64) -> (BitMatrix, Vec<Vec<bool>>) {
    let mut rng = Rng::new(seed);
    let mut inputs = BitMatrix::new(netlist.num_inputs, samples);
    let rows: Vec<Vec<bool>> = (0..samples)
        .map(|s| {
            let bits: Vec<bool> = (0..netlist.num_inputs).map(|_| rng.f64() < 0.5).collect();
            inputs.set_column(s, &bits);
            bits
        })
        .collect();
    (inputs, rows)
}

/// Plans compiled at every supported tier ≡ the 64-way path ≡ scalar on a
/// trained LUT-only netlist, across chunk-boundary batch sizes, with
/// level-parallel splitting both off and forced on.
#[test]
fn tiered_plans_match_64way_and_scalar_on_trained_manifest() {
    let (_, _, netlist) = trained_netlist("simd_tier_train", &[12, 6], 0x5eed, 0);
    for tier in SimdTier::supported() {
        let mut plan = EvalPlan::compile_with_tier(&netlist, tier);
        assert_eq!(plan.tier(), tier);
        for &level_par in &[false, true] {
            plan.set_level_parallel(level_par);
            let mut scratch = SimScratch::default();
            for samples in [1usize, 63, 64, 255, 256, 257] {
                let (inputs, rows) = random_inputs(&netlist, samples, samples as u64 ^ 0xabc);
                let wide = eval_plan(&plan, &inputs, &mut scratch);
                assert_eq!(
                    wide,
                    eval_netlist_64(&netlist, &inputs),
                    "{} lp={level_par} != 64-way at samples={samples}",
                    tier.name()
                );
                for (s, bits) in rows.iter().enumerate() {
                    assert_eq!(
                        wide.column(s),
                        netlist.eval(bits),
                        "{} lp={level_par} != scalar at sample {s}",
                        tier.name()
                    );
                }
            }
        }
    }
}

/// BRAM-threshold designs through the wide path: a trained manifest
/// synthesized at `bram_min_bits` 6 spills every neuron (fan-in 3 x 2-bit
/// codes = 6 address bits) into content-bearing BRAM records, and the
/// plan — at every tier, level-parallel on and off — must agree with
/// scalar `Netlist::eval` (which fires BRAMs in trigger order) and the
/// 64-way path bit for bit.
#[test]
fn bram_plans_match_scalar_eval_on_trained_manifest() {
    let (_, _, netlist) = trained_netlist("simd_bram_train", &[12, 6], 0xb4a3, 6);
    assert!(netlist.num_brams() > 0, "spill threshold did not trigger");
    assert!(netlist.brams_evaluable());
    for tier in SimdTier::supported() {
        let mut plan = EvalPlan::compile_with_tier(&netlist, tier);
        assert!(plan.num_bram_records() > 0);
        for &level_par in &[false, true] {
            plan.set_level_parallel(level_par);
            let mut scratch = SimScratch::default();
            for samples in [1usize, 64, 256, 300] {
                let (inputs, rows) = random_inputs(&netlist, samples, samples as u64 ^ 0xb5a);
                let wide = eval_plan(&plan, &inputs, &mut scratch);
                assert_eq!(
                    wide,
                    eval_netlist_64(&netlist, &inputs),
                    "{} lp={level_par} != 64-way at samples={samples}",
                    tier.name()
                );
                for (s, bits) in rows.iter().enumerate() {
                    assert_eq!(
                        wide.column(s),
                        netlist.eval(bits),
                        "{} lp={level_par} != scalar at sample {s}",
                        tier.name()
                    );
                }
            }
        }
    }
}

/// Fused serving over a trained BRAM-threshold design ≡ the unfused
/// oracle ≡ `LutEngine` — the end-to-end un-gating the BRAM records buy.
#[test]
fn fused_engine_serves_trained_bram_design() {
    let (ex, tables, netlist) = trained_netlist("simd_bram_serve", &[12, 6], 0xcafe, 6);
    assert!(netlist.num_brams() > 0, "spill threshold did not trigger");
    let lut = LutEngine::build(&ex, &tables).unwrap();
    let net = NetlistEngine::from_netlist(&ex, &tables, netlist).unwrap();
    let mut rng = Rng::new(0x77);
    for n in [1usize, 63, 64, 257, 600] {
        let xs: Vec<f32> = (0..16 * n).map(|_| rng.f32()).collect();
        let expect = lut.infer_batch(&xs);
        assert_eq!(net.infer_batch(&xs), expect, "fused != tables at n={n}");
        assert_eq!(net.infer_batch_unfused(&xs), expect, "unfused != tables at n={n}");
    }
}

/// Property: random untrained skip topologies, random spill thresholds —
/// whatever mix of LUT records and BRAM records falls out, the wide plan
/// at the detected tier agrees with scalar eval.
#[test]
fn prop_mixed_bram_netlists_match_scalar() {
    forall("mixed-bram-wide-vs-scalar", 0x3c, 6, |rng: &mut Rng| {
        let hidden = [6 + rng.below(8), 4 + rng.below(4)];
        let man = Manifest::synthetic_topology(
            "simd_bram_prop",
            "jets",
            16,
            5,
            &hidden,
            3,
            2,
            rng.below(2),
        );
        let st = ModelState::init(&man, rng.next_u64(), PruneMethod::APriori);
        let ex = ExportedModel::from_state(&man, &st);
        let tables = ModelTables::generate(&ex).unwrap();
        // 6 address bits per neuron: 6 spills everything, 7 nothing.
        let bram_min_bits = [6usize, 7][rng.below(2)];
        let (netlist, _) = synthesize(
            &ex,
            &tables,
            SynthOpts { registers: false, bram_min_bits, ..SynthOpts::default() },
        )
        .unwrap();
        let plan = EvalPlan::compile(&netlist);
        let mut scratch = SimScratch::default();
        let samples = [1usize, 65, 256, 300][rng.below(4)];
        let (inputs, rows) = random_inputs(&netlist, samples, rng.next_u64());
        let wide = eval_plan(&plan, &inputs, &mut scratch);
        for (s, bits) in rows.iter().enumerate() {
            assert_eq!(wide.column(s), netlist.eval(bits), "sample {s} (spill>={bram_min_bits})");
        }
    });
}

/// `LOGICNETS_SIMD` clamps the dispatch tier downward but can never raise
/// it past the hardware.  (Env mutation: other tests in this binary only
/// *read* the override, and every tier they might land on is bit-exact,
/// so the brief window is harmless.)
#[test]
fn env_override_only_lowers_dispatch() {
    let prev = std::env::var("LOGICNETS_SIMD").ok();
    std::env::set_var("LOGICNETS_SIMD", "portable");
    assert_eq!(SimdTier::detect(), SimdTier::Portable);
    assert_eq!(SimdTier::supported(), vec![SimdTier::Portable]);
    // A request for the widest tier is clamped to the hardware: with the
    // override removed, the forced tier must be one the host really has.
    std::env::set_var("LOGICNETS_SIMD", "avx512");
    let forced = SimdTier::detect();
    std::env::remove_var("LOGICNETS_SIMD");
    assert!(SimdTier::supported().contains(&forced));
    match prev {
        Some(v) => std::env::set_var("LOGICNETS_SIMD", v),
        None => std::env::remove_var("LOGICNETS_SIMD"),
    }
}
