//! Synthesis-flow integration: minimization, mapping, timing and resource
//! trends on randomly-wired exported models (no artifacts needed).

use logicnets::luts::ModelTables;
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::synth::{synthesize, verify_netlist, SynthOpts};
use logicnets::util::rng::Rng;

fn random_model(seed: u64, in_f: usize, widths: &[usize], fanin: usize, bw: usize) -> ExportedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut prev = in_f;
    for (k, &w) in widths.iter().enumerate() {
        let qi = QuantSpec::new(bw, if k == 0 { 1.0 } else { 2.0 });
        let neurons = (0..w)
            .map(|_| {
                let inputs = rng.choose_k(prev, fanin.min(prev));
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect(),
                    bias: rng.normal_f32(0.0, 0.1),
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(bw, 2.0), true));
        prev = w;
    }
    ExportedModel {
        layers,
        in_features: in_f,
        classes: *widths.last().unwrap(),
        skips: 0,
        act_widths: std::iter::once(in_f).chain(widths.iter().copied()).collect(),
    }
}

#[test]
fn equivalence_across_sizes() {
    for (seed, widths, fanin, bw) in [
        (1u64, vec![16usize, 8], 3usize, 1usize),
        (2, vec![32, 16], 3, 2),
        (3, vec![24, 24, 8], 4, 2),
        (4, vec![16, 8], 3, 3),
    ] {
        let m = random_model(seed, 16, &widths, fanin, bw);
        let tables = ModelTables::generate(&m).unwrap();
        let (netlist, rep) = synthesize(
            &m,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
        )
        .unwrap();
        let mism = verify_netlist(&m, &tables, &netlist, 150, seed).unwrap();
        assert_eq!(mism, 0, "widths={widths:?} fanin={fanin} bw={bw}");
        assert!(rep.luts as u64 <= rep.analytical_luts);
        assert!(rep.min_period_ns > 0.0);
    }
}

#[test]
fn reduction_grows_with_table_width() {
    // The paper observes larger reductions for larger analytical costs
    // (Table 5.2).  Wider tables give minimization more room.
    let small = random_model(5, 16, &[32, 16], 3, 2); // 6-bit tables
    let big = random_model(5, 16, &[32, 16], 5, 2); // 10-bit tables
    let ts = ModelTables::generate(&small).unwrap();
    let tb = ModelTables::generate(&big).unwrap();
    let opts = SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() };
    let (_, rs) = synthesize(&small, &ts, opts).unwrap();
    let (_, rb) = synthesize(&big, &tb, opts).unwrap();
    // On purely random weights the reduction *ratio* is modest either way;
    // the robust paper-shaped claim is that the absolute saving explodes
    // with the analytical cost (trained nets push the ratio itself up —
    // see `trained_like_degenerate_neurons_reduce_hard`).
    let save_small = rs.analytical_luts - rs.luts as u64;
    let save_big = rb.analytical_luts - rb.luts as u64;
    assert!(
        save_big > 4 * save_small.max(1),
        "absolute saving should grow with table width: {save_big} vs {save_small}"
    );
    assert!(rb.reduction >= 1.0 && rs.reduction >= 1.0);
}

#[test]
fn registers_tradeoff() {
    let m = random_model(6, 16, &[48, 32, 16], 4, 2);
    let tables = ModelTables::generate(&m).unwrap();
    let (_, reg) = synthesize(&m, &tables, SynthOpts::default()).unwrap();
    let (_, comb) = synthesize(
        &m,
        &tables,
        SynthOpts { registers: false, ..SynthOpts::default() },
    )
    .unwrap();
    // Registered designs: shallower critical path, more FFs, better WNS.
    assert!(reg.depth <= comb.depth);
    assert!(reg.ffs > comb.ffs);
    assert!(reg.wns_ns >= comb.wns_ns);
    // LUT count is identical — registers do not change logic.
    assert_eq!(reg.luts, comb.luts);
}

#[test]
fn trained_like_degenerate_neurons_reduce_hard() {
    // Neurons whose response saturates produce constant output bits; the
    // mapper must fold them to constants (strong Table 5.2 effect).
    let mut m = random_model(7, 16, &[32], 4, 2);
    for nr in m.layers[0].neurons.iter_mut().take(16) {
        nr.h = 100.0; // saturate high: every output bit constant 1
    }
    let tables = ModelTables::generate(&m).unwrap();
    let (_, rep) = synthesize(
        &m,
        &tables,
        SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
    )
    .unwrap();
    // half the neurons are free
    assert!(
        rep.luts as f64 <= 0.6 * rep.analytical_luts as f64,
        "{} vs {}",
        rep.luts,
        rep.analytical_luts
    );
}

#[test]
fn verilog_of_synthesizable_model_roundtrips() {
    use logicnets::verilog::{generate, parse_project, VerilogOpts};
    let m = random_model(8, 12, &[16, 8], 3, 2);
    let tables = ModelTables::generate(&m).unwrap();
    let proj = generate(&m, &tables, VerilogOpts { registers: true }).unwrap();
    // Registered top must still parse (neuron/wiring files unaffected).
    let parsed = parse_project(&proj.files).unwrap();
    assert_eq!(parsed.len(), 2);
    assert_eq!(parsed[&0].len(), 16);
    assert_eq!(parsed[&1].len(), 8);
}
